"""Build-path tests: AOT lowering, SEWB weight files, the monolithic fused
spec-step graph semantics, and train.py plumbing (smoke-scale)."""

import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile import monolithic as MONO
from compile import quantize as Q
from compile import tokenizer as tok
from compile import train as T


@pytest.fixture(scope="module")
def params():
    return {
        "target": M.init_params(M.TARGET, jax.random.PRNGKey(0)),
        "drafter": M.init_params(M.DRAFTER, jax.random.PRNGKey(1)),
    }


class TestSEWB:
    def test_roundtrip_layout(self, tmp_path, params):
        flat = M.flatten_params(params["drafter"])
        path = tmp_path / "w.bin"
        index = aot.write_weights_bin(str(path), flat)
        assert len(index) == len(flat)
        with open(path, "rb") as f:
            assert f.read(4) == b"SEWB"
            version, n = struct.unpack("<II", f.read(8))
            assert version == 1 and n == len(flat)
        # Index entries describe the same tensors in the same order.
        for (name, arr), entry in zip(flat, index):
            assert entry["name"] == name
            assert entry["shape"] == list(np.asarray(arr).shape)

    def test_quantized_variant_carries_int8(self, tmp_path, params):
        qp = Q.quantize_params(params["drafter"])
        index = aot.write_weights_bin(
            str(tmp_path / "q.bin"), M.flatten_params(qp))
        dtypes = {e["name"]: e["dtype"] for e in index}
        assert dtypes["layers.0.wq.w8"] == "i8"
        assert dtypes["layers.0.wq.scale"] == "f32"
        assert dtypes["embed"] == "f32"


class TestLowering:
    def test_forward_hlo_has_params_and_entry(self, params):
        lowered, names = aot.lower_forward(
            M.DRAFTER, params["drafter"], 16, 1, False, False, None)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        # weights + tokens = n params
        assert f"parameter({len(names)})" in text  # tokens is the last param

    def test_pallas_and_ref_lower_same_signature(self, params):
        lp, names_p = aot.lower_forward(
            M.DRAFTER, params["drafter"], 16, 1, True, False, None)
        lr, names_r = aot.lower_forward(
            M.DRAFTER, params["drafter"], 16, 1, False, False, None)
        assert names_p == names_r


class TestMonolithicSemantics:
    def test_spec_step_matches_manual_loop(self, params):
        """The fused graph must agree with a hand-rolled draft/verify loop."""
        gamma, seq = 3, 32
        fn = MONO.spec_step_fn(M.DRAFTER, M.TARGET, gamma, use_pallas=False)
        prompt = [tok.BOS_ID] + list(range(5, 17)) + [tok.SEP_ID]
        cur = len(prompt)
        tokens = jnp.asarray(prompt + [tok.PAD_ID] * (seq - cur), jnp.int32)

        n_acc, out_tokens, drafted = jax.jit(fn, static_argnums=())(
            params["drafter"], params["target"], tokens, jnp.int32(cur))
        n_acc, out_tokens, drafted = int(n_acc), np.asarray(out_tokens), np.asarray(drafted)

        # Manual reference loop.
        ids = list(prompt)
        man_drafted = []
        for i in range(gamma):
            logits = M.forward(M.DRAFTER, params["drafter"],
                               jnp.asarray(ids + [tok.PAD_ID] * (seq - len(ids)),
                                           jnp.int32), use_pallas=False)
            nxt = int(jnp.argmax(logits[len(ids) - 1]))
            man_drafted.append(nxt)
            ids.append(nxt)
        tlogits = M.forward(M.TARGET, params["target"],
                            jnp.asarray(ids + [tok.PAD_ID] * (seq - len(ids)),
                                        jnp.int32), use_pallas=False)
        man_out = [int(jnp.argmax(tlogits[cur - 1 + i])) for i in range(gamma + 1)]
        man_acc = 0
        for d, t in zip(man_drafted, man_out):
            if d != t:
                break
            man_acc += 1

        assert list(drafted) == man_drafted
        assert list(out_tokens) == man_out
        assert n_acc == man_acc

    def test_accept_count_bounds(self, params):
        gamma, seq = 4, 32
        fn = MONO.spec_step_fn(M.DRAFTER, M.TARGET, gamma, use_pallas=False)
        for seed in range(3):
            rng = np.random.default_rng(seed)
            cur = int(rng.integers(4, 20))
            toks = np.zeros(seq, np.int32)
            toks[:cur] = rng.integers(4, 44, cur)
            toks[0] = tok.BOS_ID
            n_acc, out_tokens, drafted = fn(
                params["drafter"], params["target"],
                jnp.asarray(toks), jnp.int32(cur))
            assert 0 <= int(n_acc) <= gamma
            assert out_tokens.shape == (gamma + 1,)
            assert drafted.shape == (gamma,)


class TestTrainPlumbing:
    def test_two_steps_reduce_nothing_but_run(self):
        p, hist = T.train_model(M.DRAFTER, steps=2, batch_size=2, peak_lr=1e-3,
                                log_every=10)
        assert len(hist) == 2
        assert all(np.isfinite(hist))

    def test_checkpoint_roundtrip(self, tmp_path, params):
        path = str(tmp_path / "ckpt.npz")
        T.save_checkpoint(path, params["drafter"])
        loaded = T.load_checkpoint(path, M.DRAFTER)
        t = jnp.arange(8, dtype=jnp.int32)
        a = M.forward(M.DRAFTER, params["drafter"], t, use_pallas=False)
        b = M.forward(M.DRAFTER, loaded, t, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_batch_shapes(self):
        import compile.data as D
        lex = D.build_lexicon()
        stream = D.train_stream(lex, seed=1)
        batch = T.make_batch(stream, 3)
        assert batch.shape == (3, T.MAXLEN + 1)
        assert batch.dtype == np.int32
        assert (batch[:, 0] == tok.BOS_ID).all()

    def test_greedy_decode_ref_stops_at_eos(self, params):
        ids = T.greedy_decode_ref(M.DRAFTER, params["drafter"],
                                  [tok.BOS_ID, 5, 6, tok.SEP_ID], max_new=8)
        assert len(ids) <= 4 + 8 + 1


class TestManifestOnDisk:
    """Validates the real artifacts/ when present (post `make artifacts`)."""

    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
        if not os.path.exists(path):
            pytest.skip("run `make artifacts` first")
        import json
        with open(path) as f:
            return json.load(f), os.path.dirname(path)

    def test_structure(self, manifest):
        m, _ = manifest
        assert m["tokenizer"]["vocab_size"] == tok.VOCAB_SIZE
        assert len(m["eval_samples"]) == 480
        assert set(m["variants"]) == {
            "target_fp", "target_w8a8", "drafter_fp", "drafter_w8a8"}

    def test_artifact_files_exist(self, manifest):
        m, d = manifest
        for v in m["variants"].values():
            assert os.path.exists(os.path.join(d, v["weights"]))
            for a in v["artifacts"]:
                assert os.path.exists(os.path.join(d, a["file"])), a["file"]
        for mono in m["monolithic"]:
            assert os.path.exists(os.path.join(d, mono["file"]))

    def test_eval_samples_encode(self, manifest):
        m, _ = manifest
        for s in m["eval_samples"][:50]:
            ids = tok.encode(s["prompt"]) + [tok.SEP_ID] + \
                tok.encode(s["completion"], bos=False)
            assert all(0 <= i < tok.VOCAB_SIZE for i in ids)
