"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

Hypothesis sweeps shapes and value ranges; every kernel must match ref.py to
tight f32 tolerances. This is the core correctness signal for the AOT
artifacts — the same kernels lower into the HLO the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.matmul import matmul
from compile.kernels.quant_matmul import quant_matmul
from compile.kernels.rmsnorm import rmsnorm

DIMS = st.sampled_from([8, 16, 24, 32, 48, 96, 128, 352])
SEQS = st.sampled_from([8, 16, 32, 48, 63, 64, 96, 128])


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(s=SEQS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, s, k, n, seed):
        rng = np.random.default_rng(seed)
        x, w = _rand(rng, s, k), _rand(rng, k, n)
        got = matmul(x, w)
        want = ref.matmul_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    def test_identity(self):
        x = jnp.eye(32, dtype=jnp.float32)
        np.testing.assert_allclose(matmul(x, x), x, atol=1e-6)

    def test_zero(self):
        x = jnp.zeros((16, 32), jnp.float32)
        w = jnp.ones((32, 16), jnp.float32)
        np.testing.assert_allclose(matmul(x, w), 0.0, atol=0)

    def test_odd_dims_rejected_gracefully(self):
        # _pick falls back to tile=1 for prime dims — still correct.
        rng = np.random.default_rng(0)
        x, w = _rand(rng, 7, 13), _rand(rng, 13, 5)
        np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("bm,bk,bn", [(8, 8, 8), (16, 32, 16), (32, 64, 32)])
    def test_block_shape_sweep(self, bm, bk, bn):
        rng = np.random.default_rng(1)
        x, w = _rand(rng, 64, 128), _rand(rng, 128, 96)
        got = matmul(x, w, bm=bm, bk=bk, bn=bn)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-5, atol=1e-4)


class TestQuantMatmul:
    @settings(max_examples=20, deadline=None)
    @given(s=SEQS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, s, k, n, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, s, k)
        w8 = jnp.asarray(rng.integers(-127, 128, size=(k, n)), jnp.int8)
        scale = jnp.asarray(rng.uniform(0.005, 0.05, size=n), jnp.float32)
        got = quant_matmul(x, w8, scale)
        want = ref.quant_matmul_ref(x, w8, scale)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    def test_extreme_int8(self):
        x = jnp.ones((16, 32), jnp.float32)
        w8 = jnp.full((32, 16), -127, jnp.int8)
        scale = jnp.full((16,), 0.01, jnp.float32)
        want = ref.quant_matmul_ref(x, w8, scale)
        np.testing.assert_allclose(quant_matmul(x, w8, scale), want, rtol=1e-6)

    def test_roundtrip_vs_fp(self):
        """Dequantized int8 matmul approximates the fp matmul it came from."""
        from compile.quantize import quantize_weight
        rng = np.random.default_rng(3)
        x = _rand(rng, 32, 96)
        w = np.asarray(_rand(rng, 96, 48))
        w8, scale = quantize_weight(w, qmax=127)
        got = quant_matmul(x, jnp.asarray(w8), jnp.asarray(scale))
        want = ref.matmul_ref(x, jnp.asarray(w))
        rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
        assert rel < 0.02, rel


class TestRmsnorm:
    @settings(max_examples=20, deadline=None)
    @given(s=SEQS, d=st.sampled_from([16, 24, 96, 128]),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, s, d, seed):
        rng = np.random.default_rng(seed)
        x, g = _rand(rng, s, d, scale=3.0), _rand(rng, d)
        np.testing.assert_allclose(rmsnorm(x, g), ref.rmsnorm_ref(x, g),
                                   rtol=1e-5, atol=1e-5)

    def test_unit_norm_property(self):
        """With gamma=1, output rows have RMS ~ 1."""
        rng = np.random.default_rng(5)
        x = _rand(rng, 32, 128, scale=10.0)
        out = rmsnorm(x, jnp.ones(128, jnp.float32))
        rms = jnp.sqrt(jnp.mean(out * out, axis=-1))
        np.testing.assert_allclose(rms, 1.0, atol=1e-3)

    def test_scale_invariance(self):
        """rmsnorm(c*x) == rmsnorm(x) for c > 0 (up to eps)."""
        rng = np.random.default_rng(6)
        x = _rand(rng, 16, 96)
        g = _rand(rng, 96)
        a, b = rmsnorm(x, g), rmsnorm(100.0 * x, g)
        np.testing.assert_allclose(a, b, atol=1e-3)


class TestAttention:
    @settings(max_examples=15, deadline=None)
    @given(h=st.sampled_from([1, 2, 4]), s=st.sampled_from([16, 32, 64, 96]),
           d=st.sampled_from([16, 24, 32]), causal=st.booleans(),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, h, s, d, causal, seed):
        rng = np.random.default_rng(seed)
        q, k, v = (_rand(rng, h, s, d) for _ in range(3))
        got = attention(q, k, v, causal=causal)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_causality(self):
        """Changing future K/V must not change past outputs."""
        rng = np.random.default_rng(7)
        q, k, v = (_rand(rng, 2, 64, 32) for _ in range(3))
        base = attention(q, k, v, causal=True)
        k2 = k.at[:, 40:, :].set(999.0)
        v2 = v.at[:, 40:, :].set(-999.0)
        pert = attention(q, k2, v2, causal=True)
        np.testing.assert_allclose(base[:, :40], pert[:, :40], atol=1e-5)

    def test_softmax_rows_are_convex(self):
        """Each output row is a convex combination of V rows: with V == const
        vector, output == that vector exactly."""
        rng = np.random.default_rng(8)
        q, k = _rand(rng, 2, 32, 16), _rand(rng, 2, 32, 16)
        v = jnp.broadcast_to(jnp.arange(16, dtype=jnp.float32), (2, 32, 16))
        out = attention(q, k, v, causal=True)
        np.testing.assert_allclose(out, v, rtol=1e-5, atol=1e-4)

    def test_block_shape_sweep(self):
        rng = np.random.default_rng(9)
        q, k, v = (_rand(rng, 2, 96, 24) for _ in range(3))
        want = ref.attention_ref(q, k, v)
        for bq, bkv in [(8, 8), (16, 32), (32, 16), (96, 96)]:
            got = attention(q, k, v, bq=bq, bkv=bkv)
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_large_scores_stable(self):
        """Online softmax must be stable under large score magnitudes."""
        rng = np.random.default_rng(10)
        q = _rand(rng, 1, 32, 16, scale=30.0)
        k = _rand(rng, 1, 32, 16, scale=30.0)
        v = _rand(rng, 1, 32, 16)
        out = attention(q, k, v, causal=True)
        assert bool(jnp.all(jnp.isfinite(out)))
