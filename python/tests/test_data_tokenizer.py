"""Corpus + tokenizer tests: round-trips, determinism, Spec-Bench shape."""

import pytest
from hypothesis import given, settings, strategies as st

from compile import data as D
from compile import tokenizer as tok

TEXT_ALPHABET = " abcdefghijklmnopqrstuvwxyz.,?!-0123456789:'"


@pytest.fixture(scope="module")
def lex():
    return D.build_lexicon()


class TestTokenizer:
    @settings(max_examples=50, deadline=None)
    @given(st.text(alphabet=TEXT_ALPHABET, max_size=200))
    def test_roundtrip(self, text):
        assert tok.decode(tok.encode(text)) == text

    def test_vocab_size(self):
        assert tok.VOCAB_SIZE == 48
        assert len(tok.SPEC.specials) + len(tok.SPEC.chars) == 48

    def test_specials(self):
        ids = tok.encode("ab")
        assert ids[0] == tok.BOS_ID
        assert tok.decode([tok.BOS_ID, 5, tok.EOS_ID, 6]) == tok.decode([5])

    def test_unknown_char_raises(self):
        with pytest.raises(ValueError):
            tok.encode("ABC")

    def test_ids_in_range(self):
        ids = tok.encode(TEXT_ALPHABET)
        assert all(0 <= i < tok.VOCAB_SIZE for i in ids)


class TestLexicon:
    def test_size_and_uniqueness(self, lex):
        assert len(lex.words) == D.LEXICON_SIZE
        assert len(set(lex.words)) == D.LEXICON_SIZE

    def test_deterministic(self, lex):
        assert D.build_lexicon().words == lex.words

    def test_irregular_fraction(self, lex):
        frac = sum(lex.irregular) / len(lex.irregular)
        assert 0.1 < frac < 0.3

    def test_regular_words_follow_cipher(self, lex):
        for w, t, irr in zip(lex.words, lex.translations, lex.irregular):
            if not irr:
                assert t == D.rotate_word(w)

    def test_words_fit_vocab(self, lex):
        for w in lex.words + lex.translations:
            tok.encode(w)  # must not raise


class TestEvalSet:
    def test_spec_bench_shape(self, lex):
        ev = D.eval_set(lex)
        assert len(ev) == D.EVAL_SAMPLES_TOTAL == 480
        assert len({s.task for s in ev}) == len(D.TASKS) == 13

    def test_deterministic(self, lex):
        a = D.eval_set(lex)
        b = D.eval_set(lex)
        assert [(s.prompt, s.completion) for s in a] == \
               [(s.prompt, s.completion) for s in b]

    def test_translate_avg_prompt_near_63(self, lex):
        tr = [s for s in D.eval_set(lex) if s.task == "translate"]
        avg = D.avg_prompt_len(tr)
        assert 55 <= avg <= 70, avg  # paper's S_L = 63 operating point

    def test_samples_fit_bucket(self, lex):
        for s in D.eval_set(lex):
            assert len(s.full_ids()) <= D.MAX_SAMPLE_LEN

    def test_completions_are_ground_truth(self, lex):
        for s in D.eval_set(lex)[:50]:
            body = s.prompt.split(": ", 1)[1]
            words = body.split(" ")
            assert s.completion == D.apply_task(s.task, words, lex)

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           task=st.sampled_from(D.TASKS))
    def test_any_sample_valid(self, lex, seed, task):
        s = D.make_sample(lex, task, seed)
        ids = s.full_ids()
        assert ids[0] == tok.BOS_ID and ids[-1] == tok.EOS_ID
        assert tok.SEP_ID in ids
        assert len(ids) <= D.MAX_SAMPLE_LEN


class TestTasks:
    def test_all_tasks_deterministic(self, lex):
        words = list(lex.words[:10])
        for t in D.TASKS:
            assert D.apply_task(t, words, lex) == D.apply_task(t, words, lex)

    def test_reverse_is_involution(self, lex):
        words = list(lex.words[:8])
        rev = D.apply_task("reverse-words", words, lex).split(" ")
        assert D.apply_task("reverse-words", rev, lex) == " ".join(words)

    def test_count_words(self, lex):
        assert D.apply_task("count-words", list(lex.words[:7]), lex) == "7"

    def test_translate_rev_consistent(self, lex):
        words = list(lex.words[:6])
        tr = D.apply_task("translate", words, lex).split(" ")
        tv = D.apply_task("translate-rev", words, lex).split(" ")
        assert tv == list(reversed(tr))
