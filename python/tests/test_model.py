"""L2 model tests: shapes, pallas-vs-ref equivalence, padding invariance,
quantization behaviour, flatten/unflatten round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile import quantize as Q
from compile import tokenizer as tok


@pytest.fixture(scope="module")
def params():
    return {
        "target": M.init_params(M.TARGET, jax.random.PRNGKey(0)),
        "drafter": M.init_params(M.DRAFTER, jax.random.PRNGKey(1)),
    }


def _toks(rng, n):
    return jnp.asarray(rng.integers(4, tok.VOCAB_SIZE, size=n), jnp.int32)


class TestForward:
    @pytest.mark.parametrize("name", ["target", "drafter"])
    @pytest.mark.parametrize("s", [16, 48, 128])
    def test_shapes(self, params, name, s):
        cfg = M.CONFIGS[name]
        rng = np.random.default_rng(0)
        logits = M.forward(cfg, params[name], _toks(rng, s), use_pallas=False)
        assert logits.shape == (s, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    @pytest.mark.parametrize("name", ["target", "drafter"])
    def test_pallas_matches_ref(self, params, name):
        cfg = M.CONFIGS[name]
        rng = np.random.default_rng(2)
        t = _toks(rng, 32)
        a = M.forward(cfg, params[name], t, use_pallas=True)
        b = M.forward(cfg, params[name], t, use_pallas=False)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(live=st.integers(4, 30), seed=st.integers(0, 2**31 - 1))
    def test_padding_invariance(self, params, live, seed):
        """Logits at live positions must be identical whatever PAD garbage
        follows — this is what lets the Rust runtime use seq buckets."""
        cfg = M.DRAFTER
        rng = np.random.default_rng(seed)
        t = _toks(rng, 32)
        t_padded = t.at[live:].set(tok.PAD_ID)
        t_junk = t.at[live:].set(_toks(rng, 32 - live))
        a = M.forward(cfg, params["drafter"], t_padded, use_pallas=False)
        b = M.forward(cfg, params["drafter"], t_junk, use_pallas=False)
        np.testing.assert_allclose(a[:live], b[:live], atol=1e-6)

    def test_bucket_consistency(self, params):
        """Same prompt padded into two different buckets -> same live logits
        (up to f32 reassociation)."""
        cfg = M.DRAFTER
        rng = np.random.default_rng(3)
        t16 = _toks(rng, 16)
        t64 = jnp.concatenate([t16, jnp.zeros(48, jnp.int32)])
        a = M.forward(cfg, params["drafter"], t16, use_pallas=False)
        b = M.forward(cfg, params["drafter"], t64, use_pallas=False)
        np.testing.assert_allclose(a, b[:16], rtol=1e-4, atol=1e-4)

    def test_batch_matches_single(self, params):
        cfg = M.DRAFTER
        rng = np.random.default_rng(4)
        batch = jnp.stack([_toks(rng, 24) for _ in range(4)])
        lb = M.forward_batch(cfg, params["drafter"], batch, use_pallas=False)
        for i in range(4):
            li = M.forward(cfg, params["drafter"], batch[i], use_pallas=False)
            np.testing.assert_allclose(lb[i], li, atol=1e-5)

    def test_flops_model_monotonic(self):
        f = [M.TARGET.flops_per_token(s) for s in (16, 32, 64, 128)]
        assert f == sorted(f)
        assert M.TARGET.flops_per_token(63) > M.DRAFTER.flops_per_token(63)


class TestParamsPlumbing:
    @pytest.mark.parametrize("name", ["target", "drafter"])
    def test_flatten_roundtrip(self, params, name):
        cfg = M.CONFIGS[name]
        flat = M.flatten_params(params[name])
        rebuilt = M.unflatten_params(cfg, dict(flat))
        t = jnp.arange(16, dtype=jnp.int32)
        a = M.forward(cfg, params[name], t, use_pallas=False)
        b = M.forward(cfg, rebuilt, t, use_pallas=False)
        np.testing.assert_allclose(a, b, atol=0)

    def test_flatten_deterministic_order(self, params):
        f1 = [n for n, _ in M.flatten_params(params["target"])]
        f2 = [n for n, _ in M.flatten_params(params["target"])]
        assert f1 == f2
        assert f1[0] == "embed" and f1[1] == "head"

    def test_quantized_flatten_has_w8_and_scale(self, params):
        qp = Q.quantize_params(params["drafter"])
        names = [n for n, _ in M.flatten_params(qp)]
        assert "layers.0.wq.w8" in names and "layers.0.wq.scale" in names

    def test_param_count_matches(self, params):
        flat = M.flatten_params(params["target"])
        total = sum(int(np.prod(v.shape)) for _, v in flat)
        assert total == M.TARGET.param_count()


class TestQuantization:
    def test_weight_roundtrip_error_small_int8(self, params):
        qp = Q.quantize_params(params["target"], qmax=127)
        err = Q.quantization_error(params["target"], qp)
        assert err < 0.01, err

    def test_narrow_grid_degrades_more(self, params):
        """The reproduction scheme (qmax=2) must perturb weights far more
        than true int8 — that's its purpose (see quantize.py docs)."""
        e127 = Q.quantization_error(params["target"],
                                    Q.quantize_params(params["target"], qmax=127))
        e2 = Q.quantization_error(params["target"],
                                  Q.quantize_params(params["target"], qmax=2))
        assert e2 > 10 * e127

    def test_quant_forward_close_but_not_equal(self, params):
        """w8a8 must perturb logits (that's the entire Fig. 5 mechanism) but
        keep them in the same ballpark."""
        cfg = M.DRAFTER
        p = params["drafter"]
        scales = Q.calibrate_act_scales(
            cfg, p, [np.arange(24, dtype=np.int32)[None, :] % 44 + 4])
        qp = Q.quantize_params(p)
        t = jnp.arange(24, dtype=jnp.int32) % 44 + 4
        a = M.forward(cfg, p, t, use_pallas=False)
        b = M.forward(cfg, qp, t, use_pallas=False, quant=True, act_scales=scales)
        diff = float(jnp.max(jnp.abs(a - b)))
        assert 1e-6 < diff < 5.0, diff

    def test_quant_pallas_matches_quant_ref(self, params):
        cfg = M.DRAFTER
        p = params["drafter"]
        scales = Q.calibrate_act_scales(
            cfg, p, [np.arange(16, dtype=np.int32)[None, :] % 44 + 4])
        qp = Q.quantize_params(p)
        t = jnp.arange(16, dtype=jnp.int32) % 44 + 4
        a = M.forward(cfg, qp, t, use_pallas=True, quant=True, act_scales=scales)
        b = M.forward(cfg, qp, t, use_pallas=False, quant=True, act_scales=scales)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_act_scales_positive_and_complete(self, params):
        cfg = M.DRAFTER
        scales = Q.calibrate_act_scales(
            cfg, params["drafter"],
            [np.arange(16, dtype=np.int32)[None, :] % 44 + 4])
        assert len(scales) == cfg.n_layers * len(M.LINEARS)
        assert all(v > 0 for v in scales.values())
