"""Static w8a8 quantization (Intel-Neural-Compressor-style QDQ, see DESIGN.md §1).

* Weights: symmetric per-output-channel int8. The int8 tensors + f32 scales
  are what the artifacts carry (the quant_matmul Pallas kernel dequantizes
  in-tile), so the quantized variants genuinely ship 4x-smaller linears.
* Activations: static per-tensor scales, calibrated by running the FP model
  over a calibration slice of the corpus and recording max |activation| at
  every linear input; the QDQ pair is applied in-graph at inference.

This is the mechanism behind the paper's Fig. 5: quantization perturbs the
drafter/target output distributions *differently*, which lowers the
acceptance rate alpha — the fully-quantized pair collapses, the
semi-quantized pair (target-only, the paper's deployment point) lands in
between with a broad per-sample spread.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import model as M

ACT_MARGIN = 1.0  # use plain max; the corpus is narrow enough not to need percentiles


# Integer grid half-width. 127 = true int8 (the INC recipe). At our
# sub-1M-param substitute scale, int8 barely perturbs argmax decisions —
# models this small are far more quantization-robust than the paper's 3B
# Llama — so the *reproduction* scheme narrows the grid (default qmax=2,
# ~2.3 effective bits) to induce the same drafter/target distributional
# mismatch w8a8 induces at 3B scale. Everything else (symmetric
# per-output-channel weights, static per-tensor activations) matches the
# INC recipe. Measured alpha vs qmax is reported in EXPERIMENTS.md.
DEFAULT_QMAX = 2


def quantize_weight(w: np.ndarray, qmax: int = DEFAULT_QMAX):
    """Symmetric per-output-channel integer quant: w ~ w8 * scale[None, :]."""
    w = np.asarray(w, np.float32)
    amax = np.max(np.abs(w), axis=0)          # per output column
    scale = np.where(amax > 0, amax / qmax, 1.0).astype(np.float32)
    w8 = np.clip(np.round(w / scale[None, :]), -qmax, qmax).astype(np.int8)
    return w8, scale


def dequantize_weight(w8: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return w8.astype(np.float32) * scale[None, :].astype(np.float32)


def calibrate_act_scales(cfg: M.ModelConfig, params: dict, token_batches) -> dict:
    """Run the FP reference model over calibration batches, recording the max
    |activation| feeding every linear; returns {linear_name: float_scale}."""
    recorder: dict = {}
    for toks in token_batches:
        toks = jnp.asarray(toks, jnp.int32)
        if toks.ndim == 1:
            toks = toks[None, :]
        for row in toks:
            M.forward(cfg, params, row, use_pallas=False, recorder=recorder)
    scales = {}
    for name, amax in recorder.items():
        amax = max(float(amax), 1e-6) * ACT_MARGIN
        scales[name] = amax / 127.0
    return scales


def quantize_params(params: dict, qmax: int = DEFAULT_QMAX) -> dict:
    """Replace every linear weight by {'w8': int8, 'scale': f32[N]}; norms,
    embedding and LM head stay fp32 (standard w8a8 recipe)."""
    out = {
        "embed": params["embed"],
        "head": params["head"],
        "final_norm": params["final_norm"],
        "layers": [],
    }
    for layer in params["layers"]:
        qlayer = {}
        for name, w in layer.items():
            if name in M.LINEARS:
                w8, scale = quantize_weight(np.asarray(w), qmax)
                qlayer[name] = {"w8": jnp.asarray(w8), "scale": jnp.asarray(scale)}
            else:
                qlayer[name] = w
        out["layers"].append(qlayer)
    return out


def quantization_error(params: dict, qparams: dict) -> float:
    """Mean relative Frobenius error across quantized linears (sanity metric,
    reported in the manifest)."""
    errs = []
    for layer, qlayer in zip(params["layers"], qparams["layers"]):
        for name in M.LINEARS:
            w = np.asarray(layer[name], np.float32)
            wq = dequantize_weight(np.asarray(qlayer[name]["w8"]),
                                   np.asarray(qlayer[name]["scale"]))
            errs.append(float(np.linalg.norm(w - wq) / (np.linalg.norm(w) + 1e-12)))
    return float(np.mean(errs))
