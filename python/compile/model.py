"""L2: Llama-style decoder-only transformer (target + drafter).

The paper uses Llama 3.2 3B (target) and 1B (drafter); we substitute a
structurally identical tiny pair (RMSNorm + RoPE + causal MHA + SwiGLU,
pre-norm, untied head) trained on the synthetic corpus — see DESIGN.md §1.
The drafter is the same architecture at roughly 1/3 the FLOPs, mirroring the
paper's draft/target cost ratio.

The forward pass is written once and can run in three modes:

* ``use_pallas=True``  — linear/norm/attention hot spots go through the L1
  Pallas kernels (this is what gets AOT-lowered into the HLO artifacts);
* ``use_pallas=False`` — pure-jnp reference path (training, and the oracle
  the Pallas path is tested against);
* ``quant=True``       — static w8a8: int8 weights (per-output-channel
  scales) through the quant_matmul kernel, activations fake-quantized with
  static scales calibrated offline (compile/quantize.py).

No KV cache (paper Table I): each call re-encodes the whole (padded)
sequence; causal masking makes PAD positions inert, so the Rust runtime pads
to a seq bucket and reads logits at live positions only.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.attention import attention as attention_pl
from .kernels.matmul import matmul as matmul_pl
from .kernels.quant_matmul import quant_matmul as quant_matmul_pl
from .kernels.rmsnorm import rmsnorm as rmsnorm_pl
from .tokenizer import VOCAB_SIZE


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    ffn_dim: int
    vocab: int = VOCAB_SIZE
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, v, L = self.d_model, self.ffn_dim, self.vocab, self.n_layers
        per_layer = 4 * d * d + 3 * d * f + 2 * d  # attn + mlp + 2 norms
        return v * d + L * per_layer + d + d * v   # embed + layers + norm + head

    def flops_per_token(self, seq_len: int) -> float:
        """Forward FLOPs per *sequence* (all positions), the quantity the
        analytic PU latency model consumes. 2*MACs convention."""
        d, f, L, v = self.d_model, self.ffn_dim, self.n_layers, self.vocab
        s = seq_len
        linear = 2 * s * (4 * d * d + 3 * d * f) * L
        attn = 2 * s * s * d * 2 * L  # QK^T and PV, both ~ s^2 * d per layer
        head = 2 * s * d * v
        return float(linear + attn + head)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "n_layers": self.n_layers,
            "d_model": self.d_model,
            "n_heads": self.n_heads,
            "ffn_dim": self.ffn_dim,
            "vocab": self.vocab,
            "rope_theta": self.rope_theta,
            "param_count": self.param_count(),
        }


# The pair mirrors Llama 3.2 3B/1B structurally; FLOP ratio ~ 3.2x.
TARGET = ModelConfig("target", n_layers=4, d_model=128, n_heads=4, ffn_dim=352)
DRAFTER = ModelConfig("drafter", n_layers=2, d_model=96, n_heads=4, ffn_dim=256)
CONFIGS = {"target": TARGET, "drafter": DRAFTER}

# Linear layer names inside one transformer block, in application order.
LINEARS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def init_params(cfg: ModelConfig, key) -> dict:
    """Scaled-normal init; returns a nested dict pytree."""
    k_embed, k_head, *k_layers = jax.random.split(key, cfg.n_layers + 2)
    d, f, v = cfg.d_model, cfg.ffn_dim, cfg.vocab

    def dense(k, shape):
        fan_in = shape[0]
        return jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(jnp.float32(fan_in))

    params = {
        "embed": jax.random.normal(k_embed, (v, d), jnp.float32) * 0.02,
        "head": dense(k_head, (d, v)),
        "final_norm": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for kl in k_layers:
        ks = jax.random.split(kl, len(LINEARS))
        shapes = {
            "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
            "w_gate": (d, f), "w_up": (d, f), "w_down": (f, d),
        }
        layer = {n: dense(k, shapes[n]) for n, k in zip(LINEARS, ks)}
        layer["attn_norm"] = jnp.ones((d,), jnp.float32)
        layer["mlp_norm"] = jnp.ones((d,), jnp.float32)
        params["layers"].append(layer)
    return params


def flatten_params(params: dict) -> list:
    """Deterministic (name, array) flattening — the order the manifest
    records and the Rust runtime feeds weights in."""
    out = [("embed", params["embed"]), ("head", params["head"]),
           ("final_norm", params["final_norm"])]
    for i, layer in enumerate(params["layers"]):
        for name in sorted(layer.keys()):
            entry = layer[name]
            if isinstance(entry, dict):  # quantized linear: w8 + scale
                out.append((f"layers.{i}.{name}.w8", entry["w8"]))
                out.append((f"layers.{i}.{name}.scale", entry["scale"]))
            else:
                out.append((f"layers.{i}.{name}", entry))
    return out


def unflatten_params(cfg: ModelConfig, named: dict) -> dict:
    """Inverse of flatten_params (accepts a {name: array} mapping)."""
    params = {"embed": named["embed"], "head": named["head"],
              "final_norm": named["final_norm"], "layers": []}
    for i in range(cfg.n_layers):
        layer = {}
        for name in LINEARS + ("attn_norm", "mlp_norm"):
            k = f"layers.{i}.{name}"
            if k in named:
                layer[name] = named[k]
            else:
                layer[name] = {"w8": named[k + ".w8"], "scale": named[k + ".scale"]}
        params["layers"].append(layer)
    return params


def _fake_quant_act(x: jnp.ndarray, scale: float) -> jnp.ndarray:
    """Static per-tensor activation QDQ (the a8 half of w8a8)."""
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    return q * scale


def _rope(x: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding; x: [H, S, D] with even D."""
    h, s, d = x.shape
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]  # [S, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos[None] - x2 * sin[None], x2 * cos[None] + x1 * sin[None]], axis=-1
    )


def _linear(x, w, name, quant, act_scales, use_pallas, recorder=None, key=None):
    if recorder is not None:
        # Calibration mode (quantize.py): record the max |activation| feeding
        # this linear; the static a8 scale is derived from it offline.
        k = key or name
        recorder[k] = max(recorder.get(k, 0.0), float(jnp.max(jnp.abs(x))))
    if quant:
        x = _fake_quant_act(x, act_scales[name])
        w8, sc = w["w8"], w["scale"]
        if use_pallas:
            return quant_matmul_pl(x, w8, sc)
        return ref.quant_matmul_ref(x, w8, sc)
    if use_pallas:
        return matmul_pl(x, w)
    return ref.matmul_ref(x, w)


def _norm(x, gamma, use_pallas):
    return rmsnorm_pl(x, gamma) if use_pallas else ref.rmsnorm_ref(x, gamma)


def forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            use_pallas: bool = True, quant: bool = False,
            act_scales: dict = None, recorder: dict = None) -> jnp.ndarray:
    """Full forward pass: tokens int32 [S] -> logits f32 [S, V]."""
    h, hd = cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens]  # [S, d]
    s = x.shape[0]
    for li, layer in enumerate(params["layers"]):
        sc = {k: act_scales[f"layers.{li}.{k}"] for k in LINEARS} if quant else None
        # --- attention block (pre-norm) ---
        xn = _norm(x, layer["attn_norm"], use_pallas)
        q = _linear(xn, layer["wq"], "wq", quant, sc, use_pallas, recorder, f"layers.{li}.wq")
        k = _linear(xn, layer["wk"], "wk", quant, sc, use_pallas, recorder, f"layers.{li}.wk")
        v = _linear(xn, layer["wv"], "wv", quant, sc, use_pallas, recorder, f"layers.{li}.wv")
        q = _rope(q.reshape(s, h, hd).transpose(1, 0, 2), cfg.rope_theta)
        k = _rope(k.reshape(s, h, hd).transpose(1, 0, 2), cfg.rope_theta)
        v = v.reshape(s, h, hd).transpose(1, 0, 2)
        if use_pallas:
            attn = attention_pl(q, k, v, causal=True)
        else:
            attn = ref.attention_ref(q, k, v, causal=True)
        attn = attn.transpose(1, 0, 2).reshape(s, cfg.d_model)
        x = x + _linear(attn, layer["wo"], "wo", quant, sc, use_pallas, recorder, f"layers.{li}.wo")
        # --- MLP block (pre-norm, SwiGLU) ---
        xn = _norm(x, layer["mlp_norm"], use_pallas)
        g = _linear(xn, layer["w_gate"], "w_gate", quant, sc, use_pallas, recorder, f"layers.{li}.w_gate")
        u = _linear(xn, layer["w_up"], "w_up", quant, sc, use_pallas, recorder, f"layers.{li}.w_up")
        act = ref.silu(g) * u
        x = x + _linear(act, layer["w_down"], "w_down", quant, sc, use_pallas, recorder, f"layers.{li}.w_down")
    x = _norm(x, params["final_norm"], use_pallas)
    # LM head stays fp32 in all variants (as in INC's default w8a8 recipes).
    if use_pallas:
        return matmul_pl(x, params["head"])
    return ref.matmul_ref(x, params["head"])


def forward_batch(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
                  **kw) -> jnp.ndarray:
    """Batched forward: tokens int32 [B, S] -> logits f32 [B, S, V]."""
    return jax.vmap(lambda t: forward(cfg, params, t, **kw))(tokens)
