"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these to tight tolerances. The L2 model can be built
from either implementation (``use_pallas`` flag), which is also how training
stays fast (pure-jnp fwd/bwd) while the AOT artifacts exercise the kernels.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """f32 GEMM: [S, K] @ [K, N] -> [S, N]."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def quant_matmul_ref(x: jnp.ndarray, w8: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """w8a8-style GEMM with per-output-channel weight scales.

    x: f32 [S, K] (already activation-fake-quantized by the caller),
    w8: int8 [K, N], scale: f32 [N]. Dequantization happens in f32 before the
    contraction — this mirrors the Mali behaviour the paper's footnote 3
    describes (INT8 promoted to wider arithmetic) and the TPU mapping where
    the MXU consumes bf16/f32 tiles.
    """
    return jnp.dot(x, w8.astype(jnp.float32) * scale[None, :],
                   preferred_element_type=jnp.float32)


def rmsnorm_ref(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm over the last axis: x * gamma / rms(x)."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (gamma / jnp.sqrt(ms + eps))


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """Multi-head scaled-dot-product attention.

    q, k, v: f32 [H, S, D]; returns f32 [H, S, D]. Causal mask by default
    (the models are decoder-only and run without a KV cache, per the paper's
    Table I setup).
    """
    h, s, d = q.shape
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask[None, :, :], scores, jnp.float32(-1e30))
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def silu(x: jnp.ndarray) -> jnp.ndarray:
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def swiglu_ref(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
               w_down: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP: silu(x @ w_gate) * (x @ w_up) @ w_down."""
    g = matmul_ref(x, w_gate)
    u = matmul_ref(x, w_up)
    return matmul_ref(silu(g) * u, w_down)
