"""L1 Pallas kernel: tiled f32 matmul.

TPU mapping of the paper's linear-layer hot spot. The paper's workload is the
short-sequence regime (S_L << d, §II-A) where the *linear* layers dominate —
so the GEMM tiles are what must keep the MXU fed. BlockSpecs stage
(bm x bk) x (bk x bn) tiles through VMEM; the k-grid dimension accumulates
into the output tile (revisiting semantics), which is the Pallas analogue of
a k-loop with a VMEM-resident accumulator.

Kernels are lowered with ``interpret=True``: the CPU PJRT client cannot run
Mosaic custom-calls (see /opt/xla-example/README.md); structure — tiling,
footprint, accumulation order — is what carries over to real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. Chosen so every model dimension in this repo
# (d in {96, 128}, ffn in {256, 352}, vocab 48, seq buckets multiples of 16)
# is tileable, while keeping the f32 VMEM footprint per program instance
# (bm*bk + bk*bn + bm*bn) * 4B ~ 24 KiB — far under the ~16 MiB VMEM budget,
# leaving room for double-buffering on real hardware.
BM, BK, BN = 16, 32, 16


def _mm_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pick(block: int, dim: int) -> int:
    """Largest tile <= block that divides dim (dims here are multiples of 8)."""
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(x: jnp.ndarray, w: jnp.ndarray, bm: int = BM, bk: int = BK,
           bn: int = BN) -> jnp.ndarray:
    """f32 GEMM [S, K] @ [K, N] -> [S, N] as a tiled Pallas kernel."""
    s, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bk, bn = _pick(bm, s), _pick(bk, k), _pick(bn, n)
    grid = (s // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        interpret=True,
    )(x, w)
