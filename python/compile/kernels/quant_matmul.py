"""L1 Pallas kernel: w8a8 matmul with in-kernel dequantization.

The paper's quantized variants use static w8a8 (Intel Neural Compressor);
our TPU rethink keeps the int8 weights resident in HBM (4x footprint
reduction — the reason edge deployments quantize at all) and dequantizes
*inside the kernel tile* right before the MXU contraction. This mirrors both:

* Mali's behaviour from the paper's footnote 3 (INT8 is promoted to wider
  arithmetic before use — on TPU the MXU consumes bf16/f32 tiles), and
* the bandwidth story: HBM traffic is int8, VMEM compute is f32.

Activation quantization (the "a8" half) is a static QDQ applied by the model
graph before this kernel — see ``compile/quantize.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import BK, BM, BN, _pick


def _qmm_kernel(x_ref, w8_ref, scale_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Dequantize the int8 weight tile in VMEM: per-output-channel scale.
    w = w8_ref[...].astype(jnp.float32) * scale_ref[...][None, :]
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def quant_matmul(x: jnp.ndarray, w8: jnp.ndarray, scale: jnp.ndarray,
                 bm: int = BM, bk: int = BK, bn: int = BN) -> jnp.ndarray:
    """[S, K] f32 @ [K, N] int8 (per-channel scale [N]) -> [S, N] f32."""
    s, k = x.shape
    k2, n = w8.shape
    assert k == k2 and scale.shape == (n,), (x.shape, w8.shape, scale.shape)
    bm, bk, bn = _pick(bm, s), _pick(bk, k), _pick(bn, n)
    grid = (s // bm, n // bn, k // bk)
    return pl.pallas_call(
        _qmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        interpret=True,
    )(x, w8, scale)
