"""L1 Pallas kernel: tiled causal attention with online softmax.

TPU rethink of the attention hot spot (the paper targets a Mali GPU with
workgroup tiling; on TPU the schedule is expressed with BlockSpecs):

* the grid walks (head, q-block, k-block); q/k/v tiles are staged
  HBM -> VMEM by the BlockSpec pipeline (the threadblock analogue);
* softmax is *online*: a running row-max ``m`` and normalizer ``l`` are
  carried across k-blocks, so scores never materialize at [S, S] in VMEM —
  only [bq, bk] tiles;
* causal masking skips whole k-blocks above the diagonal (their programs
  early-out), and masks within the diagonal block;
* the unnormalized accumulator lives in the output ref and is divided by
  ``l`` once, in the final k-block — a single pass over HBM.

VMEM per program instance (bq=bk=32, d<=32, f32):
q/k/v tiles + scores + m/l ~ (3*32*32 + 32*32 + 2*32)*4B ~ 16.5 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ, BKV = 32, 32
_NEG_INF = float(-1e30)


def _attn_kernel(scale, bq, bkv, causal, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref):
    qi, kj = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal: k-blocks strictly above the diagonal contribute nothing.
    diag_ok = (not causal) or (kj * bkv <= qi * bq + bq - 1)

    @pl.when(diag_ok)
    def _block():
        q = q_ref[0]                    # [bq, d]
        k = k_ref[0]                    # [bkv, d]
        v = v_ref[0]                    # [bkv, d]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            cols = kj * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)

        m_prev = m_ref[0]               # [bq]
        l_prev = l_ref[0]               # [bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        o_ref[0] = o_ref[0] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[0] = m_new
        l_ref[0] = l_new

    # Final k-block: normalize the accumulator once.
    @pl.when(kj == nk - 1)
    def _final():
        o_ref[0] = o_ref[0] / l_ref[0][:, None]


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv"))
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, bq: int = BQ, bkv: int = BKV) -> jnp.ndarray:
    """Multi-head attention; q, k, v: f32 [H, S, D] -> [H, S, D]."""
    h, s, d = q.shape
    assert k.shape == (h, s, d) and v.shape == (h, s, d)
    bq = min(bq, s)
    while s % bq:
        bq -= 1
    bkv = min(bkv, s)
    while s % bkv:
        bkv -= 1
    grid = (h, s // bq, s // bkv)
    scale = float(1.0 / float(d) ** 0.5)
    kernel = functools.partial(_attn_kernel, scale, bq, bkv, causal)
    out, _m, _l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hh, i, j: (hh, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda hh, i, j: (hh, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda hh, i, j: (hh, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda hh, i, j: (hh, i, 0)),
            pl.BlockSpec((1, bq), lambda hh, i, j: (hh, i)),
            pl.BlockSpec((1, bq), lambda hh, i, j: (hh, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, s, d), jnp.float32),
            jax.ShapeDtypeStruct((h, s), jnp.float32),
            jax.ShapeDtypeStruct((h, s), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
    return out
