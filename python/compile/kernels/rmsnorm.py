"""L1 Pallas kernel: fused RMSNorm.

One pass per row block: mean-of-squares reduction and the scale multiply are
fused in VMEM, so the activation row is read once from HBM instead of the
three passes an unfused graph would take (square+mean, rsqrt, mul). On the
short-sequence edge workload this keeps the (memory-bound) norm from eating
into the linear-layer budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BROWS = 16  # row-block: (BROWS x d) f32 tile, d <= 128 -> 8 KiB in VMEM


def _rmsnorm_kernel(eps, x_ref, g_ref, o_ref):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * (g_ref[...][None, :] * jax.lax.rsqrt(ms + eps))


@functools.partial(jax.jit, static_argnames=("eps", "brows"))
def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5,
            brows: int = BROWS) -> jnp.ndarray:
    """RMSNorm over the last axis; x: f32 [S, D], gamma: f32 [D]."""
    s, d = x.shape
    assert gamma.shape == (d,)
    br = min(brows, s)
    while s % br:
        br -= 1
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, float(eps)),
        grid=(s // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), jnp.float32),
        interpret=True,
    )(x, gamma)
