"""Character-level tokenizer shared between the build path (Python) and the
request path (Rust).

The vocabulary is fixed and versioned: it is exported into
``artifacts/manifest.json`` and the Rust ``tokenizer`` module rebuilds the
exact same mapping from it, so token ids produced on either side agree.

Special tokens:
  PAD (0)  padding after the live sequence (causal masking makes it inert)
  BOS (1)  start of sequence
  EOS (2)  end of generation
  SEP (3)  separates the task prompt from the completion ("=" in text form)
"""

from __future__ import annotations

from dataclasses import dataclass

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
SEP_ID = 3

# Printable forms for the special ids (used when detokenizing for display).
_SPECIAL = ["<pad>", "<bos>", "<eos>", "="]

# Regular characters, in id order after the specials.
_CHARS = " abcdefghijklmnopqrstuvwxyz.,?!-0123456789:'"

VOCAB_SIZE = 48  # 4 specials + 44 chars = 48 exactly


@dataclass(frozen=True)
class TokenizerSpec:
    """Serializable description of the vocabulary (goes into the manifest)."""

    specials: tuple
    chars: str
    vocab_size: int

    def to_json(self) -> dict:
        return {
            "specials": list(self.specials),
            "chars": self.chars,
            "vocab_size": self.vocab_size,
        }


SPEC = TokenizerSpec(specials=tuple(_SPECIAL), chars=_CHARS, vocab_size=VOCAB_SIZE)

assert len(_SPECIAL) + len(_CHARS) == VOCAB_SIZE, (
    len(_SPECIAL),
    len(_CHARS),
)

_CHAR_TO_ID = {c: i + len(_SPECIAL) for i, c in enumerate(_CHARS)}
_ID_TO_CHAR = {i + len(_SPECIAL): c for i, c in enumerate(_CHARS)}


def encode(text: str, bos: bool = True) -> list:
    """Encode ``text`` to token ids. Unknown characters are an error: the
    synthetic corpus only ever emits characters from the fixed vocabulary."""
    ids = [BOS_ID] if bos else []
    for ch in text:
        if ch not in _CHAR_TO_ID:
            raise ValueError(f"character {ch!r} not in vocabulary")
        ids.append(_CHAR_TO_ID[ch])
    return ids


def decode(ids, stop_at_eos: bool = True) -> str:
    """Decode token ids back to text, skipping BOS/PAD and stopping at EOS."""
    out = []
    for i in ids:
        i = int(i)
        if i in (BOS_ID, PAD_ID):
            continue
        if i == EOS_ID:
            if stop_at_eos:
                break
            continue
        if i == SEP_ID:
            out.append("=")
            continue
        if i not in _ID_TO_CHAR:
            raise ValueError(f"id {i} not in vocabulary")
        out.append(_ID_TO_CHAR[i])
    return "".join(out)
