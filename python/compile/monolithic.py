"""Monolithic speculative-sampling step as a single fused graph (paper Fig. 3).

The paper contrasts two compiler abstractions:

* **modular** (their deployed path, Fig. 4): drafter and target compiled as
  separate modules, the draft/verify control flow living in the serving
  runtime, paying a runtime-API boundary cost per call;
* **monolithic** (Fig. 3): one module containing drafter, target *and* the
  speculation control flow, which IREE 3.6 could not deploy with mixed
  device affinities (§IV-D) — they measured a 4% deviation they attribute
  partly to the modular boundary overhead.

We implement both. This file is the monolithic one: a single jitted function
per draft length γ that (1) greedily drafts γ tokens with the drafter inside
a ``fori_loop``, (2) runs one target verification pass, and (3) computes the
accepted-token count in-graph. One HLO artifact per γ; the Rust side calls
it once per speculation round instead of γ+1 times.

Positions/lengths are runtime scalars so one artifact serves any prompt
length up to the bucket size.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import model as M


def spec_step_fn(draft_cfg: M.ModelConfig, target_cfg: M.ModelConfig,
                 gamma: int, use_pallas: bool = True,
                 draft_quant: bool = False, target_quant: bool = False,
                 draft_act_scales: dict = None, target_act_scales: dict = None):
    """Returns f(draft_params, target_params, tokens[S], cur_len) ->
    (n_accepted i32, out_tokens i32[gamma+1], drafted i32[gamma]).

    ``tokens`` is the PAD-padded sequence, ``cur_len`` the live length.
    ``out_tokens`` are the target's greedy tokens at positions
    cur_len-1 .. cur_len+gamma-1 — i.e. the corrected continuation: the Rust
    coordinator appends out_tokens[:n_accepted+1] (speculative sampling's
    "always at least one target token" guarantee).
    """

    def fn(draft_params, target_params, tokens, cur_len):
        def draft_body(i, toks):
            logits = M.forward(draft_cfg, draft_params, toks,
                               use_pallas=use_pallas, quant=draft_quant,
                               act_scales=draft_act_scales)
            row = lax.dynamic_index_in_dim(logits, cur_len - 1 + i, axis=0,
                                           keepdims=False)
            nxt = jnp.argmax(row).astype(jnp.int32)
            return lax.dynamic_update_index_in_dim(toks, nxt, cur_len + i, axis=0)

        drafted_seq = lax.fori_loop(0, gamma, draft_body, tokens)
        drafted = lax.dynamic_slice(drafted_seq, (cur_len,), (gamma,))

        tlogits = M.forward(target_cfg, target_params, drafted_seq,
                            use_pallas=use_pallas, quant=target_quant,
                            act_scales=target_act_scales)
        # Target greedy tokens for positions cur_len .. cur_len+gamma
        # (predicted from rows cur_len-1 .. cur_len+gamma-1).
        rows = lax.dynamic_slice(
            tlogits, (cur_len - 1, 0), (gamma + 1, tlogits.shape[1]))
        out_tokens = jnp.argmax(rows, axis=-1).astype(jnp.int32)

        # Greedy acceptance: leading run where draft == target argmax.
        matches = (drafted == out_tokens[:gamma]).astype(jnp.int32)
        n_accepted = jnp.sum(jnp.cumprod(matches)).astype(jnp.int32)
        return n_accepted, out_tokens, drafted

    return fn


def lower_spec_step(draft_cfg, target_cfg, gamma: int, seq_len: int,
                    draft_params, target_params, **kw):
    """Jit-lower the fused step for a fixed seq bucket; weights are runtime
    parameters (flattened in manifest order) so artifacts stay small."""
    dflat = M.flatten_params(draft_params)
    tflat = M.flatten_params(target_params)
    dnames = [n for n, _ in dflat]
    tnames = [n for n, _ in tflat]
    fn = spec_step_fn(draft_cfg, target_cfg, gamma, **kw)

    def wrapped(*args):
        nd = len(dnames)
        dvals = args[:nd]
        tvals = args[nd:nd + len(tnames)]
        tokens, cur_len = args[-2], args[-1]
        dp = M.unflatten_params(draft_cfg, dict(zip(dnames, dvals)))
        tp = M.unflatten_params(target_cfg, dict(zip(tnames, tvals)))
        return fn(dp, tp, tokens, cur_len)

    example = (
        [jax.ShapeDtypeStruct(v.shape, v.dtype) for _, v in dflat]
        + [jax.ShapeDtypeStruct(v.shape, v.dtype) for _, v in tflat]
        + [jax.ShapeDtypeStruct((seq_len,), jnp.int32),
           jax.ShapeDtypeStruct((), jnp.int32)]
    )
    return jax.jit(wrapped).lower(*example), dnames, tnames
