"""Build-time training of the tiny target/drafter pair.

The paper uses pretrained Llama 3.2 3B/1B; our substitute pair must be
*trained* to make speculative sampling meaningful (random models have no
usable acceptance rate). Training is a one-time build step cached under
``artifacts/`` — it never touches the request path.

* Target: next-token cross-entropy on the multi-task synthetic corpus.
* Drafter: the same objective mixed with a KL distillation term against the
  frozen target's logits — the "structural similarity yields correlated
  logits" mechanism that makes training-free speculative sampling work
  (paper §II-B), condensed into an explicit distillation because our models
  don't share a pretraining corpus of web scale.

Pure-jnp forward (use_pallas=False) keeps fwd/bwd fast; the Pallas path is
exercised by the AOT artifacts and the kernel test suite instead.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M
from . import tokenizer as tok

MAXLEN = 128          # largest seq bucket; samples are generated to fit
TRAIN_SEED = 1234


def make_batch(stream, batch_size: int):
    """Pad/truncate full sample token ids to MAXLEN+1; returns int32
    [B, MAXLEN+1] (inputs = [:, :-1], labels = [:, 1:])."""
    rows = []
    for _ in range(batch_size):
        s = next(stream)
        ids = s.full_ids()[: MAXLEN + 1]
        ids = ids + [tok.PAD_ID] * (MAXLEN + 1 - len(ids))
        rows.append(ids)
    return np.asarray(rows, np.int32)


def loss_fn(cfg, params, batch, teacher_logits=None, distill_weight=0.0):
    inputs, labels = batch[:, :-1], batch[:, 1:]
    logits = M.forward_batch(cfg, params, inputs, use_pallas=False)  # [B,S,V]
    mask = (labels != tok.PAD_ID).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    if teacher_logits is None or distill_weight == 0.0:
        return ce
    tprob = jax.nn.softmax(teacher_logits, axis=-1)
    kl = jnp.sum(tprob * (jax.nn.log_softmax(teacher_logits, -1) - logp), axis=-1)
    kl = jnp.sum(kl * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return (1.0 - distill_weight) * ce + distill_weight * kl


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


def cosine_lr(step, steps, peak, warmup=20):
    if step < warmup:
        return peak * (step + 1) / warmup
    frac = (step - warmup) / max(1, steps - warmup)
    return peak * 0.5 * (1.0 + float(np.cos(np.pi * frac)))


def train_model(cfg, steps: int, batch_size: int, peak_lr: float,
                distill_from=None, distill_weight: float = 0.5,
                seed: int = TRAIN_SEED, log_every: int = 50,
                stream_seed: int = None):
    """Train ``cfg`` on the synthetic corpus; optionally distill from a frozen
    teacher (params of the *target* model). Returns (params, loss_history)."""
    lex = D.build_lexicon()
    stream = D.train_stream(lex, seed=stream_seed or (seed + 7))
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    teacher_cfg_params = distill_from  # (cfg, params) or None

    if teacher_cfg_params is None:
        @jax.jit
        def step_fn(params, opt_m, opt_v, opt_t, batch, lr):
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch))(params)
            new, st = adam_update(params, grads, {"m": opt_m, "v": opt_v, "t": opt_t}, lr)
            return loss, new, st["m"], st["v"], st["t"]
    else:
        tcfg, tparams = teacher_cfg_params

        @jax.jit
        def step_fn(params, opt_m, opt_v, opt_t, batch, lr):
            teacher_logits = M.forward_batch(tcfg, tparams, batch[:, :-1],
                                             use_pallas=False)
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch, teacher_logits, distill_weight)
            )(params)
            new, st = adam_update(params, grads, {"m": opt_m, "v": opt_v, "t": opt_t}, lr)
            return loss, new, st["m"], st["v"], st["t"]

    history = []
    t0 = time.time()
    m, v, t = opt["m"], opt["v"], opt["t"]
    for step in range(steps):
        batch = jnp.asarray(make_batch(stream, batch_size))
        lr = cosine_lr(step, steps, peak_lr)
        loss, params, m, v, t = step_fn(params, m, v, t, batch, lr)
        history.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            print(f"[train:{cfg.name}] step {step:4d}  loss {float(loss):.4f}  "
                  f"lr {lr:.2e}  {time.time() - t0:.0f}s", flush=True)
    return params, history


def greedy_decode_ref(cfg, params, prompt_ids, max_new: int, **fwd_kw):
    """Reference greedy decoding (Python-side, for tests/accuracy eval)."""
    ids = list(prompt_ids)
    for _ in range(max_new):
        logits = M.forward(cfg, params, jnp.asarray(ids, jnp.int32),
                           use_pallas=False, **fwd_kw)
        nxt = int(jnp.argmax(logits[len(ids) - 1]))
        ids.append(nxt)
        if nxt == tok.EOS_ID or len(ids) >= MAXLEN:
            break
    return ids


def task_accuracy(cfg, params, samples, max_samples: int = 30, **fwd_kw):
    """Exact-match + token accuracy on eval samples (build-time sanity)."""
    correct = total = exact = 0
    for s in samples[:max_samples]:
        pids = s.prompt_ids()
        want = tok.encode(s.completion, bos=False) + [tok.EOS_ID]
        out = greedy_decode_ref(cfg, params, pids, max_new=len(want) + 4, **fwd_kw)
        got = out[len(pids):]
        exact += int(got[: len(want)] == want)
        n = min(len(got), len(want))
        correct += sum(int(a == b) for a, b in zip(got[:n], want[:n]))
        total += len(want)
    return {"token_acc": correct / max(total, 1), "exact": exact / max_samples}


def save_checkpoint(path: str, params: dict):
    flat = dict(M.flatten_params(params))
    np.savez(path, **{k: np.asarray(v) for k, v in flat.items()})


def load_checkpoint(path: str, cfg) -> dict:
    z = np.load(path)
    named = {k: jnp.asarray(z[k]) for k in z.files}
    return M.unflatten_params(cfg, named)
