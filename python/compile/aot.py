"""AOT build orchestrator: train → quantize → lower → artifacts/.

Runs ONCE at build time (``make artifacts``); the Rust runtime is
self-contained afterwards. Emits:

* ``artifacts/<role>_<scheme>[_ref]_b<batch>_s<bucket>.hlo.txt`` — one HLO
  TEXT module per (model variant × kernel path × batch × seq bucket);
* ``artifacts/mono_g<γ>_s<bucket>.hlo.txt``   — fused monolithic spec-step
  graphs (semi-quantized pair, the paper's deployment point), γ = 1..5;
* ``artifacts/weights_<role>_<scheme>.bin``   — flat binary weight files
  (f32 / int8 tensors in manifest order, custom SEWB format);
* ``artifacts/manifest.json``                 — everything the Rust side
  needs: tokenizer spec, model configs, artifact & weights index, the fixed
  480-sample eval set, act scales, training/quantization metadata.

HLO *text* is the interchange format (NOT ``.serialize()``): jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from . import monolithic as MONO
from . import quantize as Q
from . import tokenizer as tok
from . import train as T

SEQ_BUCKETS = [16, 32, 48, 64, 96, 128]
BATCH_SIZES = [1, 4]
MONO_GAMMAS = [1, 2, 3, 4, 5]
MONO_BUCKET = 128

TARGET_STEPS = int(os.environ.get("SPECEDGE_TARGET_STEPS", "1200"))
DRAFTER_STEPS = int(os.environ.get("SPECEDGE_DRAFTER_STEPS", "800"))
TRAIN_BATCH = 16
QMAX = int(os.environ.get("SPECEDGE_QMAX", "0")) or None  # None -> quantize.DEFAULT_QMAX

DTYPE_TAGS = {np.dtype(np.float32): 0, np.dtype(np.int8): 1, np.dtype(np.int32): 2}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_weights_bin(path: str, flat: list) -> list:
    """SEWB v1: magic, count, then per tensor: name, dtype tag, dims, bytes.
    Everything little-endian. Returns the manifest index entries."""
    index = []
    with open(path, "wb") as f:
        f.write(b"SEWB")
        f.write(struct.pack("<II", 1, len(flat)))
        for name, arr in flat:
            a = np.ascontiguousarray(np.asarray(arr))
            tag = DTYPE_TAGS[a.dtype]
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", tag, a.ndim))
            for d in a.shape:
                f.write(struct.pack("<I", d))
            raw = a.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)
            index.append({"name": name, "dtype": ["f32", "i8", "i32"][tag],
                          "shape": list(a.shape)})
    return index


def lower_forward(cfg, params, bucket: int, batch: int, use_pallas: bool,
                  quant: bool, act_scales):
    """Lower one forward pass with weights as runtime parameters."""
    flat = M.flatten_params(params)
    names = [n for n, _ in flat]

    def wrapped(*args):
        vals, tokens = args[:-1], args[-1]
        p = M.unflatten_params(cfg, dict(zip(names, vals)))
        kw = dict(use_pallas=use_pallas, quant=quant, act_scales=act_scales)
        if batch == 1:
            return M.forward(cfg, p, tokens, **kw)
        return M.forward_batch(cfg, p, tokens, **kw)

    tok_shape = (bucket,) if batch == 1 else (batch, bucket)
    example = [jax.ShapeDtypeStruct(v.shape, v.dtype) for _, v in flat]
    example.append(jax.ShapeDtypeStruct(tok_shape, jnp.int32))
    return jax.jit(wrapped).lower(*example), names


def get_or_train(out_dir: str):
    """Train (or reuse cached checkpoints for) the target + drafter pair."""
    tpath = os.path.join(out_dir, "target_ckpt.npz")
    dpath = os.path.join(out_dir, "drafter_ckpt.npz")
    meta = {}
    if os.path.exists(tpath):
        print(f"[aot] reusing cached target checkpoint {tpath}")
        tparams = T.load_checkpoint(tpath, M.TARGET)
    else:
        tparams, hist = T.train_model(M.TARGET, TARGET_STEPS, TRAIN_BATCH, 3e-3)
        T.save_checkpoint(tpath, tparams)
        meta["target_final_loss"] = hist[-1]
    if os.path.exists(dpath):
        print(f"[aot] reusing cached drafter checkpoint {dpath}")
        dparams = T.load_checkpoint(dpath, M.DRAFTER)
    else:
        dparams, hist = T.train_model(
            M.DRAFTER, DRAFTER_STEPS, TRAIN_BATCH, 3e-3,
            distill_from=(M.TARGET, tparams), distill_weight=0.5)
        T.save_checkpoint(dpath, dparams)
        meta["drafter_final_loss"] = hist[-1]
    return tparams, dparams, meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="tiny build for CI: fewer buckets/gammas")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)
    t_start = time.time()

    buckets = [16, 64, 128] if args.fast else SEQ_BUCKETS
    gammas = [2] if args.fast else MONO_GAMMAS

    # ---- 1. models ------------------------------------------------------
    tparams, dparams, train_meta = get_or_train(out)

    # ---- 2. quantization -------------------------------------------------
    lex = D.build_lexicon()
    ev = D.eval_set(lex)
    calib = [s.full_ids()[:T.MAXLEN] for s in ev[:16]]
    calib = [ids + [tok.PAD_ID] * (T.MAXLEN - len(ids)) for ids in calib]
    print("[aot] calibrating activation scales ...")
    t_scales = Q.calibrate_act_scales(M.TARGET, tparams, [calib[:8]])
    d_scales = Q.calibrate_act_scales(M.DRAFTER, dparams, [calib[:8]])
    qmax = QMAX or Q.DEFAULT_QMAX
    tq = Q.quantize_params(tparams, qmax)
    dq = Q.quantize_params(dparams, qmax)
    qerr_t = Q.quantization_error(tparams, tq)
    qerr_d = Q.quantization_error(dparams, dq)
    print(f"[aot] weight quant rel-err: target {qerr_t:.4f} drafter {qerr_d:.4f}")

    # (role, scheme) -> (cfg, params, quant?, act_scales)
    variants = {
        ("target", "fp"): (M.TARGET, tparams, False, None),
        ("target", "w8a8"): (M.TARGET, tq, True, t_scales),
        ("drafter", "fp"): (M.DRAFTER, dparams, False, None),
        ("drafter", "w8a8"): (M.DRAFTER, dq, True, d_scales),
    }

    manifest = {
        "version": 1,
        "created_unix": int(time.time()),
        "tokenizer": tok.SPEC.to_json(),
        "seq_buckets": buckets,
        "batch_sizes": BATCH_SIZES,
        "models": {"target": M.TARGET.to_json(), "drafter": M.DRAFTER.to_json()},
        "train": train_meta,
        "quant": {"qmax": qmax, "target_rel_err": qerr_t, "drafter_rel_err": qerr_d,
                  "act_scales": {"target": t_scales, "drafter": d_scales}},
        "variants": {},
        "monolithic": [],
        "eval_samples": [
            {"task": s.task, "prompt": s.prompt, "completion": s.completion}
            for s in ev
        ],
    }

    # ---- 3. weights ------------------------------------------------------
    for (role, scheme), (cfg, params, quant, scales) in variants.items():
        key = f"{role}_{scheme}"
        wpath = os.path.join(out, f"weights_{key}.bin")
        index = write_weights_bin(wpath, M.flatten_params(params))
        manifest["variants"][key] = {
            "role": role, "scheme": scheme, "model": cfg.name,
            "weights": os.path.basename(wpath), "tensors": index,
            "quant": quant, "artifacts": [],
        }
        print(f"[aot] wrote {wpath}")

    # ---- 4. forward artifacts ---------------------------------------------
    for (role, scheme), (cfg, params, quant, scales) in variants.items():
        key = f"{role}_{scheme}"
        for kernel in ("pallas", "ref"):
            use_pallas = kernel == "pallas"
            for batch in BATCH_SIZES:
                if use_pallas and batch != 1:
                    continue  # Pallas path is the batch-1 latency path
                for bucket in buckets:
                    t0 = time.time()
                    lowered, _names = lower_forward(
                        cfg, params, bucket, batch, use_pallas, quant, scales)
                    text = to_hlo_text(lowered)
                    suffix = "" if use_pallas else "_ref"
                    fname = f"{key}{suffix}_b{batch}_s{bucket}.hlo.txt"
                    with open(os.path.join(out, fname), "w") as f:
                        f.write(text)
                    manifest["variants"][key]["artifacts"].append({
                        "file": fname, "kernel": kernel, "batch": batch,
                        "seq": bucket,
                    })
                    print(f"[aot] {fname}  ({time.time() - t0:.1f}s, "
                          f"{len(text) // 1024} KiB)")

    # ---- 5. monolithic spec-step artifacts (semi pair: fp drafter + w8a8
    #         target — the paper's deployed configuration) ------------------
    for gamma in gammas:
        t0 = time.time()
        lowered, dn, tn = MONO.lower_spec_step(
            M.DRAFTER, M.TARGET, gamma, MONO_BUCKET, dparams, tq,
            use_pallas=True, draft_quant=False, target_quant=True,
            draft_act_scales=None, target_act_scales=t_scales)
        text = to_hlo_text(lowered)
        fname = f"mono_g{gamma}_s{MONO_BUCKET}.hlo.txt"
        with open(os.path.join(out, fname), "w") as f:
            f.write(text)
        manifest["monolithic"].append({
            "file": fname, "gamma": gamma, "seq": MONO_BUCKET,
            "drafter": "drafter_fp", "target": "target_w8a8",
        })
        print(f"[aot] {fname}  ({time.time() - t0:.1f}s, {len(text) // 1024} KiB)")

    # ---- 6. manifest -------------------------------------------------------
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time() - t_start:.0f}s -> {out}/manifest.json")


if __name__ == "__main__":
    main()
