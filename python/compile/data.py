"""Synthetic Spec-Bench-style corpus.

The paper evaluates on Spec-Bench (480 samples over 13 tasks) and focuses on
the *translation* task, whose average prompt length is 63 tokens and whose
output length roughly matches the input length. We do not have Spec-Bench or
Llama-scale models, so we build a structurally equivalent synthetic benchmark
over a deterministic "toy language":

* A 300-word source lexicon of pronounceable pseudo-words, sampled Zipfian
  (so some words are common and well-learned, others rare — this is what
  gives the per-sample acceptance-rate spread the paper's Fig. 5 relies on).
* Translation maps each word deterministically: ~80% of the lexicon follows
  a global character rotation ("regular verbs"), ~20% have memorized
  irregular forms. A tiny transformer can learn the regular rule perfectly
  and the irregular forms only for frequent words.
* Twelve further deterministic tasks mirror Spec-Bench's task diversity
  (copy, reversal, extraction, counting, ...), each marked by a textual task
  prefix so one model pair serves all tasks, as in the paper.

Every sample is ``<prefix>: <input> = <output><eos>`` at the character level.
All randomness is seeded: the corpus is reproducible bit-for-bit and the
Rust workload generator replays the *same* 480 eval samples from
``artifacts/manifest.json`` metadata (task id + sample seed).
"""

from __future__ import annotations

import random
from dataclasses import dataclass

from . import tokenizer as tok

LEXICON_SIZE = 300
IRREGULAR_FRACTION = 0.2
ZIPF_EXPONENT = 1.1
CORPUS_SEED = 20260710

# Spec-Bench has 13 tasks and 480 samples; we mirror the structure.
TASKS = [
    "translate",       # the paper's focus task
    "copy",
    "reverse-words",
    "last-word",
    "first-word",
    "cipher",
    "count-words",
    "swap-ends",
    "double",
    "initials",
    "word-lengths",
    "translate-rev",
    "second-word",
]
TASK_PREFIX = {
    "translate": "tr",
    "copy": "cp",
    "reverse-words": "rw",
    "last-word": "lw",
    "first-word": "fw",
    "cipher": "ci",
    "count-words": "cw",
    "swap-ends": "se",
    "double": "db",
    "initials": "in",
    "word-lengths": "wl",
    "translate-rev": "tv",
    "second-word": "sw",
}
EVAL_SAMPLES_TOTAL = 480

_CONSONANTS = "bcdfghjklmnprstvz"
_VOWELS = "aeiou"


def _make_word(rng: random.Random, syllables: int) -> str:
    parts = []
    for _ in range(syllables):
        parts.append(rng.choice(_CONSONANTS))
        parts.append(rng.choice(_VOWELS))
        if rng.random() < 0.3:
            parts.append(rng.choice(_CONSONANTS))
    return "".join(parts)


def _rotate_char(c: str, k: int = 7) -> str:
    """The 'regular' translation rule: rotate within a-z."""
    return chr((ord(c) - ord("a") + k) % 26 + ord("a"))


def rotate_word(w: str) -> str:
    return "".join(_rotate_char(c) for c in w)


@dataclass(frozen=True)
class Lexicon:
    words: tuple            # source words, index = rank (0 = most frequent)
    translations: tuple     # deterministic target-language forms
    irregular: tuple        # bool per word: True if the form is memorized

    def translate_word(self, w: str) -> str:
        try:
            i = self.words.index(w)
        except ValueError as e:
            raise KeyError(f"word {w!r} not in lexicon") from e
        return self.translations[i]


def build_lexicon(seed: int = CORPUS_SEED) -> Lexicon:
    rng = random.Random(seed)
    words = []
    seen = set()
    while len(words) < LEXICON_SIZE:
        w = _make_word(rng, rng.choice([1, 2, 2, 3]))
        if 3 <= len(w) <= 8 and w not in seen:
            seen.add(w)
            words.append(w)
    translations = []
    irregular = []
    for w in words:
        if rng.random() < IRREGULAR_FRACTION:
            # Irregular form: an unrelated pseudo-word of similar length that
            # must be memorized per-word.
            t = _make_word(rng, rng.choice([1, 2, 2]))
            irregular.append(True)
        else:
            t = rotate_word(w)
            irregular.append(False)
        translations.append(t)
    return Lexicon(tuple(words), tuple(translations), tuple(irregular))


def _zipf_weights(n: int, s: float = ZIPF_EXPONENT):
    return [1.0 / (i + 1) ** s for i in range(n)]


def sample_sentence(lex: Lexicon, rng: random.Random, n_words=None) -> list:
    if n_words is None:
        n_words = rng.randint(8, 12)
    weights = _zipf_weights(len(lex.words))
    return rng.choices(list(lex.words), weights=weights, k=n_words)


def apply_task(task: str, words: list, lex: Lexicon) -> str:
    """Deterministic ground-truth output for ``task`` on ``words``."""
    if task == "translate":
        return " ".join(lex.translate_word(w) for w in words)
    if task == "copy":
        return " ".join(words)
    if task == "reverse-words":
        return " ".join(reversed(words))
    if task == "last-word":
        return words[-1]
    if task == "first-word":
        return words[0]
    if task == "cipher":
        return " ".join(rotate_word(w) for w in words)
    if task == "count-words":
        return str(len(words))
    if task == "swap-ends":
        ws = list(words)
        ws[0], ws[-1] = ws[-1], ws[0]
        return " ".join(ws)
    if task == "double":
        return " ".join([words[0], words[0]] + words[1:])
    if task == "initials":
        return " ".join(w[0] for w in words)
    if task == "word-lengths":
        return " ".join(str(len(w)) for w in words)
    if task == "translate-rev":
        return " ".join(lex.translate_word(w) for w in reversed(words))
    if task == "second-word":
        return words[1]
    raise ValueError(f"unknown task {task!r}")


@dataclass(frozen=True)
class Sample:
    task: str
    prompt: str       # "<prefix>: <input>" — SEP is appended at encode time
    completion: str   # ground truth, EOS appended at encode time
    seed: int

    def prompt_ids(self) -> list:
        return tok.encode(self.prompt) + [tok.SEP_ID]

    def full_ids(self) -> list:
        return self.prompt_ids() + tok.encode(self.completion, bos=False) + [tok.EOS_ID]


MAX_SAMPLE_LEN = 126  # BOS..EOS must fit the largest seq bucket (128)


def make_sample(lex: Lexicon, task: str, seed: int) -> Sample:
    rng = random.Random(seed)
    # Short tasks still get full-length inputs; output length varies by task,
    # which mirrors Spec-Bench's task-length diversity. Samples are resampled
    # with fewer words until prompt+completion fits the largest seq bucket.
    # Translation doubles the sample length (output ~= input), so it starts
    # from a slightly longer draw and the fit loop clamps it; this lands the
    # average translate prompt at ~63 tokens, the paper's S_L operating point.
    n_words = rng.randint(9, 13) if task.startswith("translate") else rng.randint(8, 12)
    while True:
        words = sample_sentence(lex, rng, n_words=n_words)
        prompt = f"{TASK_PREFIX[task]}: {' '.join(words)}"
        completion = apply_task(task, words, lex)
        s = Sample(task=task, prompt=prompt, completion=completion, seed=seed)
        if len(s.full_ids()) <= MAX_SAMPLE_LEN or n_words <= 4:
            return s
        n_words -= 1


def train_stream(lex: Lexicon, seed: int, mixture=None):
    """Infinite stream of training samples. Translation is up-weighted (it is
    the paper's focus task); the remaining tasks share the rest, so they are
    learned to *varying* degrees — the source of task-level alpha diversity."""
    if mixture is None:
        mixture = {"translate": 0.40, "translate-rev": 0.08}
        rest = (1.0 - sum(mixture.values())) / (len(TASKS) - len(mixture))
        for t in TASKS:
            mixture.setdefault(t, rest)
    tasks = list(mixture.keys())
    weights = [mixture[t] for t in tasks]
    rng = random.Random(seed)
    i = 0
    while True:
        task = rng.choices(tasks, weights=weights, k=1)[0]
        yield make_sample(lex, task, seed=rng.randrange(2**31))
        i += 1


def eval_set(lex: Lexicon, seed: int = CORPUS_SEED + 1):
    """The fixed 480-sample evaluation set (Spec-Bench-shaped). Sample seeds
    are deterministic so Rust can regenerate the identical set."""
    per_task = EVAL_SAMPLES_TOTAL // len(TASKS)          # 36
    extra = EVAL_SAMPLES_TOTAL - per_task * len(TASKS)   # remainder -> translate
    samples = []
    for ti, task in enumerate(TASKS):
        n = per_task + (extra if task == "translate" else 0)
        for j in range(n):
            samples.append(make_sample(lex, task, seed=seed * 1000 + ti * 97 + j))
    return samples


def avg_prompt_len(samples) -> float:
    return sum(len(s.prompt_ids()) for s in samples) / len(samples)
