"""Quick α probe with padded, jitted forwards (dev tool, not part of build).

Usage: python alpha_probe.py [n_samples] [qmax_target] [qmax_drafter]
"""
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import model as M
from compile import quantize as Q
from compile import tokenizer as tok
from compile import train as T

PAD_TO = 128


def quantize_params_bits(params, qmax):
    out = {"embed": params["embed"], "head": params["head"],
           "final_norm": params["final_norm"], "layers": []}
    for layer in params["layers"]:
        ql = {}
        for name, w in layer.items():
            if name in M.LINEARS:
                w = np.asarray(w, np.float32)
                amax = np.max(np.abs(w), axis=0)
                scale = np.where(amax > 0, amax / qmax, 1.0).astype(np.float32)
                w8 = np.clip(np.round(w / scale[None, :]), -qmax, qmax).astype(np.int8)
                ql[name] = {"w8": jnp.asarray(w8), "scale": jnp.asarray(scale)}
            else:
                ql[name] = w
        out["layers"].append(ql)
    return out


def make_stepper(cfg, params, **kw):
    @jax.jit
    def step(toks, pos):
        logits = M.forward(cfg, params, toks, use_pallas=False, **kw)
        return jnp.argmax(logits[pos])
    return step


def alpha_for(sample, tstep, dstep, max_new=40):
    ids = sample.prompt_ids()
    toks = np.zeros(PAD_TO, np.int32)
    toks[:len(ids)] = ids
    pos = len(ids) - 1
    agree = tot = 0
    t = jnp.asarray(toks)
    for _ in range(max_new):
        nt = int(tstep(t, pos))
        nd = int(dstep(t, pos))
        agree += int(nt == nd)
        tot += 1
        pos += 1
        if nt == tok.EOS_ID or pos >= PAD_TO - 1:
            break
        t = t.at[pos].set(nt)
    return agree / tot


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 12
    qt = int(sys.argv[2]) if len(sys.argv) > 2 else 7
    qd = int(sys.argv[3]) if len(sys.argv) > 3 else 7
    tp = T.load_checkpoint("../artifacts/target_ckpt.npz", M.TARGET)
    dp = T.load_checkpoint("../artifacts/drafter_ckpt.npz", M.DRAFTER)
    lex = D.build_lexicon()
    ev = D.eval_set(lex)
    tr = [s for s in ev if s.task == "translate"][:n]
    calib = [s.full_ids()[:128] + [0] * (128 - len(s.full_ids()[:128])) for s in ev[:8]]
    t_scales = Q.calibrate_act_scales(M.TARGET, tp, [calib])
    d_scales = Q.calibrate_act_scales(M.DRAFTER, dp, [calib])
    tq = quantize_params_bits(tp, qt)
    dq = quantize_params_bits(dp, qd)

    configs = [
        ("fp/fp", make_stepper(M.TARGET, tp), make_stepper(M.DRAFTER, dp)),
        (f"semi qmax{qt} T",
         make_stepper(M.TARGET, tq, quant=True, act_scales=t_scales),
         make_stepper(M.DRAFTER, dp)),
        (f"full qmax{qt}/{qd}",
         make_stepper(M.TARGET, tq, quant=True, act_scales=t_scales),
         make_stepper(M.DRAFTER, dq, quant=True, act_scales=d_scales)),
    ]
    for name, ts, ds in configs:
        t0 = time.time()
        vals = [alpha_for(s, ts, ds) for s in tr]
        print(f"{name}: median={np.median(vals):.2f} p90={np.percentile(vals,90):.2f} "
              f"({time.time()-t0:.0f}s) vals=" + " ".join(f"{v:.2f}" for v in vals),
              flush=True)


if __name__ == "__main__":
    main()
