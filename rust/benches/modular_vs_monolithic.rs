//! Bench: modular vs monolithic executor (paper §IV-D / Figs. 3-4).
//! Same prompt, same γ — the real-PJRT cost of the per-call runtime-API
//! boundary the paper holds responsible for part of its 4% deviation.
//! Requires `make artifacts`.

use specedge::bench::{Bench, BenchOpts};
use specedge::config::{ExecMode, KernelPath};
use specedge::hetero::{LatencyModel, Mapping, Platform};
use specedge::models::VariantKey;
use specedge::runtime::Engine;
use specedge::spec::{AcceptRule, Decoder, DecoderSetup};
use specedge::tokenizer::{Tokenizer, SEP_ID};
use std::time::Duration;

fn main() {
    let Ok(engine) = Engine::load(std::path::Path::new("artifacts")) else {
        eprintln!("SKIP modular_vs_monolithic: run `make artifacts` first");
        return;
    };
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec).unwrap();
    let sample = engine
        .manifest
        .eval_samples
        .iter()
        .find(|s| s.task == "translate")
        .unwrap()
        .clone();
    let mut prompt = tokenizer.encode(&sample.prompt, true).unwrap();
    prompt.push(SEP_ID);

    let opts = BenchOpts {
        warmup: Duration::from_millis(100),
        measure: Duration::from_secs(8),
        max_iters: 8,
        min_iters: 2,
    };
    let mut b = Bench::with_opts("mod_vs_mono", opts);
    let lat = LatencyModel::new(Platform::imx95());
    for gamma in [2usize, 5] {
        for exec in [ExecMode::Modular, ExecMode::Monolithic] {
            let setup = DecoderSetup {
                drafter: VariantKey::parse("drafter_fp").unwrap(),
                target: VariantKey::parse("target_w8a8").unwrap(),
                kernel: KernelPath::Pallas,
                mapping: Mapping::heterogeneous(1),
                gamma,
                rule: AcceptRule::Greedy,
                exec,
                max_new: 24,
            };
            let decoder = Decoder::new(&engine, lat.clone(), setup);
            decoder.speculative(&prompt).unwrap(); // warm compile
            b.bench(&format!("{}_g{gamma}_24tok", exec.as_str()), || {
                std::hint::black_box(decoder.speculative(&prompt).unwrap());
            });
        }
    }
    b.finish();
}
