//! Bench: single forward-pass latency per (variant, kernel path, bucket)
//! on the real PJRT CPU client — the data behind Fig. 6's real-hardware
//! validation column and the L1 pallas-vs-ref perf comparison.
//!
//! Requires `make artifacts`.

use specedge::bench::{Bench, BenchOpts};
use specedge::config::KernelPath;
use specedge::models::VariantKey;
use specedge::runtime::Engine;
use std::time::Duration;

fn main() {
    let Ok(engine) = Engine::load(std::path::Path::new("artifacts")) else {
        eprintln!("SKIP forward_bench: run `make artifacts` first");
        return;
    };
    let opts = BenchOpts {
        warmup: Duration::from_millis(500),
        measure: Duration::from_secs(3),
        max_iters: 500,
        min_iters: 3,
    };
    let mut b = Bench::with_opts("forward", opts);
    for key in ["drafter_fp", "target_w8a8"] {
        let v = VariantKey::parse(key).unwrap();
        for kernel in [KernelPath::Pallas, KernelPath::Ref] {
            for bucket in [16usize, 64, 128] {
                let tokens: Vec<u32> =
                    (0..bucket - 2).map(|i| 4 + (i % 40) as u32).collect();
                // warm: compile outside the timed region
                engine.forward(v, kernel, &tokens, bucket).unwrap();
                b.bench(
                    &format!("{key}/{}/s{bucket}", kernel.as_str()),
                    || {
                        std::hint::black_box(
                            engine.forward(v, kernel, &tokens, bucket).unwrap(),
                        );
                    },
                );
            }
        }
    }
    b.finish();
}
