//! Bench: end-to-end request latency through the FULL serving stack
//! (coordinator + worker + policy + engine), baseline vs speculative —
//! the headline-number bench. Requires `make artifacts`.

use specedge::bench::{Bench, BenchOpts};
use specedge::config::RunConfig;
use specedge::coordinator::Coordinator;
use specedge::hetero::Platform;
use specedge::tokenizer::{Tokenizer, SEP_ID};
use specedge::workload::Request;
use std::path::PathBuf;
use std::time::Duration;

fn request(id: u64) -> Request {
    let t = Tokenizer::builtin();
    let mut prompt = t
        .encode("tr: mogdi mogdi peni ture buda ture hevboco curih", true)
        .unwrap();
    prompt.push(SEP_ID);
    Request {
        id,
        task: "translate".into(),
        prompt,
        truth: String::new(),
        arrival_s: 0.0,
        class: None,
    }
}

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP e2e_bench: run `make artifacts` first");
        return;
    }
    let opts = BenchOpts {
        warmup: Duration::from_millis(100),
        measure: Duration::from_secs(10),
        max_iters: 8,
        min_iters: 2,
    };
    let mut b = Bench::with_opts("e2e_serving", opts);

    for (name, speculative) in [("baseline", false), ("speculative_g5", true)] {
        let cfg = RunConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            speculative,
            gamma: if speculative { Some(5) } else { None },
            max_new_tokens: 32,
            ..RunConfig::default()
        };
        let coord = Coordinator::start(cfg, Platform::imx95()).unwrap();
        coord.submit(request(0)).wait().unwrap(); // warm compiles
        let mut id = 1;
        b.bench(&format!("{name}_request_32tok"), || {
            std::hint::black_box(coord.submit(request(id)).wait().unwrap());
            id += 1;
        });
        coord.shutdown();
    }
    b.finish();
}
