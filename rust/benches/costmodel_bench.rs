//! Bench: analytical cost model + DSE (Tables II/III generation path).
//! These run on the serving hot path (adaptive routing evaluates Eq. 1 per
//! request), so they must be effectively free.

use specedge::bench::Bench;
use specedge::costmodel;
use specedge::dse::{self, PairConfig};
use specedge::hetero::{LatencyModel, Platform};
use specedge::models::{ModelSpec, Scheme};

fn pair() -> PairConfig {
    PairConfig {
        target: ModelSpec {
            name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
            ffn_dim: 352, vocab: 48, param_count: 816_256,
        },
        target_scheme: Scheme::W8a8,
        drafter: ModelSpec {
            name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
            ffn_dim: 256, vocab: 48, param_count: 230_880,
        },
        drafter_scheme: Scheme::Fp,
    }
}

fn main() {
    let mut b = Bench::new("costmodel");
    b.bench("speedup_eq1", || {
        std::hint::black_box(costmodel::speedup(
            std::hint::black_box(0.9),
            std::hint::black_box(5),
            std::hint::black_box(0.358),
        ));
    });
    b.bench("optimal_gamma", || {
        std::hint::black_box(costmodel::optimal_gamma(
            std::hint::black_box(0.9),
            std::hint::black_box(0.358),
        ));
    });
    let lat = LatencyModel::new(Platform::imx95());
    let p = pair();
    b.bench("explore_variant", || {
        std::hint::black_box(dse::explore_variant(&lat, &p, 1, 0.9, 63));
    });
    b.bench("explore_all_table2", || {
        std::hint::black_box(dse::explore_all(&lat, &p, 0.9, 63));
    });
    b.finish();
}
