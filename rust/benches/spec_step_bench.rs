//! Bench: one speculative decode per γ (modular path) plus the baseline —
//! the end-to-end data behind Fig. 7b and the headline speedup.
//! Requires `make artifacts`.

use specedge::bench::{Bench, BenchOpts};
use specedge::config::{ExecMode, KernelPath};
use specedge::hetero::{LatencyModel, Mapping, Platform};
use specedge::models::VariantKey;
use specedge::runtime::Engine;
use specedge::spec::{AcceptRule, Decoder, DecoderSetup};
use specedge::tokenizer::{Tokenizer, SEP_ID};
use std::time::Duration;

fn main() {
    let Ok(engine) = Engine::load(std::path::Path::new("artifacts")) else {
        eprintln!("SKIP spec_step_bench: run `make artifacts` first");
        return;
    };
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec).unwrap();
    let sample = engine
        .manifest
        .eval_samples
        .iter()
        .find(|s| s.task == "translate")
        .unwrap()
        .clone();
    let mut prompt = tokenizer.encode(&sample.prompt, true).unwrap();
    prompt.push(SEP_ID);

    let opts = BenchOpts {
        warmup: Duration::from_millis(100),
        measure: Duration::from_secs(8),
        max_iters: 10,
        min_iters: 2,
    };
    let mut b = Bench::with_opts("spec_decode", opts);
    let lat = LatencyModel::new(Platform::imx95());

    let mk = |gamma| DecoderSetup {
        drafter: VariantKey::parse("drafter_fp").unwrap(),
        target: VariantKey::parse("target_w8a8").unwrap(),
        kernel: KernelPath::Pallas,
        mapping: Mapping::heterogeneous(1),
        gamma,
        rule: AcceptRule::Greedy,
        exec: ExecMode::Modular,
        max_new: 32,
    };

    let decoder = Decoder::new(&engine, lat.clone(), mk(1));
    decoder.baseline(&prompt).unwrap(); // warm compile
    b.bench("baseline_32tok", || {
        std::hint::black_box(decoder.baseline(&prompt).unwrap());
    });
    for gamma in [1usize, 3, 5] {
        let decoder = Decoder::new(&engine, lat.clone(), mk(gamma));
        decoder.speculative(&prompt).unwrap();
        b.bench(&format!("speculative_g{gamma}_32tok"), || {
            std::hint::black_box(decoder.speculative(&prompt).unwrap());
        });
    }
    b.finish();
}
