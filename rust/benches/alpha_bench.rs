//! Bench: per-sample α measurement cost (the Fig. 5 experiment's unit of
//! work — 2 forwards per generated token). Requires `make artifacts`.

use specedge::bench::{Bench, BenchOpts};
use specedge::config::KernelPath;
use specedge::experiments::alpha::measure_alpha;
use specedge::models::VariantKey;
use specedge::runtime::Engine;
use specedge::tokenizer::Tokenizer;
use std::time::Duration;

fn main() {
    let Ok(engine) = Engine::load(std::path::Path::new("artifacts")) else {
        eprintln!("SKIP alpha_bench: run `make artifacts` first");
        return;
    };
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec).unwrap();
    let sample = engine
        .manifest
        .eval_samples
        .iter()
        .find(|s| s.task == "translate")
        .unwrap()
        .clone();
    let d = VariantKey::parse("drafter_fp").unwrap();
    let t = VariantKey::parse("target_w8a8").unwrap();
    // warm compiles
    measure_alpha(&engine, &tokenizer, d, t, KernelPath::Pallas, &sample, 8).unwrap();

    let opts = BenchOpts {
        warmup: Duration::from_millis(100),
        measure: Duration::from_secs(6),
        max_iters: 10,
        min_iters: 2,
    };
    let mut b = Bench::with_opts("alpha", opts);
    for max_new in [8usize, 24] {
        b.bench(&format!("measure_alpha_{max_new}tok"), || {
            std::hint::black_box(
                measure_alpha(&engine, &tokenizer, d, t, KernelPath::Pallas,
                              &sample, max_new)
                    .unwrap(),
            );
        });
    }
    b.finish();
}
