//! Bench: the decision layer's hot paths — cost prediction through both
//! CostModel impls, the calibration observe path (on every fused
//! dispatch), and the DSE candidate search the online re-partitioner
//! re-runs every K rounds. None of these touch PJRT, so this bench runs
//! without artifacts.

use specedge::bench::Bench;
use specedge::config::KernelPath;
use specedge::decision::{CalibratedModel, CostModel, DispatchObs};
use specedge::dse::{self, PairConfig};
use specedge::hetero::{LatencyModel, Mapping, Platform, PuAssignment};
use specedge::models::{ModelSpec, Scheme, VariantKey};

fn main() {
    let mut b = Bench::new("decision");

    let d = ModelSpec {
        name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
        ffn_dim: 256, vocab: 48, param_count: 230_880,
    };
    let t = ModelSpec {
        name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
        ffn_dim: 352, vocab: 48, param_count: 816_256,
    };
    let pair = PairConfig {
        target: t.clone(),
        target_scheme: Scheme::W8a8,
        drafter: d.clone(),
        drafter_scheme: Scheme::Fp,
    };
    let lat = LatencyModel::new(Platform::imx95());
    let mapping = Mapping::heterogeneous(1);

    b.bench("analytic_cost_coefficient", || {
        std::hint::black_box(CostModel::cost_coefficient(
            &lat,
            (&d, Scheme::Fp),
            (&t, Scheme::W8a8),
            mapping,
            63,
        ));
    });

    // Warm calibrated model: a fitted key per (variant, PU).
    let calib = CalibratedModel::new(lat.clone());
    let obs = DispatchObs {
        variant: VariantKey::parse("drafter_fp").unwrap(),
        kernel: KernelPath::Ref,
        bucket: 64,
        pu: PuAssignment::Gpu,
        lanes: 4,
        flops: d.forward_flops(64),
        duration_s: lat.batched_forward_latency(&d, Scheme::Fp, PuAssignment::Gpu, 64, 4),
    };
    for bucket in [16usize, 64, 128] {
        for lanes in [1usize, 4] {
            for (key, spec, scheme, pu) in [
                ("drafter_fp", &d, Scheme::Fp, PuAssignment::Gpu),
                ("target_w8a8", &t, Scheme::W8a8, PuAssignment::Cpu { cores: 1 }),
            ] {
                calib.observe(&DispatchObs {
                    variant: VariantKey::parse(key).unwrap(),
                    kernel: KernelPath::Ref,
                    bucket,
                    pu,
                    lanes,
                    flops: spec.forward_flops(bucket),
                    duration_s: lat.batched_forward_latency(spec, scheme, pu, bucket, lanes),
                });
            }
        }
    }
    b.bench("calibrated_cost_coefficient", || {
        std::hint::black_box(calib.cost_coefficient(
            (&d, Scheme::Fp),
            (&t, Scheme::W8a8),
            mapping,
            63,
        ));
    });
    b.bench("calibrated_observe", || {
        calib.observe(std::hint::black_box(&obs));
    });

    b.bench("explore_variant_analytic", || {
        std::hint::black_box(dse::explore_variant(&lat, &pair, 1, 0.9, 63));
    });
    b.bench("explore_variant_calibrated", || {
        std::hint::black_box(dse::explore_variant(&calib, &pair, 1, 0.9, 63));
    });

    // The tree-aware search the `tree: auto` knob runs: every candidate
    // mapping additionally scored against the TREE_SHAPES set. Low α is
    // the regime where trees matter, so that's the point benched.
    b.bench("explore_variant_tree_shapes_analytic", || {
        std::hint::black_box(dse::explore_variant_with_shapes(
            &lat,
            &pair,
            1,
            0.15,
            63,
            &dse::TREE_SHAPES,
        ));
    });
    b.bench("explore_variant_tree_shapes_calibrated", || {
        std::hint::black_box(dse::explore_variant_with_shapes(
            &calib,
            &pair,
            1,
            0.15,
            63,
            &dse::TREE_SHAPES,
        ));
    });
    b.bench("tree_speedup_single_shape", || {
        std::hint::black_box(dse::tree_speedup(
            &lat,
            &pair,
            mapping,
            0.15,
            63,
            specedge::costmodel::TreeShape::new(4, 1),
        ));
    });

    b.finish();
}
