//! Bench: coordinator substrate hot paths that sit on EVERY request —
//! routing policy (adaptive Eq.-1 evaluation), queue push/pop, JSON
//! protocol encode/decode, tokenizer. None of these touch PJRT, so this
//! bench runs without artifacts.

use specedge::bench::Bench;
use specedge::config::RunConfig;
use specedge::coordinator::queue::{QueueItem, RequestQueue};
use specedge::coordinator::Policy;
use specedge::hetero::Platform;
use specedge::models::ModelSpec;
use specedge::tokenizer::Tokenizer;
use specedge::util::json::Json;
use specedge::workload::Request;

fn main() {
    let mut b = Bench::new("router");

    let cfg = RunConfig::default();
    let policy = Policy::new(&cfg, Platform::imx95()).expect("policy");
    let d = ModelSpec {
        name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
        ffn_dim: 256, vocab: 48, param_count: 230_880,
    };
    let t = ModelSpec {
        name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
        ffn_dim: 352, vocab: 48, param_count: 816_256,
    };
    b.bench("policy_route", || {
        std::hint::black_box(policy.route("translate", &d, &t, 63));
    });
    b.bench("policy_observe_alpha", || {
        policy.observe_alpha("translate", std::hint::black_box(0.8));
    });

    let q = RequestQueue::new(1024);
    b.bench("queue_push_pop", || {
        let (tx, _rx) = std::sync::mpsc::channel();
        let item = QueueItem::new(
            Request {
                id: 0, task: "t".into(), prompt: vec![1, 2, 3],
                truth: String::new(), arrival_s: 0.0,
                class: None,
            }
            .into(),
            tx,
            None,
        );
        q.push(item).ok();
        std::hint::black_box(q.pop());
    });

    let tok = Tokenizer::builtin();
    let text = "tr: mogdi mogdi peni ture buda ture hevboco curih ture milori";
    b.bench("tokenizer_encode_63", || {
        std::hint::black_box(tok.encode(text, true).unwrap());
    });
    let ids = tok.encode(text, true).unwrap();
    b.bench("tokenizer_decode_63", || {
        std::hint::black_box(tok.decode(&ids));
    });

    let req = format!(
        r#"{{"prompt":"{text}","task":"translate","max_new":64}}"#
    );
    b.bench("json_parse_request", || {
        std::hint::black_box(Json::parse(&req).unwrap());
    });
    let mut reply = Json::obj();
    reply
        .set("ok", true.into())
        .set("completion", Json::Str(text.into()))
        .set("tokens", 60usize.into())
        .set("sim_ms", 1669.1.into())
        .set("alpha", 0.55.into());
    b.bench("json_serialize_reply", || {
        std::hint::black_box(reply.to_string());
    });

    b.finish();
}
