//! Thread-per-connection serving shell (`serve_mode: threaded`) — the
//! seed architecture, kept as the A/B baseline that `experiment
//! serve_load` measures the [`event_loop`](super::event_loop) shell
//! against.
//!
//! Each accepted connection gets its own OS thread running a blocking
//! read-dispatch-reply loop; at high connection counts the thread
//! spawns, stacks and context switches dominate, which is exactly the
//! regime the event loop exists for. Two fixes over the seed (wire
//! behavior unchanged): the accept loop parks on an adaptive backoff
//! instead of hot-looping at 5ms, and connection reads poll on a short
//! timeout so stop/drain take effect even while peers sit silent
//! (previously [`Server::stop`](super::Server::stop) waited for every
//! client to disconnect).
//!
//! Drain semantics here are the blocking analogue of the event loop's:
//! the accept loop closes the front door, in-flight generates run to
//! completion on their threads (new ones are refused at admission), and
//! each handler exits at its next between-lines poll. The
//! `drain_deadline_s` straggler cancellation is event-loop only — a
//! blocked `wait()` cannot be interrupted from its own thread.

use super::{
    append_history, err_json, frame_json, handle_cmd, reply_final, start_generate, CmdAction,
    GenOutcome, ServeCtx, TokenBucket,
};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Accept-loop idle backoff bounds (the seed hot-looped at a fixed 5ms).
const MIN_IDLE: Duration = Duration::from_millis(1);
const MAX_IDLE: Duration = Duration::from_millis(50);
/// Between-lines read poll: how quickly a silent connection notices
/// stop/drain.
const READ_POLL: Duration = Duration::from_millis(100);

/// Accept loop: one handler thread per connection.
pub(crate) fn run(ctx: Arc<ServeCtx>, listener: TcpListener) {
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut idle = MIN_IDLE;
    let mut last_history = Instant::now();
    while !ctx.stop.load(Ordering::SeqCst) && !ctx.drain.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                idle = MIN_IDLE;
                ctx.stats.conns_accepted.fetch_add(1, Ordering::Relaxed);
                ctx.stats.conns_open.fetch_add(1, Ordering::Relaxed);
                let c = Arc::clone(&ctx);
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, &c);
                    c.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
                }));
            }
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(idle);
                idle = (idle * 2).min(MAX_IDLE);
            }
            Err(_) => break,
        }
        // Reap finished handlers so a long churny run doesn't accumulate
        // thousands of unjoined thread handles.
        let mut i = 0;
        while i < conns.len() {
            if conns[i].is_finished() {
                let _ = conns.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
        if ctx.metrics_history.is_some() {
            let every = ctx.tuning.lock().unwrap().metrics_history_every_s;
            if last_history.elapsed().as_secs_f64() >= every {
                last_history = Instant::now();
                append_history(&ctx);
            }
        }
    }
    // Drain or stop: the front door is closed; handlers exit at their
    // next between-lines poll (in-flight generates finish first).
    for c in conns {
        let _ = c.join();
    }
    ctx.stop.store(true, Ordering::SeqCst);
    append_history(&ctx);
}

fn handle_conn(stream: TcpStream, ctx: &ServeCtx) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(READ_POLL))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut bucket = TokenBucket::new(ctx.tuning.lock().unwrap().rate_limit_burst);
    let mut line = String::new();
    loop {
        line.clear();
        // Poll-read so stop/drain are honored while the peer is silent.
        // A timeout leaves any partial bytes in `line` (read_line keeps
        // what it read before erroring), so reassembly is preserved
        // across polls.
        loop {
            match reader.read_line(&mut line) {
                Ok(0) => return Ok(()), // client closed
                Ok(_) => break,
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    if ctx.stop.load(Ordering::SeqCst) || ctx.drain.load(Ordering::SeqCst) {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        ctx.stats.lines_in.fetch_add(1, Ordering::Relaxed);
        let reply = match Json::parse(trimmed) {
            Err(e) => err_json(&format!("bad json: {e}"), None),
            Ok(req) => {
                if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
                    match handle_cmd(cmd, &req, ctx) {
                        CmdAction::Reply(j) => j,
                        CmdAction::Shutdown(j) => {
                            // The stop flag is already set; flush the ack
                            // and let the accept loop wind everything down.
                            writeln!(stream, "{j}")?;
                            return Ok(());
                        }
                    }
                } else {
                    match start_generate(&req, ctx, &mut bucket) {
                        GenOutcome::Reply(j) => j,
                        GenOutcome::Submitted(a) => {
                            if a.streaming {
                                // Relay each round's frame as it commits;
                                // the iterator ends when the worker
                                // retires the session.
                                for f in a.handle.frames() {
                                    writeln!(
                                        stream,
                                        "{}",
                                        frame_json(&f, &ctx.tokenizer, a.v2)
                                    )?;
                                }
                            }
                            reply_final(a.handle.wait(), a.streaming, a.v2, a.req_id, &ctx.backend)
                        }
                    }
                }
            }
        };
        writeln!(stream, "{reply}")?;
    }
}
