//! TCP line-JSON serving front-end — wire protocol v1 (seed, frozen) and
//! v2 (typed options + lifecycle).
//!
//! Protocol: one JSON object per line.
//!
//! **v1** (any line without `"v":2` — byte-identical to the seed
//! protocol, pinned by `tests/lifecycle_e2e.rs`):
//!
//! ```text
//! → {"prompt": "tr: cela vodu", "task": "translate"}
//! ← {"ok": true, "completion": "...", "tokens": 12, "sim_ms": 31.2,
//!    "real_ms": 8.4, "queue_ms": 0.1, "alpha": 0.83,
//!    "speculative": true, "gamma": 5, "rounds": 3}
//! ```
//!
//! **v2** (`"v":2`): requests may carry a client-chosen numeric `req_id`
//! and a typed `options` object (see
//! [`GenOptions::from_json`](crate::api::GenOptions::from_json) for the
//! full knob set):
//!
//! ```text
//! → {"v":2, "req_id":7, "prompt":"tr: cela vodu", "task":"translate",
//!    "options":{"max_new":32, "deadline_ms":250, "priority":3,
//!               "gamma_cap":2, "stop":["."]}}
//! ← {"v":2, "req_id":7, "ok":true, "finish":"stop", ...v1 fields...}
//! ```
//!
//! v2 error replies are typed — `"kind"` is one of
//! `bad_request | overloaded | cancelled | deadline | internal` — and
//! carry queue-state fields (`queue_len`, `queue_capacity`) so clients
//! can implement backoff; `cancelled`/`deadline` mark requests that died
//! before producing any decode output (a mid-decode cancel or expiry
//! instead returns `ok:true` with the partial tokens and the matching
//! `finish` reason). v1 error replies stay `{"ok":false,"error":...}`,
//! echoing the offending `req_id` when the line carried one.
//!
//! With `"stream": true` the reply is incremental: one
//! `{"ok":true,"frame":"tokens","text":...,"round":r,"drafted":d,
//! "accepted":a,"done":false}` line per speculation round as the scheduler
//! commits tokens (v2 frames additionally carry `req_id`), terminated by
//! the usual summary object tagged `"frame":"final"`.
//!
//! Commands: `{"cmd":"metrics"}` returns a metrics snapshot;
//! `{"cmd":"cancel","req_id":N}` flags request N for cancellation (it
//! aborts at its next round boundary — cancellation reaches across
//! connections, which is how a streaming request is cancelled);
//! `{"cmd":"shutdown"}` stops the listener.

use crate::api::{FinishReason, GenOptions, GenerationRequest};
use crate::coordinator::{Coordinator, RequestHandle};
use crate::fleet::FleetRouter;
use crate::tokenizer::{Tokenizer, SEP_ID};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What the server fronts: one coordinator (the historical shape) or a
/// multi-device [`FleetRouter`] (`serve --fleet topo.json`). Generate
/// lines, cancellation and backpressure fields all go through this seam;
/// the single-coordinator wire behavior is unchanged.
pub enum Backend {
    Single(Arc<Coordinator>),
    Fleet(Arc<FleetRouter>),
}

impl Backend {
    fn submit(&self, req: GenerationRequest) -> RequestHandle {
        match self {
            Backend::Single(c) => c.submit(req),
            Backend::Fleet(f) => f.submit(req).handle,
        }
    }

    fn cancel(&self, id: u64) -> bool {
        match self {
            Backend::Single(c) => c.cancel(id),
            Backend::Fleet(f) => f.cancel(id),
        }
    }

    /// Admission-queue depth (summed across fleet devices).
    fn queue_len(&self) -> usize {
        match self {
            Backend::Single(c) => c.queue_len(),
            Backend::Fleet(f) => f.devices().iter().map(|d| d.coordinator.queue_len()).sum(),
        }
    }

    /// Admission-queue capacity (summed across fleet devices).
    fn queue_capacity(&self) -> usize {
        match self {
            Backend::Single(c) => c.queue_capacity(),
            Backend::Fleet(f) => {
                f.devices().iter().map(|d| d.coordinator.queue_capacity()).sum()
            }
        }
    }
}

/// Running server handle.
pub struct Server {
    pub port: u16,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on a background thread. Port 0 picks a free port.
    pub fn start(
        coordinator: Arc<Coordinator>,
        tokenizer: Tokenizer,
        port: u16,
    ) -> anyhow::Result<Server> {
        Server::start_with(Backend::Single(coordinator), tokenizer, port)
    }

    /// Bind and serve an explicit [`Backend`] (fleet-aware entry point).
    pub fn start_with(
        backend: Backend,
        tokenizer: Tokenizer,
        port: u16,
    ) -> anyhow::Result<Server> {
        let backend = Arc::new(backend);
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let start_wall = std::time::Instant::now();
        // Server-assigned ids start at 2^48: far above practical
        // client-chosen v2 req_ids (the cancellation registry is one
        // shared namespace) yet small enough that every id stays exactly
        // representable in the f64-backed JSON codec when echoed.
        let next_id = Arc::new(AtomicU64::new(1 << 48));
        let handle = std::thread::Builder::new()
            .name("specedge-server".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let c = Arc::clone(&backend);
                            let t = tokenizer.clone();
                            let s = Arc::clone(&stop2);
                            let ids = Arc::clone(&next_id);
                            conns.push(std::thread::spawn(move || {
                                let _ = handle_conn(stream, c, t, s, ids, start_wall);
                            }));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })?;
        Ok(Server { port, stop, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    coordinator: Arc<Backend>,
    tokenizer: Tokenizer,
    stop: Arc<AtomicBool>,
    next_id: Arc<AtomicU64>,
    start_wall: std::time::Instant,
) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = match Json::parse(trimmed) {
            Err(e) => err_json(&format!("bad json: {e}"), None),
            Ok(req) => {
                let req_id = wire_req_id(&req);
                if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
                    match cmd {
                        "metrics" => metrics_json(&coordinator, start_wall),
                        "cancel" => cancel_json(&req, &coordinator),
                        "shutdown" => {
                            stop.store(true, Ordering::SeqCst);
                            let mut j = Json::obj();
                            j.set("ok", true.into());
                            writeln!(stream, "{j}")?;
                            return Ok(());
                        }
                        other => err_json(&format!("unknown cmd {other:?}"), req_id),
                    }
                } else {
                    handle_generate(&req, &coordinator, &tokenizer, &next_id, &mut stream)?
                }
            }
        };
        writeln!(stream, "{reply}")?;
    }
}

/// The client-chosen `req_id`, when the line carries a valid one (the
/// same strict integer rule the options parser applies).
fn wire_req_id(req: &Json) -> Option<u64> {
    req.get("req_id").and_then(crate::api::wire_uint)
}

/// Serve one generate request. Streaming requests write their incremental
/// frames to `stream` directly; the returned Json is the line the caller
/// writes last (the final summary, or an error object).
fn handle_generate(
    req: &Json,
    coordinator: &Backend,
    tokenizer: &Tokenizer,
    next_id: &AtomicU64,
    stream: &mut TcpStream,
) -> anyhow::Result<Json> {
    let version = req.get("v").and_then(Json::as_usize).unwrap_or(1);
    let req_id = wire_req_id(req);
    if version != 1 && version != 2 {
        return Ok(err_v2(
            "bad_request",
            &format!("unsupported protocol version {version}"),
            req_id,
            coordinator,
        ));
    }
    let v2 = version == 2;
    let prompt_text = match req.get("prompt").and_then(Json::as_str) {
        Some(p) => p,
        None => {
            return Ok(if v2 {
                err_v2("bad_request", "missing `prompt`", req_id, coordinator)
            } else {
                err_json("missing `prompt`", req_id)
            });
        }
    };
    let task = req
        .get("task")
        .and_then(Json::as_str)
        .unwrap_or("unknown")
        .to_string();
    let streaming = req.get("stream").and_then(Json::as_bool).unwrap_or(false);
    // v2 only: the typed options object (strictly validated — unknown
    // knobs and wrong types come back as bad_request).
    let options = if v2 {
        match req.get("options") {
            None => GenOptions::default(),
            Some(o) => match GenOptions::from_json(o) {
                Ok(o) => o,
                Err(e) => {
                    return Ok(err_v2(
                        "bad_request",
                        &format!("invalid options: {e}"),
                        req_id,
                        coordinator,
                    ));
                }
            },
        }
    } else {
        GenOptions::default()
    };
    let mut prompt = match tokenizer.encode(prompt_text, true) {
        Ok(p) => p,
        Err(e) => {
            return Ok(if v2 {
                err_v2("bad_request", &format!("{e}"), req_id, coordinator)
            } else {
                err_json(&format!("{e}"), req_id)
            });
        }
    };
    prompt.push(SEP_ID);
    // v2 clients address cancellation by their own req_id, so it becomes
    // the coordinator-visible id; v1 keeps server-assigned ids.
    let id = match req_id {
        Some(id) if v2 => id,
        _ => next_id.fetch_add(1, Ordering::Relaxed),
    };
    let request = GenerationRequest {
        id,
        task,
        prompt,
        truth: String::new(),
        arrival_s: 0.0,
        options,
    };
    let handle = coordinator.submit(request);
    if !streaming {
        return Ok(reply_final(handle.wait(), false, v2, req_id, coordinator));
    }
    // Relay each round's frame as it commits; the iterator ends when the
    // worker retires the session and drops the sender.
    for f in handle.frames() {
        let mut j = Json::obj();
        j.set("ok", true.into())
            .set("frame", Json::Str("tokens".into()))
            .set("round", f.round.into())
            .set("text", Json::Str(tokenizer.decode(&f.tokens)))
            .set("n_tokens", f.tokens.len().into())
            .set("drafted", f.drafted.into())
            .set("accepted", f.accepted.into())
            .set("done", f.done.into());
        if v2 {
            j.set("req_id", (f.id as usize).into()).set("v", 2usize.into());
        }
        writeln!(stream, "{j}")?;
    }
    Ok(reply_final(handle.wait(), true, v2, req_id, coordinator))
}

/// Map a request's final outcome onto the wire: v1 keeps the seed reply
/// shapes byte-for-byte; v2 adds `v`/`req_id`/`finish` and turns
/// produced-nothing lifecycle deaths into typed errors.
fn reply_final(
    result: anyhow::Result<crate::coordinator::EngineResponse>,
    tagged: bool,
    v2: bool,
    req_id: Option<u64>,
    coordinator: &Backend,
) -> Json {
    let r = match result {
        Ok(r) => r,
        Err(_) => {
            return if v2 {
                err_v2("internal", "worker dropped the request", req_id, coordinator)
            } else {
                err_json("worker dropped the request", req_id)
            };
        }
    };
    if r.finish == FinishReason::Rejected {
        // The seed protocol surfaced backpressure as this exact error.
        return if v2 {
            err_v2("overloaded", "queue full (backpressure)", req_id, coordinator)
        } else {
            err_json("queue full (backpressure)", req_id)
        };
    }
    if v2 && r.rounds == 0 && r.tokens.is_empty() {
        // Died before producing anything: a typed lifecycle error.
        match r.finish {
            FinishReason::Cancelled => {
                return err_v2("cancelled", "cancelled before any output", req_id, coordinator);
            }
            FinishReason::DeadlineExceeded => {
                return err_v2("deadline", "deadline expired before any output", req_id, coordinator);
            }
            _ => {}
        }
    }
    final_json(r, tagged, v2)
}

fn cancel_json(req: &Json, coordinator: &Backend) -> Json {
    let id = match wire_req_id(req) {
        Some(id) => id,
        None => {
            return err_v2(
                "bad_request",
                "cancel requires a numeric `req_id`",
                None,
                coordinator,
            );
        }
    };
    if coordinator.cancel(id) {
        let mut j = Json::obj();
        j.set("ok", true.into())
            .set("cancelled", true.into())
            .set("req_id", (id as usize).into())
            .set("v", 2usize.into());
        j
    } else {
        err_v2(
            "bad_request",
            &format!("unknown req_id {id} (never submitted, or already finished)"),
            Some(id),
            coordinator,
        )
    }
}

fn metrics_json(backend: &Backend, start_wall: std::time::Instant) -> Json {
    match backend {
        Backend::Single(c) => coordinator_metrics_json(c, start_wall),
        Backend::Fleet(f) => fleet_metrics_json(f, start_wall),
    }
}

/// Fleet metrics: one full per-device metrics object per device (keyed by
/// device name, same shape as the single-coordinator snapshot) plus the
/// fleet-tier placement/verify-routing counters.
fn fleet_metrics_json(fleet: &FleetRouter, start_wall: std::time::Instant) -> Json {
    let mut j = Json::obj();
    j.set("ok", true.into())
        .set("fleet_devices", fleet.device_count().into())
        .set("wall_s", start_wall.elapsed().as_secs_f64().into());
    let mut devices = Vec::new();
    for d in fleet.devices() {
        let mut dj = coordinator_metrics_json(&d.coordinator, start_wall);
        dj.set("device", Json::Str(d.name.clone()));
        devices.push(dj);
    }
    j.set("devices", Json::Arr(devices));
    let fr = fleet.metrics().snapshot();
    j.set(
        "placements",
        Json::Arr(fr.placements.iter().map(|&n| (n as usize).into()).collect()),
    )
    .set("kv_filtered", (fr.kv_filtered as usize).into())
    .set("cloud_requests", (fr.cloud_requests as usize).into())
    .set("local_verify_rounds", (fr.local_verify_rounds as usize).into())
    .set("cloud_verify_rounds", (fr.cloud_verify_rounds as usize).into())
    .set("cloud_verify_frac", fr.cloud_verify_frac().into())
    .set("net_s", fr.net_s.into())
    .set("cloud_tokens_shipped", (fr.cloud_tokens_shipped as usize).into());
    j
}

fn coordinator_metrics_json(coordinator: &Coordinator, start_wall: std::time::Instant) -> Json {
    let r = coordinator.metrics.snapshot();
    let mut j = Json::obj();
    j.set("ok", true.into())
        .set("requests", (r.requests as usize).into())
        .set("rejected", (r.rejected as usize).into())
        .set("tokens", (r.tokens_out as usize).into())
        .set("mean_alpha", r.mean_alpha.into())
        .set("sim_p50_ms", (r.sim_latency.median * 1e3).into())
        .set("sim_p90_ms", (r.sim_latency.p90 * 1e3).into())
        .set("rounds", (r.rounds as usize).into())
        .set("mean_round_gamma", r.mean_round_gamma.into())
        .set("mean_inflight", r.mean_inflight.into())
        .set("max_inflight", r.max_inflight.into())
        .set("dispatches", (r.dispatches as usize).into())
        .set("fused_dispatches", (r.fused_dispatches as usize).into())
        .set("batch_fill", r.batch_fill.into())
        .set("tree_rounds", (r.tree_rounds as usize).into())
        .set("mean_tree_depth", r.mean_tree_depth.into())
        .set("tree_lane_fill", r.tree_lane_fill.into())
        .set("cpu_busy_s", r.pu_busy[0].into())
        .set("gpu_busy_s", r.pu_busy[1].into())
        .set("overlap_s", r.overlap_s.into())
        .set("makespan_s", r.makespan_s.into())
        .set("tl_latency_p50_ms", (r.tl_latency.median * 1e3).into())
        .set("wall_s", start_wall.elapsed().as_secs_f64().into());
    // Request-lifecycle accounting: per-finish-reason counts, per-SLO
    // class counts, deadline-miss rate.
    for reason in FinishReason::all() {
        j.set(
            &format!("finish_{}", reason.as_str()),
            (r.finish_count(reason) as usize).into(),
        );
    }
    j.set(
        "slo_interactive",
        (r.slo_requests[crate::api::SloClass::Interactive.index()] as usize).into(),
    )
    .set(
        "slo_batch",
        (r.slo_requests[crate::api::SloClass::Batch.index()] as usize).into(),
    )
    .set("deadline_requests", (r.deadline_requests as usize).into())
    .set("deadline_missed", (r.deadline_missed as usize).into())
    .set("deadline_miss_rate", r.deadline_miss_rate().into());
    // Decision-layer state: which cost model is live, the mapping new
    // admissions receive, and the calibration/prior counters.
    let calib = coordinator.policy.calibration();
    j.set(
        "decision",
        Json::Str(coordinator.policy.decision_mode().as_str().into()),
    )
    .set(
        "mapping",
        Json::Str(coordinator.policy.current_mapping().label()),
    )
    .set(
        "repartitions",
        (coordinator.policy.repartition_count() as usize).into(),
    )
    .set("prior_decisions", (r.prior_decisions as usize).into())
    .set("calibration_obs", (r.calibration_obs as usize).into())
    .set("calibration_tracked_keys", calib.tracked_keys.into())
    .set("calibration_fitted_keys", calib.fitted_keys.into());
    // Paged-KV-cache state (all-zero when `kv_cache: off`): prefix-trie
    // effectiveness, admission sheds, and per-PU page-pool occupancy.
    j.set("kv_lookups", (r.kv_lookups as usize).into())
        .set("kv_prefix_hit_rate", r.kv_prefix_hit_rate().into())
        .set(
            "kv_prefill_tokens_saved",
            (r.kv_prefill_tokens_saved as usize).into(),
        )
        .set("kv_memory_shed", (r.kv_memory_shed as usize).into())
        .set(
            "kv_reap_reclaimed_pages",
            (r.kv_reap_reclaimed_pages as usize).into(),
        )
        .set("kv_pages_used_cpu", (r.kv_pages_used[0] as usize).into())
        .set("kv_pages_used_gpu", (r.kv_pages_used[1] as usize).into())
        .set("kv_pages_peak_cpu", (r.kv_pages_peak[0] as usize).into())
        .set("kv_pages_peak_gpu", (r.kv_pages_peak[1] as usize).into())
        .set("kv_pages_cap_cpu", (r.kv_pages_capacity[0] as usize).into())
        .set("kv_pages_cap_gpu", (r.kv_pages_capacity[1] as usize).into());
    j
}

fn final_json(r: crate::coordinator::EngineResponse, tagged: bool, v2: bool) -> Json {
    let mut j = Json::obj();
    if tagged {
        j.set("frame", Json::Str("final".into()));
    }
    if v2 {
        j.set("v", 2usize.into())
            .set("req_id", (r.id as usize).into())
            .set("finish", Json::Str(r.finish.as_str().into()));
    }
    j.set("ok", true.into())
        .set("completion", Json::Str(r.completion))
        .set("tokens", r.tokens.len().into())
        .set("sim_ms", (r.sim_s * 1e3).into())
        .set("real_ms", (r.real_s * 1e3).into())
        .set("queue_ms", (r.queue_s * 1e3).into())
        .set("alpha", r.alpha.into())
        .set("speculative", r.speculative.into())
        .set("gamma", r.gamma.into())
        .set("rounds", r.rounds.into());
    j
}

/// The seed error shape (v1, byte-identical for seed lines), plus the
/// offending `req_id` when the request line carried one.
fn err_json(msg: &str, req_id: Option<u64>) -> Json {
    let mut j = Json::obj();
    j.set("ok", false.into()).set("error", Json::Str(msg.to_string()));
    if let Some(id) = req_id {
        j.set("req_id", (id as usize).into());
    }
    j
}

/// A v2 typed error: `kind` ∈ `bad_request | overloaded | cancelled |
/// deadline | internal`, plus queue-state fields for client backoff.
fn err_v2(kind: &str, msg: &str, req_id: Option<u64>, coordinator: &Backend) -> Json {
    let mut j = err_json(msg, req_id);
    j.set("v", 2usize.into())
        .set("kind", Json::Str(kind.into()))
        .set("queue_len", coordinator.queue_len().into())
        .set("queue_capacity", coordinator.queue_capacity().into());
    j
}

/// Minimal blocking client for tests, examples and the load generator.
/// Speaks both protocol versions: [`generate`](Client::generate) /
/// [`generate_stream`](Client::generate_stream) emit seed-shaped v1
/// lines, [`generate_with`](Client::generate_with) /
/// [`generate_stream_with`](Client::generate_stream_with) the typed v2
/// protocol, and [`cancel`](Client::cancel) the cancel command. A
/// configurable [read timeout](Client::set_read_timeout) turns a dead
/// server into a typed error instead of a hang.
pub struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    pub fn connect(port: u16) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(("127.0.0.1", port))?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), stream })
    }

    /// Abort reads that wait longer than `timeout` (None = wait forever,
    /// the default). An expired timeout surfaces as an
    /// "timed out waiting for the server" error from the blocked call.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> anyhow::Result<()> {
        // Both handles alias one socket; set through the reader's (the
        // one reads actually go through) and keep the writer consistent.
        self.reader.get_ref().set_read_timeout(timeout)?;
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    /// Write one request line (no reply expected yet).
    pub fn send(&mut self, req: &Json) -> anyhow::Result<()> {
        writeln!(self.stream, "{req}")?;
        Ok(())
    }

    /// Read one reply line, mapping closed connections and read timeouts
    /// to typed errors.
    pub fn read_reply(&mut self) -> anyhow::Result<Json> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => anyhow::bail!("server closed the connection"),
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                anyhow::bail!("timed out waiting for the server (read timeout)")
            }
            Err(e) => return Err(e.into()),
        }
        Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }

    pub fn call(&mut self, req: &Json) -> anyhow::Result<Json> {
        self.send(req)?;
        self.read_reply()
    }

    /// v1 generate (seed protocol).
    pub fn generate(&mut self, prompt: &str, task: &str) -> anyhow::Result<Json> {
        let mut j = Json::obj();
        j.set("prompt", Json::Str(prompt.into()))
            .set("task", Json::Str(task.into()));
        self.call(&j)
    }

    /// v2 generate with typed options and a client-chosen `req_id` (the
    /// id [`cancel`](Client::cancel) addresses).
    pub fn generate_with(
        &mut self,
        prompt: &str,
        task: &str,
        req_id: u64,
        options: &GenOptions,
    ) -> anyhow::Result<Json> {
        self.call(&v2_line(prompt, task, req_id, options, false))
    }

    /// Cancel a submitted request by `req_id` (from any connection).
    pub fn cancel(&mut self, req_id: u64) -> anyhow::Result<Json> {
        let mut j = Json::obj();
        j.set("cmd", Json::Str("cancel".into()))
            .set("req_id", (req_id as usize).into());
        self.call(&j)
    }

    /// v1 streaming generate: returns the per-round token frames and the
    /// final summary object (which is also the only line for error
    /// replies).
    pub fn generate_stream(
        &mut self,
        prompt: &str,
        task: &str,
    ) -> anyhow::Result<(Vec<Json>, Json)> {
        let mut j = Json::obj();
        j.set("prompt", Json::Str(prompt.into()))
            .set("task", Json::Str(task.into()))
            .set("stream", true.into());
        self.send(&j)?;
        self.collect_stream()
    }

    /// v2 streaming generate with typed options.
    pub fn generate_stream_with(
        &mut self,
        prompt: &str,
        task: &str,
        req_id: u64,
        options: &GenOptions,
    ) -> anyhow::Result<(Vec<Json>, Json)> {
        self.send(&v2_line(prompt, task, req_id, options, true))?;
        self.collect_stream()
    }

    /// Drain `frame:"tokens"` lines until the terminating non-frame line.
    fn collect_stream(&mut self) -> anyhow::Result<(Vec<Json>, Json)> {
        let mut frames = Vec::new();
        loop {
            let reply = self
                .read_reply()
                .map_err(|e| anyhow::anyhow!("mid-stream: {e}"))?;
            match reply.get("frame").and_then(Json::as_str) {
                Some("tokens") => frames.push(reply),
                _ => return Ok((frames, reply)),
            }
        }
    }
}

/// Build one v2 generate line.
fn v2_line(prompt: &str, task: &str, req_id: u64, options: &GenOptions, stream: bool) -> Json {
    let mut j = Json::obj();
    j.set("v", 2usize.into())
        .set("req_id", (req_id as usize).into())
        .set("prompt", Json::Str(prompt.into()))
        .set("task", Json::Str(task.into()));
    if stream {
        j.set("stream", true.into());
    }
    let o = options.to_json();
    let empty = o.as_obj().map(|m| m.is_empty()).unwrap_or(true);
    if !empty {
        j.set("options", o);
    }
    j
}
