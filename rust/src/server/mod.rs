//! TCP line-JSON serving front-end.
//!
//! Protocol: one JSON object per line.
//!
//! ```text
//! → {"prompt": "tr: cela vodu", "task": "translate", "max_new": 64}
//! ← {"ok": true, "completion": "...", "tokens": 12, "sim_ms": 31.2,
//!    "real_ms": 8.4, "alpha": 0.83, "speculative": true, "gamma": 5}
//! ```
//!
//! With `"stream": true` the reply is incremental: one
//! `{"ok":true,"frame":"tokens","text":...,"round":r,"drafted":d,
//! "accepted":a,"done":false}` line per speculation round as the scheduler
//! commits tokens, terminated by the usual summary object tagged
//! `"frame":"final"`. Clients that never ask for streaming see the
//! single-line protocol unchanged.
//!
//! `{"cmd": "metrics"}` returns a metrics snapshot; `{"cmd": "shutdown"}`
//! stops the listener (used by tests and the E2E example).

use crate::coordinator::Coordinator;
use crate::tokenizer::{Tokenizer, SEP_ID};
use crate::util::json::Json;
use crate::workload::Request;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Running server handle.
pub struct Server {
    pub port: u16,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on a background thread. Port 0 picks a free port.
    pub fn start(
        coordinator: Arc<Coordinator>,
        tokenizer: Tokenizer,
        port: u16,
    ) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let start_wall = std::time::Instant::now();
        let next_id = Arc::new(AtomicU64::new(1));
        let handle = std::thread::Builder::new()
            .name("specedge-server".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let c = Arc::clone(&coordinator);
                            let t = tokenizer.clone();
                            let s = Arc::clone(&stop2);
                            let ids = Arc::clone(&next_id);
                            conns.push(std::thread::spawn(move || {
                                let _ = handle_conn(stream, c, t, s, ids, start_wall);
                            }));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })?;
        Ok(Server { port, stop, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    coordinator: Arc<Coordinator>,
    tokenizer: Tokenizer,
    stop: Arc<AtomicBool>,
    next_id: Arc<AtomicU64>,
    start_wall: std::time::Instant,
) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = match Json::parse(trimmed) {
            Err(e) => err_json(&format!("bad json: {e}")),
            Ok(req) => {
                if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
                    match cmd {
                        "metrics" => {
                            let r = coordinator.metrics.snapshot();
                            let mut j = Json::obj();
                            j.set("ok", true.into())
                                .set("requests", (r.requests as usize).into())
                                .set("rejected", (r.rejected as usize).into())
                                .set("tokens", (r.tokens_out as usize).into())
                                .set("mean_alpha", r.mean_alpha.into())
                                .set("sim_p50_ms", (r.sim_latency.median * 1e3).into())
                                .set("sim_p90_ms", (r.sim_latency.p90 * 1e3).into())
                                .set("rounds", (r.rounds as usize).into())
                                .set("mean_round_gamma", r.mean_round_gamma.into())
                                .set("mean_inflight", r.mean_inflight.into())
                                .set("max_inflight", r.max_inflight.into())
                                .set("dispatches", (r.dispatches as usize).into())
                                .set(
                                    "fused_dispatches",
                                    (r.fused_dispatches as usize).into(),
                                )
                                .set("batch_fill", r.batch_fill.into())
                                .set("cpu_busy_s", r.pu_busy[0].into())
                                .set("gpu_busy_s", r.pu_busy[1].into())
                                .set("overlap_s", r.overlap_s.into())
                                .set("makespan_s", r.makespan_s.into())
                                .set(
                                    "tl_latency_p50_ms",
                                    (r.tl_latency.median * 1e3).into(),
                                )
                                .set("wall_s", start_wall.elapsed().as_secs_f64().into());
                            // Decision-layer state: which cost model is
                            // live, the mapping new admissions receive,
                            // and the calibration/prior counters.
                            let calib = coordinator.policy.calibration();
                            j.set(
                                "decision",
                                Json::Str(
                                    coordinator.policy.decision_mode().as_str().into(),
                                ),
                            )
                            .set(
                                "mapping",
                                Json::Str(coordinator.policy.current_mapping().label()),
                            )
                            .set(
                                "repartitions",
                                (coordinator.policy.repartition_count() as usize).into(),
                            )
                            .set(
                                "prior_decisions",
                                (r.prior_decisions as usize).into(),
                            )
                            .set(
                                "calibration_obs",
                                (r.calibration_obs as usize).into(),
                            )
                            .set("calibration_tracked_keys", calib.tracked_keys.into())
                            .set("calibration_fitted_keys", calib.fitted_keys.into());
                            j
                        }
                        "shutdown" => {
                            stop.store(true, Ordering::SeqCst);
                            let mut j = Json::obj();
                            j.set("ok", true.into());
                            writeln!(stream, "{j}")?;
                            return Ok(());
                        }
                        other => err_json(&format!("unknown cmd {other:?}")),
                    }
                } else {
                    handle_generate(&req, &coordinator, &tokenizer, &next_id, &mut stream)?
                }
            }
        };
        writeln!(stream, "{reply}")?;
    }
}

/// Serve one generate request. Streaming requests write their incremental
/// frames to `stream` directly; the returned Json is the line the caller
/// writes last (the final summary, or an error object).
fn handle_generate(
    req: &Json,
    coordinator: &Coordinator,
    tokenizer: &Tokenizer,
    next_id: &AtomicU64,
    stream: &mut TcpStream,
) -> anyhow::Result<Json> {
    let prompt_text = match req.get("prompt").and_then(Json::as_str) {
        Some(p) => p,
        None => return Ok(err_json("missing `prompt`")),
    };
    let task = req
        .get("task")
        .and_then(Json::as_str)
        .unwrap_or("unknown")
        .to_string();
    let streaming = req.get("stream").and_then(Json::as_bool).unwrap_or(false);
    let mut prompt = match tokenizer.encode(prompt_text, true) {
        Ok(p) => p,
        Err(e) => return Ok(err_json(&format!("{e}"))),
    };
    prompt.push(SEP_ID);
    let request = Request {
        id: next_id.fetch_add(1, Ordering::Relaxed),
        task,
        prompt,
        truth: String::new(),
        arrival_s: 0.0,
    };
    if !streaming {
        return Ok(match coordinator.submit_blocking(request) {
            Err(e) => err_json(&format!("{e}")),
            Ok(r) => final_json(r, false),
        });
    }
    let (frames, final_rx) = match coordinator.submit_streaming(request) {
        Ok(p) => p,
        Err(e) => return Ok(err_json(&format!("{e}"))),
    };
    // Relay each round's frame as it commits; the iterator ends when the
    // worker retires the session and drops the sender.
    for f in frames.iter() {
        let mut j = Json::obj();
        j.set("ok", true.into())
            .set("frame", Json::Str("tokens".into()))
            .set("round", f.round.into())
            .set("text", Json::Str(tokenizer.decode(&f.tokens)))
            .set("n_tokens", f.tokens.len().into())
            .set("drafted", f.drafted.into())
            .set("accepted", f.accepted.into())
            .set("done", f.done.into());
        writeln!(stream, "{j}")?;
    }
    Ok(match final_rx.recv() {
        Err(_) => err_json("worker dropped the request"),
        Ok(r) => final_json(r, true),
    })
}

fn final_json(r: crate::coordinator::EngineResponse, tagged: bool) -> Json {
    let mut j = Json::obj();
    if tagged {
        j.set("frame", Json::Str("final".into()));
    }
    j.set("ok", true.into())
        .set("completion", Json::Str(r.completion))
        .set("tokens", r.tokens.len().into())
        .set("sim_ms", (r.sim_s * 1e3).into())
        .set("real_ms", (r.real_s * 1e3).into())
        .set("queue_ms", (r.queue_s * 1e3).into())
        .set("alpha", r.alpha.into())
        .set("speculative", r.speculative.into())
        .set("gamma", r.gamma.into())
        .set("rounds", r.rounds.into());
    j
}

fn err_json(msg: &str) -> Json {
    let mut j = Json::obj();
    j.set("ok", false.into()).set("error", Json::Str(msg.to_string()));
    j
}

/// Minimal blocking client for tests, examples and the load generator.
pub struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    pub fn connect(port: u16) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(("127.0.0.1", port))?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), stream })
    }

    pub fn call(&mut self, req: &Json) -> anyhow::Result<Json> {
        writeln!(self.stream, "{req}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }

    pub fn generate(&mut self, prompt: &str, task: &str) -> anyhow::Result<Json> {
        let mut j = Json::obj();
        j.set("prompt", Json::Str(prompt.into()))
            .set("task", Json::Str(task.into()));
        self.call(&j)
    }

    /// Streaming generate: returns the per-round token frames and the final
    /// summary object (which is also the only line for error replies).
    pub fn generate_stream(
        &mut self,
        prompt: &str,
        task: &str,
    ) -> anyhow::Result<(Vec<Json>, Json)> {
        let mut j = Json::obj();
        j.set("prompt", Json::Str(prompt.into()))
            .set("task", Json::Str(task.into()))
            .set("stream", true.into());
        writeln!(self.stream, "{j}")?;
        let mut frames = Vec::new();
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                anyhow::bail!("server closed mid-stream");
            }
            let reply = Json::parse(line.trim())
                .map_err(|e| anyhow::anyhow!("bad reply: {e}"))?;
            match reply.get("frame").and_then(Json::as_str) {
                Some("tokens") => frames.push(reply),
                _ => return Ok((frames, reply)),
            }
        }
    }
}
