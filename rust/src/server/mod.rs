//! TCP line-JSON serving front-end — wire protocol v1 (seed, frozen) and
//! v2 (typed options + lifecycle), served by one of two interchangeable
//! shells (the `serve_mode` knob).
//!
//! Protocol: one JSON object per line.
//!
//! **v1** (any line without `"v":2` — byte-identical to the seed
//! protocol, pinned by `tests/lifecycle_e2e.rs`):
//!
//! ```text
//! → {"prompt": "tr: cela vodu", "task": "translate"}
//! ← {"ok": true, "completion": "...", "tokens": 12, "sim_ms": 31.2,
//!    "real_ms": 8.4, "queue_ms": 0.1, "alpha": 0.83,
//!    "speculative": true, "gamma": 5, "rounds": 3}
//! ```
//!
//! **v2** (`"v":2`): requests may carry a client-chosen numeric `req_id`
//! and a typed `options` object (see
//! [`GenOptions::from_json`](crate::api::GenOptions::from_json) for the
//! full knob set):
//!
//! ```text
//! → {"v":2, "req_id":7, "prompt":"tr: cela vodu", "task":"translate",
//!    "options":{"max_new":32, "deadline_ms":250, "priority":3,
//!               "gamma_cap":2, "stop":["."]}}
//! ← {"v":2, "req_id":7, "ok":true, "finish":"stop", ...v1 fields...}
//! ```
//!
//! v2 error replies are typed — `"kind"` is one of
//! `bad_request | overloaded | cancelled | deadline | internal` — and
//! carry queue-state fields (`queue_len`, `queue_capacity`) so clients
//! can implement backoff; `cancelled`/`deadline` mark requests that died
//! before producing any decode output (a mid-decode cancel or expiry
//! instead returns `ok:true` with the partial tokens and the matching
//! `finish` reason). v1 error replies stay `{"ok":false,"error":...}`,
//! echoing the offending `req_id` when the line carried one. Admission
//! sheds (queue full, rate limit, drain) additionally carry
//! `retry_after_ms` when the server can estimate one.
//!
//! With `"stream": true` the reply is incremental: one
//! `{"ok":true,"frame":"tokens","text":...,"round":r,"drafted":d,
//! "accepted":a,"done":false}` line per speculation round as the scheduler
//! commits tokens (v2 frames additionally carry `req_id`), terminated by
//! the usual summary object tagged `"frame":"final"`.
//!
//! Commands: `{"cmd":"metrics"}` returns a metrics snapshot (engine
//! counters plus the `serve_*` shell counters);
//! `{"cmd":"cancel","req_id":N}` flags request N for cancellation (it
//! aborts at its next round boundary — cancellation reaches across
//! connections, which is how a streaming request is cancelled);
//! `{"cmd":"drain"}` starts a graceful drain (stop accepting, finish
//! in-flight against [`Tuning::drain_deadline_s`], exit);
//! `{"cmd":"reload","config":{...}}` hot-reloads the serving-shell knobs
//! that are safe to swap at admission boundaries;
//! `{"cmd":"shutdown"}` stops the listener.
//!
//! # Serving shells
//!
//! [`event_loop`] (default): a single nonblocking thread multiplexes
//! every connection over the coordinator's nonblocking handle API —
//! per-connection read/write buffers with partial-line reassembly,
//! bounded outbound queues (slow consumers get a typed `overloaded`
//! error instead of blocking the loop), per-client token-bucket rate
//! limiting, graceful drain, admission-boundary config hot-reload, and
//! an optional JSON-lines metrics history. [`threaded`]: the legacy
//! thread-per-connection shell, kept as the A/B baseline that
//! `experiment serve_load` measures the event loop against. Both speak
//! byte-identical wire protocols; per connection both serve at most one
//! generate at a time (later lines queue behind it), so reply order per
//! connection is identical across shells.
//!
//! There is no signal handling in-process (no libc dependency): drain is
//! triggered over the wire (`{"cmd":"drain"}`) or programmatically via
//! [`Server::drain`]; a supervisor that catches SIGTERM should do one of
//! those and then [`Server::wait`].

pub mod client;
pub mod event_loop;
pub mod threaded;

pub use client::{Client, ClientError};

use crate::api::{FinishReason, GenOptions, GenerationRequest};
use crate::config::{RunConfig, ServeMode};
use crate::coordinator::{Coordinator, EngineResponse, RequestHandle, TokenFrame};
use crate::fleet::FleetRouter;
use crate::tokenizer::{Tokenizer, SEP_ID};
use crate::util::json::Json;
use std::io::Write;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// What the server fronts: one coordinator (the historical shape) or a
/// multi-device [`FleetRouter`] (`serve --fleet topo.json`). Generate
/// lines, cancellation and backpressure fields all go through this seam;
/// the single-coordinator wire behavior is unchanged.
pub enum Backend {
    Single(Arc<Coordinator>),
    Fleet(Arc<FleetRouter>),
}

impl Backend {
    fn submit(&self, req: GenerationRequest) -> RequestHandle {
        match self {
            Backend::Single(c) => c.submit(req),
            Backend::Fleet(f) => f.submit(req).handle,
        }
    }

    fn cancel(&self, id: u64) -> bool {
        match self {
            Backend::Single(c) => c.cancel(id),
            Backend::Fleet(f) => f.cancel(id),
        }
    }

    /// Admission-queue depth (summed across fleet devices).
    fn queue_len(&self) -> usize {
        match self {
            Backend::Single(c) => c.queue_len(),
            Backend::Fleet(f) => f.devices().iter().map(|d| d.coordinator.queue_len()).sum(),
        }
    }

    /// Admission-queue capacity (summed across fleet devices).
    fn queue_capacity(&self) -> usize {
        match self {
            Backend::Single(c) => c.queue_capacity(),
            Backend::Fleet(f) => {
                f.devices().iter().map(|d| d.coordinator.queue_capacity()).sum()
            }
        }
    }
}

/// Serving-shell counters, independent of the engine's [`crate::metrics`]
/// (those count admitted requests; these count connections and lines,
/// including ones shed before admission). All relaxed — they are
/// monotonic telemetry, not synchronization.
#[derive(Default)]
pub struct ServeStats {
    /// Connections accepted over the server's lifetime.
    pub conns_accepted: AtomicU64,
    /// Connections currently open.
    pub conns_open: AtomicU64,
    /// Non-empty request lines read.
    pub lines_in: AtomicU64,
    /// Generate requests submitted to the engine.
    pub requests: AtomicU64,
    /// Generate lines shed by the per-client token bucket.
    pub rate_limited: AtomicU64,
    /// Connections force-closed because their outbound queue overflowed
    /// (slow consumer) — event-loop shell only.
    pub overloaded_disconnects: AtomicU64,
    /// Successful `{"cmd":"reload"}` applications.
    pub reloads: AtomicU64,
    /// Metrics-history lines appended.
    pub history_lines: AtomicU64,
}

/// The hot-reloadable serving-shell knobs. Everything here binds at an
/// admission boundary (next generate line, next queue push, next history
/// tick), which is what makes `{"cmd":"reload"}` safe: no in-flight
/// request ever sees a knob change mid-round. Engine knobs (decision /
/// tree / kv / fleet) bind at [`Coordinator::start`] and are reported as
/// `ignored` by reload; the decision layer already re-partitions online
/// from calibration, which is the engine-side analogue.
#[derive(Debug, Clone)]
pub struct Tuning {
    /// Per-client admission rate (requests/s); 0 disables the bucket.
    pub rate_limit_rps: f64,
    /// Token-bucket burst size (max back-to-back admissions).
    pub rate_limit_burst: usize,
    /// Max buffered outbound lines per connection before the slow
    /// consumer is shed (event-loop shell).
    pub client_queue_depth: usize,
    /// Seconds a drain waits for in-flight requests before cancelling.
    pub drain_deadline_s: f64,
    /// Seconds between metrics-history snapshots.
    pub metrics_history_every_s: f64,
}

/// Startup options for [`Server::start_opts`] — the serving-shell subset
/// of [`RunConfig`], so embedders don't need a full config to tune the
/// front door.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Which shell runs the connections (default [`ServeMode::EventLoop`]).
    pub mode: ServeMode,
    pub rate_limit_rps: f64,
    pub rate_limit_burst: usize,
    pub client_queue_depth: usize,
    pub drain_deadline_s: f64,
    /// Append a metrics snapshot to this JSON-lines file every
    /// `metrics_history_every_s` (plus one final line at exit).
    pub metrics_history_file: Option<PathBuf>,
    pub metrics_history_every_s: f64,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions::from_config(&RunConfig::default())
    }
}

impl ServeOptions {
    /// Lift the serving-shell knobs out of a full [`RunConfig`].
    pub fn from_config(cfg: &RunConfig) -> ServeOptions {
        ServeOptions {
            mode: cfg.serve_mode,
            rate_limit_rps: cfg.rate_limit_rps,
            rate_limit_burst: cfg.rate_limit_burst,
            client_queue_depth: cfg.client_queue_depth,
            drain_deadline_s: cfg.drain_deadline_s,
            metrics_history_file: cfg.metrics_history_file.clone(),
            metrics_history_every_s: cfg.metrics_history_every_s,
        }
    }

    fn tuning(&self) -> Tuning {
        Tuning {
            rate_limit_rps: self.rate_limit_rps,
            rate_limit_burst: self.rate_limit_burst,
            client_queue_depth: self.client_queue_depth,
            drain_deadline_s: self.drain_deadline_s,
            metrics_history_every_s: self.metrics_history_every_s,
        }
    }
}

/// Everything a serving shell needs, shared between [`event_loop`] and
/// [`threaded`] behind one `Arc`.
pub(crate) struct ServeCtx {
    pub backend: Arc<Backend>,
    pub tokenizer: Tokenizer,
    /// Hard stop: exit as soon as in-flight replies are flushed.
    pub stop: AtomicBool,
    /// Graceful drain: stop accepting, finish in-flight, then stop.
    pub drain: AtomicBool,
    /// Server-assigned ids start at 2^48: far above practical
    /// client-chosen v2 req_ids (the cancellation registry is one shared
    /// namespace) yet small enough that every id stays exactly
    /// representable in the f64-backed JSON codec when echoed.
    pub next_id: AtomicU64,
    pub start_wall: Instant,
    pub stats: Arc<ServeStats>,
    pub tuning: Mutex<Tuning>,
    pub mode: ServeMode,
    pub metrics_history: Option<PathBuf>,
}

/// Running server handle.
pub struct Server {
    pub port: u16,
    /// Shell counters (shared with the serving thread).
    pub stats: Arc<ServeStats>,
    ctx: Arc<ServeCtx>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on a background thread with default options
    /// (event-loop shell). Port 0 picks a free port.
    pub fn start(
        coordinator: Arc<Coordinator>,
        tokenizer: Tokenizer,
        port: u16,
    ) -> anyhow::Result<Server> {
        Server::start_with(Backend::Single(coordinator), tokenizer, port)
    }

    /// Bind and serve an explicit [`Backend`] (fleet-aware entry point).
    pub fn start_with(
        backend: Backend,
        tokenizer: Tokenizer,
        port: u16,
    ) -> anyhow::Result<Server> {
        Server::start_opts(backend, tokenizer, port, ServeOptions::default())
    }

    /// Bind and serve with the shell knobs from a full [`RunConfig`]
    /// (`serve_mode`, rate limit, drain deadline, metrics history…).
    pub fn start_cfg(
        backend: Backend,
        tokenizer: Tokenizer,
        cfg: &RunConfig,
    ) -> anyhow::Result<Server> {
        Server::start_opts(backend, tokenizer, cfg.port, ServeOptions::from_config(cfg))
    }

    /// Fully explicit entry point.
    pub fn start_opts(
        backend: Backend,
        tokenizer: Tokenizer,
        port: u16,
        opts: ServeOptions,
    ) -> anyhow::Result<Server> {
        let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
        let port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        let stats = Arc::new(ServeStats::default());
        let ctx = Arc::new(ServeCtx {
            backend: Arc::new(backend),
            tokenizer,
            stop: AtomicBool::new(false),
            drain: AtomicBool::new(false),
            next_id: AtomicU64::new(1 << 48),
            start_wall: Instant::now(),
            stats: Arc::clone(&stats),
            tuning: Mutex::new(opts.tuning()),
            mode: opts.mode,
            metrics_history: opts.metrics_history_file.clone(),
        });
        let ctx2 = Arc::clone(&ctx);
        let handle = std::thread::Builder::new()
            .name("specedge-server".into())
            .spawn(move || match ctx2.mode {
                ServeMode::EventLoop => event_loop::run(ctx2, listener),
                ServeMode::Threaded => threaded::run(ctx2, listener),
            })?;
        Ok(Server { port, stats, ctx, handle: Some(handle) })
    }

    /// Start a graceful drain (the programmatic twin of
    /// `{"cmd":"drain"}`): stop accepting, let in-flight requests finish
    /// against the drain deadline, then exit. [`wait`](Self::wait)
    /// returns once the drain completes.
    pub fn drain(&self) {
        self.ctx.drain.store(true, Ordering::SeqCst);
    }

    /// True once a drain or shutdown has been requested.
    pub fn draining(&self) -> bool {
        self.ctx.drain.load(Ordering::SeqCst) || self.ctx.stop.load(Ordering::SeqCst)
    }

    /// Block until the serving thread exits (drain completed, shutdown
    /// command, or [`stop`](Self::stop) from another handle).
    pub fn wait(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    pub fn stop(mut self) {
        self.ctx.stop.store(true, Ordering::SeqCst);
        self.wait();
    }
}

/// Per-client token bucket. Holds only position state; rate and burst
/// are read from [`Tuning`] on every take so hot-reload applies to
/// existing connections too.
pub(crate) struct TokenBucket {
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    pub(crate) fn new(burst: usize) -> TokenBucket {
        TokenBucket { tokens: burst as f64, last: Instant::now() }
    }

    /// Try to admit one request: `Err(retry_after_ms)` when the bucket
    /// is empty. `rps <= 0` disables limiting.
    pub(crate) fn try_take(&mut self, rps: f64, burst: usize) -> Result<(), f64> {
        if rps <= 0.0 {
            return Ok(());
        }
        let now = Instant::now();
        let refill = now.duration_since(self.last).as_secs_f64() * rps;
        self.tokens = (self.tokens + refill).min(burst.max(1) as f64);
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else {
            Err((1.0 - self.tokens) / rps * 1e3)
        }
    }
}

/// One submitted generate request as a shell tracks it: the engine
/// handle plus the wire framing it was admitted under.
pub(crate) struct ActiveGen {
    pub handle: RequestHandle,
    pub v2: bool,
    pub req_id: Option<u64>,
    pub streaming: bool,
    /// Stashed final response (event loop: frames may still be queued
    /// behind it when it first polls ready).
    pub resp: Option<anyhow::Result<EngineResponse>>,
}

/// What a generate line turned into at admission.
pub(crate) enum GenOutcome {
    /// Shed or malformed: reply immediately, nothing submitted.
    Reply(Json),
    /// Admitted: poll/stream the handle.
    Submitted(ActiveGen),
}

/// Parse, admission-check and submit one generate line. All admission
/// gates (protocol, drain, rate limit, option validation) live here so
/// both shells shed identical traffic with identical replies.
pub(crate) fn start_generate(req: &Json, ctx: &ServeCtx, bucket: &mut TokenBucket) -> GenOutcome {
    let version = req.get("v").and_then(Json::as_usize).unwrap_or(1);
    let req_id = wire_req_id(req);
    if version != 1 && version != 2 {
        return GenOutcome::Reply(err_v2(
            "bad_request",
            &format!("unsupported protocol version {version}"),
            req_id,
            &ctx.backend,
        ));
    }
    let v2 = version == 2;
    if ctx.drain.load(Ordering::SeqCst) || ctx.stop.load(Ordering::SeqCst) {
        let msg = "draining: not accepting new requests";
        return GenOutcome::Reply(if v2 {
            err_v2("overloaded", msg, req_id, &ctx.backend)
        } else {
            err_json(msg, req_id)
        });
    }
    let (rps, burst) = {
        let t = ctx.tuning.lock().unwrap();
        (t.rate_limit_rps, t.rate_limit_burst)
    };
    if let Err(retry_ms) = bucket.try_take(rps, burst) {
        ctx.stats.rate_limited.fetch_add(1, Ordering::Relaxed);
        let msg = "rate limited (per-client token bucket empty)";
        let mut j = if v2 {
            err_v2("overloaded", msg, req_id, &ctx.backend)
        } else {
            err_json(msg, req_id)
        };
        j.set("retry_after_ms", retry_ms.into());
        return GenOutcome::Reply(j);
    }
    let prompt_text = match req.get("prompt").and_then(Json::as_str) {
        Some(p) => p,
        None => {
            return GenOutcome::Reply(if v2 {
                err_v2("bad_request", "missing `prompt`", req_id, &ctx.backend)
            } else {
                err_json("missing `prompt`", req_id)
            });
        }
    };
    let task = req
        .get("task")
        .and_then(Json::as_str)
        .unwrap_or("unknown")
        .to_string();
    let streaming = req.get("stream").and_then(Json::as_bool).unwrap_or(false);
    // v2 only: the typed options object (strictly validated — unknown
    // knobs and wrong types come back as bad_request).
    let options = if v2 {
        match req.get("options") {
            None => GenOptions::default(),
            Some(o) => match GenOptions::from_json(o) {
                Ok(o) => o,
                Err(e) => {
                    return GenOutcome::Reply(err_v2(
                        "bad_request",
                        &format!("invalid options: {e}"),
                        req_id,
                        &ctx.backend,
                    ));
                }
            },
        }
    } else {
        GenOptions::default()
    };
    let mut prompt = match ctx.tokenizer.encode(prompt_text, true) {
        Ok(p) => p,
        Err(e) => {
            return GenOutcome::Reply(if v2 {
                err_v2("bad_request", &format!("{e}"), req_id, &ctx.backend)
            } else {
                err_json(&format!("{e}"), req_id)
            });
        }
    };
    prompt.push(SEP_ID);
    // v2 clients address cancellation by their own req_id, so it becomes
    // the coordinator-visible id; v1 keeps server-assigned ids.
    let id = match req_id {
        Some(id) if v2 => id,
        _ => ctx.next_id.fetch_add(1, Ordering::Relaxed),
    };
    let request = GenerationRequest {
        id,
        task,
        prompt,
        truth: String::new(),
        arrival_s: 0.0,
        options,
    };
    let handle = ctx.backend.submit(request);
    ctx.stats.requests.fetch_add(1, Ordering::Relaxed);
    GenOutcome::Submitted(ActiveGen { handle, v2, req_id, streaming, resp: None })
}

/// What a command line asks the shell to do after replying.
pub(crate) enum CmdAction {
    /// Just send the reply.
    Reply(Json),
    /// Send the reply, then stop the server (the stop flag is already
    /// set; the shell must still flush this reply before exiting).
    Shutdown(Json),
}

/// Dispatch one `{"cmd":...}` line. Shared by both shells so command
/// behavior (and reply bytes) cannot drift between them.
pub(crate) fn handle_cmd(cmd: &str, req: &Json, ctx: &ServeCtx) -> CmdAction {
    match cmd {
        "metrics" => CmdAction::Reply(serve_metrics(ctx)),
        "cancel" => CmdAction::Reply(cancel_json(req, &ctx.backend)),
        "drain" => {
            ctx.drain.store(true, Ordering::SeqCst);
            let mut j = Json::obj();
            j.set("ok", true.into()).set("draining", true.into());
            CmdAction::Reply(j)
        }
        "reload" => CmdAction::Reply(reload_json(req, ctx)),
        "shutdown" => {
            ctx.stop.store(true, Ordering::SeqCst);
            let mut j = Json::obj();
            j.set("ok", true.into());
            CmdAction::Shutdown(j)
        }
        other => CmdAction::Reply(err_json(&format!("unknown cmd {other:?}"), wire_req_id(req))),
    }
}

/// `{"cmd":"reload","config":{...}}`: validate the override object
/// against the full config schema, then apply the serving-shell subset
/// that is safe to swap at admission boundaries. The reply lists which
/// keys were `applied` and which were `ignored` (valid but bound at
/// engine startup), so callers learn exactly what took effect.
fn reload_json(req: &Json, ctx: &ServeCtx) -> Json {
    let overrides = match req.get("config") {
        Some(o) if o.as_obj().is_some() => o,
        _ => return err_v2("bad_request", "reload requires a `config` object", None, &ctx.backend),
    };
    // Full-schema validation first: unknown keys, wrong types and
    // out-of-range values are rejected atomically (nothing applied).
    let mut probe = RunConfig::default();
    if let Err(e) = probe.apply_json(overrides) {
        return err_v2("bad_request", &format!("invalid config: {e}"), None, &ctx.backend);
    }
    if let Err(e) = probe.validate() {
        return err_v2("bad_request", &format!("invalid config: {e}"), None, &ctx.backend);
    }
    const HOT: [&str; 5] = [
        "rate_limit_rps",
        "rate_limit_burst",
        "client_queue_depth",
        "drain_deadline_s",
        "metrics_history_every_s",
    ];
    let mut applied = Vec::new();
    let mut ignored = Vec::new();
    {
        let mut t = ctx.tuning.lock().unwrap();
        for key in overrides.as_obj().unwrap().keys() {
            match key.as_str() {
                "rate_limit_rps" => t.rate_limit_rps = probe.rate_limit_rps,
                "rate_limit_burst" => t.rate_limit_burst = probe.rate_limit_burst,
                "client_queue_depth" => t.client_queue_depth = probe.client_queue_depth,
                "drain_deadline_s" => t.drain_deadline_s = probe.drain_deadline_s,
                "metrics_history_every_s" => {
                    t.metrics_history_every_s = probe.metrics_history_every_s
                }
                _ => {}
            }
            if HOT.contains(&key.as_str()) {
                applied.push(Json::Str(key.clone()));
            } else {
                ignored.push(Json::Str(key.clone()));
            }
        }
    }
    ctx.stats.reloads.fetch_add(1, Ordering::Relaxed);
    let mut j = Json::obj();
    j.set("ok", true.into())
        .set("v", 2usize.into())
        .set("applied", Json::Arr(applied))
        .set("ignored", Json::Arr(ignored));
    j
}

/// The engine metrics snapshot plus the serving-shell `serve_*` counters.
pub(crate) fn serve_metrics(ctx: &ServeCtx) -> Json {
    let mut j = metrics_json(&ctx.backend, ctx.start_wall);
    let s = &ctx.stats;
    j.set("serve_mode", Json::Str(ctx.mode.as_str().into()))
        .set("serve_conns_open", (s.conns_open.load(Ordering::Relaxed) as usize).into())
        .set(
            "serve_conns_accepted",
            (s.conns_accepted.load(Ordering::Relaxed) as usize).into(),
        )
        .set("serve_lines", (s.lines_in.load(Ordering::Relaxed) as usize).into())
        .set("serve_requests", (s.requests.load(Ordering::Relaxed) as usize).into())
        .set(
            "serve_rate_limited",
            (s.rate_limited.load(Ordering::Relaxed) as usize).into(),
        )
        .set(
            "serve_overloaded_disconnects",
            (s.overloaded_disconnects.load(Ordering::Relaxed) as usize).into(),
        )
        .set("serve_reloads", (s.reloads.load(Ordering::Relaxed) as usize).into())
        .set("serve_draining", ctx.drain.load(Ordering::SeqCst).into());
    j
}

/// Append one metrics snapshot line to the configured history file
/// (no-op when `metrics_history_file` is unset). Each line is the
/// `{"cmd":"metrics"}` reply plus a `t_s` offset, so histories from
/// different runs line up by time-since-start.
pub(crate) fn append_history(ctx: &ServeCtx) {
    let Some(path) = &ctx.metrics_history else { return };
    let mut j = serve_metrics(ctx);
    j.set("t_s", ctx.start_wall.elapsed().as_secs_f64().into());
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        if writeln!(f, "{j}").is_ok() {
            ctx.stats.history_lines.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// The client-chosen `req_id`, when the line carries a valid one (the
/// same strict integer rule the options parser applies).
pub(crate) fn wire_req_id(req: &Json) -> Option<u64> {
    req.get("req_id").and_then(crate::api::wire_uint)
}

/// One streamed token frame as a wire line (shared by both shells so
/// frame bytes are shell-independent).
pub(crate) fn frame_json(f: &TokenFrame, tokenizer: &Tokenizer, v2: bool) -> Json {
    let mut j = Json::obj();
    j.set("ok", true.into())
        .set("frame", Json::Str("tokens".into()))
        .set("round", f.round.into())
        .set("text", Json::Str(tokenizer.decode(&f.tokens)))
        .set("n_tokens", f.tokens.len().into())
        .set("drafted", f.drafted.into())
        .set("accepted", f.accepted.into())
        .set("done", f.done.into());
    if v2 {
        j.set("req_id", (f.id as usize).into()).set("v", 2usize.into());
    }
    j
}

/// Map a request's final outcome onto the wire: v1 keeps the seed reply
/// shapes byte-for-byte; v2 adds `v`/`req_id`/`finish` and turns
/// produced-nothing lifecycle deaths into typed errors.
pub(crate) fn reply_final(
    result: anyhow::Result<EngineResponse>,
    tagged: bool,
    v2: bool,
    req_id: Option<u64>,
    coordinator: &Backend,
) -> Json {
    let r = match result {
        Ok(r) => r,
        Err(_) => {
            return if v2 {
                err_v2("internal", "worker dropped the request", req_id, coordinator)
            } else {
                err_json("worker dropped the request", req_id)
            };
        }
    };
    if r.finish == FinishReason::Rejected {
        // The seed protocol surfaced backpressure as this exact error.
        return if v2 {
            err_v2("overloaded", "queue full (backpressure)", req_id, coordinator)
        } else {
            err_json("queue full (backpressure)", req_id)
        };
    }
    if v2 && r.rounds == 0 && r.tokens.is_empty() {
        // Died before producing anything: a typed lifecycle error.
        match r.finish {
            FinishReason::Cancelled => {
                return err_v2("cancelled", "cancelled before any output", req_id, coordinator);
            }
            FinishReason::DeadlineExceeded => {
                return err_v2("deadline", "deadline expired before any output", req_id, coordinator);
            }
            _ => {}
        }
    }
    final_json(r, tagged, v2)
}

fn cancel_json(req: &Json, coordinator: &Backend) -> Json {
    let id = match wire_req_id(req) {
        Some(id) => id,
        None => {
            return err_v2(
                "bad_request",
                "cancel requires a numeric `req_id`",
                None,
                coordinator,
            );
        }
    };
    if coordinator.cancel(id) {
        let mut j = Json::obj();
        j.set("ok", true.into())
            .set("cancelled", true.into())
            .set("req_id", (id as usize).into())
            .set("v", 2usize.into());
        j
    } else {
        err_v2(
            "bad_request",
            &format!("unknown req_id {id} (never submitted, or already finished)"),
            Some(id),
            coordinator,
        )
    }
}

fn metrics_json(backend: &Backend, start_wall: Instant) -> Json {
    match backend {
        Backend::Single(c) => coordinator_metrics_json(c, start_wall),
        Backend::Fleet(f) => fleet_metrics_json(f, start_wall),
    }
}

/// Fleet metrics: one full per-device metrics object per device (keyed by
/// device name, same shape as the single-coordinator snapshot) plus the
/// fleet-tier placement/verify-routing counters.
fn fleet_metrics_json(fleet: &FleetRouter, start_wall: Instant) -> Json {
    let mut j = Json::obj();
    j.set("ok", true.into())
        .set("fleet_devices", fleet.device_count().into())
        .set("wall_s", start_wall.elapsed().as_secs_f64().into());
    let mut devices = Vec::new();
    for d in fleet.devices() {
        let mut dj = coordinator_metrics_json(&d.coordinator, start_wall);
        dj.set("device", Json::Str(d.name.clone()));
        devices.push(dj);
    }
    j.set("devices", Json::Arr(devices));
    let fr = fleet.metrics().snapshot();
    j.set(
        "placements",
        Json::Arr(fr.placements.iter().map(|&n| (n as usize).into()).collect()),
    )
    .set("kv_filtered", (fr.kv_filtered as usize).into())
    .set("cloud_requests", (fr.cloud_requests as usize).into())
    .set("local_verify_rounds", (fr.local_verify_rounds as usize).into())
    .set("cloud_verify_rounds", (fr.cloud_verify_rounds as usize).into())
    .set("cloud_verify_frac", fr.cloud_verify_frac().into())
    .set("net_s", fr.net_s.into())
    .set("cloud_tokens_shipped", (fr.cloud_tokens_shipped as usize).into());
    j
}

fn coordinator_metrics_json(coordinator: &Coordinator, start_wall: Instant) -> Json {
    let r = coordinator.metrics.snapshot();
    let mut j = Json::obj();
    j.set("ok", true.into())
        .set("requests", (r.requests as usize).into())
        .set("rejected", (r.rejected as usize).into())
        .set("tokens", (r.tokens_out as usize).into())
        .set("mean_alpha", r.mean_alpha.into())
        .set("sim_p50_ms", (r.sim_latency.median * 1e3).into())
        .set("sim_p90_ms", (r.sim_latency.p90 * 1e3).into())
        .set("rounds", (r.rounds as usize).into())
        .set("mean_round_gamma", r.mean_round_gamma.into())
        .set("mean_inflight", r.mean_inflight.into())
        .set("max_inflight", r.max_inflight.into())
        .set("dispatches", (r.dispatches as usize).into())
        .set("fused_dispatches", (r.fused_dispatches as usize).into())
        .set("batch_fill", r.batch_fill.into())
        .set("tree_rounds", (r.tree_rounds as usize).into())
        .set("mean_tree_depth", r.mean_tree_depth.into())
        .set("tree_lane_fill", r.tree_lane_fill.into())
        .set("cpu_busy_s", r.pu_busy[0].into())
        .set("gpu_busy_s", r.pu_busy[1].into())
        .set("overlap_s", r.overlap_s.into())
        .set("makespan_s", r.makespan_s.into())
        .set("tl_latency_p50_ms", (r.tl_latency.median * 1e3).into())
        .set("wall_s", start_wall.elapsed().as_secs_f64().into());
    // Request-lifecycle accounting: per-finish-reason counts, per-SLO
    // class counts, deadline-miss rate.
    for reason in FinishReason::all() {
        j.set(
            &format!("finish_{}", reason.as_str()),
            (r.finish_count(reason) as usize).into(),
        );
    }
    j.set(
        "slo_interactive",
        (r.slo_requests[crate::api::SloClass::Interactive.index()] as usize).into(),
    )
    .set(
        "slo_batch",
        (r.slo_requests[crate::api::SloClass::Batch.index()] as usize).into(),
    )
    .set("deadline_requests", (r.deadline_requests as usize).into())
    .set("deadline_missed", (r.deadline_missed as usize).into())
    .set("deadline_miss_rate", r.deadline_miss_rate().into());
    // Decision-layer state: which cost model is live, the mapping new
    // admissions receive, and the calibration/prior counters.
    let calib = coordinator.policy.calibration();
    j.set(
        "decision",
        Json::Str(coordinator.policy.decision_mode().as_str().into()),
    )
    .set(
        "mapping",
        Json::Str(coordinator.policy.current_mapping().label()),
    )
    .set(
        "repartitions",
        (coordinator.policy.repartition_count() as usize).into(),
    )
    .set("prior_decisions", (r.prior_decisions as usize).into())
    .set("calibration_obs", (r.calibration_obs as usize).into())
    .set("calibration_tracked_keys", calib.tracked_keys.into())
    .set("calibration_fitted_keys", calib.fitted_keys.into());
    // Traffic-class accounting: per-class retire counts and α mixes plus
    // the chosen-drafter histogram (one bucket under `drafter: fixed`).
    for class in crate::scenario::RequestClass::all() {
        j.set(
            &format!("class_requests_{}", class.as_str()),
            (r.class_requests[class.index()] as usize).into(),
        );
        j.set(
            &format!("class_alpha_{}", class.as_str()),
            r.class_alpha[class.index()].into(),
        );
    }
    for (name, n) in &r.drafter_hist {
        j.set(&format!("drafter_requests_{name}"), (*n as usize).into());
    }
    // Paged-KV-cache state (all-zero when `kv_cache: off`): prefix-trie
    // effectiveness, admission sheds, and per-PU page-pool occupancy.
    j.set("kv_lookups", (r.kv_lookups as usize).into())
        .set("kv_prefix_hit_rate", r.kv_prefix_hit_rate().into())
        .set(
            "kv_prefill_tokens_saved",
            (r.kv_prefill_tokens_saved as usize).into(),
        )
        .set("kv_memory_shed", (r.kv_memory_shed as usize).into())
        .set(
            "kv_reap_reclaimed_pages",
            (r.kv_reap_reclaimed_pages as usize).into(),
        )
        .set("kv_pages_used_cpu", (r.kv_pages_used[0] as usize).into())
        .set("kv_pages_used_gpu", (r.kv_pages_used[1] as usize).into())
        .set("kv_pages_peak_cpu", (r.kv_pages_peak[0] as usize).into())
        .set("kv_pages_peak_gpu", (r.kv_pages_peak[1] as usize).into())
        .set("kv_pages_cap_cpu", (r.kv_pages_capacity[0] as usize).into())
        .set("kv_pages_cap_gpu", (r.kv_pages_capacity[1] as usize).into());
    j
}

fn final_json(r: EngineResponse, tagged: bool, v2: bool) -> Json {
    let mut j = Json::obj();
    if tagged {
        j.set("frame", Json::Str("final".into()));
    }
    if v2 {
        j.set("v", 2usize.into())
            .set("req_id", (r.id as usize).into())
            .set("finish", Json::Str(r.finish.as_str().into()));
    }
    j.set("ok", true.into())
        .set("completion", Json::Str(r.completion))
        .set("tokens", r.tokens.len().into())
        .set("sim_ms", (r.sim_s * 1e3).into())
        .set("real_ms", (r.real_s * 1e3).into())
        .set("queue_ms", (r.queue_s * 1e3).into())
        .set("alpha", r.alpha.into())
        .set("speculative", r.speculative.into())
        .set("gamma", r.gamma.into())
        .set("rounds", r.rounds.into());
    j
}

/// The seed error shape (v1, byte-identical for seed lines), plus the
/// offending `req_id` when the request line carried one.
pub(crate) fn err_json(msg: &str, req_id: Option<u64>) -> Json {
    let mut j = Json::obj();
    j.set("ok", false.into()).set("error", Json::Str(msg.to_string()));
    if let Some(id) = req_id {
        j.set("req_id", (id as usize).into());
    }
    j
}

/// A v2 typed error: `kind` ∈ `bad_request | overloaded | cancelled |
/// deadline | internal`, plus queue-state fields for client backoff.
pub(crate) fn err_v2(kind: &str, msg: &str, req_id: Option<u64>, coordinator: &Backend) -> Json {
    let mut j = err_json(msg, req_id);
    j.set("v", 2usize.into())
        .set("kind", Json::Str(kind.into()))
        .set("queue_len", coordinator.queue_len().into())
        .set("queue_capacity", coordinator.queue_capacity().into());
    j
}
