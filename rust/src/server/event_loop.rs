//! Nonblocking event-loop serving shell (the default `serve_mode`).
//!
//! One thread multiplexes every connection over `std::net` nonblocking
//! sockets and the coordinator's nonblocking handle API
//! ([`RequestHandle::try_frame`](crate::coordinator::RequestHandle::try_frame)
//! /
//! [`RequestHandle::try_wait_done`](crate::coordinator::RequestHandle::try_wait_done)):
//! no per-connection threads, no per-connection stacks, no blocking
//! reads. Each sweep the loop accepts pending connections, reads
//! whatever bytes are available (reassembling partial lines), admits at
//! most one generate per connection (matching the threaded shell's
//! per-connection serialization, so reply order is identical), polls
//! in-flight handles for frames/finals, and flushes bounded outbound
//! queues.
//!
//! Scheduling is poll-based: `std::net` exposes no portable readiness
//! API without a libc dependency, so instead of blocking in `epoll` the
//! loop parks on an adaptive backoff — 500µs doubling to 10ms while
//! fully idle, capped at 1ms while any request is in flight — which
//! bounds both idle CPU burn and added response latency.
//!
//! Overload behavior is all shed-don't-block:
//! - admission backpressure surfaces as the engine's `Rejected` →
//!   `queue full (backpressure)` reply (unchanged from the seed);
//! - per-client token buckets shed with `overloaded` + `retry_after_ms`;
//! - a slow consumer whose outbound queue overflows gets its in-flight
//!   request cancelled and one final typed `overloaded` error, then the
//!   connection closes after the queue flushes — it never blocks the
//!   loop or other connections.
//!
//! Winding down: `{"cmd":"drain"}` (or [`Server::drain`](super::Server::drain))
//! stops accepting, lets in-flight requests finish until
//! [`Tuning::drain_deadline_s`](super::Tuning::drain_deadline_s), then
//! cancels the stragglers — every in-flight request still receives a
//! final reply before the loop exits. `{"cmd":"shutdown"}` (or
//! [`Server::stop`](super::Server::stop)) cancels in-flight work
//! immediately and exits once replies are flushed (bounded by a 2s
//! grace).

use super::{
    append_history, err_json, err_v2, frame_json, handle_cmd, reply_final, start_generate,
    ActiveGen, CmdAction, GenOutcome, ServeCtx, TokenBucket, Tuning,
};
use crate::util::json::Json;
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Read chunk size per syscall.
const READ_CHUNK: usize = 4096;
/// Max bytes read from one connection per sweep (fairness bound).
const SWEEP_READ_BUDGET: usize = 64 * 1024;
/// Max bytes of a single line before the connection is dropped as
/// malformed (the reassembly buffer is per-connection memory).
const MAX_LINE: usize = 1 << 20;
/// Max parsed-but-unprocessed lines per connection before the loop stops
/// reading from it (TCP backpressure does the rest).
const PENDING_CAP: usize = 64;
/// Idle-park bounds: exponential backoff between these while nothing is
/// readable, acceptable or pollable.
const MIN_IDLE: Duration = Duration::from_micros(500);
const MAX_IDLE: Duration = Duration::from_millis(10);
/// Park cap while any request is in flight (bounds added reply latency).
const ACTIVE_IDLE_CAP: Duration = Duration::from_millis(1);
/// How long a hard stop waits for cancelled in-flight requests to
/// answer and flush before abandoning them.
const STOP_GRACE: Duration = Duration::from_secs(2);

/// One multiplexed connection: nonblocking socket, read-side line
/// reassembly, parsed-line queue, bounded outbound byte queue, at most
/// one in-flight generate, and a rate-limit bucket.
struct Conn {
    stream: TcpStream,
    /// Partial-line reassembly buffer (bytes read, no newline yet).
    rbuf: Vec<u8>,
    /// Complete lines awaiting admission.
    pending: VecDeque<String>,
    /// Outbound lines (serialized, newline-terminated), partially
    /// written front first.
    wq: VecDeque<Vec<u8>>,
    /// Bytes of `wq[0]` already written.
    woff: usize,
    active: Option<ActiveGen>,
    bucket: TokenBucket,
    /// Peer closed its write side; finish serving what we have.
    eof: bool,
    /// Connection is gone; reap it.
    dead: bool,
    /// Stop reading/admitting, close once `wq` flushes.
    close_after_flush: bool,
}

impl Conn {
    fn new(stream: TcpStream, burst: usize) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            pending: VecDeque::new(),
            wq: VecDeque::new(),
            woff: 0,
            active: None,
            bucket: TokenBucket::new(burst),
            eof: false,
            dead: false,
            close_after_flush: false,
        }
    }

    /// Pull whatever bytes are ready off the socket and split completed
    /// lines into `pending`. Returns true if anything was read.
    fn read_available(&mut self) -> bool {
        if self.eof || self.dead || self.close_after_flush || self.pending.len() >= PENDING_CAP {
            return false;
        }
        let mut any = false;
        let mut budget = SWEEP_READ_BUDGET;
        let mut chunk = [0u8; READ_CHUNK];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.eof = true;
                    break;
                }
                Ok(n) => {
                    any = true;
                    self.rbuf.extend_from_slice(&chunk[..n]);
                    if self.rbuf.len() > MAX_LINE {
                        self.dead = true;
                        return true;
                    }
                    budget = budget.saturating_sub(n);
                    if n < chunk.len() || budget == 0 {
                        break;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        // Reassembly: hand off every complete line, keep the tail.
        let mut start = 0;
        while let Some(pos) = self.rbuf[start..].iter().position(|&b| b == b'\n') {
            let end = start + pos;
            match std::str::from_utf8(&self.rbuf[start..end]) {
                Ok(s) => self.pending.push_back(s.to_string()),
                Err(_) => {
                    self.dead = true;
                    return true;
                }
            }
            start = end + 1;
        }
        self.rbuf.drain(..start);
        any
    }

    /// Queue one reply line, honoring the bounded-queue depth. Returns
    /// false on overflow (slow consumer — caller sheds the connection).
    /// Output to a connection that is already closing is dropped.
    fn push_line(&mut self, j: &Json, depth: usize) -> bool {
        if self.dead || self.close_after_flush {
            return true;
        }
        if self.wq.len() >= depth {
            return false;
        }
        self.force_line(j);
        true
    }

    /// Queue a line past the depth bound (the final error on an
    /// overflowing connection).
    fn force_line(&mut self, j: &Json) {
        if self.dead {
            return;
        }
        let mut b = j.to_string().into_bytes();
        b.push(b'\n');
        self.wq.push_back(b);
    }

    /// Write as much queued output as the socket accepts. Returns true
    /// if any bytes moved.
    fn flush(&mut self) -> bool {
        if self.dead {
            return false;
        }
        let mut any = false;
        loop {
            if self.wq.is_empty() {
                break;
            }
            let res = {
                let front = &self.wq[0];
                self.stream.write(&front[self.woff..])
            };
            match res {
                Ok(0) => {
                    self.dead = true;
                    return any;
                }
                Ok(n) => {
                    any = true;
                    self.woff += n;
                    if self.woff == self.wq[0].len() {
                        self.wq.pop_front();
                        self.woff = 0;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return any;
                }
            }
        }
        if self.wq.is_empty() && self.close_after_flush {
            self.dead = true;
        }
        any
    }
}

/// Queue `j` on `c`, shedding the connection on outbound overflow: the
/// in-flight request (if any) is cancelled so the engine frees its slot
/// and KV pages immediately, one typed `overloaded` error is forced out,
/// and the connection closes once its queue flushes.
fn send(c: &mut Conn, j: &Json, tuning: &Tuning, ctx: &ServeCtx) {
    if !c.push_line(j, tuning.client_queue_depth) {
        if let Some(a) = c.active.take() {
            a.handle.cancel();
        }
        let err = err_v2(
            "overloaded",
            "slow consumer: outbound queue overflow, closing connection",
            None,
            &ctx.backend,
        );
        c.force_line(&err);
        c.close_after_flush = true;
        ctx.stats.overloaded_disconnects.fetch_add(1, Ordering::Relaxed);
    }
}

/// The event loop itself. Runs until shutdown/stop (immediate, bounded
/// by [`STOP_GRACE`]) or a completed drain.
pub(crate) fn run(ctx: Arc<ServeCtx>, listener: TcpListener) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut idle = MIN_IDLE;
    let mut last_history = Instant::now();
    let mut drain_started: Option<Instant> = None;
    let mut drain_cancelled = false;
    let mut stop_started: Option<Instant> = None;
    let mut stop_cancelled = false;
    loop {
        let mut activity = false;
        let stopping = ctx.stop.load(Ordering::SeqCst);
        let draining = ctx.drain.load(Ordering::SeqCst);
        let tuning = ctx.tuning.lock().unwrap().clone();

        // Accept everything pending (drain/stop close the front door).
        if !stopping && !draining {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        activity = true;
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        stream.set_nodelay(true).ok();
                        ctx.stats.conns_accepted.fetch_add(1, Ordering::Relaxed);
                        ctx.stats.conns_open.fetch_add(1, Ordering::Relaxed);
                        conns.push(Conn::new(stream, tuning.rate_limit_burst));
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    // Transient accept failure (fd exhaustion, aborted
                    // handshake): back off, don't kill the server.
                    Err(_) => break,
                }
            }
        }

        for c in conns.iter_mut() {
            if c.dead {
                continue;
            }
            if !stopping {
                activity |= c.read_available();

                // Admit queued lines while no generate is in flight on
                // this connection (per-connection serialization keeps
                // reply order identical to the threaded shell).
                while !c.dead && !c.close_after_flush && c.active.is_none() {
                    let Some(line) = c.pending.pop_front() else { break };
                    if line.trim().is_empty() {
                        continue;
                    }
                    activity = true;
                    ctx.stats.lines_in.fetch_add(1, Ordering::Relaxed);
                    match Json::parse(line.trim()) {
                        Err(e) => {
                            let j = err_json(&format!("bad json: {e}"), None);
                            send(c, &j, &tuning, &ctx);
                        }
                        Ok(req) => {
                            if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
                                match handle_cmd(cmd, &req, &ctx) {
                                    CmdAction::Reply(j) => send(c, &j, &tuning, &ctx),
                                    CmdAction::Shutdown(j) => {
                                        send(c, &j, &tuning, &ctx);
                                        break;
                                    }
                                }
                            } else {
                                match start_generate(&req, &ctx, &mut c.bucket) {
                                    GenOutcome::Reply(j) => send(c, &j, &tuning, &ctx),
                                    GenOutcome::Submitted(a) => c.active = Some(a),
                                }
                            }
                        }
                    }
                }
            }

            // Poll the in-flight request: relay frames, then the final.
            let mut frames: Vec<Json> = Vec::new();
            let mut fin: Option<Json> = None;
            if let Some(a) = c.active.as_mut() {
                loop {
                    while let Some(f) = a.handle.try_frame() {
                        activity = true;
                        if a.streaming {
                            frames.push(frame_json(&f, &ctx.tokenizer, a.v2));
                        }
                    }
                    if a.resp.is_none() {
                        if let Some(r) = a.handle.try_wait_done() {
                            activity = true;
                            a.resp = Some(r);
                            // One more frame sweep: the worker sends its
                            // last frames before the response, so they
                            // are already buffered — drain them so the
                            // final line really is final.
                            continue;
                        }
                    }
                    break;
                }
                if let Some(r) = a.resp.take() {
                    fin = Some(reply_final(r, a.streaming, a.v2, a.req_id, &ctx.backend));
                }
            }
            for j in &frames {
                send(c, j, &tuning, &ctx);
            }
            if let Some(j) = fin {
                send(c, &j, &tuning, &ctx);
                c.active = None;
            }

            activity |= c.flush();
        }

        // Reap finished/broken connections; cancel whatever they still
        // had in flight so the engine frees the slot (and its KV pages)
        // immediately.
        conns.retain_mut(|c| {
            let gone = c.dead
                || (c.eof && c.active.is_none() && c.pending.is_empty() && c.wq.is_empty());
            if gone {
                if let Some(a) = c.active.take() {
                    a.handle.cancel();
                }
                ctx.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
            }
            !gone
        });

        if ctx.metrics_history.is_some()
            && last_history.elapsed().as_secs_f64() >= tuning.metrics_history_every_s
        {
            last_history = Instant::now();
            append_history(&ctx);
        }

        if stopping {
            // Hard stop: cancel in-flight once, deliver+flush whatever
            // answers inside the grace window, then exit.
            if !stop_cancelled {
                stop_cancelled = true;
                for c in conns.iter() {
                    if let Some(a) = &c.active {
                        a.handle.cancel();
                    }
                }
            }
            let started = *stop_started.get_or_insert_with(Instant::now);
            let busy = conns
                .iter()
                .any(|c| !c.dead && (c.active.is_some() || !c.wq.is_empty()));
            if !busy || started.elapsed() > STOP_GRACE {
                break;
            }
        } else if draining {
            // Graceful drain: in-flight requests run to completion until
            // the deadline, then get cancelled — either way every one of
            // them receives a final reply before the loop exits.
            let started = *drain_started.get_or_insert_with(Instant::now);
            if !drain_cancelled && started.elapsed().as_secs_f64() >= tuning.drain_deadline_s {
                drain_cancelled = true;
                for c in conns.iter() {
                    if let Some(a) = &c.active {
                        a.handle.cancel();
                    }
                }
            }
            let busy = conns
                .iter()
                .any(|c| !c.dead && (c.active.is_some() || !c.wq.is_empty()));
            if !busy {
                ctx.stop.store(true, Ordering::SeqCst);
                break;
            }
        }

        if activity {
            idle = MIN_IDLE;
        } else {
            let cap = if conns.iter().any(|c| c.active.is_some()) {
                ACTIVE_IDLE_CAP
            } else {
                MAX_IDLE
            };
            std::thread::sleep(idle.min(cap));
            idle = (idle * 2).min(MAX_IDLE);
        }
    }
    append_history(&ctx);
}
