//! Minimal blocking client for tests, examples and the load generator.
//!
//! Speaks both protocol versions: [`generate`](Client::generate) /
//! [`generate_stream`](Client::generate_stream) emit seed-shaped v1
//! lines, [`generate_with`](Client::generate_with) /
//! [`generate_stream_with`](Client::generate_stream_with) the typed v2
//! protocol, and [`cancel`](Client::cancel) the cancel command. A
//! configurable [read timeout](Client::set_read_timeout) and
//! [connect timeout](Client::connect_timeout) turn a dead or saturated
//! server into a typed error instead of a hang, and the `try_*` variants
//! ([`try_call`](Client::try_call), [`try_generate`](Client::try_generate),
//! [`try_generate_with`](Client::try_generate_with)) classify `ok:false`
//! replies into [`ClientError`] — most importantly
//! [`ClientError::Overloaded`], which carries the server's
//! `retry_after_ms` / queue-state hints so callers can implement backoff
//! instead of pattern-matching error strings.

use crate::api::GenOptions;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Typed client-side view of an `ok:false` reply (or a transport
/// failure). Produced by the `try_*` calls; the plain calls keep
/// returning raw reply objects for wire-level tests.
#[derive(Debug, thiserror::Error)]
pub enum ClientError {
    /// The server shed the request (admission queue full, per-client
    /// rate limit, drain in progress, or outbound-queue overflow). When
    /// the server estimated a retry horizon it rides along.
    #[error("server overloaded: {msg}")]
    Overloaded {
        msg: String,
        /// Server-suggested backoff, when present (`retry_after_ms`).
        retry_after_ms: Option<f64>,
        /// Admission-queue state at shed time (v2 replies).
        queue_len: Option<usize>,
        queue_capacity: Option<usize>,
    },
    /// Any other error reply; `kind` is the v2 taxonomy value
    /// (`bad_request | cancelled | deadline | internal`) or `"error"`
    /// for untyped v1 replies.
    #[error("server error ({kind}): {msg}")]
    Server { kind: String, msg: String },
    /// The request never got a well-formed reply (connect/read/write
    /// failure, timeout, or unparseable bytes).
    #[error("{0}")]
    Transport(String),
}

impl ClientError {
    /// The server-suggested backoff as a [`Duration`], when one rode
    /// along on an overloaded reply.
    pub fn retry_after(&self) -> Option<Duration> {
        match self {
            ClientError::Overloaded { retry_after_ms: Some(ms), .. } if *ms >= 0.0 => {
                Some(Duration::from_secs_f64(ms / 1e3))
            }
            _ => None,
        }
    }

    /// True for replies a client should retry later rather than treat
    /// as a bug.
    pub fn is_overloaded(&self) -> bool {
        matches!(self, ClientError::Overloaded { .. })
    }

    /// Classify one reply object: `None` for `ok:true`.
    fn classify(reply: &Json) -> Option<ClientError> {
        if reply.get("ok").and_then(Json::as_bool).unwrap_or(false) {
            return None;
        }
        let msg = reply
            .get("error")
            .and_then(Json::as_str)
            .unwrap_or("malformed error reply")
            .to_string();
        let kind = reply.get("kind").and_then(Json::as_str).unwrap_or("error");
        // v1 sheds carry no `kind`; recognize the two fixed shed
        // messages so v1 callers get the typed variant too.
        let overloaded = kind == "overloaded"
            || msg.starts_with("queue full")
            || msg.starts_with("rate limited")
            || msg.starts_with("draining");
        if overloaded {
            Some(ClientError::Overloaded {
                msg,
                retry_after_ms: reply.get("retry_after_ms").and_then(Json::as_f64),
                queue_len: reply.get("queue_len").and_then(Json::as_usize),
                queue_capacity: reply.get("queue_capacity").and_then(Json::as_usize),
            })
        } else {
            Some(ClientError::Server { kind: kind.to_string(), msg })
        }
    }
}

/// Blocking line-JSON client; one socket, one reply stream.
pub struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    pub fn connect(port: u16) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(("127.0.0.1", port))?;
        Client::from_stream(stream)
    }

    /// Connect with a bound on how long the TCP handshake may take (a
    /// saturated or dead server surfaces as an error instead of an
    /// OS-default multi-minute hang).
    pub fn connect_timeout(port: u16, timeout: Duration) -> anyhow::Result<Client> {
        let addr = std::net::SocketAddr::from(([127, 0, 0, 1], port));
        let stream = TcpStream::connect_timeout(&addr, timeout)?;
        Client::from_stream(stream)
    }

    fn from_stream(stream: TcpStream) -> anyhow::Result<Client> {
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), stream })
    }

    /// Abort reads that wait longer than `timeout` (None = wait forever,
    /// the default). An expired timeout surfaces as an
    /// "timed out waiting for the server" error from the blocked call.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> anyhow::Result<()> {
        // Both handles alias one socket; set through the reader's (the
        // one reads actually go through) and keep the writer consistent.
        self.reader.get_ref().set_read_timeout(timeout)?;
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    /// Write one request line (no reply expected yet).
    pub fn send(&mut self, req: &Json) -> anyhow::Result<()> {
        writeln!(self.stream, "{req}")?;
        Ok(())
    }

    /// Read one reply line, mapping closed connections and read timeouts
    /// to typed errors.
    pub fn read_reply(&mut self) -> anyhow::Result<Json> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => anyhow::bail!("server closed the connection"),
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                anyhow::bail!("timed out waiting for the server (read timeout)")
            }
            Err(e) => return Err(e.into()),
        }
        Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }

    pub fn call(&mut self, req: &Json) -> anyhow::Result<Json> {
        self.send(req)?;
        self.read_reply()
    }

    /// [`call`](Client::call), with `ok:false` replies classified into
    /// [`ClientError`] (overload sheds become
    /// [`ClientError::Overloaded`] with the server's backoff hints).
    pub fn try_call(&mut self, req: &Json) -> Result<Json, ClientError> {
        let reply = self
            .call(req)
            .map_err(|e| ClientError::Transport(e.to_string()))?;
        match ClientError::classify(&reply) {
            None => Ok(reply),
            Some(e) => Err(e),
        }
    }

    /// v1 generate (seed protocol).
    pub fn generate(&mut self, prompt: &str, task: &str) -> anyhow::Result<Json> {
        self.call(&v1_line(prompt, task))
    }

    /// [`generate`](Client::generate) with typed error classification.
    pub fn try_generate(&mut self, prompt: &str, task: &str) -> Result<Json, ClientError> {
        self.try_call(&v1_line(prompt, task))
    }

    /// v2 generate with typed options and a client-chosen `req_id` (the
    /// id [`cancel`](Client::cancel) addresses).
    pub fn generate_with(
        &mut self,
        prompt: &str,
        task: &str,
        req_id: u64,
        options: &GenOptions,
    ) -> anyhow::Result<Json> {
        self.call(&v2_line(prompt, task, req_id, options, false))
    }

    /// [`generate_with`](Client::generate_with) with typed error
    /// classification.
    pub fn try_generate_with(
        &mut self,
        prompt: &str,
        task: &str,
        req_id: u64,
        options: &GenOptions,
    ) -> Result<Json, ClientError> {
        self.try_call(&v2_line(prompt, task, req_id, options, false))
    }

    /// Cancel a submitted request by `req_id` (from any connection).
    pub fn cancel(&mut self, req_id: u64) -> anyhow::Result<Json> {
        let mut j = Json::obj();
        j.set("cmd", Json::Str("cancel".into()))
            .set("req_id", (req_id as usize).into());
        self.call(&j)
    }

    /// v1 streaming generate: returns the per-round token frames and the
    /// final summary object (which is also the only line for error
    /// replies).
    pub fn generate_stream(
        &mut self,
        prompt: &str,
        task: &str,
    ) -> anyhow::Result<(Vec<Json>, Json)> {
        let mut j = v1_line(prompt, task);
        j.set("stream", true.into());
        self.send(&j)?;
        self.collect_stream()
    }

    /// v2 streaming generate with typed options.
    pub fn generate_stream_with(
        &mut self,
        prompt: &str,
        task: &str,
        req_id: u64,
        options: &GenOptions,
    ) -> anyhow::Result<(Vec<Json>, Json)> {
        self.send(&v2_line(prompt, task, req_id, options, true))?;
        self.collect_stream()
    }

    /// Drain `frame:"tokens"` lines until the terminating non-frame line.
    fn collect_stream(&mut self) -> anyhow::Result<(Vec<Json>, Json)> {
        let mut frames = Vec::new();
        loop {
            let reply = self
                .read_reply()
                .map_err(|e| anyhow::anyhow!("mid-stream: {e}"))?;
            match reply.get("frame").and_then(Json::as_str) {
                Some("tokens") => frames.push(reply),
                _ => return Ok((frames, reply)),
            }
        }
    }
}

/// Build one v1 generate line.
fn v1_line(prompt: &str, task: &str) -> Json {
    let mut j = Json::obj();
    j.set("prompt", Json::Str(prompt.into()))
        .set("task", Json::Str(task.into()));
    j
}

/// Build one v2 generate line.
pub(crate) fn v2_line(
    prompt: &str,
    task: &str,
    req_id: u64,
    options: &GenOptions,
    stream: bool,
) -> Json {
    let mut j = Json::obj();
    j.set("v", 2usize.into())
        .set("req_id", (req_id as usize).into())
        .set("prompt", Json::Str(prompt.into()))
        .set("task", Json::Str(task.into()));
    if stream {
        j.set("stream", true.into());
    }
    let o = options.to_json();
    let empty = o.as_obj().map(|m| m.is_empty()).unwrap_or(true);
    if !empty {
        j.set("options", o);
    }
    j
}
