//! Design-space exploration (paper §III-B) — the decision layer's
//! candidate search.
//!
//! The space is v·N^m static spatial mappings: v = Π nᵢ hardware design
//! variants (6 CPU-core counts × 1 GPU shader = 6 on the i.MX95), N = 2 PUs,
//! m = 2 graph partitions (drafter | target) → 24 candidate mappings.
//! Each is filtered by feasibility rules that mirror the paper's
//! constraints and scored at the given (α, c); the search also picks γ*
//! per mapping.
//!
//! Every entry point is generic over the [`CostModel`] trait, so the same
//! search runs offline against the analytic
//! [`LatencyModel`](crate::hetero::LatencyModel) (Tables II/III, the
//! `explore` CLI) *and* online against the continuously refit
//! [`CalibratedModel`](crate::decision::CalibratedModel) — which is how
//! the decision engine re-partitions a live deployment
//! ([`crate::decision::Policy`]).
//!
//! **Memory feasibility under `kv_cache: on`.** When the caller supplies a
//! [`KvLoad`] (the live in-flight count × per-session token budget), every
//! candidate mapping must additionally hold the fleet's KV working set:
//! for each PU, the pages the drafter and target roles mapped there would
//! reserve at admission ([`kv_feasible`]) must fit that PU's page pool
//! ([`crate::hetero::platform::MemoryModel::kv_pages`]). A mapping that
//! fails is [`Infeasibility::KvMemory`] — hard-infeasible, because
//! speculation gains cannot rescue a deployment whose sessions the
//! admission controller would shed. Without a `KvLoad` (historical
//! callers, `kv_cache: off`) the search is bit-identical to before.

use crate::costmodel::{self, TreeShape};
use crate::decision::CostModel;
use crate::hetero::{Mapping, Platform, PuAssignment, PuId, NUM_PUS};
use crate::kvcache;
use crate::models::{ModelSpec, Scheme};
use crate::util::json::Json;

/// The shape candidates the `tree: auto` search scores, alongside the
/// plain chain. Kept tiny: leaves stay ≤ 16 (the session pads lanes up to
/// compiled batch sizes, so wider trees mostly buy padding), and the
/// depth-1 rows matter — on boundary-dominated platforms a wide shallow
/// tree is often the only shape that beats the chain.
pub const TREE_SHAPES: [TreeShape; 6] = [
    TreeShape { branching: 2, depth: 1 },
    TreeShape { branching: 4, depth: 1 },
    TreeShape { branching: 2, depth: 2 },
    TreeShape { branching: 3, depth: 2 },
    TreeShape { branching: 4, depth: 2 },
    TreeShape { branching: 2, depth: 3 },
];

/// Why a candidate mapping was rejected (NA rows in Tables II/III).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Infeasibility {
    /// c ≥ α: speculation can never pay off (paper §II-B).
    CostExceedsAlpha,
    /// Quantized target on the Mali GPU: INT8 promotion makes it strictly
    /// worse (paper footnote 3) — excluded like the paper does.
    QuantOnGpu,
    /// Paper-scale weights exceed the device memory budget (§IV-A fn. 2).
    Memory,
    /// The KV working set at the live in-flight count does not fit the
    /// mapping's per-PU page pools ([`kv_feasible`]) — only produced when
    /// the search is given a [`KvLoad`] (`kv_cache: on`).
    KvMemory,
}

/// The live KV working-set the memory-aware search sizes mappings against:
/// every in-flight session reserves its whole token budget (prompt +
/// generation window) on the PUs its role mapping names at admission, so a
/// mapping is only usable when `inflight × pages(budget)` fits each pool.
/// Prefix sharing can only shrink the real reservation below this, so the
/// filter is conservative in the safe direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvLoad {
    /// Concurrent sessions the deployment must sustain.
    pub inflight: usize,
    /// Per-session token budget (prompt + max new tokens).
    pub budget_tokens: usize,
}

/// Whether `mapping`'s per-PU KV page pools can hold `kv.inflight`
/// sessions of `kv.budget_tokens` tokens each: drafter-role pages land on
/// the drafter's PU, target-role pages on the target's (summed when the
/// mapping is homogeneous), compared against the platform's
/// `kv_pages_cpu` / `kv_pages_gpu` capacities.
pub fn kv_feasible(platform: &Platform, pair: &PairConfig, mapping: Mapping, kv: &KvLoad) -> bool {
    let mem = &platform.memory;
    let mut need = [0usize; NUM_PUS];
    need[mapping.drafter.id().index()] +=
        kv.inflight * kvcache::pages_required(&pair.drafter, pair.drafter_scheme, mem, kv.budget_tokens);
    need[mapping.target.id().index()] +=
        kv.inflight * kvcache::pages_required(&pair.target, pair.target_scheme, mem, kv.budget_tokens);
    PuId::all().iter().all(|&pu| need[pu.index()] <= mem.kv_pages(pu))
}

/// One evaluated point of the design space.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Design variant (1-based = CPU cores available), paper Table II.
    pub variant: usize,
    pub mapping: Mapping,
    /// Cost coefficient at the operating sequence length.
    pub c: f64,
    /// Chosen draft length (0 = no speculation).
    pub gamma: usize,
    /// Predicted speedup vs the non-speculative baseline on this variant.
    pub speedup: f64,
    /// `Some(shape)` when the winning rate came from a speculation *tree*
    /// rather than the linear chain (then `gamma == shape.depth`).
    pub tree: Option<TreeShape>,
    pub infeasible: Option<Infeasibility>,
}

impl Candidate {
    pub fn speculates(&self) -> bool {
        self.gamma > 0 && self.infeasible.is_none()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("variant", self.variant.into())
            .set("mapping", Json::Str(self.mapping.label()))
            .set("heterogeneous", self.mapping.is_heterogeneous().into())
            .set("c", self.c.into())
            .set("gamma", self.gamma.into())
            .set("speedup", self.speedup.into());
        if let Some(t) = self.tree {
            j.set("tree", Json::Str(t.label()));
        }
        if let Some(inf) = self.infeasible {
            j.set("infeasible", Json::Str(format!("{inf:?}")));
        }
        j
    }
}

/// The model pair being explored (specs + quantization schemes).
#[derive(Debug, Clone)]
pub struct PairConfig {
    pub target: ModelSpec,
    pub target_scheme: Scheme,
    pub drafter: ModelSpec,
    pub drafter_scheme: Scheme,
}

/// Result of exploring one design variant: the best candidate plus the full
/// per-mapping detail (for the experiment drivers).
#[derive(Debug, Clone)]
pub struct VariantDecision {
    pub best: Candidate,
    pub all: Vec<Candidate>,
}

/// Enumerate and score every mapping for one design variant.
///
/// With N = 2 PUs and m = 2 partitions there are 4 assignments per variant;
/// GPU-target assignments are filtered per the paper (quantized target
/// unsupported; fp target doesn't fit GPU memory at paper scale).
pub fn explore_variant<M: CostModel + ?Sized>(
    model: &M,
    pair: &PairConfig,
    variant: usize,
    alpha: f64,
    seq_len: usize,
) -> VariantDecision {
    explore_variant_with_shapes(model, pair, variant, alpha, seq_len, &[])
}

/// [`explore_variant`] with an enlarged candidate space: every mapping is
/// additionally scored at each speculation-tree `shape`, and a tree
/// candidate replaces the chain row when it predicts a strictly higher
/// speedup. Tree rows skip the `c < α` filter — per-level acceptance
/// `β = 1 − (1−α)^k` can clear a bar α itself cannot — but keep the hard
/// memory / quantization feasibility gates. An empty `shapes` slice is
/// exactly the historical chain-only search.
pub fn explore_variant_with_shapes<M: CostModel + ?Sized>(
    model: &M,
    pair: &PairConfig,
    variant: usize,
    alpha: f64,
    seq_len: usize,
    shapes: &[TreeShape],
) -> VariantDecision {
    explore_variant_with_shapes_kv(model, pair, variant, alpha, seq_len, shapes, None)
}

/// [`explore_variant_with_shapes`] with the memory-aware feasibility
/// filter: when a [`KvLoad`] is given, every mapping whose in-flight KV
/// working set exceeds its per-PU page pools is rejected
/// ([`Infeasibility::KvMemory`]) *before* γ or tree scoring — a hard gate
/// like the weight-memory and quantization exclusions (tree shapes cannot
/// rescue a mapping that doesn't fit). `kv: None` takes the identical
/// code path as the historical search.
pub fn explore_variant_with_shapes_kv<M: CostModel + ?Sized>(
    model: &M,
    pair: &PairConfig,
    variant: usize,
    alpha: f64,
    seq_len: usize,
    shapes: &[TreeShape],
    kv: Option<&KvLoad>,
) -> VariantDecision {
    let assignments = [
        PuAssignment::Cpu { cores: variant },
        PuAssignment::Gpu,
    ];
    let mut all = Vec::new();
    for d_pu in assignments {
        for t_pu in assignments {
            let mapping = Mapping { drafter: d_pu, target: t_pu };
            let mut cand = score_mapping(model, pair, variant, mapping, alpha, seq_len);
            // The KV filter outranks the soft c-vs-α verdict (trees skip
            // that filter, but nothing rescues a working set that doesn't
            // fit); the weight-memory / quantization reasons, checked
            // first, are kept as the reported cause.
            if !matches!(
                cand.infeasible,
                Some(Infeasibility::Memory) | Some(Infeasibility::QuantOnGpu)
            ) {
                if let Some(kv) = kv {
                    if !kv_feasible(model.platform(), pair, mapping, kv) {
                        cand = Candidate {
                            variant,
                            mapping,
                            c: cand.c,
                            gamma: 0,
                            speedup: 1.0,
                            tree: None,
                            infeasible: Some(Infeasibility::KvMemory),
                        };
                    }
                }
            }
            let hard_infeasible = matches!(
                cand.infeasible,
                Some(Infeasibility::Memory)
                    | Some(Infeasibility::QuantOnGpu)
                    | Some(Infeasibility::KvMemory)
            );
            if !hard_infeasible {
                for &shape in shapes {
                    if !shape.branches() {
                        continue; // a 1-wide tree is the chain row already scored
                    }
                    let s = tree_speedup(model, pair, mapping, alpha, seq_len, shape);
                    if s > 1.0 && s > cand.speedup {
                        cand = Candidate {
                            variant,
                            mapping,
                            c: cand.c,
                            gamma: shape.depth,
                            speedup: s,
                            tree: Some(shape),
                            infeasible: None,
                        };
                    }
                }
            }
            all.push(cand);
        }
    }
    // Best = highest predicted speedup among feasible candidates; ties break
    // toward no-speculation / homogeneous (fewer moving parts, the paper's
    // "discourage if the gain is negligible" guidance).
    let mut best = all
        .iter()
        .filter(|c| c.infeasible.is_none())
        .cloned()
        .max_by(|a, b| {
            a.speedup
                .partial_cmp(&b.speedup)
                .unwrap()
                .then_with(|| b.mapping.is_heterogeneous().cmp(&a.mapping.is_heterogeneous()))
        })
        .unwrap_or_else(|| no_speculation(variant));
    if best.speedup <= 1.0 + 1e-9 {
        best = no_speculation(variant);
    }
    VariantDecision { best, all }
}

fn no_speculation(variant: usize) -> Candidate {
    Candidate {
        variant,
        mapping: Mapping::homogeneous(variant),
        c: f64::NAN,
        gamma: 0,
        speedup: 1.0,
        tree: None,
        infeasible: None,
    }
}

/// Predicted speedup of (k, d)-tree speculation over the non-speculative
/// baseline on `mapping`: expected committed tokens per round
/// ([`costmodel::expected_tree_tokens_per_round`]) priced against the
/// round's dispatch schedule — `d` drafter expansions of `k^(level−1)`
/// lanes plus one `k^d`-lane target verification, each lane-linear with a
/// single dispatch boundary ([`CostModel::batched_forward_latency`]).
/// At k = 1 the lane prices collapse to single forwards and this is
/// exactly Eq. (1)'s S(α, d, c); at k ≥ 2 the shape only wins where the
/// β − α acceptance gain outruns the lane-linear compute — in practice on
/// boundary-dominated platforms at low α.
pub fn tree_speedup<M: CostModel + ?Sized>(
    model: &M,
    pair: &PairConfig,
    mapping: Mapping,
    alpha: f64,
    seq_len: usize,
    shape: TreeShape,
) -> f64 {
    let tt = model.forward_latency(&pair.target, pair.target_scheme, mapping.target, seq_len);
    let mut cost = model.batched_forward_latency(
        &pair.target,
        pair.target_scheme,
        mapping.target,
        seq_len,
        shape.leaves(),
    );
    for level in 1..=shape.depth {
        cost += model.batched_forward_latency(
            &pair.drafter,
            pair.drafter_scheme,
            mapping.drafter,
            seq_len,
            costmodel::tree_draft_lanes(shape.branching, level),
        );
    }
    let tokens = costmodel::expected_tree_tokens_per_round(alpha, shape.branching, shape.depth);
    tokens * tt / cost
}

/// Score one mapping: feasibility filters, then Eq. (1) with γ* search.
pub fn score_mapping<M: CostModel + ?Sized>(
    model: &M,
    pair: &PairConfig,
    variant: usize,
    mapping: Mapping,
    alpha: f64,
    seq_len: usize,
) -> Candidate {
    let mem = &model.platform().memory;
    // Memory feasibility at paper scale (CPU+GPU share the SoC DRAM).
    if !mem.pair_fits(pair.target_scheme, pair.drafter_scheme) {
        return Candidate {
            variant, mapping, c: f64::NAN, gamma: 0, speedup: 1.0,
            tree: None,
            infeasible: Some(Infeasibility::Memory),
        };
    }
    // INT8 on the Mali is promoted to FP32 — the paper never maps the
    // quantized target there (footnote 3); we filter it the same way.
    let quant_on_gpu = (mapping.target.is_gpu() && pair.target_scheme == Scheme::W8a8)
        || (mapping.drafter.is_gpu() && pair.drafter_scheme == Scheme::W8a8);
    if quant_on_gpu && !model.platform().gpu.supports_int8 {
        return Candidate {
            variant, mapping, c: f64::NAN, gamma: 0, speedup: 1.0,
            tree: None,
            infeasible: Some(Infeasibility::QuantOnGpu),
        };
    }
    let c = model.cost_coefficient(
        (&pair.drafter, pair.drafter_scheme),
        (&pair.target, pair.target_scheme),
        mapping,
        seq_len,
    );
    if !costmodel::feasible(alpha, c) {
        return Candidate {
            variant, mapping, c, gamma: 0, speedup: 1.0,
            tree: None,
            infeasible: Some(Infeasibility::CostExceedsAlpha),
        };
    }
    let choice = costmodel::optimal_gamma(alpha, c);
    Candidate {
        variant, mapping, c,
        gamma: choice.gamma,
        speedup: choice.speedup,
        tree: None,
        infeasible: None,
    }
}

/// Full exploration across all design variants (Tables II/III generator).
pub fn explore_all<M: CostModel + ?Sized>(
    model: &M,
    pair: &PairConfig,
    alpha: f64,
    seq_len: usize,
) -> Vec<VariantDecision> {
    (1..=model.platform().design_variants())
        .map(|v| explore_variant(model, pair, v, alpha, seq_len))
        .collect()
}

/// Total size of the design space, v·N^m (paper §III-B formula).
pub fn design_space_size(v: usize, n_pus: usize, m_partitions: usize) -> usize {
    v * n_pus.pow(m_partitions as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::{LatencyModel, Platform};

    fn pair() -> PairConfig {
        PairConfig {
            target: ModelSpec {
                name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
                ffn_dim: 352, vocab: 48, param_count: 816_256,
            },
            target_scheme: Scheme::W8a8,
            drafter: ModelSpec {
                name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
                ffn_dim: 256, vocab: 48, param_count: 230_880,
            },
            drafter_scheme: Scheme::Fp,
        }
    }

    fn lat() -> LatencyModel {
        LatencyModel::new(Platform::imx95())
    }

    #[test]
    fn space_size_formula() {
        // Paper example: v = 6, N = 2, m = 2 → 24.
        assert_eq!(design_space_size(6, 2, 2), 24);
    }

    /// The headline reproduction: Table II at α = 0.90, S_L = 63.
    #[test]
    fn table2_decisions() {
        let decisions = explore_all(&lat(), &pair(), 0.90, 63);
        // Variant 1: heterogeneous, γ* ∈ {4, 5} (see costmodel tests: the
        // paper's γ = 5 is a near-tie with γ = 4 at its own c), S ≈ 1.68.
        let v1 = &decisions[0].best;
        assert!(v1.mapping.is_heterogeneous(), "{v1:?}");
        assert!(v1.gamma == 4 || v1.gamma == 5, "{v1:?}");
        assert!((v1.speedup - 1.68).abs() < 0.05, "S = {}", v1.speedup);
        // Variant 2: heterogeneous, small speedup, γ ∈ {2, 3}.
        let v2 = &decisions[1].best;
        assert!(v2.mapping.is_heterogeneous());
        assert!(v2.gamma >= 1 && v2.gamma <= 3);
        assert!(v2.speedup > 1.0 && v2.speedup < 1.3);
        // Variants 3, 4, 6: no speculation at all.
        for v in [2usize, 3, 5] {
            assert_eq!(decisions[v].best.gamma, 0, "variant {}", v + 1);
        }
        // Variant 5: if it speculates it must be homogeneous + tiny gain.
        let v5 = &decisions[4].best;
        if v5.gamma > 0 {
            assert!(!v5.mapping.is_heterogeneous());
            assert!(v5.speedup < 1.1);
        }
    }

    /// Table III: α = 0.17 → nothing speculates anywhere.
    #[test]
    fn table3_no_speculation_at_low_alpha() {
        for d in explore_all(&lat(), &pair(), 0.17, 63) {
            assert_eq!(d.best.gamma, 0);
            assert!((d.best.speedup - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn memory_infeasible_pair_never_speculates() {
        let mut p = pair();
        p.target_scheme = Scheme::Fp; // paper-scale FP16 target doesn't fit
        let d = explore_variant(&lat(), &p, 1, 0.95, 63);
        assert_eq!(d.best.gamma, 0);
        assert!(d.all.iter().all(|c| c.infeasible == Some(Infeasibility::Memory)));
    }

    #[test]
    fn quant_target_never_mapped_to_gpu() {
        let d = explore_variant(&lat(), &pair(), 1, 0.9, 63);
        for c in &d.all {
            if c.mapping.target.is_gpu() {
                assert!(c.infeasible.is_some());
            }
        }
    }

    /// A platform where compute is fast but dispatch boundaries are not:
    /// a 200× throughput bump with a 2 ms CPU boundary (an offload-runtime
    /// submit) and a cheap 100 µs GPU queue. Forward latency is then
    /// mostly boundary, which is the regime where paying k× lane compute
    /// to widen per-level acceptance is nearly free.
    fn boundary_bound() -> LatencyModel {
        let mut p = Platform::imx95();
        p.name = "imx95-npu-sim".into();
        p.cpu.peak_gflops_per_core *= 200.0;
        p.cpu.dispatch_overhead_s = 2e-3;
        p.gpu.peak_gflops *= 200.0;
        p.gpu.dispatch_overhead_s = 100e-6;
        LatencyModel::new(p)
    }

    #[test]
    fn tree_width_one_is_eq1() {
        // A (1, d) tree prices exactly like the γ = d chain: lane counts
        // collapse to 1, so tree_speedup must agree with Eq. (1).
        let l = lat();
        let p = pair();
        let m = Mapping::heterogeneous(1);
        let c = l.cost_coefficient(
            (&p.drafter, p.drafter_scheme),
            (&p.target, p.target_scheme),
            m,
            63,
        );
        for alpha in [0.17, 0.5, 0.9] {
            for d in 1..=5 {
                let tree = tree_speedup(&l, &p, m, alpha, 63, TreeShape::new(1, d));
                let chain = costmodel::speedup(alpha, d, c);
                assert!(
                    (tree - chain).abs() < 1e-9 * chain.max(1.0),
                    "alpha={alpha} d={d}: {tree} vs {chain}"
                );
            }
        }
    }

    #[test]
    fn compute_bound_platform_keeps_the_chain() {
        // Stock i.MX95: lane compute is the whole latency, so every tree
        // shape pays k^d × compute for a sub-(d+1)× token gain — the
        // enlarged search must still land on the chain (or no speculation).
        for alpha in [0.3, 0.9] {
            let d = explore_variant_with_shapes(&lat(), &pair(), 1, alpha, 63, &TREE_SHAPES);
            assert!(d.best.tree.is_none(), "alpha={alpha}: {:?}", d.best);
            let chain = explore_variant(&lat(), &pair(), 1, alpha, 63);
            assert_eq!(d.best.gamma, chain.best.gamma);
            assert!((d.best.speedup - chain.best.speedup).abs() < 1e-12);
        }
    }

    #[test]
    fn boundary_bound_platform_picks_a_tree_at_low_alpha() {
        let l = boundary_bound();
        let p = pair();
        // Low α: the chain barely pays (c ≈ 0.06 → S ≈ 1.08 at γ = 1),
        // but a wide shallow tree lifts per-level acceptance enough to
        // beat it despite the k× verify lanes.
        let low = explore_variant_with_shapes(&l, &p, 1, 0.15, 63, &TREE_SHAPES);
        let chain = explore_variant(&l, &p, 1, 0.15, 63);
        assert!(low.best.tree.is_some(), "{:?}", low.best);
        assert!(
            low.best.speedup > chain.best.speedup + 1e-9,
            "tree {} vs chain {}",
            low.best.speedup,
            chain.best.speedup
        );
        let shape = low.best.tree.unwrap();
        assert_eq!(low.best.gamma, shape.depth);
        // High α on the same platform: deep chains dominate again.
        let high = explore_variant_with_shapes(&l, &p, 1, 0.9, 63, &TREE_SHAPES);
        assert!(high.best.tree.is_none(), "{:?}", high.best);
    }

    #[test]
    fn empty_shape_list_is_bit_identical_to_chain_search() {
        let l = lat();
        let p = pair();
        for alpha in [0.17, 0.9] {
            let a = explore_variant(&l, &p, 1, alpha, 63);
            let b = explore_variant_with_shapes(&l, &p, 1, alpha, 63, &[]);
            assert_eq!(a.all.len(), b.all.len());
            for (x, y) in a.all.iter().zip(&b.all) {
                assert_eq!(x.gamma, y.gamma);
                assert_eq!(x.tree, y.tree);
                assert_eq!(x.infeasible, y.infeasible);
                assert_eq!(x.speedup.to_bits(), y.speedup.to_bits());
            }
        }
    }

    #[test]
    fn kv_load_rejects_mappings_that_do_not_fit() {
        let l = lat();
        let p = pair();
        // Huge pools: the filter is inert and the decision matches the
        // filterless search bit-for-bit.
        let roomy = KvLoad { inflight: 4, budget_tokens: 128 };
        let a = explore_variant_with_shapes_kv(&l, &p, 1, 0.9, 63, &[], Some(&roomy));
        let b = explore_variant(&l, &p, 1, 0.9, 63);
        assert_eq!(a.best.gamma, b.best.gamma);
        assert_eq!(a.best.speedup.to_bits(), b.best.speedup.to_bits());
        assert!(a.all.iter().all(|c| c.infeasible != Some(Infeasibility::KvMemory)));

        // Starve the CPU pool: every mapping needs target pages on the
        // CPU (quant target can't go to the GPU), so all four candidates
        // become KvMemory-infeasible and the best falls back to baseline.
        let mut plat = Platform::imx95();
        plat.memory.kv_pages_cpu = 2;
        let tight = LatencyModel::new(plat);
        let d = explore_variant_with_shapes_kv(
            &tight, &p, 1, 0.9, 63, &TREE_SHAPES, Some(&roomy));
        let rejected = d.all.iter()
            .filter(|c| c.infeasible == Some(Infeasibility::KvMemory))
            .count();
        assert!(rejected >= 1, "{:?}", d.all);
        assert_eq!(d.best.gamma, 0);
        // The GPU-target rows keep their original (earlier-checked) cause.
        for c in &d.all {
            if c.mapping.target.is_gpu() {
                assert_eq!(c.infeasible, Some(Infeasibility::QuantOnGpu));
            }
        }
    }

    #[test]
    fn kv_feasibility_sums_roles_on_shared_pus() {
        let p = pair();
        let mut plat = Platform::imx95();
        // Exactly the heterogeneous demand at inflight=2, budget=64:
        // target w8a8 needs ceil(64/16)=4 pages/session on the CPU,
        // drafter fp needs ceil(64/21)=4 pages/session on the GPU.
        plat.memory.kv_pages_cpu = 8;
        plat.memory.kv_pages_gpu = 8;
        let kv = KvLoad { inflight: 2, budget_tokens: 64 };
        assert!(kv_feasible(&plat, &p, Mapping::heterogeneous(1), &kv));
        // Homogeneous folds both roles onto the CPU pool: 8 + 8 > 8.
        assert!(!kv_feasible(&plat, &p, Mapping::homogeneous(1), &kv));
        plat.memory.kv_pages_cpu = 16;
        assert!(kv_feasible(&plat, &p, Mapping::homogeneous(1), &kv));
    }

    #[test]
    fn higher_alpha_never_reduces_best_speedup() {
        let l = lat();
        let p = pair();
        let mut prev = 0.0;
        for i in 0..=10 {
            let a = i as f64 / 10.0;
            let s = explore_variant(&l, &p, 1, a, 63).best.speedup;
            assert!(s >= prev - 1e-9, "alpha {a}: {s} < {prev}");
            prev = s;
        }
    }
}
