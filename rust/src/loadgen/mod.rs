//! Many-client load harness for the serving front-end.
//!
//! Drives a running [`server`](crate::server) port with up to tens of
//! thousands of simulated concurrent clients without spawning a thread
//! per client: a small pool of driver threads multiplexes nonblocking
//! client sockets with the same sweep discipline the event-loop shell
//! uses (partial-line reassembly on read, partial-write buffers on
//! send). That keeps the harness itself out of the measurement — a
//! thread-per-client loadgen would hit the exact scheduler collapse the
//! experiment is trying to measure *in the server*.
//!
//! Two drive modes:
//!
//! - **closed-loop** (`open_loop_rps == 0`): every client keeps exactly
//!   one request in flight, issuing the next as soon as the final reply
//!   lands, `requests_per_client` times. With
//!   [`LoadSpec::reconnect_per_request`] each request also pays a fresh
//!   TCP connect — connection churn, the regime where thread-per-
//!   connection serving pays a serialized accept+spawn per request.
//! - **open-loop** (`open_loop_rps > 0`): arrivals follow a Poisson
//!   process (rate split evenly across clients, independent per-client
//!   exponential gaps) for `duration_s`, regardless of completions.
//!   Latency is measured from the *scheduled* arrival, so server-side
//!   queueing during overload shows up in the tail instead of slowing
//!   the arrival process down (the open-loop property).
//!
//! A third mode replays a **workload trace** ([`LoadSpec::schedule`],
//! resolved from a saved [`crate::scenario::WorkloadTrace`] via
//! [`crate::scenario::trace_schedule`]): entry `k` goes to client
//! `k % clients` and is issued open-loop at the entry's recorded arrival
//! stamp, carrying the entry's task, prompt, `max_new` budget, SLO class
//! and deadline on the v2 wire. The arrival process lives in the trace,
//! not in the harness — two runs of the same trace issue byte-identical
//! request lines on the same schedule.
//!
//! Mixed SLO classes: the first `interactive_frac` of clients send v2
//! lines with `slo: interactive` and a `deadline_ms`; the rest send
//! seed-shaped v1 lines (batch class). Streaming mode records
//! accept-to-first-frame per request and checks frame integrity (round
//! monotonicity, `done` terminality) so a load run doubles as a
//! corruption check.
//!
//! The result is a [`LoadReport`]: p50/p99/p999 latency, throughput,
//! deadline-miss rate, accept-to-first-frame percentiles, shed/error
//! taxonomy counts, and (optionally) per-request completions keyed by
//! `c{client}.r{seq}` so two runs over the same prompt schedule can be
//! asserted byte-identical (`experiment serve_load` does exactly that
//! across `serve_mode`s).

use crate::scenario::ScheduledCall;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use std::collections::{BTreeMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// One load scenario against an already-running server.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Server port (localhost).
    pub port: u16,
    /// Concurrent simulated clients.
    pub clients: usize,
    /// Closed-loop: requests each client issues.
    pub requests_per_client: usize,
    /// Aggregate Poisson arrival rate (requests/s); 0 = closed-loop.
    pub open_loop_rps: f64,
    /// Open-loop: how long arrivals keep coming.
    pub duration_s: f64,
    /// Closed-loop: reconnect for every request (connection churn).
    pub reconnect_per_request: bool,
    /// Request streamed frames and record accept-to-first-frame.
    pub streaming: bool,
    /// Fraction of clients in the interactive SLO class (v2 lines with
    /// `deadline_ms`); the rest send v1 batch-class lines.
    pub interactive_frac: f64,
    /// Deadline the interactive class requests (ms); 0 disables.
    pub deadline_ms: f64,
    /// Prompt schedule, cycled deterministically by (client, seq).
    pub prompts: Vec<String>,
    pub task: String,
    /// Trace replay: issue exactly these calls at their recorded arrival
    /// stamps (entry `k` → client `k % clients`). Overrides the Poisson /
    /// closed-loop drive modes; `prompts`/`task` are ignored.
    pub schedule: Option<Vec<ScheduledCall>>,
    /// Driver threads multiplexing the clients (0 = auto).
    pub drivers: usize,
    pub seed: u64,
    /// TCP connect timeout per attempt.
    pub connect_timeout_s: f64,
    /// Per-request reply timeout (a stuck request becomes an error
    /// instead of hanging the harness).
    pub request_timeout_s: f64,
    /// Keep per-request completion text for cross-run parity asserts
    /// (costs memory at high request counts).
    pub record_completions: bool,
}

impl Default for LoadSpec {
    fn default() -> LoadSpec {
        LoadSpec {
            port: 0,
            clients: 64,
            requests_per_client: 4,
            open_loop_rps: 0.0,
            duration_s: 5.0,
            reconnect_per_request: false,
            streaming: false,
            interactive_frac: 0.0,
            deadline_ms: 0.0,
            prompts: vec!["tr: cela vodu".into()],
            task: "translate".into(),
            schedule: None,
            drivers: 0,
            seed: 17,
            connect_timeout_s: 5.0,
            request_timeout_s: 60.0,
            record_completions: false,
        }
    }
}

impl LoadSpec {
    fn driver_count(&self) -> usize {
        if self.drivers > 0 {
            return self.drivers;
        }
        (self.clients / 64).clamp(1, 8)
    }

    fn prompt_for(&self, client: usize, seq: usize) -> &str {
        &self.prompts[(client * self.requests_per_client.max(1) + seq) % self.prompts.len()]
    }
}

/// One request's fate, as the harness observed it.
struct ReqOutcome {
    client: usize,
    seq: usize,
    ok: bool,
    /// Typed overload shed (queue full / rate limit / drain).
    shed: bool,
    /// Transport failure, malformed reply, or reply timeout.
    error: bool,
    corrupt: bool,
    latency_ms: f64,
    ttff_ms: Option<f64>,
    deadline_missed: bool,
    completion: Option<String>,
}

/// Aggregated result of one [`run`].
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub clients: usize,
    /// Requests issued (sent, or scheduled and given up on).
    pub issued: usize,
    /// Requests that got an `ok:true` final reply.
    pub completed: usize,
    /// Typed overload sheds (`queue full` / rate limit / drain).
    pub shed: usize,
    /// Transport failures, malformed replies, reply timeouts.
    pub errors: usize,
    /// Streams with frame-integrity violations.
    pub corrupt: usize,
    pub wall_s: f64,
    /// Completed requests per wall second.
    pub throughput_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    /// Accept-to-first-frame percentiles (NaN unless streaming).
    pub ttff_p50_ms: f64,
    pub ttff_p99_ms: f64,
    /// Interactive-class requests carrying a deadline, and how many
    /// the server reported expired.
    pub deadline_requests: usize,
    pub deadline_missed: usize,
    /// `c{client}.r{seq}` → completion text, when
    /// [`LoadSpec::record_completions`] was set.
    pub completions: BTreeMap<String, String>,
}

impl LoadReport {
    pub fn deadline_miss_rate(&self) -> f64 {
        if self.deadline_requests == 0 {
            return 0.0;
        }
        self.deadline_missed as f64 / self.deadline_requests as f64
    }

    /// Flatten for CSV/JSONL rows (completions excluded).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("clients", self.clients.into())
            .set("issued", self.issued.into())
            .set("completed", self.completed.into())
            .set("shed", self.shed.into())
            .set("errors", self.errors.into())
            .set("corrupt", self.corrupt.into())
            .set("wall_s", self.wall_s.into())
            .set("throughput_rps", self.throughput_rps.into())
            .set("mean_ms", self.mean_ms.into())
            .set("p50_ms", self.p50_ms.into())
            .set("p99_ms", self.p99_ms.into())
            .set("p999_ms", self.p999_ms.into())
            .set("ttff_p50_ms", self.ttff_p50_ms.into())
            .set("ttff_p99_ms", self.ttff_p99_ms.into())
            .set("deadline_requests", self.deadline_requests.into())
            .set("deadline_missed", self.deadline_missed.into())
            .set("deadline_miss_rate", self.deadline_miss_rate().into());
        j
    }
}

/// Client connection lifecycle within a driver sweep.
enum Phase {
    /// No request due yet (or between churn reconnects).
    Idle,
    /// Writing the request line (partial writes resume here).
    Sending,
    /// Reading reply lines until the final one.
    Waiting,
    /// Quota met / window closed.
    Done,
}

/// One simulated client: nonblocking socket + reassembly buffers + the
/// request state machine.
struct Sim {
    id: usize,
    interactive: bool,
    stream: Option<TcpStream>,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    woff: usize,
    phase: Phase,
    /// Requests issued so far (seq of the in-flight one is `sent - 1`).
    sent: usize,
    rng: Rng,
    /// Open-loop arrivals (seconds since run start) not yet issued.
    backlog: VecDeque<f64>,
    /// Next scheduled arrival offset (open-loop).
    next_arrival_s: f64,
    /// Trace replay: this client's slice of the schedule, arrival order.
    calls: VecDeque<ScheduledCall>,
    /// Trace replay: the call behind the in-flight request.
    cur_call: Option<ScheduledCall>,
    /// Closed-loop start jitter, so a 10k-client run doesn't open with
    /// one synchronized thundering herd.
    start_at_s: f64,
    /// Latency clock origin for the in-flight request.
    clock_from_s: f64,
    sent_at: Instant,
    saw_first_frame: Option<f64>,
    last_round: i64,
    saw_done_frame: bool,
    frame_corrupt: bool,
}

impl Sim {
    fn new(id: usize, spec: &LoadSpec) -> Sim {
        let mut rng = Rng::new(spec.seed ^ (0x9e37_79b9_7f4a_7c15u64.wrapping_mul(id as u64 + 1)));
        let start_at_s = rng.f64() * 0.01;
        let next_arrival_s = if spec.open_loop_rps > 0.0 {
            rng.exp(spec.open_loop_rps / spec.clients.max(1) as f64)
        } else {
            0.0
        };
        let calls: VecDeque<ScheduledCall> = spec
            .schedule
            .as_ref()
            .map(|sched| {
                sched
                    .iter()
                    .enumerate()
                    .filter(|(k, _)| k % spec.clients.max(1) == id)
                    .map(|(_, c)| c.clone())
                    .collect()
            })
            .unwrap_or_default();
        Sim {
            id,
            interactive: (id as f64 + 0.5) < spec.interactive_frac * spec.clients as f64,
            stream: None,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            woff: 0,
            phase: Phase::Idle,
            sent: 0,
            rng,
            backlog: VecDeque::new(),
            next_arrival_s,
            calls,
            cur_call: None,
            start_at_s,
            clock_from_s: 0.0,
            sent_at: Instant::now(),
            saw_first_frame: None,
            last_round: -1,
            saw_done_frame: false,
            frame_corrupt: false,
        }
    }

    /// Build the wire line for request `seq`.
    fn request_line(&self, spec: &LoadSpec, seq: usize) -> String {
        if let Some(call) = &self.cur_call {
            // Trace replay: the entry's own prompt/task/shape on the v2
            // wire (`slo: batch` entries simply carry no deadline).
            let mut j = Json::obj();
            j.set("prompt", Json::Str(call.prompt.clone()))
                .set("task", Json::Str(call.task.clone()));
            if spec.streaming {
                j.set("stream", true.into());
            }
            let mut o = Json::obj();
            o.set("max_new", call.max_new.into())
                .set("slo", Json::Str(call.slo.as_str().into()));
            if let Some(d) = call.deadline_s {
                o.set("deadline_ms", (d * 1e3).into());
            }
            let req_id = self.id * 1_000_000 + seq + 1;
            j.set("v", 2usize.into()).set("req_id", req_id.into()).set("options", o);
            let mut line = j.to_string();
            line.push('\n');
            return line;
        }
        let mut j = Json::obj();
        j.set("prompt", Json::Str(spec.prompt_for(self.id, seq).into()))
            .set("task", Json::Str(spec.task.clone()));
        if spec.streaming {
            j.set("stream", true.into());
        }
        if self.interactive && spec.deadline_ms > 0.0 {
            // v2 interactive class: client-chosen req_id namespaced by
            // client (well below the server's 2^48 id floor).
            let req_id = self.id * 1_000_000 + seq + 1;
            let mut o = Json::obj();
            o.set("deadline_ms", spec.deadline_ms.into())
                .set("slo", Json::Str("interactive".into()));
            j.set("v", 2usize.into())
                .set("req_id", req_id.into())
                .set("options", o);
        }
        let mut line = j.to_string();
        line.push('\n');
        line
    }
}

/// Classify one final reply line into an outcome.
fn finish_outcome(sim: &Sim, spec: &LoadSpec, reply: &Json, now_s: f64) -> ReqOutcome {
    let seq = sim.sent - 1;
    let latency_ms = (now_s - sim.clock_from_s) * 1e3;
    let ok = reply.get("ok").and_then(Json::as_bool).unwrap_or(false);
    if !ok {
        let msg = reply.get("error").and_then(Json::as_str).unwrap_or("");
        let kind = reply.get("kind").and_then(Json::as_str).unwrap_or("");
        let shed = kind == "overloaded"
            || msg.starts_with("queue full")
            || msg.starts_with("rate limited")
            || msg.starts_with("draining");
        let deadline_missed = kind == "deadline";
        return ReqOutcome {
            client: sim.id,
            seq,
            ok: false,
            shed,
            error: !shed && !deadline_missed,
            corrupt: sim.frame_corrupt,
            latency_ms,
            ttff_ms: sim.saw_first_frame,
            deadline_missed,
            completion: None,
        };
    }
    // Streaming integrity: the final must follow a done-frame (unless
    // the request produced nothing at all).
    let corrupt = sim.frame_corrupt
        || (spec.streaming && sim.saw_first_frame.is_some() && !sim.saw_done_frame);
    let finish = reply.get("finish").and_then(Json::as_str).unwrap_or("");
    ReqOutcome {
        client: sim.id,
        seq,
        ok: true,
        shed: false,
        error: false,
        corrupt,
        latency_ms,
        ttff_ms: sim.saw_first_frame,
        deadline_missed: finish.starts_with("deadline"),
        completion: if spec.record_completions {
            reply.get("completion").and_then(Json::as_str).map(str::to_string)
        } else {
            None
        },
    }
}

/// Drive one slice of the client population to completion.
fn drive(spec: &LoadSpec, ids: std::ops::Range<usize>, t0: Instant) -> Vec<ReqOutcome> {
    let mut sims: Vec<Sim> = ids.map(|i| Sim::new(i, spec)).collect();
    let mut out: Vec<ReqOutcome> = Vec::new();
    let trace = spec.schedule.is_some();
    let open_loop = spec.open_loop_rps > 0.0 && !trace;
    let rate_per_client = spec.open_loop_rps / spec.clients.max(1) as f64;
    // Trace replay keeps arrivals coming until the last recorded stamp.
    let trace_window_s = spec
        .schedule
        .as_ref()
        .map(|s| s.iter().map(|c| c.arrival_s).fold(0.0, f64::max))
        .unwrap_or(0.0);
    // Hard stop: the arrival window (open) / quota (closed) plus a grace
    // period for stragglers; whatever is still unanswered then is lost.
    let grace_s = spec.request_timeout_s + 5.0;
    let mut idle_park = Duration::from_micros(200);
    loop {
        let now_s = t0.elapsed().as_secs_f64();
        let mut activity = false;
        let mut all_done = true;
        for sim in sims.iter_mut() {
            // Open-loop: materialize arrivals that have come due.
            if open_loop {
                while sim.next_arrival_s <= now_s {
                    if sim.next_arrival_s > spec.duration_s {
                        break;
                    }
                    sim.backlog.push_back(sim.next_arrival_s);
                    sim.next_arrival_s += sim.rng.exp(rate_per_client);
                }
            }
            match sim.phase {
                Phase::Done => continue,
                Phase::Idle => {
                    all_done = false;
                    let due = if trace {
                        sim.calls.front().map(|c| c.arrival_s).filter(|&a| a <= now_s)
                    } else if open_loop {
                        sim.backlog.front().copied()
                    } else if sim.sent < spec.requests_per_client && now_s >= sim.start_at_s {
                        Some(now_s)
                    } else {
                        None
                    };
                    let trace_done = trace && sim.calls.is_empty();
                    let closed_done =
                        !trace && !open_loop && sim.sent >= spec.requests_per_client;
                    let open_done = open_loop
                        && sim.backlog.is_empty()
                        && sim.next_arrival_s > spec.duration_s;
                    if trace_done || closed_done || open_done {
                        sim.phase = Phase::Done;
                        continue;
                    }
                    let Some(arrival_s) = due else { continue };
                    activity = true;
                    if trace {
                        sim.cur_call = sim.calls.pop_front();
                    } else if open_loop {
                        sim.backlog.pop_front();
                    }
                    // (Re)connect when churning or not yet connected.
                    if sim.stream.is_none()
                        || (!open_loop && !trace && spec.reconnect_per_request)
                    {
                        sim.stream = None;
                        let addr = std::net::SocketAddr::from(([127, 0, 0, 1], spec.port));
                        let timeout = Duration::from_secs_f64(spec.connect_timeout_s.max(0.1));
                        match TcpStream::connect_timeout(&addr, timeout) {
                            Ok(s) => {
                                s.set_nodelay(true).ok();
                                if s.set_nonblocking(true).is_err() {
                                    continue;
                                }
                                sim.stream = Some(s);
                            }
                            Err(_) => {
                                sim.sent += 1;
                                out.push(ReqOutcome {
                                    client: sim.id,
                                    seq: sim.sent - 1,
                                    ok: false,
                                    shed: false,
                                    error: true,
                                    corrupt: false,
                                    latency_ms: (now_s - arrival_s) * 1e3,
                                    ttff_ms: None,
                                    deadline_missed: false,
                                    completion: None,
                                });
                                continue;
                            }
                        }
                    }
                    let line = sim.request_line(spec, sim.sent);
                    sim.sent += 1;
                    sim.clock_from_s = arrival_s;
                    sim.sent_at = Instant::now();
                    sim.saw_first_frame = None;
                    sim.last_round = -1;
                    sim.saw_done_frame = false;
                    sim.frame_corrupt = false;
                    sim.wbuf = line.into_bytes();
                    sim.woff = 0;
                    sim.rbuf.clear();
                    sim.phase = Phase::Sending;
                }
                Phase::Sending | Phase::Waiting => {
                    all_done = false;
                }
            }
            // Progress the in-flight request (write side, then read side).
            if matches!(sim.phase, Phase::Sending) {
                let Some(s) = sim.stream.as_mut() else {
                    sim.phase = Phase::Idle;
                    continue;
                };
                loop {
                    match s.write(&sim.wbuf[sim.woff..]) {
                        Ok(0) => {
                            fail_inflight(sim, spec, &mut out, now_s);
                            break;
                        }
                        Ok(n) => {
                            activity = true;
                            sim.woff += n;
                            if sim.woff == sim.wbuf.len() {
                                sim.phase = Phase::Waiting;
                                break;
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(_) => {
                            fail_inflight(sim, spec, &mut out, now_s);
                            break;
                        }
                    }
                }
                if matches!(sim.phase, Phase::Sending)
                    && sim.sent_at.elapsed().as_secs_f64() > spec.request_timeout_s
                {
                    fail_inflight(sim, spec, &mut out, now_s);
                }
            }
            if matches!(sim.phase, Phase::Waiting) {
                activity |= pump_replies(sim, spec, &mut out, t0);
                if matches!(sim.phase, Phase::Waiting)
                    && sim.sent_at.elapsed().as_secs_f64() > spec.request_timeout_s
                {
                    fail_inflight(sim, spec, &mut out, t0.elapsed().as_secs_f64());
                }
            }
        }
        if all_done {
            break;
        }
        let window_s = if trace {
            trace_window_s
        } else if open_loop {
            spec.duration_s
        } else {
            // Closed-loop has no wall window; rely on per-request
            // timeouts, bounded by quota * timeout in the worst case.
            f64::MAX / 4.0
        };
        if now_s > window_s + grace_s {
            // Straggler cutoff: everything still in flight is lost.
            for sim in sims.iter_mut() {
                if matches!(sim.phase, Phase::Sending | Phase::Waiting) {
                    fail_inflight(sim, spec, &mut out, now_s);
                }
                sim.phase = Phase::Done;
            }
            break;
        }
        if activity {
            idle_park = Duration::from_micros(200);
        } else {
            std::thread::sleep(idle_park);
            idle_park = (idle_park * 2).min(Duration::from_millis(5));
        }
    }
    out
}

/// Record the in-flight request as errored and reset the connection
/// (the next arrival reconnects).
fn fail_inflight(sim: &mut Sim, _spec: &LoadSpec, out: &mut Vec<ReqOutcome>, now_s: f64) {
    out.push(ReqOutcome {
        client: sim.id,
        seq: sim.sent.saturating_sub(1),
        ok: false,
        shed: false,
        error: true,
        corrupt: sim.frame_corrupt,
        latency_ms: (now_s - sim.clock_from_s) * 1e3,
        ttff_ms: sim.saw_first_frame,
        deadline_missed: false,
        completion: None,
    });
    sim.stream = None;
    sim.phase = Phase::Idle;
}

/// Read whatever reply bytes are available; handle frames and the final
/// line. Returns true if bytes moved.
fn pump_replies(sim: &mut Sim, spec: &LoadSpec, out: &mut Vec<ReqOutcome>, t0: Instant) -> bool {
    let Some(s) = sim.stream.as_mut() else {
        fail_inflight(sim, spec, out, t0.elapsed().as_secs_f64());
        return false;
    };
    let mut any = false;
    let mut chunk = [0u8; 4096];
    let mut closed = false;
    loop {
        match s.read(&mut chunk) {
            Ok(0) => {
                closed = true;
                break;
            }
            Ok(n) => {
                any = true;
                sim.rbuf.extend_from_slice(&chunk[..n]);
                if n < chunk.len() {
                    break;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                closed = true;
                break;
            }
        }
    }
    // Process complete lines.
    let mut start = 0;
    while let Some(pos) = sim.rbuf[start..].iter().position(|&b| b == b'\n') {
        let end = start + pos;
        let parsed = std::str::from_utf8(&sim.rbuf[start..end])
            .ok()
            .and_then(|l| Json::parse(l.trim()).ok());
        start = end + 1;
        let now_s = t0.elapsed().as_secs_f64();
        let Some(reply) = parsed else {
            sim.frame_corrupt = true;
            continue;
        };
        if reply.get("frame").and_then(Json::as_str) == Some("tokens") {
            // Frame integrity: rounds strictly increase, nothing after
            // the done frame.
            let round = reply.get("round").and_then(Json::as_i64).unwrap_or(-1);
            if round <= sim.last_round || sim.saw_done_frame {
                sim.frame_corrupt = true;
            }
            sim.last_round = round;
            if sim.saw_first_frame.is_none() {
                sim.saw_first_frame = Some((now_s - sim.clock_from_s) * 1e3);
            }
            if reply.get("done").and_then(Json::as_bool).unwrap_or(false) {
                sim.saw_done_frame = true;
            }
            continue;
        }
        // Final line for the in-flight request.
        if matches!(sim.phase, Phase::Waiting) {
            out.push(finish_outcome(sim, spec, &reply, now_s));
            sim.phase = Phase::Idle;
            if !spec.reconnect_per_request {
                // Keep the connection for the next request.
            } else {
                sim.stream = None;
                sim.rbuf.clear();
                return any;
            }
        }
    }
    sim.rbuf.drain(..start);
    if closed && matches!(sim.phase, Phase::Waiting) {
        fail_inflight(sim, spec, out, t0.elapsed().as_secs_f64());
    } else if closed {
        sim.stream = None;
    }
    any
}

/// Run one load scenario to completion and aggregate the outcomes.
pub fn run(spec: &LoadSpec) -> anyhow::Result<LoadReport> {
    anyhow::ensure!(spec.port != 0, "loadgen needs a concrete server port");
    anyhow::ensure!(spec.clients > 0, "loadgen needs at least one client");
    anyhow::ensure!(
        spec.schedule.is_some() || !spec.prompts.is_empty(),
        "loadgen needs at least one prompt (or a trace schedule)"
    );
    if let Some(sched) = &spec.schedule {
        anyhow::ensure!(!sched.is_empty(), "trace schedule has no entries");
    }
    let drivers = spec.driver_count();
    let per = spec.clients.div_ceil(drivers);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for d in 0..drivers {
        let lo = d * per;
        let hi = ((d + 1) * per).min(spec.clients);
        if lo >= hi {
            break;
        }
        let spec = spec.clone();
        handles.push(std::thread::spawn(move || drive(&spec, lo..hi, t0)));
    }
    let mut outcomes: Vec<ReqOutcome> = Vec::new();
    for h in handles {
        outcomes.extend(h.join().map_err(|_| anyhow::anyhow!("load driver panicked"))?);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let issued = outcomes.len();
    let completed = outcomes.iter().filter(|o| o.ok).count();
    let shed = outcomes.iter().filter(|o| o.shed).count();
    let errors = outcomes.iter().filter(|o| o.error).count();
    let corrupt = outcomes.iter().filter(|o| o.corrupt).count();
    let mut lat = Summary::from_values(
        outcomes.iter().filter(|o| o.ok).map(|o| o.latency_ms).collect(),
    );
    let mut ttff = Summary::from_values(
        outcomes.iter().filter_map(|o| o.ttff_ms).collect(),
    );
    // Same class rule as `Sim::new`, so the denominator matches exactly.
    // Trace replay carries deadlines per entry instead of per client.
    let deadline_requests = if let Some(sched) = &spec.schedule {
        sched.iter().filter(|c| c.deadline_s.is_some()).count()
    } else {
        outcomes
            .iter()
            .filter(|o| {
                spec.deadline_ms > 0.0
                    && (o.client as f64 + 0.5) < spec.interactive_frac * spec.clients as f64
            })
            .count()
    };
    let deadline_missed = outcomes.iter().filter(|o| o.deadline_missed).count();
    let mut completions = BTreeMap::new();
    if spec.record_completions {
        for o in &outcomes {
            if let Some(c) = &o.completion {
                completions.insert(format!("c{}.r{}", o.client, o.seq), c.clone());
            }
        }
    }
    Ok(LoadReport {
        clients: spec.clients,
        issued,
        completed,
        shed,
        errors,
        corrupt,
        wall_s,
        throughput_rps: completed as f64 / wall_s.max(1e-9),
        mean_ms: lat.mean(),
        p50_ms: lat.percentile(50.0),
        p99_ms: lat.percentile(99.0),
        p999_ms: lat.percentile(99.9),
        ttff_p50_ms: ttff.percentile(50.0),
        ttff_p99_ms: ttff.percentile(99.0),
        deadline_requests,
        deadline_missed,
        completions,
    })
}
