//! Request-lifecycle API v2: the typed client-facing request surface.
//!
//! Everything a caller can say about one generation request lives here:
//!
//! * [`GenOptions`] — per-request knobs: token budget (`max_new`),
//!   sampling mode ([`SamplingMode`]: greedy, or stochastic with
//!   temperature + seed), stop sequences / stop token ids, a latency
//!   deadline + [`SloClass`] + integer priority (consumed by the
//!   coordinator queue for priority ordering and deadline-based
//!   admission shedding), and advisory speculation hints (γ cap,
//!   force-spec-off) the decision engine clamps its per-round choice
//!   against.
//! * [`GenerationRequest`] — a [`Request`](crate::workload::Request)
//!   plus its options; the one submission type
//!   [`Coordinator::submit`](crate::coordinator::Coordinator::submit)
//!   accepts (a bare `Request` converts with default options).
//! * [`FinishReason`] — why a request ended, carried on every
//!   [`EngineResponse`](crate::coordinator::EngineResponse) and in the
//!   v2 wire protocol's `finish` field.
//!
//! **Defaults reproduce the seed behavior exactly**: `GenOptions::default()`
//! is greedy sampling, the server-configured `max_new_tokens`, no stops,
//! no deadline, `Interactive` at priority 0, and no speculation hints —
//! bit-for-bit the token streams the pre-options code produced.
//!
//! **Deadline clock.** `deadline_s` is accounted against the *serving
//! clock*: real queueing delay plus simulated decode seconds (the
//! paper-comparable latency this repo reports). Expiry before admission
//! sheds the request from the queue; expiry mid-decode aborts the live
//! session at the next round boundary, returning the tokens committed so
//! far with [`FinishReason::DeadlineExceeded`].
//!
//! The JSON codecs in this module double as the v2 wire `options` object
//! (`GenOptions::from_json` / `to_json`) — see the protocol table in
//! [`crate::server`].

use crate::util::json::Json;
use crate::workload::Request;

/// Strict wire integer: the JSON codec is f64-backed, so "integer" means
/// a finite number with no fractional part (31.5 must not silently
/// become 31).
fn wire_int(v: &Json) -> Option<i64> {
    v.as_f64()
        .filter(|x| x.is_finite() && x.fract() == 0.0)
        .map(|x| x as i64)
}

/// Strict non-negative wire integer (shared with the server's `req_id`
/// parsing, so the whole protocol agrees on what an integer is).
pub(crate) fn wire_uint(v: &Json) -> Option<u64> {
    wire_int(v).filter(|x| *x >= 0).map(|x| x as u64)
}

/// How tokens are sampled/accepted for one request.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum SamplingMode {
    /// Deterministic argmax decoding with the greedy accept rule (the
    /// paper's setting, and the default).
    #[default]
    Greedy,
    /// The stochastic speculative-sampling accept rule at `temperature`,
    /// seeded per request for reproducibility.
    Stochastic { temperature: f64, seed: u64 },
}

impl SamplingMode {
    /// Parse the wire `sampling` object:
    /// `{"mode":"greedy"}` or
    /// `{"mode":"stochastic","temperature":0.8,"seed":7}` (temperature
    /// defaults to 1.0, seed to the crate's historical 0x5EED stream).
    pub fn from_json(j: &Json) -> anyhow::Result<SamplingMode> {
        let mode = j.req_str("mode")?;
        match mode {
            "greedy" => Ok(SamplingMode::Greedy),
            "stochastic" => {
                let temperature = match j.get("temperature") {
                    None => 1.0,
                    Some(v) => v
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("sampling.temperature must be a number"))?,
                };
                let seed = match j.get("seed") {
                    None => 0x5EED,
                    Some(v) => wire_uint(v)
                        .ok_or_else(|| anyhow::anyhow!("sampling.seed must be a non-negative integer"))?,
                };
                Ok(SamplingMode::Stochastic { temperature, seed })
            }
            other => anyhow::bail!("sampling.mode must be greedy|stochastic, got {other:?}"),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        match *self {
            SamplingMode::Greedy => {
                j.set("mode", "greedy".into());
            }
            SamplingMode::Stochastic { temperature, seed } => {
                j.set("mode", "stochastic".into())
                    .set("temperature", temperature.into())
                    .set("seed", (seed as usize).into());
            }
        }
        j
    }
}

/// Service-level class of one request, consumed by the coordinator queue:
/// `Interactive` requests are always admitted ahead of `Batch` ones,
/// regardless of numeric priority.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SloClass {
    #[default]
    Interactive,
    Batch,
}

/// Number of [`SloClass`] variants (metrics arrays).
pub const NUM_SLO_CLASSES: usize = 2;

impl SloClass {
    pub fn parse(s: &str) -> anyhow::Result<SloClass> {
        match s {
            "interactive" => Ok(SloClass::Interactive),
            "batch" => Ok(SloClass::Batch),
            _ => anyhow::bail!("slo must be interactive|batch, got {s:?}"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
        }
    }

    /// Dense index (metrics arrays; admission rank — lower admits first).
    pub fn index(&self) -> usize {
        match self {
            SloClass::Interactive => 0,
            SloClass::Batch => 1,
        }
    }
}

/// Why a request finished — carried on every
/// [`EngineResponse`](crate::coordinator::EngineResponse) and, for v2
/// wire requests, in the final reply's `finish` field.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum FinishReason {
    /// A natural stop: EOS, or one of the request's stop token ids.
    Stop,
    /// The token budget (`max_new` or the bucket-space cap) was reached.
    #[default]
    Length,
    /// The output ended with one of the request's stop sequences (which
    /// is truncated from the returned tokens).
    StopSequence,
    /// The caller cancelled; tokens committed before the abort are
    /// returned.
    Cancelled,
    /// The request's deadline expired (in the queue, or mid-decode at a
    /// round boundary); tokens committed before expiry are returned.
    DeadlineExceeded,
    /// The coordinator rejected the submission (queue full, or shutting
    /// down); no decode ever ran.
    Rejected,
}

/// Number of [`FinishReason`] variants (metrics arrays).
pub const NUM_FINISH_REASONS: usize = 6;

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Stop => "stop",
            FinishReason::Length => "length",
            FinishReason::StopSequence => "stop_sequence",
            FinishReason::Cancelled => "cancelled",
            FinishReason::DeadlineExceeded => "deadline_exceeded",
            FinishReason::Rejected => "rejected",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<FinishReason> {
        Ok(match s {
            "stop" => FinishReason::Stop,
            "length" => FinishReason::Length,
            "stop_sequence" => FinishReason::StopSequence,
            "cancelled" => FinishReason::Cancelled,
            "deadline_exceeded" => FinishReason::DeadlineExceeded,
            "rejected" => FinishReason::Rejected,
            _ => anyhow::bail!("unknown finish reason {s:?}"),
        })
    }

    /// Dense index for metrics arrays (declaration order).
    pub fn index(&self) -> usize {
        match self {
            FinishReason::Stop => 0,
            FinishReason::Length => 1,
            FinishReason::StopSequence => 2,
            FinishReason::Cancelled => 3,
            FinishReason::DeadlineExceeded => 4,
            FinishReason::Rejected => 5,
        }
    }

    /// All variants, in [`index`](Self::index) order (report rendering).
    pub fn all() -> [FinishReason; NUM_FINISH_REASONS] {
        [
            FinishReason::Stop,
            FinishReason::Length,
            FinishReason::StopSequence,
            FinishReason::Cancelled,
            FinishReason::DeadlineExceeded,
            FinishReason::Rejected,
        ]
    }
}

/// Typed per-request generation options. `Default` reproduces the
/// pre-options serving behavior exactly (see the module docs).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct GenOptions {
    /// Token budget override (`None` = the server's `max_new_tokens`;
    /// overrides are clamped to the server's `max_new_limit`).
    pub max_new: Option<usize>,
    pub sampling: SamplingMode,
    /// Generation stops (and the matched suffix is truncated) when the
    /// output ends with any of these strings.
    pub stop_sequences: Vec<String>,
    /// Token ids treated like EOS (never emitted).
    pub stop_tokens: Vec<u32>,
    /// Serving-clock deadline in seconds (see the module docs for the
    /// clock definition). `None` = no deadline.
    pub deadline_s: Option<f64>,
    pub slo: SloClass,
    /// Higher admits first within an SLO class; 0 is the default.
    pub priority: i32,
    /// Advisory upper bound on the speculation draft length γ
    /// (0 ⇒ baseline decode). The decision engine clamps against it but
    /// never widens its own choice.
    pub gamma_cap: Option<usize>,
    /// Force speculation off for this request.
    pub no_spec: bool,
}

impl GenOptions {
    /// Parse the v2 wire `options` object. Strict: unknown keys and
    /// wrongly-typed values are errors (surfaced as `bad_request`), so
    /// misspelled knobs fail loudly instead of silently doing nothing.
    pub fn from_json(j: &Json) -> anyhow::Result<GenOptions> {
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("options must be an object"))?;
        let mut o = GenOptions::default();
        for (k, v) in obj {
            match k.as_str() {
                "max_new" => {
                    o.max_new = Some(
                        wire_uint(v)
                            .ok_or_else(|| anyhow::anyhow!("max_new must be a non-negative integer"))?
                            as usize,
                    );
                }
                "sampling" => o.sampling = SamplingMode::from_json(v)?,
                "stop" => {
                    let arr = v
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("stop must be an array of strings"))?;
                    o.stop_sequences = arr
                        .iter()
                        .map(|s| {
                            s.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| anyhow::anyhow!("stop must be an array of strings"))
                        })
                        .collect::<anyhow::Result<Vec<String>>>()?;
                }
                "stop_tokens" => {
                    let arr = v
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("stop_tokens must be an array of token ids"))?;
                    o.stop_tokens = arr
                        .iter()
                        .map(|t| {
                            wire_uint(t)
                                .map(|x| x as u32)
                                .ok_or_else(|| anyhow::anyhow!("stop_tokens must be an array of token ids"))
                        })
                        .collect::<anyhow::Result<Vec<u32>>>()?;
                }
                "deadline_ms" => {
                    let ms = v
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("deadline_ms must be a number"))?;
                    o.deadline_s = Some(ms / 1e3);
                }
                "slo" => {
                    o.slo = SloClass::parse(
                        v.as_str()
                            .ok_or_else(|| anyhow::anyhow!("slo must be a string"))?,
                    )?;
                }
                "priority" => {
                    o.priority = wire_int(v)
                        .ok_or_else(|| anyhow::anyhow!("priority must be an integer"))?
                        as i32;
                }
                "gamma_cap" => {
                    o.gamma_cap = Some(
                        wire_uint(v)
                            .ok_or_else(|| anyhow::anyhow!("gamma_cap must be a non-negative integer"))?
                            as usize,
                    );
                }
                "no_spec" => {
                    o.no_spec = v
                        .as_bool()
                        .ok_or_else(|| anyhow::anyhow!("no_spec must be a boolean"))?;
                }
                other => anyhow::bail!("unknown option {other:?}"),
            }
        }
        o.validate()?;
        Ok(o)
    }

    /// Serialize as a v2 wire `options` object, omitting fields at their
    /// defaults (so the default options serialize to `{}`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        if let Some(m) = self.max_new {
            j.set("max_new", m.into());
        }
        if self.sampling != SamplingMode::Greedy {
            j.set("sampling", self.sampling.to_json());
        }
        if !self.stop_sequences.is_empty() {
            j.set(
                "stop",
                Json::Arr(self.stop_sequences.iter().map(|s| Json::Str(s.clone())).collect()),
            );
        }
        if !self.stop_tokens.is_empty() {
            j.set(
                "stop_tokens",
                Json::Arr(self.stop_tokens.iter().map(|&t| (t as usize).into()).collect()),
            );
        }
        if let Some(d) = self.deadline_s {
            j.set("deadline_ms", (d * 1e3).into());
        }
        if self.slo != SloClass::Interactive {
            j.set("slo", self.slo.as_str().into());
        }
        if self.priority != 0 {
            j.set("priority", (self.priority as i64).into());
        }
        if let Some(g) = self.gamma_cap {
            j.set("gamma_cap", g.into());
        }
        if self.no_spec {
            j.set("no_spec", true.into());
        }
        j
    }

    /// Range checks shared by the wire parser and the Rust API.
    pub fn validate(&self) -> anyhow::Result<()> {
        if let Some(m) = self.max_new {
            anyhow::ensure!(m >= 1, "max_new must be >= 1");
        }
        if let SamplingMode::Stochastic { temperature, .. } = self.sampling {
            anyhow::ensure!(
                temperature.is_finite() && temperature > 0.0,
                "temperature must be finite and > 0"
            );
        }
        if let Some(d) = self.deadline_s {
            anyhow::ensure!(d.is_finite() && d >= 0.0, "deadline must be finite and >= 0");
        }
        Ok(())
    }
}

/// One submission: a workload [`Request`] plus its [`GenOptions`]. A bare
/// `Request` converts with default options, so seed-era call sites keep
/// working through the handle API unchanged.
#[derive(Debug, Clone)]
pub struct GenerationRequest {
    pub id: u64,
    pub task: String,
    /// Prompt token ids (BOS ... SEP).
    pub prompt: Vec<u32>,
    /// Ground-truth completion text (accuracy accounting; may be empty).
    pub truth: String,
    /// Arrival offset within the run, seconds (0 for closed-loop).
    pub arrival_s: f64,
    pub options: GenOptions,
}

impl GenerationRequest {
    pub fn new(id: u64, task: impl Into<String>, prompt: Vec<u32>) -> GenerationRequest {
        GenerationRequest {
            id,
            task: task.into(),
            prompt,
            truth: String::new(),
            arrival_s: 0.0,
            options: GenOptions::default(),
        }
    }

    pub fn with_options(mut self, options: GenOptions) -> GenerationRequest {
        self.options = options;
        self
    }
}

impl From<Request> for GenerationRequest {
    fn from(r: Request) -> GenerationRequest {
        GenerationRequest {
            id: r.id,
            task: r.task,
            prompt: r.prompt,
            truth: r.truth,
            arrival_s: r.arrival_s,
            options: GenOptions::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_seed_equivalent() {
        let o = GenOptions::default();
        assert_eq!(o.max_new, None);
        assert_eq!(o.sampling, SamplingMode::Greedy);
        assert!(o.stop_sequences.is_empty() && o.stop_tokens.is_empty());
        assert_eq!(o.deadline_s, None);
        assert_eq!(o.slo, SloClass::Interactive);
        assert_eq!(o.priority, 0);
        assert_eq!(o.gamma_cap, None);
        assert!(!o.no_spec);
        o.validate().unwrap();
        // Default options serialize to the empty object.
        assert_eq!(o.to_json().to_string(), "{}");
    }

    #[test]
    fn options_json_roundtrip() {
        let o = GenOptions {
            max_new: Some(32),
            sampling: SamplingMode::Stochastic { temperature: 0.8, seed: 7 },
            stop_sequences: vec!["ab".into()],
            stop_tokens: vec![9],
            deadline_s: Some(0.25),
            slo: SloClass::Batch,
            priority: -3,
            gamma_cap: Some(2),
            no_spec: true,
        };
        let j = o.to_json();
        let back = GenOptions::from_json(&j).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn unknown_and_mistyped_options_rejected() {
        let j = Json::parse(r#"{"max_mew": 3}"#).unwrap();
        assert!(GenOptions::from_json(&j).is_err(), "typo must fail loudly");
        let j = Json::parse(r#"{"max_new": "three"}"#).unwrap();
        assert!(GenOptions::from_json(&j).is_err());
        // Non-integer numbers must fail loudly, not silently truncate.
        let j = Json::parse(r#"{"max_new": 31.5}"#).unwrap();
        assert!(GenOptions::from_json(&j).is_err());
        let j = Json::parse(r#"{"priority": 1.9}"#).unwrap();
        assert!(GenOptions::from_json(&j).is_err());
        let j = Json::parse(r#"{"stop_tokens": [4.2]}"#).unwrap();
        assert!(GenOptions::from_json(&j).is_err());
        let j = Json::parse(r#"{"gamma_cap": -1}"#).unwrap();
        assert!(GenOptions::from_json(&j).is_err());
        let j = Json::parse(r#"{"stop": "notanarray"}"#).unwrap();
        assert!(GenOptions::from_json(&j).is_err());
        let j = Json::parse(r#"{"slo": "gold"}"#).unwrap();
        assert!(GenOptions::from_json(&j).is_err());
        let j = Json::parse(r#"{"sampling": {"mode":"fast"}}"#).unwrap();
        assert!(GenOptions::from_json(&j).is_err());
        assert!(GenOptions::from_json(&Json::parse("3").unwrap()).is_err());
    }

    #[test]
    fn validation_ranges() {
        let bad_temp = GenOptions {
            sampling: SamplingMode::Stochastic { temperature: 0.0, seed: 1 },
            ..GenOptions::default()
        };
        assert!(bad_temp.validate().is_err());
        let bad_max = GenOptions { max_new: Some(0), ..GenOptions::default() };
        assert!(bad_max.validate().is_err());
        let bad_deadline = GenOptions { deadline_s: Some(-1.0), ..GenOptions::default() };
        assert!(bad_deadline.validate().is_err());
        let j = Json::parse(r#"{"deadline_ms": 250}"#).unwrap();
        let o = GenOptions::from_json(&j).unwrap();
        assert!((o.deadline_s.unwrap() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn sampling_defaults_fill_in() {
        let j = Json::parse(r#"{"mode":"stochastic"}"#).unwrap();
        let s = SamplingMode::from_json(&j).unwrap();
        assert_eq!(s, SamplingMode::Stochastic { temperature: 1.0, seed: 0x5EED });
    }

    #[test]
    fn finish_reason_strings_roundtrip() {
        for r in FinishReason::all() {
            assert_eq!(FinishReason::parse(r.as_str()).unwrap(), r);
        }
        assert!(FinishReason::parse("nope").is_err());
        assert_eq!(FinishReason::default(), FinishReason::Length);
        // Indices are dense and unique.
        let mut seen = [false; NUM_FINISH_REASONS];
        for r in FinishReason::all() {
            assert!(!seen[r.index()]);
            seen[r.index()] = true;
        }
    }

    #[test]
    fn request_conversion_keeps_fields() {
        let r = Request {
            id: 7,
            task: "translate".into(),
            prompt: vec![1, 2, 3],
            truth: "x".into(),
            arrival_s: 1.5,
            class: None,
        };
        let g: GenerationRequest = r.into();
        assert_eq!(g.id, 7);
        assert_eq!(g.task, "translate");
        assert_eq!(g.prompt, vec![1, 2, 3]);
        assert_eq!(g.options, GenOptions::default());
    }
}
