//! Cloud verification tier: the edge device runs the drafter, ships draft
//! tokens over the modeled link ([`NetworkModel`]), and a server-class
//! platform runs the target verification — the PipeSD-style collaborative
//! regime. Drafting for round *r+1* overlaps round *r*'s ship+verify, so
//! the steady-state round costs
//! `max(draft_s, rtt + payload/bw + cloud_verify_s)`
//! ([`costmodel::collaborative_round_latency`]); only the first round pays
//! the serial pipeline-fill sum.
//!
//! The tier makes one decision per request — **local-verify vs
//! cloud-verify** — by comparing predicted per-token latency of the best
//! local configuration (γ* from Eq. (1) at the device's cost coefficient)
//! against the best pipelined collaborative configuration
//! ([`costmodel::optimal_gamma_collaborative`]). Low edge α favors the
//! cloud: rounds are short (early rejections), so the round is
//! link-latency-bound and a fast link plus a ~100× faster verifier beats
//! paying the slow local target forward every round. High α or a slow
//! link favors local verification.
//!
//! Token streams are *identical* either way: verification runs the same
//! target model with the same accept rule, only faster — which is what
//! makes the bit-parity assertions in `experiment fleet` possible.

use super::network::NetworkModel;
use crate::config::CloudVerifyMode;
use crate::costmodel::{self, CollabChoice};
use crate::decision::{round_latency, CostModel};
use crate::dse::PairConfig;
use crate::hetero::{LatencyModel, Mapping, Platform, PuAssignment};
use crate::runtime::Engine;
use crate::spec::{DecodeSession, DecoderSetup};

/// Where a request's verification runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerifyRoute {
    Local,
    Cloud,
}

/// The routing decision with its audit trail: both predicted per-token
/// latencies and the γ each side would run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteChoice {
    pub route: VerifyRoute,
    /// Best local configuration: (γ*, per-token seconds).
    pub local_gamma: usize,
    pub local_per_token_s: f64,
    /// Best pipelined collaborative configuration.
    pub cloud: CollabChoice,
}

/// Per-replay accounting of a cloud-verified collaborative decode.
#[derive(Debug, Clone, Default)]
pub struct CollabOutcome {
    /// The committed tokens — bit-identical to a local decode.
    pub tokens: Vec<u32>,
    pub rounds: u64,
    /// What the same rounds cost under purely local pricing (the
    /// session's own simulated clock).
    pub local_sim_s: f64,
    /// Pipelined collaborative cost of the same rounds.
    pub collab_sim_s: f64,
    /// Modeled link seconds paid (serial sum over rounds; the pipelined
    /// clock hides most of it behind drafting).
    pub net_s: f64,
    /// Draft tokens shipped uplink.
    pub tokens_shipped: u64,
}

/// The cloud verifier: a server-class [`Platform`] priced by its own
/// [`LatencyModel`], behind a [`NetworkModel`] link.
pub struct CloudTier {
    lat: LatencyModel,
    pub net: NetworkModel,
    pub mode: CloudVerifyMode,
}

impl CloudTier {
    pub fn new(platform: Platform, net: NetworkModel, mode: CloudVerifyMode) -> CloudTier {
        CloudTier { lat: LatencyModel::new(platform), net, mode }
    }

    pub fn platform(&self) -> &Platform {
        &self.lat.platform
    }

    /// Seconds the cloud verifier spends on one γ-token verification
    /// forward. The cloud runs only the target role, on its accelerator.
    pub fn verify_s(&self, pair: &PairConfig, seq_len: usize) -> f64 {
        self.lat
            .forward_latency(&pair.target, pair.target_scheme, PuAssignment::Gpu, seq_len)
    }

    /// Full remote leg of one cloud-verified round: ship γ drafts up,
    /// verify on the cloud, ship the verdict down.
    pub fn remote_round_s(&self, pair: &PairConfig, gamma: usize, seq_len: usize) -> f64 {
        self.net.round_link_s(gamma) + self.verify_s(pair, seq_len)
    }

    /// Edge draft leg of one round: γ sequential drafter forwards on the
    /// edge device (its own cost model, its current mapping).
    pub fn draft_s(
        &self,
        edge: &dyn CostModel,
        pair: &PairConfig,
        mapping: Mapping,
        gamma: usize,
        seq_len: usize,
    ) -> f64 {
        if gamma == 0 {
            return 0.0;
        }
        gamma as f64
            * edge.forward_latency(&pair.drafter, pair.drafter_scheme, mapping.drafter, seq_len)
    }

    /// The per-request routing decision: best-local vs best-collaborative
    /// predicted per-token latency, honoring the configured
    /// [`CloudVerifyMode`] pin. `Off` and `Local` both produce a Local
    /// route (the audit fields still carry both predictions).
    pub fn verify_route(
        &self,
        edge: &dyn CostModel,
        pair: &PairConfig,
        mapping: Mapping,
        alpha: f64,
        seq_len: usize,
    ) -> RouteChoice {
        let drafter = (&pair.drafter, pair.drafter_scheme);
        let target = (&pair.target, pair.target_scheme);
        let c = edge.cost_coefficient(drafter, target, mapping, seq_len);
        let local_gamma = costmodel::optimal_gamma(alpha, c).gamma;
        let local_round_s =
            round_latency(edge, drafter, target, mapping, local_gamma, seq_len);
        let local_per_token_s =
            local_round_s / costmodel::expected_tokens_per_round(alpha, local_gamma);
        let cloud = costmodel::optimal_gamma_collaborative(alpha, costmodel::GAMMA_MAX, |g| {
            (
                self.draft_s(edge, pair, mapping, g, seq_len),
                self.remote_round_s(pair, g, seq_len),
            )
        });
        let route = match self.mode {
            CloudVerifyMode::Off | CloudVerifyMode::Local => VerifyRoute::Local,
            CloudVerifyMode::Cloud => VerifyRoute::Cloud,
            CloudVerifyMode::Auto => {
                if cloud.per_token_s < local_per_token_s {
                    VerifyRoute::Cloud
                } else {
                    VerifyRoute::Local
                }
            }
        };
        RouteChoice { route, local_gamma, local_per_token_s, cloud }
    }

    /// Run one prompt to completion as a cloud-verified collaborative
    /// decode: the session executes the real draft/verify forwards (so the
    /// committed tokens are exactly the local stream), while the
    /// collaborative clock re-prices each round as pipeline-fill for round
    /// 0 and `max(draft, ship+verify+verdict)` after
    /// ([`costmodel::collaborative_round_latency`]).
    pub fn collaborative_replay(
        &self,
        engine: &Engine,
        edge: &LatencyModel,
        pair: &PairConfig,
        setup: DecoderSetup,
        prompt: &[u32],
    ) -> anyhow::Result<CollabOutcome> {
        let mapping = setup.mapping;
        let mut session = DecodeSession::new(engine, edge.clone(), setup, true, prompt);
        let mut out = CollabOutcome::default();
        while !session.is_done() {
            let seq_len = session.seq_len();
            let step = session.step(engine)?;
            let draft_s = self.draft_s(edge, pair, mapping, step.drafted, seq_len);
            let remote_s = self.remote_round_s(pair, step.drafted, seq_len);
            out.collab_sim_s += if out.rounds == 0 {
                // Pipeline fill: nothing overlaps the first round.
                costmodel::collaborative_round_latency(draft_s, remote_s, false)
            } else {
                costmodel::collaborative_round_latency(draft_s, remote_s, true)
            };
            out.local_sim_s += step.sim_s;
            out.net_s += self.net.round_link_s(step.drafted);
            out.tokens_shipped += step.drafted as u64;
            out.rounds += 1;
            out.tokens.extend_from_slice(&step.committed);
            if step.done {
                break;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{ModelSpec, Scheme};

    fn pair() -> PairConfig {
        PairConfig {
            target: ModelSpec {
                name: "target".into(),
                n_layers: 12,
                d_model: 768,
                n_heads: 12,
                ffn_dim: 3072,
                vocab: 16000,
                param_count: 124_000_000,
            },
            target_scheme: Scheme::W8a8,
            drafter: ModelSpec {
                name: "drafter".into(),
                n_layers: 4,
                d_model: 256,
                n_heads: 4,
                ffn_dim: 1024,
                vocab: 16000,
                param_count: 7_000_000,
            },
            drafter_scheme: Scheme::Fp,
        }
    }

    fn tier(rtt_ms: f64, mbps: f64, mode: CloudVerifyMode) -> CloudTier {
        CloudTier::new(Platform::cloud(), NetworkModel::from_cfg(rtt_ms, mbps), mode)
    }

    #[test]
    fn cloud_verify_is_much_faster_than_edge_verify() {
        let t = tier(20.0, 100.0, CloudVerifyMode::Auto);
        let edge = LatencyModel::new(Platform::imx95());
        let p = pair();
        let m = Mapping::heterogeneous(2);
        let edge_verify = edge.forward_latency(&p.target, p.target_scheme, m.target, 64);
        assert!(t.verify_s(&p, 64) < edge_verify / 10.0);
        // The remote round still pays the link at least once.
        assert!(t.remote_round_s(&p, 4, 64) > t.net.rtt_s);
    }

    #[test]
    fn low_alpha_fast_link_routes_cloud_slow_link_routes_local() {
        let edge = LatencyModel::new(Platform::imx95());
        let p = pair();
        let m = Mapping::heterogeneous(2);
        // Link regimes sized off the edge verify forward itself, so the
        // assertions survive any recalibration of the platform constants.
        let edge_verify = edge.forward_latency(&p.target, p.target_scheme, m.target, 64);
        // Fast: RTT a small fraction of one edge verify — the whole
        // remote leg undercuts the local verify, so cloud wins strictly.
        let fast = tier(edge_verify * 1e3 / 50.0, 1000.0, CloudVerifyMode::Auto);
        let r = fast.verify_route(&edge, &p, m, 0.2, 64);
        assert_eq!(r.route, VerifyRoute::Cloud);
        assert!(r.cloud.per_token_s < r.local_per_token_s);
        // Slow: RTT = 20 edge verifies. Even at the maximal E[tokens] per
        // round (< γ+1 ≤ 9), the cloud per-token cost ≥ rtt/9 > 2× the
        // edge verify ≥ the best local per-token — local wins strictly.
        let slow = tier(edge_verify * 1e3 * 20.0, 1.0, CloudVerifyMode::Auto);
        let r = slow.verify_route(&edge, &p, m, 0.2, 64);
        assert_eq!(r.route, VerifyRoute::Local);
        assert!(r.local_per_token_s < r.cloud.per_token_s);
        // Pins override the comparison but keep the audit predictions.
        let pinned = tier(edge_verify * 1e3 * 20.0, 1.0, CloudVerifyMode::Cloud);
        assert_eq!(pinned.verify_route(&edge, &p, m, 0.2, 64).route, VerifyRoute::Cloud);
        let off = tier(1.0, 1000.0, CloudVerifyMode::Off);
        assert_eq!(off.verify_route(&edge, &p, m, 0.2, 64).route, VerifyRoute::Local);
    }
}
