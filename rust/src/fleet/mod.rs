//! Multi-device fleet tier: a routing layer that fronts N per-device
//! [`Coordinator`]s — each running its own engine, decision policy, and
//! metrics against its *own* [`Platform`] calibration — plus an optional
//! cloud verification tier for cloud-edge collaborative speculation.
//!
//! Submission flow: [`FleetRouter::submit`] scores every device with the
//! [`placement`] policy (load from the fleet [`DeviceTimelines`] and live
//! queue depths, SLO/deadline headroom, calibrated per-device cost at the
//! device's live α estimate), reserves the predicted service time on the
//! winner's timeline lane, decides local-verify vs cloud-verify when a
//! cloud tier is configured ([`cloud::CloudTier::verify_route`]), and
//! delegates to the winning device's coordinator — returning that
//! coordinator's ordinary [`RequestHandle`], so fleet clients stream
//! frames and wait exactly like single-device clients. A fleet of one
//! device with no cloud tier is therefore *bit-identical* to the plain
//! coordinator: same submission path, same worker, same RNG streams.
//!
//! Fleet topology comes from a JSON file (the `fleet_file` knob):
//!
//! ```json
//! {
//!   "devices": [
//!     { "name": "edge0", "platform": "imx95" },
//!     { "name": "edge1", "platform": "calib/orin.json" },
//!     { "name": "edge2", "platform": { "name": "custom", "gpu": { "peak_gflops": 80.0 } } }
//!   ],
//!   "cloud": { "platform": "cloud", "rtt_ms": 20.0, "mbps": 100.0 }
//! }
//! ```
//!
//! A `platform` entry is a built-in name ([`Platform::builtin`]), a path
//! to a calibration JSON, or an inline object (merged over the i.MX95
//! defaults like any platform file). The optional `cloud` section enables
//! the collaborative tier; `rtt_ms`/`mbps` default to the run config's
//! `cloud_rtt_ms`/`cloud_mbps` knobs when omitted.

pub mod cloud;
pub mod network;
pub mod placement;
pub mod timeline;

pub use cloud::{CloudTier, CollabOutcome, RouteChoice, VerifyRoute};
pub use network::NetworkModel;
pub use placement::{place, DeviceView, Placement, PlacementRequest};
pub use timeline::{DeviceSpan, DeviceTimelines};

use crate::api::GenerationRequest;
use crate::config::{CloudVerifyMode, KvCacheMode, RunConfig};
use crate::coordinator::{Coordinator, RequestHandle};
use crate::dse::{KvLoad, PairConfig};
use crate::hetero::Platform;
use crate::metrics::FleetMetrics;
use crate::runtime::Manifest;
use crate::util::json::Json;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

/// One device of the fleet topology, as parsed from the fleet file.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub name: String,
    pub platform: Platform,
}

/// The optional cloud section of the fleet file.
#[derive(Debug, Clone)]
pub struct CloudSpec {
    pub platform: Platform,
    /// Link parameters; `None` falls back to the run-config knobs.
    pub rtt_ms: Option<f64>,
    pub mbps: Option<f64>,
}

/// Parsed fleet topology.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub devices: Vec<DeviceSpec>,
    pub cloud: Option<CloudSpec>,
}

/// Resolve one `platform` entry: built-in name, calibration-file path, or
/// inline object. `base_dir` anchors relative paths (the fleet file's own
/// directory).
fn resolve_platform(j: &Json, base_dir: &Path) -> anyhow::Result<Platform> {
    if let Some(name) = j.as_str() {
        if let Some(p) = Platform::builtin(name) {
            return Ok(p);
        }
        let path = base_dir.join(name);
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!("platform {name:?}: not a built-in and unreadable as {path:?}: {e}")
        })?;
        let parsed = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        return Platform::from_json(&parsed);
    }
    if j.as_obj().is_some() {
        return Platform::from_json(j);
    }
    anyhow::bail!("platform must be a built-in name, a file path, or an object")
}

impl FleetSpec {
    /// Parse the fleet topology JSON. Strict where it matters: at least
    /// one device, unique device names, every platform valid.
    pub fn from_json(j: &Json, base_dir: &Path) -> anyhow::Result<FleetSpec> {
        let devices_json = j.req_arr("devices")?;
        anyhow::ensure!(!devices_json.is_empty(), "fleet needs at least one device");
        let mut devices = Vec::with_capacity(devices_json.len());
        for (i, d) in devices_json.iter().enumerate() {
            let name = match d.get("name").and_then(Json::as_str) {
                Some(n) => n.to_string(),
                None => format!("device{i}"),
            };
            let platform = d
                .get("platform")
                .map(|p| resolve_platform(p, base_dir))
                .transpose()?
                .unwrap_or_else(Platform::imx95);
            devices.push(DeviceSpec { name, platform });
        }
        for i in 1..devices.len() {
            anyhow::ensure!(
                !devices[..i].iter().any(|d| d.name == devices[i].name),
                "duplicate device name {:?}",
                devices[i].name
            );
        }
        let cloud = match j.get("cloud") {
            None => None,
            Some(c) => Some(CloudSpec {
                platform: c
                    .get("platform")
                    .map(|p| resolve_platform(p, base_dir))
                    .transpose()?
                    .unwrap_or_else(Platform::cloud),
                rtt_ms: c.get("rtt_ms").and_then(Json::as_f64),
                mbps: c.get("mbps").and_then(Json::as_f64),
            }),
        };
        Ok(FleetSpec { devices, cloud })
    }

    /// Load and parse a fleet file; relative platform paths resolve
    /// against the fleet file's directory.
    pub fn load(path: &Path) -> anyhow::Result<FleetSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("fleet file {path:?}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("fleet file {path:?}: {e}"))?;
        let base = path.parent().unwrap_or_else(|| Path::new("."));
        FleetSpec::from_json(&j, base)
    }

    /// A homogeneous N-device fleet of one platform (experiments, tests).
    pub fn homogeneous(n: usize, platform: Platform) -> FleetSpec {
        FleetSpec {
            devices: (0..n)
                .map(|i| DeviceSpec { name: format!("edge{i}"), platform: platform.clone() })
                .collect(),
            cloud: None,
        }
    }
}

/// One started fleet device.
pub struct FleetDevice {
    pub name: String,
    pub coordinator: Coordinator,
}

/// Result of one fleet submission: which device got it, how verification
/// was routed (when a cloud tier exists), and the device coordinator's
/// ordinary handle.
pub struct FleetSubmission {
    pub device: usize,
    pub verify: Option<RouteChoice>,
    pub handle: RequestHandle,
}

/// The routing tier. See the module docs for the submission flow.
pub struct FleetRouter {
    devices: Vec<FleetDevice>,
    cloud: Option<CloudTier>,
    pair: PairConfig,
    kv_cache: KvCacheMode,
    max_new_tokens: usize,
    metrics: FleetMetrics,
    timelines: Mutex<DeviceTimelines>,
    /// Wall-clock origin for the timeline "now": device lanes hold
    /// predicted *simulated* service seconds, drained against real elapsed
    /// time — a deliberate approximation (sim and wall clocks run at the
    /// same millisecond scale) that only steers load balancing, never
    /// correctness.
    started: Instant,
}

impl FleetRouter {
    /// Start one coordinator per device (same run config, per-device
    /// platform) and the cloud tier if the spec carries one.
    pub fn start(cfg: &RunConfig, spec: FleetSpec) -> anyhow::Result<FleetRouter> {
        anyhow::ensure!(!spec.devices.is_empty(), "fleet needs at least one device");
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let (d_key, t_key) = cfg.variant_keys()?;
        let pair = PairConfig {
            target: manifest.model_for(t_key)?.clone(),
            target_scheme: t_key.scheme,
            drafter: manifest.model_for(d_key)?.clone(),
            drafter_scheme: d_key.scheme,
        };
        let mut devices = Vec::with_capacity(spec.devices.len());
        for d in spec.devices {
            devices.push(FleetDevice {
                name: d.name,
                coordinator: Coordinator::start(cfg.clone(), d.platform)?,
            });
        }
        let cloud = match spec.cloud {
            Some(c) if cfg.cloud_verify != CloudVerifyMode::Off => Some(CloudTier::new(
                c.platform,
                NetworkModel::from_cfg(
                    c.rtt_ms.unwrap_or(cfg.cloud_rtt_ms),
                    c.mbps.unwrap_or(cfg.cloud_mbps),
                ),
                cfg.cloud_verify,
            )),
            _ => None,
        };
        let n = devices.len();
        Ok(FleetRouter {
            devices,
            cloud,
            pair,
            kv_cache: cfg.kv_cache,
            max_new_tokens: cfg.max_new_tokens,
            metrics: FleetMetrics::new(n),
            timelines: Mutex::new(DeviceTimelines::new(n)),
            started: Instant::now(),
        })
    }

    /// Place `req` on the best device and delegate to its coordinator.
    pub fn submit(&self, req: impl Into<GenerationRequest>) -> FleetSubmission {
        let req: GenerationRequest = req.into();
        let max_new = req.options.max_new.unwrap_or(self.max_new_tokens);
        let preq = PlacementRequest {
            pair: &self.pair,
            // Operating point: prompt plus half the budget — the mean
            // sequence length over the decode.
            seq_len: req.prompt.len() + max_new / 2,
            max_new,
            slo: req.options.slo,
            deadline_s: req.options.deadline_s,
        };
        let now = self.started.elapsed().as_secs_f64();
        let placement = {
            let tl = self.timelines.lock().unwrap();
            let views: Vec<DeviceView> = self
                .devices
                .iter()
                .enumerate()
                .map(|(i, d)| {
                    let policy = &d.coordinator.policy;
                    DeviceView {
                        platform: &policy.latency_model().platform,
                        cost: policy.cost_model(),
                        mapping: policy.current_mapping(),
                        queue_len: d.coordinator.queue_len(),
                        backlog_s: tl.backlog(i, now),
                        alpha: policy.alpha_estimate(&req.task),
                        kv_probe: match self.kv_cache {
                            KvCacheMode::Off => None,
                            KvCacheMode::On => Some(KvLoad {
                                // Admission probe: everything queued ahead
                                // plus this request, each at full budget.
                                inflight: d.coordinator.queue_len() + 1,
                                budget_tokens: req.prompt.len() + max_new,
                            }),
                        },
                    }
                })
                .collect();
            place(&views, &preq)
        };
        let device = &self.devices[placement.device];
        // Reserve the predicted service time on the winner's lane.
        {
            let policy = &device.coordinator.policy;
            let view = DeviceView {
                platform: &policy.latency_model().platform,
                cost: policy.cost_model(),
                mapping: policy.current_mapping(),
                queue_len: 0,
                backlog_s: 0.0,
                alpha: policy.alpha_estimate(&req.task),
                kv_probe: None,
            };
            let service_s = placement::predicted_service_s(&view, &preq);
            self.timelines
                .lock()
                .unwrap()
                .reserve(placement.device, now, service_s);
        }
        self.metrics
            .record_placement(placement.device, placement.kv_filtered);
        // Verify routing: predicted local vs pipelined-collaborative
        // per-token latency on the *placed* device.
        let verify = self.cloud.as_ref().map(|cloud| {
            let policy = &device.coordinator.policy;
            let choice = cloud.verify_route(
                policy.cost_model(),
                &self.pair,
                policy.current_mapping(),
                policy.alpha_estimate(&req.task),
                preq.seq_len,
            );
            if choice.route == VerifyRoute::Cloud {
                self.metrics.record_cloud_request();
            }
            choice
        });
        FleetSubmission {
            device: placement.device,
            verify,
            handle: device.coordinator.submit(req),
        }
    }

    pub fn device_count(&self) -> usize {
        self.devices.len()
    }

    pub fn devices(&self) -> &[FleetDevice] {
        &self.devices
    }

    pub fn cloud(&self) -> Option<&CloudTier> {
        self.cloud.as_ref()
    }

    pub fn pair(&self) -> &PairConfig {
        &self.pair
    }

    pub fn metrics(&self) -> &FleetMetrics {
        &self.metrics
    }

    /// Cancel a request by id on whichever device holds it.
    pub fn cancel(&self, id: u64) -> bool {
        self.devices.iter().any(|d| d.coordinator.cancel(id))
    }

    pub fn shutdown(self) {
        for d in self.devices {
            d.coordinator.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_spec_parses_builtins_inline_and_cloud() {
        let j = Json::parse(
            r#"{
              "devices": [
                { "name": "a", "platform": "imx95" },
                { "platform": { "name": "tweaked", "gpu": { "peak_gflops": 99.0 } } },
                { "name": "c" }
              ],
              "cloud": { "rtt_ms": 5.0 }
            }"#,
        )
        .unwrap();
        let spec = FleetSpec::from_json(&j, Path::new(".")).unwrap();
        assert_eq!(spec.devices.len(), 3);
        assert_eq!(spec.devices[0].name, "a");
        assert_eq!(spec.devices[0].platform.name, "imx95-sim");
        assert_eq!(spec.devices[1].name, "device1");
        assert_eq!(spec.devices[1].platform.name, "tweaked");
        assert!((spec.devices[1].platform.gpu.peak_gflops - 99.0).abs() < 1e-12);
        // Platform omitted entirely: the i.MX95 default.
        assert_eq!(spec.devices[2].platform.name, "imx95-sim");
        let cloud = spec.cloud.unwrap();
        assert_eq!(cloud.platform.name, "cloud-sim");
        assert_eq!(cloud.rtt_ms, Some(5.0));
        assert_eq!(cloud.mbps, None);
    }

    #[test]
    fn fleet_spec_rejects_empty_and_duplicate_names() {
        let empty = Json::parse(r#"{ "devices": [] }"#).unwrap();
        assert!(FleetSpec::from_json(&empty, Path::new(".")).is_err());
        let dup = Json::parse(
            r#"{ "devices": [ { "name": "x" }, { "name": "x" } ] }"#,
        )
        .unwrap();
        let err = FleetSpec::from_json(&dup, Path::new(".")).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
        let bad = Json::parse(r#"{ "devices": [ { "platform": 7 } ] }"#).unwrap();
        assert!(FleetSpec::from_json(&bad, Path::new(".")).is_err());
    }

    #[test]
    fn homogeneous_helper_names_devices_sequentially() {
        let spec = FleetSpec::homogeneous(3, Platform::imx95());
        assert_eq!(spec.devices.len(), 3);
        assert_eq!(spec.devices[0].name, "edge0");
        assert_eq!(spec.devices[2].name, "edge2");
        assert!(spec.cloud.is_none());
    }
}
