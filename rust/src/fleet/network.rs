//! Network-latency model for the cloud tier: a fixed round-trip time plus
//! a bandwidth term on token payloads. This is the `c_net` of the
//! cloud-edge collaborative regime — one speculation round ships γ draft
//! token ids (plus per-token draft metadata for the accept rule) up to the
//! verifier and receives the accept count plus one corrected token back,
//! so the per-round link charge is
//! `rtt + payload_up/bw + payload_down/bw`.
//!
//! The model is deliberately two-parameter (RTT, bandwidth): the
//! experiments sweep exactly these two axes, matching how the PipeSD-style
//! analyses parameterize the edge↔cloud link.

/// Wire bytes per draft token shipped uplink: a `u32` token id plus an
/// `f64` draft probability for the stochastic accept rule (greedy ignores
/// it, but the wire format carries it so the rule is a verifier choice),
/// plus framing.
pub const BYTES_PER_DRAFT_TOKEN: f64 = 16.0;

/// Wire bytes of a verification verdict: accept count + bonus token +
/// framing. One per round, regardless of γ.
pub const VERDICT_BYTES: f64 = 64.0;

/// Edge↔cloud link: RTT plus bandwidth term on token payloads.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// Full round-trip time, seconds (both propagation directions).
    pub rtt_s: f64,
    /// Link bandwidth, bytes per second (symmetric).
    pub bytes_per_s: f64,
}

impl NetworkModel {
    /// Build from the config-level units: milliseconds and megabits/s.
    pub fn from_cfg(rtt_ms: f64, mbps: f64) -> NetworkModel {
        NetworkModel {
            rtt_s: rtt_ms * 1e-3,
            bytes_per_s: mbps * 1e6 / 8.0,
        }
    }

    /// One propagation direction, seconds (half the RTT).
    pub fn one_way_s(&self) -> f64 {
        self.rtt_s / 2.0
    }

    /// Serialization time for a `bytes` payload on the link.
    pub fn transfer_s(&self, bytes: f64) -> f64 {
        bytes / self.bytes_per_s
    }

    /// Seconds to ship `gamma` draft tokens up to the verifier
    /// (one-way propagation + payload serialization).
    pub fn ship_drafts_s(&self, gamma: usize) -> f64 {
        self.one_way_s() + self.transfer_s(gamma as f64 * BYTES_PER_DRAFT_TOKEN)
    }

    /// Seconds for the verdict to come back down (one-way + verdict
    /// payload).
    pub fn ship_verdict_s(&self) -> f64 {
        self.one_way_s() + self.transfer_s(VERDICT_BYTES)
    }

    /// Total link seconds of one cloud-verified round: γ drafts up,
    /// verdict down. Excludes the verifier's compute — callers add the
    /// cloud forward latency between the two legs.
    pub fn round_link_s(&self, gamma: usize) -> f64 {
        self.ship_drafts_s(gamma) + self.ship_verdict_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_conversion_from_cfg() {
        let n = NetworkModel::from_cfg(20.0, 100.0);
        assert!((n.rtt_s - 0.020).abs() < 1e-15);
        // 100 Mbit/s = 12.5 MB/s.
        assert!((n.bytes_per_s - 12.5e6).abs() < 1e-6);
        assert!((n.one_way_s() - 0.010).abs() < 1e-15);
    }

    #[test]
    fn rtt_and_bandwidth_terms_compose() {
        let n = NetworkModel::from_cfg(10.0, 8.0); // 8 Mbit/s = 1 MB/s
        // transfer_s is linear in bytes at 1 byte/µs.
        assert!((n.transfer_s(1e6) - 1.0).abs() < 1e-12);
        assert!((n.transfer_s(0.0) - 0.0).abs() < 1e-15);
        // γ=4: up leg = 5ms + 64B/1MBps; verdict = 5ms + 64B/1MBps.
        let up = n.ship_drafts_s(4);
        assert!((up - (0.005 + 64.0 / 1e6)).abs() < 1e-12);
        let down = n.ship_verdict_s();
        assert!((down - (0.005 + 64.0 / 1e6)).abs() < 1e-12);
        // The full round pays the RTT exactly once.
        let round = n.round_link_s(4);
        assert!((round - (up + down)).abs() < 1e-15);
        assert!(round > n.rtt_s);
    }

    #[test]
    fn round_link_grows_with_gamma_but_rtt_dominates_small_payloads() {
        let fast = NetworkModel::from_cfg(2.0, 1000.0);
        // Monotone in γ.
        let mut prev = fast.round_link_s(0);
        for g in 1..=8 {
            let cur = fast.round_link_s(g);
            assert!(cur > prev);
            prev = cur;
        }
        // At 1 Gbit/s, 8 tokens × 16 B is ~1µs — RTT dominates by 1000×.
        let payload = fast.round_link_s(8) - fast.rtt_s;
        assert!(payload < fast.rtt_s / 100.0, "payload={payload}");
        // On a 0.1 Mbit/s link the bandwidth term is no longer noise.
        let slow = NetworkModel::from_cfg(2.0, 0.1);
        let slow_payload = slow.round_link_s(8) - slow.rtt_s;
        assert!(slow_payload > slow.rtt_s, "slow_payload={slow_payload}");
    }
}
