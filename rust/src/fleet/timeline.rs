//! Per-*device* simulated timelines — the fleet-tier generalization of
//! [`crate::hetero::PuTimelines`] from 2 fixed processing units to N
//! devices. One lane per device; the same **readiness rule** applies: a
//! request placed on device *d* with its inputs (arrival) available at
//! `arrival_s` starts at `max(ready[d], arrival_s)` and occupies *d* for
//! its predicted service seconds. Lanes on different devices overlap
//! freely — devices are independent machines, so unlike the intra-device
//! PU model there is no cross-lane blocking mode.
//!
//! The router uses this as its *predicted-backlog* load signal: at
//! placement time, `backlog(d, now)` is how far device *d*'s lane already
//! extends past the present, and a placement reserves the request's
//! predicted service time on the chosen lane. The fleet makespan
//! (`makespan()`) is the latest lane end — the quantity the scaling
//! experiment divides tokens by.

/// One reserved interval on a device lane.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceSpan {
    pub device: usize,
    pub start_s: f64,
    pub end_s: f64,
}

/// N independent device lanes with per-lane busy accounting.
#[derive(Debug, Clone)]
pub struct DeviceTimelines {
    /// Earliest time each device can start its next reservation.
    ready: Vec<f64>,
    /// Σ reserved service seconds per device.
    busy: Vec<f64>,
    /// Reservations per device.
    reservations: Vec<u64>,
}

impl DeviceTimelines {
    pub fn new(devices: usize) -> DeviceTimelines {
        DeviceTimelines {
            ready: vec![0.0; devices],
            busy: vec![0.0; devices],
            reservations: vec![0; devices],
        }
    }

    pub fn devices(&self) -> usize {
        self.ready.len()
    }

    /// Reserve `service_s` on `device` for work whose inputs are available
    /// at `arrival_s`. Readiness rule: starts at
    /// `max(ready[device], arrival_s)`.
    pub fn reserve(&mut self, device: usize, arrival_s: f64, service_s: f64) -> DeviceSpan {
        let start_s = self.ready[device].max(arrival_s);
        let end_s = start_s + service_s;
        self.ready[device] = end_s;
        self.busy[device] += service_s;
        self.reservations[device] += 1;
        DeviceSpan { device, start_s, end_s }
    }

    /// Earliest time `device` can start new work (0 before any
    /// reservation).
    pub fn ready(&self, device: usize) -> f64 {
        self.ready[device]
    }

    /// How far `device`'s lane extends past `now_s` — the predicted queue
    /// seconds a request placed now would wait before starting (0 when the
    /// lane is idle).
    pub fn backlog(&self, device: usize, now_s: f64) -> f64 {
        (self.ready[device] - now_s).max(0.0)
    }

    /// Σ reserved service seconds on `device`.
    pub fn busy(&self, device: usize) -> f64 {
        self.busy[device]
    }

    /// Reservations placed on `device`.
    pub fn reservations(&self, device: usize) -> u64 {
        self.reservations[device]
    }

    /// Latest lane end across all devices — the fleet-level makespan
    /// (0 before any reservation).
    pub fn makespan(&self) -> f64 {
        self.ready.iter().fold(0.0f64, |a, &b| a.max(b))
    }

    /// Index of the device whose lane frees up first (deterministic
    /// lowest-index tie-break). `None` for an empty fleet.
    pub fn least_loaded(&self, now_s: f64) -> Option<usize> {
        (0..self.ready.len()).min_by(|&a, &b| {
            self.backlog(a, now_s)
                .partial_cmp(&self.backlog(b, now_s))
                .unwrap()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn readiness_rule_matches_pu_timelines_semantics() {
        let mut tl = DeviceTimelines::new(3);
        // Idle lane: starts at arrival.
        let a = tl.reserve(0, 0.5, 1.0);
        assert_eq!(a, DeviceSpan { device: 0, start_s: 0.5, end_s: 1.5 });
        // Same lane serializes: arrival 0.0 but lane busy until 1.5.
        let b = tl.reserve(0, 0.0, 0.25);
        assert_eq!(b.start_s, 1.5);
        assert_eq!(b.end_s, 1.75);
        // A different lane overlaps freely.
        let c = tl.reserve(1, 0.0, 2.0);
        assert_eq!(c.start_s, 0.0);
        assert_eq!(tl.makespan(), 2.0);
        assert_eq!(tl.busy(0), 1.25);
        assert_eq!(tl.busy(1), 2.0);
        assert_eq!(tl.busy(2), 0.0);
        assert_eq!(tl.reservations(0), 2);
    }

    #[test]
    fn backlog_and_least_loaded_track_lane_ends() {
        let mut tl = DeviceTimelines::new(2);
        tl.reserve(0, 0.0, 3.0);
        tl.reserve(1, 0.0, 1.0);
        assert_eq!(tl.backlog(0, 0.5), 2.5);
        assert_eq!(tl.backlog(1, 0.5), 0.5);
        // Past the lane end, backlog clamps to 0.
        assert_eq!(tl.backlog(1, 5.0), 0.0);
        assert_eq!(tl.least_loaded(0.5), Some(1));
        // Tie (both idle far in the future) breaks to the lowest index.
        assert_eq!(tl.least_loaded(10.0), Some(0));
        assert_eq!(DeviceTimelines::new(0).least_loaded(0.0), None);
    }
}
