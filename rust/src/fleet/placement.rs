//! Device placement policy: score every device of the fleet for an
//! incoming request and pick the argmin. The score combines the three
//! signals the issue names:
//!
//! 1. **Load** — the device's predicted backlog seconds (its
//!    [`super::DeviceTimelines`] lane extent past now) plus its live admission
//!    queue depth, each queued request priced at the device's predicted
//!    round latency.
//! 2. **SLO class / deadline headroom** — interactive requests weight the
//!    wait term (they feel queueing, batch requests amortize it), and a
//!    request carrying a deadline adds a soft penalty proportional to the
//!    predicted overshoot on devices that would miss it.
//! 3. **Calibrated per-device cost** — predicted service seconds from the
//!    device's own [`CostModel`] (its calibrated [`crate::decision::Policy`]
//!    model under `decision: calibrated`, analytic otherwise) at the
//!    device's live per-task α estimate: γ* from the paper's Eq. (1)
//!    speedup at the device's cost coefficient, rounds priced by
//!    [`crate::decision::round_latency`].
//!
//! Devices whose paged-KV admission probe says this request would
//! *immediately shed* ([`dse::kv_feasible`] is false for the post-admission
//! [`dse::KvLoad`]) are filtered out before scoring whenever at least one
//! feasible device exists; if none is feasible the whole fleet is scored
//! anyway (the per-device admission layer sheds by its own policy — a
//! guaranteed-shed placement still beats rejecting outright). Ties break
//! to the lowest device index, so placement is deterministic.

use crate::api::SloClass;
use crate::costmodel;
use crate::decision::{round_latency, CostModel};
use crate::dse::{self, PairConfig};
use crate::hetero::{Mapping, Platform};

/// Everything placement may consult about one device, assembled by the
/// router from live coordinator state (queue depth, policy α/cost model,
/// KV gauges) — placement itself is a pure function of these views.
pub struct DeviceView<'a> {
    pub platform: &'a Platform,
    /// The device's cost model (calibrated or analytic).
    pub cost: &'a dyn CostModel,
    /// The device's current drafter/target mapping.
    pub mapping: Mapping,
    /// Live admission-queue depth (requests not yet picked up).
    pub queue_len: usize,
    /// Predicted backlog seconds from the fleet timelines.
    pub backlog_s: f64,
    /// The device's live α estimate for this request's task.
    pub alpha: f64,
    /// Post-admission KV load probe: the [`dse::KvLoad`] the device would
    /// carry *with this request admitted*. `None` when the paged KV cache
    /// is off (no admission shedding exists to predict).
    pub kv_probe: Option<dse::KvLoad>,
}

/// The request facts placement scores against.
pub struct PlacementRequest<'a> {
    pub pair: &'a PairConfig,
    /// Operating sequence length (prompt + budget midpoint).
    pub seq_len: usize,
    /// Token budget (for rounds-to-finish service estimate).
    pub max_new: usize,
    pub slo: SloClass,
    pub deadline_s: Option<f64>,
}

/// Placement decision: chosen device plus the audit trail.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub device: usize,
    /// The winning score (predicted weighted completion seconds).
    pub score: f64,
    /// Devices removed by the KV-admission probe for this request.
    pub kv_filtered: usize,
    /// Per-device scores (`f64::INFINITY` for filtered devices) — the
    /// experiment CSV and metrics endpoint expose these for audit.
    pub scores: Vec<f64>,
}

/// Interactive requests feel every queued second; batch requests amortize
/// them. The wait term is scaled by this factor for interactive SLOs.
const INTERACTIVE_WAIT_WEIGHT: f64 = 2.0;

/// Soft-penalty slope on predicted deadline overshoot: a device predicted
/// to miss by Δ seconds scores as if Δ·SLOPE extra seconds of latency —
/// steep enough that any deadline-meeting device wins, without making
/// misses infinitely bad (every device may miss).
const DEADLINE_MISS_SLOPE: f64 = 4.0;

/// Predicted service seconds for the request on one device: γ* from the
/// device's cost coefficient at its live α, rounds-to-budget at the
/// expected tokens per round, each round priced by the device model.
pub fn predicted_service_s(view: &DeviceView, req: &PlacementRequest) -> f64 {
    let drafter = (&req.pair.drafter, req.pair.drafter_scheme);
    let target = (&req.pair.target, req.pair.target_scheme);
    let c = view
        .cost
        .cost_coefficient(drafter, target, view.mapping, req.seq_len);
    let gamma = costmodel::optimal_gamma(view.alpha, c).gamma;
    let round_s = round_latency(view.cost, drafter, target, view.mapping, gamma, req.seq_len);
    let per_round = costmodel::expected_tokens_per_round(view.alpha, gamma);
    let rounds = (req.max_new as f64 / per_round).ceil().max(1.0);
    rounds * round_s
}

/// Score one device (lower is better). Exposed for the experiment's audit
/// columns; [`place`] is the argmin over feasible devices.
pub fn score_device(view: &DeviceView, req: &PlacementRequest) -> f64 {
    let service_s = predicted_service_s(view, req);
    // Queue depth priced at this device's own per-round rate: a queued
    // request occupies the device for roughly one request's service time,
    // but we only know the *count*, so charge each at this request's
    // predicted service (self-similar traffic assumption).
    let wait_s = view.backlog_s + view.queue_len as f64 * service_s;
    let wait_weight = match req.slo {
        SloClass::Interactive => INTERACTIVE_WAIT_WEIGHT,
        SloClass::Batch => 1.0,
    };
    let mut score = wait_weight * wait_s + service_s;
    if let Some(deadline_s) = req.deadline_s {
        let overshoot = (wait_s + service_s - deadline_s).max(0.0);
        score += DEADLINE_MISS_SLOPE * overshoot;
    }
    score
}

/// Pick the device for `req`: filter KV-infeasible devices (unless that
/// empties the fleet), score the rest, take the argmin with lowest-index
/// tie-break. Panics on an empty device slice — the router never has zero
/// devices (config validation rejects an empty fleet).
pub fn place(devices: &[DeviceView], req: &PlacementRequest) -> Placement {
    assert!(!devices.is_empty(), "placement over an empty fleet");
    let feasible: Vec<bool> = devices
        .iter()
        .map(|v| match &v.kv_probe {
            Some(kv) => dse::kv_feasible(v.platform, req.pair, v.mapping, kv),
            None => true,
        })
        .collect();
    let kv_filtered = feasible.iter().filter(|&&f| !f).count();
    // Only honor the filter when it leaves at least one device.
    let use_filter = kv_filtered < devices.len();
    let scores: Vec<f64> = devices
        .iter()
        .zip(&feasible)
        .map(|(v, &ok)| {
            if use_filter && !ok {
                f64::INFINITY
            } else {
                score_device(v, req)
            }
        })
        .collect();
    let device = scores
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    Placement { device, score: scores[device], kv_filtered, scores }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::LatencyModel;
    use crate::models::{ModelSpec, Scheme};

    fn pair() -> PairConfig {
        PairConfig {
            target: ModelSpec {
                name: "target".into(),
                n_layers: 12,
                d_model: 768,
                n_heads: 12,
                ffn_dim: 3072,
                vocab: 16000,
                param_count: 124_000_000,
            },
            target_scheme: Scheme::W8a8,
            drafter: ModelSpec {
                name: "drafter".into(),
                n_layers: 4,
                d_model: 256,
                n_heads: 4,
                ffn_dim: 1024,
                vocab: 16000,
                param_count: 7_000_000,
            },
            drafter_scheme: Scheme::Fp,
        }
    }

    fn req(pair: &PairConfig) -> PlacementRequest<'_> {
        PlacementRequest {
            pair,
            seq_len: 64,
            max_new: 32,
            slo: SloClass::Batch,
            deadline_s: None,
        }
    }

    #[test]
    fn idle_fast_device_beats_backlogged_one() {
        let p = Platform::imx95();
        let lat = LatencyModel::new(p.clone());
        let pair = pair();
        let m = Mapping::heterogeneous(2);
        let mk = |backlog_s: f64, queue_len: usize| DeviceView {
            platform: &p,
            cost: &lat,
            mapping: m,
            queue_len,
            backlog_s,
            alpha: 0.8,
            kv_probe: None,
        };
        let views = [mk(5.0, 3), mk(0.0, 0)];
        let got = place(&views, &req(&pair));
        assert_eq!(got.device, 1);
        assert!(got.scores[0] > got.scores[1]);
        assert_eq!(got.kv_filtered, 0);
        // Identical devices tie-break to the lowest index.
        let tied = [mk(1.0, 1), mk(1.0, 1)];
        assert_eq!(place(&tied, &req(&pair)).device, 0);
    }

    #[test]
    fn interactive_slo_weights_the_wait_term() {
        let p = Platform::imx95();
        let lat = LatencyModel::new(p.clone());
        let pair = pair();
        let m = Mapping::heterogeneous(2);
        let view = DeviceView {
            platform: &p,
            cost: &lat,
            mapping: m,
            queue_len: 0,
            backlog_s: 1.0,
            alpha: 0.8,
            kv_probe: None,
        };
        let mut r = req(&pair);
        let batch = score_device(&view, &r);
        r.slo = SloClass::Interactive;
        let interactive = score_device(&view, &r);
        let service = predicted_service_s(&view, &r);
        assert!((batch - (1.0 + service)).abs() < 1e-9);
        assert!((interactive - (2.0 + service)).abs() < 1e-9);
    }

    #[test]
    fn deadline_overshoot_penalizes_slow_devices() {
        let p = Platform::imx95();
        let lat = LatencyModel::new(p.clone());
        let pair = pair();
        let m = Mapping::heterogeneous(2);
        let mk = |backlog_s: f64| DeviceView {
            platform: &p,
            cost: &lat,
            mapping: m,
            queue_len: 0,
            backlog_s,
            alpha: 0.8,
            kv_probe: None,
        };
        // Device 0 idles but is about to be beaten: give it a backlog
        // just over the deadline so only device 1 can meet it.
        let views = [mk(10.0), mk(11.0)];
        let mut r = req(&pair);
        r.deadline_s = Some(10.5);
        // Without a deadline the lower-backlog device wins...
        r.deadline_s = None;
        assert_eq!(place(&views, &r).device, 0);
        // ...and the deadline cannot flip an ordering where the winner
        // also overshoots less, but the penalty widens the gap.
        r.deadline_s = Some(5.0);
        let with = place(&views, &r);
        assert_eq!(with.device, 0);
        assert!(with.scores[1] - with.scores[0] > views[1].backlog_s - views[0].backlog_s);
    }

    #[test]
    fn kv_infeasible_device_is_filtered_unless_fleet_empties() {
        let p = Platform::imx95();
        let lat = LatencyModel::new(p.clone());
        let pair = pair();
        let m = Mapping::heterogeneous(2);
        let pages = p.memory.kv_pages(crate::hetero::PuId::Cpu);
        // A probe load that cannot fit: more in-flight budget tokens than
        // the page pool could ever hold.
        let shed = dse::KvLoad { inflight: pages + 1, budget_tokens: 1 << 20 };
        let fits = dse::KvLoad { inflight: 1, budget_tokens: 128 };
        let mk = |kv: dse::KvLoad, backlog_s: f64| DeviceView {
            platform: &p,
            cost: &lat,
            mapping: m,
            queue_len: 0,
            backlog_s,
            alpha: 0.8,
            kv_probe: Some(kv),
        };
        // The infeasible device is *better* on load, but must lose.
        let views = [mk(shed, 0.0), mk(fits, 3.0)];
        let got = place(&views, &req(&pair));
        assert_eq!(got.device, 1);
        assert_eq!(got.kv_filtered, 1);
        assert!(got.scores[0].is_infinite());
        // When every device would shed, the filter is waived.
        let all_shed = [mk(shed, 1.0), mk(shed, 0.0)];
        let got = place(&all_shed, &req(&pair));
        assert_eq!(got.device, 1);
        assert_eq!(got.kv_filtered, 2);
        assert!(got.scores.iter().all(|s| s.is_finite()));
    }
}
