//! The unified decision layer: **one** subsystem owns every cost-model
//! question the runtime asks — how long will a forward take, which
//! (mapping, γ, speculate?) wins, and when should that choice be revised.
//!
//! Historically this logic was scattered across three layers that never
//! talked: `costmodel` (the Eq. (1) formulas), `dse` (an offline
//! 24-candidate mapping search at fixed measured (α, c)) and
//! `coordinator::policy` (an online α-EWMA with a boot-frozen mapping).
//! The runtime *measures* per-PU dispatch durations but threw that
//! evidence away instead of feeding it back into the model that made the
//! prediction. This module closes the loop:
//!
//! * [`model`] — the [`CostModel`] trait: the latency-prediction contract
//!   every decision is scored against, implemented by the analytic
//!   [`crate::hetero::LatencyModel`]; plus [`resolve_route`], the single
//!   mapping → PU-route rule sessions use at plan time, and
//!   [`DispatchObs`], one executed dispatch as the executor observed it.
//! * [`calibrated`] — the [`CalibratedModel`]: the analytic prior
//!   continuously refit (online least squares per (variant, kernel, PU))
//!   from observed dispatch durations.
//! * [`engine`] — the [`Policy`] decision engine: per-task α EWMAs,
//!   per-request and per-round Eq. (1) routing, prior-usage transparency,
//!   and — under `decision: "calibrated"` — periodic online
//!   re-partitioning through the DSE candidate search, adopted at the
//!   next session-admission boundary.
//!
//! The Eq. (1) primitives stay in [`crate::costmodel`] and the candidate
//! search in [`crate::dse`] (now generic over [`CostModel`]); both are
//! re-exported here so the decision layer is the one-stop API.
//!
//! **A/B knob.** `decision: "analytic"` (default) scores against the
//! offline calibration with a boot-frozen mapping — bit-identical charges,
//! token streams and dispatch counts to the pre-decision-layer code.
//! `decision: "calibrated"` turns on the feedback loop and online
//! re-partitioning (`repartition_every` rounds between searches).
//!
//! **Chain vs tree** (`tree` config knob: `off | auto | KxD`). The engine
//! can speculate a token *tree* instead of a linear chain: `(k, d)` shapes
//! draft the top-k candidates per node and verify all `k^d` root-to-leaf
//! paths as the lanes of one batched target dispatch. The trade is priced
//! by [`tree_speedup`]: per-level acceptance rises to
//! `β = 1 − (1−α)^k` ([`tree_level_acceptance`]) while every level and the
//! verification pay lane-linear compute with a single dispatch boundary
//! ([`CostModel::batched_forward_latency`]). Under `auto` every routing
//! decision — and, in calibrated mode, the periodic re-partition search
//! ([`explore_variant_with_shapes`]) — scores the [`TREE_SHAPES`]
//! candidates against the chain and adopts a shape only on a strict
//! predicted win, so compute-bound platforms keep the chain and
//! boundary-bound platforms switch to wide shallow trees at low α. The
//! winning shape rides [`RouteDecision::tree`] into the session
//! ([`crate::spec::DecodeSession::set_tree`]). `off` (default) is
//! bit-identical to the historical chain-only behavior.

pub mod calibrated;
pub mod engine;
pub mod model;

pub use calibrated::{CalibratedModel, CalibrationReport};
pub use engine::{Policy, RouteDecision, SpecHints};
pub use model::{resolve_route, round_latency, CostModel, DispatchObs};

// The decision layer's other two pillars, re-exported for one-stop use.
pub use crate::costmodel::{
    expected_tokens_per_round, expected_tree_tokens_per_round, optimal_gamma, speedup,
    tree_level_acceptance, TreeShape,
};
pub use crate::dse::{
    explore_all, explore_variant, explore_variant_with_shapes,
    explore_variant_with_shapes_kv, kv_feasible, tree_speedup, Candidate, KvLoad,
    PairConfig, VariantDecision, TREE_SHAPES,
};
