//! The online decision engine: the cost model applied *live*, per request
//! and per round (formerly `coordinator::policy`, now the heart of the
//! unified decision layer).
//!
//! The paper's workflow decides (speculation?, mapping, γ) offline from
//! profiled (α, c). A serving system can do better: the engine keeps a
//! per-task running estimate of α (EWMA over per-request acceptance rates)
//! and re-evaluates Eq. (1) per request, so a task whose drafts keep getting
//! rejected automatically falls back to plain autoregressive decoding —
//! exactly the "naive adoption can increase latency" failure mode the paper
//! warns about, handled at runtime. With resumable sessions the engine is
//! additionally consulted *between speculation rounds*
//! ([`Policy::route_round`]): the live session's own acceptance evidence is
//! blended with the task EWMA, so γ can shrink — or speculation switch off
//! entirely — midway through a request.
//!
//! **Cost-model choice** (`decision` config knob). All predictions go
//! through the [`CostModel`] trait: `analytic` scores against the
//! offline-calibrated [`LatencyModel`] (bit-identical to the historical
//! policy), `calibrated` against a [`CalibratedModel`] that is continuously
//! refit from the dispatch durations the executor feeds back via
//! [`Policy::observe_dispatches`].
//!
//! **Online re-partitioning** (calibrated mode only, and only when the
//! configuration permits the heterogeneous mapping — `heterogeneous:
//! false` pins the homogeneous one). Every `repartition_every` consulted
//! rounds the engine re-runs the DSE candidate search
//! ([`crate::dse::explore_variant`]) for the deployed design variant, at
//! the calibrated c and the EWMAs of recently consulted α estimates and
//! sequence lengths (aggregates — one session's collapsing α cannot flip
//! the fleet-wide mapping by itself), and adopts the winning mapping. The
//! switch
//! takes effect at the **next session admission** ([`Policy::route`] hands
//! out the current mapping; in-flight sessions keep the mapping frozen
//! into their `DecoderSetup` at admission), so per-session charges stay
//! deterministic and no dispatch ever changes route mid-request. Under
//! `decision: "analytic"` the mapping stays boot-frozen, reproducing the
//! pre-decision-layer behavior exactly.
//!
//! **Prior transparency.** A routing decision taken with *zero* α
//! observations for its task silently used the optimistic prior
//! (`prior_alpha = 0.90`) in earlier revisions; it is now flagged on the
//! returned [`RouteDecision`] (`used_prior`), logged once per task, and
//! counted by the coordinator into the metrics report.
//!
//! **Per-class state + drafter selection** (`drafter: auto`). Under the
//! scenario subsystem a request carries a traffic class
//! ([`RequestClass`]); the engine then keeps α / sequence-length EWMAs
//! *per class* and, given a [`DrafterRegistry`] of the manifest's
//! quantized drafter variants, periodically re-scores every (drafter
//! variant, mapping, γ/tree) candidate per class at that class's
//! per-drafter α estimates ([`DrafterRegistry::select`]) — so a
//! quant-tolerant class drafts with the cheap W8A8 body on the CPU while
//! a quant-averse one keeps the fp drafter (possibly on the GPU), within
//! one serving run. The hardware cost-coefficient calibration stays
//! global (dispatch durations are class-independent); what is per-class
//! is the *workload* state: α, per-drafter α, and the seq-length
//! operating point. Under `drafter: fixed` (the default) none of this
//! state exists and every path is bit-identical to the historical
//! single-drafter engine.

use crate::config::{DecisionMode, DrafterMode, ExecMode, RunConfig, TreeChoice};
use crate::costmodel::{self, TreeShape};
use crate::dse::{self, PairConfig};
use crate::hetero::{LatencyModel, Mapping, Platform};
use crate::models::VariantKey;
use crate::scenario::{DrafterRegistry, RequestClass};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::calibrated::{CalibratedModel, CalibrationReport};
use super::model::{CostModel, DispatchObs};

/// Per-request advisory speculation hints, carried on
/// [`GenOptions`](crate::api::GenOptions): they *clamp* the engine's
/// choice (never widen it), so a client can bound its own speculation
/// risk without overriding the cost model's feasibility reasoning.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecHints {
    /// Upper bound on the draft length γ (`Some(0)` forces baseline).
    pub gamma_cap: Option<usize>,
    /// Force speculation off for this request.
    pub force_off: bool,
}

impl SpecHints {
    /// Extract the hints a request's options carry.
    pub fn from_options(o: &crate::api::GenOptions) -> SpecHints {
        SpecHints { gamma_cap: o.gamma_cap, force_off: o.no_spec }
    }

    /// Clamp a route decision against the hints. Forced-off and
    /// zero-capped requests route to baseline decode (predicted speedup
    /// 1.0 — the prediction describes what will actually run).
    pub fn clamp(&self, mut dec: RouteDecision) -> RouteDecision {
        let cap_off = self.gamma_cap == Some(0);
        if (self.force_off || cap_off) && dec.speculative {
            dec.speculative = false;
            dec.gamma = 0;
            dec.tree = None;
            dec.predicted_speedup = 1.0;
            return dec;
        }
        if let Some(cap) = self.gamma_cap {
            if dec.speculative && dec.gamma > cap {
                dec.gamma = cap;
                // A γ cap bounds *drafted depth*, so a tree shrinks to the
                // capped depth (never widens, never deepens).
                if let Some(shape) = dec.tree {
                    dec.tree = Some(TreeShape::new(shape.branching, cap));
                }
            }
        }
        dec
    }
}

/// Per-request routing decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteDecision {
    pub speculative: bool,
    pub gamma: usize,
    /// Speculate as a token *tree* of this shape (γ = its depth) rather
    /// than a linear chain. `None` = chain (the historical behavior);
    /// always `None` when not speculating.
    pub tree: Option<TreeShape>,
    pub mapping: Mapping,
    /// Predicted speedup at decision time (diagnostics).
    pub predicted_speedup: f64,
    /// The α estimate the decision used.
    pub alpha_used: f64,
    /// The α estimate was the optimistic prior: zero observations existed
    /// for the task (and, for round-level consults, the session had no
    /// evidence of its own yet).
    pub used_prior: bool,
}

/// Which cost model backs the engine.
enum ModelChoice {
    Analytic,
    Calibrated(CalibratedModel),
}

/// Per-class decision state (`drafter: auto` only): the class-local twin
/// of the engine's global α/seq mixes, plus per-drafter α evidence and
/// the class's currently selected (drafter, mapping).
struct ClassState {
    /// EWMA of consulted α estimates for this class (NaN = unset).
    alpha_mix: f64,
    /// EWMA of consulted sequence lengths (0 = unset).
    seq_mix: f64,
    /// Consulted rounds for this class (drives the selection cadence).
    rounds: u64,
    /// Per-drafter observed-α EWMAs (fed by retire-time
    /// [`Policy::observe_alpha_tagged`]). A drafter with no observations
    /// yet is scored optimistically at the class α mix — that optimism is
    /// the exploration that gets an untried variant its first sessions.
    drafter_alpha: HashMap<VariantKey, f64>,
    /// The class's current selection; `None` until the first consult
    /// triggers a selection (admissions fall back to the configured
    /// default drafter until then).
    chosen: Option<(VariantKey, Mapping)>,
}

impl Default for ClassState {
    fn default() -> ClassState {
        ClassState {
            alpha_mix: f64::NAN,
            seq_mix: 0.0,
            rounds: 0,
            drafter_alpha: HashMap::new(),
            chosen: None,
        }
    }
}

/// Shared decision engine (one per coordinator, consulted by all workers).
pub struct Policy {
    lat: LatencyModel,
    model: ModelChoice,
    fixed_gamma: Option<usize>,
    speculative_enabled: bool,
    adaptive: bool,
    /// Tree-speculation mode (`tree` config knob), normalized at
    /// construction: trees run only under the modular exec mode (the
    /// monolithic spec-step HLO has the chain baked in), so a monolithic
    /// configuration pins this to `Off`.
    tree_choice: TreeChoice,
    /// Current mapping — boot-frozen under analytic, re-partitioned online
    /// under calibrated. Admission reads it; in-flight sessions keep the
    /// copy frozen into their setup.
    mapping: Mutex<Mapping>,
    drafter: VariantKey,
    target: VariantKey,
    design_variant: usize,
    /// Whether the heterogeneous mapping is permitted at all
    /// (`cfg.heterogeneous`): false pins the homogeneous mapping, which
    /// also makes re-partitioning inert (one permitted mapping).
    allow_hetero: bool,
    /// Per-task EWMA of acceptance rate.
    alpha: Mutex<HashMap<String, f64>>,
    /// Optimistic prior before any observation (the paper's p90 α).
    prior_alpha: f64,
    ewma: f64,
    /// Tasks already warned about riding the prior (log-once state; the
    /// serving-side *count* lives in the metrics report, recorded by the
    /// worker from `RouteDecision::used_prior` — one source of truth).
    prior_logged: Mutex<HashSet<String>>,
    /// Re-partition cadence state (calibrated mode).
    repartition_every: usize,
    rounds_seen: AtomicU64,
    repartitions: AtomicU64,
    /// EWMA of consulted sequence lengths — the live operating point the
    /// re-partition search is evaluated at (0 = nothing consulted yet).
    seq_mix: Mutex<f64>,
    /// EWMA of consulted α estimates (NaN = nothing consulted yet). The
    /// re-partition search runs at this *aggregate*, never at one
    /// consult's session-blended α — a single collapsing (or lucky)
    /// session must not be able to flip the fleet-wide mapping by landing
    /// on the cadence boundary.
    alpha_mix: Mutex<f64>,
    /// Memory-aware load point for the re-partition search: set by workers
    /// when the paged KV cache is on, so re-partitioning rejects mappings
    /// whose in-flight KV working set does not fit the per-PU page pools
    /// ([`dse::kv_feasible`]). `None` (cache off) keeps the historical
    /// search bit-identical.
    kv_load: Mutex<Option<dse::KvLoad>>,
    /// Drafter-selection mode (`drafter` config knob).
    drafter_mode: DrafterMode,
    /// Candidate drafter variants (`drafter: auto`): the worker builds the
    /// registry from the manifest at boot and installs it here. `None`
    /// (fixed mode, or before boot) disables per-class selection.
    registry: Mutex<Option<DrafterRegistry>>,
    /// Per-class decision state (`drafter: auto` only; empty otherwise).
    class_state: Mutex<HashMap<RequestClass, ClassState>>,
}

impl Policy {
    /// Build the engine from the run configuration. The drafter/target
    /// variant keys come from the config (`drafter_variant` /
    /// `target_variant`) and are role-checked here; the worker validates
    /// them against the artifact manifest before reporting ready.
    pub fn new(cfg: &RunConfig, platform: Platform) -> anyhow::Result<Policy> {
        let (drafter, target) = cfg.variant_keys()?;
        let mapping = if cfg.heterogeneous {
            Mapping::heterogeneous(cfg.design_variant)
        } else {
            Mapping::homogeneous(cfg.design_variant)
        };
        let lat = LatencyModel::new(platform);
        let model = match cfg.decision {
            DecisionMode::Analytic => ModelChoice::Analytic,
            DecisionMode::Calibrated => ModelChoice::Calibrated(CalibratedModel::new(lat.clone())),
        };
        Ok(Policy {
            lat,
            model,
            fixed_gamma: cfg.gamma,
            speculative_enabled: cfg.speculative,
            adaptive: cfg.gamma.is_none(),
            tree_choice: if cfg.exec_mode == ExecMode::Modular {
                cfg.tree
            } else {
                TreeChoice::Off
            },
            mapping: Mutex::new(mapping),
            drafter,
            target,
            design_variant: cfg.design_variant,
            allow_hetero: cfg.heterogeneous,
            alpha: Mutex::new(HashMap::new()),
            prior_alpha: 0.90,
            ewma: 0.2,
            prior_logged: Mutex::new(HashSet::new()),
            repartition_every: cfg.repartition_every,
            rounds_seen: AtomicU64::new(0),
            repartitions: AtomicU64::new(0),
            seq_mix: Mutex::new(0.0),
            alpha_mix: Mutex::new(f64::NAN),
            kv_load: Mutex::new(None),
            drafter_mode: cfg.drafter,
            registry: Mutex::new(None),
            class_state: Mutex::new(HashMap::new()),
        })
    }

    pub fn variants(&self) -> (VariantKey, VariantKey) {
        (self.drafter, self.target)
    }

    pub fn latency_model(&self) -> &LatencyModel {
        &self.lat
    }

    /// The cost model decisions are scored against.
    pub fn cost_model(&self) -> &dyn CostModel {
        match &self.model {
            ModelChoice::Analytic => &self.lat,
            ModelChoice::Calibrated(m) => m,
        }
    }

    pub fn decision_mode(&self) -> DecisionMode {
        match self.model {
            ModelChoice::Analytic => DecisionMode::Analytic,
            ModelChoice::Calibrated(_) => DecisionMode::Calibrated,
        }
    }

    /// The mapping new admissions receive right now.
    pub fn current_mapping(&self) -> Mapping {
        *self.mapping.lock().unwrap()
    }

    /// Completed online re-partition switches.
    pub fn repartition_count(&self) -> u64 {
        self.repartitions.load(Ordering::Relaxed)
    }

    /// Declare the KV working-set load the deployment must sustain (the
    /// worker calls this once when the paged cache is on). Subsequent
    /// re-partition searches treat page capacity as a hard feasibility
    /// filter at this load point.
    pub fn set_kv_load(&self, kv: dse::KvLoad) {
        *self.kv_load.lock().unwrap() = Some(kv);
    }

    /// Calibration state (zeroes under the analytic model).
    pub fn calibration(&self) -> CalibrationReport {
        match &self.model {
            ModelChoice::Analytic => CalibrationReport::default(),
            ModelChoice::Calibrated(m) => m.report(),
        }
    }

    /// Feed the executor's observed dispatch durations back into the
    /// calibrated model. Returns how many observations the estimator
    /// actually accepted (0 under the analytic model, which has nothing
    /// to refit; malformed observations are dropped by the estimator).
    pub fn observe_dispatches(&self, obs: &[DispatchObs]) -> usize {
        match &self.model {
            ModelChoice::Analytic => 0,
            ModelChoice::Calibrated(m) => obs.iter().filter(|o| m.observe(o)).count(),
        }
    }

    /// Current α estimate for a task.
    pub fn alpha_estimate(&self, task: &str) -> f64 {
        self.alpha_lookup(task).0
    }

    /// α estimate plus whether it was the prior (no observations).
    fn alpha_lookup(&self, task: &str) -> (f64, bool) {
        match self.alpha.lock().unwrap().get(task) {
            Some(&a) => (a, false),
            None => (self.prior_alpha, true),
        }
    }

    /// Log (once per task) that a decision rode the prior.
    fn note_prior(&self, task: &str) {
        let mut logged = self.prior_logged.lock().unwrap();
        if logged.insert(task.to_string()) {
            eprintln!(
                "[decision] task {task:?}: routing with zero alpha observations \
                 (optimistic prior_alpha = {:.2} stands in)",
                self.prior_alpha
            );
        }
    }

    /// Decide the execution plan for one request at admission.
    pub fn route(
        &self,
        task: &str,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        seq_len: usize,
    ) -> RouteDecision {
        let (alpha, raw_prior) = self.alpha_lookup(task);
        let used_prior = raw_prior && self.adaptive && self.speculative_enabled;
        if used_prior {
            self.note_prior(task);
        }
        let mapping = self.current_mapping();
        self.decide(alpha, used_prior, self.drafter, d_spec, t_spec, mapping, seq_len)
    }

    /// [`route`](Self::route) clamped against a request's advisory
    /// speculation hints ([`SpecHints`]).
    pub fn route_with(
        &self,
        task: &str,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        seq_len: usize,
        hints: SpecHints,
    ) -> RouteDecision {
        hints.clamp(self.route(task, d_spec, t_spec, seq_len))
    }

    /// Re-decide the plan between speculation rounds of a live session.
    ///
    /// `mapping` is the mapping *frozen into the session at admission*
    /// ([`crate::spec::DecodeSession::mapping`]) — the session's dispatches
    /// run on those routes regardless of later re-partition switches, so
    /// its γ/speculate choices must be priced there, not at the engine's
    /// current mapping. `session_drafted` / `session_alpha` are the
    /// session's own running draft count and acceptance rate; once the
    /// session has real evidence its α dominates the task-level EWMA
    /// (weight grows with the sample count), so a request whose drafts
    /// collapse mid-flight falls back to baseline within that request —
    /// not merely for the next one. Each consult also advances the
    /// re-partition cadence (calibrated mode).
    #[allow(clippy::too_many_arguments)]
    pub fn route_round(
        &self,
        task: &str,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        mapping: Mapping,
        seq_len: usize,
        session_drafted: usize,
        session_alpha: f64,
    ) -> RouteDecision {
        let (task_alpha, raw_prior) = self.alpha_lookup(task);
        let session_evidence =
            self.adaptive && session_drafted > 0 && session_alpha.is_finite();
        let alpha = if session_evidence {
            let n = session_drafted as f64;
            let w = (n / (n + 8.0)).min(0.9);
            w * session_alpha + (1.0 - w) * task_alpha
        } else {
            task_alpha
        };
        let used_prior =
            raw_prior && !session_evidence && self.adaptive && self.speculative_enabled;
        if used_prior {
            self.note_prior(task);
        }
        let dec = self.decide(alpha, used_prior, self.drafter, d_spec, t_spec, mapping, seq_len);
        self.note_round(alpha, d_spec, t_spec, seq_len);
        dec
    }

    /// [`route_round`](Self::route_round) clamped against a request's
    /// advisory speculation hints ([`SpecHints`]) — the serving worker's
    /// per-round consult. The hints bound every round's choice, so a
    /// γ-capped request stays capped even as its α evidence improves.
    #[allow(clippy::too_many_arguments)]
    pub fn route_round_with(
        &self,
        task: &str,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        mapping: Mapping,
        seq_len: usize,
        session_drafted: usize,
        session_alpha: f64,
        hints: SpecHints,
    ) -> RouteDecision {
        hints.clamp(self.route_round(
            task,
            d_spec,
            t_spec,
            mapping,
            seq_len,
            session_drafted,
            session_alpha,
        ))
    }

    /// Score the plan at one (α, drafter, mapping, seq) operating point —
    /// `drafter` is the variant whose scheme prices the draft forwards
    /// (always the configured default on the historical paths; the
    /// class-selected variant on the `*_with_drafter` paths).
    #[allow(clippy::too_many_arguments)]
    fn decide(
        &self,
        alpha: f64,
        used_prior: bool,
        drafter: VariantKey,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        mapping: Mapping,
        seq_len: usize,
    ) -> RouteDecision {
        if !self.speculative_enabled {
            return RouteDecision {
                speculative: false,
                gamma: 0,
                tree: None,
                mapping,
                predicted_speedup: 1.0,
                alpha_used: f64::NAN,
                used_prior: false,
            };
        }
        let c = self.cost_model().cost_coefficient(
            (d_spec, drafter.scheme),
            (t_spec, self.target.scheme),
            mapping,
            seq_len,
        );
        let mut dec = if let Some(g) = self.fixed_gamma {
            // Fixed-γ mode: still predict the speedup for diagnostics.
            RouteDecision {
                speculative: true,
                gamma: g,
                tree: None,
                mapping,
                predicted_speedup: costmodel::speedup(alpha, g, c),
                alpha_used: alpha,
                used_prior,
            }
        } else {
            let choice = costmodel::optimal_gamma(alpha, c);
            RouteDecision {
                speculative: choice.gamma > 0,
                gamma: choice.gamma,
                tree: None,
                mapping,
                predicted_speedup: choice.speedup,
                alpha_used: alpha,
                used_prior,
            }
        };
        self.consider_tree(&mut dec, alpha, drafter, d_spec, t_spec, mapping, seq_len);
        dec
    }

    /// Apply the `tree` knob on top of the chain decision. `Fixed` is an
    /// operator override like fixed γ: speculate as a tree of that shape
    /// whenever speculation is enabled at all (its predicted speedup is
    /// still scored honestly for diagnostics; a 1-wide shape is the chain
    /// and the session normalizes it away). `Auto` scores the candidate
    /// shapes ([`dse::TREE_SHAPES`]) against the chain through the active
    /// cost model — analytic or online-calibrated — and adopts a shape
    /// only on a strict predicted win; it defers to an operator-pinned γ.
    #[allow(clippy::too_many_arguments)]
    fn consider_tree(
        &self,
        dec: &mut RouteDecision,
        alpha: f64,
        drafter: VariantKey,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        mapping: Mapping,
        seq_len: usize,
    ) {
        if self.tree_choice == TreeChoice::Off {
            return;
        }
        let pair = PairConfig {
            target: t_spec.clone(),
            target_scheme: self.target.scheme,
            drafter: d_spec.clone(),
            drafter_scheme: drafter.scheme,
        };
        match self.tree_choice {
            TreeChoice::Off => {}
            TreeChoice::Fixed(shape) => {
                dec.speculative = true;
                dec.gamma = shape.depth;
                dec.tree = Some(shape).filter(TreeShape::branches);
                dec.predicted_speedup =
                    dse::tree_speedup(self.cost_model(), &pair, mapping, alpha, seq_len, shape);
            }
            TreeChoice::Auto => {
                if self.fixed_gamma.is_some() {
                    return;
                }
                for &shape in dse::TREE_SHAPES.iter() {
                    let s = dse::tree_speedup(
                        self.cost_model(),
                        &pair,
                        mapping,
                        alpha,
                        seq_len,
                        shape,
                    );
                    if s > 1.0 && s > dec.predicted_speedup {
                        dec.speculative = true;
                        dec.gamma = shape.depth;
                        dec.tree = Some(shape);
                        dec.predicted_speedup = s;
                    }
                }
            }
        }
    }

    /// Whether online re-partitioning is active. Besides the calibrated
    /// mode and cadence gates, `heterogeneous: false` pins the homogeneous
    /// mapping: with exactly one permitted mapping per design variant
    /// there is nothing to switch, and a configured A/B baseline must
    /// never silently adopt the heterogeneous mapping.
    fn repartition_enabled(&self) -> bool {
        matches!(self.model, ModelChoice::Calibrated(_))
            && self.repartition_every > 0
            && self.speculative_enabled
            && self.allow_hetero
    }

    /// Advance the re-partition cadence by one consulted round (folding
    /// the consult's α and seq-length into the aggregate mixes); every
    /// `repartition_every` rounds re-run the mapping search.
    fn note_round(
        &self,
        alpha: f64,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        seq_len: usize,
    ) {
        if !self.repartition_enabled() {
            return;
        }
        {
            let mut mix = self.seq_mix.lock().unwrap();
            *mix = if *mix <= 0.0 {
                seq_len as f64
            } else {
                0.9 * *mix + 0.1 * seq_len as f64
            };
        }
        if alpha.is_finite() {
            let mut mix = self.alpha_mix.lock().unwrap();
            *mix = if mix.is_nan() {
                alpha
            } else {
                0.8 * *mix + 0.2 * alpha
            };
        }
        let n = self.rounds_seen.fetch_add(1, Ordering::Relaxed) + 1;
        if n % self.repartition_every as u64 == 0 {
            self.repartition(d_spec, t_spec);
        }
    }

    /// Re-run the DSE candidate search at the calibrated (α, c) and the
    /// live α / sequence-length mixes; adopt the winning mapping for
    /// *future* admissions (in-flight sessions finish on their planned
    /// routes).
    fn repartition(
        &self,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
    ) {
        let seq = {
            let mix = *self.seq_mix.lock().unwrap();
            (mix.round() as usize).max(1)
        };
        let alpha = {
            let mix = *self.alpha_mix.lock().unwrap();
            if mix.is_nan() {
                self.prior_alpha
            } else {
                mix
            }
        };
        let pair = PairConfig {
            target: t_spec.clone(),
            target_scheme: self.target.scheme,
            drafter: d_spec.clone(),
            drafter_scheme: self.drafter.scheme,
        };
        // Under `tree: auto` the re-partition search scores the enlarged
        // (mapping × shape) candidate space, so the calibrated model's
        // observed dispatch durations feed the same chain-vs-tree choice
        // the per-round consults make. Otherwise this is bit-identical to
        // the historical chain-only search.
        let shapes: &[TreeShape] = match self.tree_choice {
            TreeChoice::Auto => &dse::TREE_SHAPES,
            _ => &[],
        };
        let kv = *self.kv_load.lock().unwrap();
        let decision = dse::explore_variant_with_shapes_kv(
            self.cost_model(),
            &pair,
            self.design_variant,
            alpha,
            seq,
            shapes,
            kv.as_ref(),
        );
        let new_mapping = decision.best.mapping;
        let mut cur = self.mapping.lock().unwrap();
        if new_mapping != *cur {
            eprintln!(
                "[decision] re-partitioned: {} -> {} (alpha = {alpha:.3}, seq = {seq}, \
                 gamma* = {}, predicted S = {:.3}, model = {})",
                cur.label(),
                new_mapping.label(),
                decision.best.gamma,
                decision.best.speedup,
                self.cost_model().name()
            );
            *cur = new_mapping;
            self.repartitions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Cost-model prediction of the cross-PU overlap fraction the per-PU
    /// timelines should approach for a γ decided at `seq_len` under this
    /// engine's *current* mapping (0 for homogeneous mappings — there is
    /// only one timeline to occupy). Serving-side twin of the bound the
    /// `overlap` experiment evaluates at its explicit mapping via
    /// [`costmodel::predicted_overlap_frac`]: compare it against the live
    /// `Report::overlap_frac` to see whether co-scheduling is dense
    /// enough to realize the mapping's predicted concurrency.
    pub fn predicted_overlap(
        &self,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        gamma: usize,
        seq_len: usize,
    ) -> f64 {
        let mapping = self.current_mapping();
        if !mapping.is_heterogeneous() {
            return 0.0;
        }
        let c = self.cost_model().cost_coefficient(
            (d_spec, self.drafter.scheme),
            (t_spec, self.target.scheme),
            mapping,
            seq_len,
        );
        costmodel::predicted_overlap_frac(gamma as f64, c)
    }

    /// Feed back an observed per-request acceptance rate.
    pub fn observe_alpha(&self, task: &str, observed: f64) {
        if !observed.is_finite() || !self.adaptive {
            return;
        }
        let mut m = self.alpha.lock().unwrap();
        let e = m.entry(task.to_string()).or_insert(self.prior_alpha);
        *e = (1.0 - self.ewma) * *e + self.ewma * observed;
    }

    // --- per-class drafter selection (`drafter: auto`) -------------------

    /// Drafter-selection mode the engine was configured with.
    pub fn drafter_mode(&self) -> DrafterMode {
        self.drafter_mode
    }

    /// Install the candidate drafter registry (the worker builds it from
    /// the artifact manifest at boot under `drafter: auto`). Without a
    /// registry the auto mode routes exactly like fixed mode.
    pub fn set_drafter_registry(&self, reg: DrafterRegistry) {
        *self.registry.lock().unwrap() = Some(reg);
    }

    /// The drafter variant a new session of `task` should be admitted
    /// with: the task's class selection under `drafter: auto` (once one
    /// exists), the configured default otherwise.
    pub fn drafter_for(&self, task: &str) -> VariantKey {
        if self.drafter_mode == DrafterMode::Auto {
            if let Some(class) = RequestClass::for_task(task) {
                if let Some(cs) = self.class_state.lock().unwrap().get(&class) {
                    if let Some((key, _)) = cs.chosen {
                        return key;
                    }
                }
            }
        }
        self.drafter
    }

    /// The class's currently selected drafter, if a selection has run.
    pub fn chosen_drafter(&self, class: RequestClass) -> Option<VariantKey> {
        self.class_state
            .lock()
            .unwrap()
            .get(&class)
            .and_then(|cs| cs.chosen.map(|(key, _)| key))
    }

    /// The class's α-mix EWMA (None until the class has been consulted).
    pub fn class_alpha_mix(&self, class: RequestClass) -> Option<f64> {
        self.class_state
            .lock()
            .unwrap()
            .get(&class)
            .map(|cs| cs.alpha_mix)
            .filter(|a| a.is_finite())
    }

    /// α estimate for (task, drafter): the class's per-drafter EWMA when
    /// auto mode has evidence for that variant, else the task EWMA /
    /// prior exactly like [`alpha_lookup`](Self::alpha_lookup).
    fn alpha_for_drafter(&self, task: &str, drafter: VariantKey) -> (f64, bool) {
        if self.drafter_mode == DrafterMode::Auto {
            if let Some(class) = RequestClass::for_task(task) {
                if let Some(cs) = self.class_state.lock().unwrap().get(&class) {
                    if let Some(&a) = cs.drafter_alpha.get(&drafter) {
                        return (a, false);
                    }
                }
            }
        }
        self.alpha_lookup(task)
    }

    /// The mapping a new session drafting with `drafter` should freeze:
    /// the class's selected mapping when auto mode selected this drafter
    /// for the task's class, the engine's current mapping otherwise.
    fn mapping_for(&self, task: &str, drafter: VariantKey) -> Mapping {
        if self.drafter_mode == DrafterMode::Auto {
            if let Some(class) = RequestClass::for_task(task) {
                if let Some(cs) = self.class_state.lock().unwrap().get(&class) {
                    if let Some((key, mapping)) = cs.chosen {
                        if key == drafter {
                            return mapping;
                        }
                    }
                }
            }
        }
        self.current_mapping()
    }

    /// [`route_with`](Self::route_with) generalized to an explicit drafter
    /// variant: prices draft forwards at that variant's scheme, uses the
    /// task class's per-drafter α evidence, and admits onto the class's
    /// selected mapping. With the configured default drafter under
    /// `drafter: fixed` this is exactly `route_with` — same α lookup, same
    /// mapping, same decision — so the single-drafter path stays
    /// bit-identical.
    pub fn route_with_drafter(
        &self,
        task: &str,
        drafter: VariantKey,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        seq_len: usize,
        hints: SpecHints,
    ) -> RouteDecision {
        let (alpha, raw_prior) = self.alpha_for_drafter(task, drafter);
        let used_prior = raw_prior && self.adaptive && self.speculative_enabled;
        if used_prior {
            self.note_prior(task);
        }
        let mapping = self.mapping_for(task, drafter);
        hints.clamp(self.decide(alpha, used_prior, drafter, d_spec, t_spec, mapping, seq_len))
    }

    /// [`route_round_with`](Self::route_round_with) generalized to an
    /// explicit drafter variant. Besides the global re-partition cadence,
    /// each consult advances the task class's own α/seq mixes and — every
    /// `repartition_every` class rounds (and once at the class's first
    /// consult) — re-runs the per-class drafter selection over the
    /// registry. Under `drafter: fixed` with the default drafter this
    /// delegates verbatim to `route_round_with`.
    #[allow(clippy::too_many_arguments)]
    pub fn route_round_with_drafter(
        &self,
        task: &str,
        drafter: VariantKey,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        mapping: Mapping,
        seq_len: usize,
        session_drafted: usize,
        session_alpha: f64,
        hints: SpecHints,
    ) -> RouteDecision {
        if self.drafter_mode == DrafterMode::Fixed && drafter == self.drafter {
            return self.route_round_with(
                task,
                d_spec,
                t_spec,
                mapping,
                seq_len,
                session_drafted,
                session_alpha,
                hints,
            );
        }
        let (task_alpha, raw_prior) = self.alpha_for_drafter(task, drafter);
        let session_evidence =
            self.adaptive && session_drafted > 0 && session_alpha.is_finite();
        let alpha = if session_evidence {
            let n = session_drafted as f64;
            let w = (n / (n + 8.0)).min(0.9);
            w * session_alpha + (1.0 - w) * task_alpha
        } else {
            task_alpha
        };
        let used_prior =
            raw_prior && !session_evidence && self.adaptive && self.speculative_enabled;
        if used_prior {
            self.note_prior(task);
        }
        let dec = self.decide(alpha, used_prior, drafter, d_spec, t_spec, mapping, seq_len);
        self.note_round(alpha, d_spec, t_spec, seq_len);
        self.note_class_round(task, alpha, seq_len, t_spec);
        hints.clamp(dec)
    }

    /// Retire-time feedback tagged with the drafter that produced it:
    /// updates the task EWMA exactly like
    /// [`observe_alpha`](Self::observe_alpha) and additionally the task
    /// class's per-drafter α EWMA, the evidence the next per-class
    /// selection scores that variant at.
    pub fn observe_alpha_tagged(&self, task: &str, drafter: VariantKey, observed: f64) {
        self.observe_alpha(task, observed);
        if self.drafter_mode != DrafterMode::Auto || !observed.is_finite() || !self.adaptive
        {
            return;
        }
        let Some(class) = RequestClass::for_task(task) else {
            return;
        };
        let mut state = self.class_state.lock().unwrap();
        let cs = state.entry(class).or_default();
        let e = cs.drafter_alpha.entry(drafter).or_insert(self.prior_alpha);
        *e = (1.0 - self.ewma) * *e + self.ewma * observed;
    }

    /// Advance one class's consult state (auto mode): fold the consult's
    /// α and seq length into the class mixes and, at the selection
    /// cadence, re-run the per-class drafter selection.
    fn note_class_round(
        &self,
        task: &str,
        alpha: f64,
        seq_len: usize,
        t_spec: &crate::models::ModelSpec,
    ) {
        if self.drafter_mode != DrafterMode::Auto {
            return;
        }
        let Some(class) = RequestClass::for_task(task) else {
            return;
        };
        let select_now = {
            let mut state = self.class_state.lock().unwrap();
            let cs = state.entry(class).or_default();
            cs.seq_mix = if cs.seq_mix <= 0.0 {
                seq_len as f64
            } else {
                0.9 * cs.seq_mix + 0.1 * seq_len as f64
            };
            if alpha.is_finite() {
                cs.alpha_mix = if cs.alpha_mix.is_nan() {
                    alpha
                } else {
                    0.8 * cs.alpha_mix + 0.2 * alpha
                };
            }
            cs.rounds += 1;
            cs.rounds == 1
                || (self.repartition_every > 0
                    && cs.rounds % self.repartition_every as u64 == 0)
        };
        if select_now {
            self.select_class_drafter(class, t_spec);
        }
    }

    /// Re-run the per-class drafter selection: score every registered
    /// drafter variant through the DSE ([`DrafterRegistry::select`]) at
    /// the class's per-drafter α evidence (optimistic class-mix fallback
    /// for unobserved variants), the class seq mix, the active tree-shape
    /// space and the KV load point; adopt the winner for the class's
    /// *future* admissions. `heterogeneous: false` pins the homogeneous
    /// mapping here exactly as it does for global re-partitioning.
    fn select_class_drafter(&self, class: RequestClass, t_spec: &crate::models::ModelSpec) {
        let reg = self.registry.lock().unwrap();
        let Some(reg) = reg.as_ref() else {
            return;
        };
        let (seq, fallback, drafter_alpha) = {
            let state = self.class_state.lock().unwrap();
            let Some(cs) = state.get(&class) else {
                return;
            };
            let seq = (cs.seq_mix.round() as usize).max(1);
            let fallback = if cs.alpha_mix.is_nan() {
                self.prior_alpha
            } else {
                cs.alpha_mix
            };
            (seq, fallback, cs.drafter_alpha.clone())
        };
        let shapes: &[TreeShape] = match self.tree_choice {
            TreeChoice::Auto => &dse::TREE_SHAPES,
            _ => &[],
        };
        let kv = *self.kv_load.lock().unwrap();
        let choice = reg.select(
            self.cost_model(),
            t_spec,
            self.target.scheme,
            self.design_variant,
            seq,
            shapes,
            kv.as_ref(),
            &|k| drafter_alpha.get(&k).copied().unwrap_or(fallback),
        );
        let mapping = if self.allow_hetero {
            choice.decision.mapping
        } else {
            Mapping::homogeneous(self.design_variant)
        };
        let mut state = self.class_state.lock().unwrap();
        let cs = state.entry(class).or_default();
        if cs.chosen != Some((choice.key, mapping)) {
            eprintln!(
                "[decision] class {}: drafter -> {} on {} (gamma* = {}, \
                 predicted S = {:.3}, model = {})",
                class.as_str(),
                choice.key.name(),
                mapping.label(),
                choice.decision.gamma,
                choice.decision.speedup,
                self.cost_model().name()
            );
            cs.chosen = Some((choice.key, mapping));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelSpec;

    fn specs() -> (ModelSpec, ModelSpec) {
        (
            ModelSpec {
                name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
                ffn_dim: 256, vocab: 48, param_count: 230_880,
            },
            ModelSpec {
                name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
                ffn_dim: 352, vocab: 48, param_count: 816_256,
            },
        )
    }

    fn policy(cfg: &RunConfig) -> Policy {
        Policy::new(cfg, Platform::imx95()).unwrap()
    }

    #[test]
    fn optimistic_prior_speculates_and_is_flagged() {
        let cfg = RunConfig::default();
        let p = policy(&cfg);
        let (d, t) = specs();
        let dec = p.route("translate", &d, &t, 63);
        assert!(dec.speculative);
        assert!(dec.gamma >= 3, "{dec:?}");
        assert!(dec.predicted_speedup > 1.3);
        // Zero observations: the decision is flagged (the worker mirrors
        // the flag into the metrics report).
        assert!(dec.used_prior);
        // After feedback the same task no longer rides the prior.
        p.observe_alpha("translate", 0.8);
        let dec = p.route("translate", &d, &t, 63);
        assert!(!dec.used_prior);
    }

    #[test]
    fn low_alpha_task_falls_back_to_baseline() {
        let cfg = RunConfig::default();
        let p = policy(&cfg);
        let (d, t) = specs();
        // Hammer the estimate down with rejections.
        for _ in 0..60 {
            p.observe_alpha("hard-task", 0.05);
        }
        let dec = p.route("hard-task", &d, &t, 63);
        assert!(!dec.speculative, "{dec:?}");
        // Other tasks keep the optimistic prior.
        assert!(p.route("translate", &d, &t, 63).speculative);
    }

    #[test]
    fn fixed_gamma_respected() {
        let cfg = RunConfig { gamma: Some(2), ..RunConfig::default() };
        let p = policy(&cfg);
        let (d, t) = specs();
        let dec = p.route("translate", &d, &t, 63);
        assert!(dec.speculative);
        assert_eq!(dec.gamma, 2);
        // Fixed γ also disables adaptation (and prior flagging — the
        // prior is the configuration, not a silent fallback).
        assert!(!dec.used_prior);
        p.observe_alpha("translate", 0.0);
        assert!((p.alpha_estimate("translate") - 0.90).abs() < 1e-12);
    }

    #[test]
    fn speculation_disabled_routes_baseline() {
        let cfg = RunConfig { speculative: false, ..RunConfig::default() };
        let p = policy(&cfg);
        let (d, t) = specs();
        let dec = p.route("translate", &d, &t, 63);
        assert!(!dec.speculative);
        assert_eq!(dec.gamma, 0);
        assert!(!dec.used_prior);
    }

    #[test]
    fn route_round_tracks_session_evidence() {
        let cfg = RunConfig::default();
        let p = policy(&cfg);
        let (d, t) = specs();
        // No evidence yet: identical to the admission decision.
        let admit = p.route("translate", &d, &t, 63);
        let m = p.current_mapping();
        let r0 = p.route_round("translate", &d, &t, m, 63, 0, f64::NAN);
        assert_eq!(admit, r0);
        // A collapsing in-flight α must never pick a larger γ than a
        // perfect one, and with heavy evidence it dominates the prior.
        let bad = p.route_round("translate", &d, &t, m, 63, 64, 0.0);
        let good = p.route_round("translate", &d, &t, m, 63, 64, 1.0);
        assert!(bad.gamma <= good.gamma, "{bad:?} vs {good:?}");
        assert!(bad.alpha_used < admit.alpha_used);
        assert!(good.alpha_used > admit.alpha_used);
        // Session evidence means the decision no longer rides the prior.
        assert!(!bad.used_prior && !good.used_prior);
    }

    #[test]
    fn route_round_respects_global_off_switch() {
        let cfg = RunConfig { speculative: false, ..RunConfig::default() };
        let p = policy(&cfg);
        let (d, t) = specs();
        let dec = p.route_round("translate", &d, &t, p.current_mapping(), 63, 10, 1.0);
        assert!(!dec.speculative);
        assert_eq!(dec.gamma, 0);
    }

    #[test]
    fn predicted_overlap_heterogeneous_only() {
        let (d, t) = specs();
        let het = policy(&RunConfig::default());
        let f = het.predicted_overlap(&d, &t, 5, 63);
        assert!(f > 0.0 && f <= 1.0, "{f}");
        // Homogeneous mapping: one timeline, nothing to overlap.
        let hom = policy(&RunConfig { heterogeneous: false, ..RunConfig::default() });
        assert_eq!(hom.predicted_overlap(&d, &t, 5, 63), 0.0);
        // No speculation, no draft/verify split.
        assert_eq!(het.predicted_overlap(&d, &t, 0, 63), 0.0);
    }

    #[test]
    fn spec_hints_clamp_but_never_widen() {
        let cfg = RunConfig::default();
        let p = policy(&cfg);
        let (d, t) = specs();
        let free = p.route("translate", &d, &t, 63);
        assert!(free.speculative && free.gamma >= 3);
        // A γ cap below the engine's choice clamps it.
        let capped = p.route_with(
            "translate", &d, &t, 63,
            SpecHints { gamma_cap: Some(2), force_off: false },
        );
        assert!(capped.speculative);
        assert_eq!(capped.gamma, 2);
        // A cap above the choice changes nothing.
        let loose = p.route_with(
            "translate", &d, &t, 63,
            SpecHints { gamma_cap: Some(free.gamma + 3), force_off: false },
        );
        assert_eq!(loose.gamma, free.gamma);
        // force_off and gamma_cap=0 both route to baseline.
        for hints in [
            SpecHints { gamma_cap: None, force_off: true },
            SpecHints { gamma_cap: Some(0), force_off: false },
        ] {
            let off = p.route_with("translate", &d, &t, 63, hints);
            assert!(!off.speculative, "{off:?}");
            assert_eq!(off.gamma, 0);
            assert!((off.predicted_speedup - 1.0).abs() < 1e-12);
        }
        // Hints never resurrect speculation the engine already rejected.
        let baseline = SpecHints::default().clamp(RouteDecision {
            speculative: false,
            gamma: 0,
            tree: None,
            mapping: p.current_mapping(),
            predicted_speedup: 1.0,
            alpha_used: f64::NAN,
            used_prior: false,
        });
        assert!(!baseline.speculative);
    }

    #[test]
    fn spec_hints_apply_per_round() {
        let cfg = RunConfig::default();
        let p = policy(&cfg);
        let (d, t) = specs();
        let m = p.current_mapping();
        // Even with perfect session evidence, the cap holds every round.
        let dec = p.route_round_with(
            "translate", &d, &t, m, 63, 64, 1.0,
            SpecHints { gamma_cap: Some(1), force_off: false },
        );
        assert!(dec.speculative);
        assert_eq!(dec.gamma, 1);
    }

    /// Boundary-bound platform (fast compute, expensive CPU dispatch):
    /// the regime where a wide shallow tree beats the chain at low α.
    fn boundary_bound_platform() -> Platform {
        let mut p = Platform::imx95();
        p.name = "imx95-npu-sim".into();
        p.cpu.peak_gflops_per_core *= 200.0;
        p.cpu.dispatch_overhead_s = 2e-3;
        p.gpu.peak_gflops *= 200.0;
        p.gpu.dispatch_overhead_s = 100e-6;
        p
    }

    #[test]
    fn tree_off_is_the_default_and_decisions_stay_chain() {
        let p = policy(&RunConfig::default());
        let (d, t) = specs();
        let dec = p.route("translate", &d, &t, 63);
        assert!(dec.speculative);
        assert_eq!(dec.tree, None);
    }

    #[test]
    fn fixed_tree_shape_forces_tree_speculation() {
        let cfg = RunConfig {
            tree: TreeChoice::Fixed(TreeShape::new(2, 3)),
            ..RunConfig::default()
        };
        let p = policy(&cfg);
        let (d, t) = specs();
        let dec = p.route("translate", &d, &t, 63);
        assert!(dec.speculative);
        assert_eq!(dec.tree, Some(TreeShape::new(2, 3)));
        assert_eq!(dec.gamma, 3);
        // A pinned 1-wide shape is the chain: γ = depth, no tree.
        let cfg = RunConfig {
            tree: TreeChoice::Fixed(TreeShape::new(1, 4)),
            ..RunConfig::default()
        };
        let p = policy(&cfg);
        let dec = p.route("translate", &d, &t, 63);
        assert!(dec.speculative);
        assert_eq!(dec.tree, None);
        assert_eq!(dec.gamma, 4);
    }

    #[test]
    fn monolithic_exec_pins_tree_off() {
        let cfg = RunConfig {
            tree: TreeChoice::Fixed(TreeShape::new(2, 3)),
            exec_mode: crate::config::ExecMode::Monolithic,
            ..RunConfig::default()
        };
        let p = policy(&cfg);
        let (d, t) = specs();
        let dec = p.route("translate", &d, &t, 63);
        assert_eq!(dec.tree, None);
    }

    #[test]
    fn auto_tree_picks_chain_on_compute_bound_platform() {
        // Stock i.MX95 lane compute dominates: auto must not pay k^d
        // lanes, and the decision is identical to tree: off.
        let cfg = RunConfig { tree: TreeChoice::Auto, ..RunConfig::default() };
        let auto = policy(&cfg);
        let off = policy(&RunConfig::default());
        let (d, t) = specs();
        for alpha_obs in [0.9, 0.3] {
            for p in [&auto, &off] {
                for _ in 0..40 {
                    p.observe_alpha("task", alpha_obs);
                }
            }
            let a = auto.route("task", &d, &t, 63);
            let o = off.route("task", &d, &t, 63);
            assert_eq!(a.tree, None, "alpha={alpha_obs}: {a:?}");
            assert_eq!(a.gamma, o.gamma);
            assert_eq!(a.speculative, o.speculative);
        }
    }

    #[test]
    fn auto_tree_speculates_where_the_chain_cannot() {
        // Boundary-bound platform at low α: the chain's best is weak, the
        // wide shallow tree's per-level acceptance β = 1−(1−α)^k wins.
        let cfg = RunConfig { tree: TreeChoice::Auto, ..RunConfig::default() };
        let p = Policy::new(&cfg, boundary_bound_platform()).unwrap();
        let chain =
            Policy::new(&RunConfig::default(), boundary_bound_platform()).unwrap();
        let (d, t) = specs();
        for pol in [&p, &chain] {
            for _ in 0..60 {
                pol.observe_alpha("hard", 0.15);
            }
        }
        let tree_dec = p.route("hard", &d, &t, 63);
        let chain_dec = chain.route("hard", &d, &t, 63);
        assert!(tree_dec.speculative, "{tree_dec:?}");
        let shape = tree_dec.tree.expect("expected a tree shape");
        assert!(shape.branches());
        assert_eq!(tree_dec.gamma, shape.depth);
        assert!(
            tree_dec.predicted_speedup > chain_dec.predicted_speedup + 1e-9,
            "tree {} vs chain {}",
            tree_dec.predicted_speedup,
            chain_dec.predicted_speedup
        );
    }

    #[test]
    fn hints_clamp_trees_too() {
        let cfg = RunConfig {
            tree: TreeChoice::Fixed(TreeShape::new(3, 3)),
            ..RunConfig::default()
        };
        let p = policy(&cfg);
        let (d, t) = specs();
        // force_off beats the pinned shape.
        let off = p.route_with(
            "translate", &d, &t, 63,
            SpecHints { gamma_cap: None, force_off: true },
        );
        assert!(!off.speculative);
        assert_eq!(off.tree, None);
        // A γ cap shrinks the tree's depth, never its width.
        let capped = p.route_with(
            "translate", &d, &t, 63,
            SpecHints { gamma_cap: Some(2), force_off: false },
        );
        assert!(capped.speculative);
        assert_eq!(capped.gamma, 2);
        assert_eq!(capped.tree, Some(TreeShape::new(3, 2)));
    }

    #[test]
    fn ewma_converges() {
        let cfg = RunConfig::default();
        let p = policy(&cfg);
        for _ in 0..100 {
            p.observe_alpha("t", 0.5);
        }
        assert!((p.alpha_estimate("t") - 0.5).abs() < 0.01);
    }

    #[test]
    fn bad_variant_keys_rejected_at_construction() {
        let cfg = RunConfig {
            drafter_variant: "target_w8a8".into(),
            ..RunConfig::default()
        };
        assert!(Policy::new(&cfg, Platform::imx95()).is_err());
        let cfg = RunConfig {
            target_variant: "not_a_key".into(),
            ..RunConfig::default()
        };
        assert!(Policy::new(&cfg, Platform::imx95()).is_err());
    }

    #[test]
    fn analytic_mode_never_repartitions() {
        let cfg = RunConfig { repartition_every: 2, ..RunConfig::default() };
        let p = policy(&cfg);
        let (d, t) = specs();
        let boot = p.current_mapping();
        for _ in 0..40 {
            p.observe_alpha("t", 0.05);
            p.route_round("t", &d, &t, boot, 63, 0, f64::NAN);
        }
        assert_eq!(p.current_mapping(), boot);
        assert_eq!(p.repartition_count(), 0);
    }

    #[test]
    fn calibrated_mode_repartitions_on_alpha_drift() {
        let cfg = RunConfig {
            decision: crate::config::DecisionMode::Calibrated,
            repartition_every: 4,
            ..RunConfig::default()
        };
        let p = policy(&cfg);
        let (d, t) = specs();
        assert!(p.current_mapping().is_heterogeneous());
        // Collapse α: speculation becomes infeasible at every candidate
        // mapping, so the search settles on the homogeneous no-spec route.
        // (Each consult passes the *current* mapping, as freshly admitted
        // sessions would.)
        for _ in 0..30 {
            p.observe_alpha("t", 0.02);
            p.route_round("t", &d, &t, p.current_mapping(), 63, 0, f64::NAN);
        }
        assert!(!p.current_mapping().is_heterogeneous(), "expected a mapping switch");
        assert!(p.repartition_count() >= 1);
        // New admissions get the switched mapping.
        let dec = p.route("t", &d, &t, 63);
        assert_eq!(dec.mapping, p.current_mapping());
        // Recovery: α climbs back, the heterogeneous mapping returns.
        for _ in 0..60 {
            p.observe_alpha("t", 0.95);
            p.route_round("t", &d, &t, p.current_mapping(), 63, 0, f64::NAN);
        }
        assert!(p.current_mapping().is_heterogeneous(), "expected a switch back");
        assert!(p.repartition_count() >= 2);
    }

    #[test]
    fn homogeneous_pin_disables_repartitioning() {
        let cfg = RunConfig {
            decision: crate::config::DecisionMode::Calibrated,
            repartition_every: 2,
            heterogeneous: false,
            ..RunConfig::default()
        };
        let p = policy(&cfg);
        let (d, t) = specs();
        // Healthy α would make the DSE search prefer the heterogeneous
        // mapping — but the operator pinned the homogeneous baseline.
        for _ in 0..20 {
            p.observe_alpha("t", 0.9);
            p.route_round("t", &d, &t, p.current_mapping(), 63, 0, f64::NAN);
        }
        assert!(!p.current_mapping().is_heterogeneous());
        assert_eq!(p.repartition_count(), 0);
    }

    /// Inline manifest with both drafter variants (the registry source
    /// for the auto-mode tests).
    fn registry_manifest() -> crate::runtime::manifest::Manifest {
        let j = crate::util::json::Json::parse(
            r#"{
          "tokenizer": {"specials":["<pad>","<bos>","<eos>","="],
                        "chars":" abcdefghijklmnopqrstuvwxyz.,?!-0123456789:'",
                        "vocab_size":48},
          "seq_buckets": [128], "batch_sizes": [1],
          "models": {
            "target": {"name":"target","n_layers":4,"d_model":128,"n_heads":4,
                       "ffn_dim":352,"vocab":48,"param_count":816256},
            "drafter": {"name":"drafter","n_layers":2,"d_model":96,"n_heads":4,
                        "ffn_dim":256,"vocab":48,"param_count":230880}
          },
          "variants": {
            "drafter_fp": {"role":"drafter","scheme":"fp","model":"drafter",
              "weights":"w_dfp.bin","tensors":[],"artifacts":[]},
            "drafter_w8a8": {"role":"drafter","scheme":"w8a8","model":"drafter",
              "weights":"w_dq.bin","tensors":[],"artifacts":[]},
            "target_w8a8": {"role":"target","scheme":"w8a8","model":"target",
              "weights":"w_tq.bin","tensors":[],"artifacts":[]}
          },
          "monolithic": [], "eval_samples": []}"#,
        )
        .unwrap();
        crate::runtime::manifest::Manifest::from_json(std::path::Path::new("/tmp"), &j)
            .unwrap()
    }

    #[test]
    fn drafter_aware_paths_match_fixed_mode_bit_for_bit() {
        let cfg = RunConfig::default();
        let p = policy(&cfg);
        let (d, t) = specs();
        let dk = p.variants().0;
        assert_eq!(p.drafter_mode(), DrafterMode::Fixed);
        assert_eq!(p.drafter_for("translate"), dk);
        for _ in 0..10 {
            p.observe_alpha_tagged("translate", dk, 0.7);
        }
        // Tagged feedback in fixed mode is exactly observe_alpha: compare
        // against a twin fed through the untagged path.
        let twin = policy(&cfg);
        for _ in 0..10 {
            twin.observe_alpha("translate", 0.7);
        }
        assert_eq!(
            p.alpha_estimate("translate").to_bits(),
            twin.alpha_estimate("translate").to_bits()
        );
        // Admission and round consults agree with the historical paths.
        let a = p.route_with("translate", &d, &t, 63, SpecHints::default());
        let b = p.route_with_drafter("translate", dk, &d, &t, 63, SpecHints::default());
        assert_eq!(a, b);
        let m = p.current_mapping();
        let r1 =
            p.route_round_with("translate", &d, &t, m, 63, 16, 0.6, SpecHints::default());
        let r2 = p.route_round_with_drafter(
            "translate", dk, &d, &t, m, 63, 16, 0.6, SpecHints::default(),
        );
        assert_eq!(r1, r2);
        // Fixed mode keeps zero per-class state.
        for class in RequestClass::all() {
            assert_eq!(p.chosen_drafter(class), None);
            assert_eq!(p.class_alpha_mix(class), None);
        }
    }

    #[test]
    fn auto_mode_settles_classes_on_different_drafters() {
        let cfg = RunConfig {
            drafter: DrafterMode::Auto,
            repartition_every: 4,
            ..RunConfig::default()
        };
        let p = policy(&cfg);
        p.set_drafter_registry(
            crate::scenario::DrafterRegistry::from_manifest(&registry_manifest()).unwrap(),
        );
        let (d, t) = specs();
        let fp = VariantKey::parse("drafter_fp").unwrap();
        let q = VariantKey::parse("drafter_w8a8").unwrap();
        // "translate" (Translate class): fp drafts well, quantized
        // collapses. "copy" (Chat class): the reverse.
        for _ in 0..30 {
            p.observe_alpha_tagged("translate", fp, 0.92);
            p.observe_alpha_tagged("translate", q, 0.05);
            p.observe_alpha_tagged("copy", fp, 0.05);
            p.observe_alpha_tagged("copy", q, 0.92);
            for task in ["translate", "copy"] {
                let dk = p.drafter_for(task);
                let m = p.mapping_for(task, dk);
                p.route_round_with_drafter(
                    task, dk, &d, &t, m, 63, 0, f64::NAN, SpecHints::default(),
                );
            }
        }
        assert_eq!(p.chosen_drafter(RequestClass::Translate), Some(fp));
        assert_eq!(p.chosen_drafter(RequestClass::Chat), Some(q));
        assert_eq!(p.drafter_for("translate"), fp);
        assert_eq!(p.drafter_for("copy"), q);
        // Unclassed tasks keep the configured default.
        assert_eq!(p.drafter_for("not-an-eval-task"), fp);
        // Per-class state exists for the consulted classes only.
        assert!(p.class_alpha_mix(RequestClass::Translate).is_some());
        assert!(p.class_alpha_mix(RequestClass::Chat).is_some());
        assert_eq!(p.class_alpha_mix(RequestClass::Summarize), None);
        // The two classes genuinely decide differently in one run.
        let dec_tr = p.route_with_drafter(
            "translate", p.drafter_for("translate"), &d, &t, 63, SpecHints::default(),
        );
        let dec_ch = p.route_with_drafter(
            "copy", p.drafter_for("copy"), &d, &t, 63, SpecHints::default(),
        );
        assert!(dec_tr.speculative && dec_ch.speculative);
    }

    #[test]
    fn auto_mode_without_registry_routes_like_fixed() {
        let cfg = RunConfig { drafter: DrafterMode::Auto, ..RunConfig::default() };
        let p = policy(&cfg);
        let (d, t) = specs();
        assert_eq!(p.drafter_mode(), DrafterMode::Auto);
        assert_eq!(p.drafter_for("translate"), p.variants().0);
        let dec = p.route_round_with_drafter(
            "translate",
            p.variants().0,
            &d,
            &t,
            p.current_mapping(),
            63,
            0,
            f64::NAN,
            SpecHints::default(),
        );
        assert!(dec.speculative);
        // Selection without candidates is a no-op; the class still tracks
        // its consult mixes.
        assert_eq!(p.chosen_drafter(RequestClass::Translate), None);
        assert!(p.class_alpha_mix(RequestClass::Translate).is_some());
    }

    #[test]
    fn route_round_prices_the_frozen_mapping_not_the_current_one() {
        let cfg = RunConfig {
            decision: crate::config::DecisionMode::Calibrated,
            repartition_every: 4,
            ..RunConfig::default()
        };
        let p = policy(&cfg);
        let (d, t) = specs();
        let frozen = p.current_mapping(); // heterogeneous at boot
        // Collapse α so the engine re-partitions away from the boot mapping.
        for _ in 0..30 {
            p.observe_alpha("t", 0.02);
            p.route_round("t", &d, &t, p.current_mapping(), 63, 0, f64::NAN);
        }
        assert_ne!(p.current_mapping(), frozen);
        // An in-flight session admitted on the old mapping is still priced
        // there: its decision carries the frozen mapping, and with strong
        // session evidence of a high α it keeps speculating on it.
        let dec = p.route_round("t", &d, &t, frozen, 63, 256, 0.95);
        assert_eq!(dec.mapping, frozen);
        assert!(dec.speculative, "{dec:?}");
    }
}
