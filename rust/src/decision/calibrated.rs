//! The calibrated cost model: the analytic prior, continuously refit from
//! the dispatch durations the executor actually observes.
//!
//! **Estimator.** Every observed dispatch obeys the same two-coefficient
//! law the analytic model assumes (see
//! [`LatencyModel::batched_forward_latency`]): a dispatch of `b` executed
//! lanes at bucket `s` costs
//!
//! ```text
//! duration = a · (b × flops(s)) + oh
//! ```
//!
//! where `a` is the variant's inverse effective throughput on that PU and
//! `oh` the PU's runtime-API dispatch boundary. Both are unknowns the
//! offline calibration may have gotten wrong (thermal throttling, DVFS,
//! contention, a mis-profiled board) — so per (variant, kernel, physical
//! PU) key the model keeps an *online least-squares fit* of observed
//! duration against the feature `x = lanes × flops(bucket)`: five running
//! sums (`n, Σx, Σy, Σx², Σxy`) give the closed-form slope/intercept at
//! any moment, in O(1) memory and time per observation. Per-bucket
//! observation counts are kept alongside for reporting.
//!
//! **Prediction.** A key predicts `a · flops(seq) + oh` once its fit is
//! well-conditioned (enough observations *and* genuine spread in `x` —
//! a single bucket at a single batch size cannot separate slope from
//! intercept). Until then the model falls back to the analytic prior, so
//! an empty or degenerate calibration state behaves exactly like
//! `decision: "analytic"`. When serving itself runs on the simulated
//! clock, the observed durations *are* analytic-model outputs and the fit
//! converges back onto the prior — calibration only changes decisions
//! when the measured platform genuinely deviates from the offline one.
//!
//! The store is keyed by [`PuId`] (the physical device), not the core
//! count: serving runs at one fixed design variant, so the CPU-cluster
//! coefficients it fits are those of the deployed core count.

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use crate::config::KernelPath;
use crate::hetero::{LatencyModel, Platform, PuAssignment, PuId};
use crate::models::{ModelSpec, Role, Scheme, VariantKey};

use super::model::{CostModel, DispatchObs};

/// Minimum observations before a fit may override the analytic prior.
const MIN_OBS: usize = 6;

/// Calibration store key: which compiled variant, through which kernel
/// lowering, on which physical PU.
type CalibKey = (VariantKey, KernelPath, PuId);

/// Online least-squares accumulator for one calibration key.
#[derive(Debug, Clone, Default)]
struct LaneFit {
    n: f64,
    sx: f64,
    sy: f64,
    sxx: f64,
    sxy: f64,
    /// Observations per sequence bucket (reporting only).
    buckets: BTreeMap<usize, u64>,
}

impl LaneFit {
    fn push(&mut self, bucket: usize, x: f64, y: f64) {
        self.n += 1.0;
        self.sx += x;
        self.sy += y;
        self.sxx += x * x;
        self.sxy += x * y;
        *self.buckets.entry(bucket).or_insert(0) += 1;
    }

    /// Fitted `(slope, intercept)` — `None` while under-observed or
    /// degenerate (all observations at one `x`, slope non-positive).
    fn coefficients(&self) -> Option<(f64, f64)> {
        if self.n < MIN_OBS as f64 {
            return None;
        }
        let den = self.n * self.sxx - self.sx * self.sx;
        // Relative conditioning check: with no spread in x the normal
        // equations are singular and slope/intercept cannot be separated.
        if den <= 1e-9 * self.n * self.sxx {
            return None;
        }
        let a = (self.n * self.sxy - self.sx * self.sy) / den;
        let b = (self.sy - a * self.sx) / self.n;
        if !a.is_finite() || !b.is_finite() || a <= 0.0 {
            return None;
        }
        Some((a, b.max(0.0)))
    }
}

/// Point-in-time calibration state (metrics command / diagnostics).
#[derive(Debug, Clone, Copy, Default)]
pub struct CalibrationReport {
    /// Keys with at least one observation.
    pub tracked_keys: usize,
    /// Keys whose fit is well-conditioned (actively overriding the prior).
    pub fitted_keys: usize,
    /// Total observations folded in.
    pub observations: u64,
}

/// The calibrated [`CostModel`]: analytic prior + online refit.
#[derive(Debug)]
pub struct CalibratedModel {
    analytic: LatencyModel,
    fits: Mutex<HashMap<CalibKey, LaneFit>>,
    observations: std::sync::atomic::AtomicU64,
}

impl CalibratedModel {
    pub fn new(analytic: LatencyModel) -> CalibratedModel {
        CalibratedModel {
            analytic,
            fits: Mutex::new(HashMap::new()),
            observations: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Fold one observed dispatch into the fit for its key. Returns
    /// whether the observation was accepted (malformed ones — zero lanes,
    /// non-positive duration or FLOPs — are dropped, uncounted).
    pub fn observe(&self, o: &DispatchObs) -> bool {
        if !o.duration_s.is_finite() || o.duration_s <= 0.0 || o.flops <= 0.0 || o.lanes == 0 {
            return false;
        }
        let x = o.lanes as f64 * o.flops;
        let key = (o.variant, o.kernel, o.pu.id());
        let mut fits = self.fits.lock().unwrap();
        fits.entry(key).or_default().push(o.bucket, x, o.duration_s);
        self.observations
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        true
    }

    pub fn report(&self) -> CalibrationReport {
        let fits = self.fits.lock().unwrap();
        CalibrationReport {
            tracked_keys: fits.len(),
            fitted_keys: fits.values().filter(|f| f.coefficients().is_some()).count(),
            observations: self.observations.load(std::sync::atomic::Ordering::Relaxed),
        }
    }

    /// Best well-conditioned fit for (variant, pu) across kernel lowerings
    /// — most observations, ties broken on the kernel ordering so the
    /// choice is deterministic — as `(slope, intercept)`.
    fn best_fit(&self, variant: VariantKey, pu: PuId) -> Option<(f64, f64)> {
        let fits = self.fits.lock().unwrap();
        let mut best: Option<(f64, KernelPath, f64, f64)> = None; // (n, kernel, a, b)
        for ((v, kernel, pid), fit) in fits.iter() {
            if *v != variant || *pid != pu {
                continue;
            }
            if let Some((a, b)) = fit.coefficients() {
                let better = match &best {
                    None => true,
                    Some((bn, bk, _, _)) => {
                        fit.n > *bn || (fit.n == *bn && *kernel < *bk)
                    }
                };
                if better {
                    best = Some((fit.n, *kernel, a, b));
                }
            }
        }
        best.map(|(_, _, a, b)| (a, b))
    }
}

impl CostModel for CalibratedModel {
    fn name(&self) -> &'static str {
        "calibrated"
    }

    fn platform(&self) -> &Platform {
        &self.analytic.platform
    }

    fn forward_latency(
        &self,
        spec: &ModelSpec,
        scheme: Scheme,
        pu: PuAssignment,
        seq_len: usize,
    ) -> f64 {
        // The manifest names specs "drafter"/"target" — the same convention
        // the platform efficiency tables key on.
        let role = if spec.name == "drafter" {
            Role::Drafter
        } else {
            Role::Target
        };
        match self.best_fit(VariantKey::new(role, scheme), pu.id()) {
            Some((a, b)) => a * spec.forward_flops(seq_len) + b,
            None => self.analytic.forward_latency(spec, scheme, pu, seq_len),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::Mapping;

    fn specs() -> (ModelSpec, ModelSpec) {
        (
            ModelSpec {
                name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
                ffn_dim: 256, vocab: 48, param_count: 230_880,
            },
            ModelSpec {
                name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
                ffn_dim: 352, vocab: 48, param_count: 816_256,
            },
        )
    }

    /// Feed observations of `truth`'s dispatch durations for one
    /// (variant, spec, scheme, pu) across buckets and lane counts.
    fn feed(
        model: &CalibratedModel,
        truth: &LatencyModel,
        variant: &str,
        spec: &ModelSpec,
        scheme: Scheme,
        pu: PuAssignment,
    ) {
        let v = VariantKey::parse(variant).unwrap();
        for _rep in 0..2 {
            for bucket in [16usize, 64, 128] {
                for lanes in [1usize, 4] {
                    model.observe(&DispatchObs {
                        variant: v,
                        kernel: KernelPath::Ref,
                        bucket,
                        pu,
                        lanes,
                        flops: spec.forward_flops(bucket),
                        duration_s: truth
                            .batched_forward_latency(spec, scheme, pu, bucket, lanes),
                    });
                }
            }
        }
    }

    #[test]
    fn empty_calibration_matches_analytic_exactly() {
        let analytic = LatencyModel::new(Platform::imx95());
        let m = CalibratedModel::new(analytic.clone());
        let (d, t) = specs();
        for seq in [16usize, 63, 128] {
            let a = analytic.cost_coefficient(
                (&d, Scheme::Fp), (&t, Scheme::W8a8), Mapping::heterogeneous(1), seq);
            let b = m.cost_coefficient(
                (&d, Scheme::Fp), (&t, Scheme::W8a8), Mapping::heterogeneous(1), seq);
            assert_eq!(a.to_bits(), b.to_bits(), "fallback must be bit-exact");
        }
        let r = m.report();
        assert_eq!(r.tracked_keys, 0);
        assert_eq!(r.observations, 0);
    }

    #[test]
    fn fit_recovers_a_perturbed_platform() {
        let analytic = LatencyModel::new(Platform::imx95());
        let mut p = Platform::imx95();
        p.gpu.peak_gflops *= 0.7; // the board runs 30% slower than profiled
        p.gpu.dispatch_overhead_s *= 1.3;
        let truth = LatencyModel::new(p);
        let m = CalibratedModel::new(analytic.clone());
        let (d, _) = specs();
        feed(&m, &truth, "drafter_fp", &d, Scheme::Fp, PuAssignment::Gpu);
        let fitted = m.forward_latency(&d, Scheme::Fp, PuAssignment::Gpu, 64);
        let want = truth.forward_latency(&d, Scheme::Fp, PuAssignment::Gpu, 64);
        let got_prior = analytic.forward_latency(&d, Scheme::Fp, PuAssignment::Gpu, 64);
        assert!(
            (fitted - want).abs() / want < 0.01,
            "fitted {fitted} vs true {want} (prior {got_prior})"
        );
        assert!(m.report().fitted_keys >= 1);
    }

    #[test]
    fn degenerate_observations_never_override_the_prior() {
        let analytic = LatencyModel::new(Platform::imx95());
        let m = CalibratedModel::new(analytic.clone());
        let (d, _) = specs();
        // Plenty of observations, but all at one (bucket, lanes): slope and
        // intercept are not separable — the fit must stay inert.
        for _ in 0..50 {
            m.observe(&DispatchObs {
                variant: VariantKey::parse("drafter_fp").unwrap(),
                kernel: KernelPath::Ref,
                bucket: 64,
                pu: PuAssignment::Gpu,
                lanes: 1,
                flops: d.forward_flops(64),
                duration_s: 123.0,
            });
        }
        let got = m.forward_latency(&d, Scheme::Fp, PuAssignment::Gpu, 64);
        let prior = analytic.forward_latency(&d, Scheme::Fp, PuAssignment::Gpu, 64);
        assert_eq!(got.to_bits(), prior.to_bits());
        let r = m.report();
        assert_eq!(r.tracked_keys, 1);
        assert_eq!(r.fitted_keys, 0);
        assert_eq!(r.observations, 50);
    }

    #[test]
    fn garbage_observations_are_dropped() {
        let analytic = LatencyModel::new(Platform::imx95());
        let m = CalibratedModel::new(analytic);
        let (d, _) = specs();
        for (lanes, dur) in [(0usize, 1.0), (1, f64::NAN), (1, -1.0), (1, 0.0)] {
            m.observe(&DispatchObs {
                variant: VariantKey::parse("drafter_fp").unwrap(),
                kernel: KernelPath::Ref,
                bucket: 64,
                pu: PuAssignment::Gpu,
                lanes,
                flops: d.forward_flops(64),
                duration_s: dur,
            });
        }
        assert_eq!(m.report().observations, 0);
    }
}
