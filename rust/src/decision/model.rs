//! The [`CostModel`] abstraction: what the decision layer needs from a
//! latency predictor, decoupled from *where the numbers come from*.
//!
//! Two implementations exist:
//!
//! * the **analytic** model — [`crate::hetero::LatencyModel`], the
//!   offline-calibrated FLOPs ÷ throughput + dispatch-boundary model the
//!   paper profiles once and then trusts (`decision: "analytic"`, the
//!   default);
//! * the **calibrated** model — [`super::CalibratedModel`], which starts
//!   from the analytic prior and continuously refits its per-(variant,
//!   kernel, PU) latency coefficients from the dispatch durations the
//!   executor actually observes (`decision: "calibrated"`), closing the
//!   predict → measure → correct loop the paper only runs offline.
//!
//! Everything downstream of the trait — Eq. (1) γ* search
//! ([`crate::costmodel`]), the DSE candidate enumeration ([`crate::dse`]),
//! and the online routing policy ([`super::Policy`]) — is generic over it,
//! so the same search code scores candidates against either model.

use crate::config::KernelPath;
use crate::hetero::{LatencyModel, Mapping, Platform, PuAssignment, PuRoute};
use crate::models::{ModelSpec, Role, Scheme, VariantKey};
use crate::spec::RequestKind;

/// A latency predictor the decision layer can score candidates against.
///
/// The contract mirrors the analytic [`LatencyModel`]: seconds for one
/// forward pass of a model on a PU at a padded sequence bucket, plus
/// access to the platform description (memory budget, INT8 support —
/// the DSE feasibility filters). The provided [`cost_coefficient`]
/// derives the paper's Fig. 6 quantity `c = t_draft / t_target` from two
/// forward predictions, so every implementation prices mappings the same
/// way it prices forwards.
///
/// Implementations that key state by model *role* identify it by the
/// crate-wide manifest convention `spec.name == "drafter"` / `"target"` —
/// the same convention [`Platform::cpu_eff`] dispatches its efficiency
/// tables on.
///
/// [`cost_coefficient`]: CostModel::cost_coefficient
pub trait CostModel: Send + Sync {
    /// Short identifier for logs and the metrics command.
    fn name(&self) -> &'static str;

    /// The platform this model predicts for (feasibility filters only;
    /// the *latencies* come from `forward_latency`).
    fn platform(&self) -> &Platform;

    /// Predicted seconds for one forward of `spec` (scheme-quantized) on
    /// `pu` at `seq_len`, including one runtime-API dispatch boundary.
    fn forward_latency(
        &self,
        spec: &ModelSpec,
        scheme: Scheme,
        pu: PuAssignment,
        seq_len: usize,
    ) -> f64;

    /// Cost coefficient c = t_draft / t_target for a mapping at `seq_len`
    /// (paper Fig. 6), derived from two forward predictions.
    fn cost_coefficient(
        &self,
        drafter: (&ModelSpec, Scheme),
        target: (&ModelSpec, Scheme),
        mapping: Mapping,
        seq_len: usize,
    ) -> f64 {
        let td = self.forward_latency(drafter.0, drafter.1, mapping.drafter, seq_len);
        let tt = self.forward_latency(target.0, target.1, mapping.target, seq_len);
        td / tt
    }

    /// Predicted seconds for one `batch`-lane dispatch: lane-linear
    /// compute with a *single* dispatch boundary, derived from the
    /// single-forward prediction and the platform's per-PU boundary cost —
    /// the quantity the tree-shape search prices level expansions and the
    /// flattened verification with. [`LatencyModel`] overrides this with
    /// its inherent (bit-identical) implementation; the calibrated model
    /// inherits the default, so its online-refit forward latencies feed
    /// the same tree-vs-chain choice.
    fn batched_forward_latency(
        &self,
        spec: &ModelSpec,
        scheme: Scheme,
        pu: PuAssignment,
        seq_len: usize,
        batch: usize,
    ) -> f64 {
        let single = self.forward_latency(spec, scheme, pu, seq_len);
        let oh = match pu {
            PuAssignment::Gpu => self.platform().gpu.dispatch_overhead_s,
            PuAssignment::Cpu { .. } => self.platform().cpu.dispatch_overhead_s,
        };
        (single - oh) * batch.max(1) as f64 + oh
    }

    /// Memory-traffic term of a KV-cache hit: seconds to re-read `cached`
    /// resident tokens of `spec`'s K/V at the platform's DRAM bandwidth.
    /// The default derives it purely from the platform description —
    /// predictors refit compute latencies online, but KV bytes are
    /// geometry, not something the dispatch feed observes — so analytic
    /// and calibrated models price residency identically.
    fn kv_read_latency(&self, spec: &ModelSpec, scheme: Scheme, cached: usize) -> f64 {
        let mem = &self.platform().memory;
        let bytes = crate::kvcache::kv_bytes_per_token(spec, scheme, mem) * cached as f64;
        bytes / (mem.dram_gbps * 1e9)
    }

    /// Predicted seconds of one *incremental* forward at `seq_len` with
    /// `cached` tokens of resident KV: compute scales to the new fraction
    /// of positions, the resident fraction pays the DRAM re-read term, one
    /// dispatch boundary. The cache-hit counterpart of
    /// [`forward_latency`](CostModel::forward_latency), used by the fuser
    /// and session pricing whenever `kv_cache: on` sessions carry resident
    /// prefixes (cache-off and cache-cold dispatches never route through
    /// here, keeping `kv_cache: off` bit-identical by construction).
    fn incremental_forward_latency(
        &self,
        spec: &ModelSpec,
        scheme: Scheme,
        pu: PuAssignment,
        seq_len: usize,
        cached: usize,
    ) -> f64 {
        let single = self.forward_latency(spec, scheme, pu, seq_len);
        let oh = match pu {
            PuAssignment::Gpu => self.platform().gpu.dispatch_overhead_s,
            PuAssignment::Cpu { .. } => self.platform().cpu.dispatch_overhead_s,
        };
        let cached = cached.min(seq_len);
        let new_frac = (seq_len - cached) as f64 / seq_len.max(1) as f64;
        (single - oh) * new_frac + self.kv_read_latency(spec, scheme, cached) + oh
    }
}

/// The analytic model is the canonical implementation: the trait methods
/// delegate to the inherent ones, so scoring through `dyn CostModel` is
/// bit-identical to calling [`LatencyModel`] directly.
impl CostModel for LatencyModel {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn platform(&self) -> &Platform {
        &self.platform
    }

    fn forward_latency(
        &self,
        spec: &ModelSpec,
        scheme: Scheme,
        pu: PuAssignment,
        seq_len: usize,
    ) -> f64 {
        LatencyModel::forward_latency(self, spec, scheme, pu, seq_len)
    }

    fn batched_forward_latency(
        &self,
        spec: &ModelSpec,
        scheme: Scheme,
        pu: PuAssignment,
        seq_len: usize,
        batch: usize,
    ) -> f64 {
        LatencyModel::batched_forward_latency(self, spec, scheme, pu, seq_len, batch)
    }

    fn kv_read_latency(&self, spec: &ModelSpec, scheme: Scheme, cached: usize) -> f64 {
        LatencyModel::kv_read_latency(self, spec, scheme, cached)
    }

    fn incremental_forward_latency(
        &self,
        spec: &ModelSpec,
        scheme: Scheme,
        pu: PuAssignment,
        seq_len: usize,
        cached: usize,
    ) -> f64 {
        LatencyModel::incremental_forward_latency(self, spec, scheme, pu, seq_len, cached)
    }
}

/// Predicted seconds of one *local* modular speculation round under
/// `mapping`: γ drafter forwards plus one target verification, each with
/// its dispatch boundary — the quantity the fleet tier compares against
/// the pipelined cloud-verify round
/// ([`crate::costmodel::collaborative_round_latency`]) when it places a
/// request's verify, and the service-time term of its device placement
/// score. γ = 0 prices one baseline (non-speculative) target step.
pub fn round_latency(
    cost: &dyn CostModel,
    drafter: (&ModelSpec, Scheme),
    target: (&ModelSpec, Scheme),
    mapping: Mapping,
    gamma: usize,
    seq_len: usize,
) -> f64 {
    let draft = if gamma > 0 {
        gamma as f64 * cost.forward_latency(drafter.0, drafter.1, mapping.drafter, seq_len)
    } else {
        0.0
    };
    draft + cost.forward_latency(target.0, target.1, mapping.target, seq_len)
}

/// One executed dispatch, as observed by the executor — the calibration
/// feed. `duration_s` is the full dispatch duration (all `lanes` executed
/// lanes, one boundary), `flops` the single-lane FLOPs at `bucket`, so the
/// estimator's regression feature is `lanes × flops`.
#[derive(Debug, Clone, Copy)]
pub struct DispatchObs {
    pub variant: VariantKey,
    pub kernel: KernelPath,
    /// Padded sequence bucket the dispatch ran at.
    pub bucket: usize,
    /// PU assignment the dispatch was routed to.
    pub pu: PuAssignment,
    /// Executed lanes (batch size, padding included).
    pub lanes: usize,
    /// Single-lane forward FLOPs at `bucket` (the model-side feature).
    pub flops: f64,
    /// Observed duration of the whole dispatch, seconds.
    pub duration_s: f64,
}

/// Resolve which PU timeline(s) a planned engine call occupies under
/// `mapping` — the single route-resolution rule, shared by every session
/// (`DecodeSession::plan` calls this): plain forwards run on the PU the
/// mapping assigns to the planned variant's role; a monolithic fused
/// spec-step is charged to the target PU and blocks the drafter PU when
/// that is a different device.
pub fn resolve_route(mapping: Mapping, kind: &RequestKind) -> PuRoute {
    match kind {
        RequestKind::Forward { variant, .. } | RequestKind::TreeForward { variant, .. } => {
            PuRoute::single(match variant.role {
                Role::Drafter => mapping.drafter,
                Role::Target => mapping.target,
            })
        }
        RequestKind::MonoStep { .. } => PuRoute::mono(mapping),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> (ModelSpec, ModelSpec) {
        (
            ModelSpec {
                name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
                ffn_dim: 256, vocab: 48, param_count: 230_880,
            },
            ModelSpec {
                name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
                ffn_dim: 352, vocab: 48, param_count: 816_256,
            },
        )
    }

    #[test]
    fn analytic_trait_is_bit_identical_to_inherent() {
        let lat = LatencyModel::new(Platform::imx95());
        let (d, t) = specs();
        let as_trait: &dyn CostModel = &lat;
        for seq in [16usize, 63, 128] {
            for pu in [PuAssignment::Gpu, PuAssignment::Cpu { cores: 2 }] {
                let a = lat.forward_latency(&d, Scheme::Fp, pu, seq);
                let b = as_trait.forward_latency(&d, Scheme::Fp, pu, seq);
                assert_eq!(a.to_bits(), b.to_bits());
            }
            let m = Mapping::heterogeneous(1);
            let a = lat.cost_coefficient((&d, Scheme::Fp), (&t, Scheme::W8a8), m, seq);
            let b = as_trait.cost_coefficient((&d, Scheme::Fp), (&t, Scheme::W8a8), m, seq);
            assert_eq!(a.to_bits(), b.to_bits());
            for lanes in [1usize, 4, 9] {
                let a = lat.batched_forward_latency(&t, Scheme::W8a8, m.target, seq, lanes);
                let b = as_trait.batched_forward_latency(&t, Scheme::W8a8, m.target, seq, lanes);
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for cached in [0usize, 16, seq] {
                let a = lat.kv_read_latency(&t, Scheme::W8a8, cached);
                let b = as_trait.kv_read_latency(&t, Scheme::W8a8, cached);
                assert_eq!(a.to_bits(), b.to_bits());
                let a = lat.incremental_forward_latency(&t, Scheme::W8a8, m.target, seq, cached);
                let b =
                    as_trait.incremental_forward_latency(&t, Scheme::W8a8, m.target, seq, cached);
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        assert_eq!(as_trait.name(), "analytic");
        assert_eq!(as_trait.platform().name, "imx95-sim");
    }

    #[test]
    fn round_latency_is_gamma_drafts_plus_one_verify() {
        let lat = LatencyModel::new(Platform::imx95());
        let (d, t) = specs();
        let m = Mapping::heterogeneous(2);
        let seq = 64;
        let draft = lat.forward_latency(&d, Scheme::Fp, m.drafter, seq);
        let verify = lat.forward_latency(&t, Scheme::W8a8, m.target, seq);
        for gamma in 0..=6usize {
            let got = round_latency(&lat, (&d, Scheme::Fp), (&t, Scheme::W8a8), m, gamma, seq);
            let want = gamma as f64 * draft + verify;
            assert!((got - want).abs() < 1e-12, "gamma={gamma}: {got} vs {want}");
        }
        // Monotone in gamma: each extra draft step costs real time.
        let r1 = round_latency(&lat, (&d, Scheme::Fp), (&t, Scheme::W8a8), m, 1, seq);
        let r4 = round_latency(&lat, (&d, Scheme::Fp), (&t, Scheme::W8a8), m, 4, seq);
        assert!(r4 > r1);
    }

    #[test]
    fn route_resolution_follows_the_mapping() {
        let m = Mapping::heterogeneous(2);
        let fwd_d = RequestKind::Forward {
            variant: VariantKey::parse("drafter_fp").unwrap(),
            kernel: KernelPath::Ref,
            bucket: 64,
        };
        let fwd_t = RequestKind::Forward {
            variant: VariantKey::parse("target_w8a8").unwrap(),
            kernel: KernelPath::Ref,
            bucket: 64,
        };
        assert_eq!(resolve_route(m, &fwd_d), PuRoute::single(PuAssignment::Gpu));
        assert_eq!(
            resolve_route(m, &fwd_t),
            PuRoute::single(PuAssignment::Cpu { cores: 2 })
        );
        // Tree dispatches route exactly like plain forwards of their role.
        let tree_t = RequestKind::TreeForward {
            variant: VariantKey::parse("target_w8a8").unwrap(),
            kernel: KernelPath::Ref,
            bucket: 64,
            lanes: 8,
        };
        assert_eq!(resolve_route(m, &tree_t), resolve_route(m, &fwd_t));
        assert_eq!(resolve_route(m, &RequestKind::MonoStep { gamma: 3 }), PuRoute::mono(m));
    }
}
