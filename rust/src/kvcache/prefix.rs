//! Copy-on-write prefix cache: a refcounted trie over token-chunk keys.
//!
//! Nodes are keyed on *full chunks* of [`chunk_tokens`] prompt tokens plus
//! the session's frozen PU [`Mapping`] — a prefix cached for one mapping
//! is never attached to a session whose KV pages must live on different
//! PUs (online re-partitioning changes the mapping between admissions, so
//! the mapping is part of the key, not an invariant). Each node owns one
//! drafter page (on the mapping's drafter PU) and one target page (on the
//! target PU); `chunk_tokens` is sized so one page per role covers one
//! chunk for both models ([`super::KvLayout`]).
//!
//! Refcounts are session-level: `attach` bumps every node on the matched
//! path, `detach` drops them. A node at zero refs stays *cached* — its
//! pages remain allocated so the next request sharing the prefix attaches
//! for free — until allocation pressure evicts it (deepest-first, leaves
//! before ancestors, via [`PrefixCache::evict_one`]). Writes into a
//! shared node's pages go through [`PrefixCache::cow_page`], which hands
//! back a private copy whenever the node is shared — the original page id
//! is never surrendered to a writer, the invariant the trie proptests pin
//! ("COW never mutates a shared page").
//!
//! [`chunk_tokens`]: PrefixCache::chunk_tokens

use crate::hetero::{Mapping, PuId};
use crate::models::Role;

use super::alloc::{PageAllocator, PageId};

/// Arena index of a trie node.
pub type NodeId = usize;

#[derive(Debug, Clone)]
struct Node {
    chunk: Vec<u32>,
    mapping: Mapping,
    parent: Option<NodeId>,
    children: Vec<NodeId>,
    /// KV pages for this chunk: drafter-role page on
    /// `mapping.drafter.id()`, target-role page on `mapping.target.id()`.
    page_d: PageId,
    page_t: PageId,
    /// Sessions currently attached through this node.
    refs: usize,
    /// Root = 1 (depth in chunks; eviction prefers deeper nodes).
    depth: usize,
}

/// Result of [`PrefixCache::attach`].
#[derive(Debug, Clone, Default)]
pub struct Attach {
    /// Matched nodes, root-first; refcounts already bumped.
    pub path: Vec<NodeId>,
    /// Tokens covered by the matched path (`path.len() × chunk_tokens`).
    pub tokens: usize,
}

/// The prefix trie. Pages are owned by nodes; the allocator is passed in
/// only where pages change hands (eviction, COW copies).
#[derive(Debug, Clone)]
pub struct PrefixCache {
    chunk_tokens: usize,
    nodes: Vec<Option<Node>>,
    free_slots: Vec<NodeId>,
    roots: Vec<NodeId>,
}

impl PrefixCache {
    pub fn new(chunk_tokens: usize) -> PrefixCache {
        assert!(chunk_tokens >= 1, "chunk_tokens must be >= 1");
        PrefixCache { chunk_tokens, nodes: Vec::new(), free_slots: Vec::new(), roots: Vec::new() }
    }

    /// Tokens per trie chunk (= per node, = per page-pair).
    pub fn chunk_tokens(&self) -> usize {
        self.chunk_tokens
    }

    /// Live (non-evicted) node count.
    pub fn len(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn node(&self, id: NodeId) -> &Node {
        self.nodes[id].as_ref().expect("evicted node id")
    }

    fn node_mut(&mut self, id: NodeId) -> &mut Node {
        self.nodes[id].as_mut().expect("evicted node id")
    }

    /// Session refcount of a node (test/metrics surface).
    pub fn refs(&self, id: NodeId) -> usize {
        self.node(id).refs
    }

    /// The node's (drafter, target) page pair (test surface).
    pub fn pages(&self, id: NodeId) -> (PageId, PageId) {
        let n = self.node(id);
        (n.page_d, n.page_t)
    }

    /// Walk `tokens`' full chunks down the trie, matching children by
    /// (chunk, mapping); bump refcounts along the match. The partial tail
    /// chunk never matches (pages cover whole chunks only).
    pub fn attach(&mut self, tokens: &[u32], mapping: Mapping) -> Attach {
        let mut out = Attach::default();
        let mut level: &[NodeId] = &self.roots;
        for chunk in tokens.chunks_exact(self.chunk_tokens) {
            let hit = level.iter().copied().find(|&id| {
                let n = self.node(id);
                n.mapping == mapping && n.chunk == chunk
            });
            match hit {
                Some(id) => {
                    out.path.push(id);
                    level = &self.node(id).children;
                }
                None => break,
            }
        }
        for &id in &out.path {
            self.node_mut(id).refs += 1;
        }
        out.tokens = out.path.len() * self.chunk_tokens;
        out
    }

    /// Insert one chunk node under `parent` (`None` = a new root) holding
    /// the given page pair, with one session reference. Returns its id.
    /// The caller guarantees no equal (chunk, mapping) sibling exists —
    /// i.e. it ran [`attach`](Self::attach) first and is inserting the
    /// unmatched remainder.
    pub fn insert(
        &mut self,
        parent: Option<NodeId>,
        chunk: &[u32],
        mapping: Mapping,
        page_d: PageId,
        page_t: PageId,
    ) -> NodeId {
        debug_assert_eq!(chunk.len(), self.chunk_tokens);
        let depth = parent.map_or(1, |p| self.node(p).depth + 1);
        let node = Node {
            chunk: chunk.to_vec(),
            mapping,
            parent,
            children: Vec::new(),
            page_d,
            page_t,
            refs: 1,
            depth,
        };
        let id = match self.free_slots.pop() {
            Some(slot) => {
                self.nodes[slot] = Some(node);
                slot
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        };
        match parent {
            Some(p) => self.node_mut(p).children.push(id),
            None => self.roots.push(id),
        }
        id
    }

    /// Drop one session reference from every node on `path`. Nodes
    /// reaching zero refs stay cached (retention) until evicted.
    pub fn detach(&mut self, path: &[NodeId]) {
        for &id in path {
            let n = self.node_mut(id);
            debug_assert!(n.refs > 0, "detach of an unreferenced node");
            n.refs = n.refs.saturating_sub(1);
        }
    }

    /// Evict one cached (refs = 0, childless) node — the deepest such
    /// node, so subtrees drain leaves-first and shared short prefixes
    /// survive longest. Its pages are returned to `alloc`. `Some(pages)`
    /// when a node was evicted, `None` when nothing is evictable.
    pub fn evict_one(&mut self, alloc: &mut PageAllocator) -> anyhow::Result<Option<usize>> {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(id, n)| n.as_ref().map(|n| (id, n)))
            .filter(|(_, n)| n.refs == 0 && n.children.is_empty())
            .max_by_key(|(id, n)| (n.depth, *id))
            .map(|(id, _)| id);
        let Some(id) = victim else { return Ok(None) };
        let node = self.nodes[id].take().expect("victim just observed live");
        match node.parent {
            Some(p) => self.node_mut(p).children.retain(|&c| c != id),
            None => self.roots.retain(|&r| r != id),
        }
        self.free_slots.push(id);
        alloc.release(node.mapping.drafter.id(), &[node.page_d])?;
        alloc.release(node.mapping.target.id(), &[node.page_t])?;
        Ok(Some(2))
    }

    /// Targeted eviction for reap paths: evict `id` *if* it is cached
    /// (refs = 0) and childless, returning the page count freed. `None`
    /// when the node is still referenced, still a parent, or already
    /// evicted — the caller stops reclaiming there.
    pub fn evict_if_unused(
        &mut self,
        id: NodeId,
        alloc: &mut PageAllocator,
    ) -> anyhow::Result<Option<usize>> {
        let evictable = matches!(
            self.nodes.get(id).and_then(Option::as_ref),
            Some(n) if n.refs == 0 && n.children.is_empty()
        );
        if !evictable {
            return Ok(None);
        }
        let node = self.nodes[id].take().expect("checked live above");
        match node.parent {
            Some(p) => self.node_mut(p).children.retain(|&c| c != id),
            None => self.roots.retain(|&r| r != id),
        }
        self.free_slots.push(id);
        alloc.release(node.mapping.drafter.id(), &[node.page_d])?;
        alloc.release(node.mapping.target.id(), &[node.page_t])?;
        Ok(Some(2))
    }

    /// Copy-on-write entry for writing into a node's `role` page: a node
    /// held by at most one session hands out its own page (in-place write
    /// is safe); a *shared* node never does — the writer gets a freshly
    /// allocated private copy on the same PU and owns it. Returns
    /// `(page, copied)`; `Err` when the pool can't supply the copy.
    pub fn cow_page(
        &mut self,
        id: NodeId,
        role: Role,
        alloc: &mut PageAllocator,
    ) -> anyhow::Result<(PageId, bool)> {
        let n = self.node(id);
        let (pu, page) = match role {
            Role::Drafter => (n.mapping.drafter.id(), n.page_d),
            Role::Target => (n.mapping.target.id(), n.page_t),
        };
        if n.refs <= 1 {
            return Ok((page, false));
        }
        let copy = alloc
            .alloc(pu, 1)
            .ok_or_else(|| anyhow::anyhow!("no page for COW copy on {}", pu.label()))?;
        Ok((copy[0], true))
    }

    /// Pages currently held by trie nodes on `pu` (occupancy accounting /
    /// proptest conservation checks).
    pub fn pages_held(&self, pu: PuId) -> usize {
        self.nodes
            .iter()
            .flatten()
            .map(|n| {
                usize::from(n.mapping.drafter.id() == pu) + usize::from(n.mapping.target.id() == pu)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::PuId;

    fn cache_and_alloc() -> (PrefixCache, PageAllocator) {
        (PrefixCache::new(4), PageAllocator::new(32, 32))
    }

    /// Allocate a page pair for one chunk under `m`.
    fn pair(alloc: &mut PageAllocator, m: Mapping) -> (PageId, PageId) {
        let d = alloc.alloc(m.drafter.id(), 1).unwrap()[0];
        let t = alloc.alloc(m.target.id(), 1).unwrap()[0];
        (d, t)
    }

    #[test]
    fn attach_matches_full_chunks_for_the_same_mapping_only() {
        let (mut c, mut a) = cache_and_alloc();
        let het = Mapping::heterogeneous(1);
        let hom = Mapping::homogeneous(1);
        let toks: Vec<u32> = (0..8).collect();
        let (d0, t0) = pair(&mut a, het);
        let root = c.insert(None, &toks[..4], het, d0, t0);
        let (d1, t1) = pair(&mut a, het);
        c.insert(Some(root), &toks[4..8], het, d1, t1);

        // Same mapping: both chunks match; the partial tail (2 tokens)
        // does not.
        let hit = c.attach(&(0..10).collect::<Vec<u32>>(), het);
        assert_eq!(hit.path.len(), 2);
        assert_eq!(hit.tokens, 8);
        assert_eq!(c.refs(root), 2); // inserter + attacher
        // Different mapping: no match at all.
        let miss = c.attach(&toks, hom);
        assert!(miss.path.is_empty());
        // Diverging second chunk: only the shared root matches.
        let mut fork = toks.clone();
        fork[5] = 99;
        let part = c.attach(&fork, het);
        assert_eq!(part.path.len(), 1);
        c.detach(&hit.path);
        c.detach(&part.path);
        assert_eq!(c.refs(root), 1);
    }

    #[test]
    fn eviction_is_deepest_first_and_returns_pages() {
        let (mut c, mut a) = cache_and_alloc();
        let m = Mapping::heterogeneous(1);
        let toks: Vec<u32> = (0..8).collect();
        let (d0, t0) = pair(&mut a, m);
        let root = c.insert(None, &toks[..4], m, d0, t0);
        let (d1, t1) = pair(&mut a, m);
        let leaf = c.insert(Some(root), &toks[4..8], m, d1, t1);
        c.detach(&[root, leaf]);
        let used_before = a.used(m.drafter.id()) + a.used(m.target.id());

        // First eviction takes the leaf (deeper), not the root.
        assert_eq!(c.evict_one(&mut a).unwrap(), Some(2));
        assert_eq!(c.len(), 1);
        assert_eq!(c.refs(root), 0); // root survives, still cached
        assert_eq!(c.evict_one(&mut a).unwrap(), Some(2));
        assert!(c.is_empty());
        assert!(c.evict_one(&mut a).unwrap().is_none());
        let used_after = a.used(m.drafter.id()) + a.used(m.target.id());
        assert_eq!(used_before - used_after, 4);
    }

    #[test]
    fn referenced_or_parent_nodes_are_not_evictable() {
        let (mut c, mut a) = cache_and_alloc();
        let m = Mapping::homogeneous(2);
        let (d0, t0) = pair(&mut a, m);
        let root = c.insert(None, &[1, 2, 3, 4], m, d0, t0);
        let (d1, t1) = pair(&mut a, m);
        let leaf = c.insert(Some(root), &[5, 6, 7, 8], m, d1, t1);
        // Leaf still referenced, root has a child: nothing evictable.
        c.detach(&[root]);
        // root refs=0 but has a live child; leaf refs=1.
        assert!(c.evict_one(&mut a).unwrap().is_none());
        c.detach(&[leaf]);
        assert_eq!(c.evict_one(&mut a).unwrap(), Some(2));
    }

    #[test]
    fn cow_never_surrenders_a_shared_page() {
        let (mut c, mut a) = cache_and_alloc();
        let m = Mapping::heterogeneous(1);
        let (d0, t0) = pair(&mut a, m);
        let root = c.insert(None, &[1, 2, 3, 4], m, d0, t0);
        // Sole owner: in-place write, same page.
        let (p, copied) = c.cow_page(root, Role::Target, &mut a).unwrap();
        assert_eq!((p, copied), (t0, false));
        // Shared (second attacher): the writer gets a fresh page and the
        // node keeps its own.
        let hit = c.attach(&[1, 2, 3, 4], m);
        assert_eq!(c.refs(root), 2);
        let (p, copied) = c.cow_page(root, Role::Target, &mut a).unwrap();
        assert!(copied && p != t0);
        assert_eq!(c.pages(root), (d0, t0));
        let (pd, copied) = c.cow_page(root, Role::Drafter, &mut a).unwrap();
        assert!(copied && pd != d0);
        c.detach(&hit.path);
    }

    #[test]
    fn pages_held_counts_both_roles_per_pu() {
        let (mut c, mut a) = cache_and_alloc();
        let het = Mapping::heterogeneous(1);
        let (d0, t0) = pair(&mut a, het);
        c.insert(None, &[1, 2, 3, 4], het, d0, t0);
        assert_eq!(c.pages_held(PuId::Gpu), 1); // drafter page
        assert_eq!(c.pages_held(PuId::Cpu), 1); // target page
        let hom = Mapping::homogeneous(1);
        let (d1, t1) = pair(&mut a, hom);
        c.insert(None, &[9, 9, 9, 9], hom, d1, t1);
        assert_eq!(c.pages_held(PuId::Cpu), 3);
    }
}
