//! Paged KV-cache with cross-request prefix sharing.
//!
//! Today's engine recomputes attention over the whole bucketed sequence on
//! every forward; this subsystem makes the KV working set a first-class,
//! *bounded* resource so the serving stack can (a) price draft/verify
//! rounds incrementally — only *new* tokens pay compute, resident KV pays
//! a DRAM-read term ([`crate::hetero::LatencyModel::incremental_lane_cost`])
//! — and (b) pay prefill once across requests sharing a prompt prefix.
//!
//! Three layers:
//!
//! * [`PageAllocator`] — fixed-size pages in per-PU pools whose capacities
//!   come from the platform JSON (`memory.kv_pages_cpu` / `kv_pages_gpu`);
//!   explicit page identity, so double frees are detected.
//! * [`PrefixCache`] — a copy-on-write trie over full token chunks,
//!   refcounted per attached session, with zero-ref retention and
//!   deepest-first eviction under pressure.
//! * [`KvManager`] — one per worker: admission-time reservation of the
//!   whole session budget (prompt + generation window), prefix attach,
//!   release on retire/cancel/deadline-reap, and the [`KvStats`] the
//!   metrics registry aggregates.
//!
//! Sizing rule: one trie chunk is [`KvLayout::chunk_tokens`] tokens,
//! chosen as the *largest* token count whose K/V fits one page for **both**
//! models of the serving pair — so every chunk owns exactly one page per
//! role and page accounting stays integral. The per-token KV footprint is
//! `2 × n_layers × d_model × bytes(scheme)` ([`kv_bytes_per_token`]),
//! using the engine's real (simulation-scale) model dimensions — the
//! *paper-scale* weight footprints in
//! [`MemoryModel`](crate::hetero::platform::MemoryModel) gate weight
//! residency, while KV pages gate the live working set.
//!
//! The design-space search treats page capacity as a feasibility filter
//! ([`crate::dse::KvLoad`]): mappings whose in-flight KV working set
//! exceeds a PU's pool are rejected like the paper's weight-memory
//! exclusions. Everything here is gated behind the `kv_cache: off|on`
//! config knob; `off` (the default) never constructs a manager and is
//! bit-identical to the historical engine.

pub mod alloc;
pub mod prefix;

pub use alloc::{PageAllocator, PageId};
pub use prefix::{Attach, NodeId, PrefixCache};

use crate::hetero::platform::MemoryModel;
use crate::hetero::{Mapping, PuId, NUM_PUS};
use crate::models::{ModelSpec, Scheme};

/// Bytes of K + V one token occupies for `spec` under `scheme`:
/// `2 × n_layers × d_model × bytes_per_element`.
pub fn kv_bytes_per_token(spec: &ModelSpec, scheme: Scheme, mem: &MemoryModel) -> f64 {
    2.0 * spec.n_layers as f64 * spec.d_model as f64 * mem.scheme_bytes(scheme)
}

/// Tokens of `spec`'s KV that fit one page (at least 1; the platform
/// validator rejects pages smaller than one token's KV at sane dims).
pub fn tokens_per_page(spec: &ModelSpec, scheme: Scheme, mem: &MemoryModel) -> usize {
    ((mem.kv_page_bytes / kv_bytes_per_token(spec, scheme, mem)).floor() as usize).max(1)
}

/// Pages needed to hold `tokens` tokens of `spec`'s KV.
pub fn pages_required(spec: &ModelSpec, scheme: Scheme, mem: &MemoryModel, tokens: usize) -> usize {
    let per_page = tokens_per_page(spec, scheme, mem);
    tokens.div_ceil(per_page)
}

/// Chunking layout for one serving pair (drafter, target).
#[derive(Debug, Clone, Copy)]
pub struct KvLayout {
    /// Tokens per trie chunk — one page per role per chunk.
    pub chunk_tokens: usize,
}

impl KvLayout {
    /// Chunk size: the largest token count one page covers for *both*
    /// models, so a chunk is exactly one drafter page + one target page.
    pub fn for_pair(
        mem: &MemoryModel,
        drafter: (&ModelSpec, Scheme),
        target: (&ModelSpec, Scheme),
    ) -> KvLayout {
        let d = tokens_per_page(drafter.0, drafter.1, mem);
        let t = tokens_per_page(target.0, target.1, mem);
        KvLayout { chunk_tokens: d.min(t).max(1) }
    }

    /// Chunks covering `tokens` tokens (ceiling).
    pub fn chunks(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.chunk_tokens)
    }
}

/// One session's slice of the cache: the attached shared-prefix path plus
/// its private pages, all released together when the session leaves.
#[derive(Debug, Clone)]
pub struct SessionKv {
    mapping: Mapping,
    /// Trie nodes this session holds references on (root-first).
    path: Vec<NodeId>,
    /// Prompt tokens covered by `path` — prefill the session skips.
    shared_tokens: usize,
    /// Session-private pages per physical PU (partial prompt tail +
    /// generation window).
    private: [Vec<PageId>; NUM_PUS],
    /// Token budget reserved at admission (prompt + generation cap).
    budget_tokens: usize,
}

impl SessionKv {
    /// Prompt tokens whose prefill this session inherited from the cache.
    pub fn shared_tokens(&self) -> usize {
        self.shared_tokens
    }

    pub fn budget_tokens(&self) -> usize {
        self.budget_tokens
    }

    pub fn mapping(&self) -> Mapping {
        self.mapping
    }

    /// Total pages this session holds privately (excludes shared nodes).
    pub fn private_pages(&self) -> usize {
        self.private.iter().map(Vec::len).sum()
    }
}

/// Cumulative manager counters (the worker snapshots these into the
/// metrics registry as deltas).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvStats {
    /// Prefix-cache probes (one per admission).
    pub lookups: u64,
    /// Prompt tokens examined across probes.
    pub prefix_probe_tokens: u64,
    /// Prompt tokens matched by the prefix cache.
    pub prefix_hit_tokens: u64,
    /// Prefill tokens sessions did not recompute (== hit tokens; kept as
    /// its own counter because it is the experiment's headline metric).
    pub prefill_tokens_saved: u64,
    /// Admissions shed because the page pools were exhausted.
    pub memory_shed: u64,
    /// Pages reclaimed by cancel/deadline reaps (immediate releases).
    pub reap_reclaimed_pages: u64,
}

/// Per-worker KV-cache manager: allocator + prefix trie + accounting.
#[derive(Debug, Clone)]
pub struct KvManager {
    layout: KvLayout,
    alloc: PageAllocator,
    cache: PrefixCache,
    stats: KvStats,
}

impl KvManager {
    /// Pools sized from the platform memory model; chunking from the
    /// serving pair's model dimensions.
    pub fn new(
        mem: &MemoryModel,
        drafter: (&ModelSpec, Scheme),
        target: (&ModelSpec, Scheme),
    ) -> KvManager {
        let layout = KvLayout::for_pair(mem, drafter, target);
        KvManager {
            layout,
            alloc: PageAllocator::new(mem.kv_pages_cpu, mem.kv_pages_gpu),
            cache: PrefixCache::new(layout.chunk_tokens),
            stats: KvStats::default(),
        }
    }

    pub fn layout(&self) -> KvLayout {
        self.layout
    }

    pub fn stats(&self) -> KvStats {
        self.stats
    }

    /// (used, peak, capacity) pages on one PU's pool.
    pub fn occupancy(&self, pu: PuId) -> (usize, usize, usize) {
        (self.alloc.used(pu), self.alloc.peak(pu), self.alloc.capacity(pu))
    }

    /// Admit one session: probe the prefix cache for the prompt, then
    /// reserve pages for the *whole* budget (prompt + generation window)
    /// on the mapping's PUs — evicting cached zero-ref prefixes under
    /// pressure — and publish the prompt's uncovered full chunks as new
    /// shared nodes. `None` = pools exhausted even after eviction; the
    /// caller sheds the request (`memory_shed` is counted here).
    pub fn admit(
        &mut self,
        prompt: &[u32],
        mapping: Mapping,
        budget_tokens: usize,
    ) -> Option<SessionKv> {
        let c = self.layout.chunk_tokens;
        let budget = budget_tokens.max(prompt.len()).max(1);
        let hit = self.cache.attach(prompt, mapping);
        self.stats.lookups += 1;
        self.stats.prefix_probe_tokens += prompt.len() as u64;

        let prompt_chunks = prompt.len() / c; // full chunks only
        let new_shared = prompt_chunks - hit.path.len();
        let private_chunks = self.layout.chunks(budget) - prompt_chunks;
        let need = new_shared + private_chunks;

        let d_pu = mapping.drafter.id();
        let t_pu = mapping.target.id();
        let Some(d_pages) = self.alloc_evicting(d_pu, need) else {
            self.cache.detach(&hit.path);
            self.stats.memory_shed += 1;
            return None;
        };
        let Some(t_pages) = self.alloc_evicting(t_pu, need) else {
            self.alloc.release(d_pu, &d_pages).expect("fresh pages");
            self.cache.detach(&hit.path);
            self.stats.memory_shed += 1;
            return None;
        };
        // The reservation holds; only now do the hit counters move, so a
        // shed admission never reports phantom savings.
        self.stats.prefix_hit_tokens += hit.tokens as u64;
        self.stats.prefill_tokens_saved += hit.tokens as u64;

        // Publish the prompt's uncovered full chunks so the *next*
        // request sharing this prefix attaches to them.
        let mut path = hit.path;
        let mut parent = path.last().copied();
        let mut d_pages = d_pages.into_iter();
        let mut t_pages = t_pages.into_iter();
        for k in path.len()..prompt_chunks {
            let id = self.cache.insert(
                parent,
                &prompt[k * c..(k + 1) * c],
                mapping,
                d_pages.next().expect("reserved above"),
                t_pages.next().expect("reserved above"),
            );
            parent = Some(id);
            path.push(id);
        }
        let mut private: [Vec<PageId>; NUM_PUS] = Default::default();
        private[d_pu.index()].extend(d_pages);
        private[t_pu.index()].extend(t_pages);
        Some(SessionKv { mapping, path, shared_tokens: hit.tokens, private, budget_tokens: budget })
    }

    /// Release a session's cache state: private pages go back to the
    /// pools, shared-path references drop. A `reaped` release
    /// (cancel/deadline) additionally evicts the session's now-unreferenced
    /// path nodes immediately — a reaped prompt is the one prefix we know
    /// nobody is waiting on — and counts everything it reclaimed. Returns
    /// pages freed.
    pub fn release(&mut self, kv: SessionKv, reaped: bool) -> usize {
        let mut freed = 0;
        for pu in PuId::all() {
            let pages = &kv.private[pu.index()];
            if !pages.is_empty() {
                self.alloc.release(pu, pages).expect("session pages are live");
                freed += pages.len();
            }
        }
        self.cache.detach(&kv.path);
        if reaped {
            for &id in kv.path.iter().rev() {
                match self.cache.evict_if_unused(id, &mut self.alloc) {
                    Ok(Some(n)) => freed += n,
                    // Still referenced/parented (or an internal error —
                    // nothing more to reclaim either way): stop walking up.
                    _ => break,
                }
            }
            self.stats.reap_reclaimed_pages += freed as u64;
        }
        freed
    }

    /// Allocate with evict-and-retry: under pressure, cached zero-ref
    /// prefixes are dropped (deepest-first) until the request fits or
    /// nothing evictable remains.
    fn alloc_evicting(&mut self, pu: PuId, n: usize) -> Option<Vec<PageId>> {
        loop {
            if let Some(pages) = self.alloc.alloc(pu, n) {
                return Some(pages);
            }
            match self.cache.evict_one(&mut self.alloc) {
                Ok(Some(_)) => continue,
                _ => return None,
            }
        }
    }

    /// Direct trie/allocator access for tests and the COW surface.
    pub fn parts_mut(&mut self) -> (&mut PrefixCache, &mut PageAllocator) {
        (&mut self.cache, &mut self.alloc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::Platform;

    fn specs() -> (ModelSpec, ModelSpec) {
        (
            ModelSpec {
                name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
                ffn_dim: 256, vocab: 48, param_count: 230_880,
            },
            ModelSpec {
                name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
                ffn_dim: 352, vocab: 48, param_count: 816_256,
            },
        )
    }

    fn manager(pages_cpu: usize, pages_gpu: usize) -> KvManager {
        let (d, t) = specs();
        let mut mem = Platform::imx95().memory;
        mem.kv_pages_cpu = pages_cpu;
        mem.kv_pages_gpu = pages_gpu;
        KvManager::new(&mem, (&d, Scheme::Fp), (&t, Scheme::W8a8))
    }

    #[test]
    fn sizing_is_integral_and_pair_bounded() {
        let (d, t) = specs();
        let mem = Platform::imx95().memory;
        // 16 KiB page / (2·4·128·1 B) = 16 target-w8a8 tokens; the fp
        // drafter fits more, so the pair chunk is target-bound.
        assert_eq!(tokens_per_page(&t, Scheme::W8a8, &mem), 16);
        let layout = KvLayout::for_pair(&mem, (&d, Scheme::Fp), (&t, Scheme::W8a8));
        assert_eq!(layout.chunk_tokens, 16);
        assert!(tokens_per_page(&d, Scheme::Fp, &mem) >= layout.chunk_tokens);
        assert_eq!(pages_required(&t, Scheme::W8a8, &mem, 0), 0);
        assert_eq!(pages_required(&t, Scheme::W8a8, &mem, 17), 2);
        assert_eq!(layout.chunks(33), 3);
    }

    #[test]
    fn second_admission_shares_the_prompt_prefix() {
        let mut kv = manager(64, 64);
        let m = Mapping::heterogeneous(1);
        let c = kv.layout().chunk_tokens;
        let prompt: Vec<u32> = (0..(2 * c + 3) as u32).collect();

        let a = kv.admit(&prompt, m, prompt.len() + 8).unwrap();
        assert_eq!(a.shared_tokens(), 0);
        let used0 = kv.occupancy(PuId::Cpu).0;
        let b = kv.admit(&prompt, m, prompt.len() + 8).unwrap();
        // The two full prompt chunks came from the cache.
        assert_eq!(b.shared_tokens(), 2 * c);
        // B allocated strictly fewer new pages than A did.
        assert!(kv.occupancy(PuId::Cpu).0 - used0 < used0);
        let s = kv.stats();
        assert_eq!(s.prefill_tokens_saved, (2 * c) as u64);
        assert_eq!(s.lookups, 2);
        kv.release(a, false);
        kv.release(b, false);
        // Retention: shared nodes stay cached after both sessions leave.
        let c3 = kv.admit(&prompt, m, prompt.len()).unwrap();
        assert_eq!(c3.shared_tokens(), 2 * c);
    }

    #[test]
    fn exhaustion_sheds_and_reap_reclaims() {
        // Room for one session only (per-PU pools sized to the budget).
        let m = Mapping::heterogeneous(1);
        let mut kv = manager(4, 4);
        let c = kv.layout().chunk_tokens;
        let prompt: Vec<u32> = (0..(2 * c) as u32).collect();
        let budget = 4 * c;
        let a = kv.admit(&prompt, m, budget).unwrap();
        assert!(kv.admit(&[900, 901, 902], m, budget).is_none());
        assert_eq!(kv.stats().memory_shed, 1);
        // Reap: everything comes back, including the shared prompt nodes.
        let freed = kv.release(a, true);
        assert_eq!(freed, 8);
        assert_eq!(kv.stats().reap_reclaimed_pages, 8);
        assert_eq!(kv.occupancy(PuId::Cpu).0, 0);
        assert_eq!(kv.occupancy(PuId::Gpu).0, 0);
        // ... and the next admission fits again.
        assert!(kv.admit(&[900, 901, 902], m, budget).is_some());
    }

    #[test]
    fn pressure_evicts_cached_prefixes_for_new_admissions() {
        let m = Mapping::homogeneous(1);
        let mut kv = manager(4, 0);
        let c = kv.layout().chunk_tokens;
        let prompt_a: Vec<u32> = (0..(2 * c) as u32).collect();
        // Homogeneous: both roles on the CPU pool -> 2 pages per chunk,
        // and the 2-chunk prompt fills the 4-page pool exactly.
        let a = kv.admit(&prompt_a, m, 2 * c).unwrap();
        assert_eq!(kv.occupancy(PuId::Cpu).0, 4);
        kv.release(a, false); // cached, not freed
        assert_eq!(kv.occupancy(PuId::Cpu).0, 4);
        // A different prompt needs the pool: cached chunks are evicted.
        let prompt_b: Vec<u32> = (1000..1000 + (2 * c) as u32).collect();
        let b = kv.admit(&prompt_b, m, 2 * c).unwrap();
        assert_eq!(b.shared_tokens(), 0);
        assert_eq!(kv.occupancy(PuId::Cpu).0, 4);
        kv.release(b, false);
    }
}
