//! Fixed-size page allocator over the per-PU KV pools.
//!
//! One pool per physical PU ([`PuId`]), capacity taken from the platform's
//! [`crate::hetero::platform::MemoryModel`] (`kv_pages_cpu` /
//! `kv_pages_gpu`). Pages are identified, not just counted: the free list
//! holds explicit [`PageId`]s and a liveness bitmap shadows it, so a
//! double free or a foreign id is a detected error instead of silent pool
//! corruption — the property the allocator proptests pin.

use crate::hetero::{PuId, NUM_PUS};

/// Index of one fixed-size page within its PU's pool.
pub type PageId = u32;

#[derive(Debug, Clone)]
struct Pool {
    /// LIFO free list of page ids (all of `0..capacity` when empty).
    free: Vec<PageId>,
    /// `live[p]` = page `p` is currently allocated.
    live: Vec<bool>,
    used: usize,
    peak: usize,
}

impl Pool {
    fn new(capacity: usize) -> Pool {
        Pool {
            // Reversed so pages hand out in ascending id order (cosmetic,
            // but it makes test failures readable).
            free: (0..capacity as PageId).rev().collect(),
            live: vec![false; capacity],
            used: 0,
            peak: 0,
        }
    }
}

/// Per-PU page pools with explicit page identity.
#[derive(Debug, Clone)]
pub struct PageAllocator {
    pools: [Pool; NUM_PUS],
}

impl PageAllocator {
    /// Pools sized `pages_cpu` / `pages_gpu` (the per-worker capacities
    /// from the platform memory model).
    pub fn new(pages_cpu: usize, pages_gpu: usize) -> PageAllocator {
        PageAllocator { pools: [Pool::new(pages_cpu), Pool::new(pages_gpu)] }
    }

    pub fn capacity(&self, pu: PuId) -> usize {
        self.pools[pu.index()].live.len()
    }

    /// Pages currently allocated on `pu`.
    pub fn used(&self, pu: PuId) -> usize {
        self.pools[pu.index()].used
    }

    /// High-water mark of [`used`](Self::used).
    pub fn peak(&self, pu: PuId) -> usize {
        self.pools[pu.index()].peak
    }

    /// Pages still available on `pu`.
    pub fn available(&self, pu: PuId) -> usize {
        self.pools[pu.index()].free.len()
    }

    /// Allocate `n` pages on `pu`, all-or-nothing: `None` leaves the pool
    /// untouched (the caller decides whether to evict and retry or shed).
    pub fn alloc(&mut self, pu: PuId, n: usize) -> Option<Vec<PageId>> {
        let pool = &mut self.pools[pu.index()];
        if pool.free.len() < n {
            return None;
        }
        let at = pool.free.len() - n;
        let pages = pool.free.split_off(at);
        for &p in &pages {
            debug_assert!(!pool.live[p as usize]);
            pool.live[p as usize] = true;
        }
        pool.used += n;
        pool.peak = pool.peak.max(pool.used);
        Some(pages)
    }

    /// Return pages to `pu`'s pool. A page not currently live (double
    /// free) or outside the pool is an error; pages preceding the bad one
    /// in `pages` are still freed.
    pub fn release(&mut self, pu: PuId, pages: &[PageId]) -> anyhow::Result<()> {
        let pool = &mut self.pools[pu.index()];
        for &p in pages {
            let slot = pool
                .live
                .get_mut(p as usize)
                .ok_or_else(|| anyhow::anyhow!("page {p} outside the {} pool", pu.label()))?;
            anyhow::ensure!(*slot, "double free of page {p} on {}", pu.label());
            *slot = false;
            pool.free.push(p);
            pool.used -= 1;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_is_all_or_nothing() {
        let mut a = PageAllocator::new(4, 2);
        let got = a.alloc(PuId::Cpu, 3).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(a.used(PuId::Cpu), 3);
        // 2 > 1 remaining: refused, nothing consumed.
        assert!(a.alloc(PuId::Cpu, 2).is_none());
        assert_eq!(a.used(PuId::Cpu), 3);
        assert_eq!(a.available(PuId::Cpu), 1);
        // Pools are independent.
        assert!(a.alloc(PuId::Gpu, 2).is_some());
        assert!(a.alloc(PuId::Gpu, 1).is_none());
    }

    #[test]
    fn release_returns_pages_and_detects_double_free() {
        let mut a = PageAllocator::new(2, 0);
        let pages = a.alloc(PuId::Cpu, 2).unwrap();
        a.release(PuId::Cpu, &pages).unwrap();
        assert_eq!(a.used(PuId::Cpu), 0);
        assert_eq!(a.peak(PuId::Cpu), 2);
        // Double free and foreign ids are loud errors.
        assert!(a.release(PuId::Cpu, &pages[..1]).is_err());
        assert!(a.release(PuId::Cpu, &[99]).is_err());
        // The pool is usable again at full capacity.
        assert_eq!(a.alloc(PuId::Cpu, 2).unwrap().len(), 2);
    }

    #[test]
    fn zero_page_requests_always_succeed() {
        let mut a = PageAllocator::new(0, 0);
        assert_eq!(a.alloc(PuId::Cpu, 0).unwrap().len(), 0);
        assert!(a.alloc(PuId::Cpu, 1).is_none());
    }
}
