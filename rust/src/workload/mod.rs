//! Workloads: the fixed Spec-Bench-shaped evaluation set (replayed from the
//! manifest so Python and Rust agree sample-for-sample) plus open-loop
//! arrival processes for the serving experiments.

use crate::runtime::manifest::{EvalSample, Manifest};
use crate::scenario::{ArrivalProcess, RequestClass};
use crate::tokenizer::{Tokenizer, SEP_ID};
use crate::util::rng::Rng;

/// The paper's 13 Spec-Bench task names (ours are synthetic equivalents).
pub const TRANSLATE_TASK: &str = "translate";

/// One serving request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub task: String,
    /// Prompt token ids (BOS ... SEP).
    pub prompt: Vec<u32>,
    /// Ground-truth completion text (accuracy accounting).
    pub truth: String,
    /// Arrival offset within the run, seconds (0 for closed-loop).
    pub arrival_s: f64,
    /// Traffic class of the request (`None` when the task is outside the
    /// 13-task eval set; trace-materialized requests always carry one).
    pub class: Option<RequestClass>,
}

/// Workload built from the manifest's eval samples.
#[derive(Debug, Clone)]
pub struct Workload {
    pub requests: Vec<Request>,
}

impl Workload {
    /// All samples of one task (or all tasks if `task` is None), closed-loop.
    pub fn from_manifest(
        manifest: &Manifest,
        tokenizer: &Tokenizer,
        task: Option<&str>,
        limit: Option<usize>,
    ) -> anyhow::Result<Workload> {
        let mut requests = Vec::new();
        for (i, s) in manifest.eval_samples.iter().enumerate() {
            if let Some(t) = task {
                if s.task != t {
                    continue;
                }
            }
            requests.push(Request {
                id: i as u64,
                task: s.task.clone(),
                prompt: prompt_ids(tokenizer, s)?,
                truth: s.completion.clone(),
                arrival_s: 0.0,
                class: RequestClass::for_task(&s.task),
            });
            if let Some(l) = limit {
                if requests.len() >= l {
                    break;
                }
            }
        }
        anyhow::ensure!(!requests.is_empty(), "no samples matched task {task:?}");
        Ok(Workload { requests })
    }

    /// Stamp Poisson (exponential inter-arrival) times at `rate` req/s —
    /// the open-loop serving scenario for the E2E example. Delegates to
    /// [`ArrivalProcess::Poisson`], which draws the RNG identically to the
    /// historical inline loop (bit-for-bit arrival stamps).
    pub fn with_poisson_arrivals(self, rate: f64, seed: u64) -> Workload {
        self.with_arrivals(&ArrivalProcess::Poisson { rate }, seed)
    }

    /// Stamp arrival times from any [`ArrivalProcess`] (Poisson, bursty,
    /// diurnal) — one seeded draw per request, in request order.
    pub fn with_arrivals(mut self, process: &ArrivalProcess, seed: u64) -> Workload {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        for r in &mut self.requests {
            t += process.next_gap(&mut rng, t);
            r.arrival_s = t;
        }
        self
    }

    /// Shuffle request order (keeps arrival stamps sorted if present).
    pub fn shuffled(mut self, seed: u64) -> Workload {
        let mut rng = Rng::new(seed);
        let arrivals: Vec<f64> = self.requests.iter().map(|r| r.arrival_s).collect();
        rng.shuffle(&mut self.requests);
        for (r, a) in self.requests.iter_mut().zip(arrivals) {
            r.arrival_s = a;
        }
        self
    }

    pub fn avg_prompt_len(&self) -> f64 {
        self.requests.iter().map(|r| r.prompt.len()).sum::<usize>() as f64
            / self.requests.len().max(1) as f64
    }
}

/// Encode "<prompt>" + SEP exactly like `data.Sample.prompt_ids()`.
pub fn prompt_ids(tokenizer: &Tokenizer, s: &EvalSample) -> anyhow::Result<Vec<u32>> {
    let mut ids = tokenizer.encode(&s.prompt, true)?;
    ids.push(SEP_ID);
    Ok(ids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::path::Path;

    fn mini_manifest() -> Manifest {
        let j = Json::parse(
            r#"{
          "tokenizer": {"specials":["<pad>","<bos>","<eos>","="],
                        "chars":" abcdefghijklmnopqrstuvwxyz.,?!-0123456789:'",
                        "vocab_size":48},
          "seq_buckets": [128], "batch_sizes": [1],
          "models": {}, "variants": {}, "monolithic": [],
          "eval_samples": [
            {"task":"translate","prompt":"tr: abc","completion":"hij"},
            {"task":"copy","prompt":"cp: abc","completion":"abc"},
            {"task":"translate","prompt":"tr: de","completion":"kl"}
          ]}"#,
        )
        .unwrap();
        Manifest::from_json(Path::new("/tmp"), &j).unwrap()
    }

    #[test]
    fn filters_by_task() {
        let m = mini_manifest();
        let t = Tokenizer::builtin();
        let w = Workload::from_manifest(&m, &t, Some("translate"), None).unwrap();
        assert_eq!(w.requests.len(), 2);
        assert!(w.requests.iter().all(|r| r.task == "translate"));
        let all = Workload::from_manifest(&m, &t, None, None).unwrap();
        assert_eq!(all.requests.len(), 3);
    }

    #[test]
    fn prompt_ends_with_sep() {
        let m = mini_manifest();
        let t = Tokenizer::builtin();
        let w = Workload::from_manifest(&m, &t, None, Some(1)).unwrap();
        assert_eq!(*w.requests[0].prompt.last().unwrap(), SEP_ID);
        assert_eq!(w.requests[0].prompt[0], crate::tokenizer::BOS_ID);
    }

    #[test]
    fn poisson_arrivals_increase() {
        let m = mini_manifest();
        let t = Tokenizer::builtin();
        let w = Workload::from_manifest(&m, &t, None, None)
            .unwrap()
            .with_poisson_arrivals(10.0, 7);
        let a: Vec<f64> = w.requests.iter().map(|r| r.arrival_s).collect();
        assert!(a.windows(2).all(|x| x[1] > x[0]));
        assert!(a[0] > 0.0);
    }

    #[test]
    fn arrival_delegation_is_bit_identical() {
        let m = mini_manifest();
        let t = Tokenizer::builtin();
        let w = Workload::from_manifest(&m, &t, None, None).unwrap();
        let legacy: Vec<u64> = {
            // The historical inline loop, verbatim.
            let mut rng = Rng::new(7);
            let mut t = 0.0;
            w.requests
                .iter()
                .map(|_| {
                    t += rng.exp(10.0);
                    t.to_bits()
                })
                .collect()
        };
        let stamped = w.clone().with_poisson_arrivals(10.0, 7);
        let got: Vec<u64> = stamped.requests.iter().map(|r| r.arrival_s.to_bits()).collect();
        assert_eq!(got, legacy);
    }

    #[test]
    fn requests_carry_class_tags() {
        let m = mini_manifest();
        let t = Tokenizer::builtin();
        let w = Workload::from_manifest(&m, &t, None, None).unwrap();
        assert!(w
            .requests
            .iter()
            .all(|r| r.class == RequestClass::for_task(&r.task)));
        assert_eq!(w.requests[0].class, Some(RequestClass::Translate));
        assert_eq!(w.requests[1].class, Some(RequestClass::Chat)); // "copy"
    }

    #[test]
    fn unknown_task_errors() {
        let m = mini_manifest();
        let t = Tokenizer::builtin();
        assert!(Workload::from_manifest(&m, &t, Some("nope"), None).is_err());
    }
}
