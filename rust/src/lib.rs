//! # SpecEdge
//!
//! Reproduction of *Compiler-Assisted Speculative Sampling for Accelerated
//! LLM Inference on Heterogeneous Edge Devices* as a three-layer
//! Rust + JAX + Pallas stack (AOT via HLO text → PJRT).
//!
//! Layer 3 (this crate) owns the request path: a speculative-sampling
//! serving coordinator with heterogeneous PU mapping, the analytical cost
//! model (paper Eq. 1), design-space exploration and every experiment
//! driver. Layers 1/2 (Pallas kernels + JAX models) run once at build time
//! (`make artifacts`); Python is never on the request path.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — substrate: JSON codec, RNG, stats, CLI, thread pool
//! * [`api`] — the request-lifecycle API: typed [`api::GenOptions`],
//!   [`api::GenerationRequest`] and [`api::FinishReason`]
//! * [`config`] — typed run configuration
//! * [`tokenizer`] — char tokenizer mirroring the Python build side
//! * [`runtime`] — PJRT engine: artifact registry, executable cache
//! * [`models`] — model-variant metadata and the analytic FLOPs model
//! * [`hetero`] — the simulated i.MX95 platform (PUs, latency model, clock)
//! * [`costmodel`] — Eq. (1): speedup, feasibility, optimal draft length
//! * [`dse`] — design-space encoding v·N^m and exploration
//! * [`decision`] — the unified decision layer: [`decision::CostModel`]
//!   trait (analytic + calibrated impls), online routing engine,
//!   calibration feed and online re-partitioning
//! * [`profiler`] — cost-coefficient measurement (paper Fig. 6)
//! * [`kvcache`] — paged KV-cache: page allocator, COW prefix trie,
//!   per-worker manager with memory-aware admission
//! * [`spec`] — the speculative sampling engine (modular + monolithic)
//! * [`workload`] — Spec-Bench-shaped workload and arrival processes
//! * [`scenario`] — workload traces: request classes, seeded scenario
//!   generators, JSON-lines trace replay and the drafter registry
//!   ([`scenario::DrafterRegistry`]) for per-class drafter selection
//! * [`coordinator`] — router, fused batching, queue, worker lifecycle
//!   (plus the quarantined [`coordinator::legacy_lockstep`] reference)
//! * [`fleet`] — multi-device routing tier: per-device coordinators,
//!   placement policy, device timelines, cloud-edge collaborative
//!   speculation over a modeled network link
//! * [`server`] — TCP line-JSON serving front-end: nonblocking
//!   event-loop shell (default) + legacy thread-per-connection baseline
//! * [`loadgen`] — many-client load harness driving the server
//!   (open-loop Poisson + closed-loop, mixed SLO classes)
//! * [`metrics`] — latency/acceptance recording
//! * [`experiments`] — one driver per paper table/figure
//! * [`bench`] — mini-criterion harness used by `cargo bench` targets

pub mod api;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod decision;
pub mod dse;
pub mod experiments;
pub mod fleet;
pub mod hetero;
pub mod kvcache;
pub mod loadgen;
pub mod metrics;
pub mod models;
pub mod profiler;
pub mod runtime;
pub mod scenario;
pub mod server;
pub mod spec;
pub mod tokenizer;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Default artifacts directory (overridable via `--artifacts` / config).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";
