//! Serving metrics: latency recording, acceptance accounting, throughput —
//! at two granularities. Requests contribute end-to-end latencies; the
//! round-level scheduler additionally records every speculation round
//! (γ chosen per round, per-round α trajectory, sessions in flight), which
//! is how continuous scheduling is observed from the outside.
//!
//! The fused executor additionally reports *dispatch* accounting: how many
//! engine calls the scheduler issued, how many of them carried more than
//! one session (fused), and the batch fill ratio (real lanes / executed
//! lanes, padding included) — the observable for how well co-scheduled
//! sessions share batched dispatches.
//!
//! The per-PU timeline model contributes a third granularity: per-PU busy
//! seconds and dispatch counts, exact cross-PU overlap seconds (time when
//! both PUs of the heterogeneous mapping computed simultaneously), and
//! the aggregate simulated makespan — busy/overlap deltas and per-worker
//! makespan growth all sum across workers (each worker owns an
//! independent timeline, so the aggregate is total timeline length, and
//! the conservation law `makespan = Σ busy − overlap` holds for any
//! worker count). `overlap_s > 0` is the direct observable for
//! heterogeneous draft/verify overlap; with `hetero_overlap: false`
//! (serialized timelines) it stays 0 and the makespan equals the summed
//! busy time.

use crate::api::{FinishReason, SloClass, NUM_FINISH_REASONS, NUM_SLO_CLASSES};
use crate::hetero::{PuId, TimelineSnapshot, NUM_PUS};
use crate::scenario::{RequestClass, NUM_CLASSES};
use crate::util::stats::{BoxStats, Summary};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Thread-safe metrics sink shared by coordinator workers.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    /// Per-request simulated end-to-end latency (seconds).
    sim_latency: Summary,
    /// Per-request real wall latency (seconds).
    real_latency: Summary,
    /// Per-request queueing delay (seconds, real).
    queue_delay: Summary,
    /// Per-request acceptance rate (NaNs excluded).
    alpha: Summary,
    tokens_out: u64,
    requests: u64,
    rejected: u64,
    drafted: u64,
    accepted: u64,
    /// Scheduler rounds (one per working `DecodeSession::step`).
    rounds: u64,
    /// Σ draft-window sizes (exact mean γ = sum / rounds; 0-valued
    /// baseline steps included).
    round_gamma_sum: f64,
    /// Per-round acceptance rate (rounds that drafted only). Rounds fire
    /// ~γ× more often than requests, so a bounded reservoir keeps the
    /// hot-path sink O(1) memory over a server's lifetime.
    round_alpha: Reservoir,
    /// Σ live sessions on the recording worker at each round.
    inflight_sum: f64,
    max_inflight: usize,
    /// Engine calls issued by the schedulers (forward, batched forward or
    /// mono step — compiles excluded).
    dispatches: u64,
    /// Dispatches that carried more than one session's forward.
    fused_dispatches: u64,
    /// Σ real session lanes / Σ executed (padded) lanes over dispatches.
    lanes_real: u64,
    lanes_executed: u64,
    /// Rounds that speculated a token tree (flattened multi-lane verify).
    tree_rounds: u64,
    /// Σ accepted root-path depth over tree rounds (mean accepted depth =
    /// sum / tree_rounds).
    tree_depth_sum: f64,
    /// Σ real / executed verification lanes over tree rounds only (the
    /// tree-lane utilization observable; distinct from the fused-dispatch
    /// fill above, which also counts chain and baseline lanes).
    tree_lanes_real: u64,
    tree_lanes_executed: u64,
    /// Per-PU timeline accounting (indexed by [`PuId::index`]): Σ busy
    /// seconds and dispatch counts across workers.
    pu_busy: [f64; NUM_PUS],
    pu_dispatches: [u64; NUM_PUS],
    /// Σ exact cross-PU overlap seconds across workers.
    overlap_s: f64,
    /// Σ per-worker simulated makespans.
    makespan_s: f64,
    /// Per-request end-to-end latency on the per-PU timelines
    /// (admission → last dispatch end).
    tl_latency: Summary,
    /// Routing decisions taken with zero α observations for their task
    /// (the optimistic prior stood in — see the decision layer).
    prior_decisions: u64,
    /// Dispatch-duration observations accepted by the calibration
    /// estimator.
    calibration_obs: u64,
    /// Requests answered, by typed [`FinishReason`] (indexed by
    /// [`FinishReason::index`]); includes rejected/shed requests, so the
    /// sum can exceed the `requests` latency population.
    finish: [u64; NUM_FINISH_REASONS],
    /// Requests answered per SLO class (indexed by [`SloClass::index`]).
    slo: [u64; NUM_SLO_CLASSES],
    /// Deadline-carrying requests answered, and how many missed (shed in
    /// the queue, aborted mid-decode, or completed past budget).
    deadline_requests: u64,
    deadline_missed: u64,
    /// Paged KV-cache counters (`kv_cache: on` only; all zero when off).
    kv_lookups: u64,
    kv_prefix_probe_tokens: u64,
    kv_prefix_hit_tokens: u64,
    kv_prefill_tokens_saved: u64,
    kv_memory_shed: u64,
    kv_reap_reclaimed_pages: u64,
    /// Per-worker per-PU page gauges `[used, peak, capacity]` at the
    /// worker's last sync (indexed by worker id; workers own independent
    /// managers, so the report sums across them).
    kv_workers: Vec<[[u64; 3]; NUM_PUS]>,
    /// Requests retired per [`RequestClass`] (indexed by
    /// [`RequestClass::index`]; unclassed tasks are not counted here).
    class_requests: [u64; NUM_CLASSES],
    /// Per-class α EWMA (same 0.8/0.2 mix the decision layer runs) and
    /// how many finite observations fed it (0 ⇒ the EWMA is unset).
    class_alpha: [f64; NUM_CLASSES],
    class_alpha_n: [u64; NUM_CLASSES],
    /// Requests retired per drafter variant name (the chosen-drafter
    /// histogram; a single bucket under `drafter: fixed`).
    drafter_hist: BTreeMap<String, u64>,
}

/// Fixed-size uniform reservoir (Vitter's Algorithm R) for unbounded
/// sample streams; percentiles come from the retained subset.
#[derive(Debug)]
struct Reservoir {
    values: Vec<f64>,
    seen: u64,
    rng: crate::util::rng::Rng,
}

const RESERVOIR_CAP: usize = 4096;

impl Default for Reservoir {
    fn default() -> Reservoir {
        Reservoir {
            values: Vec::new(),
            seen: 0,
            rng: crate::util::rng::Rng::new(0x5EED5),
        }
    }
}

impl Reservoir {
    fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.values.len() < RESERVOIR_CAP {
            self.values.push(x);
        } else {
            let j = (self.rng.next_u64() % self.seen) as usize;
            if j < RESERVOIR_CAP {
                self.values[j] = x;
            }
        }
    }

    fn box_stats(&self) -> BoxStats {
        Summary::from_values(self.values.clone()).box_stats()
    }
}

/// One request's contribution.
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub sim_s: f64,
    pub real_s: f64,
    pub queue_s: f64,
    pub tokens: usize,
    pub drafted: usize,
    pub accepted: usize,
}

/// One worker's paged KV-cache sync: counter *deltas* since its previous
/// sync plus its current per-PU page gauges (the worker snapshots its
/// [`KvManager`](crate::kvcache::KvManager) stats every tick and reports
/// the growth, so restating is safe and cheap).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvRecord {
    pub lookups: u64,
    pub prefix_probe_tokens: u64,
    pub prefix_hit_tokens: u64,
    pub prefill_tokens_saved: u64,
    pub memory_shed: u64,
    pub reap_reclaimed_pages: u64,
    /// Per-PU `[used, peak, capacity]` pages at sync time (gauges, not
    /// deltas — each sync replaces the worker's previous value).
    pub occupancy: [[u64; 3]; NUM_PUS],
}

/// One scheduler round's contribution. The draft window the round ran
/// doubles as the per-round γ record (0 = baseline step).
#[derive(Debug, Clone, Copy)]
pub struct RoundRecord {
    pub drafted: usize,
    pub accepted: usize,
    pub sim_s: f64,
    pub real_s: f64,
    /// Live sessions on this worker when the round ran.
    pub inflight: usize,
    /// Executed (padded) verification lanes when the round speculated a
    /// token tree; 0 marks a chain or baseline round. For tree rounds
    /// `accepted` is the accepted root-path depth.
    pub tree_lanes_executed: usize,
    /// Verification lanes that carried live tree nodes (≤ executed).
    pub tree_lanes_real: usize,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&self, r: RequestRecord) {
        let mut m = self.inner.lock().unwrap();
        m.sim_latency.push(r.sim_s);
        m.real_latency.push(r.real_s);
        m.queue_delay.push(r.queue_s);
        if r.drafted > 0 {
            m.alpha.push(r.accepted as f64 / r.drafted as f64);
        }
        m.tokens_out += r.tokens as u64;
        m.requests += 1;
        m.drafted += r.drafted as u64;
        m.accepted += r.accepted as u64;
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn record_round(&self, r: RoundRecord) {
        let mut m = self.inner.lock().unwrap();
        m.rounds += 1;
        m.round_gamma_sum += r.drafted as f64;
        if r.drafted > 0 {
            m.round_alpha.push(r.accepted as f64 / r.drafted as f64);
        }
        m.inflight_sum += r.inflight as f64;
        m.max_inflight = m.max_inflight.max(r.inflight);
        if r.tree_lanes_executed > 0 {
            m.tree_rounds += 1;
            m.tree_depth_sum += r.accepted as f64;
            m.tree_lanes_real += r.tree_lanes_real as u64;
            m.tree_lanes_executed += r.tree_lanes_executed as u64;
        }
    }

    /// Account one scheduler tick's engine-dispatch activity (fused
    /// executor or per-session fallback).
    pub fn record_dispatches(
        &self,
        dispatches: u64,
        fused: u64,
        lanes_real: u64,
        lanes_executed: u64,
    ) {
        if dispatches == 0 {
            return;
        }
        let mut m = self.inner.lock().unwrap();
        m.dispatches += dispatches;
        m.fused_dispatches += fused;
        m.lanes_real += lanes_real;
        m.lanes_executed += lanes_executed;
    }

    /// Fold one worker's timeline growth since `prev` into the shared
    /// sink. Everything — busy, overlap, dispatches *and* makespan — is a
    /// summed delta: each worker owns an independent timeline starting at
    /// 0, so the aggregate makespan is the total timeline length across
    /// workers and `makespan = Σ busy − overlap` holds for any worker
    /// count (a max-merge would break it and let overlap_frac exceed 1).
    pub fn record_timeline(&self, snap: &TimelineSnapshot, prev: &TimelineSnapshot) {
        if snap == prev {
            return;
        }
        let mut m = self.inner.lock().unwrap();
        for p in 0..NUM_PUS {
            m.pu_busy[p] += snap.busy[p] - prev.busy[p];
            m.pu_dispatches[p] += snap.dispatches[p] - prev.dispatches[p];
        }
        m.overlap_s += snap.overlap_s - prev.overlap_s;
        m.makespan_s += snap.makespan - prev.makespan;
    }

    /// One routing decision that had zero α observations for its task and
    /// fell back to the optimistic prior (counted so "the prior stood in"
    /// is observable instead of silent).
    pub fn record_prior_decision(&self) {
        self.inner.lock().unwrap().prior_decisions += 1;
    }

    /// `n` dispatch-duration observations were accepted by the decision
    /// layer's calibration estimator.
    pub fn record_calibration(&self, n: u64) {
        if n > 0 {
            self.inner.lock().unwrap().calibration_obs += n;
        }
    }

    /// One request answered with a typed [`FinishReason`] (every path:
    /// normal completion, round-boundary aborts, queue sheds, rejects).
    pub fn record_finish(&self, reason: FinishReason) {
        self.inner.lock().unwrap().finish[reason.index()] += 1;
    }

    /// One request answered for an SLO class.
    pub fn record_slo(&self, class: SloClass) {
        self.inner.lock().unwrap().slo[class.index()] += 1;
    }

    /// One deadline-carrying request answered; `missed` if the deadline
    /// was not met (shed, aborted, or finished over budget).
    pub fn record_deadline(&self, missed: bool) {
        let mut m = self.inner.lock().unwrap();
        m.deadline_requests += 1;
        if missed {
            m.deadline_missed += 1;
        }
    }

    /// Fold one worker's paged KV-cache sync into the shared sink:
    /// counters are deltas (added), occupancy gauges replace the worker's
    /// previous report and are summed across workers at snapshot time.
    pub fn record_kv(&self, wid: usize, r: KvRecord) {
        let mut m = self.inner.lock().unwrap();
        m.kv_lookups += r.lookups;
        m.kv_prefix_probe_tokens += r.prefix_probe_tokens;
        m.kv_prefix_hit_tokens += r.prefix_hit_tokens;
        m.kv_prefill_tokens_saved += r.prefill_tokens_saved;
        m.kv_memory_shed += r.memory_shed;
        m.kv_reap_reclaimed_pages += r.reap_reclaimed_pages;
        if m.kv_workers.len() <= wid {
            m.kv_workers.resize(wid + 1, [[0; 3]; NUM_PUS]);
        }
        m.kv_workers[wid] = r.occupancy;
    }

    /// One retired request's traffic-class accounting: per-class request
    /// count, per-class α EWMA (a NaN α — the request never drafted —
    /// leaves the mix untouched) and the chosen-drafter histogram. A
    /// `None` class (task outside the 13-task eval set) still counts
    /// toward the drafter histogram.
    pub fn record_class(&self, class: Option<RequestClass>, alpha: f64, drafter: &str) {
        let mut m = self.inner.lock().unwrap();
        *m.drafter_hist.entry(drafter.to_string()).or_insert(0) += 1;
        let Some(class) = class else { return };
        let i = class.index();
        m.class_requests[i] += 1;
        if alpha.is_finite() {
            m.class_alpha[i] = if m.class_alpha_n[i] == 0 {
                alpha
            } else {
                0.8 * m.class_alpha[i] + 0.2 * alpha
            };
            m.class_alpha_n[i] += 1;
        }
    }

    /// One request's simulated timeline latency (admission → finish).
    pub fn record_timeline_latency(&self, seconds: f64) {
        if seconds.is_finite() {
            self.inner.lock().unwrap().tl_latency.push(seconds);
        }
    }

    pub fn snapshot(&self) -> Report {
        let mut m = self.inner.lock().unwrap();
        Report {
            requests: m.requests,
            rejected: m.rejected,
            tokens_out: m.tokens_out,
            mean_alpha: if m.drafted > 0 {
                m.accepted as f64 / m.drafted as f64
            } else {
                f64::NAN
            },
            sim_latency: m.sim_latency.box_stats(),
            real_latency: m.real_latency.box_stats(),
            queue_delay: m.queue_delay.box_stats(),
            rounds: m.rounds,
            mean_round_gamma: m.round_gamma_sum / m.rounds.max(1) as f64,
            round_alpha: m.round_alpha.box_stats(),
            mean_inflight: m.inflight_sum / m.rounds.max(1) as f64,
            max_inflight: m.max_inflight,
            dispatches: m.dispatches,
            fused_dispatches: m.fused_dispatches,
            batch_fill: if m.lanes_executed > 0 {
                m.lanes_real as f64 / m.lanes_executed as f64
            } else {
                f64::NAN
            },
            tree_rounds: m.tree_rounds,
            mean_tree_depth: if m.tree_rounds > 0 {
                m.tree_depth_sum / m.tree_rounds as f64
            } else {
                f64::NAN
            },
            tree_lane_fill: if m.tree_lanes_executed > 0 {
                m.tree_lanes_real as f64 / m.tree_lanes_executed as f64
            } else {
                f64::NAN
            },
            pu_busy: m.pu_busy,
            pu_dispatches: m.pu_dispatches,
            overlap_s: m.overlap_s,
            makespan_s: m.makespan_s,
            tl_latency: m.tl_latency.box_stats(),
            prior_decisions: m.prior_decisions,
            calibration_obs: m.calibration_obs,
            finish: m.finish,
            slo_requests: m.slo,
            deadline_requests: m.deadline_requests,
            deadline_missed: m.deadline_missed,
            kv_lookups: m.kv_lookups,
            kv_prefix_probe_tokens: m.kv_prefix_probe_tokens,
            kv_prefix_hit_tokens: m.kv_prefix_hit_tokens,
            kv_prefill_tokens_saved: m.kv_prefill_tokens_saved,
            kv_memory_shed: m.kv_memory_shed,
            kv_reap_reclaimed_pages: m.kv_reap_reclaimed_pages,
            kv_pages_used: sum_occupancy(&m.kv_workers, 0),
            kv_pages_peak: sum_occupancy(&m.kv_workers, 1),
            kv_pages_capacity: sum_occupancy(&m.kv_workers, 2),
            class_requests: m.class_requests,
            class_alpha: {
                let mut a = [f64::NAN; NUM_CLASSES];
                for i in 0..NUM_CLASSES {
                    if m.class_alpha_n[i] > 0 {
                        a[i] = m.class_alpha[i];
                    }
                }
                a
            },
            drafter_hist: m.drafter_hist.iter().map(|(k, &n)| (k.clone(), n)).collect(),
        }
    }
}

/// Sum one column of the per-worker `[used, peak, capacity]` gauges.
fn sum_occupancy(workers: &[[[u64; 3]; NUM_PUS]], col: usize) -> [u64; NUM_PUS] {
    let mut out = [0u64; NUM_PUS];
    for w in workers {
        for p in 0..NUM_PUS {
            out[p] += w[p][col];
        }
    }
    out
}

/// Point-in-time metrics report.
#[derive(Debug, Clone)]
pub struct Report {
    pub requests: u64,
    pub rejected: u64,
    pub tokens_out: u64,
    pub mean_alpha: f64,
    pub sim_latency: BoxStats,
    pub real_latency: BoxStats,
    pub queue_delay: BoxStats,
    /// Scheduler rounds across all workers.
    pub rounds: u64,
    /// Mean γ chosen per round (0-valued baseline steps included).
    pub mean_round_gamma: f64,
    /// Per-round α trajectory over drafting rounds.
    pub round_alpha: BoxStats,
    /// Mean / max sessions in flight per worker, sampled per round.
    pub mean_inflight: f64,
    pub max_inflight: usize,
    /// Engine dispatches issued by the schedulers, and how many of them
    /// were shared (fused) batched calls.
    pub dispatches: u64,
    pub fused_dispatches: u64,
    /// Real lanes / executed lanes across all dispatches (1.0 = every
    /// executed lane carried a live session; NaN before any dispatch).
    pub batch_fill: f64,
    /// Rounds that speculated a token tree (flattened multi-lane verify).
    pub tree_rounds: u64,
    /// Mean accepted root-path depth per tree round (NaN before any).
    pub mean_tree_depth: f64,
    /// Real / executed verification lanes over tree rounds only (NaN
    /// before any tree round) — the tree lane-utilization observable.
    pub tree_lane_fill: f64,
    /// Per-PU timeline accounting (index 0 = CPU cluster, 1 = GPU; see
    /// [`PuId::index`]): Σ busy seconds and dispatches across workers.
    pub pu_busy: [f64; NUM_PUS],
    pub pu_dispatches: [u64; NUM_PUS],
    /// Exact seconds both PUs computed simultaneously (0 under serialized
    /// `hetero_overlap: false` timelines, and before any dispatch).
    pub overlap_s: f64,
    /// Aggregate simulated makespan: Σ per-worker timeline lengths
    /// (= one worker's makespan in single-worker runs; satisfies
    /// `makespan = Σ busy − overlap` for any worker count).
    pub makespan_s: f64,
    /// Per-request simulated timeline latency (admission → finish).
    pub tl_latency: BoxStats,
    /// Routing decisions that fell back to the optimistic prior (zero α
    /// observations for the task at decision time).
    pub prior_decisions: u64,
    /// Dispatch-duration observations accepted by the calibration
    /// estimator (0 under `decision: "analytic"`).
    pub calibration_obs: u64,
    /// Requests answered per typed [`FinishReason`] (see
    /// [`finish_count`](Report::finish_count)).
    pub finish: [u64; NUM_FINISH_REASONS],
    /// Requests answered per [`SloClass`].
    pub slo_requests: [u64; NUM_SLO_CLASSES],
    /// Deadline-carrying requests answered / missed.
    pub deadline_requests: u64,
    pub deadline_missed: u64,
    /// Paged KV-cache counters (all zero under `kv_cache: off`): prefix
    /// probes, probe/hit token totals, prefill tokens sessions skipped,
    /// admissions shed on page exhaustion, pages reclaimed by reaps.
    pub kv_lookups: u64,
    pub kv_prefix_probe_tokens: u64,
    pub kv_prefix_hit_tokens: u64,
    pub kv_prefill_tokens_saved: u64,
    pub kv_memory_shed: u64,
    pub kv_reap_reclaimed_pages: u64,
    /// Per-PU page gauges summed across workers (indexed by
    /// [`PuId::index`]): in-use at last sync, high-water mark, pool size.
    pub kv_pages_used: [u64; NUM_PUS],
    pub kv_pages_peak: [u64; NUM_PUS],
    pub kv_pages_capacity: [u64; NUM_PUS],
    /// Requests retired per [`RequestClass`] (indexed by
    /// [`RequestClass::index`]).
    pub class_requests: [u64; NUM_CLASSES],
    /// Per-class retire-time α EWMA (NaN until the class retires a
    /// request that actually drafted).
    pub class_alpha: [f64; NUM_CLASSES],
    /// Requests retired per chosen drafter variant, sorted by name (one
    /// bucket under `drafter: fixed`; empty before any retire).
    pub drafter_hist: Vec<(String, u64)>,
}

impl Report {
    /// Idle seconds on one PU up to the makespan (clamped at 0).
    pub fn pu_idle(&self, pu: PuId) -> f64 {
        (self.makespan_s - self.pu_busy[pu.index()]).max(0.0)
    }

    /// Requests answered with this [`FinishReason`].
    pub fn finish_count(&self, reason: FinishReason) -> u64 {
        self.finish[reason.index()]
    }

    /// Fraction of deadline-carrying requests that missed their deadline
    /// (NaN before any deadline-carrying request finished).
    pub fn deadline_miss_rate(&self) -> f64 {
        if self.deadline_requests > 0 {
            self.deadline_missed as f64 / self.deadline_requests as f64
        } else {
            f64::NAN
        }
    }

    /// Fraction of probed prompt tokens the prefix cache already held
    /// (NaN before any probe — including the whole `kv_cache: off` world).
    pub fn kv_prefix_hit_rate(&self) -> f64 {
        if self.kv_prefix_probe_tokens > 0 {
            self.kv_prefix_hit_tokens as f64 / self.kv_prefix_probe_tokens as f64
        } else {
            f64::NAN
        }
    }

    /// Fraction of the makespan during which both PUs were busy (NaN
    /// before any timeline activity).
    pub fn overlap_frac(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.overlap_s / self.makespan_s
        } else {
            f64::NAN
        }
    }

    pub fn render(&self, wall_s: f64) -> String {
        let drafters: Vec<String> = self
            .drafter_hist
            .iter()
            .map(|(name, n)| format!("{name}={n}"))
            .collect();
        format!(
            "requests={} rejected={} tokens={} tok/s={:.1} mean_alpha={:.3}\n\
             sim latency  p50={:.1}ms p90={:.1}ms mean={:.1}ms\n\
             real latency p50={:.1}ms p90={:.1}ms mean={:.1}ms\n\
             queue delay  p50={:.1}ms p90={:.1}ms\n\
             rounds={} mean_gamma={:.2} round_alpha_p50={:.3} \
             inflight mean={:.2} max={}\n\
             dispatches={} fused={} batch_fill={:.2}\n\
             tree: rounds={} mean_accepted_depth={:.2} lane_fill={:.2}\n\
             pu: cpu busy={:.1}ms gpu busy={:.1}ms overlap={:.1}ms \
             makespan={:.1}ms tl_latency_p50={:.1}ms\n\
             decision: prior_decisions={} calibration_obs={}\n\
             finish: stop={} length={} stop_seq={} cancelled={} \
             deadline={} rejected={}\n\
             slo: interactive={} batch={} deadline_miss_rate={:.3}\n\
             class req: chat={} translate={} summarize={} code_complete={}\n\
             class alpha: chat={:.3} translate={:.3} summarize={:.3} \
             code_complete={:.3}\n\
             drafters: [{}]\n\
             kv: lookups={} prefix_hit_rate={:.3} prefill_tokens_saved={} \
             memory_shed={} reap_reclaimed_pages={}\n\
             kv pages: cpu used={} peak={} cap={} | gpu used={} peak={} cap={}",
            self.requests,
            self.rejected,
            self.tokens_out,
            self.tokens_out as f64 / wall_s.max(1e-9),
            self.mean_alpha,
            self.sim_latency.median * 1e3,
            self.sim_latency.p90 * 1e3,
            self.sim_latency.mean * 1e3,
            self.real_latency.median * 1e3,
            self.real_latency.p90 * 1e3,
            self.real_latency.mean * 1e3,
            self.queue_delay.median * 1e3,
            self.queue_delay.p90 * 1e3,
            self.rounds,
            self.mean_round_gamma,
            self.round_alpha.median,
            self.mean_inflight,
            self.max_inflight,
            self.dispatches,
            self.fused_dispatches,
            self.batch_fill,
            self.tree_rounds,
            self.mean_tree_depth,
            self.tree_lane_fill,
            self.pu_busy[PuId::Cpu.index()] * 1e3,
            self.pu_busy[PuId::Gpu.index()] * 1e3,
            self.overlap_s * 1e3,
            self.makespan_s * 1e3,
            self.tl_latency.median * 1e3,
            self.prior_decisions,
            self.calibration_obs,
            self.finish_count(FinishReason::Stop),
            self.finish_count(FinishReason::Length),
            self.finish_count(FinishReason::StopSequence),
            self.finish_count(FinishReason::Cancelled),
            self.finish_count(FinishReason::DeadlineExceeded),
            self.finish_count(FinishReason::Rejected),
            self.slo_requests[SloClass::Interactive.index()],
            self.slo_requests[SloClass::Batch.index()],
            self.deadline_miss_rate(),
            self.class_requests[RequestClass::Chat.index()],
            self.class_requests[RequestClass::Translate.index()],
            self.class_requests[RequestClass::Summarize.index()],
            self.class_requests[RequestClass::CodeComplete.index()],
            self.class_alpha[RequestClass::Chat.index()],
            self.class_alpha[RequestClass::Translate.index()],
            self.class_alpha[RequestClass::Summarize.index()],
            self.class_alpha[RequestClass::CodeComplete.index()],
            drafters.join(" "),
            self.kv_lookups,
            self.kv_prefix_hit_rate(),
            self.kv_prefill_tokens_saved,
            self.kv_memory_shed,
            self.kv_reap_reclaimed_pages,
            self.kv_pages_used[PuId::Cpu.index()],
            self.kv_pages_peak[PuId::Cpu.index()],
            self.kv_pages_capacity[PuId::Cpu.index()],
            self.kv_pages_used[PuId::Gpu.index()],
            self.kv_pages_peak[PuId::Gpu.index()],
            self.kv_pages_capacity[PuId::Gpu.index()],
        )
    }
}

/// Fleet-tier metrics sink: the router records where each request was
/// placed and why candidates were skipped; the cloud tier records how
/// verification was routed and the modeled network seconds it paid.
/// Per-device serving metrics stay in each device's own [`Metrics`] — this
/// sink only holds what exists *above* a single coordinator.
#[derive(Debug, Default)]
pub struct FleetMetrics {
    inner: Mutex<FleetInner>,
}

#[derive(Debug, Default)]
struct FleetInner {
    placements: Vec<u64>,
    kv_filtered: u64,
    local_verify_rounds: u64,
    cloud_verify_rounds: u64,
    cloud_requests: u64,
    net_s: f64,
    cloud_tokens_shipped: u64,
}

impl FleetMetrics {
    pub fn new(devices: usize) -> FleetMetrics {
        FleetMetrics {
            inner: Mutex::new(FleetInner {
                placements: vec![0; devices],
                ..FleetInner::default()
            }),
        }
    }

    /// One routed request: placed on `device`, after `kv_filtered`
    /// candidate devices were rejected by the KV-admission probe.
    pub fn record_placement(&self, device: usize, kv_filtered: usize) {
        let mut g = self.inner.lock().unwrap();
        if device < g.placements.len() {
            g.placements[device] += 1;
        }
        g.kv_filtered += kv_filtered as u64;
    }

    /// One request whose verification was routed to the cloud tier.
    pub fn record_cloud_request(&self) {
        self.inner.lock().unwrap().cloud_requests += 1;
    }

    /// Verify-routing round counters plus the modeled link seconds and
    /// token payloads shipped for the cloud-verified share.
    pub fn record_verify_rounds(&self, local: u64, cloud: u64, net_s: f64, tokens_shipped: u64) {
        let mut g = self.inner.lock().unwrap();
        g.local_verify_rounds += local;
        g.cloud_verify_rounds += cloud;
        g.net_s += net_s;
        g.cloud_tokens_shipped += tokens_shipped;
    }

    pub fn snapshot(&self) -> FleetReport {
        let g = self.inner.lock().unwrap();
        FleetReport {
            placements: g.placements.clone(),
            kv_filtered: g.kv_filtered,
            local_verify_rounds: g.local_verify_rounds,
            cloud_verify_rounds: g.cloud_verify_rounds,
            cloud_requests: g.cloud_requests,
            net_s: g.net_s,
            cloud_tokens_shipped: g.cloud_tokens_shipped,
        }
    }
}

/// Point-in-time snapshot of [`FleetMetrics`].
#[derive(Debug, Clone, Default)]
pub struct FleetReport {
    /// Requests placed per device, indexed like the fleet's device list.
    pub placements: Vec<u64>,
    /// Candidate devices skipped because the KV-admission probe predicted
    /// an immediate memory shed (summed over all placements).
    pub kv_filtered: u64,
    /// Speculation rounds verified on the placed device itself.
    pub local_verify_rounds: u64,
    /// Speculation rounds verified on the cloud tier.
    pub cloud_verify_rounds: u64,
    /// Requests whose verify was routed to the cloud at admission.
    pub cloud_requests: u64,
    /// Modeled network seconds paid shipping draft/verdict payloads.
    pub net_s: f64,
    /// Draft tokens shipped over the modeled link.
    pub cloud_tokens_shipped: u64,
}

impl FleetReport {
    /// Fraction of verify rounds routed to the cloud (NaN before any
    /// round completed).
    pub fn cloud_verify_frac(&self) -> f64 {
        let total = self.local_verify_rounds + self.cloud_verify_rounds;
        if total > 0 {
            self.cloud_verify_rounds as f64 / total as f64
        } else {
            f64::NAN
        }
    }

    pub fn render(&self) -> String {
        let placed: Vec<String> = self
            .placements
            .iter()
            .enumerate()
            .map(|(i, n)| format!("d{i}={n}"))
            .collect();
        format!(
            "fleet: placements [{}] kv_filtered={}\n\
             fleet verify: local_rounds={} cloud_rounds={} cloud_frac={:.3} \
             cloud_requests={} net={:.1}ms tokens_shipped={}",
            placed.join(" "),
            self.kv_filtered,
            self.local_verify_rounds,
            self.cloud_verify_rounds,
            self.cloud_verify_frac(),
            self.cloud_requests,
            self.net_s * 1e3,
            self.cloud_tokens_shipped,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        for i in 0..10 {
            m.record(RequestRecord {
                sim_s: 0.1 * (i + 1) as f64,
                real_s: 0.05,
                queue_s: 0.01,
                tokens: 20,
                drafted: 10,
                accepted: 5,
            });
        }
        m.record_rejected();
        let r = m.snapshot();
        assert_eq!(r.requests, 10);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.tokens_out, 200);
        assert!((r.mean_alpha - 0.5).abs() < 1e-12);
        assert!((r.sim_latency.median - 0.55).abs() < 1e-9);
    }

    #[test]
    fn round_records_aggregate() {
        let m = Metrics::new();
        m.record_round(RoundRecord {
            drafted: 5, accepted: 4, sim_s: 0.01, real_s: 0.01, inflight: 3,
            tree_lanes_executed: 0, tree_lanes_real: 0,
        });
        m.record_round(RoundRecord {
            drafted: 3, accepted: 3, sim_s: 0.01, real_s: 0.01, inflight: 1,
            tree_lanes_executed: 0, tree_lanes_real: 0,
        });
        m.record_round(RoundRecord {
            drafted: 0, accepted: 0, sim_s: 0.01, real_s: 0.01, inflight: 2,
            tree_lanes_executed: 0, tree_lanes_real: 0,
        });
        let r = m.snapshot();
        assert_eq!(r.rounds, 3);
        assert_eq!(r.max_inflight, 3);
        assert!((r.mean_inflight - 2.0).abs() < 1e-12);
        assert!((r.mean_round_gamma - 8.0 / 3.0).abs() < 1e-12);
        // The baseline round (drafted=0) must not dilute the α trajectory.
        assert_eq!(r.round_alpha.n, 2);
        assert!((r.round_alpha.mean - (0.8 + 1.0) / 2.0).abs() < 1e-12);
        // No tree rounds recorded: counters stay inert.
        assert_eq!(r.tree_rounds, 0);
        assert!(r.mean_tree_depth.is_nan());
        assert!(r.tree_lane_fill.is_nan());
    }

    #[test]
    fn tree_rounds_aggregate_depth_and_lane_fill() {
        let m = Metrics::new();
        // A 2x2 tree round: 6 executed lanes, 5 live, accepted depth 2.
        m.record_round(RoundRecord {
            drafted: 2, accepted: 2, sim_s: 0.01, real_s: 0.01, inflight: 1,
            tree_lanes_executed: 6, tree_lanes_real: 5,
        });
        // A second tree round that accepted only depth 1.
        m.record_round(RoundRecord {
            drafted: 2, accepted: 1, sim_s: 0.01, real_s: 0.01, inflight: 1,
            tree_lanes_executed: 6, tree_lanes_real: 6,
        });
        // Interleaved chain round must not contaminate tree accounting.
        m.record_round(RoundRecord {
            drafted: 4, accepted: 4, sim_s: 0.01, real_s: 0.01, inflight: 1,
            tree_lanes_executed: 0, tree_lanes_real: 0,
        });
        let r = m.snapshot();
        assert_eq!(r.rounds, 3);
        assert_eq!(r.tree_rounds, 2);
        assert!((r.mean_tree_depth - 1.5).abs() < 1e-12);
        assert!((r.tree_lane_fill - 11.0 / 12.0).abs() < 1e-12);
        let s = r.render(1.0);
        assert!(s.contains("mean_accepted_depth=1.50"), "{s}");
    }

    #[test]
    fn dispatch_records_aggregate_into_fill_ratio() {
        let m = Metrics::new();
        assert!(m.snapshot().batch_fill.is_nan(), "no dispatches yet");
        // One fused 3-of-4 dispatch + two singleton dispatches.
        m.record_dispatches(1, 1, 3, 4);
        m.record_dispatches(2, 0, 2, 2);
        let r = m.snapshot();
        assert_eq!(r.dispatches, 3);
        assert_eq!(r.fused_dispatches, 1);
        assert!((r.batch_fill - 5.0 / 6.0).abs() < 1e-12);
        // Empty ticks are ignored entirely.
        m.record_dispatches(0, 0, 0, 0);
        assert_eq!(m.snapshot().dispatches, 3);
    }

    #[test]
    fn timeline_deltas_sum_across_workers() {
        let m = Metrics::new();
        let r0 = m.snapshot();
        assert_eq!(r0.overlap_s, 0.0);
        assert_eq!(r0.makespan_s, 0.0);
        assert!(r0.overlap_frac().is_nan());
        // Worker A ticks twice (cumulative snapshots), worker B once.
        let a1 = TimelineSnapshot {
            busy: [0.4, 0.2], dispatches: [2, 1], overlap_s: 0.1, makespan: 0.5,
        };
        m.record_timeline(&a1, &TimelineSnapshot::default());
        let a2 = TimelineSnapshot {
            busy: [0.9, 0.2], dispatches: [4, 1], overlap_s: 0.2, makespan: 1.0,
        };
        m.record_timeline(&a2, &a1);
        let b1 = TimelineSnapshot {
            busy: [0.1, 0.3], dispatches: [1, 2], overlap_s: 0.05, makespan: 0.4,
        };
        m.record_timeline(&b1, &TimelineSnapshot::default());
        let r = m.snapshot();
        assert!((r.pu_busy[0] - 1.0).abs() < 1e-12);
        assert!((r.pu_busy[1] - 0.5).abs() < 1e-12);
        assert_eq!(r.pu_dispatches, [5, 3]);
        assert!((r.overlap_s - 0.25).abs() < 1e-12);
        // Makespans sum: worker A reached 1.0, worker B 0.4 — the
        // aggregate is total timeline length, not the max, so the
        // conservation bound survives multi-worker aggregation.
        assert!((r.makespan_s - 1.4).abs() < 1e-12);
        assert!((r.pu_idle(PuId::Gpu) - 0.9).abs() < 1e-12);
        assert!((r.overlap_frac() - 0.25 / 1.4).abs() < 1e-12);
        // Unchanged snapshot is a no-op.
        m.record_timeline(&b1, &b1);
        assert_eq!(m.snapshot().pu_dispatches, [5, 3]);
    }

    #[test]
    fn timeline_latency_summarized() {
        let m = Metrics::new();
        m.record_timeline_latency(0.2);
        m.record_timeline_latency(0.4);
        m.record_timeline_latency(f64::NAN); // ignored
        let r = m.snapshot();
        assert_eq!(r.tl_latency.n, 2);
        assert!((r.tl_latency.mean - 0.3).abs() < 1e-12);
    }

    #[test]
    fn decision_counters_aggregate() {
        let m = Metrics::new();
        let r = m.snapshot();
        assert_eq!(r.prior_decisions, 0);
        assert_eq!(r.calibration_obs, 0);
        m.record_prior_decision();
        m.record_prior_decision();
        m.record_calibration(3);
        m.record_calibration(0); // no-op
        let r = m.snapshot();
        assert_eq!(r.prior_decisions, 2);
        assert_eq!(r.calibration_obs, 3);
    }

    #[test]
    fn lifecycle_counters_aggregate() {
        let m = Metrics::new();
        let r = m.snapshot();
        assert_eq!(r.finish, [0; NUM_FINISH_REASONS]);
        assert_eq!(r.slo_requests, [0; NUM_SLO_CLASSES]);
        assert!(r.deadline_miss_rate().is_nan());
        m.record_finish(FinishReason::Stop);
        m.record_finish(FinishReason::Stop);
        m.record_finish(FinishReason::Cancelled);
        m.record_finish(FinishReason::DeadlineExceeded);
        m.record_slo(SloClass::Interactive);
        m.record_slo(SloClass::Batch);
        m.record_slo(SloClass::Batch);
        m.record_deadline(true);
        m.record_deadline(false);
        m.record_deadline(true);
        let r = m.snapshot();
        assert_eq!(r.finish_count(FinishReason::Stop), 2);
        assert_eq!(r.finish_count(FinishReason::Cancelled), 1);
        assert_eq!(r.finish_count(FinishReason::DeadlineExceeded), 1);
        assert_eq!(r.finish_count(FinishReason::Rejected), 0);
        assert_eq!(r.slo_requests, [1, 2]);
        assert_eq!(r.deadline_requests, 3);
        assert_eq!(r.deadline_missed, 2);
        assert!((r.deadline_miss_rate() - 2.0 / 3.0).abs() < 1e-12);
        // The render string mentions the new counters.
        let s = r.render(1.0);
        assert!(s.contains("deadline_miss_rate"), "{s}");
        assert!(s.contains("cancelled=1"), "{s}");
    }

    #[test]
    fn kv_records_sum_deltas_and_replace_gauges() {
        let m = Metrics::new();
        let r = m.snapshot();
        assert_eq!(r.kv_lookups, 0);
        assert!(r.kv_prefix_hit_rate().is_nan(), "off = never probed");
        // Worker 0 syncs twice: counters accumulate, gauges replace.
        m.record_kv(0, KvRecord {
            lookups: 2, prefix_probe_tokens: 40, prefix_hit_tokens: 16,
            prefill_tokens_saved: 16, memory_shed: 0, reap_reclaimed_pages: 0,
            occupancy: [[6, 6, 32], [2, 2, 8]],
        });
        m.record_kv(0, KvRecord {
            lookups: 1, prefix_probe_tokens: 10, prefix_hit_tokens: 4,
            prefill_tokens_saved: 4, memory_shed: 1, reap_reclaimed_pages: 8,
            occupancy: [[4, 8, 32], [1, 3, 8]],
        });
        // Worker 1's gauges sum with worker 0's latest.
        m.record_kv(1, KvRecord {
            lookups: 1, prefix_probe_tokens: 5, prefix_hit_tokens: 0,
            prefill_tokens_saved: 0, memory_shed: 0, reap_reclaimed_pages: 0,
            occupancy: [[2, 2, 32], [0, 0, 8]],
        });
        let r = m.snapshot();
        assert_eq!(r.kv_lookups, 4);
        assert_eq!(r.kv_prefill_tokens_saved, 20);
        assert_eq!(r.kv_memory_shed, 1);
        assert_eq!(r.kv_reap_reclaimed_pages, 8);
        assert!((r.kv_prefix_hit_rate() - 20.0 / 55.0).abs() < 1e-12);
        assert_eq!(r.kv_pages_used, [6, 1]);
        assert_eq!(r.kv_pages_peak, [10, 3]);
        assert_eq!(r.kv_pages_capacity, [64, 16]);
        let s = r.render(1.0);
        assert!(s.contains("prefill_tokens_saved=20"), "{s}");
        assert!(s.contains("cpu used=6 peak=10 cap=64"), "{s}");
    }

    #[test]
    fn class_records_count_mix_and_histogram() {
        let m = Metrics::new();
        let r = m.snapshot();
        assert_eq!(r.class_requests, [0; NUM_CLASSES]);
        assert!(r.class_alpha.iter().all(|a| a.is_nan()));
        assert!(r.drafter_hist.is_empty());
        m.record_class(Some(RequestClass::Chat), 0.5, "drafter_fp");
        m.record_class(Some(RequestClass::Chat), 1.0, "drafter_w8a8");
        m.record_class(Some(RequestClass::Translate), f64::NAN, "drafter_fp");
        m.record_class(None, 0.9, "drafter_fp"); // unclassed task
        let r = m.snapshot();
        assert_eq!(r.class_requests[RequestClass::Chat.index()], 2);
        assert_eq!(r.class_requests[RequestClass::Translate.index()], 1);
        assert_eq!(r.class_requests[RequestClass::Summarize.index()], 0);
        // Chat EWMA: seeded at 0.5, then 0.8·0.5 + 0.2·1.0 = 0.6.
        assert!((r.class_alpha[RequestClass::Chat.index()] - 0.6).abs() < 1e-12);
        // Translate never drafted: its EWMA stays unset.
        assert!(r.class_alpha[RequestClass::Translate.index()].is_nan());
        // The histogram is name-sorted and counts every retire, even the
        // unclassed one.
        assert_eq!(
            r.drafter_hist,
            vec![("drafter_fp".to_string(), 3), ("drafter_w8a8".to_string(), 1)]
        );
        let s = r.render(1.0);
        assert!(s.contains("class req: chat=2 translate=1"), "{s}");
        assert!(s.contains("drafters: [drafter_fp=3 drafter_w8a8=1]"), "{s}");
    }

    #[test]
    fn alpha_nan_when_no_drafts() {
        let m = Metrics::new();
        m.record(RequestRecord {
            sim_s: 0.1, real_s: 0.1, queue_s: 0.0,
            tokens: 5, drafted: 0, accepted: 0,
        });
        assert!(m.snapshot().mean_alpha.is_nan());
    }

    #[test]
    fn fleet_metrics_aggregate_and_render() {
        let f = FleetMetrics::new(3);
        let empty = f.snapshot();
        assert_eq!(empty.placements, vec![0, 0, 0]);
        assert!(empty.cloud_verify_frac().is_nan());

        f.record_placement(0, 0);
        f.record_placement(2, 1);
        f.record_placement(2, 0);
        f.record_placement(9, 0); // out-of-range device is ignored, not a panic
        f.record_cloud_request();
        f.record_verify_rounds(3, 1, 0.004, 12);
        f.record_verify_rounds(0, 1, 0.002, 5);

        let r = f.snapshot();
        assert_eq!(r.placements, vec![1, 0, 2]);
        assert_eq!(r.kv_filtered, 1);
        assert_eq!(r.local_verify_rounds, 3);
        assert_eq!(r.cloud_verify_rounds, 2);
        assert_eq!(r.cloud_requests, 1);
        assert_eq!(r.cloud_tokens_shipped, 17);
        assert!((r.net_s - 0.006).abs() < 1e-12);
        assert!((r.cloud_verify_frac() - 0.4).abs() < 1e-12);
        let s = r.render();
        assert!(s.contains("d0=1 d1=0 d2=2"), "{s}");
        assert!(s.contains("cloud_frac=0.400"), "{s}");
    }
}
