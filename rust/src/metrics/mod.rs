//! Serving metrics: latency recording, acceptance accounting, throughput.

use crate::util::stats::{BoxStats, Summary};
use std::sync::Mutex;

/// Thread-safe metrics sink shared by coordinator workers.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    /// Per-request simulated end-to-end latency (seconds).
    sim_latency: Summary,
    /// Per-request real wall latency (seconds).
    real_latency: Summary,
    /// Per-request queueing delay (seconds, real).
    queue_delay: Summary,
    /// Per-request acceptance rate (NaNs excluded).
    alpha: Summary,
    tokens_out: u64,
    requests: u64,
    rejected: u64,
    drafted: u64,
    accepted: u64,
}

/// One request's contribution.
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub sim_s: f64,
    pub real_s: f64,
    pub queue_s: f64,
    pub tokens: usize,
    pub drafted: usize,
    pub accepted: usize,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&self, r: RequestRecord) {
        let mut m = self.inner.lock().unwrap();
        m.sim_latency.push(r.sim_s);
        m.real_latency.push(r.real_s);
        m.queue_delay.push(r.queue_s);
        if r.drafted > 0 {
            m.alpha.push(r.accepted as f64 / r.drafted as f64);
        }
        m.tokens_out += r.tokens as u64;
        m.requests += 1;
        m.drafted += r.drafted as u64;
        m.accepted += r.accepted as u64;
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn snapshot(&self) -> Report {
        let mut m = self.inner.lock().unwrap();
        Report {
            requests: m.requests,
            rejected: m.rejected,
            tokens_out: m.tokens_out,
            mean_alpha: if m.drafted > 0 {
                m.accepted as f64 / m.drafted as f64
            } else {
                f64::NAN
            },
            sim_latency: m.sim_latency.box_stats(),
            real_latency: m.real_latency.box_stats(),
            queue_delay: m.queue_delay.box_stats(),
        }
    }
}

/// Point-in-time metrics report.
#[derive(Debug, Clone)]
pub struct Report {
    pub requests: u64,
    pub rejected: u64,
    pub tokens_out: u64,
    pub mean_alpha: f64,
    pub sim_latency: BoxStats,
    pub real_latency: BoxStats,
    pub queue_delay: BoxStats,
}

impl Report {
    pub fn render(&self, wall_s: f64) -> String {
        format!(
            "requests={} rejected={} tokens={} tok/s={:.1} mean_alpha={:.3}\n\
             sim latency  p50={:.1}ms p90={:.1}ms mean={:.1}ms\n\
             real latency p50={:.1}ms p90={:.1}ms mean={:.1}ms\n\
             queue delay  p50={:.1}ms p90={:.1}ms",
            self.requests,
            self.rejected,
            self.tokens_out,
            self.tokens_out as f64 / wall_s.max(1e-9),
            self.mean_alpha,
            self.sim_latency.median * 1e3,
            self.sim_latency.p90 * 1e3,
            self.sim_latency.mean * 1e3,
            self.real_latency.median * 1e3,
            self.real_latency.p90 * 1e3,
            self.real_latency.mean * 1e3,
            self.queue_delay.median * 1e3,
            self.queue_delay.p90 * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        for i in 0..10 {
            m.record(RequestRecord {
                sim_s: 0.1 * (i + 1) as f64,
                real_s: 0.05,
                queue_s: 0.01,
                tokens: 20,
                drafted: 10,
                accepted: 5,
            });
        }
        m.record_rejected();
        let r = m.snapshot();
        assert_eq!(r.requests, 10);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.tokens_out, 200);
        assert!((r.mean_alpha - 0.5).abs() < 1e-12);
        assert!((r.sim_latency.median - 0.55).abs() < 1e-9);
    }

    #[test]
    fn alpha_nan_when_no_drafts() {
        let m = Metrics::new();
        m.record(RequestRecord {
            sim_s: 0.1, real_s: 0.1, queue_s: 0.0,
            tokens: 5, drafted: 0, accepted: 0,
        });
        assert!(m.snapshot().mean_alpha.is_nan());
    }
}
