//! Token-level acceptance rules.
//!
//! Greedy (paper Table I: "greedy sampling is used across all experiments"):
//! a drafted token is accepted iff it equals the target argmax at that
//! position; on first mismatch the target argmax is emitted instead, so each
//! round always yields ≥ 1 target-quality token.
//!
//! Stochastic (the original speculative-sampling rule, implemented as an
//! extension): accept token x with probability min(1, p_t(x)/p_d(x));
//! on rejection, resample from norm(max(0, p_t − p_d)). This preserves the
//! target distribution exactly.
//!
//! Tree speculation adds the per-node generalisation
//! ([`tree_verify_node`]): k sibling candidates drawn from the same
//! drafter distribution q are tried in order against a shrinking residual
//! of the target distribution — accept candidate j with probability
//! min(1, r_j(x)/q(x)), on rejection r_{j+1} = norm(max(0, r_j − q)), and
//! when every sibling is rejected the correction is sampled from the
//! final residual. k = 1 is exactly the chain rule above, and the scheme
//! preserves the target distribution for any k (pinned by exhaustive
//! enumeration in the tests).

use crate::util::rng::Rng;

/// Which accept rule the decoder applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AcceptRule {
    Greedy,
    Stochastic,
}

impl AcceptRule {
    pub fn parse(s: &str) -> anyhow::Result<AcceptRule> {
        match s {
            "greedy" => Ok(AcceptRule::Greedy),
            "stochastic" => Ok(AcceptRule::Stochastic),
            _ => anyhow::bail!("accept rule must be greedy|stochastic, got {s:?}"),
        }
    }
}

/// Re-shape a probability distribution in place to temperature `t`:
/// `p_i ← p_i^(1/t) / Σ p_j^(1/t)`. `t = 1` is the identity (skipped —
/// the default [`crate::api::SamplingMode`] pays nothing); `t < 1`
/// sharpens toward the argmax, `t > 1` flattens. Non-positive or
/// non-finite temperatures are rejected upstream
/// ([`crate::api::GenOptions::validate`]) and ignored here.
pub fn apply_temperature(p: &mut [f32], temperature: f32) {
    if !(temperature.is_finite() && temperature > 0.0) || (temperature - 1.0).abs() < 1e-9 {
        return;
    }
    let inv_t = 1.0 / temperature;
    let mut z = 0.0f32;
    for v in p.iter_mut() {
        *v = v.max(0.0).powf(inv_t);
        z += *v;
    }
    if z > 0.0 {
        for v in p.iter_mut() {
            *v /= z;
        }
    }
}

/// Greedy rule: length of the leading run where drafted == target argmax.
pub fn greedy_accept_len(drafted: &[u32], target_argmax: &[u32]) -> usize {
    debug_assert!(target_argmax.len() >= drafted.len());
    drafted
        .iter()
        .zip(target_argmax)
        .take_while(|(d, t)| d == t)
        .count()
}

/// Outcome of the stochastic rule for one round.
#[derive(Debug, Clone)]
pub struct StochasticOutcome {
    /// Number of leading drafted tokens accepted.
    pub n_accepted: usize,
    /// The correction token (resampled on rejection, or the bonus token
    /// sampled from the target at position γ when everything was accepted).
    pub correction: u32,
}

/// Leviathan et al. Alg. 1 over one speculation round.
///
/// `draft_probs[i]` / `target_probs[i]` are the distributions at drafted
/// position i; `target_probs[gamma]` is the bonus-position distribution.
pub fn stochastic_accept(
    drafted: &[u32],
    draft_probs: &[Vec<f32>],
    target_probs: &[Vec<f32>],
    rng: &mut Rng,
) -> StochasticOutcome {
    let gamma = drafted.len();
    debug_assert_eq!(draft_probs.len(), gamma);
    debug_assert!(target_probs.len() >= gamma + 1);
    for i in 0..gamma {
        let x = drafted[i] as usize;
        let pt = target_probs[i][x].max(0.0);
        let pd = draft_probs[i][x].max(1e-30);
        let accept_p = (pt / pd).min(1.0);
        if rng.f64() >= accept_p as f64 {
            // Rejected: resample from norm(max(0, p_t − p_d)).
            let resid: Vec<f32> = target_probs[i]
                .iter()
                .zip(&draft_probs[i])
                .map(|(&t, &d)| (t - d).max(0.0))
                .collect();
            let z: f32 = resid.iter().sum();
            let correction = if z <= 0.0 {
                top1(&target_probs[i])
            } else {
                sample_categorical(&resid, z, rng)
            };
            return StochasticOutcome { n_accepted: i, correction };
        }
    }
    // All accepted: bonus token from the target's γ-position distribution.
    let z: f32 = target_probs[gamma].iter().sum();
    let correction = sample_categorical(&target_probs[gamma], z, rng);
    StochasticOutcome { n_accepted: gamma, correction }
}

/// Index of the largest score (first-max wins on ties) — the k = 1 case
/// of [`top_k_into`], shared by the stochastic fallback and single-branch
/// tree expansion. Works on any score slice (logits or probabilities).
pub fn top1(p: &[f32]) -> u32 {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in p.iter().enumerate() {
        if v > bv {
            bv = v;
            bi = i;
        }
    }
    bi as u32
}

/// Partial top-k selection without a full-vocab sort: one pass over the
/// scores maintaining a k-element insertion buffer in `out` (descending
/// score, earlier index first on ties — so `out[0]` always equals
/// [`top1`]). `out` is caller-owned scratch: reusing it across calls makes
/// the per-level tree expansion allocation-free in steady state.
pub fn top_k_into(p: &[f32], k: usize, out: &mut Vec<u32>) {
    out.clear();
    if k == 0 {
        return;
    }
    for (i, &v) in p.iter().enumerate() {
        if out.len() == k && v <= p[out[k - 1] as usize] {
            continue;
        }
        // Strict > keeps the earlier index ahead of an equal later one.
        let pos = out.iter().position(|&j| v > p[j as usize]).unwrap_or(out.len());
        out.insert(pos, i as u32);
        if out.len() > k {
            out.pop();
        }
    }
}

/// Verdict of [`tree_verify_node`] for one node's sibling set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeVerdict {
    /// `children[j]` was accepted — descend into that branch.
    Accepted(usize),
    /// Every sibling was rejected; the correction token sampled from the
    /// final residual ends the round at this node.
    Rejected(u32),
}

/// SpecInfer-style residual verification at one tree node.
///
/// `children` are the k candidate tokens (in proposal order) that were all
/// drafted from the same drafter distribution `q` at this node; `target`
/// is the target distribution there. Accept candidate j with probability
/// min(1, r_j(x)/q(x)) where r_1 = target and, on each rejection,
/// r_{j+1} = norm(max(0, r_j − q)). With k = 1 this is exactly
/// [`stochastic_accept`]'s per-position rule (same RNG-draw pattern: one
/// uniform per candidate, plus one for the correction sample).
pub fn tree_verify_node(
    children: &[u32],
    q: &[f32],
    target: &[f32],
    rng: &mut Rng,
) -> NodeVerdict {
    debug_assert_eq!(q.len(), target.len());
    let mut resid = target.to_vec();
    for (j, &c) in children.iter().enumerate() {
        let x = c as usize;
        let pt = resid[x].max(0.0);
        let pd = q[x].max(1e-30);
        let accept_p = (pt / pd).min(1.0);
        if rng.f64() < accept_p as f64 {
            return NodeVerdict::Accepted(j);
        }
        // Rejected: subtract the proposal and renormalise the residual.
        let mut z = 0.0f32;
        for (r, &d) in resid.iter_mut().zip(q) {
            *r = (*r - d).max(0.0);
            z += *r;
        }
        if z <= 0.0 {
            // Proposal covered the whole residual (q ≥ r pointwise, only
            // possible to f32 precision): fall back to the target mode.
            return NodeVerdict::Rejected(top1(target));
        }
        for r in resid.iter_mut() {
            *r /= z;
        }
    }
    let z: f32 = resid.iter().sum();
    NodeVerdict::Rejected(sample_categorical(&resid, z, rng))
}

/// Sample from an unnormalised distribution (mode fallback on zero mass) —
/// the tree round's bonus-token sampler at full accepted depth.
pub fn sample_from(p: &[f32], rng: &mut Rng) -> u32 {
    let z: f32 = p.iter().sum();
    if z <= 0.0 {
        top1(p)
    } else {
        sample_categorical(p, z, rng)
    }
}

fn sample_categorical(weights: &[f32], z: f32, rng: &mut Rng) -> u32 {
    let mut u = rng.f64() as f32 * z;
    for (i, &w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i as u32;
        }
    }
    (weights.len() - 1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_prefix() {
        assert_eq!(greedy_accept_len(&[1, 2, 3], &[1, 2, 3, 9]), 3);
        assert_eq!(greedy_accept_len(&[1, 9, 3], &[1, 2, 3, 9]), 1);
        assert_eq!(greedy_accept_len(&[9], &[1, 2]), 0);
        assert_eq!(greedy_accept_len(&[], &[1]), 0);
    }

    #[test]
    fn stochastic_identical_distributions_accept_all() {
        // p_t == p_d ⇒ accept probability 1 for the drafted token.
        let p = vec![0.25f32; 4];
        let mut rng = Rng::new(1);
        let out = stochastic_accept(
            &[0, 1],
            &[p.clone(), p.clone()],
            &[p.clone(), p.clone(), p.clone()],
            &mut rng,
        );
        assert_eq!(out.n_accepted, 2);
    }

    #[test]
    fn stochastic_zero_target_prob_rejects() {
        // Target gives the drafted token probability 0 ⇒ always reject and
        // resample from the target's residual mass.
        let pd = vec![1.0f32, 0.0, 0.0, 0.0];
        let pt = vec![0.0f32, 1.0, 0.0, 0.0];
        let mut rng = Rng::new(2);
        let out = stochastic_accept(&[0], &[pd], &[pt.clone(), pt], &mut rng);
        assert_eq!(out.n_accepted, 0);
        assert_eq!(out.correction, 1);
    }

    #[test]
    fn stochastic_preserves_target_marginal() {
        // Empirical check of the distribution-preservation property on a
        // two-symbol toy: the first emitted token must follow p_t.
        let pd = vec![0.9f32, 0.1];
        let pt = vec![0.5f32, 0.5];
        let mut rng = Rng::new(3);
        let n = 50_000;
        let mut count1 = 0usize;
        for _ in 0..n {
            // Draft proposes from p_d.
            let d = if rng.f64() < 0.9 { 0u32 } else { 1u32 };
            let out = stochastic_accept(
                &[d],
                &[pd.clone()],
                &[pt.clone(), pt.clone()],
                &mut rng,
            );
            let tok = if out.n_accepted == 1 {
                d
            } else {
                out.correction
            };
            count1 += (tok == 1) as usize;
        }
        let frac = count1 as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.01, "{frac}");
    }

    #[test]
    fn accept_rule_parse() {
        assert_eq!(AcceptRule::parse("greedy").unwrap(), AcceptRule::Greedy);
        assert!(AcceptRule::parse("x").is_err());
    }

    #[test]
    fn temperature_one_is_identity() {
        let orig = vec![0.1f32, 0.2, 0.3, 0.4];
        let mut p = orig.clone();
        apply_temperature(&mut p, 1.0);
        assert_eq!(p, orig);
        // Invalid temperatures are ignored (validated upstream).
        apply_temperature(&mut p, 0.0);
        assert_eq!(p, orig);
        apply_temperature(&mut p, f32::NAN);
        assert_eq!(p, orig);
    }

    #[test]
    fn top_k_matches_sort_and_top1() {
        let mut rng = Rng::new(7);
        let mut out = Vec::new();
        for _ in 0..200 {
            let n = 1 + rng.below(40) as usize;
            let p: Vec<f32> = (0..n).map(|_| (rng.below(9) as f32) / 8.0).collect();
            for k in 1..=4usize.min(n) {
                top_k_into(&p, k, &mut out);
                // Reference: stable full sort by descending score.
                let mut idx: Vec<u32> = (0..n as u32).collect();
                idx.sort_by(|&a, &b| {
                    p[b as usize].partial_cmp(&p[a as usize]).unwrap().then(a.cmp(&b))
                });
                assert_eq!(out, idx[..k], "p={p:?} k={k}");
                assert_eq!(out[0], top1(&p));
            }
        }
        // k larger than the vocab just returns everything, ordered.
        top_k_into(&[0.1, 0.7, 0.2], 8, &mut out);
        assert_eq!(out, [1, 2, 0]);
        top_k_into(&[0.5, 0.5], 1, &mut out);
        assert_eq!(out, [0]); // first-max wins, like top1
    }

    #[test]
    fn tree_node_width_one_matches_chain_rule() {
        // Same seed ⇒ identical RNG-draw pattern ⇒ identical verdicts for
        // k = 1 trees and the chain's per-position rule.
        let mut rng = Rng::new(11);
        for _ in 0..500 {
            let v = 2 + rng.below(6) as usize;
            let mk = |rng: &mut Rng| {
                let mut p: Vec<f32> = (0..v).map(|_| rng.f64() as f32).collect();
                let z: f32 = p.iter().sum();
                p.iter_mut().for_each(|x| *x /= z);
                p
            };
            let q = mk(&mut rng);
            let t = mk(&mut rng);
            let tok = rng.below(v as u64) as u32;
            let mut r1 = Rng::new(42);
            let mut r2 = Rng::new(42);
            let chain = stochastic_accept(&[tok], &[q.clone()], &[t.clone(), t.clone()], &mut r1);
            // Chain draws one extra uniform for the bonus on full accept;
            // compare only the per-position decision + correction.
            match tree_verify_node(&[tok], &q, &t, &mut r2) {
                NodeVerdict::Accepted(0) => assert_eq!(chain.n_accepted, 1),
                NodeVerdict::Accepted(_) => unreachable!(),
                NodeVerdict::Rejected(c) => {
                    assert_eq!(chain.n_accepted, 0);
                    assert_eq!(chain.correction, c);
                }
            }
        }
    }

    #[test]
    fn tree_node_preserves_target_exactly_by_enumeration() {
        // Exhaustive enumeration on a 3-token vocab with k = 2 siblings:
        // integrate the residual rule analytically over every candidate
        // tuple (x1, x2) ~ q ⊗ q and every accept/reject branch, and check
        // the induced emission distribution equals the target to ~1e-6.
        let q = [0.6f64, 0.3, 0.1];
        let p = [0.2f64, 0.5, 0.3];
        let norm_sub = |a: &[f64; 3], b: &[f64; 3]| {
            let mut r = [0.0f64; 3];
            let mut z = 0.0;
            for i in 0..3 {
                r[i] = (a[i] - b[i]).max(0.0);
                z += r[i];
            }
            if z > 0.0 {
                r.iter_mut().for_each(|x| *x /= z);
            }
            r
        };
        let mut emission = [0.0f64; 3];
        for x1 in 0..3 {
            for x2 in 0..3 {
                let w = q[x1] * q[x2];
                let a1 = (p[x1] / q[x1]).min(1.0);
                emission[x1] += w * a1;
                let p2 = norm_sub(&p, &q);
                let a2 = (p2[x2] / q[x2]).min(1.0);
                emission[x2] += w * (1.0 - a1) * a2;
                let p3 = norm_sub(&p2, &q);
                for (e, &m) in emission.iter_mut().zip(&p3) {
                    *e += w * (1.0 - a1) * (1.0 - a2) * m;
                }
            }
        }
        for i in 0..3 {
            assert!((emission[i] - p[i]).abs() < 1e-9, "{emission:?} vs {p:?}");
        }

        // And the implementation follows that math empirically: sample the
        // same scheme through tree_verify_node and compare frequencies.
        let qf: Vec<f32> = q.iter().map(|&x| x as f32).collect();
        let pf: Vec<f32> = p.iter().map(|&x| x as f32).collect();
        let mut rng = Rng::new(13);
        let n = 60_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            let draw = |rng: &mut Rng| {
                let u = rng.f64();
                if u < q[0] {
                    0u32
                } else if u < q[0] + q[1] {
                    1
                } else {
                    2
                }
            };
            let kids = [draw(&mut rng), draw(&mut rng)];
            let tok = match tree_verify_node(&kids, &qf, &pf, &mut rng) {
                NodeVerdict::Accepted(j) => kids[j],
                NodeVerdict::Rejected(c) => c,
            };
            counts[tok as usize] += 1;
        }
        for i in 0..3 {
            let f = counts[i] as f64 / n as f64;
            assert!((f - p[i]).abs() < 0.012, "tok {i}: {f} vs {}", p[i]);
        }
    }

    #[test]
    fn temperature_sharpens_and_flattens() {
        let mut sharp = vec![0.1f32, 0.2, 0.3, 0.4];
        apply_temperature(&mut sharp, 0.5);
        let mut flat = vec![0.1f32, 0.2, 0.3, 0.4];
        apply_temperature(&mut flat, 4.0);
        // Still distributions.
        let zs: f32 = sharp.iter().sum();
        let zf: f32 = flat.iter().sum();
        assert!((zs - 1.0).abs() < 1e-5 && (zf - 1.0).abs() < 1e-5);
        // Cold shifts mass toward the mode; hot flattens toward uniform.
        assert!(sharp[3] > 0.4 && sharp[0] < 0.1, "{sharp:?}");
        assert!(flat[3] < 0.4 && flat[0] > 0.1, "{flat:?}");
        // Argmax is temperature-invariant.
        assert!(sharp[3] > sharp[2] && flat[3] > flat[2]);
    }
}
