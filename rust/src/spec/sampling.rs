//! Token-level acceptance rules.
//!
//! Greedy (paper Table I: "greedy sampling is used across all experiments"):
//! a drafted token is accepted iff it equals the target argmax at that
//! position; on first mismatch the target argmax is emitted instead, so each
//! round always yields ≥ 1 target-quality token.
//!
//! Stochastic (the original speculative-sampling rule, implemented as an
//! extension): accept token x with probability min(1, p_t(x)/p_d(x));
//! on rejection, resample from norm(max(0, p_t − p_d)). This preserves the
//! target distribution exactly.

use crate::util::rng::Rng;

/// Which accept rule the decoder applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AcceptRule {
    Greedy,
    Stochastic,
}

impl AcceptRule {
    pub fn parse(s: &str) -> anyhow::Result<AcceptRule> {
        match s {
            "greedy" => Ok(AcceptRule::Greedy),
            "stochastic" => Ok(AcceptRule::Stochastic),
            _ => anyhow::bail!("accept rule must be greedy|stochastic, got {s:?}"),
        }
    }
}

/// Re-shape a probability distribution in place to temperature `t`:
/// `p_i ← p_i^(1/t) / Σ p_j^(1/t)`. `t = 1` is the identity (skipped —
/// the default [`crate::api::SamplingMode`] pays nothing); `t < 1`
/// sharpens toward the argmax, `t > 1` flattens. Non-positive or
/// non-finite temperatures are rejected upstream
/// ([`crate::api::GenOptions::validate`]) and ignored here.
pub fn apply_temperature(p: &mut [f32], temperature: f32) {
    if !(temperature.is_finite() && temperature > 0.0) || (temperature - 1.0).abs() < 1e-9 {
        return;
    }
    let inv_t = 1.0 / temperature;
    let mut z = 0.0f32;
    for v in p.iter_mut() {
        *v = v.max(0.0).powf(inv_t);
        z += *v;
    }
    if z > 0.0 {
        for v in p.iter_mut() {
            *v /= z;
        }
    }
}

/// Greedy rule: length of the leading run where drafted == target argmax.
pub fn greedy_accept_len(drafted: &[u32], target_argmax: &[u32]) -> usize {
    debug_assert!(target_argmax.len() >= drafted.len());
    drafted
        .iter()
        .zip(target_argmax)
        .take_while(|(d, t)| d == t)
        .count()
}

/// Outcome of the stochastic rule for one round.
#[derive(Debug, Clone)]
pub struct StochasticOutcome {
    /// Number of leading drafted tokens accepted.
    pub n_accepted: usize,
    /// The correction token (resampled on rejection, or the bonus token
    /// sampled from the target at position γ when everything was accepted).
    pub correction: u32,
}

/// Leviathan et al. Alg. 1 over one speculation round.
///
/// `draft_probs[i]` / `target_probs[i]` are the distributions at drafted
/// position i; `target_probs[gamma]` is the bonus-position distribution.
pub fn stochastic_accept(
    drafted: &[u32],
    draft_probs: &[Vec<f32>],
    target_probs: &[Vec<f32>],
    rng: &mut Rng,
) -> StochasticOutcome {
    let gamma = drafted.len();
    debug_assert_eq!(draft_probs.len(), gamma);
    debug_assert!(target_probs.len() >= gamma + 1);
    for i in 0..gamma {
        let x = drafted[i] as usize;
        let pt = target_probs[i][x].max(0.0);
        let pd = draft_probs[i][x].max(1e-30);
        let accept_p = (pt / pd).min(1.0);
        if rng.f64() >= accept_p as f64 {
            // Rejected: resample from norm(max(0, p_t − p_d)).
            let resid: Vec<f32> = target_probs[i]
                .iter()
                .zip(&draft_probs[i])
                .map(|(&t, &d)| (t - d).max(0.0))
                .collect();
            let z: f32 = resid.iter().sum();
            let correction = if z <= 0.0 {
                argmax(&target_probs[i])
            } else {
                sample_categorical(&resid, z, rng)
            };
            return StochasticOutcome { n_accepted: i, correction };
        }
    }
    // All accepted: bonus token from the target's γ-position distribution.
    let z: f32 = target_probs[gamma].iter().sum();
    let correction = sample_categorical(&target_probs[gamma], z, rng);
    StochasticOutcome { n_accepted: gamma, correction }
}

fn argmax(p: &[f32]) -> u32 {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in p.iter().enumerate() {
        if v > bv {
            bv = v;
            bi = i;
        }
    }
    bi as u32
}

fn sample_categorical(weights: &[f32], z: f32, rng: &mut Rng) -> u32 {
    let mut u = rng.f64() as f32 * z;
    for (i, &w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i as u32;
        }
    }
    (weights.len() - 1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_prefix() {
        assert_eq!(greedy_accept_len(&[1, 2, 3], &[1, 2, 3, 9]), 3);
        assert_eq!(greedy_accept_len(&[1, 9, 3], &[1, 2, 3, 9]), 1);
        assert_eq!(greedy_accept_len(&[9], &[1, 2]), 0);
        assert_eq!(greedy_accept_len(&[], &[1]), 0);
    }

    #[test]
    fn stochastic_identical_distributions_accept_all() {
        // p_t == p_d ⇒ accept probability 1 for the drafted token.
        let p = vec![0.25f32; 4];
        let mut rng = Rng::new(1);
        let out = stochastic_accept(
            &[0, 1],
            &[p.clone(), p.clone()],
            &[p.clone(), p.clone(), p.clone()],
            &mut rng,
        );
        assert_eq!(out.n_accepted, 2);
    }

    #[test]
    fn stochastic_zero_target_prob_rejects() {
        // Target gives the drafted token probability 0 ⇒ always reject and
        // resample from the target's residual mass.
        let pd = vec![1.0f32, 0.0, 0.0, 0.0];
        let pt = vec![0.0f32, 1.0, 0.0, 0.0];
        let mut rng = Rng::new(2);
        let out = stochastic_accept(&[0], &[pd], &[pt.clone(), pt], &mut rng);
        assert_eq!(out.n_accepted, 0);
        assert_eq!(out.correction, 1);
    }

    #[test]
    fn stochastic_preserves_target_marginal() {
        // Empirical check of the distribution-preservation property on a
        // two-symbol toy: the first emitted token must follow p_t.
        let pd = vec![0.9f32, 0.1];
        let pt = vec![0.5f32, 0.5];
        let mut rng = Rng::new(3);
        let n = 50_000;
        let mut count1 = 0usize;
        for _ in 0..n {
            // Draft proposes from p_d.
            let d = if rng.f64() < 0.9 { 0u32 } else { 1u32 };
            let out = stochastic_accept(
                &[d],
                &[pd.clone()],
                &[pt.clone(), pt.clone()],
                &mut rng,
            );
            let tok = if out.n_accepted == 1 {
                d
            } else {
                out.correction
            };
            count1 += (tok == 1) as usize;
        }
        let frac = count1 as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.01, "{frac}");
    }

    #[test]
    fn accept_rule_parse() {
        assert_eq!(AcceptRule::parse("greedy").unwrap(), AcceptRule::Greedy);
        assert!(AcceptRule::parse("x").is_err());
    }

    #[test]
    fn temperature_one_is_identity() {
        let orig = vec![0.1f32, 0.2, 0.3, 0.4];
        let mut p = orig.clone();
        apply_temperature(&mut p, 1.0);
        assert_eq!(p, orig);
        // Invalid temperatures are ignored (validated upstream).
        apply_temperature(&mut p, 0.0);
        assert_eq!(p, orig);
        apply_temperature(&mut p, f32::NAN);
        assert_eq!(p, orig);
    }

    #[test]
    fn temperature_sharpens_and_flattens() {
        let mut sharp = vec![0.1f32, 0.2, 0.3, 0.4];
        apply_temperature(&mut sharp, 0.5);
        let mut flat = vec![0.1f32, 0.2, 0.3, 0.4];
        apply_temperature(&mut flat, 4.0);
        // Still distributions.
        let zs: f32 = sharp.iter().sum();
        let zf: f32 = flat.iter().sum();
        assert!((zs - 1.0).abs() < 1e-5 && (zf - 1.0).abs() < 1e-5);
        // Cold shifts mass toward the mode; hot flattens toward uniform.
        assert!(sharp[3] > 0.4 && sharp[0] < 0.1, "{sharp:?}");
        assert!(flat[3] < 0.4 && flat[0] > 0.1, "{flat:?}");
        // Argmax is temperature-invariant.
        assert!(sharp[3] > sharp[2] && flat[3] > flat[2]);
    }
}
