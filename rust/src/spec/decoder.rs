//! Decode configuration, outcome accounting, and the run-to-completion
//! [`Decoder`] façade.
//!
//! The actual decode loops live in [`super::session`]: every path —
//! autoregressive baseline, modular speculation (paper Fig. 4) and
//! monolithic speculation (paper Fig. 3) — is a [`DecodeSession`] stepped
//! one round at a time. `Decoder` keeps the historical one-shot API for
//! experiments, benches and the CLI: construct a session, step it to
//! completion, hand back the aggregate [`DecodeOutcome`].

use crate::api::FinishReason;
use crate::config::{ExecMode, KernelPath};
use crate::hetero::{LatencyModel, Mapping};
use crate::models::VariantKey;
use crate::runtime::Engine;
use crate::util::rng::Rng;

use super::sampling::AcceptRule;
use super::session::DecodeSession;

/// Static decode configuration (one per serving worker / experiment run).
#[derive(Debug, Clone)]
pub struct DecoderSetup {
    pub drafter: VariantKey,
    pub target: VariantKey,
    pub kernel: KernelPath,
    pub mapping: Mapping,
    pub gamma: usize,
    pub rule: AcceptRule,
    pub exec: ExecMode,
    pub max_new: usize,
}

impl DecoderSetup {
    /// The paper's deployed configuration: semi-quantized pair, γ=5,
    /// variant 1 heterogeneous mapping, modular execution, greedy rule.
    pub fn paper_default() -> DecoderSetup {
        DecoderSetup {
            drafter: VariantKey::parse("drafter_fp").unwrap(),
            target: VariantKey::parse("target_w8a8").unwrap(),
            kernel: KernelPath::Pallas,
            mapping: Mapping::heterogeneous(1),
            gamma: 5,
            rule: AcceptRule::Greedy,
            exec: ExecMode::Modular,
            max_new: 64,
        }
    }
}

/// Result of decoding one request.
#[derive(Debug, Clone, Default)]
pub struct DecodeOutcome {
    /// Generated tokens (completion only, EOS excluded).
    pub tokens: Vec<u32>,
    /// Speculation rounds (0 for the baseline path).
    pub n_rounds: usize,
    /// Drafted / accepted counts (α = accepted/drafted).
    pub n_drafted: usize,
    pub n_accepted: usize,
    /// Forward-call counts (modular: γ+1 per round; mono: 1 per round).
    pub drafter_calls: usize,
    pub target_calls: usize,
    /// Simulated i.MX95 seconds (paper-comparable).
    pub sim_s: f64,
    /// Real PJRT wall-clock seconds on this machine.
    pub real_s: f64,
    /// Tree-speculation accounting: rounds run as a (k, d) tree, and the
    /// real vs executed-after-padding lane totals across their dispatches
    /// (lane utilization = real/executed). All 0 on chain-only decodes.
    pub tree_rounds: usize,
    pub tree_lanes_real: usize,
    pub tree_lanes_executed: usize,
    /// Why the decode ended ([`FinishReason::Length`] covers both the
    /// `max_new` cap and bucket-space exhaustion; cancellation/deadline
    /// aborts are stamped by the serving worker, not the session).
    pub finish: FinishReason,
}

impl DecodeOutcome {
    /// Per-request empirical acceptance rate.
    pub fn alpha(&self) -> f64 {
        if self.n_drafted == 0 {
            return f64::NAN;
        }
        self.n_accepted as f64 / self.n_drafted as f64
    }
}

/// Speculative / baseline decoder bound to one engine + platform.
pub struct Decoder<'e> {
    pub engine: &'e Engine,
    pub lat: LatencyModel,
    pub setup: DecoderSetup,
    rng: std::cell::RefCell<Rng>,
}

impl<'e> Decoder<'e> {
    pub fn new(engine: &'e Engine, lat: LatencyModel, setup: DecoderSetup) -> Decoder<'e> {
        Decoder { engine, lat, setup, rng: std::cell::RefCell::new(Rng::new(0x5EED)) }
    }

    pub fn reseed(&self, seed: u64) {
        *self.rng.borrow_mut() = Rng::new(seed);
    }

    /// Plain autoregressive decoding with the target model only.
    pub fn baseline(&self, prompt: &[u32]) -> anyhow::Result<DecodeOutcome> {
        self.run_to_completion(prompt, false)
    }

    /// Speculative decoding; dispatches on the configured exec mode.
    pub fn speculative(&self, prompt: &[u32]) -> anyhow::Result<DecodeOutcome> {
        self.run_to_completion(prompt, true)
    }

    /// Start a resumable session without driving it (round-level callers).
    pub fn session(&self, prompt: &[u32], speculative: bool) -> DecodeSession {
        DecodeSession::new(self.engine, self.lat.clone(), self.setup.clone(), speculative, prompt)
            .with_rng(self.rng.borrow().clone())
    }

    fn run_to_completion(
        &self,
        prompt: &[u32],
        speculative: bool,
    ) -> anyhow::Result<DecodeOutcome> {
        let mut session = self.session(prompt, speculative);
        while !session.is_done() {
            session.step(self.engine)?;
        }
        // Carry the advanced RNG stream back so repeated stochastic decodes
        // through one Decoder keep their historical stream behavior.
        *self.rng.borrow_mut() = session.rng_state();
        Ok(session.into_outcome())
    }
}
