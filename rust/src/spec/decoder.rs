//! Decode loops: autoregressive baseline + speculative sampling in both
//! compiler abstractions (modular / monolithic).
//!
//! Every loop advances two clocks:
//! * **real** — wall-clock of the PJRT CPU executions on this machine;
//! * **simulated** — the calibrated i.MX95 latency model (what the paper's
//!   numbers correspond to; see `hetero`). The modular path charges one
//!   dispatch boundary per call (γ+1 per round); the monolithic path charges
//!   a single boundary per round — exactly the overhead trade-off the paper
//!   discusses in §IV-D.

use crate::config::{ExecMode, KernelPath};
use crate::hetero::{LatencyModel, Mapping};
use crate::models::{Scheme, VariantKey};
use crate::runtime::Engine;
use crate::tokenizer::EOS_ID;
use crate::util::rng::Rng;

use super::sampling::{greedy_accept_len, stochastic_accept, AcceptRule};

/// Static decode configuration (one per serving worker / experiment run).
#[derive(Debug, Clone)]
pub struct DecoderSetup {
    pub drafter: VariantKey,
    pub target: VariantKey,
    pub kernel: KernelPath,
    pub mapping: Mapping,
    pub gamma: usize,
    pub rule: AcceptRule,
    pub exec: ExecMode,
    pub max_new: usize,
}

impl DecoderSetup {
    /// The paper's deployed configuration: semi-quantized pair, γ=5,
    /// variant 1 heterogeneous mapping, modular execution, greedy rule.
    pub fn paper_default() -> DecoderSetup {
        DecoderSetup {
            drafter: VariantKey::parse("drafter_fp").unwrap(),
            target: VariantKey::parse("target_w8a8").unwrap(),
            kernel: KernelPath::Pallas,
            mapping: Mapping::heterogeneous(1),
            gamma: 5,
            rule: AcceptRule::Greedy,
            exec: ExecMode::Modular,
            max_new: 64,
        }
    }
}

/// Result of decoding one request.
#[derive(Debug, Clone)]
pub struct DecodeOutcome {
    /// Generated tokens (completion only, EOS excluded).
    pub tokens: Vec<u32>,
    /// Speculation rounds (0 for the baseline path).
    pub n_rounds: usize,
    /// Drafted / accepted counts (α = accepted/drafted).
    pub n_drafted: usize,
    pub n_accepted: usize,
    /// Forward-call counts (modular: γ+1 per round; mono: 1 per round).
    pub drafter_calls: usize,
    pub target_calls: usize,
    /// Simulated i.MX95 seconds (paper-comparable).
    pub sim_s: f64,
    /// Real PJRT wall-clock seconds on this machine.
    pub real_s: f64,
}

impl DecodeOutcome {
    /// Per-request empirical acceptance rate.
    pub fn alpha(&self) -> f64 {
        if self.n_drafted == 0 {
            return f64::NAN;
        }
        self.n_accepted as f64 / self.n_drafted as f64
    }
}

/// Speculative / baseline decoder bound to one engine + platform.
pub struct Decoder<'e> {
    pub engine: &'e Engine,
    pub lat: LatencyModel,
    pub setup: DecoderSetup,
    rng: std::cell::RefCell<Rng>,
}

impl<'e> Decoder<'e> {
    pub fn new(engine: &'e Engine, lat: LatencyModel, setup: DecoderSetup) -> Decoder<'e> {
        Decoder { engine, lat, setup, rng: std::cell::RefCell::new(Rng::new(0x5EED)) }
    }

    pub fn reseed(&self, seed: u64) {
        *self.rng.borrow_mut() = Rng::new(seed);
    }

    fn scheme_of(&self, key: VariantKey) -> Scheme {
        key.scheme
    }

    /// Simulated seconds for one forward of `key` on its mapped PU at
    /// `bucket` (bucketed deployment: padded shapes run at bucket cost).
    fn sim_forward(&self, key: VariantKey, bucket: usize) -> anyhow::Result<f64> {
        let spec = self.engine.manifest.model_for(key)?;
        let pu = match key.role {
            crate::models::Role::Drafter => self.setup.mapping.drafter,
            crate::models::Role::Target => self.setup.mapping.target,
        };
        Ok(self
            .lat
            .forward_latency(spec, self.scheme_of(key), pu, bucket))
    }

    fn gen_cap(&self, prompt_len: usize) -> usize {
        let max_total = self.engine.manifest.largest_bucket();
        self.setup
            .max_new
            .min(max_total.saturating_sub(prompt_len + self.setup.gamma.max(1)))
    }

    /// Plain autoregressive decoding with the target model only.
    pub fn baseline(&self, prompt: &[u32]) -> anyhow::Result<DecodeOutcome> {
        let mut ids: Vec<u32> = prompt.to_vec();
        let mut out = DecodeOutcome {
            tokens: vec![], n_rounds: 0, n_drafted: 0, n_accepted: 0,
            drafter_calls: 0, target_calls: 0, sim_s: 0.0, real_s: 0.0,
        };
        let cap = self.gen_cap(prompt.len());
        for _ in 0..cap {
            let bucket = self.engine.bucket_for(ids.len())?;
            let fwd = self.engine.forward(
                self.setup.target, self.setup.kernel, &ids, bucket)?;
            out.real_s += fwd.elapsed_s;
            out.sim_s += self.sim_forward(self.setup.target, bucket)?;
            out.target_calls += 1;
            let nxt = fwd.argmax(0, ids.len() - 1);
            if nxt == EOS_ID {
                break;
            }
            ids.push(nxt);
            out.tokens.push(nxt);
        }
        Ok(out)
    }

    /// Speculative decoding; dispatches on the configured exec mode.
    pub fn speculative(&self, prompt: &[u32]) -> anyhow::Result<DecodeOutcome> {
        match self.setup.exec {
            ExecMode::Modular => self.speculative_modular(prompt),
            ExecMode::Monolithic => self.speculative_monolithic(prompt),
        }
    }

    /// Modular speculation (paper Fig. 4): γ drafter calls + 1 target call
    /// per round, control flow here in Rust, one runtime-API boundary per
    /// call (charged by the latency model's dispatch overhead).
    fn speculative_modular(&self, prompt: &[u32]) -> anyhow::Result<DecodeOutcome> {
        let gamma = self.setup.gamma.max(1);
        let mut ids: Vec<u32> = prompt.to_vec();
        let mut out = DecodeOutcome {
            tokens: vec![], n_rounds: 0, n_drafted: 0, n_accepted: 0,
            drafter_calls: 0, target_calls: 0, sim_s: 0.0, real_s: 0.0,
        };
        let cap = self.gen_cap(prompt.len());
        let max_total = self.engine.manifest.largest_bucket();

        'outer: while out.tokens.len() < cap {
            let base_len = ids.len();
            let g = gamma.min(max_total - base_len - 1);
            if g == 0 {
                break;
            }
            // ---- draft phase -------------------------------------------
            let mut drafted: Vec<u32> = Vec::with_capacity(g);
            let mut draft_probs: Vec<Vec<f32>> = Vec::new();
            for i in 0..g {
                let cur = base_len + i;
                let bucket = self.engine.bucket_for(cur)?;
                let fwd = self.engine.forward(
                    self.setup.drafter, self.setup.kernel, &ids[..], bucket)?;
                out.real_s += fwd.elapsed_s;
                out.sim_s += self.sim_forward(self.setup.drafter, bucket)?;
                out.drafter_calls += 1;
                let tok = fwd.argmax(0, cur - 1);
                if self.setup.rule == AcceptRule::Stochastic {
                    draft_probs.push(fwd.probs(0, cur - 1));
                }
                drafted.push(tok);
                ids.push(tok);
            }
            // ---- verify phase ------------------------------------------
            let ver_len = ids.len();
            let bucket = self.engine.bucket_for(ver_len)?;
            let fwd = self.engine.forward(
                self.setup.target, self.setup.kernel, &ids, bucket)?;
            out.real_s += fwd.elapsed_s;
            out.sim_s += self.sim_forward(self.setup.target, bucket)?;
            out.target_calls += 1;
            out.n_rounds += 1;
            out.n_drafted += drafted.len();

            // Target decisions for positions base_len .. base_len+g.
            let target_argmax: Vec<u32> = (0..=g)
                .map(|i| fwd.argmax(0, base_len - 1 + i))
                .collect();

            let (n_acc, correction) = match self.setup.rule {
                AcceptRule::Greedy => {
                    let k = greedy_accept_len(&drafted, &target_argmax);
                    (k, target_argmax[k])
                }
                AcceptRule::Stochastic => {
                    let target_probs: Vec<Vec<f32>> = (0..=g)
                        .map(|i| fwd.probs(0, base_len - 1 + i))
                        .collect();
                    let o = stochastic_accept(
                        &drafted, &draft_probs, &target_probs,
                        &mut self.rng.borrow_mut());
                    (o.n_accepted, o.correction)
                }
            };
            out.n_accepted += n_acc;

            // Roll back unaccepted drafts, then append accepted + correction.
            ids.truncate(base_len);
            for &t in &drafted[..n_acc] {
                if t == EOS_ID {
                    break 'outer;
                }
                ids.push(t);
                out.tokens.push(t);
                if out.tokens.len() >= cap {
                    break 'outer;
                }
            }
            if correction == EOS_ID {
                break;
            }
            ids.push(correction);
            out.tokens.push(correction);
        }
        out.tokens.truncate(cap);
        Ok(out)
    }

    /// Monolithic speculation (paper Fig. 3): one fused graph per round.
    /// Simulated time charges a *single* dispatch boundary per round — the
    /// boundary saving the paper attributes to the monolithic design.
    fn speculative_monolithic(&self, prompt: &[u32]) -> anyhow::Result<DecodeOutcome> {
        let gamma = self.setup.gamma.max(1);
        let mut ids: Vec<u32> = prompt.to_vec();
        let mut out = DecodeOutcome {
            tokens: vec![], n_rounds: 0, n_drafted: 0, n_accepted: 0,
            drafter_calls: 0, target_calls: 0, sim_s: 0.0, real_s: 0.0,
        };
        let cap = self.gen_cap(prompt.len());
        let mono_seq = self
            .engine
            .manifest
            .mono(gamma)
            .map(|m| m.seq)
            .unwrap_or_else(|| self.engine.manifest.largest_bucket());

        let oh_d = self.dispatch_overhead(self.setup.mapping.drafter);
        let oh_t = self.dispatch_overhead(self.setup.mapping.target);

        'outer: while out.tokens.len() < cap && ids.len() + gamma < mono_seq {
            let base_len = ids.len();
            let step = self.engine.mono_step(gamma, &ids, base_len)?;
            out.real_s += step.elapsed_s;
            // Simulated: γ drafter + 1 target forwards at the mono bucket,
            // minus the per-call boundaries, plus ONE boundary for the round.
            let sim_d = self.sim_forward(self.setup.drafter, mono_seq)? - oh_d;
            let sim_t = self.sim_forward(self.setup.target, mono_seq)? - oh_t;
            out.sim_s += gamma as f64 * sim_d + sim_t + oh_d.max(oh_t);
            out.drafter_calls += gamma;
            out.target_calls += 1;
            out.n_rounds += 1;
            out.n_drafted += gamma;
            let n_acc = step.n_accepted.min(gamma);
            out.n_accepted += n_acc;

            for &t in &step.drafted[..n_acc] {
                if t == EOS_ID {
                    break 'outer;
                }
                ids.push(t);
                out.tokens.push(t);
                if out.tokens.len() >= cap {
                    break 'outer;
                }
            }
            let correction = step.out_tokens[n_acc];
            if correction == EOS_ID {
                break;
            }
            ids.push(correction);
            out.tokens.push(correction);
        }
        out.tokens.truncate(cap);
        Ok(out)
    }

    fn dispatch_overhead(&self, pu: crate::hetero::PuAssignment) -> f64 {
        match pu {
            crate::hetero::PuAssignment::Cpu { .. } => {
                self.lat.platform.cpu.dispatch_overhead_s
            }
            crate::hetero::PuAssignment::Gpu => self.lat.platform.gpu.dispatch_overhead_s,
        }
    }
}
