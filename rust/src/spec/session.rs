//! Resumable decode sessions: the round-level state machine behind every
//! decode path in the crate.
//!
//! A [`DecodeSession`] owns one request's token state, dual clocks
//! (simulated i.MX95 / real PJRT wall-clock) and round counters. Since the
//! fused-execution refactor the session is a *two-phase* state machine: it
//! never calls the engine itself. Instead [`DecodeSession::plan`] describes
//! the one engine call it needs next as an [`EngineRequest`] (variant,
//! kernel path, token prefix, padded bucket), and
//! [`DecodeSession::apply`] consumes that call's result — a logits row of
//! a possibly *shared* batched dispatch — and advances the state machine.
//! An executor sits between the two: the thin [`DecodeSession::step`]
//! wrapper (plan → execute batch=1 → apply) keeps the historical
//! one-round-per-call API for `Decoder`, experiments and benches, while
//! the serving scheduler's fused executor
//! ([`crate::coordinator::fuser`]) collects many live sessions' pending
//! requests per tick and dispatches each compatible group as one
//! `Engine::forward_batch` call.
//!
//! Granularity: `plan`/`apply` advance one *engine call* at a time (a
//! modular speculation round is γ drafter calls + 1 target call, each its
//! own plan/apply cycle, because draft *i* depends on draft *i−1*'s
//! output); `step` loops the cycle until a full round (or one baseline
//! token) completes, exactly reproducing the historical semantics.
//!
//! Clock accounting is identical to the old run-to-completion loops: the
//! modular path charges one dispatch boundary per forward call (γ+1 per
//! round), the monolithic path a single boundary per round — the §IV-D
//! trade-off the paper measures. Under fused execution the executor passes
//! each session its *share* of the batched dispatch cost instead (see
//! [`crate::hetero::LatencyModel::batched_forward_latency`]).

use crate::api::FinishReason;
use crate::config::{ExecMode, KernelPath};
use crate::costmodel::TreeShape;
use crate::hetero::{LatencyModel, Mapping, PuAssignment, PuRoute};
use crate::models::{Role, VariantKey};
use crate::runtime::{Engine, ForwardOut, MonoStepOut};
use crate::tokenizer::EOS_ID;
use crate::util::rng::Rng;

use super::decoder::{DecodeOutcome, DecoderSetup};
use super::sampling::{
    apply_temperature, greedy_accept_len, sample_from, stochastic_accept, top_k_into,
    tree_verify_node, AcceptRule, NodeVerdict,
};

/// Static bounds a session computes once at admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionLimits {
    /// Generation cap (tokens) for this prompt length and admission-time γ.
    pub cap: usize,
    /// Largest compiled sequence bucket (total-length ceiling).
    pub max_total: usize,
}

impl SessionLimits {
    /// The bucketed-deployment generation cap: leave room for the prompt
    /// plus one full draft window inside the largest compiled bucket.
    /// Returns 0 for prompts near the largest bucket (nothing decodable).
    pub fn compute(max_new: usize, prompt_len: usize, gamma: usize, max_total: usize) -> usize {
        max_new.min(max_total.saturating_sub(prompt_len + gamma.max(1)))
    }

    pub fn from_engine(engine: &Engine, setup: &DecoderSetup, prompt_len: usize) -> SessionLimits {
        let max_total = engine.manifest.largest_bucket();
        SessionLimits {
            cap: Self::compute(setup.max_new, prompt_len, setup.gamma, max_total),
            max_total,
        }
    }
}

/// What one decode round (one [`DecodeSession::step`], or one completed
/// plan/apply round) did.
#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    /// Tokens committed to the output by this step (EOS excluded).
    pub committed: Vec<u32>,
    /// Draft window actually run this round — the configured γ clamped at
    /// the bucket edge (0 = baseline step or no-work completion round) —
    /// and how much of it the target accepted.
    pub drafted: usize,
    pub accepted: usize,
    /// Clock increments for this step.
    pub sim_s: f64,
    pub real_s: f64,
    /// Tree-round lane accounting: real tree-node lanes dispatched this
    /// round vs lanes actually executed after padding to the compiled
    /// batch sizes. Both 0 on chain/baseline rounds, so
    /// `tree_lanes_executed > 0` identifies a tree round (its accepted
    /// root-path depth is then `accepted`).
    pub tree_lanes_real: usize,
    pub tree_lanes_executed: usize,
    /// The session finished (EOS, cap reached, or out of bucket space).
    pub done: bool,
}

/// The one engine call a session needs next, fully described so an
/// external executor can run it — alone or fused with other sessions'
/// identical-shape requests.
#[derive(Debug, Clone)]
pub struct EngineRequest {
    pub kind: RequestKind,
    /// The session's current token prefix (prompt + committed tokens +
    /// in-flight drafts). Owned, so the executor can hold many sessions'
    /// requests at once and build a batched upload without aliasing the
    /// sessions themselves.
    pub tokens: Vec<u32>,
    /// Which PU timeline(s) the dispatch occupies, resolved from the
    /// policy-chosen [`crate::hetero::Mapping`] at plan time. The per-PU
    /// timeline executor charges the dispatch here; requests routed to
    /// different PUs can proceed concurrently.
    pub route: PuRoute,
    /// Tokens of the planned variant's prefix whose KV is already resident
    /// for this session (`kv_cache: on` only; always 0 when the cache is
    /// off). Executors price the dispatch incrementally when this is
    /// non-zero — compute for the new fraction plus the DRAM re-read term
    /// ([`LatencyModel::incremental_lane_cost`]) — and fall through to the
    /// historical full-forward pricing when it is 0, so the off mode never
    /// touches the new arithmetic.
    pub kv_cached: usize,
}

/// Fusion key: requests with equal keys can share one batched dispatch.
/// Includes the routed PU — two sessions mapping the same role to
/// different PUs must not share a dispatch, since a dispatch occupies
/// exactly one PU timeline.
pub type FuseKey = (VariantKey, KernelPath, usize, PuAssignment);

impl EngineRequest {
    /// See [`FuseKey`]. `None` for monolithic spec-steps (never
    /// cross-fused). The PU component is the route's primary — the single
    /// source of truth for where the dispatch runs, so grouping and
    /// timeline charging can never disagree.
    pub fn fuse_key(&self) -> Option<FuseKey> {
        match self.kind {
            RequestKind::Forward { variant, kernel, bucket } => {
                Some((variant, kernel, bucket, self.route.primary))
            }
            // A tree dispatch already fills its own lanes; the session
            // executes it as one batched call, never cross-fused.
            RequestKind::TreeForward { .. } | RequestKind::MonoStep { .. } => None,
        }
    }
}

/// Shape of the engine call an [`EngineRequest`] asks for. The PU it runs
/// on is not part of the shape — it lives in [`EngineRequest::route`],
/// resolved from the mapping by the planned variant's role.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RequestKind {
    /// A plain forward over the request's token prefix, padded to
    /// `bucket` — fusable across sessions into one batched dispatch.
    Forward {
        variant: VariantKey,
        kernel: KernelPath,
        bucket: usize,
    },
    /// One multi-lane forward over `lanes` tree-node prefixes held by the
    /// session's in-flight speculation tree (a drafter level expansion or
    /// the flattened leaf verification). The session executes it as one
    /// batched dispatch itself — chunked over the compiled
    /// [`crate::runtime::Manifest::batch_sizes_for`] sizes and priced by
    /// [`LatencyModel::batched_forward_latency`] — so the whole tree
    /// verifies in one target forward on the mapped PU timeline.
    TreeForward {
        variant: VariantKey,
        kernel: KernelPath,
        bucket: usize,
        lanes: usize,
    },
    /// One fused monolithic spec-step graph (paper Fig. 3); always a
    /// singleton dispatch.
    MonoStep { gamma: usize },
}

/// Result of [`DecodeSession::plan`].
#[derive(Debug)]
pub enum SessionPlan {
    /// The session needs one engine call.
    Need(EngineRequest),
    /// The step completed without engine work: the session was already
    /// finished, or this round only discovered completion (cap reached,
    /// out of bucket space).
    Done(StepOutcome),
}

/// One forward result handed back to [`DecodeSession::apply`]: a row of a
/// (possibly shared) batched dispatch plus this session's share of the
/// dispatch's clock cost.
#[derive(Debug)]
pub struct ForwardReply<'a> {
    pub fwd: &'a ForwardOut,
    /// Which batch row belongs to this session.
    pub row: usize,
    /// This session's share of the dispatch's simulated seconds. For a
    /// batch=1 dispatch this is the full single-forward latency; a fused
    /// executor splits the batched cost across the sharing sessions.
    pub sim_s: f64,
    /// This session's share of the dispatch's real wall-clock seconds.
    pub real_s: f64,
}

/// Engine result for the session's pending [`EngineRequest`].
#[derive(Debug)]
pub enum EngineReply<'a> {
    Forward(ForwardReply<'a>),
    Mono(&'a MonoStepOut),
}

/// What applying one engine reply did.
#[derive(Debug)]
pub enum StepProgress {
    /// Mid-round: the session immediately has another [`EngineRequest`]
    /// (the next draft, or the verify after the last draft).
    Pending,
    /// A full speculation round (or one baseline token) completed.
    Round(StepOutcome),
}

/// Internal [`SessionPlan`] without the owned token copy — what
/// `advance_plan` produces; `plan` attaches the tokens for external
/// executors, `step` executes in place off `self.ids`.
#[derive(Debug)]
enum PlannedKind {
    Need(RequestKind),
    Done(StepOutcome),
}

/// Where the session is inside the current round.
#[derive(Debug)]
enum RoundPhase {
    /// Between rounds: the next `plan` decides baseline / draft / mono and
    /// re-reads the (possibly policy-updated) γ and speculate flags.
    Idle,
    /// Awaiting the one target forward of a baseline step.
    Baseline,
    /// Modular drafting: `drafted.len()` of `g` draft forwards applied.
    Drafting(DraftState),
    /// All `g` drafts issued; awaiting the target verify forward.
    Verifying(DraftState),
    /// Tree drafting: `levels.len()` of `depth` level expansions applied.
    TreeDrafting(TreeState),
    /// All levels drafted; awaiting the one flattened leaf verification.
    TreeVerifying(TreeState),
    /// Awaiting the fused monolithic spec-step.
    Mono { gamma: usize },
}

/// Modular-round scratch carried across the round's plan/apply cycles.
#[derive(Debug)]
struct DraftState {
    base_len: usize,
    g: usize,
    drafted: Vec<u32>,
    /// Per-draft distributions (stochastic accept rule only).
    draft_probs: Vec<Vec<f32>>,
}

/// One node of the in-flight speculation tree.
#[derive(Debug)]
struct TreeNode {
    tok: u32,
    /// Drafter distribution *at* this node — the proposal its children
    /// were selected from (stochastic rule only; filled by the level
    /// expansion that drafted them).
    q: Option<Vec<f32>>,
}

/// Tree-round scratch: the partially-built speculation tree. Requires
/// branching ≥ 2 — a 1-wide tree routes through the chain path instead.
#[derive(Debug)]
struct TreeState {
    base_len: usize,
    branching: usize,
    /// Effective depth this round (the configured depth clamped at the
    /// bucket edge, like the chain's γ → g clamp).
    depth: usize,
    /// `levels[j][m]` = node m at tree level j, i.e. the token candidate
    /// at sequence position `base_len + j`. Parent pointers are implicit:
    /// every node expands exactly `branching` children in order, so the
    /// children of `levels[j][m]` are `levels[j+1][m·k .. m·k+k]`.
    levels: Vec<Vec<TreeNode>>,
    /// Drafter distribution over the root prefix (proposal for level 0;
    /// stochastic rule only).
    root_q: Option<Vec<f32>>,
    /// Reused top-k selection scratch — with it, per-level expansion is
    /// allocation-free in steady state (satellite: single-allocation
    /// partial top-k).
    topk: Vec<u32>,
    /// Lane accounting across this round's dispatches: real tree lanes vs
    /// executed-after-padding lanes (per-round utilization metrics).
    lanes_real: usize,
    lanes_executed: usize,
}

impl TreeState {
    fn new(base_len: usize, branching: usize, depth: usize) -> TreeState {
        TreeState {
            base_len,
            branching,
            depth,
            levels: Vec::with_capacity(depth),
            root_q: None,
            topk: Vec::with_capacity(branching),
            lanes_real: 0,
            lanes_executed: 0,
        }
    }

    /// Lanes of the next level expansion: one per node being expanded.
    fn next_draft_lanes(&self) -> usize {
        self.levels.last().map_or(1, |l| l.len())
    }

    /// Token prefixes (base + root path) for the next level expansion.
    fn draft_lane_prefixes(&self, base: &[u32]) -> Vec<Vec<u32>> {
        match self.levels.len() {
            0 => vec![base.to_vec()],
            j => (0..self.levels[j - 1].len())
                .map(|m| self.path_prefix(base, j - 1, m))
                .collect(),
        }
    }

    /// Token prefixes for the flattened verification: one lane per leaf.
    fn verify_lane_prefixes(&self, base: &[u32]) -> Vec<Vec<u32>> {
        let last = self.levels.len() - 1;
        (0..self.levels[last].len())
            .map(|m| self.path_prefix(base, last, m))
            .collect()
    }

    /// `base` extended with the tokens along the root path ending at node
    /// `m` of `level` (ancestor at level l is `m / k^(level−l)`).
    fn path_prefix(&self, base: &[u32], level: usize, m: usize) -> Vec<u32> {
        let mut seq = Vec::with_capacity(base.len() + level + 1);
        seq.extend_from_slice(base);
        for l in 0..=level {
            let idx = m / self.branching.pow((level - l) as u32);
            seq.push(self.levels[l][idx].tok);
        }
        seq
    }
}

/// Counter snapshot taken at round start so per-round [`StepOutcome`]
/// deltas can't drift from the aggregate totals.
#[derive(Debug, Clone, Copy, Default)]
struct RoundBase {
    tok: usize,
    drafted: usize,
    accepted: usize,
    tree_lanes_real: usize,
    tree_lanes_executed: usize,
    sim_s: f64,
    real_s: f64,
}

/// One request's resumable decode state machine.
///
/// Construct with [`DecodeSession::new`] (or [`DecodeSession::with_limits`]
/// when no engine is at hand, e.g. in pure state-transition tests), then
/// either call [`step`](DecodeSession::step) until
/// [`is_done`](DecodeSession::is_done), or drive the two-phase
/// [`plan`](DecodeSession::plan) / [`apply`](DecodeSession::apply)
/// protocol from an external (possibly fusing) executor. Harvest the
/// aggregate [`DecodeOutcome`] via
/// [`into_outcome`](DecodeSession::into_outcome).
pub struct DecodeSession {
    setup: DecoderSetup,
    lat: LatencyModel,
    /// Prompt + committed continuation + in-flight drafts (the model input).
    ids: Vec<u32>,
    /// Aggregate outcome accumulated across steps.
    out: DecodeOutcome,
    limits: SessionLimits,
    rng: Rng,
    /// Whether the *next* round speculates (re-decidable between rounds).
    speculative: bool,
    /// Speculation-tree shape for the next round (`None` = linear chain;
    /// kept `None` for 1-wide shapes, which *are* the chain).
    tree: Option<TreeShape>,
    phase: RoundPhase,
    round_base: RoundBase,
    done: bool,
    /// Per-PU timeline position: the simulated time at which this
    /// session's last scheduled dispatch finishes (its outputs — the next
    /// call's inputs — become available). Maintained by the timeline-aware
    /// executor; stays 0 on the serialized paths.
    ready_s: f64,
    /// Sampling temperature for the stochastic accept rule (1.0 = the
    /// raw model distributions; ignored under the greedy rule, whose
    /// argmax is temperature-invariant).
    temperature: f32,
    /// Token ids treated like EOS (per-request stop tokens).
    stop_tokens: Vec<u32>,
    /// Token-id stop sequences: the session finishes — and truncates the
    /// matched suffix — when the generated output ends with any of these.
    stop_seqs: Vec<Vec<u32>>,
    /// Per-role resident-KV extent, indexed [drafter, target]: how many
    /// leading positions of `ids` each role has valid cached K/V for.
    /// `None` = `kv_cache: off` — every plan stamps `kv_cached: 0` and no
    /// incremental pricing path is ever taken. Seeded by
    /// [`set_kv_prefix`](Self::set_kv_prefix) at admission (the shared
    /// prompt prefix), grown as the round's forwards compute fresh KV, and
    /// clamped back to the committed extent after each verify (KV computed
    /// for rejected drafts — and for the correction position, whose token
    /// changed — is invalid).
    kv: Option<[usize; 2]>,
}

impl DecodeSession {
    pub fn new(
        engine: &Engine,
        lat: LatencyModel,
        setup: DecoderSetup,
        speculative: bool,
        prompt: &[u32],
    ) -> DecodeSession {
        let limits = SessionLimits::from_engine(engine, &setup, prompt.len());
        Self::with_limits(lat, setup, speculative, prompt, limits)
    }

    /// Engine-free constructor with explicit limits (tests, custom drivers).
    pub fn with_limits(
        lat: LatencyModel,
        setup: DecoderSetup,
        speculative: bool,
        prompt: &[u32],
        limits: SessionLimits,
    ) -> DecodeSession {
        DecodeSession {
            setup,
            lat,
            ids: prompt.to_vec(),
            out: DecodeOutcome::default(),
            done: limits.cap == 0,
            limits,
            rng: Rng::new(0x5EED),
            speculative,
            tree: None,
            phase: RoundPhase::Idle,
            round_base: RoundBase::default(),
            ready_s: 0.0,
            temperature: 1.0,
            stop_tokens: Vec::new(),
            stop_seqs: Vec::new(),
            kv: None,
        }
    }

    /// Replace the RNG stream (stochastic accept rule reproducibility).
    pub fn with_rng(mut self, rng: Rng) -> DecodeSession {
        self.rng = rng;
        self
    }

    /// Snapshot of the current RNG state (to continue a stream elsewhere).
    pub fn rng_state(&self) -> Rng {
        self.rng.clone()
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Whether the session is mid-round (has planned engine work whose
    /// round has not completed). Round-level policy hooks must only be
    /// applied between rounds, i.e. when this is `false`.
    pub fn mid_round(&self) -> bool {
        !matches!(self.phase, RoundPhase::Idle)
    }

    /// Current total sequence length (prompt + committed tokens).
    pub fn seq_len(&self) -> usize {
        self.ids.len()
    }

    /// Simulated time at which this session's inputs are next available
    /// (the end of its last timeline-scheduled dispatch — the readiness
    /// rule's `inputs_ready`).
    pub fn ready_s(&self) -> f64 {
        self.ready_s
    }

    /// Move the session's timeline position (set by the per-PU timeline
    /// executor after scheduling a dispatch, and at admission to the
    /// worker's current simulated "now").
    pub fn set_ready_s(&mut self, t: f64) {
        self.ready_s = t;
    }

    pub fn limits(&self) -> SessionLimits {
        self.limits
    }

    /// Peek at the running aggregate outcome.
    pub fn outcome(&self) -> &DecodeOutcome {
        &self.out
    }

    /// Running per-session acceptance rate (NaN before any draft).
    pub fn alpha_so_far(&self) -> f64 {
        self.out.alpha()
    }

    pub fn n_drafted(&self) -> usize {
        self.out.n_drafted
    }

    pub fn n_rounds(&self) -> usize {
        self.out.n_rounds
    }

    pub fn speculative(&self) -> bool {
        self.speculative
    }

    pub fn gamma(&self) -> usize {
        self.setup.gamma
    }

    /// The PU mapping frozen into this session at admission — every
    /// dispatch it plans routes on this, regardless of later online
    /// re-partition switches (round-level policy consults must price the
    /// session at *this* mapping).
    pub fn mapping(&self) -> Mapping {
        self.setup.mapping
    }

    /// Why the session finished ([`FinishReason::Length`] until a stop
    /// condition fires; meaningful once [`is_done`](Self::is_done)).
    pub fn finish_reason(&self) -> FinishReason {
        self.out.finish
    }

    /// Sampling temperature for the stochastic accept rule (per-request
    /// option; 1.0 = raw distributions). Invalid values are ignored.
    pub fn set_temperature(&mut self, t: f32) {
        if t.is_finite() && t > 0.0 {
            self.temperature = t;
        }
    }

    /// Token ids treated like EOS for this request.
    pub fn set_stop_tokens(&mut self, ids: Vec<u32>) {
        self.stop_tokens = ids;
    }

    /// Token-id stop sequences; on a suffix match the session finishes
    /// with [`FinishReason::StopSequence`] and the matched suffix is
    /// truncated from the output (empty sequences are ignored).
    pub fn set_stop_sequences(&mut self, seqs: Vec<Vec<u32>>) {
        self.stop_seqs = seqs;
        self.stop_seqs.retain(|s| !s.is_empty());
    }

    /// Enable KV-cache accounting for this session, seeding both roles'
    /// resident extent with the `shared` prompt-prefix tokens the cache
    /// manager matched at admission (0 = enabled but cold). Never calling
    /// this (`kv_cache: off`) keeps every plan at `kv_cached: 0` and the
    /// session bit-identical to the historical engine.
    pub fn set_kv_prefix(&mut self, shared: usize) {
        let shared = shared.min(self.ids.len());
        self.kv = Some([shared; 2]);
    }

    /// Per-role resident-KV extents `[drafter, target]` (`None` = cache
    /// accounting off). Test/metrics surface.
    pub fn kv_resident(&self) -> Option<[usize; 2]> {
        self.kv
    }

    /// Resident tokens usable by a `role` forward whose input prefix is
    /// `len` tokens: the role's extent, clamped to the prefix.
    fn kv_cached_for(&self, role: Role, len: usize) -> usize {
        match self.kv {
            Some(c) => c[Self::kv_role_index(role)].min(len),
            None => 0,
        }
    }

    fn kv_role_index(role: Role) -> usize {
        match role {
            Role::Drafter => 0,
            Role::Target => 1,
        }
    }

    /// A `role` forward just computed KV for the first `len` positions.
    fn note_kv_computed(&mut self, role: Role, len: usize) {
        if let Some(c) = &mut self.kv {
            let i = Self::kv_role_index(role);
            c[i] = c[i].max(len);
        }
    }

    /// Invalidate resident KV beyond `len` committed positions (rejected
    /// drafts and the correction position were computed with tokens that
    /// are no longer in `ids`).
    fn clamp_kv(&mut self, len: usize) {
        if let Some(c) = &mut self.kv {
            for x in c.iter_mut() {
                *x = (*x).min(len);
            }
        }
    }

    /// Resident tokens the *pending* plan's dispatch can reuse — what
    /// `plan` stamps on the request and the executors price with. Derived
    /// from the live phase so plan-time stamps and execute-time pricing
    /// can never disagree.
    fn kv_cached_for_pending(&self, kind: &RequestKind) -> usize {
        match (kind, &self.phase) {
            (RequestKind::Forward { variant, .. }, _) => {
                self.kv_cached_for(variant.role, self.ids.len())
            }
            // Tree lanes share the session's base prefix; per-lane path
            // tokens are fresh every round.
            (RequestKind::TreeForward { variant, .. }, RoundPhase::TreeDrafting(st))
            | (RequestKind::TreeForward { variant, .. }, RoundPhase::TreeVerifying(st)) => {
                self.kv_cached_for(variant.role, st.base_len)
            }
            // Monolithic spec-steps run the fused graph end-to-end; the
            // paged cache never prices them incrementally.
            _ => 0,
        }
    }

    /// Re-decide speculation for the next round (round-level policy hook).
    pub fn set_speculative(&mut self, on: bool) {
        self.speculative = on;
    }

    /// Re-decide the speculation-tree shape for the next round
    /// (round-level policy hook). `None` — and any 1-wide shape, which
    /// *is* the chain — selects the linear γ-chain path, so branching
    /// factor 1 reproduces today's chain streams bit-for-bit by
    /// construction. Tree rounds need the modular exec mode (the
    /// monolithic graphs are chain-shaped); under monolithic execution
    /// the shape is ignored.
    pub fn set_tree(&mut self, shape: Option<TreeShape>) {
        self.tree = shape.filter(|s| s.branches());
    }

    /// The tree shape the next speculative round will use (`None` = chain).
    pub fn tree(&self) -> Option<TreeShape> {
        self.tree
    }

    /// Re-decide γ for the next round (round-level policy hook). The
    /// generation cap stays as computed at admission; γ only shapes the
    /// next draft window.
    pub fn set_gamma(&mut self, gamma: usize) {
        self.setup.gamma = gamma.max(1);
    }

    /// [`set_gamma`](Self::set_gamma) that also respects the compiled
    /// artifact set: monolithic fused graphs exist only for the γ values
    /// the AOT build lowered, so clamp the request to the largest compiled
    /// γ at or below it that still fits the mono bucket at the current
    /// position. When nothing fits this falls back to the raw request and
    /// the session ends (or errors loudly) exactly like the
    /// run-to-completion paths — serving uses this hook, experiments keep
    /// the strict `set_gamma` so a missing artifact is never papered over.
    pub fn set_gamma_checked(&mut self, engine: &Engine, gamma: usize) {
        let gamma = gamma.max(1);
        if self.setup.exec == ExecMode::Monolithic {
            if let Some(g) = (1..=gamma).rev().find(|&g| {
                engine
                    .manifest
                    .mono(g)
                    .map(|m| self.ids.len() + g < m.seq)
                    .unwrap_or(false)
            }) {
                self.setup.gamma = g;
                return;
            }
        }
        self.setup.gamma = gamma;
    }

    /// Finish the session and produce the aggregate outcome.
    pub fn into_outcome(mut self) -> DecodeOutcome {
        self.out.tokens.truncate(self.limits.cap);
        self.out
    }

    /// Advance the session by one unit of work: one speculation round
    /// (draft γ + verify + commit) or one baseline token, executing each
    /// planned engine call unfused (batch = 1) straight off `self.ids` —
    /// no token copies on the singleton path. Stepping a finished session
    /// is a no-op that reports `done`.
    pub fn step(&mut self, engine: &Engine) -> anyhow::Result<StepOutcome> {
        loop {
            match self.advance_plan(engine)? {
                PlannedKind::Done(out) => return Ok(out),
                PlannedKind::Need(kind) => {
                    if let StepProgress::Round(out) = self.execute_kind(engine, kind)? {
                        return Ok(out);
                    }
                }
            }
        }
    }

    /// Phase 1: describe the next engine call this session needs (or
    /// report that the step completed without engine work). Calling `plan`
    /// again before `apply` re-issues the same request. The returned
    /// request owns a copy of the token prefix so a fusing executor can
    /// hold many sessions' requests at once.
    pub fn plan(&mut self, engine: &Engine) -> anyhow::Result<SessionPlan> {
        Ok(match self.advance_plan(engine)? {
            PlannedKind::Done(out) => SessionPlan::Done(out),
            PlannedKind::Need(kind) => {
                // Route resolution lives behind the decision API: the one
                // mapping → PU-route rule shared by every session.
                let route = crate::decision::resolve_route(self.setup.mapping, &kind);
                let kv_cached = self.kv_cached_for_pending(&kind);
                SessionPlan::Need(EngineRequest { kind, tokens: self.ids.clone(), route, kv_cached })
            }
        })
    }

    /// The planning state transition behind [`plan`](Self::plan) /
    /// [`step`](Self::step): advance `Idle` into the next round's first
    /// phase (or completion) and name the pending engine call.
    fn advance_plan(&mut self, engine: &Engine) -> anyhow::Result<PlannedKind> {
        if self.done {
            return Ok(PlannedKind::Done(StepOutcome { done: true, ..StepOutcome::default() }));
        }
        if !self.mid_round() {
            // Round start: snapshot the counters for per-round deltas and
            // decide the round shape from the (policy-updatable) flags.
            self.round_base = self.counters();
            if self.out.tokens.len() >= self.limits.cap {
                self.done = true;
                return Ok(PlannedKind::Done(self.round_outcome()));
            }
            if !self.speculative {
                self.phase = RoundPhase::Baseline;
            } else {
                match self.setup.exec {
                    ExecMode::Modular => {
                        let base_len = self.ids.len();
                        let window = self.limits.max_total.saturating_sub(base_len + 1);
                        if let Some(shape) = self.tree {
                            // Tree round: depth clamps at the bucket edge
                            // exactly like the chain's γ → g clamp.
                            let d = shape.depth.min(window);
                            if d == 0 {
                                self.done = true;
                                return Ok(PlannedKind::Done(self.round_outcome()));
                            }
                            self.phase = RoundPhase::TreeDrafting(TreeState::new(
                                base_len,
                                shape.branching,
                                d,
                            ));
                        } else {
                            let gamma = self.setup.gamma.max(1);
                            let g = gamma.min(window);
                            if g == 0 {
                                self.done = true;
                                return Ok(PlannedKind::Done(self.round_outcome()));
                            }
                            self.phase = RoundPhase::Drafting(DraftState {
                                base_len,
                                g,
                                drafted: Vec::with_capacity(g),
                                draft_probs: Vec::new(),
                            });
                        }
                    }
                    ExecMode::Monolithic => {
                        let gamma = self.setup.gamma.max(1);
                        let mono_seq = engine
                            .manifest
                            .mono(gamma)
                            .map(|m| m.seq)
                            .unwrap_or(self.limits.max_total);
                        if self.ids.len() + gamma >= mono_seq {
                            self.done = true;
                            return Ok(PlannedKind::Done(self.round_outcome()));
                        }
                        self.phase = RoundPhase::Mono { gamma };
                    }
                }
            }
        }
        let kind = match &self.phase {
            RoundPhase::Idle => unreachable!("round shape decided above"),
            RoundPhase::Baseline | RoundPhase::Verifying(_) => RequestKind::Forward {
                variant: self.setup.target,
                kernel: self.setup.kernel,
                bucket: engine.bucket_for(self.ids.len())?,
            },
            RoundPhase::Drafting(_) => RequestKind::Forward {
                variant: self.setup.drafter,
                kernel: self.setup.kernel,
                bucket: engine.bucket_for(self.ids.len())?,
            },
            RoundPhase::TreeDrafting(st) => RequestKind::TreeForward {
                variant: self.setup.drafter,
                kernel: self.setup.kernel,
                // Every lane of the level-j expansion is base + j tokens.
                bucket: engine.bucket_for(st.base_len + st.levels.len())?,
                lanes: st.next_draft_lanes(),
            },
            RoundPhase::TreeVerifying(st) => RequestKind::TreeForward {
                variant: self.setup.target,
                kernel: self.setup.kernel,
                bucket: engine.bucket_for(st.base_len + st.depth)?,
                lanes: st.levels.last().map_or(1, |l| l.len()),
            },
            RoundPhase::Mono { gamma } => RequestKind::MonoStep { gamma: *gamma },
        };
        Ok(PlannedKind::Need(kind))
    }

    /// Phase 2: consume the engine result for the pending plan and advance
    /// the state machine one engine call's worth.
    pub fn apply(&mut self, engine: &Engine, reply: EngineReply) -> anyhow::Result<StepProgress> {
        anyhow::ensure!(
            !self.done && self.mid_round(),
            "apply without a pending plan"
        );
        let phase = std::mem::replace(&mut self.phase, RoundPhase::Idle);
        match (phase, reply) {
            // ---- baseline: one plain autoregressive target token -------
            (RoundPhase::Baseline, EngineReply::Forward(r)) => {
                self.out.real_s += r.real_s;
                self.out.sim_s += r.sim_s;
                self.out.target_calls += 1;
                self.note_kv_computed(Role::Target, self.ids.len());
                let nxt = r.fwd.argmax(r.row, self.ids.len() - 1);
                if let Some(reason) = self.push_committed(nxt) {
                    self.out.finish = reason;
                    self.done = true;
                }
                Ok(StepProgress::Round(self.round_outcome()))
            }
            // ---- modular draft phase (paper Fig. 4) --------------------
            (RoundPhase::Drafting(mut st), EngineReply::Forward(r)) => {
                self.out.real_s += r.real_s;
                self.out.sim_s += r.sim_s;
                self.out.drafter_calls += 1;
                let cur = self.ids.len();
                self.note_kv_computed(Role::Drafter, cur);
                let tok = r.fwd.argmax(r.row, cur - 1);
                if self.setup.rule == AcceptRule::Stochastic {
                    let mut p = r.fwd.probs(r.row, cur - 1);
                    apply_temperature(&mut p, self.temperature);
                    st.draft_probs.push(p);
                }
                st.drafted.push(tok);
                self.ids.push(tok);
                self.phase = if st.drafted.len() == st.g {
                    RoundPhase::Verifying(st)
                } else {
                    RoundPhase::Drafting(st)
                };
                Ok(StepProgress::Pending)
            }
            // ---- modular verify phase ----------------------------------
            (RoundPhase::Verifying(st), EngineReply::Forward(r)) => {
                self.out.real_s += r.real_s;
                self.out.sim_s += r.sim_s;
                self.out.target_calls += 1;
                self.out.n_rounds += 1;
                self.out.n_drafted += st.drafted.len();

                // Target decisions for positions base_len .. base_len+g.
                let target_argmax: Vec<u32> = (0..=st.g)
                    .map(|i| r.fwd.argmax(r.row, st.base_len - 1 + i))
                    .collect();
                let (n_acc, correction) = match self.setup.rule {
                    AcceptRule::Greedy => {
                        let k = greedy_accept_len(&st.drafted, &target_argmax);
                        (k, target_argmax[k])
                    }
                    AcceptRule::Stochastic => {
                        let target_probs: Vec<Vec<f32>> = (0..=st.g)
                            .map(|i| {
                                let mut p = r.fwd.probs(r.row, st.base_len - 1 + i);
                                apply_temperature(&mut p, self.temperature);
                                p
                            })
                            .collect();
                        let o = stochastic_accept(
                            &st.drafted,
                            &st.draft_probs,
                            &target_probs,
                            &mut self.rng,
                        );
                        (o.n_accepted, o.correction)
                    }
                };
                self.out.n_accepted += n_acc;

                // Roll back unaccepted drafts, then commit accepted +
                // correction. Resident KV follows: the verify computed the
                // whole window, but only the accepted extent stays valid
                // (rejected drafts and the correction position were
                // computed with tokens no longer in `ids`).
                self.note_kv_computed(Role::Target, st.base_len + st.g);
                self.clamp_kv(st.base_len + n_acc);
                self.ids.truncate(st.base_len);
                self.done = self.commit_round(&st.drafted[..n_acc], correction);
                Ok(StepProgress::Round(self.round_outcome()))
            }
            // ---- tree draft phase: one level expansion per dispatch ----
            (RoundPhase::TreeDrafting(mut st), EngineReply::Forward(r)) => {
                self.out.real_s += r.real_s;
                self.out.sim_s += r.sim_s;
                self.out.drafter_calls += 1;
                // Every lane recomputes the shared base prefix; the
                // per-lane path tokens are round-local, never resident.
                self.note_kv_computed(Role::Drafter, st.base_len);
                anyhow::ensure!(r.row == 0, "a tree dispatch owns its whole batch");
                let j = st.levels.len();
                let lanes = st.next_draft_lanes();
                anyhow::ensure!(r.fwd.batch >= lanes, "tree expansion lanes missing");
                // The proposal for position base_len + j is the drafter's
                // distribution at the last real token of each lane.
                let pos = st.base_len + j - 1;
                let k = st.branching;
                let mut level = Vec::with_capacity(lanes * k);
                for m in 0..lanes {
                    if self.setup.rule == AcceptRule::Stochastic {
                        let mut q = r.fwd.probs(m, pos);
                        apply_temperature(&mut q, self.temperature);
                        // Temperature is a monotone re-shaping, so the
                        // top-k order matches the raw logits' order.
                        top_k_into(&q, k, &mut st.topk);
                        for &t in &st.topk {
                            level.push(TreeNode { tok: t, q: None });
                        }
                        if j == 0 {
                            st.root_q = Some(q);
                        } else {
                            st.levels[j - 1][m].q = Some(q);
                        }
                    } else {
                        top_k_into(r.fwd.row(m, pos), k, &mut st.topk);
                        for &t in &st.topk {
                            level.push(TreeNode { tok: t, q: None });
                        }
                    }
                }
                st.levels.push(level);
                self.phase = if st.levels.len() == st.depth {
                    RoundPhase::TreeVerifying(st)
                } else {
                    RoundPhase::TreeDrafting(st)
                };
                Ok(StepProgress::Pending)
            }
            // ---- tree verify phase: one flattened leaf dispatch --------
            (RoundPhase::TreeVerifying(st), EngineReply::Forward(r)) => {
                self.out.real_s += r.real_s;
                self.out.sim_s += r.sim_s;
                self.out.target_calls += 1;
                self.out.n_rounds += 1;
                // The draft window is the tree depth — per-round α keeps
                // its chain meaning of accepted-path-fraction.
                self.out.n_drafted += st.depth;
                self.out.tree_rounds += 1;
                self.out.tree_lanes_real += st.lanes_real;
                self.out.tree_lanes_executed += st.lanes_executed;
                anyhow::ensure!(r.row == 0, "a tree dispatch owns its whole batch");
                anyhow::ensure!(
                    r.fwd.batch >= st.levels[st.depth - 1].len(),
                    "tree verification lanes missing"
                );

                let (path, correction) = self.tree_walk(&st, r.fwd);
                self.out.n_accepted += path.len();
                // The verify lanes computed base + full-depth paths; only
                // the base + accepted-path extent stays valid (the
                // accepted leaf's lane prefix is exactly that sequence).
                self.note_kv_computed(Role::Target, st.base_len + path.len());
                self.clamp_kv(st.base_len + path.len());
                // ids never held the drafts (lanes are built off-line), so
                // there is nothing to roll back before committing.
                self.done = self.commit_round(&path, correction);
                Ok(StepProgress::Round(self.round_outcome()))
            }
            // ---- monolithic round (paper Fig. 3): one fused graph ------
            (RoundPhase::Mono { gamma }, EngineReply::Mono(step)) => {
                let mono_seq = engine
                    .manifest
                    .mono(gamma)
                    .map(|m| m.seq)
                    .unwrap_or(self.limits.max_total);
                let oh_d = self.lat.dispatch_overhead(self.setup.mapping.drafter);
                let oh_t = self.lat.dispatch_overhead(self.setup.mapping.target);
                self.out.real_s += step.elapsed_s;
                // Simulated: γ drafter + 1 target forwards at the mono
                // bucket, minus the per-call boundaries, plus ONE boundary
                // for the round — the saving the paper attributes to the
                // monolithic design.
                let sim_d = self.sim_forward(engine, self.setup.drafter, mono_seq)? - oh_d;
                let sim_t = self.sim_forward(engine, self.setup.target, mono_seq)? - oh_t;
                self.out.sim_s += gamma as f64 * sim_d + sim_t + oh_d.max(oh_t);
                self.out.drafter_calls += gamma;
                self.out.target_calls += 1;
                self.out.n_rounds += 1;
                self.out.n_drafted += gamma;
                let n_acc = step.n_accepted.min(gamma);
                self.out.n_accepted += n_acc;

                let correction = step.out_tokens[n_acc];
                self.done = self.commit_round(&step.drafted[..n_acc], correction);
                Ok(StepProgress::Round(self.round_outcome()))
            }
            (phase, _) => {
                self.phase = phase;
                anyhow::bail!("engine reply does not match the pending plan")
            }
        }
    }

    /// Execute one planned request unfused (batch = 1) and apply its
    /// result — the fused executor's no-batched-artifact fallback.
    /// Precondition: `req` is this session's *current* pending plan (the
    /// session's own token prefix is used for the engine call; it is
    /// identical to `req.tokens` until `apply` runs).
    pub fn execute(
        &mut self,
        engine: &Engine,
        req: &EngineRequest,
    ) -> anyhow::Result<StepProgress> {
        self.execute_kind(engine, req.kind)
    }

    /// Singleton execution off the session's own token prefix (no copy).
    fn execute_kind(
        &mut self,
        engine: &Engine,
        kind: RequestKind,
    ) -> anyhow::Result<StepProgress> {
        match kind {
            RequestKind::Forward { variant, kernel, bucket } => {
                let fwd = engine.forward(variant, kernel, &self.ids, bucket)?;
                let spec = engine.manifest.model_for(variant)?;
                let pu = self.role_pu(variant.role);
                // Cache-off and cache-cold dispatches take the historical
                // pricing path — `kv_cache: off` stays bit-identical by
                // never entering the incremental arithmetic.
                let cached = self.kv_cached_for(variant.role, self.ids.len());
                let sim_s = if cached > 0 {
                    self.lat
                        .incremental_forward_latency(spec, variant.scheme, pu, bucket, cached)
                } else {
                    self.lat.forward_latency(spec, variant.scheme, pu, bucket)
                };
                let real_s = fwd.elapsed_s;
                self.apply(
                    engine,
                    EngineReply::Forward(ForwardReply { fwd: &fwd, row: 0, sim_s, real_s }),
                )
            }
            RequestKind::TreeForward { variant, kernel, bucket, lanes } => {
                let seqs: Vec<Vec<u32>> = match &self.phase {
                    RoundPhase::TreeDrafting(st) => st.draft_lane_prefixes(&self.ids),
                    RoundPhase::TreeVerifying(st) => st.verify_lane_prefixes(&self.ids),
                    _ => anyhow::bail!("tree dispatch without a tree phase"),
                };
                anyhow::ensure!(seqs.len() == lanes, "tree lane count drifted");
                let spec = engine.manifest.model_for(variant)?;
                let pu = self.role_pu(variant.role);
                // Every tree lane shares the session's resident base
                // prefix; 0 when the cache is off/cold (historical path).
                let cached = self.kv_cached_for_pending(&kind);

                // Chunk the lanes over the compiled batch sizes (smallest
                // compiled size that fits the remainder; largest on
                // overflow), padding short chunks by replicating their
                // first lane — same policy as the fuser's plan_chunks.
                let mut sizes = engine.manifest.batch_sizes_for(variant, kernel, bucket);
                if sizes.is_empty() {
                    sizes.push(1);
                }
                sizes.sort_unstable();
                let largest = *sizes.last().unwrap();

                let mut logits: Vec<f32> = Vec::with_capacity(lanes * bucket * spec.vocab);
                let mut sim_s = 0.0;
                let mut real_s = 0.0;
                let mut executed = 0usize;
                let mut off = 0usize;
                while off < lanes {
                    let remaining = lanes - off;
                    let exec_b = sizes
                        .iter()
                        .copied()
                        .find(|&s| s >= remaining)
                        .unwrap_or(largest);
                    let m = remaining.min(exec_b);
                    let batched = if exec_b > 1 {
                        let mut views: Vec<&[u32]> =
                            seqs[off..off + m].iter().map(|s| s.as_slice()).collect();
                        while views.len() < exec_b {
                            views.push(seqs[off].as_slice());
                        }
                        engine.forward_batch(variant, kernel, &views, bucket).ok()
                    } else {
                        None
                    };
                    match batched {
                        Some(fwd) => {
                            sim_s += if cached > 0 {
                                // Per-lane incremental compute (each lane
                                // reuses the resident base prefix), one
                                // dispatch boundary for the chunk.
                                self.lat.dispatch_overhead(pu)
                                    + exec_b as f64
                                        * self.lat.incremental_lane_cost(
                                            spec,
                                            variant.scheme,
                                            pu,
                                            bucket,
                                            cached,
                                        )
                            } else {
                                self.lat.batched_forward_latency(
                                    spec,
                                    variant.scheme,
                                    pu,
                                    bucket,
                                    exec_b,
                                )
                            };
                            real_s += fwd.elapsed_s;
                            logits.extend_from_slice(&fwd.logits[..m * bucket * fwd.vocab]);
                            executed += exec_b;
                        }
                        // No batched artifact (e.g. the Pallas lowering is
                        // batch-1 only) or it failed: degrade this chunk to
                        // per-lane single dispatches.
                        None => {
                            for s in &seqs[off..off + m] {
                                let fwd = engine.forward(variant, kernel, s, bucket)?;
                                sim_s += if cached > 0 {
                                    self.lat.incremental_forward_latency(
                                        spec,
                                        variant.scheme,
                                        pu,
                                        bucket,
                                        cached,
                                    )
                                } else {
                                    self.lat.forward_latency(spec, variant.scheme, pu, bucket)
                                };
                                real_s += fwd.elapsed_s;
                                logits.extend_from_slice(&fwd.logits);
                                executed += 1;
                            }
                        }
                    }
                    off += m;
                }
                let vocab = spec.vocab;
                let combined =
                    ForwardOut { logits, batch: lanes, seq: bucket, vocab, elapsed_s: real_s };
                if let RoundPhase::TreeDrafting(st) | RoundPhase::TreeVerifying(st) =
                    &mut self.phase
                {
                    st.lanes_real += lanes;
                    st.lanes_executed += executed;
                }
                self.apply(
                    engine,
                    EngineReply::Forward(ForwardReply { fwd: &combined, row: 0, sim_s, real_s }),
                )
            }
            RequestKind::MonoStep { gamma } => {
                let cur_len = self.ids.len();
                let step = engine.mono_step(gamma, &self.ids, cur_len)?;
                self.apply(engine, EngineReply::Mono(&step))
            }
        }
    }

    /// Longest-valid-root-path acceptance over the verified tree: walk
    /// from the root, at each node judging its k children against the
    /// target distribution read from a descendant lane of the verify
    /// dispatch (rows under a shared prefix agree, causality). Greedy
    /// descends into the child matching the target argmax; stochastic
    /// applies the residual rule ([`tree_verify_node`]) in proposal order
    /// — with k = 1 both degenerate to the chain's accept rules. Returns
    /// the accepted path and the correction/bonus token.
    fn tree_walk(&mut self, st: &TreeState, fwd: &ForwardOut) -> (Vec<u32>, u32) {
        let k = st.branching;
        let d = st.depth;
        let mut path = Vec::with_capacity(d);
        let mut node = 0usize; // accepted node's index in levels[j]
        for j in 0..d {
            let first_child = if j == 0 { 0 } else { node * k };
            let children = &st.levels[j][first_child..first_child + k];
            // Leftmost leaf descending from the parent — its lane holds
            // the target distribution judging position base_len + j.
            let row = first_child * k.pow((d - 1 - j) as u32);
            let pos = st.base_len + j - 1;
            match self.setup.rule {
                AcceptRule::Greedy => {
                    let t_arg = fwd.argmax(row, pos);
                    match children.iter().position(|n| n.tok == t_arg) {
                        Some(ci) => {
                            node = first_child + ci;
                            path.push(t_arg);
                        }
                        None => return (path, t_arg),
                    }
                }
                AcceptRule::Stochastic => {
                    let q = if j == 0 {
                        st.root_q.as_deref()
                    } else {
                        st.levels[j - 1][node].q.as_deref()
                    }
                    .expect("stochastic tree level drafted without its proposal");
                    let mut p = fwd.probs(row, pos);
                    apply_temperature(&mut p, self.temperature);
                    let toks: Vec<u32> = children.iter().map(|n| n.tok).collect();
                    match tree_verify_node(&toks, q, &p, &mut self.rng) {
                        NodeVerdict::Accepted(ci) => {
                            node = first_child + ci;
                            path.push(toks[ci]);
                        }
                        NodeVerdict::Rejected(c) => return (path, c),
                    }
                }
            }
        }
        // Full depth accepted: bonus token from the target's distribution
        // at the accepted leaf's last position (the leaf's own lane).
        let pos = st.base_len + d - 1;
        let bonus = match self.setup.rule {
            AcceptRule::Greedy => fwd.argmax(node, pos),
            AcceptRule::Stochastic => {
                let mut p = fwd.probs(node, pos);
                apply_temperature(&mut p, self.temperature);
                sample_from(&p, &mut self.rng)
            }
        };
        (path, bonus)
    }

    /// The round-commit state transition, shared by both speculative paths
    /// (public so the edge-case tests can drive it without an engine):
    /// append the accepted draft prefix then the correction token, stopping
    /// at EOS, a per-request stop condition, or the generation cap. Marks
    /// and returns session completion; the reason lands on
    /// [`finish_reason`](Self::finish_reason).
    pub fn commit_round(&mut self, accepted: &[u32], correction: u32) -> bool {
        for &t in accepted {
            if let Some(reason) = self.push_committed(t) {
                self.out.finish = reason;
                self.done = true;
                return true;
            }
        }
        if let Some(reason) = self.push_committed(correction) {
            self.out.finish = reason;
            self.done = true;
            return true;
        }
        self.done
    }

    /// Commit one token to the output, returning the finish reason the
    /// commit triggered (None = the session keeps going). EOS and stop
    /// tokens finish *without* being emitted; a stop-sequence match
    /// finishes with the matched suffix truncated from the output; the
    /// generation cap finishes with the token kept. With no stops
    /// configured this is exactly the seed commit rule.
    fn push_committed(&mut self, t: u32) -> Option<FinishReason> {
        if t == EOS_ID || self.stop_tokens.contains(&t) {
            return Some(FinishReason::Stop);
        }
        self.ids.push(t);
        self.out.tokens.push(t);
        if let Some(n) = self.stop_seq_match() {
            let keep = self.out.tokens.len() - n;
            self.out.tokens.truncate(keep);
            return Some(FinishReason::StopSequence);
        }
        if self.out.tokens.len() >= self.limits.cap {
            return Some(FinishReason::Length);
        }
        None
    }

    /// Length of the longest configured stop sequence the generated
    /// output currently ends with.
    fn stop_seq_match(&self) -> Option<usize> {
        let out = &self.out.tokens;
        self.stop_seqs
            .iter()
            .filter(|s| s.len() <= out.len() && out.ends_with(s.as_slice()))
            .map(|s| s.len())
            .max()
    }

    fn counters(&self) -> RoundBase {
        RoundBase {
            tok: self.out.tokens.len(),
            drafted: self.out.n_drafted,
            accepted: self.out.n_accepted,
            tree_lanes_real: self.out.tree_lanes_real,
            tree_lanes_executed: self.out.tree_lanes_executed,
            sim_s: self.out.sim_s,
            real_s: self.out.real_s,
        }
    }

    /// Per-round delta against the snapshot taken at round start. A
    /// stop-sequence match spanning a round boundary can truncate the
    /// output *below* the snapshot; the committed delta is then empty
    /// and [`DecodeOutcome::tokens`] is the authoritative output (the
    /// serving worker streams from it with a stop-length hold-back, so
    /// clients never see tokens a later match truncates).
    fn round_outcome(&self) -> StepOutcome {
        StepOutcome {
            committed: self
                .out
                .tokens
                .get(self.round_base.tok..)
                .map(|s| s.to_vec())
                .unwrap_or_default(),
            drafted: self.out.n_drafted - self.round_base.drafted,
            accepted: self.out.n_accepted - self.round_base.accepted,
            tree_lanes_real: self.out.tree_lanes_real - self.round_base.tree_lanes_real,
            tree_lanes_executed: self.out.tree_lanes_executed
                - self.round_base.tree_lanes_executed,
            sim_s: self.out.sim_s - self.round_base.sim_s,
            real_s: self.out.real_s - self.round_base.real_s,
            done: self.done,
        }
    }

    /// The PU the mapping assigns to a model role.
    fn role_pu(&self, role: crate::models::Role) -> PuAssignment {
        match role {
            crate::models::Role::Drafter => self.setup.mapping.drafter,
            crate::models::Role::Target => self.setup.mapping.target,
        }
    }

    /// Simulated seconds for one forward of `key` on its mapped PU at
    /// `bucket` (bucketed deployment: padded shapes run at bucket cost).
    fn sim_forward(
        &self,
        engine: &Engine,
        key: VariantKey,
        bucket: usize,
    ) -> anyhow::Result<f64> {
        let spec = engine.manifest.model_for(key)?;
        Ok(self.lat.forward_latency(spec, key.scheme, self.role_pu(key.role), bucket))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::Platform;

    fn session(cap: usize) -> DecodeSession {
        let limits = SessionLimits { cap, max_total: 128 };
        DecodeSession::with_limits(
            LatencyModel::new(Platform::imx95()),
            DecoderSetup::paper_default(),
            true,
            &[1, 5, 6],
            limits,
        )
    }

    // The commit/cap/EOS edge-case coverage lives in
    // rust/tests/session_edge.rs (driven through the public surface);
    // plan/apply round equivalence against step() in rust/tests/fused_e2e.rs.

    #[test]
    fn kv_prefix_seeds_both_roles_and_is_clamped_to_the_prompt() {
        let mut s = session(8);
        assert_eq!(s.kv_resident(), None);
        let fwd = RequestKind::Forward {
            variant: s.setup.target,
            kernel: s.setup.kernel,
            bucket: 64,
        };
        // No seeded prefix: every dispatch is priced cold.
        assert_eq!(s.kv_cached_for_pending(&fwd), 0);
        // Prompt is 3 tokens; a claimed 100-token prefix clamps to 3.
        s.set_kv_prefix(100);
        assert_eq!(s.kv_resident(), Some([3, 3]));
        assert_eq!(s.kv_cached_for_pending(&fwd), 3);
        // Verification clamps residency back to the accepted extent.
        s.note_kv_computed(Role::Target, 7);
        s.clamp_kv(4);
        assert_eq!(s.kv_resident(), Some([3, 4]));
        // Mono steps run the fused graph end-to-end: never incremental.
        assert_eq!(s.kv_cached_for_pending(&RequestKind::MonoStep { gamma: 4 }), 0);
    }

    #[test]
    fn round_policy_hooks_update_next_round() {
        let mut s = session(8);
        assert!(s.speculative());
        s.set_gamma(7);
        assert_eq!(s.gamma(), 7);
        s.set_gamma(0); // clamped: a speculative round drafts at least 1
        assert_eq!(s.gamma(), 1);
        s.set_speculative(false);
        assert!(!s.speculative());
    }

    #[test]
    fn fresh_session_is_at_round_boundary() {
        let s = session(8);
        assert!(!s.mid_round());
    }

    #[test]
    fn one_wide_tree_is_the_chain() {
        // A branching-1 shape is normalised away: the session keeps the
        // chain code path (and therefore its exact token/sim_s streams).
        let mut s = session(8);
        s.set_tree(Some(TreeShape::new(1, 5)));
        assert_eq!(s.tree(), None);
        s.set_tree(Some(TreeShape::new(2, 3)));
        assert_eq!(s.tree(), Some(TreeShape { branching: 2, depth: 3 }));
        s.set_tree(None);
        assert_eq!(s.tree(), None);
    }

    #[test]
    fn tree_path_prefixes_follow_implicit_parents() {
        // Hand-build a (2, 2) tree and check the lane reconstruction:
        // ancestor of leaf m at level l is m / k^(level−l).
        let mut st = TreeState::new(3, 2, 2);
        st.levels.push(vec![
            TreeNode { tok: 10, q: None },
            TreeNode { tok: 11, q: None },
        ]);
        assert_eq!(st.next_draft_lanes(), 2);
        st.levels.push(vec![
            TreeNode { tok: 20, q: None },
            TreeNode { tok: 21, q: None },
            TreeNode { tok: 22, q: None },
            TreeNode { tok: 23, q: None },
        ]);
        let base = [1, 2, 3];
        let lanes = st.verify_lane_prefixes(&base);
        assert_eq!(
            lanes,
            vec![
                vec![1, 2, 3, 10, 20],
                vec![1, 2, 3, 10, 21],
                vec![1, 2, 3, 11, 22],
                vec![1, 2, 3, 11, 23],
            ]
        );
    }

    #[test]
    fn stop_token_finishes_like_eos() {
        let mut s = session(8);
        s.set_stop_tokens(vec![42]);
        assert!(s.commit_round(&[10, 42, 11], 12));
        assert!(s.is_done());
        assert_eq!(s.finish_reason(), crate::api::FinishReason::Stop);
        // The stop token and everything after it are excluded.
        assert_eq!(s.outcome().tokens, vec![10]);
    }

    #[test]
    fn stop_sequence_truncation_is_exact() {
        let mut s = session(16);
        s.set_stop_sequences(vec![vec![7, 8], vec![]]); // empty seq ignored
        assert!(s.commit_round(&[5, 6, 7, 8, 9], 10));
        assert!(s.is_done());
        assert_eq!(s.finish_reason(), crate::api::FinishReason::StopSequence);
        // Output ends exactly before the matched sequence.
        assert_eq!(s.outcome().tokens, vec![5, 6]);
    }

    #[test]
    fn stop_sequence_matches_across_rounds() {
        let mut s = session(16);
        s.set_stop_sequences(vec![vec![7, 8]]);
        assert!(!s.commit_round(&[5, 7], 9)); // ends ...7, 9 — no match yet
        assert!(s.commit_round(&[7, 8], 10)); // ...9, 7, 8 matches
        assert_eq!(s.outcome().tokens, vec![5, 7, 9]);
        assert_eq!(s.finish_reason(), crate::api::FinishReason::StopSequence);
    }

    #[test]
    fn default_session_finish_reasons_are_seed_shaped() {
        // Cap-limited commit reports Length, exactly the seed cap rule.
        let mut s = session(2);
        assert!(s.commit_round(&[4, 5, 6], 7));
        assert_eq!(s.outcome().tokens, vec![4, 5]);
        assert_eq!(s.finish_reason(), crate::api::FinishReason::Length);
        // EOS reports Stop.
        let mut s = session(8);
        assert!(s.commit_round(&[4, crate::tokenizer::EOS_ID], 7));
        assert_eq!(s.finish_reason(), crate::api::FinishReason::Stop);
    }
}
