//! Resumable decode sessions: the round-level state machine behind every
//! decode path in the crate.
//!
//! A [`DecodeSession`] owns one request's token state, dual clocks
//! (simulated i.MX95 / real PJRT wall-clock) and round counters, and
//! advances one *speculation round* (or one baseline token) per
//! [`DecodeSession::step`] call. Run-to-completion decoding is a trivial
//! loop over `step` (see `Decoder::baseline` / `Decoder::speculative`);
//! the serving coordinator instead interleaves many live sessions
//! round-by-round and re-consults the routing policy between rounds, so
//! γ and speculate-on/off can change *within* a request as the session's
//! running α diverges from the admission-time estimate.
//!
//! Clock accounting is identical to the old run-to-completion loops: the
//! modular path charges one dispatch boundary per forward call (γ+1 per
//! round), the monolithic path a single boundary per round — the §IV-D
//! trade-off the paper measures.

use crate::config::ExecMode;
use crate::hetero::{LatencyModel, PuAssignment};
use crate::models::VariantKey;
use crate::runtime::Engine;
use crate::tokenizer::EOS_ID;
use crate::util::rng::Rng;

use super::decoder::{DecodeOutcome, DecoderSetup};
use super::sampling::{greedy_accept_len, stochastic_accept, AcceptRule};

/// Static bounds a session computes once at admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionLimits {
    /// Generation cap (tokens) for this prompt length and admission-time γ.
    pub cap: usize,
    /// Largest compiled sequence bucket (total-length ceiling).
    pub max_total: usize,
}

impl SessionLimits {
    /// The bucketed-deployment generation cap: leave room for the prompt
    /// plus one full draft window inside the largest compiled bucket.
    /// Returns 0 for prompts near the largest bucket (nothing decodable).
    pub fn compute(max_new: usize, prompt_len: usize, gamma: usize, max_total: usize) -> usize {
        max_new.min(max_total.saturating_sub(prompt_len + gamma.max(1)))
    }

    pub fn from_engine(engine: &Engine, setup: &DecoderSetup, prompt_len: usize) -> SessionLimits {
        let max_total = engine.manifest.largest_bucket();
        SessionLimits {
            cap: Self::compute(setup.max_new, prompt_len, setup.gamma, max_total),
            max_total,
        }
    }
}

/// What one [`DecodeSession::step`] did.
#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    /// Tokens committed to the output by this step (EOS excluded).
    pub committed: Vec<u32>,
    /// Draft window actually run this round — the configured γ clamped at
    /// the bucket edge (0 = baseline step or no-work completion round) —
    /// and how much of it the target accepted.
    pub drafted: usize,
    pub accepted: usize,
    /// Clock increments for this step.
    pub sim_s: f64,
    pub real_s: f64,
    /// The session finished (EOS, cap reached, or out of bucket space).
    pub done: bool,
}

/// One request's resumable decode state machine.
///
/// Construct with [`DecodeSession::new`] (or [`DecodeSession::with_limits`]
/// when no engine is at hand, e.g. in pure state-transition tests), then
/// call [`step`](DecodeSession::step) until [`is_done`](DecodeSession::is_done)
/// and harvest the aggregate [`DecodeOutcome`] via
/// [`into_outcome`](DecodeSession::into_outcome).
pub struct DecodeSession {
    setup: DecoderSetup,
    lat: LatencyModel,
    /// Prompt + committed continuation (the model input).
    ids: Vec<u32>,
    /// Aggregate outcome accumulated across steps.
    out: DecodeOutcome,
    limits: SessionLimits,
    rng: Rng,
    /// Whether the *next* round speculates (re-decidable between rounds).
    speculative: bool,
    done: bool,
}

impl DecodeSession {
    pub fn new(
        engine: &Engine,
        lat: LatencyModel,
        setup: DecoderSetup,
        speculative: bool,
        prompt: &[u32],
    ) -> DecodeSession {
        let limits = SessionLimits::from_engine(engine, &setup, prompt.len());
        Self::with_limits(lat, setup, speculative, prompt, limits)
    }

    /// Engine-free constructor with explicit limits (tests, custom drivers).
    pub fn with_limits(
        lat: LatencyModel,
        setup: DecoderSetup,
        speculative: bool,
        prompt: &[u32],
        limits: SessionLimits,
    ) -> DecodeSession {
        DecodeSession {
            setup,
            lat,
            ids: prompt.to_vec(),
            out: DecodeOutcome::default(),
            done: limits.cap == 0,
            limits,
            rng: Rng::new(0x5EED),
            speculative,
        }
    }

    /// Replace the RNG stream (stochastic accept rule reproducibility).
    pub fn with_rng(mut self, rng: Rng) -> DecodeSession {
        self.rng = rng;
        self
    }

    /// Snapshot of the current RNG state (to continue a stream elsewhere).
    pub fn rng_state(&self) -> Rng {
        self.rng.clone()
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Current total sequence length (prompt + committed tokens).
    pub fn seq_len(&self) -> usize {
        self.ids.len()
    }

    pub fn limits(&self) -> SessionLimits {
        self.limits
    }

    /// Peek at the running aggregate outcome.
    pub fn outcome(&self) -> &DecodeOutcome {
        &self.out
    }

    /// Running per-session acceptance rate (NaN before any draft).
    pub fn alpha_so_far(&self) -> f64 {
        self.out.alpha()
    }

    pub fn n_drafted(&self) -> usize {
        self.out.n_drafted
    }

    pub fn n_rounds(&self) -> usize {
        self.out.n_rounds
    }

    pub fn speculative(&self) -> bool {
        self.speculative
    }

    pub fn gamma(&self) -> usize {
        self.setup.gamma
    }

    /// Re-decide speculation for the next round (round-level policy hook).
    pub fn set_speculative(&mut self, on: bool) {
        self.speculative = on;
    }

    /// Re-decide γ for the next round (round-level policy hook). The
    /// generation cap stays as computed at admission; γ only shapes the
    /// next draft window.
    pub fn set_gamma(&mut self, gamma: usize) {
        self.setup.gamma = gamma.max(1);
    }

    /// [`set_gamma`](Self::set_gamma) that also respects the compiled
    /// artifact set: monolithic fused graphs exist only for the γ values
    /// the AOT build lowered, so clamp the request to the largest compiled
    /// γ at or below it that still fits the mono bucket at the current
    /// position. When nothing fits this falls back to the raw request and
    /// the session ends (or errors loudly) exactly like the
    /// run-to-completion paths — serving uses this hook, experiments keep
    /// the strict `set_gamma` so a missing artifact is never papered over.
    pub fn set_gamma_checked(&mut self, engine: &Engine, gamma: usize) {
        let gamma = gamma.max(1);
        if self.setup.exec == ExecMode::Monolithic {
            if let Some(g) = (1..=gamma).rev().find(|&g| {
                engine
                    .manifest
                    .mono(g)
                    .map(|m| self.ids.len() + g < m.seq)
                    .unwrap_or(false)
            }) {
                self.setup.gamma = g;
                return;
            }
        }
        self.setup.gamma = gamma;
    }

    /// Finish the session and produce the aggregate outcome.
    pub fn into_outcome(mut self) -> DecodeOutcome {
        self.out.tokens.truncate(self.limits.cap);
        self.out
    }

    /// Advance the session by one unit of work: one speculation round
    /// (draft γ + verify + commit) or one baseline token. Stepping a
    /// finished session is a no-op that reports `done`.
    pub fn step(&mut self, engine: &Engine) -> anyhow::Result<StepOutcome> {
        if self.done {
            return Ok(StepOutcome { done: true, ..StepOutcome::default() });
        }
        // Delta-track the aggregate counters so per-step reporting can't
        // drift from the totals.
        let (tok0, dr0, acc0, sim0, real0) = (
            self.out.tokens.len(),
            self.out.n_drafted,
            self.out.n_accepted,
            self.out.sim_s,
            self.out.real_s,
        );
        if self.speculative {
            match self.setup.exec {
                ExecMode::Modular => self.round_modular(engine)?,
                ExecMode::Monolithic => self.round_monolithic(engine)?,
            }
        } else {
            self.round_baseline(engine)?;
        }
        Ok(StepOutcome {
            committed: self.out.tokens[tok0..].to_vec(),
            drafted: self.out.n_drafted - dr0,
            accepted: self.out.n_accepted - acc0,
            sim_s: self.out.sim_s - sim0,
            real_s: self.out.real_s - real0,
            done: self.done,
        })
    }

    /// One plain autoregressive token with the target model.
    fn round_baseline(&mut self, engine: &Engine) -> anyhow::Result<()> {
        if self.out.tokens.len() >= self.limits.cap {
            self.done = true;
            return Ok(());
        }
        let bucket = engine.bucket_for(self.ids.len())?;
        let fwd = engine.forward(self.setup.target, self.setup.kernel, &self.ids, bucket)?;
        self.out.real_s += fwd.elapsed_s;
        self.out.sim_s += self.sim_forward(engine, self.setup.target, bucket)?;
        self.out.target_calls += 1;
        let nxt = fwd.argmax(0, self.ids.len() - 1);
        if nxt == EOS_ID {
            self.done = true;
            return Ok(());
        }
        self.ids.push(nxt);
        self.out.tokens.push(nxt);
        if self.out.tokens.len() >= self.limits.cap {
            self.done = true;
        }
        Ok(())
    }

    /// Modular speculation round (paper Fig. 4): γ drafter calls + 1 target
    /// call, control flow here in Rust, one runtime-API boundary per call.
    fn round_modular(&mut self, engine: &Engine) -> anyhow::Result<()> {
        if self.out.tokens.len() >= self.limits.cap {
            self.done = true;
            return Ok(());
        }
        let gamma = self.setup.gamma.max(1);
        let base_len = self.ids.len();
        let g = gamma.min(self.limits.max_total.saturating_sub(base_len + 1));
        if g == 0 {
            self.done = true;
            return Ok(());
        }
        // ---- draft phase ---------------------------------------------
        let mut drafted: Vec<u32> = Vec::with_capacity(g);
        let mut draft_probs: Vec<Vec<f32>> = Vec::new();
        for i in 0..g {
            let cur = base_len + i;
            let bucket = engine.bucket_for(cur)?;
            let fwd =
                engine.forward(self.setup.drafter, self.setup.kernel, &self.ids, bucket)?;
            self.out.real_s += fwd.elapsed_s;
            self.out.sim_s += self.sim_forward(engine, self.setup.drafter, bucket)?;
            self.out.drafter_calls += 1;
            let tok = fwd.argmax(0, cur - 1);
            if self.setup.rule == AcceptRule::Stochastic {
                draft_probs.push(fwd.probs(0, cur - 1));
            }
            drafted.push(tok);
            self.ids.push(tok);
        }
        // ---- verify phase --------------------------------------------
        let ver_len = self.ids.len();
        let bucket = engine.bucket_for(ver_len)?;
        let fwd = engine.forward(self.setup.target, self.setup.kernel, &self.ids, bucket)?;
        self.out.real_s += fwd.elapsed_s;
        self.out.sim_s += self.sim_forward(engine, self.setup.target, bucket)?;
        self.out.target_calls += 1;
        self.out.n_rounds += 1;
        self.out.n_drafted += drafted.len();

        // Target decisions for positions base_len .. base_len+g.
        let target_argmax: Vec<u32> =
            (0..=g).map(|i| fwd.argmax(0, base_len - 1 + i)).collect();
        let (n_acc, correction) = match self.setup.rule {
            AcceptRule::Greedy => {
                let k = greedy_accept_len(&drafted, &target_argmax);
                (k, target_argmax[k])
            }
            AcceptRule::Stochastic => {
                let target_probs: Vec<Vec<f32>> =
                    (0..=g).map(|i| fwd.probs(0, base_len - 1 + i)).collect();
                let o = stochastic_accept(&drafted, &draft_probs, &target_probs, &mut self.rng);
                (o.n_accepted, o.correction)
            }
        };
        self.out.n_accepted += n_acc;

        // Roll back unaccepted drafts, then commit accepted + correction.
        self.ids.truncate(base_len);
        self.done = self.commit_round(&drafted[..n_acc], correction);
        Ok(())
    }

    /// Monolithic speculation round (paper Fig. 3): one fused graph charged
    /// a *single* dispatch boundary — the saving the paper attributes to
    /// the monolithic design.
    fn round_monolithic(&mut self, engine: &Engine) -> anyhow::Result<()> {
        let gamma = self.setup.gamma.max(1);
        let mono_seq = engine
            .manifest
            .mono(gamma)
            .map(|m| m.seq)
            .unwrap_or(self.limits.max_total);
        if self.out.tokens.len() >= self.limits.cap || self.ids.len() + gamma >= mono_seq {
            self.done = true;
            return Ok(());
        }
        let oh_d = self.dispatch_overhead(self.setup.mapping.drafter);
        let oh_t = self.dispatch_overhead(self.setup.mapping.target);

        let base_len = self.ids.len();
        let step = engine.mono_step(gamma, &self.ids, base_len)?;
        self.out.real_s += step.elapsed_s;
        // Simulated: γ drafter + 1 target forwards at the mono bucket,
        // minus the per-call boundaries, plus ONE boundary for the round.
        let sim_d = self.sim_forward(engine, self.setup.drafter, mono_seq)? - oh_d;
        let sim_t = self.sim_forward(engine, self.setup.target, mono_seq)? - oh_t;
        self.out.sim_s += gamma as f64 * sim_d + sim_t + oh_d.max(oh_t);
        self.out.drafter_calls += gamma;
        self.out.target_calls += 1;
        self.out.n_rounds += 1;
        self.out.n_drafted += gamma;
        let n_acc = step.n_accepted.min(gamma);
        self.out.n_accepted += n_acc;

        let correction = step.out_tokens[n_acc];
        self.done = self.commit_round(&step.drafted[..n_acc], correction);
        Ok(())
    }

    /// The round-commit state transition, shared by both speculative paths
    /// (public so the edge-case tests can drive it without an engine):
    /// append the accepted draft prefix then the correction token, stopping
    /// at EOS or the generation cap. Marks and returns session completion.
    pub fn commit_round(&mut self, accepted: &[u32], correction: u32) -> bool {
        for &t in accepted {
            if t == EOS_ID {
                self.done = true;
                return true;
            }
            self.ids.push(t);
            self.out.tokens.push(t);
            if self.out.tokens.len() >= self.limits.cap {
                self.done = true;
                return true;
            }
        }
        if correction == EOS_ID {
            self.done = true;
            return true;
        }
        self.ids.push(correction);
        self.out.tokens.push(correction);
        if self.out.tokens.len() >= self.limits.cap {
            self.done = true;
        }
        self.done
    }

    /// Simulated seconds for one forward of `key` on its mapped PU at
    /// `bucket` (bucketed deployment: padded shapes run at bucket cost).
    fn sim_forward(
        &self,
        engine: &Engine,
        key: VariantKey,
        bucket: usize,
    ) -> anyhow::Result<f64> {
        let spec = engine.manifest.model_for(key)?;
        let pu = match key.role {
            crate::models::Role::Drafter => self.setup.mapping.drafter,
            crate::models::Role::Target => self.setup.mapping.target,
        };
        Ok(self.lat.forward_latency(spec, key.scheme, pu, bucket))
    }

    fn dispatch_overhead(&self, pu: PuAssignment) -> f64 {
        match pu {
            PuAssignment::Cpu { .. } => self.lat.platform.cpu.dispatch_overhead_s,
            PuAssignment::Gpu => self.lat.platform.gpu.dispatch_overhead_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::Platform;

    fn session(cap: usize) -> DecodeSession {
        let limits = SessionLimits { cap, max_total: 128 };
        DecodeSession::with_limits(
            LatencyModel::new(Platform::imx95()),
            DecoderSetup::paper_default(),
            true,
            &[1, 5, 6],
            limits,
        )
    }

    // The commit/cap/EOS edge-case coverage lives in
    // rust/tests/session_edge.rs (driven through the public surface).

    #[test]
    fn round_policy_hooks_update_next_round() {
        let mut s = session(8);
        assert!(s.speculative());
        s.set_gamma(7);
        assert_eq!(s.gamma(), 7);
        s.set_gamma(0); // clamped: a speculative round drafts at least 1
        assert_eq!(s.gamma(), 1);
        s.set_speculative(false);
        assert!(!s.speculative());
    }
}
