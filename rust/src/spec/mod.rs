//! Speculative sampling (paper §II-B, Leviathan et al. [3]).
//!
//! * [`sampling`] — token-level accept rules: greedy (the paper's setting)
//!   and the stochastic min(1, p_t/p_d) rule as an extension.
//! * [`decoder`] — the decode loops: autoregressive baseline, **modular**
//!   speculation (separate drafter/target executables, control flow in
//!   Rust — paper Fig. 4) and **monolithic** speculation (one fused
//!   spec-step HLO per γ — paper Fig. 3).

pub mod decoder;
pub mod sampling;

pub use decoder::{DecodeOutcome, Decoder, DecoderSetup};
pub use sampling::{greedy_accept_len, stochastic_accept, AcceptRule};
