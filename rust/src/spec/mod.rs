//! Speculative sampling (paper §II-B, Leviathan et al. [3]).
//!
//! * [`sampling`] — token-level accept rules: greedy (the paper's setting)
//!   and the stochastic min(1, p_t/p_d) rule as an extension.
//! * [`session`] — the resumable [`DecodeSession`] state machine: a
//!   two-phase `plan()`/`apply()` protocol (one engine call per cycle, so
//!   an external executor can fuse compatible calls across sessions) with
//!   a thin `step()` wrapper advancing one speculation round (or one
//!   baseline token) at a time, in both compiler abstractions —
//!   **modular** (separate drafter/target executables, control flow in
//!   Rust — paper Fig. 4) and **monolithic** (one fused spec-step HLO per
//!   γ — paper Fig. 3).
//! * [`decoder`] — setup/outcome types and the run-to-completion
//!   [`Decoder`] façade over sessions.

pub mod decoder;
pub mod sampling;
pub mod session;

pub use decoder::{DecodeOutcome, Decoder, DecoderSetup};
pub use sampling::{
    greedy_accept_len, stochastic_accept, top1, top_k_into, tree_verify_node, AcceptRule,
    NodeVerdict,
};
pub use session::{
    DecodeSession, EngineReply, EngineRequest, ForwardReply, FuseKey, RequestKind,
    SessionLimits, SessionPlan, StepOutcome, StepProgress,
};
