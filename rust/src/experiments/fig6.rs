//! Fig. 6 — cost coefficient c vs input sequence length, per design variant.
//! (a) homogeneous CPU mappings; (b) heterogeneous (drafter on GPU).
//!
//! c > 1 regions are infeasible (paper's red shading). The black reference
//! line in the paper is S_L = 63 (translation average) — our CSV includes
//! that column and the console table prints the S_L = 63 row. A real-PJRT
//! validation column (drafter/target wall-clock ratio on this machine's
//! CPU) is appended for the homogeneous case.

use crate::config::KernelPath;
use crate::models::VariantKey;
use crate::profiler;

use super::Ctx;

const SEQS: &[usize] = &[8, 16, 24, 32, 48, 63, 80, 96, 112, 128];

pub fn run(ctx: &Ctx, heterogeneous: bool) -> anyhow::Result<()> {
    let which = if heterogeneous { "fig6b" } else { "fig6a" };
    let drafter = VariantKey::parse("drafter_fp").unwrap();
    let target = VariantKey::parse("target_w8a8").unwrap();

    let points = profiler::cost_curves(
        &ctx.lat, &ctx.engine, drafter, target, SEQS, heterogeneous, None,
    )?;

    let mut csv = String::from("variant,seq,c_sim,infeasible\n");
    for p in &points {
        csv.push_str(&format!(
            "{},{},{:.4},{}\n",
            p.variant, p.seq, p.c_sim, (p.c_sim > 1.0) as u8
        ));
    }

    println!(
        "Fig. 6{} — cost coefficient c ({}) — S_L = 63 column:",
        if heterogeneous { "b" } else { "a" },
        if heterogeneous { "drafter on Mali-G310" } else { "homogeneous CPU" }
    );
    println!("{:<26} {:>8} {:>11}", "design variant", "c(63)", "feasible?");
    for v in 1..=ctx.lat.platform.design_variants() {
        let c63 = points
            .iter()
            .find(|p| p.variant == v && p.seq == 63)
            .map(|p| p.c_sim)
            .unwrap_or(f64::NAN);
        println!(
            "{:<26} {:>8.3} {:>11}",
            format!("{} (C-A55 {v}C{})", v, if heterogeneous { " + GPU" } else { "" }),
            c63,
            if c63 < 1.0 { "yes" } else { "NO (red)" }
        );
    }

    // Real-hardware validation (homogeneous only: no Mali on this machine):
    // the measured drafter/target PJRT latency ratio at S_L = 63.
    if !heterogeneous {
        let c_real = profiler::real_cost_coefficient(
            &ctx.engine, drafter, target, KernelPath::Pallas, 63, 5,
        )?;
        println!("real PJRT-CPU c(63) on this machine: {c_real:.3} \
                  (shape check; absolute scale differs from the A55)");
        csv.push_str(&format!("real_pjrt,63,{c_real:.4},{}\n", (c_real > 1.0) as u8));
    }

    ctx.write_csv(&format!("{which}.csv"), &csv)?;
    Ok(())
}
