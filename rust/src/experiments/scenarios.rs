//! `experiment scenarios` — the scenario subsystem exercised end to end at
//! the decision level. For every built-in scenario
//! ([`builtin_scenarios`]): generate the seeded trace, save/load it and
//! prove the round trip is bit-identical, then decode it twice — once
//! under the frozen global policy (`drafter: fixed`, one α state per
//! task) and once under the per-class policy (`drafter: auto` with the
//! manifest's [`DrafterRegistry`]). Rounds are priced on the platform
//! latency model and acceptances drawn from each entry's true α regime
//! (quantized drafts survive chat/translate-style continuations but
//! collapse on the extractive classes), so the sweep shows exactly where
//! per-class drafter selection pays.
//!
//! Self-asserts, per the roadmap's scenario milestone:
//! * saved traces replay bit-for-bit (fresh policy on the loaded trace
//!   reproduces token counts and simulated clock to the last bit),
//! * every mixed trace drives the classes to *different* γ/drafter
//!   decisions within one run,
//! * the per-class policy strictly wins aggregate ms/token on at least
//!   one scenario,
//! * the single-class trace under `drafter: fixed` is bit-identical
//!   through the drafter-aware route surface and the pre-registry one.

use super::Ctx;
use crate::config::{DecisionMode, DrafterMode, RunConfig, TreeChoice};
use crate::decision::{Policy, SpecHints};
use crate::hetero::LatencyModel;
use crate::models::{ModelSpec, Scheme};
use crate::scenario::{
    builtin_scenarios, DrafterRegistry, RequestClass, TraceEntry, WorkloadTrace,
};
use crate::util::rng::Rng;

/// Decision sequence length (mirrors the serving default bucket).
const SEQ: usize = 63;

/// The 3-core operating point: the heterogeneous mapping prices out
/// (c ≥ 1 — GPU drafting cannot keep up with a 3-core target) and the
/// w8a8 target keeps every GPU-target mapping quantization-filtered, so
/// the drafter contest is fp-on-CPU vs the cheaper w8a8-on-CPU body —
/// the regime where per-class drafter selection is the live decision.
fn operating_cfg(base: &RunConfig) -> RunConfig {
    let mut cfg = base.clone();
    cfg.design_variant = 3;
    cfg.heterogeneous = false;
    cfg.decision = DecisionMode::Analytic;
    cfg.tree = TreeChoice::Off;
    cfg.speculative = true;
    cfg.gamma = None;
    cfg.repartition_every = 8;
    cfg
}

fn frozen_policy(ctx: &Ctx) -> anyhow::Result<Policy> {
    let mut cfg = operating_cfg(&ctx.cfg);
    cfg.drafter = DrafterMode::Fixed;
    Policy::new(&cfg, ctx.lat.platform.clone())
}

fn auto_policy(ctx: &Ctx) -> anyhow::Result<Policy> {
    let mut cfg = operating_cfg(&ctx.cfg);
    cfg.drafter = DrafterMode::Auto;
    let policy = Policy::new(&cfg, ctx.lat.platform.clone())?;
    policy.set_drafter_registry(DrafterRegistry::from_manifest(&ctx.engine.manifest)?);
    Ok(policy)
}

/// How well each class's drafts survive quantization: the w8a8 drafter
/// tracks the w8a8 target's rounding on the conversational classes but
/// loses most of its acceptances on the extractive / structured ones.
fn quant_factor(class: RequestClass) -> f64 {
    match class {
        RequestClass::Chat | RequestClass::Translate => 1.0,
        RequestClass::Summarize => 0.40,
        RequestClass::CodeComplete => 0.50,
    }
}

/// Ground-truth acceptance rate for one entry under one drafter scheme.
fn true_alpha(e: &TraceEntry, scheme: Scheme) -> f64 {
    match scheme {
        Scheme::Fp => e.alpha_regime,
        Scheme::W8a8 => (e.alpha_regime * quant_factor(e.class)).min(0.98),
    }
}

/// Aggregate outcome of decoding one trace under one policy.
#[derive(Debug, Clone, Copy, Default)]
struct Agg {
    tokens: u64,
    rounds: u64,
    sim_s: f64,
    deadline_misses: u64,
}

impl Agg {
    fn ms_per_token(&self) -> f64 {
        self.sim_s * 1e3 / self.tokens.max(1) as f64
    }

    /// Bit-exact fingerprint for the replay-determinism assert.
    fn bits(&self) -> (u64, u64, u64, u64) {
        (self.tokens, self.rounds, self.sim_s.to_bits(), self.deadline_misses)
    }
}

/// Decode every trace entry against `policy`: admit at the policy's
/// drafter for the entry's task, re-consult between rounds, price each
/// round on the latency model and draw acceptances from the entry's true
/// α (seeded per entry, so the same trace always replays bit-for-bit).
/// `legacy` drives the pre-registry route/observe surface instead (only
/// meaningful under `drafter: fixed`) — the parity leg's reference.
fn simulate(
    lat: &LatencyModel,
    policy: &Policy,
    d_spec: &ModelSpec,
    t_spec: &ModelSpec,
    trace: &WorkloadTrace,
    legacy: bool,
) -> Agg {
    let (default_drafter, target) = policy.variants();
    let hints = SpecHints::default();
    let mut agg = Agg::default();
    for e in &trace.entries {
        let drafter = if legacy { default_drafter } else { policy.drafter_for(&e.task) };
        let admit = if legacy {
            policy.route_with(&e.task, d_spec, t_spec, SEQ, hints)
        } else {
            policy.route_with_drafter(&e.task, drafter, d_spec, t_spec, SEQ, hints)
        };
        let mapping = admit.mapping;
        let alpha = true_alpha(e, drafter.scheme);
        let mut rng = Rng::new(trace.seed ^ e.id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let (mut produced, mut drafted, mut accepted) = (0usize, 0usize, 0usize);
        let mut entry_s = 0.0;
        while produced < e.max_new {
            let session_alpha =
                if drafted > 0 { accepted as f64 / drafted as f64 } else { f64::NAN };
            let dec = if legacy {
                policy.route_round_with(
                    &e.task, d_spec, t_spec, mapping, SEQ, drafted, session_alpha, hints,
                )
            } else {
                policy.route_round_with_drafter(
                    &e.task,
                    drafter,
                    d_spec,
                    t_spec,
                    mapping,
                    SEQ,
                    drafted,
                    session_alpha,
                    hints,
                )
            };
            let t_target = lat.forward_latency(t_spec, target.scheme, mapping.target, SEQ);
            if dec.speculative && dec.gamma > 0 {
                let t_draft = lat.forward_latency(d_spec, drafter.scheme, mapping.drafter, SEQ);
                entry_s += dec.gamma as f64 * t_draft + t_target;
                let mut acc = 0usize;
                for _ in 0..dec.gamma {
                    if rng.f64() < alpha {
                        acc += 1;
                    } else {
                        break;
                    }
                }
                drafted += dec.gamma;
                accepted += acc;
                produced += acc + 1;
            } else {
                entry_s += t_target;
                produced += 1;
            }
            agg.rounds += 1;
        }
        let observed = if drafted > 0 { accepted as f64 / drafted as f64 } else { f64::NAN };
        if legacy {
            policy.observe_alpha(&e.task, observed);
        } else {
            policy.observe_alpha_tagged(&e.task, drafter, observed);
        }
        agg.tokens += produced as u64;
        agg.sim_s += entry_s;
        if let Some(d) = e.deadline_s {
            if entry_s > d {
                agg.deadline_misses += 1;
            }
        }
    }
    agg
}

pub fn run(ctx: &Ctx) -> anyhow::Result<()> {
    println!("Scenario sweep: per-class decisions + drafter selection vs frozen policy");

    let requests = ctx.limit.unwrap_or(96).clamp(16, 400);
    let scenarios = builtin_scenarios(requests, ctx.seed);

    let probe = frozen_policy(ctx)?;
    let (dkey, tkey) = probe.variants();
    let d_spec = ctx.engine.manifest.model_for(dkey)?.clone();
    let t_spec = ctx.engine.manifest.model_for(tkey)?.clone();

    let mut csv = String::from(
        "scenario,policy,requests,classes,tokens,rounds,sim_s,ms_per_token,\
         deadline_misses,chat_drafter,translate_drafter,summarize_drafter,\
         code_complete_drafter\n",
    );
    let mut wins = 0usize;

    for spec in &scenarios {
        let trace = spec.generate();

        // Persistence: the JSONL round trip is lossless and canonical.
        let path = ctx.out_dir.join(format!("trace_{}.jsonl", spec.name));
        trace.save(&path)?;
        let loaded = WorkloadTrace::load(&path)?;
        anyhow::ensure!(loaded == trace, "trace {} did not survive save->load", spec.name);
        anyhow::ensure!(
            loaded.to_jsonl() == trace.to_jsonl(),
            "trace {} serialization is not canonical",
            spec.name
        );

        // Frozen global policy: one drafter, task-level α state only.
        let frozen = frozen_policy(ctx)?;
        let agg_frozen = simulate(&ctx.lat, &frozen, &d_spec, &t_spec, &trace, false);

        // Per-class policy with drafter selection over the registry.
        let auto = auto_policy(ctx)?;
        let agg_auto = simulate(&ctx.lat, &auto, &d_spec, &t_spec, &trace, false);

        // Replay determinism: a *fresh* policy decoding the loaded trace
        // reproduces the auto run to the last bit.
        let replay = auto_policy(ctx)?;
        let agg_replay = simulate(&ctx.lat, &replay, &d_spec, &t_spec, &loaded, false);
        anyhow::ensure!(
            agg_replay.bits() == agg_auto.bits(),
            "scenario {}: replay of the saved trace diverged",
            spec.name
        );

        if agg_auto.ms_per_token() < agg_frozen.ms_per_token() {
            wins += 1;
        }

        let counts = trace.class_counts();
        for (name, policy, agg) in
            [("frozen", &frozen, &agg_frozen), ("auto", &auto, &agg_auto)]
        {
            let chosen: Vec<String> = RequestClass::all()
                .iter()
                .map(|c| {
                    if counts[c.index()] == 0 {
                        "-".to_string()
                    } else {
                        policy.drafter_for(c.task_pool()[0]).name()
                    }
                })
                .collect();
            csv.push_str(&format!(
                "{},{},{},{},{},{},{:.6},{:.4},{},{},{},{},{}\n",
                spec.name,
                name,
                trace.entries.len(),
                trace.class_count(),
                agg.tokens,
                agg.rounds,
                agg.sim_s,
                agg.ms_per_token(),
                agg.deadline_misses,
                chosen[0],
                chosen[1],
                chosen[2],
                chosen[3],
            ));
        }
        println!(
            "  {:<18} frozen {:>8.4} ms/tok | auto {:>8.4} ms/tok ({} req, {} classes)",
            spec.name,
            agg_frozen.ms_per_token(),
            agg_auto.ms_per_token(),
            trace.entries.len(),
            trace.class_count()
        );

        // Per-class divergence: on a mixed trace the classes must settle
        // on different drafters or different γ within the one run.
        if trace.class_count() >= 2 {
            let mut per_class: Vec<(String, usize)> = Vec::new();
            for class in RequestClass::all() {
                if counts[class.index()] == 0 {
                    continue;
                }
                let task = class.task_pool()[0];
                let key = auto.drafter_for(task);
                let dec = auto
                    .route_with_drafter(task, key, &d_spec, &t_spec, SEQ, SpecHints::default());
                per_class.push((key.name(), dec.gamma));
            }
            let diverged = per_class
                .iter()
                .any(|a| per_class.iter().any(|b| a.0 != b.0 || a.1 != b.1));
            anyhow::ensure!(
                diverged,
                "scenario {}: classes settled on identical drafter and gamma \
                 despite distinct alpha regimes",
                spec.name
            );
        }
    }

    // Pinned path: the single-class trace under `drafter: fixed` decodes
    // bit-identically through the drafter-aware surface and the
    // pre-registry surface.
    let single = &scenarios[0];
    anyhow::ensure!(single.mix.len() == 1, "scenarios[0] must be the single-class anchor");
    let trace = single.generate();
    let p_legacy = frozen_policy(ctx)?;
    let a_legacy = simulate(&ctx.lat, &p_legacy, &d_spec, &t_spec, &trace, true);
    let p_tagged = frozen_policy(ctx)?;
    let a_tagged = simulate(&ctx.lat, &p_tagged, &d_spec, &t_spec, &trace, false);
    anyhow::ensure!(
        a_tagged.bits() == a_legacy.bits(),
        "single-class fixed-drafter run diverged from the pre-registry path"
    );

    anyhow::ensure!(
        wins >= 1,
        "per-class drafter selection never strictly beat the frozen policy"
    );
    println!("  strict ms/token wins: {wins}/{} scenarios", scenarios.len());
    ctx.write_csv("scenarios.csv", &csv)?;
    Ok(())
}
