//! Heterogeneous draft/verify overlap — the per-PU timeline experiment.
//!
//! The paper's central claim is that speculative sampling and
//! heterogeneous execution are *jointly* beneficial. A single serialized
//! clock can never show the joint part: with the drafter mapped to the
//! GPU and the target to the CPU cluster, one session's draft forwards
//! can only overlap *co-scheduled* sessions' verify forwards if each PU
//! has its own timeline. This driver runs the same co-scheduled session
//! sets under both timeline modes and reports, per in-flight count:
//!
//! * the serialized makespan (`hetero_overlap: false` — equal to the
//!   summed per-PU busy time, conservation-checked),
//! * the overlapped makespan, the resulting measured makespan speedup
//!   and the cost model's pipeline-bound prediction
//!   ([`costmodel::predicted_pipeline_speedup`]),
//! * the simulated overlap fraction vs the steady-state bound
//!   ([`costmodel::predicted_overlap_frac`]), both evaluated at the mean
//!   γ of the sessions *actually co-scheduled at that in-flight count*.
//!
//! Sessions are given *staggered* draft lengths (γ cycling over 2..=5) so
//! their draft and verify phases de-phase: in any tick some sessions are
//! drafting on the GPU while others verify on the CPU. Identically-phased
//! sessions would instead fuse into one shared dispatch per tick —
//! batching, the *other* axis of concurrency — and leave nothing to
//! overlap.

use crate::config::{ExecMode, KernelPath};
use crate::coordinator::fuser::{self, TickEvent};
use crate::costmodel;
use crate::hetero::{LatencyModel, Mapping, PuId, PuTimelines};
use crate::models::{Scheme, VariantKey};
use crate::runtime::Engine;
use crate::spec::{AcceptRule, DecodeSession, DecoderSetup};
use crate::workload::prompt_ids;

use super::Ctx;

const GAMMAS: &[usize] = &[2, 3, 4, 5];
const MAX_NEW: usize = 24;

fn setup(gamma: usize) -> DecoderSetup {
    DecoderSetup {
        drafter: VariantKey::parse("drafter_fp").unwrap(),
        target: VariantKey::parse("target_w8a8").unwrap(),
        kernel: KernelPath::Ref,
        mapping: Mapping::heterogeneous(1),
        gamma,
        rule: AcceptRule::Greedy,
        exec: ExecMode::Modular,
        max_new: MAX_NEW,
    }
}

/// Tick `sessions` to completion on `tl` through the fused executor —
/// the one timeline drive loop, shared with the overlap e2e tests.
pub fn drive_to_completion(
    engine: &Engine,
    lat: &LatencyModel,
    sessions: &mut [DecodeSession],
    tl: &mut PuTimelines,
) -> anyhow::Result<()> {
    let mut ticks = 0usize;
    loop {
        let mut refs: Vec<&mut DecodeSession> =
            sessions.iter_mut().filter(|s| !s.is_done()).collect();
        if refs.is_empty() {
            return Ok(());
        }
        let (events, _stats) = fuser::tick(engine, lat, &mut refs, Some(&mut *tl), false);
        anyhow::ensure!(
            !events.iter().any(|e| matches!(e, TickEvent::Failed)),
            "session failed during timeline drive"
        );
        ticks += 1;
        anyhow::ensure!(ticks < 100_000, "timeline drive failed to converge");
    }
}

struct ModeResult {
    makespan: f64,
    busy_cpu: f64,
    busy_gpu: f64,
    overlap_s: f64,
    tokens: Vec<Vec<u32>>,
}

/// Drive `n` staggered sessions to completion through the fused tick
/// executor against the given timeline mode.
fn run_mode(ctx: &Ctx, prompts: &[Vec<u32>], overlapped: bool) -> anyhow::Result<ModeResult> {
    let mut tl = if overlapped {
        PuTimelines::new()
    } else {
        PuTimelines::serialized()
    };
    let mut sessions: Vec<DecodeSession> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            DecodeSession::new(&ctx.engine, ctx.lat.clone(), setup(GAMMAS[i % GAMMAS.len()]),
                               true, p)
        })
        .collect();
    drive_to_completion(&ctx.engine, &ctx.lat, &mut sessions, &mut tl)?;
    Ok(ModeResult {
        makespan: tl.makespan(),
        busy_cpu: tl.busy(PuId::Cpu),
        busy_gpu: tl.busy(PuId::Gpu),
        overlap_s: tl.overlap_s(),
        tokens: sessions.into_iter().map(|s| s.into_outcome().tokens).collect(),
    })
}

/// Mean γ of the first `n` staggered sessions — the operating point the
/// pipeline bound is evaluated at for that in-flight count.
fn mean_gamma(n: usize) -> f64 {
    (0..n).map(|i| GAMMAS[i % GAMMAS.len()] as f64).sum::<f64>() / n as f64
}

pub fn run(ctx: &Ctx) -> anyhow::Result<()> {
    let d = ctx.engine.manifest.model_for(VariantKey::parse("drafter_fp").unwrap())?;
    let t = ctx.engine.manifest.model_for(VariantKey::parse("target_w8a8").unwrap())?;
    let c = ctx.lat.cost_coefficient(
        (d, Scheme::Fp), (t, Scheme::W8a8), Mapping::heterogeneous(1), 63);

    let samples: Vec<_> = ctx
        .engine
        .manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .cloned()
        .collect();
    anyhow::ensure!(!samples.is_empty(), "eval set has no translate samples");

    let max_n = ctx.limit.unwrap_or(8).clamp(1, 16);
    println!("Overlap — per-PU timelines, drafter@GPU / target@CPU (c = {c:.3}):");
    println!(
        "{:<10} {:>12} {:>12} {:>8} {:>9} {:>10} {:>10} {:>9} {:>9}",
        "inflight", "serial_ms", "overlap_ms", "speedup", "pred_spd",
        "busy_cpu", "busy_gpu", "sim_frac", "pred_frac"
    );
    let mut csv = String::from(
        "inflight,serialized_makespan_s,overlapped_makespan_s,speedup,\
         predicted_pipeline_speedup,busy_cpu_s,busy_gpu_s,overlap_s,\
         sim_overlap_frac,predicted_overlap_frac\n",
    );
    for n in [1usize, 2, 4, 8] {
        if n > max_n {
            break;
        }
        let prompts: Vec<Vec<u32>> = (0..n)
            .map(|i| prompt_ids(&ctx.tokenizer, &samples[i % samples.len()]))
            .collect::<anyhow::Result<_>>()?;
        let serial = run_mode(ctx, &prompts, false)?;
        let over = run_mode(ctx, &prompts, true)?;
        // The timeline mode must not change what is decoded…
        anyhow::ensure!(over.tokens == serial.tokens, "timeline mode changed tokens");
        // …and the serialized makespan must conserve the busy sum.
        anyhow::ensure!(
            (serial.makespan - (serial.busy_cpu + serial.busy_gpu)).abs()
                < 1e-9 * serial.makespan.max(1.0),
            "serialized makespan {} != busy sum {}",
            serial.makespan,
            serial.busy_cpu + serial.busy_gpu
        );
        // The bound at this row's actual γ mix (n=1 runs only γ=2, …).
        let g = mean_gamma(n);
        let pred_frac = costmodel::predicted_overlap_frac(g, c);
        let pred_speedup = costmodel::predicted_pipeline_speedup(g, c);
        let speedup = serial.makespan / over.makespan.max(1e-12);
        let sim_frac = over.overlap_s / over.makespan.max(1e-12);
        println!(
            "{:<10} {:>12.2} {:>12.2} {:>8.3} {:>9.3} {:>10.2} {:>10.2} {:>9.3} {:>9.3}",
            n, serial.makespan * 1e3, over.makespan * 1e3, speedup, pred_speedup,
            over.busy_cpu * 1e3, over.busy_gpu * 1e3, sim_frac, pred_frac
        );
        csv.push_str(&format!(
            "{n},{:.6},{:.6},{:.4},{:.4},{:.6},{:.6},{:.6},{:.4},{:.4}\n",
            serial.makespan, over.makespan, speedup, pred_speedup,
            over.busy_cpu, over.busy_gpu, over.overlap_s, sim_frac, pred_frac
        ));
    }
    ctx.write_csv("overlap.csv", &csv)?;
    Ok(())
}
