//! Fig. 7 — acceleration S vs acceptance rate α for γ = 1..5, design
//! variant 1 heterogeneous (quantized target on one CPU core, fp drafter on
//! the GPU).
//!
//! (a) predicted: Eq. (1) curves at the variant-1 cost coefficient.
//! (b) measured: real speculative decodes over eval samples; per sample we
//!     record its empirical α and its acceleration (simulated baseline time
//!     / simulated speculative time), then bin by α. The paper reports the
//!     measured curve landing ≈4% right of the prediction — our §IV-D
//!     equivalent (modular boundary overhead) is quantified by the
//!     `deviation` experiment.

use crate::config::{ExecMode, KernelPath};
use crate::costmodel;
use crate::hetero::Mapping;
use crate::models::{Scheme, VariantKey};
use crate::spec::{AcceptRule, Decoder, DecoderSetup};
use crate::workload::prompt_ids;

use super::Ctx;

const GAMMAS: &[usize] = &[1, 2, 3, 4, 5];

fn variant1_c(ctx: &Ctx) -> anyhow::Result<f64> {
    let d = ctx.engine.manifest.model_for(VariantKey::parse("drafter_fp").unwrap())?;
    let t = ctx.engine.manifest.model_for(VariantKey::parse("target_w8a8").unwrap())?;
    Ok(ctx.lat.cost_coefficient(
        (d, Scheme::Fp), (t, Scheme::W8a8), Mapping::heterogeneous(1), 63))
}

/// (a) predicted curves.
pub fn run_predicted(ctx: &Ctx) -> anyhow::Result<()> {
    let c = variant1_c(ctx)?;
    println!("Fig. 7a — predicted S(alpha, gamma, c = {c:.3}):");
    let mut csv = String::from("alpha,gamma,speedup\n");
    print!("{:<7}", "alpha");
    for g in GAMMAS {
        print!(" {:>8}", format!("g={g}"));
    }
    println!();
    for i in 0..=20 {
        let alpha = i as f64 / 20.0;
        print!("{:<7.2}", alpha);
        for &g in GAMMAS {
            let s = costmodel::speedup(alpha, g, c);
            print!(" {:>8.3}", s);
            csv.push_str(&format!("{alpha:.3},{g},{s:.4}\n"));
        }
        println!();
    }
    ctx.write_csv("fig7a.csv", &csv)?;
    Ok(())
}

/// (b) measured acceleration via real speculative decodes.
pub fn run_measured(ctx: &Ctx) -> anyhow::Result<()> {
    let c = variant1_c(ctx)?;
    let n_samples = ctx.limit.unwrap_or(16);
    // Use translate samples first, then other tasks to widen the α range
    // (our semi-quantized per-sample α spans a narrower band than the
    // paper's 0..1 — see EXPERIMENTS.md).
    let mut samples: Vec<_> = ctx
        .engine
        .manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .take(n_samples / 2)
        .cloned()
        .collect();
    let per_other = 1.max(n_samples / 2 / 12);
    let mut counts: std::collections::HashMap<String, usize> = Default::default();
    for s in &ctx.engine.manifest.eval_samples.clone() {
        if s.task == "translate" || samples.len() >= n_samples {
            continue;
        }
        let c = counts.entry(s.task.clone()).or_insert(0);
        if *c < per_other {
            *c += 1;
            samples.push(s.clone());
        }
    }

    let mut csv = String::from("task,gamma,alpha,accel_sim,accel_real,predicted\n");
    println!(
        "Fig. 7b — measured acceleration (variant 1 hetero, semi pair, \
         {} samples x gammas {:?}):",
        samples.len(), GAMMAS
    );

    for s in &samples {
        let prompt = prompt_ids(&ctx.tokenizer, s)?;
        let base_setup = DecoderSetup {
            drafter: VariantKey::parse("drafter_fp").unwrap(),
            target: VariantKey::parse("target_w8a8").unwrap(),
            kernel: KernelPath::Pallas,
            mapping: Mapping::heterogeneous(1),
            gamma: 1,
            rule: AcceptRule::Greedy,
            exec: ExecMode::Modular,
            max_new: 64,
        };
        let decoder = Decoder::new(&ctx.engine, ctx.lat.clone(), base_setup.clone());
        let baseline = decoder.baseline(&prompt)?;
        if baseline.tokens.is_empty() {
            continue;
        }
        for &g in GAMMAS {
            let mut setup = base_setup.clone();
            setup.gamma = g;
            let decoder = Decoder::new(&ctx.engine, ctx.lat.clone(), setup);
            let spec = decoder.speculative(&prompt)?;
            if spec.n_drafted == 0 {
                continue;
            }
            // Normalize per token: EOS position can differ slightly between
            // paths when quant flips a borderline decision.
            let base_per_tok = baseline.sim_s / baseline.tokens.len().max(1) as f64;
            let spec_per_tok = spec.sim_s / spec.tokens.len().max(1) as f64;
            let accel_sim = base_per_tok / spec_per_tok;
            let base_real = baseline.real_s / baseline.tokens.len().max(1) as f64;
            let spec_real = spec.real_s / spec.tokens.len().max(1) as f64;
            let alpha = spec.alpha();
            let predicted = costmodel::speedup(alpha, g, c);
            csv.push_str(&format!(
                "{},{},{:.4},{:.4},{:.4},{:.4}\n",
                s.task, g, alpha, accel_sim, base_real / spec_real, predicted
            ));
        }
    }

    // Console: binned means per γ.
    println!("{:<6} {:<12} {:>10} {:>12} {:>12}", "gamma", "alpha bin",
             "n", "mean accel", "mean pred");
    for &g in GAMMAS {
        for bin in 0..5 {
            let lo = bin as f64 * 0.2;
            let hi = lo + 0.2;
            let rows: Vec<(f64, f64)> = csv
                .lines()
                .skip(1)
                .filter_map(|l| {
                    let f: Vec<&str> = l.split(',').collect();
                    let (gg, a, acc, pred): (usize, f64, f64, f64) = (
                        f[1].parse().ok()?,
                        f[2].parse().ok()?,
                        f[3].parse().ok()?,
                        f[5].parse().ok()?,
                    );
                    (gg == g && a >= lo && a < hi).then_some((acc, pred))
                })
                .collect();
            if rows.is_empty() {
                continue;
            }
            let n = rows.len();
            let ma = rows.iter().map(|r| r.0).sum::<f64>() / n as f64;
            let mp = rows.iter().map(|r| r.1).sum::<f64>() / n as f64;
            println!(
                "{:<6} [{:.1},{:.1}) {:>10} {:>12.3} {:>12.3}",
                g, lo, hi, n, ma, mp
            );
        }
    }
    ctx.write_csv("fig7b.csv", &csv)?;
    Ok(())
}
