//! Serving-shell load benchmark (`experiment serve_load`) — the
//! event-loop-vs-threaded comparison the server rewrite is justified by,
//! plus open-loop SLO behavior and drain correctness. Four phases, one
//! CSV (`serve_load.csv`, tagged by the `phase` column) and one JSON-lines
//! file (`serve_load.jsonl`, one [`LoadReport`] per phase):
//!
//! **threaded_closed / event_closed** — the same closed-loop
//! connection-churn workload (every request pays a fresh TCP connect —
//! the regime where thread-per-connection serving pays a serialized
//! accept + thread spawn per request) against each `serve_mode`, same
//! seed, same prompt schedule, same engine config. Asserts:
//! every request completes (zero shed / error / corrupt) in both modes,
//! and the per-request completions are **byte-identical across modes**
//! (`c{client}.r{seq}` → completion) — the shells may only differ in
//! *when* bytes move, never in *which* bytes. At ≥ 1000 clients (and not
//! under `SPECEDGE_BENCH_SMOKE`) additionally asserts the event-loop
//! shell **strictly wins** on both throughput and p99 latency.
//!
//! **event_open** — open-loop Poisson arrivals against the event-loop
//! shell with mixed SLO classes (half the clients interactive v2 lines
//! with a `deadline_ms`, half batch v1) and streaming on, reporting
//! p50/p99/p999, accept-to-first-frame and the deadline-miss rate.
//! Asserts zero corrupt streams and zero transport errors (deadline
//! expiries come back as *typed* replies, not drops).
//!
//! **event_drain** — one in-flight request per client, then
//! [`Server::drain`] fires *while they are executing* (the experiment
//! waits until every request is admitted first, so the race is
//! drain-vs-execution, not drain-vs-admission). Asserts the graceful
//! drain drops nothing: every single request still gets its `ok:true`
//! final, and the serving thread then exits on its own
//! ([`Server::wait`] returns).

use crate::config::{RunConfig, ServeMode};
use crate::coordinator::Coordinator;
use crate::loadgen::{self, LoadReport, LoadSpec};
use crate::server::{Backend, Server};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::Ctx;

/// Closed-loop requests per client (phases A/B).
const REQS_PER_CLIENT: usize = 2;

/// Engine + shell config shared by every phase: a deliberately light
/// decode (the experiment measures the *front door*, not the engine) and
/// an admission queue sized so closed-loop phases never shed.
fn serve_cfg(ctx: &Ctx, clients: usize, mode: ServeMode) -> RunConfig {
    let mut cfg = ctx.cfg.clone();
    cfg.serve_mode = mode;
    cfg.port = 0;
    cfg.workers = 2;
    cfg.max_inflight = 8;
    cfg.max_new_tokens = 2;
    cfg.queue_capacity = clients * 2 + 16;
    cfg.rate_limit_rps = 0.0;
    cfg.fleet_file = None;
    cfg.metrics_history_file = None;
    cfg
}

fn start_server(ctx: &Ctx, cfg: &RunConfig) -> anyhow::Result<(Server, Arc<Coordinator>)> {
    let coord = Arc::new(Coordinator::start(cfg.clone(), ctx.lat.platform.clone())?);
    let server = Server::start_cfg(
        Backend::Single(Arc::clone(&coord)),
        ctx.tokenizer.clone(),
        cfg,
    )?;
    Ok((server, coord))
}

/// Stop the serving shell, then reclaim and shut down the engine.
fn stop_server(server: Server, coord: Arc<Coordinator>) {
    server.stop();
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}

/// Format a float CSV cell, empty when the metric has no samples.
fn fm(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        String::new()
    }
}

fn record(csv: &mut String, jsonl: &mut String, mode: &str, phase: &str, r: &LoadReport) {
    println!(
        "  {phase:<16} {mode:<10} {:>5} clients  {:>6} req  {:>6} ok  \
         {:>4} shed  {:>3} err  {:>3} corrupt  {:>8.1} req/s  \
         p50 {} ms  p99 {} ms  miss {:.3}",
        r.clients,
        r.issued,
        r.completed,
        r.shed,
        r.errors,
        r.corrupt,
        r.throughput_rps,
        fm(r.p50_ms),
        fm(r.p99_ms),
        r.deadline_miss_rate(),
    );
    csv.push_str(&format!(
        "{mode},{phase},{},{},{},{},{},{},{:.3},{:.2},{},{},{},{},{},{:.4}\n",
        r.clients,
        r.issued,
        r.completed,
        r.shed,
        r.errors,
        r.corrupt,
        r.wall_s,
        r.throughput_rps,
        fm(r.p50_ms),
        fm(r.p99_ms),
        fm(r.p999_ms),
        fm(r.ttff_p50_ms),
        fm(r.ttff_p99_ms),
        r.deadline_miss_rate(),
    ));
    let mut j = r.to_json();
    j.set("mode", crate::util::json::Json::Str(mode.into()))
        .set("phase", crate::util::json::Json::Str(phase.into()));
    jsonl.push_str(&j.to_string());
    jsonl.push('\n');
}

/// A phase's requests must all complete, with no shed, error or
/// corruption — the closed-loop phases are sized so anything else is a
/// serving-shell bug, not load.
fn assert_clean(phase: &str, r: &LoadReport) -> anyhow::Result<()> {
    anyhow::ensure!(
        r.completed == r.issued && r.shed == 0 && r.errors == 0,
        "{phase}: {} of {} completed ({} shed, {} errors) — requests were lost",
        r.completed,
        r.issued,
        r.shed,
        r.errors
    );
    anyhow::ensure!(r.corrupt == 0, "{phase}: {} corrupted reply streams", r.corrupt);
    Ok(())
}

pub fn run(ctx: &Ctx) -> anyhow::Result<()> {
    let smoke = std::env::var("SPECEDGE_BENCH_SMOKE").is_ok();
    let clients = ctx.limit.unwrap_or(if smoke { 64 } else { 1200 }).max(2);
    // The headline claim is only asserted at benchmark scale: small runs
    // (CI smoke) check correctness and parity, not the perf ordering.
    let strict = clients >= 1000 && !smoke;

    let prompts: Vec<String> = ctx
        .engine
        .manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .take(6)
        .map(|s| s.prompt.clone())
        .collect();
    anyhow::ensure!(!prompts.is_empty(), "no translate eval samples in the manifest");

    let base = LoadSpec {
        clients,
        requests_per_client: REQS_PER_CLIENT,
        reconnect_per_request: true,
        record_completions: true,
        prompts,
        task: "translate".into(),
        seed: ctx.seed,
        ..LoadSpec::default()
    };

    let mut csv = String::from(
        "mode,phase,clients,issued,completed,shed,errors,corrupt,wall_s,\
         throughput_rps,p50_ms,p99_ms,p999_ms,ttff_p50_ms,ttff_p99_ms,\
         deadline_miss_rate\n",
    );
    let mut jsonl = String::new();

    println!(
        "Serving load ({clients} clients x {REQS_PER_CLIENT} requests closed-loop, \
         strict perf assert: {strict}):"
    );

    // ---- A: threaded shell, closed-loop churn --------------------------
    let cfg_a = serve_cfg(ctx, clients, ServeMode::Threaded);
    let (server_a, coord_a) = start_server(ctx, &cfg_a)?;
    let spec_a = LoadSpec { port: server_a.port, ..base.clone() };
    let a = loadgen::run(&spec_a)?;
    stop_server(server_a, coord_a);
    record(&mut csv, &mut jsonl, "threaded", "threaded_closed", &a);
    assert_clean("threaded_closed", &a)?;

    // ---- B: event-loop shell, identical workload -----------------------
    let mut cfg_b = serve_cfg(ctx, clients, ServeMode::EventLoop);
    let history = ctx.out_dir.join("metrics_history.jsonl");
    let _ = std::fs::remove_file(&history);
    cfg_b.metrics_history_file = Some(history.clone());
    let (server_b, coord_b) = start_server(ctx, &cfg_b)?;
    let spec_b = LoadSpec { port: server_b.port, ..base.clone() };
    let b = loadgen::run(&spec_b)?;
    stop_server(server_b, coord_b);
    record(&mut csv, &mut jsonl, "event_loop", "event_closed", &b);
    assert_clean("event_closed", &b)?;

    // Wire parity: identical per-request token streams across shells.
    anyhow::ensure!(
        a.completions.len() == a.issued && b.completions.len() == b.issued,
        "parity: completion records missing ({} / {} vs {} / {})",
        a.completions.len(),
        a.issued,
        b.completions.len(),
        b.issued
    );
    anyhow::ensure!(
        a.completions == b.completions,
        "event_loop and threaded shells produced different completions \
         for the same request schedule"
    );
    println!(
        "  parity: {} completions byte-identical across serve modes OK",
        a.completions.len()
    );
    // The history file must have accumulated snapshots (at least the
    // final at-exit line).
    let hist_lines = std::fs::read_to_string(&history)
        .map(|s| s.lines().count())
        .unwrap_or(0);
    anyhow::ensure!(hist_lines > 0, "metrics history {history:?} is empty");

    if strict {
        anyhow::ensure!(
            b.throughput_rps > a.throughput_rps,
            "event_loop throughput ({:.1} req/s) did not beat threaded ({:.1} req/s) \
             at {clients} clients",
            b.throughput_rps,
            a.throughput_rps
        );
        anyhow::ensure!(
            b.p99_ms < a.p99_ms,
            "event_loop p99 ({:.1} ms) did not beat threaded ({:.1} ms) at {clients} clients",
            b.p99_ms,
            a.p99_ms
        );
        println!(
            "  win: event_loop {:.1} req/s / p99 {:.1} ms vs threaded {:.1} req/s / \
             p99 {:.1} ms OK",
            b.throughput_rps,
            b.p99_ms,
            a.throughput_rps,
            a.p99_ms
        );
    }

    // ---- C: event-loop shell, open-loop Poisson, mixed SLO classes -----
    let cfg_c = serve_cfg(ctx, clients, ServeMode::EventLoop);
    let (server_c, coord_c) = start_server(ctx, &cfg_c)?;
    let spec_c = LoadSpec {
        port: server_c.port,
        open_loop_rps: (clients as f64 * 0.5).clamp(8.0, 400.0),
        duration_s: 3.0,
        reconnect_per_request: false,
        streaming: true,
        interactive_frac: 0.5,
        deadline_ms: 250.0,
        record_completions: false,
        ..base.clone()
    };
    let c = loadgen::run(&spec_c)?;
    stop_server(server_c, coord_c);
    record(&mut csv, &mut jsonl, "event_loop", "event_open", &c);
    anyhow::ensure!(c.corrupt == 0, "event_open: {} corrupted streams", c.corrupt);
    anyhow::ensure!(
        c.errors == 0,
        "event_open: {} transport errors (deadline expiries must be typed replies)",
        c.errors
    );
    anyhow::ensure!(c.completed > 0, "event_open: nothing completed");
    anyhow::ensure!(
        c.deadline_requests > 0,
        "event_open: no interactive-class requests were issued"
    );
    anyhow::ensure!(
        c.ttff_p50_ms.is_finite(),
        "event_open: streaming produced no first-frame samples"
    );

    // ---- D: graceful drain under in-flight load ------------------------
    let d_clients = clients.min(128);
    let mut cfg_d = serve_cfg(ctx, d_clients, ServeMode::EventLoop);
    // More work per request, so the drain genuinely races execution.
    cfg_d.max_new_tokens = 8;
    let (mut server_d, coord_d) = start_server(ctx, &cfg_d)?;
    let spec_d = LoadSpec {
        port: server_d.port,
        clients: d_clients,
        requests_per_client: 1,
        reconnect_per_request: false,
        record_completions: false,
        ..base.clone()
    };
    let stats = Arc::clone(&server_d.stats);
    let gen = std::thread::spawn(move || loadgen::run(&spec_d));
    // Wait until every request is admitted, then drain mid-execution.
    let t0 = Instant::now();
    while (stats.requests.load(Ordering::Relaxed) as usize) < d_clients {
        anyhow::ensure!(
            t0.elapsed() < Duration::from_secs(30),
            "event_drain: only {} of {d_clients} requests admitted after 30 s",
            stats.requests.load(Ordering::Relaxed)
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    server_d.drain();
    let d = gen.join().map_err(|_| anyhow::anyhow!("load thread panicked"))??;
    record(&mut csv, &mut jsonl, "event_loop", "event_drain", &d);
    anyhow::ensure!(
        d.issued == d_clients,
        "event_drain: issued {} of {d_clients}",
        d.issued
    );
    assert_clean("event_drain", &d)?;
    // The drain must also terminate the serving thread on its own.
    server_d.wait();
    println!("  drain: all {d_clients} in-flight requests completed, server exited OK");
    drop(server_d);
    if let Ok(c) = Arc::try_unwrap(coord_d) {
        c.shutdown();
    }

    ctx.write_csv("serve_load.csv", &csv)?;
    let jsonl_path = ctx.out_dir.join("serve_load.jsonl");
    std::fs::write(&jsonl_path, &jsonl)?;
    println!("  -> wrote {}", jsonl_path.display());
    Ok(())
}
