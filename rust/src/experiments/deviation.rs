//! §IV-D — modular-boundary overhead: the deviation source.
//!
//! The paper deployed the modular pipeline (IREE couldn't place a monolithic
//! graph heterogeneously) and attributes part of its 4% prediction deviation
//! to the per-call runtime-API overhead. We have *both* executors, so we can
//! quantify the gap directly: same prompts, same γ, modular vs monolithic —
//! identical tokens (greedy determinism), different boundary counts.

use crate::config::{ExecMode, KernelPath};
use crate::hetero::Mapping;
use crate::models::VariantKey;
use crate::spec::{AcceptRule, Decoder, DecoderSetup};
use crate::util::stats::Summary;
use crate::workload::prompt_ids;

use super::Ctx;

pub fn run(ctx: &Ctx) -> anyhow::Result<()> {
    let gamma = 5;
    let n = ctx.limit.unwrap_or(10);
    let samples: Vec<_> = ctx
        .engine
        .manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .take(n)
        .cloned()
        .collect();

    let setup = |exec: ExecMode| DecoderSetup {
        drafter: VariantKey::parse("drafter_fp").unwrap(),
        target: VariantKey::parse("target_w8a8").unwrap(),
        kernel: KernelPath::Pallas,
        mapping: Mapping::heterogeneous(1),
        gamma,
        rule: AcceptRule::Greedy,
        exec,
        max_new: 48,
    };

    let mut sim_ratio = Summary::new();
    let mut real_ratio = Summary::new();
    let mut tokens_match = 0usize;
    let mut csv = String::from(
        "sample,mod_sim_s,mono_sim_s,mod_real_s,mono_real_s,same_tokens\n");
    for (i, s) in samples.iter().enumerate() {
        let prompt = prompt_ids(&ctx.tokenizer, s)?;
        let modular = Decoder::new(&ctx.engine, ctx.lat.clone(), setup(ExecMode::Modular))
            .speculative(&prompt)?;
        let mono = Decoder::new(
            &ctx.engine, ctx.lat.clone(), setup(ExecMode::Monolithic))
            .speculative(&prompt)?;
        let same = modular.tokens == mono.tokens;
        tokens_match += same as usize;
        if mono.sim_s > 0.0 {
            sim_ratio.push(modular.sim_s / mono.sim_s);
        }
        if mono.real_s > 0.0 {
            real_ratio.push(modular.real_s / mono.real_s);
        }
        csv.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{:.6},{}\n",
            i, modular.sim_s, mono.sim_s, modular.real_s, mono.real_s, same as u8
        ));
    }

    let sim_overhead_pct = (sim_ratio.mean() - 1.0) * 100.0;
    println!("§IV-D deviation — modular vs monolithic (gamma = {gamma}, {} samples):", samples.len());
    println!("  identical outputs: {tokens_match}/{}", samples.len());
    println!(
        "  simulated modular/monolithic time ratio: {:.4} (boundary overhead ≈ {:.1}%)",
        sim_ratio.mean(), sim_overhead_pct
    );
    println!(
        "  real PJRT modular/monolithic time ratio: {:.4}",
        real_ratio.mean()
    );
    println!(
        "  paper context: measured 4% deviation attributed partly to this boundary"
    );
    ctx.write_csv("deviation.csv", &csv)?;
    Ok(())
}
