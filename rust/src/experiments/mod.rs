//! Experiment drivers — one per table/figure in the paper's evaluation
//! (DESIGN.md §4 maps each to its module). Every driver prints a console
//! table AND writes a CSV under the results dir, so the paper's plots can
//! be regenerated from the CSVs.

pub mod alpha;
pub mod deviation;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fleet;
pub mod kvcache;
pub mod overlap;
pub mod repartition;
pub mod scenarios;
pub mod serve_load;
pub mod tables;
pub mod tree;

use crate::config::RunConfig;
use crate::hetero::{LatencyModel, Platform};
use crate::runtime::Engine;
use crate::tokenizer::Tokenizer;
use std::path::PathBuf;

/// Shared context for experiment drivers.
pub struct Ctx {
    pub engine: Engine,
    pub tokenizer: Tokenizer,
    pub lat: LatencyModel,
    pub out_dir: PathBuf,
    /// Per-task / total sample limits (trim for quick runs).
    pub limit: Option<usize>,
    pub seed: u64,
    /// The run configuration the experiment was launched with (drivers
    /// that start coordinators — e.g. `fleet` — clone and adjust it).
    pub cfg: RunConfig,
}

impl Ctx {
    pub fn new(cfg: &RunConfig, platform: Platform, out_dir: PathBuf,
               limit: Option<usize>) -> anyhow::Result<Ctx> {
        let engine = Engine::load(&cfg.artifacts_dir)?;
        let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec)?;
        std::fs::create_dir_all(&out_dir)?;
        Ok(Ctx {
            engine,
            tokenizer,
            lat: LatencyModel::new(platform),
            out_dir,
            limit,
            seed: cfg.seed,
            cfg: cfg.clone(),
        })
    }

    pub fn write_csv(&self, name: &str, content: &str) -> anyhow::Result<PathBuf> {
        let path = self.out_dir.join(name);
        std::fs::write(&path, content)?;
        println!("  -> wrote {}", path.display());
        Ok(path)
    }
}

/// Run one experiment by id ("fig5a", "table2", ..., or "all").
pub fn run(ctx: &Ctx, which: &str) -> anyhow::Result<()> {
    match which {
        "fig5a" => fig5::run(ctx, true),
        "fig5b" => fig5::run(ctx, false),
        "fig6a" => fig6::run(ctx, false),
        "fig6b" => fig6::run(ctx, true),
        "table2" => tables::run(ctx, 0.90),
        "table3" => tables::run(ctx, 0.17),
        "fig7a" => fig7::run_predicted(ctx),
        "fig7b" => fig7::run_measured(ctx),
        "deviation" => deviation::run(ctx),
        "alpha" => alpha::run(ctx),
        "overlap" => overlap::run(ctx),
        "repartition" => repartition::run(ctx),
        "tree" => tree::run(ctx),
        "kvcache" => kvcache::run(ctx),
        "fleet" => fleet::run(ctx),
        "serve_load" => serve_load::run(ctx),
        "scenarios" => scenarios::run(ctx),
        "all" => {
            for id in [
                "table2", "table3", "fig6a", "fig6b", "fig7a", "fig5a", "fig5b",
                "fig7b", "deviation", "overlap", "repartition", "tree", "kvcache",
                "fleet", "serve_load", "scenarios",
            ] {
                println!("\n=== experiment {id} ===");
                run(ctx, id)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment {other:?} (fig5a fig5b fig6a fig6b table2 table3 \
             fig7a fig7b deviation alpha overlap repartition tree kvcache fleet \
             serve_load scenarios all)"
        ),
    }
}
