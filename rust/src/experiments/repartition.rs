//! Online re-partitioning under drift — the decision-layer experiment.
//!
//! The paper decides (mapping, γ, speculate?) **once**, offline, from
//! profiled (α, c). This driver measures what that costs when the
//! operating point drifts, by simulating the same workload under two
//! policies:
//!
//! * **frozen** — the admission-time decision (analytic model, prior
//!   α = 0.90) held for the whole run, exactly the paper's deployment;
//! * **online** — the decision engine's calibrated loop: per-round α
//!   feedback (EWMA) plus dispatch-duration observations refit the
//!   [`CalibratedModel`], and every K rounds the DSE candidate search
//!   re-evaluates (mapping, γ, speculate?) at the calibrated (α, c).
//!
//! Drift comes from two directions at once, mirroring reality on an edge
//! board: the **workload** α collapses mid-run (0.92 → 0.25 → 0.85, the
//! Table II ↔ Table III swing), and the **silicon** deviates from the
//! offline profile (GPU 22% slower, CPU dispatch boundary 50% higher —
//! thermals/DVFS). Every round is *charged* against the true platform, so
//! the comparison is honest: the online policy only wins by making better
//! decisions, not by being priced differently.
//!
//! Output: one CSV row per online round — true vs estimated α, the
//! analytic / calibrated / true cost coefficients (predicted-vs-calibrated
//! convergence), the current (γ, mapping) and the switch count — plus an
//! aggregate makespan comparison. The run fails loudly if the online
//! policy never switches or does not strictly beat the frozen one.

use crate::config::KernelPath;
use crate::costmodel;
use crate::decision::{CalibratedModel, CostModel, DispatchObs};
use crate::dse::{self, PairConfig};
use crate::hetero::{LatencyModel, Mapping};
use crate::models::{Scheme, VariantKey};

use super::Ctx;

/// Re-evaluate the candidate search every K simulated rounds.
const REEVAL_EVERY: usize = 8;
/// EWMA rate for the per-round α feedback.
const ALPHA_EWMA: f64 = 0.3;
/// Operating sequence length (the paper's S_L = 63 point).
const SEQ: usize = 63;

/// The drifting workload: acceptance by progress fraction through the
/// token budget — Table II conditions, a hard-task collapse, recovery.
fn true_alpha(progress: f64) -> f64 {
    if progress < 0.4 {
        0.92
    } else if progress < 0.7 {
        0.25
    } else {
        0.85
    }
}

/// True cost of one round of a (mapping, γ) choice: γ drafter forwards
/// plus the verify/baseline target forward, priced on the true platform.
fn round_cost(truth: &LatencyModel, pair: &PairConfig, mapping: Mapping, gamma: usize) -> f64 {
    let t_target =
        truth.forward_latency(&pair.target, pair.target_scheme, mapping.target, SEQ);
    if gamma == 0 {
        return t_target;
    }
    let t_draft =
        truth.forward_latency(&pair.drafter, pair.drafter_scheme, mapping.drafter, SEQ);
    gamma as f64 * t_draft + t_target
}

/// Expected tokens one round commits at the true α.
fn round_tokens(alpha: f64, gamma: usize) -> f64 {
    if gamma == 0 {
        1.0
    } else {
        costmodel::expected_tokens_per_round(alpha, gamma)
    }
}

/// Run one policy to the token budget. `reeval` is called before each
/// round with (round index, EWMA α estimate, progress) and may change the
/// (mapping, γ) choice; the frozen policy passes a no-op.
fn simulate(
    truth: &LatencyModel,
    pair: &PairConfig,
    budget: f64,
    mut choice: (Mapping, usize),
    mut reeval: impl FnMut(usize, f64, &(Mapping, usize)) -> Option<(Mapping, usize)>,
    mut per_round: impl FnMut(usize, f64, f64, &(Mapping, usize), f64),
) -> (f64, usize) {
    let mut tokens = 0.0;
    let mut elapsed = 0.0;
    let mut alpha_est = 0.90;
    let mut round = 0usize;
    while tokens < budget && round < 100_000 {
        let progress = tokens / budget;
        let a_true = true_alpha(progress);
        if let Some(next) = reeval(round, alpha_est, &choice) {
            choice = next;
        }
        elapsed += round_cost(truth, pair, choice.0, choice.1);
        tokens += round_tokens(a_true, choice.1);
        // Per-request α feedback, as `observe_alpha` would see it.
        alpha_est = (1.0 - ALPHA_EWMA) * alpha_est + ALPHA_EWMA * a_true;
        per_round(round, a_true, alpha_est, &choice, elapsed);
        round += 1;
    }
    (elapsed, round)
}

pub fn run(ctx: &Ctx) -> anyhow::Result<()> {
    let drafter = VariantKey::parse("drafter_fp").unwrap();
    let target = VariantKey::parse("target_w8a8").unwrap();
    let pair = PairConfig {
        target: ctx.engine.manifest.model_for(target)?.clone(),
        target_scheme: Scheme::W8a8,
        drafter: ctx.engine.manifest.model_for(drafter)?.clone(),
        drafter_scheme: Scheme::Fp,
    };

    // The true silicon has drifted from the offline profile.
    let mut p = ctx.lat.platform.clone();
    p.gpu.peak_gflops *= 0.78;
    p.cpu.dispatch_overhead_s *= 1.5;
    let truth = LatencyModel::new(p);

    let budget = ctx.limit.unwrap_or(600).max(60) as f64;
    let het = Mapping::heterogeneous(1);

    // Frozen-at-admission: the analytic decision at the prior α, held.
    let frozen = dse::explore_variant(&ctx.lat, &pair, 1, 0.90, SEQ).best;
    let frozen_choice = (frozen.mapping, frozen.gamma);
    let (frozen_time, frozen_rounds) = simulate(
        &truth,
        &pair,
        budget,
        frozen_choice,
        |_, _, _| None,
        |_, _, _, _, _| {},
    );

    // Online: calibrated model + periodic re-partitioning.
    let calib = CalibratedModel::new(ctx.lat.clone());
    let buckets: Vec<usize> = if ctx.engine.manifest.seq_buckets.is_empty() {
        vec![SEQ]
    } else {
        ctx.engine.manifest.seq_buckets.clone()
    };
    // Shared by the two simulate() closures (Cell: one mutates, one reads).
    let switches = std::cell::Cell::new(0usize);
    let mut csv = String::from(
        "round,alpha_true,alpha_est,c_analytic,c_calibrated,c_true,gamma,mapping,\
         heterogeneous,switches,elapsed_s\n",
    );
    let c_analytic = ctx
        .lat
        .cost_coefficient((&pair.drafter, pair.drafter_scheme),
                          (&pair.target, pair.target_scheme), het, SEQ);
    let c_true = truth
        .cost_coefficient((&pair.drafter, pair.drafter_scheme),
                          (&pair.target, pair.target_scheme), het, SEQ);
    let (online_time, online_rounds) = simulate(
        &truth,
        &pair,
        budget,
        frozen_choice, // same admission decision; divergence is earned online
        |round, alpha_est, cur| {
            if round == 0 || round % REEVAL_EVERY != 0 {
                return None;
            }
            let best = dse::explore_variant(&calib, &pair, 1, alpha_est, SEQ).best;
            let next = (best.mapping, best.gamma);
            if next != *cur {
                switches.set(switches.get() + 1);
                println!(
                    "  round {round}: re-partitioned {} gamma={} -> {} gamma={} \
                     (alpha_est = {alpha_est:.3})",
                    cur.0.label(), cur.1, next.0.label(), next.1
                );
                return Some(next);
            }
            None
        },
        |round, a_true, alpha_est, cur, elapsed| {
            // The executor's observation feed: this round's dispatches on
            // the true platform, cycled across the compiled buckets so the
            // estimator sees genuine x-spread.
            let bucket = buckets[round % buckets.len()];
            for (key, spec, scheme, pu) in [
                (drafter, &pair.drafter, pair.drafter_scheme, cur.0.drafter),
                (target, &pair.target, pair.target_scheme, cur.0.target),
            ] {
                calib.observe(&DispatchObs {
                    variant: key,
                    kernel: KernelPath::Ref,
                    bucket,
                    pu,
                    lanes: 1,
                    flops: spec.forward_flops(bucket),
                    duration_s: truth.forward_latency(spec, scheme, pu, bucket),
                });
            }
            let c_cal = calib.cost_coefficient(
                (&pair.drafter, pair.drafter_scheme),
                (&pair.target, pair.target_scheme), het, SEQ);
            csv.push_str(&format!(
                "{round},{a_true:.4},{alpha_est:.4},{c_analytic:.4},{c_cal:.4},\
                 {c_true:.4},{},{},{},{},{elapsed:.6}\n",
                cur.1,
                cur.0.label().replace(',', ";"),
                cur.0.is_heterogeneous() as u8,
                switches.get(),
            ));
        },
    );

    let c_cal_final = calib.cost_coefficient(
        (&pair.drafter, pair.drafter_scheme),
        (&pair.target, pair.target_scheme), het, SEQ);
    let n_switches = switches.get();
    println!(
        "Repartition — drifting α, perturbed silicon, token budget {budget}:\n\
         frozen-at-admission: {} gamma={} -> makespan {:.2} ms over {frozen_rounds} rounds\n\
         online (K={REEVAL_EVERY}):  {n_switches} switch(es) -> makespan {:.2} ms over \
         {online_rounds} rounds ({:.2}x)\n\
         cost coefficient at S_L={SEQ}: analytic {c_analytic:.3} | calibrated \
         {c_cal_final:.3} | true {c_true:.3}",
        frozen_choice.0.label(),
        frozen_choice.1,
        frozen_time * 1e3,
        online_time * 1e3,
        frozen_time / online_time.max(1e-12),
    );
    ctx.write_csv("repartition.csv", &csv)?;

    // The acceptance criteria, enforced at run time.
    anyhow::ensure!(
        n_switches >= 1,
        "online policy never switched mapping/γ under drift"
    );
    anyhow::ensure!(
        online_time < frozen_time,
        "online makespan {online_time} not strictly better than frozen {frozen_time}"
    );
    // And the calibrated c must sit nearer the truth than the stale
    // analytic prediction does.
    anyhow::ensure!(
        (c_cal_final - c_true).abs() < (c_analytic - c_true).abs(),
        "calibration did not move c toward the truth"
    );
    Ok(())
}
