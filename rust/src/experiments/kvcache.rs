//! Paged KV cache with cross-request prefix sharing (`experiment kvcache`).
//!
//! Two sweeps, one CSV (`kvcache.csv`, tagged by the `section` column):
//!
//! **serve** — a fleet of requests sharing one long system-prompt prefix
//! (the canonical edge-assistant shape) decodes twice: `kv_cache: off`
//! (every forward priced cold over the whole bucketed sequence, exactly
//! the historical engine) and `kv_cache: on` (a [`KvManager`] admits each
//! request, the prefix trie carries the shared prompt chunks, and every
//! dispatch after the first prices only its *new* tokens plus the DRAM
//! re-read of the resident KV). The driver fails loudly unless the token
//! streams are bit-identical, the cache-on run saves prefill tokens where
//! the cache-off run saves none, and cache-on ms/token is *strictly*
//! lower.
//!
//! **dse** — the memory-aware feasibility filter: the same mapping search
//! that produced the paper's Tables II/III re-runs under a [`KvLoad`]
//! (4 concurrent sessions × 128-token budgets) while the platform's page
//! pools sweep from starved to roomy, under the analytic *and* the
//! calibrated cost model. Starved pools must reject every mapping
//! ([`Infeasibility::KvMemory`] — speculation cannot rescue a working set
//! that does not fit); roomy pools must reject none and reproduce the
//! unfiltered winner.

use crate::config::{ExecMode, KernelPath};
use crate::decision::{CalibratedModel, CostModel};
use crate::dse::{self, Infeasibility, KvLoad, PairConfig};
use crate::hetero::{LatencyModel, Mapping};
use crate::kvcache::KvManager;
use crate::models::{Scheme, VariantKey};
use crate::spec::{AcceptRule, DecodeSession, DecoderSetup};

use super::Ctx;

/// Design variant for both sweeps (CPU cores for the target).
const VARIANT: usize = 1;
/// Concurrent sessions the DSE feasibility filter must sustain.
const DSE_INFLIGHT: usize = 4;
/// Per-session token budget (prompt + generation window) for the filter.
const DSE_BUDGET: usize = 128;

pub fn run(ctx: &Ctx) -> anyhow::Result<()> {
    let d_key = VariantKey::parse("drafter_fp").unwrap();
    let t_key = VariantKey::parse("target_w8a8").unwrap();
    let d_spec = ctx.engine.manifest.model_for(d_key)?.clone();
    let t_spec = ctx.engine.manifest.model_for(t_key)?.clone();
    let mapping = Mapping::heterogeneous(VARIANT);
    let mem = &ctx.lat.platform.memory;

    let mut csv = String::from(
        "section,model,kv_pages_cpu,kv_pages_gpu,kv_on,requests,tokens,\
         ms_per_tok,prefill_tokens_saved,prefix_hit_rate,kv_rejected\n",
    );

    // ---- serve: shared-system-prompt fleet, cache off vs on -----------
    let n = ctx.limit.unwrap_or(6).clamp(2, 8);
    let samples: Vec<_> = ctx
        .engine
        .manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .take(n)
        .cloned()
        .collect();
    anyhow::ensure!(samples.len() >= 2, "need >= 2 eval samples to share a prefix");

    // One system prompt every request carries, long enough to span
    // multiple trie chunks (chunk size is pair-derived; see KvLayout).
    let mgr_probe = KvManager::new(mem, (&d_spec, d_key.scheme), (&t_spec, t_key.scheme));
    let chunk = mgr_probe.layout().chunk_tokens;
    let mut sys = ctx.tokenizer.encode(&samples[0].prompt.repeat(8), true)?;
    sys.truncate((2 * chunk + chunk / 2).max(2 * chunk));
    anyhow::ensure!(sys.len() >= 2 * chunk, "system prompt spans < 2 chunks");

    let prompts: Vec<Vec<u32>> = samples
        .iter()
        .map(|s| -> anyhow::Result<Vec<u32>> {
            let mut p = sys.clone();
            p.extend(ctx.tokenizer.encode(&s.prompt, false)?);
            p.truncate(ctx.engine.manifest.largest_bucket() - 24);
            Ok(p)
        })
        .collect::<anyhow::Result<_>>()?;

    let setup = DecoderSetup {
        drafter: d_key,
        target: t_key,
        kernel: KernelPath::Pallas,
        mapping,
        gamma: 4,
        rule: AcceptRule::Greedy,
        exec: ExecMode::Modular,
        max_new: 16,
    };

    // Cache off: the historical engine, every request pays full prefill.
    let mut off_tokens: Vec<Vec<u32>> = Vec::new();
    let (mut off_sim, mut off_count) = (0.0f64, 0usize);
    for p in &prompts {
        let mut s = DecodeSession::new(&ctx.engine, ctx.lat.clone(), setup.clone(), true, p);
        while !s.is_done() {
            s.step(&ctx.engine)?;
        }
        let out = s.into_outcome();
        off_sim += out.sim_s;
        off_count += out.tokens.len();
        off_tokens.push(out.tokens);
    }

    // Cache on: one manager for the fleet; sessions run sequentially but
    // retire-released prefix chunks persist, so every request after the
    // first inherits the system prompt's prefill.
    let mut mgr = KvManager::new(mem, (&d_spec, d_key.scheme), (&t_spec, t_key.scheme));
    let mut on_tokens: Vec<Vec<u32>> = Vec::new();
    let (mut on_sim, mut on_count) = (0.0f64, 0usize);
    for p in &prompts {
        let kv = mgr
            .admit(p, mapping, p.len() + setup.max_new)
            .ok_or_else(|| anyhow::anyhow!("experiment pools sized to never shed"))?;
        let mut s = DecodeSession::new(&ctx.engine, ctx.lat.clone(), setup.clone(), true, p);
        s.set_kv_prefix(kv.shared_tokens());
        while !s.is_done() {
            s.step(&ctx.engine)?;
        }
        let out = s.into_outcome();
        on_sim += out.sim_s;
        on_count += out.tokens.len();
        on_tokens.push(out.tokens);
        mgr.release(kv, false);
    }

    let stats = mgr.stats();
    let hit_rate = stats.prefix_hit_tokens as f64 / stats.prefix_probe_tokens.max(1) as f64;
    let off_ms = off_sim * 1e3 / off_count.max(1) as f64;
    let on_ms = on_sim * 1e3 / on_count.max(1) as f64;
    println!(
        "KV cache serve sweep ({} requests, {}-token shared prefix, chunk {}):",
        prompts.len(),
        sys.len(),
        chunk
    );
    println!(
        "  off: {off_count} tokens  {off_ms:.3} ms/tok   (prefill saved: 0)\n  \
         on:  {on_count} tokens  {on_ms:.3} ms/tok   (prefill saved: {}, hit rate {:.3})",
        stats.prefill_tokens_saved, hit_rate
    );
    csv.push_str(&format!(
        "serve,-,{},{},0,{},{off_count},{off_ms:.4},0,0.000,0\n",
        mem.kv_pages_cpu,
        mem.kv_pages_gpu,
        prompts.len()
    ));
    csv.push_str(&format!(
        "serve,-,{},{},1,{},{on_count},{on_ms:.4},{},{hit_rate:.3},0\n",
        mem.kv_pages_cpu,
        mem.kv_pages_gpu,
        prompts.len(),
        stats.prefill_tokens_saved
    ));

    anyhow::ensure!(
        off_tokens == on_tokens,
        "kv_cache on changed the token streams — pricing must never touch decoding"
    );
    anyhow::ensure!(
        stats.prefill_tokens_saved > 0,
        "shared system prompt produced no prefill savings"
    );
    anyhow::ensure!(
        on_ms < off_ms,
        "cache-on ms/token ({on_ms:.4}) not strictly below cache-off ({off_ms:.4})"
    );
    anyhow::ensure!(stats.memory_shed == 0, "roomy pools shed an admission");

    // ---- dse: page capacity as a feasibility filter --------------------
    let pair = PairConfig {
        target: t_spec.clone(),
        target_scheme: Scheme::W8a8,
        drafter: d_spec.clone(),
        drafter_scheme: Scheme::Fp,
    };
    let kv = KvLoad { inflight: DSE_INFLIGHT, budget_tokens: DSE_BUDGET };
    let alpha = 0.8;
    // Pages each PU would need in the worst single-pool case: both roles'
    // working sets landing on one pool (the homogeneous mapping).
    let need_both = DSE_INFLIGHT
        * (crate::kvcache::pages_required(&d_spec, Scheme::Fp, mem, DSE_BUDGET)
            + crate::kvcache::pages_required(&t_spec, Scheme::W8a8, mem, DSE_BUDGET));
    println!(
        "KV-aware DSE sweep (inflight {DSE_INFLIGHT} x {DSE_BUDGET} tokens => \
         worst-case {need_both} pages on one pool):"
    );
    for pages in [2usize, need_both, 4 * need_both] {
        let mut p = ctx.lat.platform.clone();
        p.memory.kv_pages_cpu = pages;
        p.memory.kv_pages_gpu = pages;
        let lat = LatencyModel::new(p);
        let calibrated = CalibratedModel::new(lat.clone());
        let models: [(&str, &dyn CostModel); 2] =
            [("analytic", &lat), ("calibrated", &calibrated)];
        for (name, model) in models {
            let dec = dse::explore_variant_with_shapes_kv(
                model, &pair, VARIANT, alpha, 63, &[], Some(&kv),
            );
            let rejected = dec
                .all
                .iter()
                .filter(|c| c.infeasible == Some(Infeasibility::KvMemory))
                .count();
            let best_ms = if dec.best.gamma > 0 {
                let tt = model.forward_latency(
                    &pair.target,
                    pair.target_scheme,
                    dec.best.mapping.target,
                    63,
                );
                tt * 1e3 / dec.best.speedup.max(1e-12)
            } else {
                f64::NAN
            };
            println!(
                "  {name:<10} pages/pool={pages:<5} kv_rejected={rejected}  \
                 best: {} gamma={} S={:.3}",
                dec.best.mapping.label(),
                dec.best.gamma,
                dec.best.speedup
            );
            csv.push_str(&format!(
                "dse,{name},{pages},{pages},1,0,0,{best_ms:.4},0,0.000,{rejected}\n"
            ));
            if pages < need_both / DSE_INFLIGHT {
                // Starved: not even one session fits anywhere.
                anyhow::ensure!(
                    rejected >= 1,
                    "{name}: starved pools ({pages} pages) rejected no mapping"
                );
                anyhow::ensure!(
                    dec.best.gamma == 0 && dec.best.infeasible.is_some(),
                    "{name}: starved pools still produced a feasible mapping"
                );
            }
            if pages >= 4 * need_both {
                anyhow::ensure!(
                    rejected == 0,
                    "{name}: roomy pools ({pages} pages) still rejected {rejected} mappings"
                );
            }
        }
    }
    // The serving pools themselves must also pass the filter the search
    // applies — the deployment the serve sweep just ran is DSE-feasible.
    anyhow::ensure!(
        dse::kv_feasible(&ctx.lat.platform, &pair, mapping, &kv),
        "stock platform pools fail the DSE feasibility filter at the serve load"
    );

    ctx.write_csv("kvcache.csv", &csv)?;
    Ok(())
}
