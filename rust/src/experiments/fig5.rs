//! Fig. 5 — acceptance-rate α distributions per quantization scheme.
//! (a) translation task only; (b) the full 13-task suite.
//!
//! Paper's qualitative result: boxes shift *down* as quantization increases
//! (FP/FP highest, fully-quantized collapses). Our reproduction measures the
//! same ordering on the real numerics of the tiny pair (DESIGN.md §1 for the
//! qmax substitution).

use crate::config::KernelPath;
use crate::util::stats::{BoxStats, Summary};

use super::alpha::{measure_alpha, scheme_pairs};
use super::Ctx;

pub fn run(ctx: &Ctx, translate_only: bool) -> anyhow::Result<()> {
    let which = if translate_only { "fig5a" } else { "fig5b" };
    // Default sample budget: all 48 translate samples for (a); a slice per
    // task for (b) to keep runtime sane (override with --limit).
    let per_task_limit = ctx
        .limit
        .unwrap_or(if translate_only { 48 } else { 8 });

    let mut csv = String::from("scheme,task_set,alpha\n");
    let mut table: Vec<(String, BoxStats)> = Vec::new();
    for (name, drafter, target) in scheme_pairs() {
        let mut summary = Summary::new();
        let mut per_task_counts: std::collections::HashMap<&str, usize> =
            Default::default();
        for s in &ctx.engine.manifest.eval_samples.clone() {
            if translate_only && s.task != "translate" {
                continue;
            }
            let c = per_task_counts.entry(Box::leak(s.task.clone().into_boxed_str()) as &str)
                .or_insert(0);
            if *c >= per_task_limit {
                continue;
            }
            *c += 1;
            let a = measure_alpha(
                &ctx.engine, &ctx.tokenizer, drafter, target,
                KernelPath::Pallas, s, 48,
            )?;
            if a.is_finite() {
                summary.push(a);
                csv.push_str(&format!("{},{},{:.4}\n",
                    name, if translate_only { "translate" } else { "all" }, a));
            }
        }
        let stats = summary.box_stats();
        table.push((name.to_string(), stats));
    }

    println!(
        "Fig. 5{} — alpha distribution vs quantization ({}):",
        if translate_only { "a" } else { "b" },
        if translate_only { "translation task" } else { "full suite" }
    );
    println!("{:<10} {:>7} {:>7} {:>7} {:>7} {:>7} {:>5}",
             "scheme", "q1", "median", "q3", "p90", "mean", "n");
    for (name, b) in &table {
        println!(
            "{:<10} {:>7.3} {:>7.3} {:>7.3} {:>7.3} {:>7.3} {:>5}",
            name, b.q1, b.median, b.q3, b.p90, b.mean, b.n
        );
    }
    // The paper's ordering check: median must fall with quantization level.
    if table.len() == 3 && table[0].1.median < table[2].1.median {
        println!("WARNING: expected fp-fp median >= full-q median; check build");
    }
    ctx.write_csv(&format!("{which}.csv"), &csv)?;

    let mut summary_csv = String::from("scheme,");
    summary_csv.push_str(BoxStats::csv_header());
    summary_csv.push('\n');
    for (name, b) in &table {
        summary_csv.push_str(&format!("{},{}\n", name, b.to_csv()));
    }
    ctx.write_csv(&format!("{which}_summary.csv"), &summary_csv)?;
    Ok(())
}
