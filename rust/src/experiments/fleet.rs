//! Multi-device fleet and cloud-edge collaborative speculation
//! (`experiment fleet`). Four parts, one CSV (`fleet.csv`, tagged by the
//! `section` column):
//!
//! **scale** — the same closed-loop request batch decodes through a
//! [`FleetRouter`] of 1, 2 and 3 identical devices. Aggregate throughput
//! (total tokens / fleet makespan, where the fleet makespan is the
//! *maximum* per-device simulated makespan) must scale *strictly* as
//! devices are added, and with ≥ 2 devices the placement policy must use
//! every device.
//!
//! **route** — the local-verify vs cloud-verify decision across an α
//! sweep on two links whose parameters are *derived from the edge model
//! itself* so the assertions are platform-robust: a fast link
//! (RTT = edge verify latency / 50, 1 Gbit/s) must produce a **strict
//! cloud-verify win** at low α (and in fact at every swept α — the
//! pipelined round `max(draft, rtt + payload/bw + cloud_verify)` beats
//! `draft + edge_verify` whenever the whole remote leg undercuts the edge
//! verify forward), and a slow link (RTT = 200× the worst local per-token
//! latency) must produce a **strict local-verify win** at every α.
//!
//! **collab** — a real pipelined collaborative decode
//! ([`CloudTier::collaborative_replay`]): the session executes the true
//! draft/verify forwards while the collaborative clock re-prices rounds.
//! The committed tokens must be **bit-identical** to the plain local
//! decode of the same prompt (verification is the same computation, only
//! placed elsewhere), and on the fast link the collaborative clock must
//! strictly beat the local clock.
//!
//! **parity** — a fleet of exactly one device (no cloud tier) serves the
//! scale batch; its token streams must be bit-identical to a plain
//! [`Coordinator`] given the same requests, pinning that the routing tier
//! adds no behavior at N = 1.

use crate::api::GenerationRequest;
use crate::config::{CloudVerifyMode, ExecMode, KernelPath, RunConfig};
use crate::coordinator::Coordinator;
use crate::decision::CostModel;
use crate::dse::PairConfig;
use crate::fleet::{CloudTier, FleetRouter, FleetSpec, NetworkModel, VerifyRoute};
use crate::hetero::Mapping;
use crate::models::VariantKey;
use crate::spec::{AcceptRule, DecodeSession, DecoderSetup};
use crate::tokenizer::SEP_ID;

use super::Ctx;

/// Design variant (CPU cores for the target role).
const VARIANT: usize = 1;
/// Fleet sizes the scale sweep walks through.
const FLEET_SIZES: [usize; 3] = [1, 2, 3];
/// α points for the verify-routing sweep.
const ALPHAS: [f64; 5] = [0.05, 0.2, 0.5, 0.8, 0.95];
/// Operating sequence length for the routing sweep.
const SEQ: usize = 64;

/// Run the scale batch through a router of `n` devices; returns
/// (per-request token streams, total tokens, fleet makespan seconds,
/// requests placed per device).
fn run_fleet(
    cfg: &RunConfig,
    ctx: &Ctx,
    n: usize,
    prompts: &[Vec<u32>],
) -> anyhow::Result<(Vec<Vec<u32>>, usize, f64, Vec<u64>)> {
    let fleet = FleetRouter::start(cfg, FleetSpec::homogeneous(n, ctx.lat.platform.clone()))?;
    let mut handles = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let req = GenerationRequest::new(1 + i as u64, "translate", p.clone());
        handles.push(fleet.submit(req).handle);
    }
    let mut streams = Vec::new();
    let mut tokens = 0usize;
    for h in handles {
        let r = h.wait()?;
        anyhow::ensure!(
            !r.tokens.is_empty(),
            "fleet({n}) request {} produced no tokens (finish {:?})",
            r.id,
            r.finish
        );
        tokens += r.tokens.len();
        streams.push(r.tokens);
    }
    // Fleet makespan: the slowest device's simulated timeline.
    let makespan = fleet
        .devices()
        .iter()
        .map(|d| d.coordinator.metrics.snapshot().makespan_s)
        .fold(0.0f64, f64::max);
    let placements = fleet.metrics().snapshot().placements;
    fleet.shutdown();
    Ok((streams, tokens, makespan, placements))
}

pub fn run(ctx: &Ctx) -> anyhow::Result<()> {
    let d_key = VariantKey::parse("drafter_fp").unwrap();
    let t_key = VariantKey::parse("target_w8a8").unwrap();
    let pair = PairConfig {
        target: ctx.engine.manifest.model_for(t_key)?.clone(),
        target_scheme: t_key.scheme,
        drafter: ctx.engine.manifest.model_for(d_key)?.clone(),
        drafter_scheme: d_key.scheme,
    };
    let mapping = Mapping::heterogeneous(VARIANT);
    let edge: &dyn CostModel = &ctx.lat;
    let drafter = (&pair.drafter, pair.drafter_scheme);
    let target = (&pair.target, pair.target_scheme);

    let mut csv = String::from(
        "section,devices,alpha,rtt_ms,mbps,requests,tokens,makespan_s,tok_per_s,\
         route,local_ms_per_tok,cloud_ms_per_tok,net_ms\n",
    );

    // ---- scale: aggregate throughput vs device count -------------------
    // Divisible by every swept fleet size, so a balanced placement makes
    // the max per-device load — and with it the fleet makespan — strictly
    // drop at each size step. (`--limit` doesn't shrink this: 6 short
    // requests already are the smoke-scale batch.)
    let k: usize = 6;
    let samples: Vec<_> = ctx
        .engine
        .manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .cloned()
        .collect();
    anyhow::ensure!(!samples.is_empty(), "no translate eval samples in the manifest");
    let prompts: Vec<Vec<u32>> = (0..k)
        .map(|i| -> anyhow::Result<Vec<u32>> {
            let mut p = ctx.tokenizer.encode(&samples[i % samples.len()].prompt, true)?;
            p.push(SEP_ID);
            Ok(p)
        })
        .collect::<anyhow::Result<_>>()?;

    let mut cfg = ctx.cfg.clone();
    cfg.workers = 1;
    cfg.max_inflight = 2;
    cfg.max_new_tokens = 16;
    cfg.fleet_file = None;

    println!("Fleet scaling ({k} requests, devices {FLEET_SIZES:?}):");
    let mut throughputs: Vec<f64> = Vec::new();
    let mut one_device_streams: Vec<Vec<u32>> = Vec::new();
    for &n in &FLEET_SIZES {
        let (streams, tokens, makespan, placements) = run_fleet(&cfg, ctx, n, &prompts)?;
        anyhow::ensure!(makespan > 0.0, "fleet({n}): zero makespan");
        let tps = tokens as f64 / makespan;
        println!(
            "  {n} device(s): {tokens} tokens  makespan {:.1} ms  {tps:.1} tok/s  \
             placements {placements:?}",
            makespan * 1e3
        );
        csv.push_str(&format!(
            "scale,{n},,,,{k},{tokens},{makespan:.6},{tps:.2},,,,\n"
        ));
        if n >= 2 {
            anyhow::ensure!(
                placements.iter().all(|&p| p > 0),
                "fleet({n}): placement starved a device ({placements:?})"
            );
        }
        anyhow::ensure!(
            placements.iter().map(|&p| p as usize).sum::<usize>() == k,
            "fleet({n}): placements {placements:?} do not sum to {k}"
        );
        throughputs.push(tps);
        if n == 1 {
            one_device_streams = streams;
        }
    }
    for w in throughputs.windows(2) {
        anyhow::ensure!(
            w[1] > w[0],
            "aggregate throughput did not scale strictly: {throughputs:?}"
        );
    }

    // ---- parity: fleet-of-1 is bit-identical to the plain coordinator --
    let plain = Coordinator::start(cfg.clone(), ctx.lat.platform.clone())?;
    let mut handles = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        handles.push(plain.submit(GenerationRequest::new(1 + i as u64, "translate", p.clone())));
    }
    let mut plain_streams = Vec::new();
    let mut plain_tokens = 0usize;
    for h in handles {
        let r = h.wait()?;
        plain_tokens += r.tokens.len();
        plain_streams.push(r.tokens);
    }
    plain.shutdown();
    anyhow::ensure!(
        plain_streams == one_device_streams,
        "fleet-of-1 token streams differ from the plain coordinator"
    );
    println!("  parity: fleet-of-1 == plain coordinator ({plain_tokens} tokens) OK");
    csv.push_str(&format!("parity,1,,,,{k},{plain_tokens},,,,,,\n"));

    // ---- route: local vs cloud verify across alpha x link --------------
    // Link parameters derived from the edge model so the regime
    // assertions hold on any calibration (see module docs).
    let edge_verify_s = edge.forward_latency(&pair.target, pair.target_scheme, mapping.target, SEQ);
    let c = edge.cost_coefficient(drafter, target, mapping, SEQ);
    anyhow::ensure!(
        c < 1.0,
        "drafter is not cheaper than the target (c = {c:.3}); link derivation invalid"
    );
    let fast = NetworkModel::from_cfg(edge_verify_s * 1e3 / 50.0, 1000.0);
    let fast_tier = CloudTier::new(crate::hetero::Platform::cloud(), fast, CloudVerifyMode::Auto);
    // Precondition for the cloud-win argument: the whole remote leg
    // undercuts one edge verify forward.
    let remote_leg = fast_tier.remote_round_s(&pair, crate::costmodel::GAMMA_MAX, SEQ);
    anyhow::ensure!(
        remote_leg < edge_verify_s,
        "fast-link remote leg ({remote_leg:.6}s) not below edge verify ({edge_verify_s:.6}s)"
    );
    // Worst local per-token latency over the sweep sizes the slow link.
    let worst_local = ALPHAS
        .iter()
        .map(|&a| {
            fast_tier
                .verify_route(edge, &pair, mapping, a, SEQ)
                .local_per_token_s
        })
        .fold(0.0f64, f64::max);
    let slow = NetworkModel::from_cfg(worst_local * 200.0 * 1e3, 1.0);
    let slow_tier = CloudTier::new(crate::hetero::Platform::cloud(), slow, CloudVerifyMode::Auto);

    println!(
        "Verify routing (edge verify {:.2} ms, fast RTT {:.3} ms, slow RTT {:.1} ms):",
        edge_verify_s * 1e3,
        fast.rtt_s * 1e3,
        slow.rtt_s * 1e3
    );
    for (link_name, tier) in [("fast", &fast_tier), ("slow", &slow_tier)] {
        for &alpha in &ALPHAS {
            let r = tier.verify_route(edge, &pair, mapping, alpha, SEQ);
            let route = match r.route {
                VerifyRoute::Cloud => "cloud",
                VerifyRoute::Local => "local",
            };
            println!(
                "  {link_name} link  alpha={alpha:.2}  -> {route:<5} \
                 (local {:.2} ms/tok, cloud {:.2} ms/tok)",
                r.local_per_token_s * 1e3,
                r.cloud.per_token_s * 1e3
            );
            csv.push_str(&format!(
                "route,,{alpha},{:.4},{:.1},,,,,{route},{:.4},{:.4},\n",
                tier.net.rtt_s * 1e3,
                tier.net.bytes_per_s * 8.0 / 1e6,
                r.local_per_token_s * 1e3,
                r.cloud.per_token_s * 1e3
            ));
            match link_name {
                "fast" => anyhow::ensure!(
                    r.route == VerifyRoute::Cloud && r.cloud.per_token_s < r.local_per_token_s,
                    "fast link at alpha {alpha}: cloud-verify did not strictly win"
                ),
                _ => anyhow::ensure!(
                    r.route == VerifyRoute::Local && r.local_per_token_s < r.cloud.per_token_s,
                    "slow link at alpha {alpha}: local-verify did not strictly win"
                ),
            }
        }
    }

    // ---- collab: real pipelined collaborative decode -------------------
    let setup = DecoderSetup {
        drafter: d_key,
        target: t_key,
        kernel: KernelPath::Pallas,
        mapping,
        gamma: 4,
        rule: AcceptRule::Greedy,
        exec: ExecMode::Modular,
        max_new: 16,
    };
    let n_collab = prompts.len().min(3);
    let (mut collab_s, mut local_s, mut net_s, mut collab_tokens) = (0.0f64, 0.0f64, 0.0f64, 0usize);
    for p in prompts.iter().take(n_collab) {
        let collab = fast_tier.collaborative_replay(&ctx.engine, &ctx.lat, &pair, setup.clone(), p)?;
        // The plain local decode of the same prompt.
        let mut s = DecodeSession::new(&ctx.engine, ctx.lat.clone(), setup.clone(), true, p);
        while !s.is_done() {
            s.step(&ctx.engine)?;
        }
        let local = s.into_outcome();
        anyhow::ensure!(
            collab.tokens == local.tokens,
            "collaborative decode changed the token stream"
        );
        anyhow::ensure!(
            (collab.local_sim_s - local.sim_s).abs() < 1e-9,
            "replay local clock ({:.6}) != session clock ({:.6})",
            collab.local_sim_s,
            local.sim_s
        );
        anyhow::ensure!(
            collab.collab_sim_s < collab.local_sim_s,
            "fast-link collaborative clock ({:.4}s) not strictly below local ({:.4}s)",
            collab.collab_sim_s,
            collab.local_sim_s
        );
        collab_s += collab.collab_sim_s;
        local_s += collab.local_sim_s;
        net_s += collab.net_s;
        collab_tokens += collab.tokens.len();
    }
    println!(
        "Collaborative replay ({n_collab} prompts): local {:.1} ms, pipelined cloud {:.1} ms, \
         link {:.1} ms serial — bit-identical streams",
        local_s * 1e3,
        collab_s * 1e3,
        net_s * 1e3
    );
    csv.push_str(&format!(
        "collab,,,{:.4},{:.1},{n_collab},{collab_tokens},,,cloud,{:.4},{:.4},{:.2}\n",
        fast.rtt_s * 1e3,
        fast.bytes_per_s * 8.0 / 1e6,
        local_s * 1e3 / collab_tokens.max(1) as f64,
        collab_s * 1e3 / collab_tokens.max(1) as f64,
        net_s * 1e3
    ));

    ctx.write_csv("fleet.csv", &csv)?;
    Ok(())
}
