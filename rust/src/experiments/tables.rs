//! Tables II & III — estimated speedup per design variant at S_L = 63,
//! for α = 0.90 (Table II) and α = 0.17 (Table III).
//!
//! Each row is the cost-model-guided decision for one design variant:
//! whether to speculate, at which γ, with which mapping, and the predicted
//! speedup. Paper reference rows (Table II): v1 → hetero γ=5 1.68×;
//! v2 → hetero γ=2 1.10×; v5 → homo γ=1 1.02×; v3/v4/v6 → no speculation.

use crate::dse::{self, PairConfig};
use crate::models::{Scheme, VariantKey};

use super::Ctx;

pub fn run(ctx: &Ctx, alpha: f64) -> anyhow::Result<()> {
    let which = if (alpha - 0.90).abs() < 0.1 { "table2" } else { "table3" };
    let drafter = VariantKey::parse("drafter_fp").unwrap();
    let target = VariantKey::parse("target_w8a8").unwrap();
    let pair = PairConfig {
        target: ctx.engine.manifest.model_for(target)?.clone(),
        target_scheme: Scheme::W8a8,
        drafter: ctx.engine.manifest.model_for(drafter)?.clone(),
        drafter_scheme: Scheme::Fp,
    };
    let decisions = dse::explore_all(&ctx.lat, &pair, alpha, 63);

    println!(
        "Table {} — estimated speedup, alpha = {alpha}, S_L = 63 \
         (design space: v·N^m = {}·2^2 = {}):",
        if which == "table2" { "II" } else { "III" },
        ctx.lat.platform.design_variants(),
        dse::design_space_size(ctx.lat.platform.design_variants(), 2, 2),
    );
    println!(
        "{:<8} {:<22} {:<14} {:>8} {:>9}",
        "Variant", "Speculative Sampling", "Heterogeneous", "c", "Speedup"
    );
    let mut csv = String::from("variant,speculative,gamma,heterogeneous,c,speedup\n");
    for d in &decisions {
        let b = &d.best;
        let spec_col = if b.gamma > 0 {
            format!("Yes (gamma = {})", b.gamma)
        } else {
            "No".to_string()
        };
        let het_col = if b.gamma > 0 {
            if b.mapping.is_heterogeneous() { "Yes" } else { "No" }
        } else {
            "NA"
        };
        println!(
            "{:<8} {:<22} {:<14} {:>8} {:>9.2}",
            b.variant,
            spec_col,
            het_col,
            if b.c.is_nan() { "-".to_string() } else { format!("{:.3}", b.c) },
            b.speedup
        );
        csv.push_str(&format!(
            "{},{},{},{},{:.4},{:.4}\n",
            b.variant,
            (b.gamma > 0) as u8,
            b.gamma,
            b.mapping.is_heterogeneous() as u8,
            if b.c.is_nan() { -1.0 } else { b.c },
            b.speedup
        ));
    }
    ctx.write_csv(&format!("{which}.csv"), &csv)?;

    // Full per-mapping detail (all 4 assignments × variants) for the record.
    let mut detail = String::from(
        "variant,mapping,heterogeneous,c,gamma,speedup,infeasible\n");
    for d in &decisions {
        for c in &d.all {
            detail.push_str(&format!(
                "{},{},{},{},{},{:.4},{}\n",
                c.variant,
                c.mapping.label().replace(',', ";"),
                c.mapping.is_heterogeneous() as u8,
                if c.c.is_nan() { -1.0 } else { c.c },
                c.gamma,
                c.speedup,
                c.infeasible.map(|i| format!("{i:?}")).unwrap_or_default()
            ));
        }
    }
    ctx.write_csv(&format!("{which}_detail.csv"), &detail)?;
    Ok(())
}
