//! α measurement machinery (paper §III-C / §IV-A) shared by fig5 and fig7.
//!
//! α is measured exactly as Eq. (1) consumes it: the probability that the
//! target accepts a drafter proposal. We walk the *target's* greedy path
//! (teacher-forced, like the paper's server-side estimation on a 16-core
//! Xeon — hardware-independence is the point of §III-C) and count
//! drafter/target argmax agreement per step.

use crate::config::KernelPath;
use crate::models::VariantKey;
use crate::runtime::manifest::EvalSample;
use crate::runtime::Engine;
use crate::tokenizer::{Tokenizer, EOS_ID};
use crate::workload::prompt_ids;

use super::Ctx;

/// The three quantization pairings of the paper's Fig. 5 (left→right boxes).
pub fn scheme_pairs() -> Vec<(&'static str, VariantKey, VariantKey)> {
    vec![
        ("fp-fp", VariantKey::parse("drafter_fp").unwrap(),
         VariantKey::parse("target_fp").unwrap()),
        ("semi(Tq)", VariantKey::parse("drafter_fp").unwrap(),
         VariantKey::parse("target_w8a8").unwrap()),
        ("full-q", VariantKey::parse("drafter_w8a8").unwrap(),
         VariantKey::parse("target_w8a8").unwrap()),
    ]
}

/// Teacher-forced per-sample acceptance rate.
pub fn measure_alpha(
    engine: &Engine,
    tokenizer: &Tokenizer,
    drafter: VariantKey,
    target: VariantKey,
    kernel: KernelPath,
    sample: &EvalSample,
    max_new: usize,
) -> anyhow::Result<f64> {
    let mut ids = prompt_ids(tokenizer, sample)?;
    let max_total = engine.manifest.largest_bucket();
    let mut agree = 0usize;
    let mut total = 0usize;
    for _ in 0..max_new {
        if ids.len() + 1 >= max_total {
            break;
        }
        let bucket = engine.bucket_for(ids.len())?;
        let pos = ids.len() - 1;
        let t_fwd = engine.forward(target, kernel, &ids, bucket)?;
        let nt = t_fwd.argmax(0, pos);
        let d_fwd = engine.forward(drafter, kernel, &ids, bucket)?;
        let nd = d_fwd.argmax(0, pos);
        agree += (nt == nd) as usize;
        total += 1;
        if nt == EOS_ID {
            break;
        }
        ids.push(nt);
    }
    if total == 0 {
        return Ok(f64::NAN);
    }
    Ok(agree as f64 / total as f64)
}

/// Standalone `specedge alpha` command: print per-task α summary for the
/// semi-quantized pair (quick operational check).
pub fn run(ctx: &Ctx) -> anyhow::Result<()> {
    let (name, drafter, target) = scheme_pairs().remove(1);
    let limit = ctx.limit.unwrap_or(4);
    let mut by_task: std::collections::BTreeMap<String, crate::util::stats::Summary> =
        Default::default();
    for s in &ctx.engine.manifest.eval_samples.clone() {
        let t = by_task.entry(s.task.clone()).or_default();
        if t.len() >= limit {
            continue;
        }
        let a = measure_alpha(
            &ctx.engine, &ctx.tokenizer, drafter, target,
            crate::config::KernelPath::Pallas, s, 48,
        )?;
        if a.is_finite() {
            t.push(a);
        }
    }
    println!("alpha ({name}), {limit} samples/task:");
    println!("{:<16} {:>8} {:>8} {:>8}", "task", "median", "mean", "n");
    for (task, mut s) in by_task {
        println!(
            "{:<16} {:>8.3} {:>8.3} {:>8}",
            task, s.median(), s.mean(), s.len()
        );
    }
    Ok(())
}
