//! Chain vs tree speculation — the tree-aware DSE sweep (`experiment tree`).
//!
//! Speculating a token *tree* (top-k children per node, all k^d
//! root-to-leaf paths verified as the lanes of one batched target
//! dispatch) trades lane-linear compute for per-level acceptance
//! β = 1 − (1−α)^k. On a compute-dominated platform the extra lanes cost
//! exactly what they would save, so the chain always wins; when the
//! per-dispatch boundary dominates the forward time, wide shallow trees
//! amortize it across lanes and win precisely in the low-α regime where
//! the chain collapses to γ* = 1 or gives up speculating altogether.
//!
//! The driver sweeps α on two platforms — the stock calibration and a
//! boundary-dominated variant of it (NPU-class arithmetic throughput, so
//! a forward is dispatch overhead, not FLOPs) — comparing the chain-only
//! DSE against the tree-aware search at every point. It then replays a
//! few greedy decodes end-to-end to pin the executor: greedy tree
//! decoding must reproduce the chain's token stream exactly (both follow
//! the target argmax), while reporting tree rounds and lane fill.
//!
//! Fails loudly unless (a) the tree-aware DSE strictly beats the chain's
//! per-token latency at low α on the boundary-bound platform, (b) it
//! keeps the chain at every α on the compute-bound stock platform (lane
//! cost dominates there), and (c) it returns to the chain at high α even
//! where trees win at low α.

use crate::config::{ExecMode, KernelPath};
use crate::costmodel::TreeShape;
use crate::dse::{self, Candidate, PairConfig, TREE_SHAPES};
use crate::hetero::{LatencyModel, Mapping, Platform};
use crate::models::{Scheme, VariantKey};
use crate::spec::{AcceptRule, DecodeSession, DecoderSetup};
use crate::workload::prompt_ids;

use super::Ctx;

/// Operating sequence length (the paper's S_L = 63 point).
const SEQ: usize = 63;
/// Design variant scored by the sweep (CPU cores for the target).
const VARIANT: usize = 1;

/// The boundary-dominated platform: same board, NPU-class arithmetic
/// throughput. Compute shrinks 200×, so a forward is almost entirely the
/// per-dispatch boundary — the regime where lanes are nearly free and the
/// per-level acceptance boost β = 1 − (1−α)^k is worth buying.
fn boundary_bound(stock: &Platform) -> Platform {
    let mut p = stock.clone();
    p.name = "imx95-npu-sim".to_string();
    p.cpu.peak_gflops_per_core *= 200.0;
    p.cpu.dispatch_overhead_s = 2e-3;
    p.gpu.peak_gflops *= 200.0;
    p.gpu.dispatch_overhead_s = 100e-6;
    p
}

/// Per-committed-token latency of a DSE winner: the baseline forward at
/// the candidate's own mapping divided by its predicted speedup (chain
/// and tree speedups are both normalized against that same baseline).
fn ms_per_tok(lat: &LatencyModel, pair: &PairConfig, cand: &Candidate) -> f64 {
    let tt = lat.forward_latency(&pair.target, pair.target_scheme, cand.mapping.target, SEQ);
    tt * 1e3 / cand.speedup.max(1e-12)
}

pub fn run(ctx: &Ctx) -> anyhow::Result<()> {
    let d_key = VariantKey::parse("drafter_fp").unwrap();
    let t_key = VariantKey::parse("target_w8a8").unwrap();
    let pair = PairConfig {
        target: ctx.engine.manifest.model_for(t_key)?.clone(),
        target_scheme: Scheme::W8a8,
        drafter: ctx.engine.manifest.model_for(d_key)?.clone(),
        drafter_scheme: Scheme::Fp,
    };

    // ---- analytic α sweep: chain-only vs tree-aware DSE ---------------
    let alphas = [0.05, 0.10, 0.15, 0.20, 0.30, 0.40, 0.50, 0.60, 0.70, 0.80, 0.90, 0.95];
    let stock_name = ctx.lat.platform.name.clone();
    let platforms = [ctx.lat.platform.clone(), boundary_bound(&ctx.lat.platform)];

    let mut csv = String::from(
        "platform,alpha,chain_gamma,chain_speedup,chain_ms_per_tok,\
         tree,tree_gamma,tree_speedup,tree_ms_per_tok,tree_wins\n",
    );
    let mut boundary_low_alpha_win = false;
    let mut boundary_high_alpha_chain = false;
    println!(
        "Tree speculation vs chain — tree-aware DSE (variant {VARIANT}, S_L = {SEQ}, \
         shapes {:?}):",
        TREE_SHAPES.iter().map(TreeShape::label).collect::<Vec<_>>()
    );
    for p in &platforms {
        let lat = LatencyModel::new(p.clone());
        let on_stock = p.name == stock_name;
        for &alpha in &alphas {
            let chain = dse::explore_variant(&lat, &pair, VARIANT, alpha, SEQ).best;
            let tree =
                dse::explore_variant_with_shapes(&lat, &pair, VARIANT, alpha, SEQ, &TREE_SHAPES)
                    .best;
            let chain_ms = ms_per_tok(&lat, &pair, &chain);
            let tree_ms = ms_per_tok(&lat, &pair, &tree);
            let wins = tree.tree.is_some() && tree_ms < chain_ms;
            let label = tree.tree.map_or_else(|| "chain".to_string(), |s| s.label());
            println!(
                "  {:<14} alpha={alpha:.2}  chain gamma={} S={:.3} {:.3}ms/tok | \
                 tree {label} S={:.3} {:.3}ms/tok{}",
                p.name, chain.gamma, chain.speedup, chain_ms, tree.speedup, tree_ms,
                if wins { "  <- tree wins" } else { "" }
            );
            csv.push_str(&format!(
                "{},{alpha:.2},{},{:.4},{chain_ms:.4},{label},{},{:.4},{tree_ms:.4},{}\n",
                p.name, chain.gamma, chain.speedup, tree.gamma, tree.speedup, wins as u8
            ));
            if on_stock {
                // Compute-dominated: lane cost eats the β gain exactly, so
                // the tree-aware search must come back bit-identical.
                anyhow::ensure!(
                    tree.tree.is_none() && tree.speedup.to_bits() == chain.speedup.to_bits(),
                    "tree-aware DSE left the chain on the compute-bound platform \
                     (alpha {alpha}: {label} S={:.3} vs chain S={:.3})",
                    tree.speedup, chain.speedup
                );
            } else {
                if alpha <= 0.20 && wins {
                    boundary_low_alpha_win = true;
                }
                if alpha >= 0.90 && tree.tree.is_none() {
                    boundary_high_alpha_chain = true;
                }
            }
        }
    }
    ctx.write_csv("tree.csv", &csv)?;
    anyhow::ensure!(
        boundary_low_alpha_win,
        "no strict tree per-token-latency win at low alpha on the boundary-bound platform"
    );
    anyhow::ensure!(
        boundary_high_alpha_chain,
        "tree-aware DSE failed to return to the chain at high alpha"
    );

    // ---- end-to-end: greedy tree decode ≡ greedy chain decode ---------
    // Both follow the target argmax token-for-token; the tree only changes
    // how many candidates each round shows the target, never what greedy
    // acceptance commits. Same γ = tree depth, so the per-round lookahead
    // (and bucket-edge termination) matches too.
    let shape = TreeShape::new(2, 2);
    let n = ctx.limit.unwrap_or(4).clamp(1, 8);
    let samples: Vec<_> = ctx
        .engine
        .manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .take(n)
        .cloned()
        .collect();
    let setup = DecoderSetup {
        drafter: d_key,
        target: t_key,
        kernel: KernelPath::Pallas,
        mapping: Mapping::heterogeneous(VARIANT),
        gamma: shape.depth,
        rule: AcceptRule::Greedy,
        exec: ExecMode::Modular,
        max_new: 32,
    };
    let (mut same, mut tree_rounds, mut lanes_real, mut lanes_executed) = (0usize, 0, 0, 0);
    for s in &samples {
        let prompt = prompt_ids(&ctx.tokenizer, s)?;
        let mut chain =
            DecodeSession::new(&ctx.engine, ctx.lat.clone(), setup.clone(), true, &prompt);
        while !chain.is_done() {
            chain.step(&ctx.engine)?;
        }
        let chain_out = chain.into_outcome();
        let mut tree =
            DecodeSession::new(&ctx.engine, ctx.lat.clone(), setup.clone(), true, &prompt);
        tree.set_tree(Some(shape));
        while !tree.is_done() {
            tree.step(&ctx.engine)?;
        }
        let tree_out = tree.into_outcome();
        same += (chain_out.tokens == tree_out.tokens) as usize;
        tree_rounds += tree_out.tree_rounds;
        lanes_real += tree_out.tree_lanes_real;
        lanes_executed += tree_out.tree_lanes_executed;
    }
    println!(
        "  e2e greedy {} ({} samples): identical token streams {same}/{}, \
         tree rounds {tree_rounds}, lane fill {:.2}",
        shape.label(),
        samples.len(),
        samples.len(),
        lanes_real as f64 / lanes_executed.max(1) as f64
    );
    anyhow::ensure!(
        same == samples.len(),
        "greedy tree decode diverged from the chain on {}/{} samples",
        samples.len() - same,
        samples.len()
    );
    anyhow::ensure!(tree_rounds > 0, "tree sessions never ran a tree round");
    Ok(())
}
