//! PJRT runtime: loads the AOT artifacts (HLO text) and executes them on the
//! request path. Python is never involved here.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` into typed structs
//! * [`weights`] — reads the SEWB binary weight files, uploads them once as
//!   device-resident `PjRtBuffer`s
//! * [`engine`] — executable cache per (variant, kernel, batch, bucket) and
//!   the `tokens → logits` / fused-spec-step execution entry points

pub mod engine;
pub mod manifest;
pub mod weights;

pub use engine::{Engine, ForwardOut, MonoStepOut};
pub use manifest::{ArtifactEntry, Manifest, MonoEntry, VariantEntry};
