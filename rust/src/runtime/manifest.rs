//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use crate::config::KernelPath;
use crate::models::{ModelSpec, VariantKey};
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One compiled forward-pass artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub kernel: KernelPath,
    pub batch: usize,
    pub seq: usize,
}

/// One weight tensor in a SEWB file (order matters: it is the parameter
/// order of the compiled executables).
#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub name: String,
    pub dtype: String, // "f32" | "i8" | "i32"
    pub shape: Vec<usize>,
}

/// One model variant (role × scheme): weights + its artifacts.
#[derive(Debug, Clone)]
pub struct VariantEntry {
    pub key: VariantKey,
    pub weights_file: String,
    pub tensors: Vec<TensorEntry>,
    pub artifacts: Vec<ArtifactEntry>,
}

impl VariantEntry {
    /// Find the artifact for (kernel, batch, bucket).
    pub fn artifact(&self, kernel: KernelPath, batch: usize, seq: usize)
        -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.kernel == kernel && a.batch == batch && a.seq == seq)
    }
}

/// One fused monolithic spec-step artifact.
#[derive(Debug, Clone)]
pub struct MonoEntry {
    pub file: String,
    pub gamma: usize,
    pub seq: usize,
    pub drafter: VariantKey,
    pub target: VariantKey,
}

/// One evaluation sample (the fixed 480-sample Spec-Bench-shaped set).
#[derive(Debug, Clone)]
pub struct EvalSample {
    pub task: String,
    pub prompt: String,
    pub completion: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub tokenizer_spec: Json,
    pub seq_buckets: Vec<usize>,
    pub batch_sizes: Vec<usize>,
    pub models: HashMap<String, ModelSpec>,
    pub variants: HashMap<VariantKey, VariantEntry>,
    pub monolithic: Vec<MonoEntry>,
    pub eval_samples: Vec<EvalSample>,
    pub qmax: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {path:?}: {e}\n(hint: run `make artifacts` first)"
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        Manifest::from_json(dir, &j)
    }

    pub fn from_json(dir: &Path, j: &Json) -> anyhow::Result<Manifest> {
        let seq_buckets: Vec<usize> = j
            .req_arr("seq_buckets")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let batch_sizes: Vec<usize> = j
            .req_arr("batch_sizes")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        anyhow::ensure!(!seq_buckets.is_empty(), "manifest has no seq buckets");

        let mut models = HashMap::new();
        for (name, mj) in j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing models"))?
        {
            models.insert(name.clone(), ModelSpec::from_json(mj)?);
        }

        let mut variants = HashMap::new();
        for (name, vj) in j
            .get("variants")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing variants"))?
        {
            let key = VariantKey::parse(name)?;
            let tensors = vj
                .req_arr("tensors")?
                .iter()
                .map(|t| -> anyhow::Result<TensorEntry> {
                    Ok(TensorEntry {
                        name: t.req_str("name")?.to_string(),
                        dtype: t.req_str("dtype")?.to_string(),
                        shape: t
                            .req_arr("shape")?
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect(),
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let artifacts = vj
                .req_arr("artifacts")?
                .iter()
                .map(|a| -> anyhow::Result<ArtifactEntry> {
                    Ok(ArtifactEntry {
                        file: a.req_str("file")?.to_string(),
                        kernel: KernelPath::parse(a.req_str("kernel")?)?,
                        batch: a.req_usize("batch")?,
                        seq: a.req_usize("seq")?,
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            variants.insert(
                key,
                VariantEntry {
                    key,
                    weights_file: vj.req_str("weights")?.to_string(),
                    tensors,
                    artifacts,
                },
            );
        }

        let monolithic = j
            .req_arr("monolithic")?
            .iter()
            .map(|m| -> anyhow::Result<MonoEntry> {
                Ok(MonoEntry {
                    file: m.req_str("file")?.to_string(),
                    gamma: m.req_usize("gamma")?,
                    seq: m.req_usize("seq")?,
                    drafter: VariantKey::parse(m.req_str("drafter")?)?,
                    target: VariantKey::parse(m.req_str("target")?)?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;

        let eval_samples = j
            .req_arr("eval_samples")?
            .iter()
            .map(|s| -> anyhow::Result<EvalSample> {
                Ok(EvalSample {
                    task: s.req_str("task")?.to_string(),
                    prompt: s.req_str("prompt")?.to_string(),
                    completion: s.req_str("completion")?.to_string(),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;

        let qmax = j
            .at(&["quant", "qmax"])
            .and_then(Json::as_usize)
            .unwrap_or(127);

        Ok(Manifest {
            dir: dir.to_path_buf(),
            tokenizer_spec: j
                .get("tokenizer")
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("manifest missing tokenizer"))?,
            seq_buckets,
            batch_sizes,
            models,
            variants,
            monolithic,
            eval_samples,
            qmax,
        })
    }

    /// Smallest bucket that fits `len` live tokens (None if none fits).
    pub fn bucket_for(&self, len: usize) -> Option<usize> {
        self.seq_buckets.iter().copied().find(|&b| b >= len)
    }

    pub fn largest_bucket(&self) -> usize {
        *self.seq_buckets.last().unwrap()
    }

    pub fn model_for(&self, key: VariantKey) -> anyhow::Result<&ModelSpec> {
        self.models
            .get(key.role.as_str())
            .ok_or_else(|| anyhow::anyhow!("no model spec for {}", key.name()))
    }

    pub fn variant(&self, key: VariantKey) -> anyhow::Result<&VariantEntry> {
        self.variants
            .get(&key)
            .ok_or_else(|| anyhow::anyhow!("no variant {} in manifest", key.name()))
    }

    pub fn mono(&self, gamma: usize) -> Option<&MonoEntry> {
        self.monolithic.iter().find(|m| m.gamma == gamma)
    }

    /// Batch sizes actually lowered for (variant, kernel, bucket),
    /// ascending and deduplicated. Empty when the variant is unknown or
    /// nothing was lowered for that shape — the single source of truth
    /// for both executable warmup and the fused executor's chunk planner.
    pub fn batch_sizes_for(
        &self,
        variant: VariantKey,
        kernel: KernelPath,
        seq: usize,
    ) -> Vec<usize> {
        let mut sizes: Vec<usize> = match self.variant(variant) {
            Ok(entry) => entry
                .artifacts
                .iter()
                .filter(|a| a.kernel == kernel && a.seq == seq)
                .map(|a| a.batch)
                .collect(),
            Err(_) => Vec::new(),
        };
        sizes.sort_unstable();
        sizes.dedup();
        sizes
    }

    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal manifest JSON for unit tests (no files on disk needed).
    pub fn mini_manifest_json() -> String {
        r#"{
          "tokenizer": {"specials":["<pad>","<bos>","<eos>","="],
                        "chars":" abcdefghijklmnopqrstuvwxyz.,?!-0123456789:'",
                        "vocab_size":48},
          "seq_buckets": [16, 64, 128],
          "batch_sizes": [1, 4],
          "models": {
            "target": {"name":"target","n_layers":4,"d_model":128,"n_heads":4,
                       "ffn_dim":352,"vocab":48,"rope_theta":10000.0,
                       "param_count":816256},
            "drafter": {"name":"drafter","n_layers":2,"d_model":96,"n_heads":4,
                        "ffn_dim":256,"vocab":48,"rope_theta":10000.0,
                        "param_count":230880}
          },
          "quant": {"qmax": 2},
          "variants": {
            "target_fp": {"role":"target","scheme":"fp","model":"target",
              "weights":"weights_target_fp.bin",
              "tensors":[{"name":"embed","dtype":"f32","shape":[48,128]}],
              "artifacts":[{"file":"target_fp_b1_s64.hlo.txt","kernel":"pallas",
                            "batch":1,"seq":64}]}
          },
          "monolithic": [{"file":"mono_g2_s128.hlo.txt","gamma":2,"seq":128,
                          "drafter":"drafter_fp","target":"target_w8a8"}],
          "eval_samples": [{"task":"translate","prompt":"tr: a","completion":"h"}]
        }"#
        .to_string()
    }

    #[test]
    fn parses_mini() {
        let j = Json::parse(&mini_manifest_json()).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/x"), &j).unwrap();
        assert_eq!(m.seq_buckets, vec![16, 64, 128]);
        assert_eq!(m.qmax, 2);
        assert_eq!(m.eval_samples.len(), 1);
        assert_eq!(m.monolithic[0].gamma, 2);
        let v = m
            .variant(VariantKey::parse("target_fp").unwrap())
            .unwrap();
        assert!(v.artifact(KernelPath::Pallas, 1, 64).is_some());
        assert!(v.artifact(KernelPath::Ref, 1, 64).is_none());
    }

    #[test]
    fn batch_sizes_for_reflects_lowered_artifacts() {
        let j = Json::parse(&mini_manifest_json()).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/x"), &j).unwrap();
        let v = VariantKey::parse("target_fp").unwrap();
        // Only a pallas batch-1 seq-64 artifact is lowered in the mini set.
        assert_eq!(m.batch_sizes_for(v, KernelPath::Pallas, 64), vec![1]);
        assert!(m.batch_sizes_for(v, KernelPath::Ref, 64).is_empty());
        assert!(m.batch_sizes_for(v, KernelPath::Pallas, 16).is_empty());
        let missing = VariantKey::parse("drafter_w8a8").unwrap();
        assert!(m.batch_sizes_for(missing, KernelPath::Pallas, 64).is_empty());
    }

    #[test]
    fn bucket_selection() {
        let j = Json::parse(&mini_manifest_json()).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/x"), &j).unwrap();
        assert_eq!(m.bucket_for(10), Some(16));
        assert_eq!(m.bucket_for(16), Some(16));
        assert_eq!(m.bucket_for(17), Some(64));
        assert_eq!(m.bucket_for(129), None);
        assert_eq!(m.largest_bucket(), 128);
    }

    #[test]
    fn missing_fields_error() {
        let j = Json::parse(r#"{"seq_buckets":[16]}"#).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
    }
}
