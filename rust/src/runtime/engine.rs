//! The PJRT execution engine: compiles AOT artifacts on first use (cached
//! thereafter) and runs them with device-resident weights.
//!
//! One `Engine` per worker thread (the xla wrapper types hold raw pointers
//! and are not `Send`); the PJRT *CPU* client underneath is cheap enough to
//! instantiate per worker. The hot path per forward call is: pad tokens →
//! upload one tiny i32 buffer → `execute_b` → download logits.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use crate::config::KernelPath;
use crate::models::VariantKey;
use crate::tokenizer::PAD_ID;

use super::manifest::Manifest;
use super::weights;

/// Cache key for a compiled forward executable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct ExeKey {
    variant: VariantKey,
    kernel: KernelPath,
    batch: usize,
    seq: usize,
}

/// Result of a forward pass.
#[derive(Debug, Clone)]
pub struct ForwardOut {
    /// Row-major logits [batch * seq, vocab].
    pub logits: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    /// Real wall-clock of the PJRT execution (excludes compile).
    pub elapsed_s: f64,
}

impl ForwardOut {
    /// Logits row for (batch item, position).
    pub fn row(&self, b: usize, pos: usize) -> &[f32] {
        debug_assert!(b < self.batch && pos < self.seq);
        let start = (b * self.seq + pos) * self.vocab;
        &self.logits[start..start + self.vocab]
    }

    /// Greedy token at (batch item, position).
    pub fn argmax(&self, b: usize, pos: usize) -> u32 {
        let row = self.row(b, pos);
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (i, &v) in row.iter().enumerate() {
            if v > bv {
                bv = v;
                best = i;
            }
        }
        best as u32
    }

    /// Softmax probabilities at (b, pos) — used by the stochastic accept
    /// rule, which calls this γ+1 times per round. Exponentiates into a
    /// single output buffer and normalizes in place (one allocation,
    /// one multiply per element instead of a divide).
    pub fn probs(&self, b: usize, pos: usize) -> Vec<f32> {
        let row = self.row(b, pos);
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut out = Vec::with_capacity(row.len());
        let mut z = 0.0f32;
        for &v in row {
            let e = (v - m).exp();
            z += e;
            out.push(e);
        }
        let inv = 1.0 / z;
        for p in &mut out {
            *p *= inv;
        }
        out
    }
}

/// Result of one fused monolithic speculation step.
#[derive(Debug, Clone)]
pub struct MonoStepOut {
    /// Leading drafted tokens accepted by the target (greedy rule).
    pub n_accepted: usize,
    /// Target greedy tokens at positions cur_len .. cur_len+γ (the corrected
    /// continuation; append `out_tokens[..n_accepted + 1]`).
    pub out_tokens: Vec<u32>,
    /// The γ tokens the drafter proposed (diagnostics / α accounting).
    pub drafted: Vec<u32>,
    pub elapsed_s: f64,
}

/// The engine. Construct once per worker thread via [`Engine::load`].
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Rc<Manifest>,
    /// Device-resident weights per variant (uploaded lazily, kept forever).
    weights: RefCell<HashMap<VariantKey, Rc<Vec<xla::PjRtBuffer>>>>,
    exes: RefCell<HashMap<ExeKey, Rc<xla::PjRtLoadedExecutable>>>,
    mono_exes: RefCell<HashMap<usize, Rc<xla::PjRtLoadedExecutable>>>,
    /// Scratch pad buffer reused across calls (perf: zero realloc).
    pad_scratch: RefCell<Vec<i32>>,
    /// Counters for the profiler / metrics.
    pub n_forward_calls: std::cell::Cell<u64>,
    pub n_compiles: std::cell::Cell<u64>,
}

impl Engine {
    /// Load the manifest and create a PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Engine> {
        let manifest = Rc::new(Manifest::load(artifacts_dir)?);
        Self::with_manifest(manifest)
    }

    pub fn with_manifest(manifest: Rc<Manifest>) -> anyhow::Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            weights: RefCell::new(HashMap::new()),
            exes: RefCell::new(HashMap::new()),
            mono_exes: RefCell::new(HashMap::new()),
            pad_scratch: RefCell::new(Vec::new()),
            n_forward_calls: std::cell::Cell::new(0),
            n_compiles: std::cell::Cell::new(0),
        })
    }

    /// Device-resident weights for a variant (upload on first use).
    fn weights_for(&self, key: VariantKey) -> anyhow::Result<Rc<Vec<xla::PjRtBuffer>>> {
        if let Some(w) = self.weights.borrow().get(&key) {
            return Ok(Rc::clone(w));
        }
        let entry = self.manifest.variant(key)?;
        let path = self.manifest.path_of(&entry.weights_file);
        let tensors = weights::read_sewb(&path)?;
        anyhow::ensure!(
            tensors.len() == entry.tensors.len(),
            "{}: weights file has {} tensors, manifest says {}",
            key.name(), tensors.len(), entry.tensors.len()
        );
        for (t, m) in tensors.iter().zip(&entry.tensors) {
            anyhow::ensure!(
                t.name == m.name && t.shape == m.shape,
                "{}: tensor mismatch {} vs {}", key.name(), t.name, m.name
            );
        }
        let bufs = Rc::new(weights::upload(&self.client, &tensors)?);
        self.weights.borrow_mut().insert(key, Rc::clone(&bufs));
        Ok(bufs)
    }

    fn compile(&self, file: &str) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        let path = self.manifest.path_of(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e:?}"))?;
        self.n_compiles.set(self.n_compiles.get() + 1);
        Ok(Rc::new(exe))
    }

    fn forward_exe(
        &self,
        variant: VariantKey,
        kernel: KernelPath,
        batch: usize,
        seq: usize,
    ) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = ExeKey { variant, kernel, batch, seq };
        if let Some(e) = self.exes.borrow().get(&key) {
            return Ok(Rc::clone(e));
        }
        let entry = self.manifest.variant(variant)?;
        let art = entry.artifact(kernel, batch, seq).ok_or_else(|| {
            anyhow::anyhow!(
                "no artifact for {} kernel={} batch={batch} seq={seq}",
                variant.name(), kernel.as_str()
            )
        })?;
        let exe = self.compile(&art.file)?;
        self.exes.borrow_mut().insert(key, Rc::clone(&exe));
        Ok(exe)
    }

    /// Pre-compile the executables a decode session will need (avoids
    /// first-call compile latency on the serving path). Warms every batch
    /// size the manifest lowered for (variant, kernel, bucket), so the
    /// fused executor's first shared dispatch doesn't pay compile time.
    pub fn warmup(
        &self,
        variants: &[VariantKey],
        kernel: KernelPath,
        buckets: &[usize],
    ) -> anyhow::Result<()> {
        for &v in variants {
            self.weights_for(v)?;
            for &b in buckets {
                self.forward_exe(v, kernel, 1, b)?;
                // Batched lowerings don't exist for every (kernel, bucket)
                // — compile the ones the manifest actually has.
                for n in self.manifest.batch_sizes_for(v, kernel, b) {
                    if n > 1 {
                        self.forward_exe(v, kernel, n, b)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Smallest compiled bucket that fits `len` tokens.
    pub fn bucket_for(&self, len: usize) -> anyhow::Result<usize> {
        self.manifest.bucket_for(len).ok_or_else(|| {
            anyhow::anyhow!(
                "requested sequence length {len} does not fit any compiled \
                 seq bucket (manifest has {:?}; largest is {}) — re-run the \
                 AOT build with a bucket ≥ {len} or shorten the request",
                self.manifest.seq_buckets,
                self.manifest.largest_bucket()
            )
        })
    }

    /// Single-sequence forward: pad to the bucket, run, return full logits.
    pub fn forward(
        &self,
        variant: VariantKey,
        kernel: KernelPath,
        tokens: &[u32],
        bucket: usize,
    ) -> anyhow::Result<ForwardOut> {
        anyhow::ensure!(tokens.len() <= bucket, "{} > bucket {bucket}", tokens.len());
        let exe = self.forward_exe(variant, kernel, 1, bucket)?;
        let w = self.weights_for(variant)?;

        // Shares the monotonically-grown pad scratch with forward_batch
        // (fill a bucket-sized prefix, upload just that slice).
        let mut scratch = self.pad_scratch.borrow_mut();
        if scratch.len() < bucket {
            scratch.resize(bucket, PAD_ID as i32);
        }
        for (dst, &t) in scratch.iter_mut().zip(tokens.iter()) {
            *dst = t as i32;
        }
        scratch[tokens.len()..bucket].fill(PAD_ID as i32);
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&scratch[..bucket], &[bucket], None)
            .map_err(|e| anyhow::anyhow!("token upload: {e:?}"))?;
        drop(scratch);

        let mut args: Vec<&xla::PjRtBuffer> = w.iter().collect();
        args.push(&tok_buf);

        let t0 = Instant::now();
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", variant.name()))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e:?}"))?;
        let elapsed_s = t0.elapsed().as_secs_f64();
        self.n_forward_calls.set(self.n_forward_calls.get() + 1);

        let out = lit.to_tuple1().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let logits = out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let spec = self.manifest.model_for(variant)?;
        anyhow::ensure!(
            logits.len() == bucket * spec.vocab,
            "logits size {} != {bucket} * {}", logits.len(), spec.vocab
        );
        Ok(ForwardOut {
            logits,
            batch: 1,
            seq: bucket,
            vocab: spec.vocab,
            elapsed_s,
        })
    }

    /// Batched forward over `batch` sequences padded to the same bucket.
    /// `batch == 1` runs the rank-1 single-sequence artifact, so callers
    /// can fall back to unbatched dispatch through the same entry point.
    pub fn forward_batch(
        &self,
        variant: VariantKey,
        kernel: KernelPath,
        seqs: &[&[u32]],
        bucket: usize,
    ) -> anyhow::Result<ForwardOut> {
        let batch = seqs.len();
        anyhow::ensure!(batch >= 1, "empty batch");
        let exe = self.forward_exe(variant, kernel, batch, bucket)?;
        let w = self.weights_for(variant)?;
        // The pad scratch grows monotonically and is never shrunk, so a
        // burst of wide tree-lane dispatches allocates at most once for the
        // largest lane count seen and every later call reuses that buffer.
        let need = batch * bucket;
        let mut scratch = self.pad_scratch.borrow_mut();
        if scratch.len() < need {
            scratch.resize(need, PAD_ID as i32);
        }
        for (b, s) in seqs.iter().enumerate() {
            anyhow::ensure!(s.len() <= bucket, "{} > bucket {bucket}", s.len());
            let row = &mut scratch[b * bucket..(b + 1) * bucket];
            for (dst, &t) in row.iter_mut().zip(s.iter()) {
                *dst = t as i32;
            }
            row[s.len()..].fill(PAD_ID as i32);
        }
        // The batch-1 artifact takes rank-1 tokens (aot.py lowers
        // `(bucket,)` for batch 1, `(batch, bucket)` otherwise).
        let rank2 = [batch, bucket];
        let shape: &[usize] = if batch == 1 { &rank2[1..] } else { &rank2 };
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&scratch[..need], shape, None)
            .map_err(|e| anyhow::anyhow!("token upload: {e:?}"))?;
        drop(scratch);
        let mut args: Vec<&xla::PjRtBuffer> = w.iter().collect();
        args.push(&tok_buf);
        let t0 = Instant::now();
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("execute batch: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e:?}"))?;
        let elapsed_s = t0.elapsed().as_secs_f64();
        self.n_forward_calls.set(self.n_forward_calls.get() + 1);
        let out = lit.to_tuple1().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let logits = out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let spec = self.manifest.model_for(variant)?;
        Ok(ForwardOut {
            logits,
            batch,
            seq: bucket,
            vocab: spec.vocab,
            elapsed_s,
        })
    }

    /// One fused monolithic speculation step (paper Fig. 3).
    pub fn mono_step(
        &self,
        gamma: usize,
        tokens: &[u32],
        cur_len: usize,
    ) -> anyhow::Result<MonoStepOut> {
        let entry = self
            .manifest
            .mono(gamma)
            .ok_or_else(|| anyhow::anyhow!("no monolithic artifact for gamma={gamma}"))?
            .clone();
        anyhow::ensure!(
            cur_len >= 1 && cur_len + gamma <= entry.seq,
            "cur_len {cur_len} + gamma {gamma} exceeds mono bucket {}", entry.seq
        );
        // NB: take the cached Rc out before the else-branch mutates the map
        // (a single `if let Some(e) = .borrow().get(..)` would hold the
        // shared borrow across the `borrow_mut`).
        let cached = self.mono_exes.borrow().get(&gamma).map(Rc::clone);
        let exe = match cached {
            Some(e) => e,
            None => {
                let e = self.compile(&entry.file)?;
                self.mono_exes.borrow_mut().insert(gamma, Rc::clone(&e));
                e
            }
        };
        let dw = self.weights_for(entry.drafter)?;
        let tw = self.weights_for(entry.target)?;

        let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        padded.resize(entry.seq, PAD_ID as i32);
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&padded, &[entry.seq], None)
            .map_err(|e| anyhow::anyhow!("token upload: {e:?}"))?;
        let len_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&[cur_len as i32], &[], None)
            .map_err(|e| anyhow::anyhow!("len upload: {e:?}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = dw.iter().collect();
        args.extend(tw.iter());
        args.push(&tok_buf);
        args.push(&len_buf);

        let t0 = Instant::now();
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("mono execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e:?}"))?;
        let elapsed_s = t0.elapsed().as_secs_f64();
        self.n_forward_calls.set(self.n_forward_calls.get() + 1);

        let (acc, out_tok, drafted) =
            lit.to_tuple3().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let n_accepted = acc.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e:?}"))?[0];
        let out_tokens: Vec<u32> = out_tok
            .to_vec::<i32>()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?
            .into_iter()
            .map(|t| t as u32)
            .collect();
        let drafted: Vec<u32> = drafted
            .to_vec::<i32>()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?
            .into_iter()
            .map(|t| t as u32)
            .collect();
        anyhow::ensure!(out_tokens.len() == gamma + 1 && drafted.len() == gamma);
        Ok(MonoStepOut {
            n_accepted: n_accepted as usize,
            out_tokens,
            drafted,
            elapsed_s,
        })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }
}
