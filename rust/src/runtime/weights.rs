//! SEWB weight-file reader (format written by `python/compile/aot.py`):
//!
//! ```text
//! magic "SEWB" | u32 version | u32 n_tensors
//! per tensor: u16 name_len | name | u8 dtype(0=f32,1=i8,2=i32) | u8 ndim
//!             | u32 dims[ndim] | u64 nbytes | raw little-endian bytes
//! ```
//!
//! Tensors are uploaded once as device-resident `PjRtBuffer`s; the hot path
//! only ever uploads the (tiny) token buffer per call.

use std::io::Read;
use std::path::Path;

/// Dtype tag in a SEWB file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I8,
    I32,
}

impl Dtype {
    fn from_tag(tag: u8) -> anyhow::Result<Dtype> {
        match tag {
            0 => Ok(Dtype::F32),
            1 => Ok(Dtype::I8),
            2 => Ok(Dtype::I32),
            t => anyhow::bail!("unknown SEWB dtype tag {t}"),
        }
    }

    pub fn size(&self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::I8 => 1,
        }
    }
}

/// One tensor read from a SEWB file.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl HostTensor {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(self.dtype == Dtype::F32, "{} is not f32", self.name);
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

/// Read every tensor of a SEWB file, preserving file order (= the parameter
/// order of the compiled executables).
pub fn read_sewb(path: &Path) -> anyhow::Result<Vec<HostTensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("cannot open weights {path:?}: {e}"))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == b"SEWB", "{path:?}: bad magic {magic:?}");
    let version = read_u32(&mut f)?;
    anyhow::ensure!(version == 1, "{path:?}: unsupported SEWB version {version}");
    let n = read_u32(&mut f)? as usize;
    anyhow::ensure!(n < 100_000, "{path:?}: implausible tensor count {n}");
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u16(&mut f)? as usize;
        let mut name_bytes = vec![0u8; name_len];
        f.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes)
            .map_err(|_| anyhow::anyhow!("{path:?}: non-utf8 tensor name"))?;
        let mut hdr = [0u8; 2];
        f.read_exact(&mut hdr)?;
        let dtype = Dtype::from_tag(hdr[0])?;
        let ndim = hdr[1] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut f)? as usize);
        }
        let nbytes = read_u64(&mut f)? as usize;
        let expected = shape.iter().product::<usize>() * dtype.size();
        anyhow::ensure!(
            nbytes == expected,
            "{path:?}: tensor {name}: {nbytes} bytes != shape {shape:?} * {}",
            dtype.size()
        );
        let mut data = vec![0u8; nbytes];
        f.read_exact(&mut data)?;
        out.push(HostTensor { name, dtype, shape, data });
    }
    Ok(out)
}

/// Upload tensors as device-resident buffers, in order.
///
/// NOTE: we deliberately use the *typed* `buffer_from_host_buffer::<T>` —
/// the crate's `buffer_from_host_raw_bytes` passes `ElementType as i32`
/// where the C API expects `PrimitiveType` numbering (off by one: F32 → 10
/// = F16), silently creating half-sized f16 buffers. The typed path goes
/// through `T::TY.primitive_type()` and is correct.
pub fn upload(
    client: &xla::PjRtClient,
    tensors: &[HostTensor],
) -> anyhow::Result<Vec<xla::PjRtBuffer>> {
    tensors
        .iter()
        .map(|t| {
            let res = match t.dtype {
                Dtype::F32 => {
                    let v: Vec<f32> = t
                        .data
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect();
                    client.buffer_from_host_buffer::<f32>(&v, &t.shape, None)
                }
                Dtype::I8 => {
                    let v: Vec<i8> = t.data.iter().map(|&b| b as i8).collect();
                    client.buffer_from_host_buffer::<i8>(&v, &t.shape, None)
                }
                Dtype::I32 => {
                    let v: Vec<i32> = t
                        .data
                        .chunks_exact(4)
                        .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect();
                    client.buffer_from_host_buffer::<i32>(&v, &t.shape, None)
                }
            };
            res.map_err(|e| anyhow::anyhow!("uploading {}: {e:?}", t.name))
        })
        .collect()
}

fn read_u16<R: Read>(r: &mut R) -> anyhow::Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32<R: Read>(r: &mut R) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_mini_sewb(path: &Path) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"SEWB").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap(); // version
        f.write_all(&2u32.to_le_bytes()).unwrap(); // 2 tensors
        // tensor 1: "a" f32 [2,2]
        f.write_all(&1u16.to_le_bytes()).unwrap();
        f.write_all(b"a").unwrap();
        f.write_all(&[0u8, 2u8]).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&16u64.to_le_bytes()).unwrap();
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        // tensor 2: "b" i8 [3]
        f.write_all(&1u16.to_le_bytes()).unwrap();
        f.write_all(b"b").unwrap();
        f.write_all(&[1u8, 1u8]).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        f.write_all(&3u64.to_le_bytes()).unwrap();
        f.write_all(&[5u8, 250, 7]).unwrap();
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("specedge_sewb_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        write_mini_sewb(&p);
        let ts = read_sewb(&p).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "a");
        assert_eq!(ts[0].shape, vec![2, 2]);
        assert_eq!(ts[0].as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ts[1].dtype, Dtype::I8);
        assert_eq!(ts[1].data, vec![5, 250, 7]);
        assert!(ts[1].as_f32().is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("specedge_sewb_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00").unwrap();
        assert!(read_sewb(&p).is_err());
    }
}
