//! Char-level tokenizer, rebuilt from the manifest's tokenizer spec so the
//! Rust request path and the Python build path agree token-for-token (the
//! Python side is `python/compile/tokenizer.py`).

use crate::util::json::Json;
use std::collections::HashMap;

pub const PAD_ID: u32 = 0;
pub const BOS_ID: u32 = 1;
pub const EOS_ID: u32 = 2;
pub const SEP_ID: u32 = 3;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    chars: Vec<char>,
    char_to_id: HashMap<char, u32>,
    pub vocab_size: usize,
    n_specials: usize,
}

impl Tokenizer {
    /// Build from the manifest's `tokenizer` object.
    pub fn from_manifest(spec: &Json) -> anyhow::Result<Tokenizer> {
        let chars: Vec<char> = spec.req_str("chars")?.chars().collect();
        let vocab_size = spec.req_usize("vocab_size")?;
        let n_specials = spec.req_arr("specials")?.len();
        anyhow::ensure!(
            n_specials + chars.len() == vocab_size,
            "tokenizer spec inconsistent: {} specials + {} chars != {}",
            n_specials, chars.len(), vocab_size
        );
        let char_to_id = chars
            .iter()
            .enumerate()
            .map(|(i, &c)| (c, (i + n_specials) as u32))
            .collect();
        Ok(Tokenizer { chars, char_to_id, vocab_size, n_specials })
    }

    /// The default spec (mirrors tokenizer.py); used by unit tests and
    /// tools that run without a manifest.
    pub fn builtin() -> Tokenizer {
        let spec = Json::parse(
            r#"{"specials":["<pad>","<bos>","<eos>","="],
                "chars":" abcdefghijklmnopqrstuvwxyz.,?!-0123456789:'",
                "vocab_size":48}"#,
        )
        .unwrap();
        Tokenizer::from_manifest(&spec).unwrap()
    }

    pub fn encode(&self, text: &str, bos: bool) -> anyhow::Result<Vec<u32>> {
        let mut ids = Vec::with_capacity(text.len() + 1);
        if bos {
            ids.push(BOS_ID);
        }
        for ch in text.chars() {
            match self.char_to_id.get(&ch) {
                Some(&id) => ids.push(id),
                None => anyhow::bail!("character {ch:?} not in vocabulary"),
            }
        }
        Ok(ids)
    }

    /// Decode ids, skipping BOS/PAD, stopping at EOS, rendering SEP as '='.
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut out = String::new();
        for &id in ids {
            match id {
                BOS_ID | PAD_ID => continue,
                EOS_ID => break,
                SEP_ID => out.push('='),
                id => {
                    let idx = id as usize - self.n_specials;
                    if let Some(&c) = self.chars.get(idx) {
                        out.push(c);
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = Tokenizer::builtin();
        let s = "tr: hello world 123?!";
        let ids = t.encode(s, true).unwrap();
        assert_eq!(ids[0], BOS_ID);
        assert_eq!(t.decode(&ids), s);
    }

    #[test]
    fn matches_python_ids() {
        // "a" must be id 5 (4 specials + space=4, a=5) — pinned so both
        // sides stay in sync.
        let t = Tokenizer::builtin();
        assert_eq!(t.encode(" a", false).unwrap(), vec![4, 5]);
        assert_eq!(t.vocab_size, 48);
    }

    #[test]
    fn eos_stops_decode() {
        let t = Tokenizer::builtin();
        assert_eq!(t.decode(&[5, EOS_ID, 6]), "a");
    }

    #[test]
    fn unknown_char_errors() {
        let t = Tokenizer::builtin();
        assert!(t.encode("ABC", false).is_err());
    }

    #[test]
    fn sep_renders_as_equals() {
        let t = Tokenizer::builtin();
        assert_eq!(t.decode(&[5, SEP_ID, 6]), "a=b");
    }
}
