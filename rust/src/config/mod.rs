//! Typed run configuration.
//!
//! Every binary (CLI subcommands, examples, benches) builds a [`RunConfig`]
//! from defaults + an optional JSON config file + CLI overrides. The
//! platform calibration (the simulated i.MX95) lives in its own file,
//! `configs/imx95.json`, parsed by `hetero::platform`.

use crate::costmodel::TreeShape;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// How the engine composes drafter and target (paper Figs. 3 & 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Separate compiled modules; control flow in Rust; per-call boundary
    /// overhead (the paper's deployed configuration).
    Modular,
    /// One fused spec-step HLO per γ; draft loop + verify in-graph.
    Monolithic,
}

impl ExecMode {
    pub fn parse(s: &str) -> anyhow::Result<ExecMode> {
        match s {
            "modular" => Ok(ExecMode::Modular),
            "monolithic" => Ok(ExecMode::Monolithic),
            _ => anyhow::bail!("exec mode must be modular|monolithic, got {s:?}"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ExecMode::Modular => "modular",
            ExecMode::Monolithic => "monolithic",
        }
    }
}

/// Which clock drives reported latencies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Timing {
    /// Virtual clock from the calibrated PU latency model (paper-comparable;
    /// the default — this is how we stand in for the i.MX95 silicon).
    Simulated,
    /// Real wall-clock of the PJRT CPU execution on this machine.
    Real,
}

impl Timing {
    pub fn parse(s: &str) -> anyhow::Result<Timing> {
        match s {
            "simulated" => Ok(Timing::Simulated),
            "real" => Ok(Timing::Real),
            _ => anyhow::bail!("timing must be simulated|real, got {s:?}"),
        }
    }
}

/// Kernel path baked into the artifacts the engine loads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KernelPath {
    /// Pallas kernels (interpret=True lowering) — the L1 deliverable.
    Pallas,
    /// Pure-jnp reference lowering — ablation / fast path.
    Ref,
}

impl KernelPath {
    pub fn parse(s: &str) -> anyhow::Result<KernelPath> {
        match s {
            "pallas" => Ok(KernelPath::Pallas),
            "ref" => Ok(KernelPath::Ref),
            _ => anyhow::bail!("kernel path must be pallas|ref, got {s:?}"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KernelPath::Pallas => "pallas",
            KernelPath::Ref => "ref",
        }
    }
}

/// Which cost model drives the routing/partitioning decision layer
/// ([`crate::decision`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionMode {
    /// The analytic latency model exactly as calibrated offline — the
    /// paper's workflow, and bit-identical to the pre-decision-layer
    /// behavior (the default).
    Analytic,
    /// The analytic model continuously refit from measured dispatch
    /// durations; additionally enables online re-partitioning every
    /// `repartition_every` rounds.
    Calibrated,
}

impl DecisionMode {
    pub fn parse(s: &str) -> anyhow::Result<DecisionMode> {
        match s {
            "analytic" => Ok(DecisionMode::Analytic),
            "calibrated" => Ok(DecisionMode::Calibrated),
            _ => anyhow::bail!("decision must be analytic|calibrated, got {s:?}"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DecisionMode::Analytic => "analytic",
            DecisionMode::Calibrated => "calibrated",
        }
    }
}

/// Speculation-*tree* mode (the `tree` knob). `off` (the default) keeps
/// the linear γ-chain and is bit-identical to the historical behavior;
/// `auto` lets the decision layer score a small set of `(branching,
/// depth)` shapes against the chain per decision (chain wins keep the
/// chain); an explicit `KxD` shape pins tree speculation to that shape
/// whenever the engine speculates at all. Trees run only under the
/// modular exec mode — the monolithic spec-step HLO has the chain baked
/// into the graph — and a pinned `1xD` shape *is* the chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TreeChoice {
    Off,
    Auto,
    Fixed(TreeShape),
}

impl TreeChoice {
    pub fn parse(s: &str) -> anyhow::Result<TreeChoice> {
        match s {
            "off" => Ok(TreeChoice::Off),
            "auto" => Ok(TreeChoice::Auto),
            _ => TreeShape::parse(s).map(TreeChoice::Fixed).map_err(|e| {
                anyhow::anyhow!("tree must be off|auto|KxD (e.g. 2x3): {e}")
            }),
        }
    }

    pub fn label(&self) -> String {
        match self {
            TreeChoice::Off => "off".to_string(),
            TreeChoice::Auto => "auto".to_string(),
            TreeChoice::Fixed(shape) => shape.label(),
        }
    }
}

/// Paged KV-cache mode (the `kv_cache` knob). `off` (the default) keeps
/// the historical full-recompute engine bit-for-bit: no manager is
/// constructed, every forward is priced by the plain latency model and
/// admission never consults page pools. `on` enables the
/// [`crate::kvcache`] subsystem: per-session page reservation at
/// admission (exhaustion sheds the request), cross-request prefix
/// sharing, incremental forward pricing, and memory-aware DSE filtering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvCacheMode {
    Off,
    On,
}

impl KvCacheMode {
    pub fn parse(s: &str) -> anyhow::Result<KvCacheMode> {
        match s {
            "off" => Ok(KvCacheMode::Off),
            "on" => Ok(KvCacheMode::On),
            _ => anyhow::bail!("kv_cache must be off|on, got {s:?}"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KvCacheMode::Off => "off",
            KvCacheMode::On => "on",
        }
    }

    pub fn enabled(&self) -> bool {
        matches!(self, KvCacheMode::On)
    }
}

/// Serving-shell architecture (the `serve_mode` knob). `event_loop` (the
/// default) multiplexes every connection onto one nonblocking event-loop
/// thread over the coordinator's handle API — per-connection read/write
/// buffers, bounded outbound queues, token-bucket rate limiting, graceful
/// drain and config hot-reload. `threaded` keeps the legacy
/// thread-per-connection front-end as the A/B baseline the `serve_load`
/// experiment measures against. Both modes speak byte-identical v1/v2
/// wire protocols.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    Threaded,
    EventLoop,
}

impl ServeMode {
    pub fn parse(s: &str) -> anyhow::Result<ServeMode> {
        match s {
            "threaded" => Ok(ServeMode::Threaded),
            "event_loop" => Ok(ServeMode::EventLoop),
            _ => anyhow::bail!("serve_mode must be threaded|event_loop, got {s:?}"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ServeMode::Threaded => "threaded",
            ServeMode::EventLoop => "event_loop",
        }
    }
}

/// Drafter-selection mode (the `drafter` knob). `fixed` (the default)
/// always drafts with the configured `drafter_variant` — bit-identical to
/// the historical single-drafter behavior. `auto` builds a
/// [`crate::scenario::DrafterRegistry`] from the manifest's `drafter_*`
/// variants and lets the decision layer choose the drafter *per request
/// class* at session admission, scoring every (drafter variant, mapping,
/// γ/tree) candidate through the DSE at per-drafter α estimates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrafterMode {
    Fixed,
    Auto,
}

impl DrafterMode {
    pub fn parse(s: &str) -> anyhow::Result<DrafterMode> {
        match s {
            "fixed" => Ok(DrafterMode::Fixed),
            "auto" => Ok(DrafterMode::Auto),
            _ => anyhow::bail!("drafter must be fixed|auto, got {s:?}"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DrafterMode::Fixed => "fixed",
            DrafterMode::Auto => "auto",
        }
    }
}

/// Per-request verify placement under a fleet's cloud tier (the
/// `cloud_verify` knob). Only consulted when a fleet file declares a
/// `cloud` section ([`crate::fleet`]); without one every request verifies
/// locally and the knob is inert. `auto` (the default) compares the
/// predicted pipelined cloud-verify round latency against the local round
/// at the device's live (α, c) per request; `local` / `cloud` pin the
/// route for A/B runs; `off` disables the cloud tier even when the fleet
/// file declares one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CloudVerifyMode {
    Off,
    Auto,
    Local,
    Cloud,
}

impl CloudVerifyMode {
    pub fn parse(s: &str) -> anyhow::Result<CloudVerifyMode> {
        match s {
            "off" => Ok(CloudVerifyMode::Off),
            "auto" => Ok(CloudVerifyMode::Auto),
            "local" => Ok(CloudVerifyMode::Local),
            "cloud" => Ok(CloudVerifyMode::Cloud),
            _ => anyhow::bail!("cloud_verify must be off|auto|local|cloud, got {s:?}"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            CloudVerifyMode::Off => "off",
            CloudVerifyMode::Auto => "auto",
            CloudVerifyMode::Local => "local",
            CloudVerifyMode::Cloud => "cloud",
        }
    }
}

/// Complete engine + serving configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Directory with manifest.json, *.hlo.txt, weights_*.bin.
    pub artifacts_dir: PathBuf,
    /// Platform calibration file (None -> built-in i.MX95 defaults).
    pub platform_file: Option<PathBuf>,
    /// How drafter and target compose: separate compiled modules
    /// (`modular`) or one fused spec-step graph (`monolithic`). See
    /// [`ExecMode`].
    pub exec_mode: ExecMode,
    /// Which clock reported latencies come from: the calibrated simulated
    /// platform (`simulated`, the default) or the real PJRT wall clock
    /// (`real`). See [`Timing`].
    pub timing: Timing,
    /// Kernel lowering baked into the loaded artifacts: `pallas` or the
    /// pure-jnp `ref` ablation. See [`KernelPath`].
    pub kernel_path: KernelPath,
    /// Draft length; None = let the cost model pick γ* per request.
    pub gamma: Option<usize>,
    /// Speculation on/off (off = plain autoregressive baseline).
    pub speculative: bool,
    /// Design variant (1-based: number of CPU cores available), paper §III-B.
    pub design_variant: usize,
    /// Heterogeneous mapping allowed (drafter on GPU, target on CPU):
    /// selects the boot mapping, and under calibrated re-partitioning the
    /// *permission* to adopt the heterogeneous mapping. `false` pins the
    /// homogeneous mapping — online re-partitioning is then inert (there
    /// is exactly one permitted mapping per design variant).
    pub heterogeneous: bool,
    /// Max new tokens per request (the default when a request's
    /// `GenOptions` carries no `max_new` override).
    pub max_new_tokens: usize,
    /// Server-side ceiling on a request's `max_new` *override* (API v2):
    /// client-requested budgets are clamped into `1..=max_new_limit`, so
    /// one request can't monopolize a worker. Does not constrain
    /// `max_new_tokens` itself.
    pub max_new_limit: usize,
    /// Serving: number of engine workers.
    pub workers: usize,
    /// Serving: TCP port.
    pub port: u16,
    /// Serving: queue capacity before backpressure rejects.
    pub queue_capacity: usize,
    /// Serving-shell architecture: `event_loop` (nonblocking connection
    /// multiplexing, the default) or the legacy `threaded`
    /// thread-per-connection baseline. See [`ServeMode`].
    pub serve_mode: ServeMode,
    /// Per-client token-bucket rate limit in requests/second
    /// (0 = unlimited, the default). Over-limit generate lines get a
    /// typed `overloaded` reply carrying `retry_after_ms`.
    pub rate_limit_rps: f64,
    /// Token-bucket burst depth: how many requests a client may issue
    /// back-to-back before the refill rate binds.
    pub rate_limit_burst: usize,
    /// Bounded per-client outbound reply queue, in lines. A consumer too
    /// slow to drain its socket overflows the queue and is disconnected
    /// with a typed `overloaded` error instead of blocking the loop
    /// (event-loop mode only — threaded mode blocks per thread).
    pub client_queue_depth: usize,
    /// Graceful drain: seconds in-flight requests get to finish after a
    /// `{"cmd":"drain"}` (or `Server::drain()`) before being cancelled
    /// against their handles. Every in-flight request still receives its
    /// final reply — drain never drops one.
    pub drain_deadline_s: f64,
    /// Metrics history: append one JSON-lines metrics snapshot to this
    /// file every [`metrics_history_every_s`](Self::metrics_history_every_s)
    /// seconds while serving (event-loop mode; `None` = off).
    pub metrics_history_file: Option<PathBuf>,
    /// Seconds between metrics-history snapshots.
    pub metrics_history_every_s: f64,
    /// Batch limit for the dynamic batcher (1 = no batching).
    pub max_batch: usize,
    /// Live decode sessions each worker interleaves round-by-round
    /// (continuous scheduling; 1 = run-to-completion serving).
    pub max_inflight: usize,
    /// Cross-session fused execution: co-scheduled sessions needing the
    /// same (variant, kernel, bucket) forward share one batched dispatch
    /// when a batched artifact exists. `false` reverts to the pre-fusion
    /// behavior for A/B comparisons: per-session engine calls on the
    /// scheduler path, and the legacy lockstep batcher for the
    /// `max_batch > 1` baseline configuration.
    pub fuse: bool,
    /// Per-PU timeline simulation: dispatches routed to different PUs of
    /// the heterogeneous mapping (draft forwards on one, verify forwards
    /// on the other) proceed concurrently on the fused tick scheduler,
    /// each starting at `max(pu_ready, inputs_ready)`; metrics gain
    /// per-PU busy/idle/overlap and the merged makespan. `false` keeps
    /// the single serialized virtual clock (every dispatch queues behind
    /// every other), reproducing the pre-overlap timings bit-for-bit for
    /// A/B parity. Per-session/-request `sim_s` charges are identical in
    /// both modes; the knob changes only the timeline observables.
    pub hetero_overlap: bool,
    /// Cost model behind the decision layer: `analytic` (default; exact
    /// pre-refactor behavior) or `calibrated` (refit online from measured
    /// dispatch durations, with periodic re-partitioning).
    pub decision: DecisionMode,
    /// Calibrated mode: re-run the DSE mapping/γ search every K consulted
    /// rounds and adopt the winner at the next session admission
    /// (0 = never re-partition). Ignored under `decision: "analytic"`.
    pub repartition_every: usize,
    /// Speculation-tree mode: `off` (chain only, the default), `auto`
    /// (decision layer searches tree shapes against the chain), or a
    /// pinned `KxD` shape. See [`TreeChoice`].
    pub tree: TreeChoice,
    /// Paged KV-cache + prefix sharing: `off` (bit-identical historical
    /// engine, the default) or `on`. See [`KvCacheMode`].
    pub kv_cache: KvCacheMode,
    /// Fleet topology file (JSON: a `devices` array — each with its own
    /// platform — plus an optional `cloud` tier; see [`crate::fleet`]).
    /// `None` (the default) serves through the plain single-device
    /// coordinator exactly as before; when set, `serve` fronts one
    /// coordinator per device with the fleet placement router.
    pub fleet_file: Option<PathBuf>,
    /// Verify placement under a fleet cloud tier: `auto` (predicted
    /// round-latency choice, the default), `local`/`cloud` (pinned), or
    /// `off` (ignore the cloud tier). Inert without a fleet file that
    /// declares a `cloud` section. See [`CloudVerifyMode`].
    pub cloud_verify: CloudVerifyMode,
    /// Default cloud-link round-trip time in milliseconds for the fleet
    /// network model (a fleet file's `cloud.rtt_ms` overrides it).
    pub cloud_rtt_ms: f64,
    /// Default cloud-link bandwidth in megabits/second for the fleet
    /// network model (a fleet file's `cloud.mbps` overrides it).
    pub cloud_mbps: f64,
    /// Variant key of the drafter model (must name a `drafter_*` variant
    /// present in the artifact manifest).
    pub drafter_variant: String,
    /// Variant key of the target model (must name a `target_*` variant
    /// present in the artifact manifest).
    pub target_variant: String,
    /// Drafter selection: `fixed` (always `drafter_variant`, the default)
    /// or `auto` (per-request-class choice over the manifest's drafter
    /// variants). See [`DrafterMode`].
    pub drafter: DrafterMode,
    /// Workload-trace file (JSON lines, [`crate::scenario::WorkloadTrace`]).
    /// `None` (the default) keeps the built-in manifest workload; when
    /// set, batch runs and the loadgen replay the trace's per-request
    /// class/arrival/length draws bit-for-bit.
    pub trace_file: Option<PathBuf>,
    /// RNG seed (workload, stochastic sampling).
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig {
            artifacts_dir: PathBuf::from(crate::DEFAULT_ARTIFACTS_DIR),
            platform_file: None,
            exec_mode: ExecMode::Modular,
            timing: Timing::Simulated,
            kernel_path: KernelPath::Pallas,
            gamma: None,
            speculative: true,
            design_variant: 1,
            heterogeneous: true,
            max_new_tokens: 64,
            max_new_limit: 1024,
            workers: 1,
            port: 7643,
            queue_capacity: 256,
            serve_mode: ServeMode::EventLoop,
            rate_limit_rps: 0.0,
            rate_limit_burst: 32,
            client_queue_depth: 1024,
            drain_deadline_s: 30.0,
            metrics_history_file: None,
            metrics_history_every_s: 5.0,
            max_batch: 1,
            max_inflight: 4,
            fuse: true,
            hetero_overlap: true,
            decision: DecisionMode::Analytic,
            repartition_every: 64,
            tree: TreeChoice::Off,
            kv_cache: KvCacheMode::Off,
            fleet_file: None,
            cloud_verify: CloudVerifyMode::Auto,
            cloud_rtt_ms: 20.0,
            cloud_mbps: 100.0,
            drafter_variant: "drafter_fp".to_string(),
            target_variant: "target_w8a8".to_string(),
            drafter: DrafterMode::Fixed,
            trace_file: None,
            seed: 0xC0FFEE,
        }
    }
}

impl RunConfig {
    /// Merge a JSON config file over the defaults.
    pub fn from_file(path: &Path) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        let mut c = RunConfig::default();
        c.apply_json(&j)?;
        Ok(c)
    }

    pub fn apply_json(&mut self, j: &Json) -> anyhow::Result<()> {
        if let Some(v) = j.get("artifacts_dir").and_then(Json::as_str) {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("platform_file").and_then(Json::as_str) {
            self.platform_file = Some(PathBuf::from(v));
        }
        if let Some(v) = j.get("exec_mode").and_then(Json::as_str) {
            self.exec_mode = ExecMode::parse(v)?;
        }
        if let Some(v) = j.get("timing").and_then(Json::as_str) {
            self.timing = Timing::parse(v)?;
        }
        if let Some(v) = j.get("kernel_path").and_then(Json::as_str) {
            self.kernel_path = KernelPath::parse(v)?;
        }
        if let Some(v) = j.get("gamma").and_then(Json::as_usize) {
            self.gamma = Some(v);
        }
        if let Some(v) = j.get("speculative").and_then(Json::as_bool) {
            self.speculative = v;
        }
        if let Some(v) = j.get("design_variant").and_then(Json::as_usize) {
            self.design_variant = v;
        }
        if let Some(v) = j.get("heterogeneous").and_then(Json::as_bool) {
            self.heterogeneous = v;
        }
        if let Some(v) = j.get("max_new_tokens").and_then(Json::as_usize) {
            self.max_new_tokens = v;
        }
        if let Some(v) = j.get("max_new_limit").and_then(Json::as_usize) {
            self.max_new_limit = v;
        }
        if let Some(v) = j.get("workers").and_then(Json::as_usize) {
            self.workers = v;
        }
        if let Some(v) = j.get("port").and_then(Json::as_usize) {
            self.port = v as u16;
        }
        if let Some(v) = j.get("queue_capacity").and_then(Json::as_usize) {
            self.queue_capacity = v;
        }
        if let Some(v) = j.get("serve_mode").and_then(Json::as_str) {
            self.serve_mode = ServeMode::parse(v)?;
        }
        if let Some(v) = j.get("rate_limit_rps").and_then(Json::as_f64) {
            self.rate_limit_rps = v;
        }
        if let Some(v) = j.get("rate_limit_burst").and_then(Json::as_usize) {
            self.rate_limit_burst = v;
        }
        if let Some(v) = j.get("client_queue_depth").and_then(Json::as_usize) {
            self.client_queue_depth = v;
        }
        if let Some(v) = j.get("drain_deadline_s").and_then(Json::as_f64) {
            self.drain_deadline_s = v;
        }
        if let Some(v) = j.get("metrics_history_file").and_then(Json::as_str) {
            self.metrics_history_file = Some(PathBuf::from(v));
        }
        if let Some(v) = j.get("metrics_history_every_s").and_then(Json::as_f64) {
            self.metrics_history_every_s = v;
        }
        if let Some(v) = j.get("max_batch").and_then(Json::as_usize) {
            self.max_batch = v;
        }
        if let Some(v) = j.get("max_inflight").and_then(Json::as_usize) {
            self.max_inflight = v;
        }
        if let Some(v) = j.get("fuse").and_then(Json::as_bool) {
            self.fuse = v;
        }
        if let Some(v) = j.get("hetero_overlap").and_then(Json::as_bool) {
            self.hetero_overlap = v;
        }
        if let Some(v) = j.get("decision").and_then(Json::as_str) {
            self.decision = DecisionMode::parse(v)?;
        }
        if let Some(v) = j.get("repartition_every").and_then(Json::as_usize) {
            self.repartition_every = v;
        }
        if let Some(v) = j.get("tree").and_then(Json::as_str) {
            self.tree = TreeChoice::parse(v)?;
        }
        if let Some(v) = j.get("kv_cache").and_then(Json::as_str) {
            self.kv_cache = KvCacheMode::parse(v)?;
        }
        if let Some(v) = j.get("fleet_file").and_then(Json::as_str) {
            self.fleet_file = Some(PathBuf::from(v));
        }
        if let Some(v) = j.get("cloud_verify").and_then(Json::as_str) {
            self.cloud_verify = CloudVerifyMode::parse(v)?;
        }
        if let Some(v) = j.get("cloud_rtt_ms").and_then(Json::as_f64) {
            self.cloud_rtt_ms = v;
        }
        if let Some(v) = j.get("cloud_mbps").and_then(Json::as_f64) {
            self.cloud_mbps = v;
        }
        if let Some(v) = j.get("drafter_variant").and_then(Json::as_str) {
            self.drafter_variant = v.to_string();
        }
        if let Some(v) = j.get("target_variant").and_then(Json::as_str) {
            self.target_variant = v.to_string();
        }
        if let Some(v) = j.get("drafter").and_then(Json::as_str) {
            self.drafter = DrafterMode::parse(v)?;
        }
        if let Some(v) = j.get("trace_file").and_then(Json::as_str) {
            self.trace_file = Some(PathBuf::from(v));
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            self.seed = v as u64;
        }
        self.validate()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (1..=6).contains(&self.design_variant),
            "design_variant must be 1..=6 (CPU core count on the i.MX95)"
        );
        anyhow::ensure!(self.workers >= 1, "workers must be >= 1");
        anyhow::ensure!(self.max_new_limit >= 1, "max_new_limit must be >= 1");
        anyhow::ensure!(self.max_batch >= 1, "max_batch must be >= 1");
        anyhow::ensure!(self.max_inflight >= 1, "max_inflight must be >= 1");
        if let Some(g) = self.gamma {
            anyhow::ensure!((1..=8).contains(&g), "gamma must be 1..=8");
        }
        anyhow::ensure!(
            self.rate_limit_rps.is_finite() && self.rate_limit_rps >= 0.0,
            "rate_limit_rps must be finite and >= 0 (0 = unlimited)"
        );
        anyhow::ensure!(self.rate_limit_burst >= 1, "rate_limit_burst must be >= 1");
        anyhow::ensure!(self.client_queue_depth >= 1, "client_queue_depth must be >= 1");
        anyhow::ensure!(
            self.drain_deadline_s.is_finite() && self.drain_deadline_s >= 0.0,
            "drain_deadline_s must be finite and >= 0"
        );
        anyhow::ensure!(
            self.metrics_history_every_s.is_finite() && self.metrics_history_every_s > 0.0,
            "metrics_history_every_s must be finite and > 0"
        );
        anyhow::ensure!(
            self.cloud_rtt_ms.is_finite() && self.cloud_rtt_ms >= 0.0,
            "cloud_rtt_ms must be finite and >= 0"
        );
        anyhow::ensure!(
            self.cloud_mbps.is_finite() && self.cloud_mbps > 0.0,
            "cloud_mbps must be finite and > 0"
        );
        if let TreeChoice::Fixed(shape) = self.tree {
            anyhow::ensure!(
                (1..=4).contains(&shape.branching),
                "tree branching must be 1..=4, got {}",
                shape.branching
            );
            anyhow::ensure!(
                (1..=8).contains(&shape.depth),
                "tree depth must be 1..=8, got {}",
                shape.depth
            );
            anyhow::ensure!(
                shape.leaves() <= 64,
                "tree shape {} has {} leaves (> 64 verification lanes)",
                shape.label(),
                shape.leaves()
            );
        }
        self.variant_keys()?;
        Ok(())
    }

    /// Parse and role-check the configured (drafter, target) variant keys
    /// — the single validation the decision layer and `validate` share.
    pub fn variant_keys(
        &self,
    ) -> anyhow::Result<(crate::models::VariantKey, crate::models::VariantKey)> {
        let d = crate::models::VariantKey::parse(&self.drafter_variant)
            .map_err(|e| anyhow::anyhow!("drafter_variant: {e}"))?;
        anyhow::ensure!(
            d.role == crate::models::Role::Drafter,
            "drafter_variant must name a drafter_* variant, got {:?}",
            self.drafter_variant
        );
        let t = crate::models::VariantKey::parse(&self.target_variant)
            .map_err(|e| anyhow::anyhow!("target_variant: {e}"))?;
        anyhow::ensure!(
            t.role == crate::models::Role::Target,
            "target_variant must name a target_* variant, got {:?}",
            self.target_variant
        );
        Ok((d, t))
    }

    pub fn manifest_path(&self) -> PathBuf {
        self.artifacts_dir.join("manifest.json")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn json_overrides() {
        let mut c = RunConfig::default();
        let j = Json::parse(
            r#"{"exec_mode":"monolithic","gamma":3,"design_variant":2,
                "timing":"real","speculative":false,"max_batch":4,
                "max_inflight":8,"fuse":false}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.exec_mode, ExecMode::Monolithic);
        assert_eq!(c.gamma, Some(3));
        assert_eq!(c.design_variant, 2);
        assert_eq!(c.timing, Timing::Real);
        assert!(!c.speculative);
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.max_inflight, 8);
        assert!(!c.fuse);
    }

    #[test]
    fn fuse_defaults_on() {
        assert!(RunConfig::default().fuse);
    }

    #[test]
    fn max_new_limit_parses_and_validates() {
        assert_eq!(RunConfig::default().max_new_limit, 1024);
        let mut c = RunConfig::default();
        c.apply_json(&Json::parse(r#"{"max_new_limit":128}"#).unwrap()).unwrap();
        assert_eq!(c.max_new_limit, 128);
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"max_new_limit":0}"#).unwrap()).is_err());
    }

    #[test]
    fn hetero_overlap_defaults_on_and_parses() {
        assert!(RunConfig::default().hetero_overlap);
        let mut c = RunConfig::default();
        let j = Json::parse(r#"{"hetero_overlap":false}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert!(!c.hetero_overlap);
    }

    #[test]
    fn decision_defaults_analytic_and_parses() {
        let c = RunConfig::default();
        assert_eq!(c.decision, DecisionMode::Analytic);
        assert_eq!(c.drafter_variant, "drafter_fp");
        assert_eq!(c.target_variant, "target_w8a8");
        let mut c = RunConfig::default();
        let j = Json::parse(
            r#"{"decision":"calibrated","repartition_every":16,
                "drafter_variant":"drafter_w8a8","target_variant":"target_fp"}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.decision, DecisionMode::Calibrated);
        assert_eq!(c.repartition_every, 16);
        assert_eq!(c.drafter_variant, "drafter_w8a8");
        assert_eq!(c.target_variant, "target_fp");
        assert!(DecisionMode::parse("bogus").is_err());
    }

    #[test]
    fn swapped_variant_roles_rejected() {
        // A target_* key in the drafter slot (and vice versa) must fail
        // loudly at config validation, not at decode time.
        let mut c = RunConfig::default();
        let j = Json::parse(r#"{"drafter_variant":"target_w8a8"}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
        let mut c = RunConfig::default();
        let j = Json::parse(r#"{"target_variant":"drafter_fp"}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
        let mut c = RunConfig::default();
        let j = Json::parse(r#"{"target_variant":"nonsense"}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }

    #[test]
    fn tree_knob_defaults_off_and_parses() {
        assert_eq!(RunConfig::default().tree, TreeChoice::Off);
        let mut c = RunConfig::default();
        c.apply_json(&Json::parse(r#"{"tree":"auto"}"#).unwrap()).unwrap();
        assert_eq!(c.tree, TreeChoice::Auto);
        let mut c = RunConfig::default();
        c.apply_json(&Json::parse(r#"{"tree":"2x3"}"#).unwrap()).unwrap();
        assert_eq!(c.tree, TreeChoice::Fixed(TreeShape { branching: 2, depth: 3 }));
        assert_eq!(c.tree.label(), "2x3");
        assert_eq!(TreeChoice::parse("off").unwrap().label(), "off");
        assert!(TreeChoice::parse("sideways").is_err());
        // Bounds: branching 1..=4, depth 1..=8, ≤ 64 verification lanes.
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"tree":"5x2"}"#).unwrap()).is_err());
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"tree":"2x9"}"#).unwrap()).is_err());
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"tree":"4x4"}"#).unwrap()).is_err());
        // 1xD is the chain — legal, and normalized away at the session.
        let mut c = RunConfig::default();
        c.apply_json(&Json::parse(r#"{"tree":"1x5"}"#).unwrap()).unwrap();
        assert_eq!(c.tree, TreeChoice::Fixed(TreeShape { branching: 1, depth: 5 }));
    }

    #[test]
    fn kv_cache_knob_defaults_off_and_parses() {
        assert_eq!(RunConfig::default().kv_cache, KvCacheMode::Off);
        assert!(!RunConfig::default().kv_cache.enabled());
        let mut c = RunConfig::default();
        c.apply_json(&Json::parse(r#"{"kv_cache":"on"}"#).unwrap()).unwrap();
        assert_eq!(c.kv_cache, KvCacheMode::On);
        assert!(c.kv_cache.enabled());
        assert_eq!(c.kv_cache.as_str(), "on");
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"kv_cache":"paged"}"#).unwrap()).is_err());
    }

    #[test]
    fn fleet_knobs_default_and_parse() {
        let c = RunConfig::default();
        assert_eq!(c.fleet_file, None);
        assert_eq!(c.cloud_verify, CloudVerifyMode::Auto);
        assert!((c.cloud_rtt_ms - 20.0).abs() < 1e-12);
        assert!((c.cloud_mbps - 100.0).abs() < 1e-12);
        let mut c = RunConfig::default();
        let j = Json::parse(
            r#"{"fleet_file":"configs/fleet.json","cloud_verify":"cloud",
                "cloud_rtt_ms":5.5,"cloud_mbps":250}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.fleet_file, Some(PathBuf::from("configs/fleet.json")));
        assert_eq!(c.cloud_verify, CloudVerifyMode::Cloud);
        assert!((c.cloud_rtt_ms - 5.5).abs() < 1e-12);
        assert!((c.cloud_mbps - 250.0).abs() < 1e-12);
        assert_eq!(CloudVerifyMode::parse("auto").unwrap().as_str(), "auto");
        assert_eq!(CloudVerifyMode::parse("local").unwrap(), CloudVerifyMode::Local);
        assert_eq!(CloudVerifyMode::parse("off").unwrap(), CloudVerifyMode::Off);
        assert!(CloudVerifyMode::parse("remote").is_err());
        // Degenerate link parameters fail at config load, not mid-serve.
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"cloud_mbps":0}"#).unwrap()).is_err());
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"cloud_rtt_ms":-1}"#).unwrap()).is_err());
    }

    #[test]
    fn serve_mode_defaults_event_loop_and_parses() {
        let c = RunConfig::default();
        assert_eq!(c.serve_mode, ServeMode::EventLoop);
        assert_eq!(c.serve_mode.as_str(), "event_loop");
        let mut c = RunConfig::default();
        c.apply_json(&Json::parse(r#"{"serve_mode":"threaded"}"#).unwrap()).unwrap();
        assert_eq!(c.serve_mode, ServeMode::Threaded);
        assert_eq!(ServeMode::parse("event_loop").unwrap(), ServeMode::EventLoop);
        assert!(ServeMode::parse("async").is_err());
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"serve_mode":"epoll"}"#).unwrap()).is_err());
    }

    #[test]
    fn serving_shell_knobs_default_and_validate() {
        let c = RunConfig::default();
        assert!((c.rate_limit_rps - 0.0).abs() < 1e-12, "rate limit defaults off");
        assert_eq!(c.rate_limit_burst, 32);
        assert_eq!(c.client_queue_depth, 1024);
        assert!((c.drain_deadline_s - 30.0).abs() < 1e-12);
        assert_eq!(c.metrics_history_file, None);
        assert!((c.metrics_history_every_s - 5.0).abs() < 1e-12);
        let mut c = RunConfig::default();
        let j = Json::parse(
            r#"{"rate_limit_rps":100.5,"rate_limit_burst":8,"client_queue_depth":64,
                "drain_deadline_s":2.5,"metrics_history_file":"hist.jsonl",
                "metrics_history_every_s":1}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert!((c.rate_limit_rps - 100.5).abs() < 1e-12);
        assert_eq!(c.rate_limit_burst, 8);
        assert_eq!(c.client_queue_depth, 64);
        assert!((c.drain_deadline_s - 2.5).abs() < 1e-12);
        assert_eq!(c.metrics_history_file, Some(PathBuf::from("hist.jsonl")));
        assert!((c.metrics_history_every_s - 1.0).abs() < 1e-12);
        // Degenerate values fail at config load, not mid-serve.
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"rate_limit_rps":-1}"#).unwrap()).is_err());
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"rate_limit_burst":0}"#).unwrap()).is_err());
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"client_queue_depth":0}"#).unwrap()).is_err());
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"drain_deadline_s":-0.5}"#).unwrap()).is_err());
        let mut c = RunConfig::default();
        assert!(c
            .apply_json(&Json::parse(r#"{"metrics_history_every_s":0}"#).unwrap())
            .is_err());
    }

    #[test]
    fn drafter_knob_defaults_fixed_and_parses() {
        let c = RunConfig::default();
        assert_eq!(c.drafter, DrafterMode::Fixed);
        assert_eq!(c.drafter.as_str(), "fixed");
        assert_eq!(c.trace_file, None);
        let mut c = RunConfig::default();
        let j = Json::parse(r#"{"drafter":"auto","trace_file":"t.jsonl"}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.drafter, DrafterMode::Auto);
        assert_eq!(c.trace_file, Some(PathBuf::from("t.jsonl")));
        assert_eq!(DrafterMode::parse("fixed").unwrap(), DrafterMode::Fixed);
        assert!(DrafterMode::parse("adaptive").is_err());
        let mut c = RunConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"drafter":"both"}"#).unwrap()).is_err());
    }

    #[test]
    fn zero_inflight_rejected() {
        let mut c = RunConfig::default();
        let j = Json::parse(r#"{"max_inflight":0}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }

    #[test]
    fn invalid_variant_rejected() {
        let mut c = RunConfig::default();
        let j = Json::parse(r#"{"design_variant":9}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }

    #[test]
    fn invalid_mode_rejected() {
        let mut c = RunConfig::default();
        let j = Json::parse(r#"{"exec_mode":"fused"}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }
}
