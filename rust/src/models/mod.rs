//! Model-variant metadata: identity, quantization scheme, and the analytic
//! FLOPs model the heterogeneous latency simulator consumes (mirrors
//! `ModelConfig.flops_per_token` on the Python side).

use crate::util::json::Json;

/// Which model of the speculative pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Role {
    Target,
    Drafter,
}

impl Role {
    pub fn as_str(&self) -> &'static str {
        match self {
            Role::Target => "target",
            Role::Drafter => "drafter",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Role> {
        match s {
            "target" => Ok(Role::Target),
            "drafter" => Ok(Role::Drafter),
            _ => anyhow::bail!("unknown role {s:?}"),
        }
    }
}

/// Quantization scheme of a compiled variant (paper Fig. 5: FP, semi, full).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Scheme {
    Fp,
    W8a8,
}

impl Scheme {
    pub fn as_str(&self) -> &'static str {
        match self {
            Scheme::Fp => "fp",
            Scheme::W8a8 => "w8a8",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Scheme> {
        match s {
            "fp" => Ok(Scheme::Fp),
            "w8a8" => Ok(Scheme::W8a8),
            _ => anyhow::bail!("unknown scheme {s:?}"),
        }
    }
}

/// A (role, scheme) pair — the unit the runtime loads and the DSE maps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VariantKey {
    pub role: Role,
    pub scheme: Scheme,
}

impl VariantKey {
    pub fn new(role: Role, scheme: Scheme) -> VariantKey {
        VariantKey { role, scheme }
    }

    pub fn name(&self) -> String {
        format!("{}_{}", self.role.as_str(), self.scheme.as_str())
    }

    pub fn parse(s: &str) -> anyhow::Result<VariantKey> {
        let (r, q) = s
            .split_once('_')
            .ok_or_else(|| anyhow::anyhow!("bad variant key {s:?}"))?;
        Ok(VariantKey { role: Role::parse(r)?, scheme: Scheme::parse(q)? })
    }
}

/// Architecture description (from the manifest's `models` section).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub ffn_dim: usize,
    pub vocab: usize,
    pub param_count: usize,
}

impl ModelSpec {
    pub fn from_json(j: &Json) -> anyhow::Result<ModelSpec> {
        Ok(ModelSpec {
            name: j.req_str("name")?.to_string(),
            n_layers: j.req_usize("n_layers")?,
            d_model: j.req_usize("d_model")?,
            n_heads: j.req_usize("n_heads")?,
            ffn_dim: j.req_usize("ffn_dim")?,
            vocab: j.req_usize("vocab")?,
            param_count: j.req_usize("param_count")?,
        })
    }

    /// Forward FLOPs for one full-sequence pass (no KV cache, 2·MAC
    /// convention). Mirrors `ModelConfig.flops_per_token` in model.py —
    /// the analytic latency model in `hetero` consumes this.
    pub fn forward_flops(&self, seq_len: usize) -> f64 {
        let (d, f, l, v, s) = (
            self.d_model as f64,
            self.ffn_dim as f64,
            self.n_layers as f64,
            self.vocab as f64,
            seq_len as f64,
        );
        let linear = 2.0 * s * (4.0 * d * d + 3.0 * d * f) * l;
        let attn = 2.0 * s * s * d * 2.0 * l;
        let head = 2.0 * s * d * v;
        linear + attn + head
    }

    /// Fraction of FLOPs in linear layers at this seq length — the paper's
    /// §II-A observation (short sequences are linear-dominated) made
    /// quantitative; used in DESIGN.md §8 and the kernel perf analysis.
    pub fn linear_fraction(&self, seq_len: usize) -> f64 {
        let (d, f, l, s) = (
            self.d_model as f64,
            self.ffn_dim as f64,
            self.n_layers as f64,
            seq_len as f64,
        );
        let linear = 2.0 * s * (4.0 * d * d + 3.0 * d * f) * l;
        linear / self.forward_flops(seq_len)
    }

    /// Parameter bytes for a given scheme (w8a8 keeps norms/embeds fp32 but
    /// linears drop to 1 byte + per-channel scales).
    pub fn weight_bytes(&self, scheme: Scheme) -> usize {
        let linears = self.n_layers
            * (4 * self.d_model * self.d_model + 3 * self.d_model * self.ffn_dim);
        let rest = self.param_count - linears;
        match scheme {
            Scheme::Fp => self.param_count * 4,
            Scheme::W8a8 => linears + rest * 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn target() -> ModelSpec {
        ModelSpec {
            name: "target".into(),
            n_layers: 4,
            d_model: 128,
            n_heads: 4,
            ffn_dim: 352,
            vocab: 48,
            param_count: 816_256,
        }
    }

    #[test]
    fn variant_key_roundtrip() {
        let k = VariantKey::new(Role::Target, Scheme::W8a8);
        assert_eq!(k.name(), "target_w8a8");
        assert_eq!(VariantKey::parse("target_w8a8").unwrap(), k);
        assert!(VariantKey::parse("bogus").is_err());
    }

    #[test]
    fn flops_monotonic_in_seq() {
        let m = target();
        let f: Vec<f64> = [16, 32, 64, 128].iter().map(|&s| m.forward_flops(s)).collect();
        assert!(f.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn short_sequences_are_linear_dominated() {
        // Paper §II-A: S_L << d  =>  linear layers dominate.
        let m = target();
        assert!(m.linear_fraction(16) > 0.85);
        assert!(m.linear_fraction(63) > m.linear_fraction(128));
    }

    #[test]
    fn quant_weights_smaller() {
        let m = target();
        assert!(m.weight_bytes(Scheme::W8a8) < m.weight_bytes(Scheme::Fp) / 2);
    }
}
