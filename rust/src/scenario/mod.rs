//! Scenario subsystem: workload traces, request classes, arrival
//! processes, and resource-aware drafter selection.
//!
//! The decision layer (calibrated refit, online re-partitioning, tree
//! shapes, KV admission) was historically exercised by one synthetic
//! translate workload. This module is the layer between *traffic* and the
//! decision engine:
//!
//! * **[`WorkloadTrace`]** — a JSON-lines trace schema: one header line
//!   plus one [`TraceEntry`] per request (class, arrival time, task +
//!   sample draw, output-length draw, SLO class/deadline, α regime).
//!   Saving and re-loading a trace reproduces a run bit-for-bit
//!   ([`WorkloadTrace::to_jsonl`] / [`WorkloadTrace::from_jsonl`] are
//!   exact inverses, and [`materialize`] is a pure function of the
//!   trace + manifest).
//! * **[`ScenarioSpec`]** — seeded generators for
//!   chat/translate/summarize/code-complete class mixes
//!   ([`ClassMix`]) under Poisson, bursty, or diurnal arrivals
//!   ([`ArrivalProcess`]); [`builtin_scenarios`] ships the standard set
//!   the `scenarios` experiment sweeps.
//! * **[`RequestClass`]** — the four traffic classes, each owning a pool
//!   of the manifest's 13 eval tasks ([`RequestClass::task_pool`]); the
//!   inverse map [`RequestClass::for_task`] is how serving code tags
//!   per-class metrics and per-class decision state without carrying the
//!   class through every request type.
//! * **[`DrafterRegistry`]** — the manifest's `drafter_*` quantized
//!   variants as *candidate draft models* (self-drafting via
//!   quantization), with [`DrafterRegistry::select`] scoring every
//!   (drafter variant, mapping, γ/tree) candidate through the DSE at
//!   per-drafter α estimates — resource-aware drafter selection per
//!   request class.
//!
//! The int8 economics make drafter choice real: W8A8 linears run
//! *faster* on the A55 cores (dot-product extension) but are *promoted*
//! (slower) on the Mali GPU, so the quantized drafter is only ever
//! CPU-mapped and wins exactly where its cheaper forwards survive its
//! (class-dependent) acceptance-rate penalty.

use crate::api::SloClass;
use crate::costmodel::TreeShape;
use crate::decision::CostModel;
use crate::dse::{self, KvLoad, PairConfig};
use crate::models::{ModelSpec, Role, Scheme, VariantKey};
use crate::runtime::manifest::Manifest;
use crate::tokenizer::Tokenizer;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{prompt_ids, Request, Workload};
use std::collections::HashMap;
use std::path::Path;

// ---------------------------------------------------------------------------
// Request classes
// ---------------------------------------------------------------------------

/// Traffic class of one request — the unit per-class decision state and
/// per-class metrics are keyed by.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RequestClass {
    Chat,
    Translate,
    Summarize,
    CodeComplete,
}

/// Number of [`RequestClass`] variants (dense metrics arrays).
pub const NUM_CLASSES: usize = 4;

impl RequestClass {
    pub fn as_str(&self) -> &'static str {
        match self {
            RequestClass::Chat => "chat",
            RequestClass::Translate => "translate",
            RequestClass::Summarize => "summarize",
            RequestClass::CodeComplete => "code_complete",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<RequestClass> {
        match s {
            "chat" => Ok(RequestClass::Chat),
            "translate" => Ok(RequestClass::Translate),
            "summarize" => Ok(RequestClass::Summarize),
            "code_complete" => Ok(RequestClass::CodeComplete),
            _ => anyhow::bail!(
                "class must be chat|translate|summarize|code_complete, got {s:?}"
            ),
        }
    }

    /// Dense index (metrics arrays), declaration order.
    pub fn index(&self) -> usize {
        match self {
            RequestClass::Chat => 0,
            RequestClass::Translate => 1,
            RequestClass::Summarize => 2,
            RequestClass::CodeComplete => 3,
        }
    }

    /// All variants, in [`index`](Self::index) order.
    pub fn all() -> [RequestClass; NUM_CLASSES] {
        [
            RequestClass::Chat,
            RequestClass::Translate,
            RequestClass::Summarize,
            RequestClass::CodeComplete,
        ]
    }

    /// The eval tasks this class draws from — a partition of the
    /// manifest's 13 Spec-Bench-shaped tasks into traffic archetypes
    /// (echo-like tasks serve as "chat", transform-heavy ones as "code").
    pub fn task_pool(&self) -> &'static [&'static str] {
        match self {
            RequestClass::Chat => &["copy", "first-word", "last-word", "second-word"],
            RequestClass::Translate => &["translate", "translate-rev"],
            RequestClass::Summarize => &["initials", "word-lengths", "count-words"],
            RequestClass::CodeComplete => {
                &["cipher", "double", "swap-ends", "reverse-words"]
            }
        }
    }

    /// Inverse of [`task_pool`](Self::task_pool): the class a task belongs
    /// to (`None` for tasks outside the 13-task eval set).
    pub fn for_task(task: &str) -> Option<RequestClass> {
        RequestClass::all()
            .into_iter()
            .find(|c| c.task_pool().contains(&task))
    }
}

// ---------------------------------------------------------------------------
// Arrival processes
// ---------------------------------------------------------------------------

/// Open-loop arrival process of a scenario. All three are exponential
/// inter-arrival draws; bursty/diurnal modulate the instantaneous rate by
/// the current arrival time, so a trace's timestamps are reproducible
/// from (process, seed) alone.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Constant-rate Poisson at `rate` req/s — bit-identical to the
    /// historical [`Workload::with_poisson_arrivals`] stamps.
    Poisson { rate: f64 },
    /// Square-wave load: the first `burst_frac` of every `period_s`
    /// window arrives at `burst_rate`, the rest at `base_rate`.
    Bursty {
        base_rate: f64,
        burst_rate: f64,
        period_s: f64,
        burst_frac: f64,
    },
    /// Sinusoidal day/night load: rate swings `±amplitude` (relative)
    /// around `base_rate` over `period_s`.
    Diurnal {
        base_rate: f64,
        amplitude: f64,
        period_s: f64,
    },
}

impl ArrivalProcess {
    /// Instantaneous rate at arrival-clock time `t` (req/s, always > 0).
    pub fn rate_at(&self, t: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, period_s, burst_frac } => {
                let phase = (t / period_s).fract();
                if phase < burst_frac {
                    burst_rate
                } else {
                    base_rate
                }
            }
            ArrivalProcess::Diurnal { base_rate, amplitude, period_s } => {
                let phase = 2.0 * std::f64::consts::PI * t / period_s;
                (base_rate * (1.0 + amplitude * phase.sin())).max(0.05 * base_rate)
            }
        }
    }

    /// Draw the gap to the next arrival given the previous arrival at `t`.
    /// For `Poisson` this consumes exactly one `rng.exp(rate)` draw — the
    /// delegation contract [`Workload::with_arrivals`] relies on.
    pub fn next_gap(&self, rng: &mut Rng, t: f64) -> f64 {
        rng.exp(self.rate_at(t))
    }

    pub fn label(&self) -> String {
        match *self {
            ArrivalProcess::Poisson { rate } => format!("poisson@{rate}"),
            ArrivalProcess::Bursty { burst_rate, .. } => format!("bursty@{burst_rate}"),
            ArrivalProcess::Diurnal { base_rate, .. } => format!("diurnal@{base_rate}"),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        match *self {
            ArrivalProcess::Poisson { rate } => {
                j.set("kind", "poisson".into()).set("rate", rate.into());
            }
            ArrivalProcess::Bursty { base_rate, burst_rate, period_s, burst_frac } => {
                j.set("kind", "bursty".into())
                    .set("base_rate", base_rate.into())
                    .set("burst_rate", burst_rate.into())
                    .set("period_s", period_s.into())
                    .set("burst_frac", burst_frac.into());
            }
            ArrivalProcess::Diurnal { base_rate, amplitude, period_s } => {
                j.set("kind", "diurnal".into())
                    .set("base_rate", base_rate.into())
                    .set("amplitude", amplitude.into())
                    .set("period_s", period_s.into());
            }
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ArrivalProcess> {
        match j.req_str("kind")? {
            "poisson" => Ok(ArrivalProcess::Poisson { rate: j.req_f64("rate")? }),
            "bursty" => Ok(ArrivalProcess::Bursty {
                base_rate: j.req_f64("base_rate")?,
                burst_rate: j.req_f64("burst_rate")?,
                period_s: j.req_f64("period_s")?,
                burst_frac: j.req_f64("burst_frac")?,
            }),
            "diurnal" => Ok(ArrivalProcess::Diurnal {
                base_rate: j.req_f64("base_rate")?,
                amplitude: j.req_f64("amplitude")?,
                period_s: j.req_f64("period_s")?,
            }),
            other => anyhow::bail!("arrival kind must be poisson|bursty|diurnal, got {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Trace schema
// ---------------------------------------------------------------------------

/// One request of a workload trace. `task`/`sample` name the prompt draw
/// (resolved against the manifest's eval set at [`materialize`] time —
/// `sample` indexes the task's samples modulo their count, so a trace
/// replays against any artifact build that ships the task).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEntry {
    pub id: u64,
    pub class: RequestClass,
    pub task: String,
    /// Prompt draw: index into the task's eval samples (modulo count).
    pub sample: usize,
    /// Arrival offset within the run, seconds.
    pub arrival_s: f64,
    /// Output-length draw (the request's `max_new` budget).
    pub max_new: usize,
    pub slo: SloClass,
    /// Latency deadline in seconds (`None` = no deadline).
    pub deadline_s: Option<f64>,
    /// The class's true acceptance-rate regime for the fp drafter — the
    /// scenario simulator's ground truth (serving code never reads it).
    pub alpha_regime: f64,
}

impl TraceEntry {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("id", (self.id as usize).into())
            .set("class", self.class.as_str().into())
            .set("task", self.task.as_str().into())
            .set("sample", self.sample.into())
            .set("arrival_s", self.arrival_s.into())
            .set("max_new", self.max_new.into())
            .set("slo", self.slo.as_str().into())
            .set("alpha_regime", self.alpha_regime.into());
        if let Some(d) = self.deadline_s {
            j.set("deadline_ms", (d * 1e3).into());
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TraceEntry> {
        Ok(TraceEntry {
            id: j.req_usize("id")? as u64,
            class: RequestClass::parse(j.req_str("class")?)?,
            task: j.req_str("task")?.to_string(),
            sample: j.req_usize("sample")?,
            arrival_s: j.req_f64("arrival_s")?,
            max_new: j.req_usize("max_new")?,
            slo: SloClass::parse(j.req_str("slo")?)?,
            deadline_s: j.get("deadline_ms").and_then(Json::as_f64).map(|ms| ms / 1e3),
            alpha_regime: j.req_f64("alpha_regime")?,
        })
    }
}

/// A generated (or loaded) workload trace: a header plus one
/// [`TraceEntry`] per request, serialized as JSON lines.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadTrace {
    pub name: String,
    /// The generator seed (diagnostic — replay never re-draws).
    pub seed: u64,
    pub entries: Vec<TraceEntry>,
}

impl WorkloadTrace {
    /// Serialize as JSON lines: a header line then one entry per line.
    /// Deterministic (object keys are ordered), so equal traces always
    /// serialize to identical bytes.
    pub fn to_jsonl(&self) -> String {
        let mut header = Json::obj();
        header
            .set("kind", "specedge-trace".into())
            .set("version", 1usize.into())
            .set("name", self.name.as_str().into())
            .set("seed", (self.seed as usize).into())
            .set("requests", self.entries.len().into());
        let mut out = header.to_string();
        out.push('\n');
        for e in &self.entries {
            out.push_str(&e.to_json().to_string());
            out.push('\n');
        }
        out
    }

    pub fn from_jsonl(text: &str) -> anyhow::Result<WorkloadTrace> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header_line = lines.next().ok_or_else(|| anyhow::anyhow!("empty trace"))?;
        let header =
            Json::parse(header_line).map_err(|e| anyhow::anyhow!("trace header: {e}"))?;
        anyhow::ensure!(
            header.req_str("kind")? == "specedge-trace",
            "not a specedge trace (kind mismatch)"
        );
        anyhow::ensure!(
            header.req_usize("version")? == 1,
            "unsupported trace version"
        );
        let mut entries = Vec::new();
        for (i, line) in lines.enumerate() {
            let j = Json::parse(line).map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 2))?;
            entries.push(TraceEntry::from_json(&j)?);
        }
        anyhow::ensure!(
            entries.len() == header.req_usize("requests")?,
            "trace header declares {} requests, found {}",
            header.req_usize("requests")?,
            entries.len()
        );
        Ok(WorkloadTrace {
            name: header.req_str("name")?.to_string(),
            seed: header.req_usize("seed")? as u64,
            entries,
        })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_jsonl())
            .map_err(|e| anyhow::anyhow!("writing trace {path:?}: {e}"))
    }

    pub fn load(path: &Path) -> anyhow::Result<WorkloadTrace> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading trace {path:?}: {e}"))?;
        WorkloadTrace::from_jsonl(&text)
    }

    /// Requests per class, dense-indexed.
    pub fn class_counts(&self) -> [usize; NUM_CLASSES] {
        let mut counts = [0usize; NUM_CLASSES];
        for e in &self.entries {
            counts[e.class.index()] += 1;
        }
        counts
    }

    /// Distinct classes present in the trace.
    pub fn class_count(&self) -> usize {
        self.class_counts().iter().filter(|&&c| c > 0).count()
    }
}

// ---------------------------------------------------------------------------
// Scenario generators
// ---------------------------------------------------------------------------

/// One class's share of a scenario's traffic plus its request-shape
/// distribution.
#[derive(Debug, Clone)]
pub struct ClassMix {
    pub class: RequestClass,
    /// Relative traffic weight (normalized over the scenario's mix).
    pub weight: f64,
    /// True fp-drafter acceptance rate of this class (the α regime the
    /// scenario simulator decodes under).
    pub alpha: f64,
    /// Output-length draw bounds, inclusive.
    pub max_new: (usize, usize),
    pub slo: SloClass,
    pub deadline_s: Option<f64>,
}

/// A seeded scenario: class mix × arrival process → [`WorkloadTrace`].
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub seed: u64,
    pub requests: usize,
    pub arrivals: ArrivalProcess,
    pub mix: Vec<ClassMix>,
}

impl ScenarioSpec {
    /// Generate the trace. Same spec (including seed) ⇒ identical trace;
    /// every random draw comes from one seeded stream in entry order.
    pub fn generate(&self) -> WorkloadTrace {
        let mut rng = Rng::new(self.seed);
        let total: f64 = self.mix.iter().map(|m| m.weight).sum();
        let mut entries = Vec::with_capacity(self.requests);
        let mut t = 0.0;
        for id in 0..self.requests {
            t += self.arrivals.next_gap(&mut rng, t);
            let mut pick = rng.f64() * total;
            let mut chosen = &self.mix[0];
            for m in &self.mix {
                if pick < m.weight {
                    chosen = m;
                    break;
                }
                pick -= m.weight;
            }
            let task = *rng.choose(chosen.class.task_pool());
            let sample = rng.below(1 << 20);
            let (lo, hi) = chosen.max_new;
            let max_new = rng.range(lo as i64, hi as i64) as usize;
            entries.push(TraceEntry {
                id: id as u64,
                class: chosen.class,
                task: task.to_string(),
                sample,
                arrival_s: t,
                max_new,
                slo: chosen.slo,
                deadline_s: chosen.deadline_s,
                alpha_regime: chosen.alpha,
            });
        }
        WorkloadTrace { name: self.name.clone(), seed: self.seed, entries }
    }
}

/// The standard scenario set the `scenarios` experiment sweeps: a
/// single-class parity scenario (pinned bit-identical to the pre-scenario
/// behavior under `drafter: fixed`), plus three mixed-traffic scenarios
/// where per-class α regimes pull the decision layer in different
/// directions per class.
pub fn builtin_scenarios(requests: usize, seed: u64) -> Vec<ScenarioSpec> {
    let interactive = |class, weight, alpha, lo, hi| ClassMix {
        class,
        weight,
        alpha,
        max_new: (lo, hi),
        slo: SloClass::Interactive,
        deadline_s: None,
    };
    let batch = |class, weight, alpha, lo, hi| ClassMix {
        class,
        weight,
        alpha,
        max_new: (lo, hi),
        slo: SloClass::Batch,
        deadline_s: None,
    };
    vec![
        // The parity anchor: one class, constant-rate Poisson — exactly
        // the historical translate workload shape.
        ScenarioSpec {
            name: "translate_poisson".into(),
            seed,
            requests,
            arrivals: ArrivalProcess::Poisson { rate: 8.0 },
            mix: vec![interactive(RequestClass::Translate, 1.0, 0.90, 24, 48)],
        },
        // Chat-dominated bursts: two well-drafted classes plus a
        // low-α summarize tail that should fall back per class.
        ScenarioSpec {
            name: "chat_bursty".into(),
            seed: seed ^ 0x1,
            requests,
            arrivals: ArrivalProcess::Bursty {
                base_rate: 4.0,
                burst_rate: 24.0,
                period_s: 10.0,
                burst_frac: 0.3,
            },
            mix: vec![
                interactive(RequestClass::Chat, 0.55, 0.93, 8, 24),
                interactive(RequestClass::Translate, 0.25, 0.88, 24, 48),
                batch(RequestClass::Summarize, 0.20, 0.40, 32, 64),
            ],
        },
        // All four classes under a day/night swing — the broadest
        // per-class divergence surface.
        ScenarioSpec {
            name: "mixed_diurnal".into(),
            seed: seed ^ 0x2,
            requests,
            arrivals: ArrivalProcess::Diurnal {
                base_rate: 8.0,
                amplitude: 0.7,
                period_s: 60.0,
            },
            mix: vec![
                interactive(RequestClass::Chat, 0.30, 0.92, 8, 24),
                interactive(RequestClass::Translate, 0.30, 0.90, 24, 48),
                batch(RequestClass::Summarize, 0.20, 0.45, 32, 64),
                batch(RequestClass::CodeComplete, 0.20, 0.70, 16, 48),
            ],
        },
        // Code-heavy steady load: a mid-α class where drafter choice
        // (cheap quantized forwards vs higher fp acceptance) matters.
        ScenarioSpec {
            name: "code_poisson".into(),
            seed: seed ^ 0x3,
            requests,
            arrivals: ArrivalProcess::Poisson { rate: 12.0 },
            mix: vec![
                batch(RequestClass::CodeComplete, 0.60, 0.72, 16, 48),
                interactive(RequestClass::Chat, 0.40, 0.92, 8, 24),
            ],
        },
    ]
}

// ---------------------------------------------------------------------------
// Trace replay
// ---------------------------------------------------------------------------

/// Resolve a trace against the manifest's eval set: each entry's
/// (task, sample) draw becomes a [`Request`] with the entry's arrival
/// stamp and class tag. Pure — the same (trace, manifest) always yields
/// identical prompts, which is what makes saved traces replay
/// bit-for-bit.
pub fn materialize(
    trace: &WorkloadTrace,
    manifest: &Manifest,
    tokenizer: &Tokenizer,
) -> anyhow::Result<Workload> {
    let by_task = samples_by_task(manifest);
    let mut requests = Vec::with_capacity(trace.entries.len());
    for e in &trace.entries {
        let pool = by_task
            .get(e.task.as_str())
            .ok_or_else(|| anyhow::anyhow!("trace task {:?} has no eval samples", e.task))?;
        let s = &manifest.eval_samples[pool[e.sample % pool.len()]];
        requests.push(Request {
            id: e.id,
            task: e.task.clone(),
            prompt: prompt_ids(tokenizer, s)?,
            truth: s.completion.clone(),
            arrival_s: e.arrival_s,
            class: Some(e.class),
        });
    }
    anyhow::ensure!(!requests.is_empty(), "trace has no entries");
    Ok(Workload { requests })
}

/// One loadgen call resolved from a trace entry: the prompt *text* (the
/// wire carries text, not token ids) plus the entry's arrival stamp and
/// request options.
#[derive(Debug, Clone)]
pub struct ScheduledCall {
    pub arrival_s: f64,
    pub task: String,
    pub prompt: String,
    pub max_new: usize,
    pub slo: SloClass,
    pub deadline_s: Option<f64>,
}

/// Resolve a trace into the loadgen's wire-level schedule (same sample
/// resolution as [`materialize`], but keeping prompt text).
pub fn trace_schedule(
    trace: &WorkloadTrace,
    manifest: &Manifest,
) -> anyhow::Result<Vec<ScheduledCall>> {
    let by_task = samples_by_task(manifest);
    trace
        .entries
        .iter()
        .map(|e| {
            let pool = by_task
                .get(e.task.as_str())
                .ok_or_else(|| anyhow::anyhow!("trace task {:?} has no eval samples", e.task))?;
            let s = &manifest.eval_samples[pool[e.sample % pool.len()]];
            Ok(ScheduledCall {
                arrival_s: e.arrival_s,
                task: e.task.clone(),
                prompt: s.prompt.clone(),
                max_new: e.max_new,
                slo: e.slo,
                deadline_s: e.deadline_s,
            })
        })
        .collect()
}

fn samples_by_task(manifest: &Manifest) -> HashMap<&str, Vec<usize>> {
    let mut by_task: HashMap<&str, Vec<usize>> = HashMap::new();
    for (i, s) in manifest.eval_samples.iter().enumerate() {
        by_task.entry(s.task.as_str()).or_default().push(i);
    }
    by_task
}

// ---------------------------------------------------------------------------
// Drafter registry
// ---------------------------------------------------------------------------

/// One candidate draft model: a `drafter_*` variant from the manifest.
#[derive(Debug, Clone)]
pub struct DrafterCandidate {
    pub key: VariantKey,
    pub spec: ModelSpec,
}

/// A drafter variant chosen for a (class, operating point), with the DSE
/// candidate that won it the slot.
#[derive(Debug, Clone)]
pub struct DrafterChoice {
    pub key: VariantKey,
    pub decision: dse::Candidate,
}

/// The manifest's drafter variants as selectable draft models.
///
/// The compile pipeline lowers every (role, scheme) variant — the
/// `quant_matmul` kernels exercised by `examples/quant_ablation.rs` give
/// the same drafter architecture a second, cheaper-on-CPU body. This
/// registry is the single enumeration path over those variants: the
/// ablation example lists pairings through it, and the decision layer
/// scores its candidates per request class through
/// [`select`](Self::select).
#[derive(Debug, Clone)]
pub struct DrafterRegistry {
    candidates: Vec<DrafterCandidate>,
}

impl DrafterRegistry {
    /// Every `drafter_*` variant present in the manifest, role-checked
    /// and resolved to its architecture spec, sorted by key for
    /// deterministic iteration. Errors when the manifest ships none.
    pub fn from_manifest(manifest: &Manifest) -> anyhow::Result<DrafterRegistry> {
        let mut keys: Vec<VariantKey> = manifest
            .variants
            .keys()
            .filter(|k| k.role == Role::Drafter)
            .copied()
            .collect();
        keys.sort();
        let candidates = keys
            .into_iter()
            .map(|key| {
                Ok(DrafterCandidate { key, spec: manifest.model_for(key)?.clone() })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(
            !candidates.is_empty(),
            "manifest has no drafter_* variants to register"
        );
        Ok(DrafterRegistry { candidates })
    }

    pub fn candidates(&self) -> &[DrafterCandidate] {
        &self.candidates
    }

    pub fn contains(&self, key: VariantKey) -> bool {
        self.candidates.iter().any(|c| c.key == key)
    }

    /// All (drafter, target) variant pairings the manifest can actually
    /// run, sorted — the quantization-ablation grid (fp/fp, semi, full).
    pub fn pairings(&self, manifest: &Manifest) -> Vec<(VariantKey, VariantKey)> {
        let mut targets: Vec<VariantKey> = manifest
            .variants
            .keys()
            .filter(|k| k.role == Role::Target)
            .copied()
            .collect();
        targets.sort();
        let mut out = Vec::new();
        for d in &self.candidates {
            for &t in &targets {
                out.push((d.key, t));
            }
        }
        out
    }

    /// Score every (drafter variant, mapping, γ/tree) candidate for one
    /// operating point and return the best. `alpha_for` supplies the
    /// *per-drafter* α estimate (quantized drafters typically accept
    /// less); every drafter is scored against the same non-speculative
    /// target baseline, so speedups compare fairly across variants. Ties
    /// (e.g. nothing speculates anywhere) break toward the first
    /// registered candidate — `drafter_fp`, the historical default.
    #[allow(clippy::too_many_arguments)]
    pub fn select<M: CostModel + ?Sized>(
        &self,
        model: &M,
        target: &ModelSpec,
        target_scheme: Scheme,
        design_variant: usize,
        seq_len: usize,
        shapes: &[TreeShape],
        kv: Option<&KvLoad>,
        alpha_for: &dyn Fn(VariantKey) -> f64,
    ) -> DrafterChoice {
        let mut best: Option<DrafterChoice> = None;
        for cand in &self.candidates {
            let pair = PairConfig {
                target: target.clone(),
                target_scheme,
                drafter: cand.spec.clone(),
                drafter_scheme: cand.key.scheme,
            };
            let alpha = alpha_for(cand.key);
            let d = dse::explore_variant_with_shapes_kv(
                model,
                &pair,
                design_variant,
                alpha,
                seq_len,
                shapes,
                kv,
            );
            let better = match &best {
                None => true,
                Some(b) => d.best.speedup > b.decision.speedup + 1e-9,
            };
            if better {
                best = Some(DrafterChoice { key: cand.key, decision: d.best });
            }
        }
        best.expect("registry is never empty")
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::{LatencyModel, Platform};

    fn mini_manifest() -> Manifest {
        let j = Json::parse(
            r#"{
          "tokenizer": {"specials":["<pad>","<bos>","<eos>","="],
                        "chars":" abcdefghijklmnopqrstuvwxyz.,?!-0123456789:'",
                        "vocab_size":48},
          "seq_buckets": [128], "batch_sizes": [1],
          "models": {
            "target": {"name":"target","n_layers":4,"d_model":128,"n_heads":4,
                       "ffn_dim":352,"vocab":48,"param_count":816256},
            "drafter": {"name":"drafter","n_layers":2,"d_model":96,"n_heads":4,
                        "ffn_dim":256,"vocab":48,"param_count":230880}
          },
          "variants": {
            "drafter_fp": {"role":"drafter","scheme":"fp","model":"drafter",
              "weights":"w_dfp.bin","tensors":[],"artifacts":[]},
            "drafter_w8a8": {"role":"drafter","scheme":"w8a8","model":"drafter",
              "weights":"w_dq.bin","tensors":[],"artifacts":[]},
            "target_w8a8": {"role":"target","scheme":"w8a8","model":"target",
              "weights":"w_tq.bin","tensors":[],"artifacts":[]}
          },
          "monolithic": [],
          "eval_samples": [
            {"task":"translate","prompt":"tr: abc","completion":"hij"},
            {"task":"translate","prompt":"tr: de","completion":"kl"},
            {"task":"copy","prompt":"cp: abc","completion":"abc"},
            {"task":"cipher","prompt":"ci: ab","completion":"bc"},
            {"task":"initials","prompt":"in: a b","completion":"ab"},
            {"task":"first-word","prompt":"fw: x y","completion":"x"}
          ]}"#,
        )
        .unwrap();
        Manifest::from_json(Path::new("/tmp"), &j).unwrap()
    }

    fn mini_scenario() -> ScenarioSpec {
        ScenarioSpec {
            name: "t".into(),
            seed: 7,
            requests: 40,
            arrivals: ArrivalProcess::Poisson { rate: 10.0 },
            mix: vec![
                ClassMix {
                    class: RequestClass::Translate,
                    weight: 0.6,
                    alpha: 0.9,
                    max_new: (8, 16),
                    slo: SloClass::Interactive,
                    deadline_s: None,
                },
                ClassMix {
                    class: RequestClass::Chat,
                    weight: 0.4,
                    alpha: 0.8,
                    max_new: (4, 8),
                    slo: SloClass::Batch,
                    deadline_s: Some(0.25),
                },
            ],
        }
    }

    #[test]
    fn classes_partition_the_13_tasks() {
        let mut seen = std::collections::HashSet::new();
        for c in RequestClass::all() {
            for t in c.task_pool() {
                assert!(seen.insert(*t), "task {t} in two pools");
                assert_eq!(RequestClass::for_task(t), Some(c));
            }
        }
        assert_eq!(seen.len(), 13);
        assert_eq!(RequestClass::for_task("nope"), None);
        // Dense indices are a bijection.
        for (i, c) in RequestClass::all().into_iter().enumerate() {
            assert_eq!(c.index(), i);
            assert_eq!(RequestClass::parse(c.as_str()).unwrap(), c);
        }
        assert!(RequestClass::parse("gardening").is_err());
    }

    #[test]
    fn generator_is_seed_deterministic() {
        let spec = mini_scenario();
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a, b);
        assert_eq!(a.to_jsonl(), b.to_jsonl());
        // A different seed moves at least the arrival stamps.
        let other = ScenarioSpec { seed: 8, ..mini_scenario() }.generate();
        assert_ne!(a, other);
    }

    #[test]
    fn jsonl_roundtrip_is_exact() {
        let trace = mini_scenario().generate();
        let text = trace.to_jsonl();
        let back = WorkloadTrace::from_jsonl(&text).unwrap();
        assert_eq!(back, trace);
        // Serialization is a fixed point: save → load → save is identical.
        assert_eq!(back.to_jsonl(), text);
    }

    #[test]
    fn truncated_or_foreign_traces_rejected() {
        assert!(WorkloadTrace::from_jsonl("").is_err());
        assert!(WorkloadTrace::from_jsonl("{\"kind\":\"other\"}").is_err());
        let trace = mini_scenario().generate();
        let text = trace.to_jsonl();
        // Drop the last entry line: the header count no longer matches.
        let cut = &text[..text.trim_end().rfind('\n').unwrap() + 1];
        assert!(WorkloadTrace::from_jsonl(cut).is_err());
    }

    #[test]
    fn poisson_trace_arrivals_match_workload_stamps() {
        // The delegation contract: ArrivalProcess::Poisson consumes the
        // RNG exactly like the historical with_poisson_arrivals loop.
        let mut rng = Rng::new(42);
        let p = ArrivalProcess::Poisson { rate: 10.0 };
        let mut t = 0.0;
        let stamped: Vec<f64> = (0..20)
            .map(|_| {
                t += p.next_gap(&mut rng, t);
                t
            })
            .collect();
        let mut rng2 = Rng::new(42);
        let mut t2 = 0.0;
        let legacy: Vec<f64> = (0..20)
            .map(|_| {
                t2 += rng2.exp(10.0);
                t2
            })
            .collect();
        assert_eq!(
            stamped.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            legacy.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn arrivals_increase_under_every_process() {
        for arrivals in [
            ArrivalProcess::Poisson { rate: 10.0 },
            ArrivalProcess::Bursty {
                base_rate: 2.0,
                burst_rate: 40.0,
                period_s: 5.0,
                burst_frac: 0.25,
            },
            ArrivalProcess::Diurnal { base_rate: 8.0, amplitude: 0.9, period_s: 30.0 },
        ] {
            let spec = ScenarioSpec { arrivals, ..mini_scenario() };
            let trace = spec.generate();
            let a: Vec<f64> = trace.entries.iter().map(|e| e.arrival_s).collect();
            assert!(a.windows(2).all(|w| w[1] > w[0]), "{arrivals:?}");
            assert!(a[0] > 0.0);
            // Arrival-process JSON roundtrips.
            assert_eq!(ArrivalProcess::from_json(&arrivals.to_json()).unwrap(), arrivals);
        }
        assert!(ArrivalProcess::from_json(&Json::parse(r#"{"kind":"x"}"#).unwrap()).is_err());
    }

    #[test]
    fn bursty_rate_follows_the_square_wave() {
        let p = ArrivalProcess::Bursty {
            base_rate: 2.0,
            burst_rate: 20.0,
            period_s: 10.0,
            burst_frac: 0.3,
        };
        assert_eq!(p.rate_at(0.0), 20.0);
        assert_eq!(p.rate_at(2.9), 20.0);
        assert_eq!(p.rate_at(3.1), 2.0);
        assert_eq!(p.rate_at(13.1), 2.0);
        let d = ArrivalProcess::Diurnal { base_rate: 8.0, amplitude: 0.5, period_s: 60.0 };
        assert!(d.rate_at(15.0) > 8.0); // sin peak
        assert!(d.rate_at(45.0) < 8.0); // sin trough
        assert!(d.rate_at(45.0) > 0.0);
    }

    #[test]
    fn materialize_replays_bit_for_bit() {
        let m = mini_manifest();
        let tok = Tokenizer::builtin();
        let trace = mini_scenario().generate();
        let w1 = materialize(&trace, &m, &tok).unwrap();
        let reloaded = WorkloadTrace::from_jsonl(&trace.to_jsonl()).unwrap();
        let w2 = materialize(&reloaded, &m, &tok).unwrap();
        assert_eq!(w1.requests.len(), w2.requests.len());
        for (a, b) in w1.requests.iter().zip(&w2.requests) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.task, b.task);
            assert_eq!(a.class, b.class);
            assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
        }
        // Class tags survive materialization.
        assert!(w1.requests.iter().all(|r| r.class == RequestClass::for_task(&r.task)));
    }

    #[test]
    fn materialize_rejects_tasks_without_samples() {
        let m = mini_manifest();
        let tok = Tokenizer::builtin();
        let mut trace = mini_scenario().generate();
        trace.entries[0].task = "swap-ends".into(); // not in the mini set
        assert!(materialize(&trace, &m, &tok).is_err());
        assert!(trace_schedule(&trace, &m).is_err());
    }

    #[test]
    fn trace_schedule_carries_options() {
        let m = mini_manifest();
        let trace = mini_scenario().generate();
        let sched = trace_schedule(&trace, &m).unwrap();
        assert_eq!(sched.len(), trace.entries.len());
        for (c, e) in sched.iter().zip(&trace.entries) {
            assert_eq!(c.arrival_s.to_bits(), e.arrival_s.to_bits());
            assert_eq!(c.task, e.task);
            assert_eq!(c.max_new, e.max_new);
            assert_eq!(c.slo, e.slo);
            assert_eq!(c.deadline_s, e.deadline_s);
            assert!(!c.prompt.is_empty());
        }
    }

    #[test]
    fn builtin_scenarios_cover_single_and_mixed_class() {
        let scenarios = builtin_scenarios(60, 0xC0FFEE);
        assert!(scenarios.len() >= 4);
        let traces: Vec<WorkloadTrace> = scenarios.iter().map(|s| s.generate()).collect();
        // One single-class parity scenario, and at least one with 3+.
        assert!(traces.iter().any(|t| t.class_count() == 1));
        assert!(traces.iter().any(|t| t.class_count() >= 3));
        for t in &traces {
            assert_eq!(t.entries.len(), 60);
        }
        // All three arrival processes are exercised.
        let kinds: std::collections::HashSet<String> = scenarios
            .iter()
            .map(|s| s.arrivals.to_json().req_str("kind").unwrap().to_string())
            .collect();
        assert_eq!(kinds.len(), 3);
    }

    #[test]
    fn registry_enumerates_sorted_drafters() {
        let m = mini_manifest();
        let reg = DrafterRegistry::from_manifest(&m).unwrap();
        let keys: Vec<String> = reg.candidates().iter().map(|c| c.key.name()).collect();
        assert_eq!(keys, vec!["drafter_fp", "drafter_w8a8"]);
        assert!(reg.contains(VariantKey::parse("drafter_fp").unwrap()));
        assert!(!reg.contains(VariantKey::parse("target_w8a8").unwrap()));
        let pairings = reg.pairings(&m);
        assert_eq!(pairings.len(), 2); // 2 drafters × 1 target
        assert!(pairings.iter().all(|(d, t)| {
            d.role == Role::Drafter && t.role == Role::Target
        }));
    }

    #[test]
    fn registry_requires_a_drafter() {
        let j = Json::parse(
            r#"{
          "tokenizer": {"specials":["<pad>"],"chars":"ab","vocab_size":3},
          "seq_buckets": [16], "batch_sizes": [1],
          "models": {}, "variants": {}, "monolithic": [], "eval_samples": []}"#,
        )
        .unwrap();
        let m = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        assert!(DrafterRegistry::from_manifest(&m).is_err());
    }

    #[test]
    fn select_follows_the_per_drafter_alpha() {
        let m = mini_manifest();
        let reg = DrafterRegistry::from_manifest(&m).unwrap();
        let lat = LatencyModel::new(Platform::imx95());
        let target = m.model_for(VariantKey::parse("target_w8a8").unwrap()).unwrap();
        let fp = VariantKey::parse("drafter_fp").unwrap();
        let q = VariantKey::parse("drafter_w8a8").unwrap();
        // fp drafts well, the quantized drafter is useless → fp wins.
        let pick_fp = reg.select(
            &lat, target, Scheme::W8a8, 1, 63, &[], None,
            &|k| if k == fp { 0.90 } else { 0.05 },
        );
        assert_eq!(pick_fp.key, fp);
        assert!(pick_fp.decision.speculates());
        // Reversed regime → the quantized drafter wins the slot, and its
        // mapping never lands the w8a8 body on the GPU.
        let pick_q = reg.select(
            &lat, target, Scheme::W8a8, 1, 63, &[], None,
            &|k| if k == q { 0.90 } else { 0.05 },
        );
        assert_eq!(pick_q.key, q);
        assert!(pick_q.decision.speculates());
        assert!(!pick_q.decision.mapping.drafter.is_gpu());
        // Nothing drafts anywhere → tie at speedup 1.0 → the historical
        // default (first registered, drafter_fp) keeps the slot.
        let pick_none = reg.select(
            &lat, target, Scheme::W8a8, 1, 63, &[], None, &|_| 0.05,
        );
        assert_eq!(pick_none.key, fp);
        assert!(!pick_none.decision.speculates());
    }

    #[test]
    fn select_respects_kv_feasibility() {
        let m = mini_manifest();
        let reg = DrafterRegistry::from_manifest(&m).unwrap();
        let mut plat = Platform::imx95();
        plat.memory.kv_pages_cpu = 1;
        plat.memory.kv_pages_gpu = 1;
        let lat = LatencyModel::new(plat);
        let target = m.model_for(VariantKey::parse("target_w8a8").unwrap()).unwrap();
        let kv = KvLoad { inflight: 8, budget_tokens: 128 };
        // Starved pools: no drafter can field a feasible mapping, so the
        // choice must fall back to the non-speculative default.
        let pick = reg.select(
            &lat, target, Scheme::W8a8, 1, 63, &[], Some(&kv), &|_| 0.95,
        );
        assert!(!pick.decision.speculates());
    }
}
