//! Mini-criterion: a small benchmark harness for the `cargo bench` targets
//! (criterion itself is unavailable offline — DESIGN.md §1).
//!
//! Usage in a `harness = false` bench target:
//!
//! ```ignore
//! let mut b = Bench::new("costmodel");
//! b.bench("optimal_gamma", || { costmodel::optimal_gamma(0.9, 0.358); });
//! b.finish();
//! ```
//!
//! Each benchmark warms up, then runs timed batches until a time budget is
//! spent, reporting mean / p50 / p95 per iteration and writing a CSV next to
//! the results dir if `SPECEDGE_BENCH_OUT` is set.
//!
//! Two environment switches serve CI:
//! * `SPECEDGE_BENCH_SMOKE=1` — clamp warmup/measure budgets so every
//!   target finishes in seconds (the per-PR perf-trajectory smoke job);
//! * `SPECEDGE_BENCH_JSON=path` — append one JSON object per result to
//!   `path` (JSON lines; the CI job wraps them into `BENCH_pr.json`).

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

pub struct BenchOpts {
    pub warmup: Duration,
    pub measure: Duration,
    /// Upper bound on iterations (useful for very slow end-to-end benches).
    pub max_iters: u64,
    pub min_iters: u64,
}

impl Default for BenchOpts {
    fn default() -> BenchOpts {
        BenchOpts {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_iters: 1_000_000,
            min_iters: 5,
        }
    }
}

impl BenchOpts {
    /// Smoke mode (`SPECEDGE_BENCH_SMOKE=1`): clamp the budgets — numbers
    /// stay directionally useful while the whole suite finishes fast.
    fn clamp_for_smoke(mut self) -> BenchOpts {
        if std::env::var_os("SPECEDGE_BENCH_SMOKE").is_some() {
            self.warmup = self.warmup.min(Duration::from_millis(20));
            self.measure = self.measure.min(Duration::from_millis(200));
            self.max_iters = self.max_iters.min(10_000);
            self.min_iters = self.min_iters.min(3);
        }
        self
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub group: String,
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

pub struct Bench {
    group: String,
    opts: BenchOpts,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        Bench::with_opts(group, BenchOpts::default())
    }

    pub fn with_opts(group: &str, opts: BenchOpts) -> Bench {
        Bench {
            group: group.to_string(),
            opts: opts.clamp_for_smoke(),
            results: Vec::new(),
        }
    }

    /// Time `f` (called once per iteration).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup.
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.opts.warmup && warm_iters < self.opts.max_iters {
            f();
            warm_iters += 1;
        }
        // Measure.
        let mut lat = Summary::new();
        let m0 = Instant::now();
        let mut iters = 0u64;
        while (m0.elapsed() < self.opts.measure && iters < self.opts.max_iters)
            || iters < self.opts.min_iters
        {
            let t0 = Instant::now();
            f();
            lat.push(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        let r = BenchResult {
            group: self.group.clone(),
            name: name.to_string(),
            iters,
            mean_s: lat.mean(),
            p50_s: lat.percentile(50.0),
            p95_s: lat.percentile(95.0),
        };
        println!(
            "{:<40} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            format!("{}/{}", r.group, r.name),
            r.iters,
            fmt_time(r.mean_s),
            fmt_time(r.p50_s),
            fmt_time(r.p95_s),
        );
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Print the footer and optionally dump CSV (SPECEDGE_BENCH_OUT=dir)
    /// and/or JSON lines (SPECEDGE_BENCH_JSON=file, appended so the CI
    /// smoke job can collect every bench target into one report).
    pub fn finish(self) {
        if let Ok(path) = std::env::var("SPECEDGE_BENCH_JSON") {
            use std::io::Write;
            let mut lines = String::new();
            for r in &self.results {
                lines.push_str(&format!(
                    r#"{{"group":"{}","name":"{}","iters":{},"mean_s":{:.9},"p50_s":{:.9},"p95_s":{:.9}}}"#,
                    r.group, r.name, r.iters, r.mean_s, r.p50_s, r.p95_s
                ));
                lines.push('\n');
            }
            if let Ok(mut f) = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
            {
                let _ = f.write_all(lines.as_bytes());
            }
        }
        if let Ok(dir) = std::env::var("SPECEDGE_BENCH_OUT") {
            let path = std::path::Path::new(&dir)
                .join(format!("bench_{}.csv", self.group.replace('/', "_")));
            let mut csv = String::from("group,name,iters,mean_s,p50_s,p95_s\n");
            for r in &self.results {
                csv.push_str(&format!(
                    "{},{},{},{:.9},{:.9},{:.9}\n",
                    r.group, r.name, r.iters, r.mean_s, r.p50_s, r.p95_s
                ));
            }
            let _ = std::fs::create_dir_all(&dir);
            let _ = std::fs::write(path, csv);
        }
    }
}

pub fn fmt_time(s: f64) -> String {
    if !s.is_finite() {
        return "n/a".to_string();
    }
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench::with_opts(
            "test",
            BenchOpts {
                warmup: Duration::from_millis(1),
                measure: Duration::from_millis(20),
                max_iters: 10_000,
                min_iters: 5,
            },
        );
        let r = b.bench("noop", || { std::hint::black_box(1 + 1); }).clone();
        assert!(r.iters >= 5);
        assert!(r.mean_s >= 0.0);
        b.finish();
    }

    #[test]
    fn fmt_times() {
        assert!(fmt_time(5e-9).contains("ns"));
        assert!(fmt_time(5e-6).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains(" s"));
    }
}
