//! The analytical cost model — paper Eq. (1), from Leviathan et al. [3]:
//!
//! ```text
//! S(α, γ, c) = (1 − α^{γ+1}) / ((1 − α)(γc + 1))
//! ```
//!
//! * `α` — expected acceptance rate (mean fraction of drafted tokens the
//!   target accepts); model/task-dependent, hardware-independent.
//! * `γ` — draft length (tokens speculated per round).
//! * `c` — cost coefficient `t_draft / t_target`, hardware- and
//!   mapping-dependent (measured by [`crate::profiler`]).
//!
//! Feasibility: any speedup > 1 requires `c < α` (paper §II-B). The DSE
//! layer evaluates this model at each candidate mapping's measured (α, c)
//! and picks the (mapping, γ*) with the highest predicted S.
//!
//! **Where (α, c) come from.** This module is pure Eq.-(1) arithmetic;
//! the *operating point* it is evaluated at is owned by the unified
//! decision layer ([`crate::decision`]): `c` comes from a
//! [`crate::decision::CostModel`] — the offline-calibrated analytic
//! [`crate::hetero::LatencyModel`], or the online-refit
//! [`crate::decision::CalibratedModel`] — and `α` from the decision
//! engine's per-task EWMAs. The `decision: "analytic" | "calibrated"`
//! config knob selects between them (analytic is the default and is
//! bit-identical to the historical behavior).
//!
//! **Batched dispatches.** Eq. (1) prices a *single-stream* round: γ+1
//! dispatch boundaries (modular) or one (monolithic). Under the serving
//! fuser, co-scheduled sessions share batched forwards, priced by
//! [`crate::hetero::LatencyModel::batched_forward_latency`]: a `b`-lane
//! dispatch costs `b ×` the single-lane compute plus **one** boundary,
//! split across the sharing sessions — so per-session dispatch overhead
//! shrinks toward `1/b` of the single-stream figure while compute time is
//! unchanged. The per-stream speedup model above is unaffected; only the
//! overhead term the simulated clock accrues per call changes.
//!
//! **Heterogeneous overlap.** Eq. (1) also prices draft and verify as if
//! they serialized even across PUs. With per-PU timelines
//! ([`crate::hetero::PuTimelines`]; `hetero_overlap` config knob) a
//! heterogeneous mapping runs one session's drafts on one PU while
//! co-scheduled sessions verify on the other, under the readiness rule
//! `start = max(pu_ready, inputs_ready)`. In the steady-state pipeline
//! bound, per round the drafter PU is busy `γc` and the target PU `1`
//! (units of t_target), so the overlappable fraction is
//! [`predicted_overlap_frac`] `= min(γc, 1) / max(γc, 1)` and the
//! makespan contracts by [`predicted_pipeline_speedup`]
//! `= (γc + 1) / max(γc, 1)` — a *multiplicative* throughput factor on
//! top of Eq. (1) that exists only for heterogeneous mappings, which is
//! precisely the paper's joint-benefit claim. The `overlap` experiment
//! compares this bound against the simulated timelines.
//!
//! **Tree speculation.** A [`TreeShape`] `(k, d)` round drafts the top-k
//! candidates per node for `d` levels and verifies all `k^d`
//! root-to-leaf paths as the lanes of **one** batched target forward. A
//! level survives when *any* of its k candidates is accepted, so with
//! per-candidate acceptance α the per-level acceptance is
//! [`tree_level_acceptance`] `β = 1 − (1−α)^k` and the expected committed
//! tokens per round are [`expected_tree_tokens_per_round`]
//! `= 1 + Σ_{i=1..d} β^i` — at k = 1 exactly
//! [`expected_tokens_per_round`] (the chain). What the wider tree *buys*
//! (β ≫ α at low α) it *pays* in lanes: the level-i drafter expansion
//! runs [`tree_draft_lanes`] `= k^(i−1)` lanes and the verify
//! [`tree_verify_lanes`] `= k^d`, each priced lane-linear with one
//! dispatch boundary by
//! [`crate::hetero::LatencyModel::batched_forward_latency`]. The decision
//! layer ([`crate::dse::tree_speedup`]) scores that trade per
//! (α, mapping, shape) and picks chain vs tree and the shape; the `tree`
//! config knob (`off | auto | KxD`) selects the search mode.
//!
//! **Incremental pricing with a paged KV cache** (`kv_cache: on`). The
//! latencies above charge every forward for the *whole* bucketed sequence
//! — correct for a cache-less engine that re-runs prefill each dispatch.
//! With the paged KV cache ([`crate::kvcache`]) a session's resident
//! prefix is not recomputed: a dispatch with `cached` resident tokens is
//! priced as compute over only the `seq_len − cached` new tokens plus a
//! DRAM re-read of the resident KV
//! ([`crate::decision::CostModel::kv_read_latency`], sized by
//! `kv_bytes_per_token × cached / dram_gbps`) plus the usual single
//! dispatch boundary —
//! [`crate::decision::CostModel::incremental_forward_latency`] and the
//! per-lane [`crate::hetero::LatencyModel::incremental_lane_cost`] under
//! the fuser. Cold dispatches (`cached = 0`) and `kv_cache: off` route
//! through the historical full-sequence formulas unchanged, which is what
//! keeps the off mode bit-identical.

/// Maximum draft length the search considers (the paper sweeps 0..=5; we
/// allow a little headroom for the extension experiments).
pub const GAMMA_MAX: usize = 8;

/// Shape of a speculation tree: `branching` candidates drafted per node,
/// for `depth` levels. `(1, d)` *is* the linear chain with γ = d — the
/// session routes it through the chain code path, so branching 1
/// reproduces chain streams bit-for-bit by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TreeShape {
    pub branching: usize,
    pub depth: usize,
}

impl TreeShape {
    /// Both dimensions are clamped to ≥ 1.
    pub fn new(branching: usize, depth: usize) -> TreeShape {
        TreeShape { branching: branching.max(1), depth: depth.max(1) }
    }

    /// Parse the `KxD` knob syntax, e.g. `"2x3"`.
    pub fn parse(s: &str) -> anyhow::Result<TreeShape> {
        let (k, d) = s
            .split_once(['x', 'X'])
            .ok_or_else(|| anyhow::anyhow!("tree shape must be KxD (e.g. 2x3), got {s:?}"))?;
        let branching: usize = k
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad tree branching {k:?} in {s:?}"))?;
        let depth: usize = d
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad tree depth {d:?} in {s:?}"))?;
        anyhow::ensure!(branching >= 1 && depth >= 1, "tree shape {s:?} must be ≥ 1x1");
        Ok(TreeShape { branching, depth })
    }

    /// The `KxD` label (inverse of [`TreeShape::parse`]).
    pub fn label(&self) -> String {
        format!("{}x{}", self.branching, self.depth)
    }

    /// Verification lanes: one per root-to-leaf path, `k^depth`.
    pub fn leaves(&self) -> usize {
        tree_verify_lanes(self.branching, self.depth)
    }

    /// Total drafted nodes across all levels: Σ_{i=1..depth} k^i.
    pub fn nodes(&self) -> usize {
        (1..=self.depth).map(|i| tree_verify_lanes(self.branching, i)).sum()
    }

    /// Whether the shape actually branches; a 1-wide tree is the chain.
    pub fn branches(&self) -> bool {
        self.branching >= 2
    }
}

/// Per-level acceptance of a k-wide tree: the level survives when *any*
/// of its k candidates is accepted, so with i.i.d. per-candidate
/// acceptance α this is `β = 1 − (1−α)^k`. k = 1 degenerates to α.
pub fn tree_level_acceptance(alpha: f64, branching: usize) -> f64 {
    let a = alpha.clamp(0.0, 1.0);
    1.0 - (1.0 - a).powi(branching.max(1) as i32)
}

/// Expected tokens committed per (k, d)-tree round:
/// `1 + Σ_{i=1..d} β^i` with β = [`tree_level_acceptance`] — the accepted
/// root path plus the always-emitted correction/bonus token. At k = 1
/// this is exactly [`expected_tokens_per_round`] (geometric sum of α).
pub fn expected_tree_tokens_per_round(alpha: f64, branching: usize, depth: usize) -> f64 {
    let beta = tree_level_acceptance(alpha, branching);
    let mut e = 1.0;
    let mut p = 1.0;
    for _ in 0..depth {
        p *= beta;
        e += p;
    }
    e
}

/// Lanes of the flattened verification dispatch: `k^d` leaves.
pub fn tree_verify_lanes(branching: usize, depth: usize) -> usize {
    branching.max(1).saturating_pow(depth as u32)
}

/// Lanes of the drafter expansion dispatch producing level `level`
/// (1-based): `k^(level−1)` — one lane per node being expanded, starting
/// from a single root lane.
pub fn tree_draft_lanes(branching: usize, level: usize) -> usize {
    branching.max(1).saturating_pow(level.saturating_sub(1) as u32)
}

/// Predicted speedup S(α, γ, c) over non-speculative decoding.
///
/// γ = 0 degenerates to 1.0 (no speculation). α is clamped to [0, 1).
/// α = 1 would be a division by zero; the limit is (γ+1)/(γc+1), which we
/// return explicitly for numerical robustness near 1.
pub fn speedup(alpha: f64, gamma: usize, c: f64) -> f64 {
    if gamma == 0 {
        return 1.0;
    }
    let g = gamma as f64;
    let denom_hw = g * c + 1.0;
    if alpha >= 1.0 - 1e-12 {
        return (g + 1.0) / denom_hw;
    }
    let a = alpha.max(0.0);
    (1.0 - a.powi(gamma as i32 + 1)) / ((1.0 - a) * denom_hw)
}

/// Expected number of tokens produced per speculation round (the numerator
/// of Eq. 1 scaled out): E[#accepted] + 1 correction token.
pub fn expected_tokens_per_round(alpha: f64, gamma: usize) -> f64 {
    if alpha >= 1.0 - 1e-12 {
        return gamma as f64 + 1.0;
    }
    let a = alpha.max(0.0);
    (1.0 - a.powi(gamma as i32 + 1)) / (1.0 - a)
}

/// Speculation is worth anything at all only if c < α (paper §II-B).
pub fn feasible(alpha: f64, c: f64) -> bool {
    c < alpha
}

/// Predicted fraction of the heterogeneous makespan during which *both*
/// PUs compute, in the steady-state pipeline bound: per round the drafter
/// PU is busy `γ·c` and the target PU `1` (in units of t_target), so with
/// enough co-scheduled sessions the smaller side hides entirely under the
/// larger and the makespan contracts from `γc + 1` to `max(γc, 1)`:
///
/// ```text
/// overlap_frac = min(γc, 1) / max(γc, 1)
/// ```
///
/// This is what the per-PU timeline simulation should approach from below
/// as in-flight sessions increase (pipeline fill/drain and fusion
/// re-phasing keep it under the bound); the `overlap` experiment reports
/// predicted vs simulated. γ ≤ 0 (no speculation: one PU only) is 0.
///
/// `gamma` is fractional so a *mixed* co-scheduled set prices correctly:
/// sessions with draft lengths γ₁..γₙ put `Σγᵢ·c` draft time against `n`
/// verify units per round, which is this bound at the mean γ.
pub fn predicted_overlap_frac(gamma: f64, c: f64) -> f64 {
    if gamma <= 0.0 || c <= 0.0 {
        return 0.0;
    }
    let gc = gamma * c;
    gc.min(1.0) / gc.max(1.0)
}

/// The matching pipeline-bound makespan contraction: serialized time
/// `γc + 1` over overlapped time `max(γc, 1)` per round — the *additional*
/// throughput factor heterogeneous overlap buys on top of Eq. (1)'s
/// single-stream speedup (1.0 when γ ≤ 0). Fractional γ prices a mixed
/// co-scheduled set, as in [`predicted_overlap_frac`].
pub fn predicted_pipeline_speedup(gamma: f64, c: f64) -> f64 {
    if gamma <= 0.0 || c <= 0.0 {
        return 1.0;
    }
    let gc = gamma * c;
    (gc + 1.0) / gc.max(1.0)
}

/// Latency of one *collaborative* (edge-draft / cloud-verify) round.
///
/// The edge drafts γ tokens locally (`draft_round_s`, boundaries
/// included), ships them over the link and waits for the remote verdict
/// (`remote_round_s` = uplink payload + cloud verify + downlink verdict,
/// RTT included — see [`crate::fleet::NetworkModel`]). Pipelined (the
/// deployment the fleet tier models — round r+1's drafting overlaps round
/// r's ship/verify, PipeSD-style), the steady-state round costs the
/// *slower* of the two stages; serial execution pays their sum. The
/// pipelined bound is what the decision layer compares against the local
/// round when it places a request's verify.
pub fn collaborative_round_latency(
    draft_round_s: f64,
    remote_round_s: f64,
    pipelined: bool,
) -> f64 {
    if pipelined {
        draft_round_s.max(remote_round_s)
    } else {
        draft_round_s + remote_round_s
    }
}

/// Result of the collaborative γ search: the draft length minimizing the
/// pipelined per-token latency `round_s / E[tokens/round]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollabChoice {
    /// Draft length (≥ 1 — a collaborative round with nothing drafted has
    /// nothing to ship).
    pub gamma: usize,
    /// Pipelined steady-state round latency at that γ (seconds).
    pub round_s: f64,
    /// `round_s / E[tokens/round]` — the figure compared against the local
    /// per-token latency.
    pub per_token_s: f64,
}

/// γ* search for the collaborative round: `round(γ)` returns the round's
/// `(draft_round_s, remote_round_s)` pair (both γ-dependent — more drafts
/// mean more edge compute *and* a bigger shipped payload), and the search
/// minimizes the pipelined per-token latency over `1..=gamma_max`.
pub fn optimal_gamma_collaborative(
    alpha: f64,
    gamma_max: usize,
    round: impl Fn(usize) -> (f64, f64),
) -> CollabChoice {
    let mut best: Option<CollabChoice> = None;
    for g in 1..=gamma_max.max(1) {
        let (draft_s, remote_s) = round(g);
        let round_s = collaborative_round_latency(draft_s, remote_s, true);
        let per_token_s = round_s / expected_tokens_per_round(alpha, g);
        if best.map_or(true, |b| per_token_s < b.per_token_s) {
            best = Some(CollabChoice { gamma: g, round_s, per_token_s });
        }
    }
    best.expect("gamma_max >= 1 guarantees a candidate")
}

/// Result of the γ search for one (α, c) operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GammaChoice {
    /// Optimal draft length (0 = do not speculate).
    pub gamma: usize,
    /// Predicted speedup at that γ (1.0 when γ = 0).
    pub speedup: f64,
}

/// Exhaustive γ* search over 0..=GAMMA_MAX (the design space is tiny; the
/// paper does the same sweep).
pub fn optimal_gamma(alpha: f64, c: f64) -> GammaChoice {
    optimal_gamma_bounded(alpha, c, GAMMA_MAX)
}

/// γ* search with an explicit upper bound (used by ablations).
pub fn optimal_gamma_bounded(alpha: f64, c: f64, gamma_max: usize) -> GammaChoice {
    let mut best = GammaChoice { gamma: 0, speedup: 1.0 };
    for g in 1..=gamma_max {
        let s = speedup(alpha, g, c);
        if s > best.speedup {
            best = GammaChoice { gamma: g, speedup: s };
        }
    }
    best
}

/// Solve for the c that would make a given (α, γ) hit a given speedup —
/// used by the calibration tests to pin the paper's Table II numbers.
pub fn c_for_speedup(alpha: f64, gamma: usize, target_speedup: f64) -> f64 {
    let g = gamma as f64;
    let num = 1.0 - alpha.powi(gamma as i32 + 1);
    (num / ((1.0 - alpha) * target_speedup) - 1.0) / g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_zero_is_unity() {
        assert_eq!(speedup(0.9, 0, 0.3), 1.0);
    }

    #[test]
    fn paper_table2_variant1() {
        // Paper Table II: α=0.90, variant 1 (hetero) → S = 1.68 at c = 0.358.
        // NOTE (reproduction finding, see EXPERIMENTS.md): at the c implied
        // by the paper's own 1.68× (Eq. 1 ⇒ c = 0.358), the argmax of Eq. 1
        // is γ* = 4 (S = 1.684), with γ = 5 within 0.3% (S = 1.679) — the
        // paper's quoted γ = 5 is not the exact argmax of its own model.
        let c = 0.358;
        let choice = optimal_gamma_bounded(0.90, c, 5);
        assert!(choice.gamma == 4 || choice.gamma == 5, "{choice:?}");
        assert!((choice.speedup - 1.68).abs() < 0.02, "{}", choice.speedup);
        let s5 = speedup(0.90, 5, c);
        assert!((s5 - 1.68).abs() < 0.01, "{s5}");
    }

    #[test]
    fn paper_table2_variant2() {
        // α=0.90, variant 2 → γ*=2, S=1.10 at c≈0.73.
        let choice = optimal_gamma_bounded(0.90, 0.73, 5);
        assert_eq!(choice.gamma, 2);
        assert!((choice.speedup - 1.10).abs() < 0.02, "{}", choice.speedup);
    }

    #[test]
    fn paper_table3_low_alpha_never_speculates() {
        // α=0.17: even γ=1 must lose for every calibrated c (all ≥ 0.358).
        for c in [0.358, 0.73, 0.80, 0.86, 1.07, 2.15] {
            let choice = optimal_gamma(0.17, c);
            assert_eq!(choice.gamma, 0, "c={c}");
        }
    }

    #[test]
    fn feasibility_threshold() {
        assert!(feasible(0.9, 0.35));
        assert!(!feasible(0.17, 0.35));
        // At exactly c = α there is no speedup for any γ.
        for g in 1..=GAMMA_MAX {
            assert!(speedup(0.5, g, 0.5) <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn monotone_in_alpha() {
        for g in 1..=5 {
            let mut prev = 0.0;
            for i in 0..20 {
                let a = i as f64 / 20.0;
                let s = speedup(a, g, 0.4);
                assert!(s >= prev - 1e-12);
                prev = s;
            }
        }
    }

    #[test]
    fn alpha_one_limit() {
        let s = speedup(1.0, 4, 0.25);
        assert!((s - 5.0 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn expected_tokens_bounds() {
        // 1 <= E[tokens/round] <= γ+1
        for g in 1..=6 {
            for i in 0..=10 {
                let a = i as f64 / 10.0;
                let e = expected_tokens_per_round(a, g);
                assert!(e >= 1.0 - 1e-12 && e <= g as f64 + 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn c_for_speedup_inverts() {
        let c = c_for_speedup(0.9, 5, 1.68);
        assert!((speedup(0.9, 5, c) - 1.68).abs() < 1e-9);
        assert!((c - 0.358).abs() < 0.01, "{c}");
    }

    #[test]
    fn predicted_overlap_bounds_and_balance_point() {
        // No speculation → single-PU execution, nothing to overlap.
        assert_eq!(predicted_overlap_frac(0.0, 0.5), 0.0);
        assert_eq!(predicted_pipeline_speedup(0.0, 0.5), 1.0);
        // Perfect balance (γc = 1): both PUs fully busy → overlap 1, and
        // the pipeline bound halves the serialized makespan.
        assert!((predicted_overlap_frac(2.0, 0.5) - 1.0).abs() < 1e-12);
        assert!((predicted_pipeline_speedup(2.0, 0.5) - 2.0).abs() < 1e-12);
        // Paper operating point (γ=5, c≈0.358): drafts dominate.
        let f = predicted_overlap_frac(5.0, 0.358);
        assert!((f - 1.0 / (5.0 * 0.358)).abs() < 1e-12);
        // Fractional γ (a mixed set's mean, e.g. γ ∈ {2, 5} → 3.5).
        assert!((predicted_overlap_frac(3.5, 0.2) - 0.7).abs() < 1e-12);
        // Bounds: 0 ≤ frac ≤ 1, speedup ∈ (1, 2].
        for g in 1..=8 {
            for c in [0.1, 0.358, 0.73, 1.5] {
                let f = predicted_overlap_frac(g as f64, c);
                assert!((0.0..=1.0).contains(&f), "g={g} c={c} f={f}");
                let s = predicted_pipeline_speedup(g as f64, c);
                assert!(s > 1.0 && s <= 2.0 + 1e-12, "g={g} c={c} s={s}");
            }
        }
    }

    #[test]
    fn lower_c_never_hurts() {
        for g in 1..=GAMMA_MAX {
            assert!(speedup(0.8, g, 0.2) >= speedup(0.8, g, 0.6));
        }
    }

    #[test]
    fn collaborative_round_pipelined_bound() {
        // Pipelined = max of the stages; serial = their sum; the pipeline
        // never loses and hides the smaller stage entirely.
        assert_eq!(collaborative_round_latency(0.03, 0.01, true), 0.03);
        assert_eq!(collaborative_round_latency(0.03, 0.01, false), 0.04);
        assert_eq!(collaborative_round_latency(0.01, 0.05, true), 0.05);
        for (d, r) in [(0.0, 0.2), (0.1, 0.1), (0.5, 0.02)] {
            let p = collaborative_round_latency(d, r, true);
            let s = collaborative_round_latency(d, r, false);
            assert!(p <= s + 1e-15);
            assert!(p >= d.max(r) - 1e-15);
        }
    }

    #[test]
    fn collaborative_gamma_search_is_argmin() {
        // Edge draft step 3 ms/token; remote = 6 ms link floor + 0.5 ms
        // per shipped token + 2 ms cloud verify.
        let round = |g: usize| (0.003 * g as f64, 0.006 + 0.0005 * g as f64 + 0.002);
        for alpha in [0.1, 0.5, 0.9] {
            let best = optimal_gamma_collaborative(alpha, GAMMA_MAX, round);
            assert!(best.gamma >= 1 && best.gamma <= GAMMA_MAX);
            for g in 1..=GAMMA_MAX {
                let (d, r) = round(g);
                let per_tok = collaborative_round_latency(d, r, true)
                    / expected_tokens_per_round(alpha, g);
                assert!(per_tok >= best.per_token_s - 1e-12, "gamma {g} beats optimum");
            }
            assert!(
                (best.per_token_s
                    - best.round_s / expected_tokens_per_round(alpha, best.gamma))
                .abs()
                    < 1e-15
            );
        }
        // A high-α point drafts deeper than a low-α point: more of the
        // window survives verification, so the link floor amortizes.
        let lo = optimal_gamma_collaborative(0.2, GAMMA_MAX, round);
        let hi = optimal_gamma_collaborative(0.95, GAMMA_MAX, round);
        assert!(hi.gamma >= lo.gamma, "{} < {}", hi.gamma, lo.gamma);
    }
        let s = TreeShape::parse("2x3").unwrap();
        assert_eq!(s, TreeShape { branching: 2, depth: 3 });
        assert_eq!(s.label(), "2x3");
        assert_eq!(s.leaves(), 8);
        assert_eq!(s.nodes(), 2 + 4 + 8);
        assert!(s.branches());
        assert!(!TreeShape::new(1, 5).branches());
        assert!(TreeShape::parse("2x").is_err());
        assert!(TreeShape::parse("0x3").is_err());
        assert!(TreeShape::parse("chain").is_err());
        // Lane schedule: level-i expansion runs k^(i−1) lanes.
        assert_eq!(tree_draft_lanes(2, 1), 1);
        assert_eq!(tree_draft_lanes(2, 3), 4);
        assert_eq!(tree_verify_lanes(3, 2), 9);
    }

    #[test]
    fn tree_width_one_is_the_chain() {
        // β(α, 1) = α and the expected-token formula collapses to Eq. (1)'s
        // numerator, the chain's geometric sum.
        for i in 0..=10 {
            let a = i as f64 / 10.0;
            assert!((tree_level_acceptance(a, 1) - a).abs() < 1e-12);
            for d in 1..=6 {
                let t = expected_tree_tokens_per_round(a, 1, d);
                let chain = expected_tokens_per_round(a, d);
                assert!((t - chain).abs() < 1e-9, "a={a} d={d}: {t} vs {chain}");
            }
        }
    }

    #[test]
    fn tree_tokens_monotone_in_branching_and_bounded() {
        for i in 1..10 {
            let a = i as f64 / 10.0;
            for d in 1..=4 {
                let mut prev = 0.0;
                for k in 1..=4 {
                    let e = expected_tree_tokens_per_round(a, k, d);
                    // Wider trees only raise per-level acceptance.
                    assert!(e >= prev - 1e-12, "a={a} k={k} d={d}");
                    assert!(e >= 1.0 - 1e-12 && e <= d as f64 + 1.0 + 1e-12);
                    prev = e;
                }
            }
        }
        // At low α the widening matters most: k=4 more than doubles the
        // per-level acceptance at α = 0.3.
        let b1 = tree_level_acceptance(0.3, 1);
        let b4 = tree_level_acceptance(0.3, 4);
        assert!(b4 > 2.0 * b1, "{b1} -> {b4}");
    }
}
