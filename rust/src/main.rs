//! `specedge` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   serve       start the TCP serving front-end
//!   decode      decode one prompt from the command line
//!   profile     print per-variant forward latencies (sim + real)
//!   explore     run the cost-model-guided DSE (Tables II/III style)
//!   experiment  regenerate a paper table/figure (or `all`)
//!   alpha       quick per-task acceptance-rate check
//!   loadgen     drive a running server (closed/open-loop or --trace)
//!   info        print manifest / platform summary

use specedge::config::{
    CloudVerifyMode, DecisionMode, DrafterMode, ExecMode, KernelPath, KvCacheMode, RunConfig,
    ServeMode, Timing, TreeChoice,
};
use specedge::coordinator::Coordinator;
use specedge::dse::{self, PairConfig};
use specedge::experiments;
use specedge::fleet::{FleetRouter, FleetSpec};
use specedge::hetero::{LatencyModel, Mapping, Platform};
use specedge::models::VariantKey;
use specedge::profiler;
use specedge::runtime::Engine;
use specedge::server::{Backend, Server};
use specedge::spec::{AcceptRule, Decoder, DecoderSetup};
use specedge::tokenizer::{Tokenizer, SEP_ID};
use specedge::util::cli::Cli;
use std::path::PathBuf;
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("{e}");
        std::process::exit(1);
    }
}

fn cli() -> Cli {
    Cli::new("specedge", "speculative sampling on heterogeneous edge (paper repro)")
        .opt("artifacts", "artifacts directory", Some("artifacts"))
        .opt("platform", "platform calibration JSON (default: built-in i.MX95)", None)
        .opt("config", "run-config JSON file", None)
        .opt("gamma", "fixed draft length (default: cost-model-chosen)", None)
        .opt("variant", "design variant = CPU cores 1..6", Some("1"))
        .opt("exec", "modular|monolithic", Some("modular"))
        .opt("kernel", "pallas|ref artifacts", Some("pallas"))
        .opt("timing", "simulated|real", Some("simulated"))
        .opt("decision", "decision cost model: analytic|calibrated", None)
        .opt("repartition-every", "calibrated: re-run mapping search every K rounds", None)
        .opt("tree", "tree speculation: off|auto|KxD (e.g. 2x3)", None)
        .opt("kv-cache", "paged KV cache + prefix sharing: off|on", None)
        .opt("drafter", "drafter selection: fixed|auto (per-class registry)", None)
        .opt("trace", "workload trace JSONL (scenario replay; see loadgen)", None)
        .opt("fleet", "serve: fleet topology JSON (multi-device routing)", None)
        .opt("cloud-verify", "fleet: cloud verification off|auto|local|cloud", None)
        .opt("cloud-rtt-ms", "fleet: cloud link round-trip, milliseconds", None)
        .opt("cloud-mbps", "fleet: cloud link bandwidth, megabits/s", None)
        .opt("alpha", "alpha for explore", Some("0.90"))
        .opt("seq", "operating sequence length", Some("63"))
        .opt("max-new", "max new tokens", Some("64"))
        .opt("port", "serve: TCP port (0 = auto)", Some("7643"))
        .opt("workers", "serve: engine workers", Some("1"))
        .opt("max-inflight", "serve: live sessions interleaved per worker", Some("4"))
        .opt("serve-mode", "serve: connection shell, event_loop|threaded", None)
        .opt("rate-limit-rps", "serve: per-client admission rate (0 = off)", None)
        .opt("rate-limit-burst", "serve: per-client token-bucket burst", None)
        .opt("client-queue-depth", "serve: outbound lines buffered per client", None)
        .opt("drain-deadline-s", "serve: drain grace before in-flight cancel", None)
        .opt("metrics-history", "serve: append metrics snapshots to this JSONL file", None)
        .opt("metrics-history-every-s", "serve: seconds between history snapshots", None)
        .opt("clients", "loadgen: concurrent simulated clients", Some("64"))
        .opt("requests-per-client", "loadgen: closed-loop requests per client", Some("4"))
        .opt("rps", "loadgen: open-loop aggregate arrival rate (0 = closed)", Some("0"))
        .opt("duration-s", "loadgen: open-loop arrival window, seconds", Some("5"))
        .opt("limit", "experiments: sample limit", None)
        .opt("out", "experiments: results dir", Some("results"))
        .opt("prompt", "decode: prompt text (task-prefixed, e.g. 'tr: ...')", None)
        .opt("stop", "decode: comma-separated stop sequences", None)
        .opt("task", "decode/serve: task label", Some("translate"))
        .flag("homogeneous", "use the homogeneous CPU mapping")
        .flag("no-spec", "disable speculation (baseline decode)")
        .flag("stochastic", "stochastic accept rule instead of greedy")
}

fn build_config(args: &specedge::util::cli::Args) -> anyhow::Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(p) => RunConfig::from_file(std::path::Path::new(p))?,
        None => RunConfig::default(),
    };
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(a);
    }
    if let Some(p) = args.get("platform") {
        cfg.platform_file = Some(PathBuf::from(p));
    }
    if let Some(g) = args.get_usize("gamma")? {
        cfg.gamma = Some(g);
    }
    if let Some(v) = args.get_usize("variant")? {
        cfg.design_variant = v;
    }
    if let Some(e) = args.get("exec") {
        cfg.exec_mode = ExecMode::parse(e)?;
    }
    if let Some(k) = args.get("kernel") {
        cfg.kernel_path = KernelPath::parse(k)?;
    }
    if let Some(t) = args.get("timing") {
        cfg.timing = Timing::parse(t)?;
    }
    if let Some(d) = args.get("decision") {
        cfg.decision = DecisionMode::parse(d)?;
    }
    if let Some(k) = args.get_usize("repartition-every")? {
        cfg.repartition_every = k;
    }
    if let Some(t) = args.get("tree") {
        cfg.tree = TreeChoice::parse(t)?;
    }
    if let Some(k) = args.get("kv-cache") {
        cfg.kv_cache = KvCacheMode::parse(k)?;
    }
    if let Some(d) = args.get("drafter") {
        cfg.drafter = DrafterMode::parse(d)?;
    }
    if let Some(t) = args.get("trace") {
        cfg.trace_file = Some(PathBuf::from(t));
    }
    if let Some(f) = args.get("fleet") {
        cfg.fleet_file = Some(PathBuf::from(f));
    }
    if let Some(c) = args.get("cloud-verify") {
        cfg.cloud_verify = CloudVerifyMode::parse(c)?;
    }
    if let Some(r) = args.get_f64("cloud-rtt-ms")? {
        cfg.cloud_rtt_ms = r;
    }
    if let Some(b) = args.get_f64("cloud-mbps")? {
        cfg.cloud_mbps = b;
    }
    if let Some(m) = args.get_usize("max-new")? {
        cfg.max_new_tokens = m;
    }
    if let Some(w) = args.get_usize("workers")? {
        cfg.workers = w;
    }
    if let Some(m) = args.get_usize("max-inflight")? {
        cfg.max_inflight = m;
    }
    if let Some(p) = args.get_usize("port")? {
        cfg.port = p as u16;
    }
    if let Some(m) = args.get("serve-mode") {
        cfg.serve_mode = ServeMode::parse(m)?;
    }
    if let Some(r) = args.get_f64("rate-limit-rps")? {
        cfg.rate_limit_rps = r;
    }
    if let Some(b) = args.get_usize("rate-limit-burst")? {
        cfg.rate_limit_burst = b;
    }
    if let Some(d) = args.get_usize("client-queue-depth")? {
        cfg.client_queue_depth = d;
    }
    if let Some(d) = args.get_f64("drain-deadline-s")? {
        cfg.drain_deadline_s = d;
    }
    if let Some(p) = args.get("metrics-history") {
        cfg.metrics_history_file = Some(PathBuf::from(p));
    }
    if let Some(s) = args.get_f64("metrics-history-every-s")? {
        cfg.metrics_history_every_s = s;
    }
    cfg.heterogeneous = !args.has_flag("homogeneous");
    cfg.speculative = !args.has_flag("no-spec");
    cfg.validate()?;
    Ok(cfg)
}

fn load_platform(cfg: &RunConfig) -> anyhow::Result<Platform> {
    match &cfg.platform_file {
        Some(p) => Platform::from_file(p),
        None => Ok(Platform::imx95()),
    }
}

fn run() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli().parse(&argv)?;
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("info");
    let cfg = build_config(&args)?;
    let platform = load_platform(&cfg)?;

    match cmd {
        "info" => cmd_info(&cfg, &platform),
        "decode" => cmd_decode(&cfg, platform, &args),
        "profile" => cmd_profile(&cfg, platform),
        "explore" => cmd_explore(&cfg, platform, &args),
        "experiment" => cmd_experiment(&cfg, platform, &args),
        "alpha" => cmd_experiment_named(&cfg, platform, &args, "alpha"),
        "loadgen" => cmd_loadgen(&cfg, &args),
        "serve" => cmd_serve(cfg, platform),
        other => anyhow::bail!("unknown command {other:?}\n\n{}", cli().usage()),
    }
}

fn cmd_info(cfg: &RunConfig, platform: &Platform) -> anyhow::Result<()> {
    let engine = Engine::load(&cfg.artifacts_dir)?;
    let m = &engine.manifest;
    println!("specedge — PJRT platform: {}", engine.platform_name());
    println!("artifacts: {}", cfg.artifacts_dir.display());
    println!("  seq buckets: {:?}", m.seq_buckets);
    println!("  variants:");
    for (k, v) in &m.variants {
        println!("    {:<14} {} artifacts, {} tensors",
                 k.name(), v.artifacts.len(), v.tensors.len());
    }
    println!("  monolithic gammas: {:?}",
             m.monolithic.iter().map(|x| x.gamma).collect::<Vec<_>>());
    println!("  eval samples: {} over {} tasks",
             m.eval_samples.len(),
             m.eval_samples.iter().map(|s| s.task.as_str())
                 .collect::<std::collections::BTreeSet<_>>().len());
    println!("platform: {} ({} CPU cores + {})",
             platform.name, platform.cpu.cores, platform.gpu.name);
    Ok(())
}

fn cmd_decode(
    cfg: &RunConfig,
    platform: Platform,
    args: &specedge::util::cli::Args,
) -> anyhow::Result<()> {
    let prompt_text = args.req("prompt")?;
    let engine = Engine::load(&cfg.artifacts_dir)?;
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec)?;
    let mut prompt = tokenizer.encode(prompt_text, true)?;
    prompt.push(SEP_ID);

    let mapping = if cfg.heterogeneous {
        Mapping::heterogeneous(cfg.design_variant)
    } else {
        Mapping::homogeneous(cfg.design_variant)
    };
    let setup = DecoderSetup {
        drafter: VariantKey::parse(&cfg.drafter_variant)?,
        target: VariantKey::parse(&cfg.target_variant)?,
        kernel: cfg.kernel_path,
        mapping,
        gamma: cfg.gamma.unwrap_or(5),
        rule: if args.has_flag("stochastic") {
            AcceptRule::Stochastic
        } else {
            AcceptRule::Greedy
        },
        exec: cfg.exec_mode,
        max_new: cfg.max_new_tokens,
    };
    let lat = LatencyModel::new(platform);
    let decoder = Decoder::new(&engine, lat, setup);
    // Drive a session directly so per-request options (stop sequences)
    // apply; without --stop this is exactly Decoder::speculative/baseline.
    let mut session = decoder.session(&prompt, cfg.speculative);
    if let Some(stops) = args.get("stop") {
        let encoded: Vec<Vec<u32>> = stops
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| tokenizer.encode(s, false))
            .collect::<anyhow::Result<Vec<Vec<u32>>>>()?;
        session.set_stop_sequences(encoded);
    }
    // `auto` needs the serving policy's online α estimate, so the one-shot
    // CLI decode honors an explicit fixed shape only (`serve` does both).
    if let TreeChoice::Fixed(shape) = cfg.tree {
        session.set_tree(Some(shape));
    }
    while !session.is_done() {
        session.step(&engine)?;
    }
    let out = session.into_outcome();
    println!("completion: {}", tokenizer.decode(&out.tokens));
    println!(
        "tokens={} rounds={} drafted={} accepted={} alpha={:.3} finish={}",
        out.tokens.len(), out.n_rounds, out.n_drafted, out.n_accepted, out.alpha(),
        out.finish.as_str()
    );
    println!(
        "simulated {:.1} ms | real {:.1} ms ({} drafter + {} target calls)",
        out.sim_s * 1e3, out.real_s * 1e3, out.drafter_calls, out.target_calls
    );
    if out.tree_rounds > 0 {
        println!(
            "tree rounds={} lane_fill={:.2}",
            out.tree_rounds,
            out.tree_lanes_real as f64 / out.tree_lanes_executed.max(1) as f64
        );
    }
    Ok(())
}

fn cmd_profile(cfg: &RunConfig, platform: Platform) -> anyhow::Result<()> {
    let engine = Engine::load(&cfg.artifacts_dir)?;
    let lat = LatencyModel::new(platform);
    let seqs: Vec<usize> = engine.manifest.seq_buckets.clone();
    println!("{:<16} {:<14} {:>6} {:>12} {:>12}",
             "variant", "pu", "seq", "sim", "real(pjrt)");
    for key in ["drafter_fp", "target_w8a8", "target_fp", "drafter_w8a8"] {
        let variant = VariantKey::parse(key)?;
        let pu = if variant.role == specedge::models::Role::Drafter && cfg.heterogeneous {
            specedge::hetero::PuAssignment::Gpu
        } else {
            specedge::hetero::PuAssignment::Cpu { cores: cfg.design_variant }
        };
        let sim = profiler::profile_simulated(&lat, &engine, variant, pu, &seqs)?;
        let real = profiler::profile_real(&engine, variant, cfg.kernel_path, &seqs, 3)?;
        for (s, r) in sim.iter().zip(&real) {
            println!(
                "{:<16} {:<14} {:>6} {:>12} {:>12}",
                key, s.pu_label, s.seq,
                specedge::bench::fmt_time(s.sim_s),
                specedge::bench::fmt_time(r.real_s.unwrap_or(f64::NAN)),
            );
        }
    }
    Ok(())
}

fn cmd_explore(
    cfg: &RunConfig,
    platform: Platform,
    args: &specedge::util::cli::Args,
) -> anyhow::Result<()> {
    let alpha = args.get_f64("alpha")?.unwrap_or(0.90);
    let seq = args.get_usize("seq")?.unwrap_or(63);
    let engine = Engine::load(&cfg.artifacts_dir)?;
    let lat = LatencyModel::new(platform);
    let d_key = VariantKey::parse(&cfg.drafter_variant)?;
    let t_key = VariantKey::parse(&cfg.target_variant)?;
    let pair = PairConfig {
        target: engine.manifest.model_for(t_key)?.clone(),
        target_scheme: t_key.scheme,
        drafter: engine.manifest.model_for(d_key)?.clone(),
        drafter_scheme: d_key.scheme,
    };
    println!("DSE at alpha={alpha} seq={seq}:");
    for d in dse::explore_all(&lat, &pair, alpha, seq) {
        let b = &d.best;
        println!(
            "variant {}: {} gamma={} S={:.3} [{}]",
            b.variant,
            if b.gamma > 0 { "SPECULATE" } else { "baseline " },
            b.gamma,
            b.speedup,
            b.mapping.label()
        );
    }
    Ok(())
}

fn cmd_experiment(
    cfg: &RunConfig,
    platform: Platform,
    args: &specedge::util::cli::Args,
) -> anyhow::Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    cmd_experiment_named(cfg, platform, args, which)
}

fn cmd_experiment_named(
    cfg: &RunConfig,
    platform: Platform,
    args: &specedge::util::cli::Args,
    which: &str,
) -> anyhow::Result<()> {
    let out = PathBuf::from(args.get("out").unwrap_or("results"));
    let limit = args.get_usize("limit")?;
    let ctx = experiments::Ctx::new(cfg, platform, out, limit)?;
    experiments::run(&ctx, which)
}

fn cmd_loadgen(cfg: &RunConfig, args: &specedge::util::cli::Args) -> anyhow::Result<()> {
    let mut spec = specedge::loadgen::LoadSpec {
        port: cfg.port,
        clients: args.get_usize("clients")?.unwrap_or(64),
        requests_per_client: args.get_usize("requests-per-client")?.unwrap_or(4),
        open_loop_rps: args.get_f64("rps")?.unwrap_or(0.0),
        duration_s: args.get_f64("duration-s")?.unwrap_or(5.0),
        task: args.get("task").unwrap_or("translate").to_string(),
        seed: cfg.seed,
        ..specedge::loadgen::LoadSpec::default()
    };
    if let Some(path) = &cfg.trace_file {
        // Trace replay: resolve the saved trace against the manifest's
        // eval set; arrivals come from the trace, not the harness.
        let engine = Engine::load(&cfg.artifacts_dir)?;
        let trace = specedge::scenario::WorkloadTrace::load(path)?;
        spec.schedule = Some(specedge::scenario::trace_schedule(&trace, &engine.manifest)?);
        println!(
            "loadgen: replaying trace {:?} ({} requests, {} classes)",
            trace.name,
            trace.entries.len(),
            trace.class_count()
        );
    }
    let report = specedge::loadgen::run(&spec)?;
    println!("{}", report.to_json());
    Ok(())
}

fn cmd_serve(cfg: RunConfig, platform: Platform) -> anyhow::Result<()> {
    let tokenizer = Tokenizer::builtin();
    let mut server = match &cfg.fleet_file {
        Some(path) => {
            // Fleet mode: one coordinator per device from the topology
            // file; the per-device platforms come from the fleet file, so
            // the CLI-level platform is ignored.
            let spec = FleetSpec::load(path)?;
            let n = spec.devices.len();
            let fleet = Arc::new(FleetRouter::start(&cfg, spec)?);
            let s = Server::start_cfg(Backend::Fleet(Arc::clone(&fleet)), tokenizer, &cfg)?;
            println!(
                "specedge fleet: {} device(s){}",
                n,
                if fleet.cloud().is_some() { " + cloud verify tier" } else { "" }
            );
            s
        }
        None => {
            let coordinator = Arc::new(Coordinator::start(cfg.clone(), platform)?);
            Server::start_cfg(Backend::Single(coordinator), tokenizer, &cfg)?
        }
    };
    println!(
        "specedge serving on 127.0.0.1:{} ({} shell)",
        server.port,
        cfg.serve_mode.as_str()
    );
    println!(
        "protocol: one JSON per line; {{\"cmd\":\"drain\"}} to drain, \
         {{\"cmd\":\"shutdown\"}} to stop"
    );
    // Block until a drain completes or a shutdown command stops the shell
    // (no signal handling: the container toolchain has no libc binding, so
    // lifecycle is driven over the wire or via Server::drain).
    server.wait();
    Ok(())
}
