//! Cost-coefficient profiler (paper §III-C, Fig. 6).
//!
//! Measures t_draft and t_target per (variant, PU assignment, sequence
//! length) and derives c = t_draft / t_target. Two backends:
//!
//! * **simulated** — the calibrated i.MX95 latency model (paper-facing);
//! * **real** — wall-clock of the PJRT CPU executions on this machine
//!   (reported alongside in EXPERIMENTS.md; same *shape*, different scale).

use crate::config::KernelPath;
use crate::hetero::{LatencyModel, Mapping};
use crate::models::VariantKey;
use crate::runtime::Engine;
use crate::util::stats::Summary;

/// One profile row.
#[derive(Debug, Clone)]
pub struct ProfileRow {
    pub variant: VariantKey,
    pub pu_label: String,
    pub seq: usize,
    /// Simulated seconds per forward.
    pub sim_s: f64,
    /// Real seconds per forward (median over `reps`), if measured.
    pub real_s: Option<f64>,
}

/// Cost-coefficient curve point (Fig. 6 series).
#[derive(Debug, Clone)]
pub struct CostPoint {
    pub seq: usize,
    /// Design variant (CPU cores available).
    pub variant: usize,
    pub heterogeneous: bool,
    pub c_sim: f64,
    pub c_real: Option<f64>,
}

/// Profile the simulated latency of one variant across seq lengths/PUs.
pub fn profile_simulated(
    lat: &LatencyModel,
    engine: &Engine,
    variant: VariantKey,
    pu: crate::hetero::PuAssignment,
    seqs: &[usize],
) -> anyhow::Result<Vec<ProfileRow>> {
    let spec = engine.manifest.model_for(variant)?;
    Ok(seqs
        .iter()
        .map(|&s| ProfileRow {
            variant,
            pu_label: pu.label(),
            seq: s,
            sim_s: lat.forward_latency(spec, variant.scheme, pu, s),
            real_s: None,
        })
        .collect())
}

/// Measure real PJRT wall-clock per forward (median of `reps`, after one
/// warmup execution that also triggers compilation).
pub fn profile_real(
    engine: &Engine,
    variant: VariantKey,
    kernel: KernelPath,
    seqs: &[usize],
    reps: usize,
) -> anyhow::Result<Vec<ProfileRow>> {
    let mut rows = Vec::new();
    for &s in seqs {
        let bucket = engine.bucket_for(s)?;
        let tokens: Vec<u32> = (0..s.min(bucket)).map(|i| 4 + (i % 40) as u32).collect();
        engine.forward(variant, kernel, &tokens, bucket)?; // warmup/compile
        let mut lat = Summary::new();
        for _ in 0..reps {
            let out = engine.forward(variant, kernel, &tokens, bucket)?;
            lat.push(out.elapsed_s);
        }
        rows.push(ProfileRow {
            variant,
            pu_label: format!("pjrt-cpu/{}", kernel.as_str()),
            seq: s,
            sim_s: f64::NAN,
            real_s: Some(lat.median()),
        });
    }
    Ok(rows)
}

/// The Fig. 6 data: c vs sequence length for every design variant, in both
/// homogeneous (a) and heterogeneous (b) mappings. Pair = (drafter, target)
/// variants (the paper's semi-quantized deployment by default).
pub fn cost_curves(
    lat: &LatencyModel,
    engine: &Engine,
    drafter: VariantKey,
    target: VariantKey,
    seqs: &[usize],
    heterogeneous: bool,
    real_ratio: Option<f64>,
) -> anyhow::Result<Vec<CostPoint>> {
    let d_spec = engine.manifest.model_for(drafter)?;
    let t_spec = engine.manifest.model_for(target)?;
    let mut points = Vec::new();
    for variant in 1..=lat.platform.design_variants() {
        let mapping = if heterogeneous {
            Mapping::heterogeneous(variant)
        } else {
            Mapping::homogeneous(variant)
        };
        for &s in seqs {
            let c = lat.cost_coefficient(
                (d_spec, drafter.scheme),
                (t_spec, target.scheme),
                mapping,
                s,
            );
            points.push(CostPoint {
                seq: s,
                variant,
                heterogeneous,
                c_sim: c,
                c_real: real_ratio,
            });
        }
    }
    Ok(points)
}

/// Real-hardware cost coefficient on this machine (PJRT CPU): the ratio of
/// measured drafter/target forward latencies. There is no real GPU here, so
/// this only validates the *homogeneous* shape.
pub fn real_cost_coefficient(
    engine: &Engine,
    drafter: VariantKey,
    target: VariantKey,
    kernel: KernelPath,
    seq: usize,
    reps: usize,
) -> anyhow::Result<f64> {
    let d = profile_real(engine, drafter, kernel, &[seq], reps)?;
    let t = profile_real(engine, target, kernel, &[seq], reps)?;
    Ok(d[0].real_s.unwrap() / t[0].real_s.unwrap())
}
