//! Analytic per-call latency model: FLOPs ÷ effective throughput + dispatch
//! overhead, per (model, scheme, PU assignment, sequence length).
//!
//! This is the quantity the profiler measures (Fig. 6 cost coefficients are
//! ratios of these) and the virtual clock accrues during engine execution.
//!
//! **Batched dispatches** (the fused executor and the lockstep batcher)
//! are charged [`LatencyModel::batched_forward_latency`]: `b` lanes cost
//! `b ×` the single-lane compute (no batching win on a saturated edge PU —
//! the GEMMs already occupy the whole cluster at batch 1) but only **one**
//! runtime-API dispatch boundary, which is exactly the per-call overhead
//! fusion amortizes. The total is split evenly across the *real* requests
//! sharing the dispatch, so no simulated time vanishes into padding lanes.

use crate::models::{ModelSpec, Scheme};
use crate::util::json::Json;

use super::platform::Platform;
use super::pu::PuAssignment;

/// Latency model over a calibrated platform.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    pub platform: Platform,
}

impl Default for LatencyModel {
    fn default() -> LatencyModel {
        LatencyModel::new(Platform::default())
    }
}

impl LatencyModel {
    pub fn new(platform: Platform) -> LatencyModel {
        LatencyModel { platform }
    }

    /// One forward pass of `spec` (scheme-quantized) on `pu` at `seq_len`.
    /// Returns seconds of simulated device time, including one runtime-API
    /// dispatch boundary.
    pub fn forward_latency(
        &self,
        spec: &ModelSpec,
        scheme: Scheme,
        pu: PuAssignment,
        seq_len: usize,
    ) -> f64 {
        let flops = spec.forward_flops(seq_len);
        let linear_frac = spec.linear_fraction(seq_len);
        match pu {
            PuAssignment::Cpu { cores } => {
                let c = &self.platform.cpu;
                let eff = self.platform.cpu_eff(spec, cores);
                let thrpt = c.peak_gflops_per_core * 1e9 * cores as f64 * eff;
                // int8 linears run faster on the A55 (dot-product ext);
                // non-linear FLOPs (attention scores, norms) stay fp32.
                let speed = match scheme {
                    Scheme::Fp => 1.0,
                    Scheme::W8a8 => 1.0 / (linear_frac / c.int8_speedup + (1.0 - linear_frac)),
                };
                flops / (thrpt * speed) + c.dispatch_overhead_s
            }
            PuAssignment::Gpu => {
                let g = &self.platform.gpu;
                // Paper footnote 3: Mali promotes INT8 to FP32, *adding*
                // overhead — quantized models are slower on this GPU.
                let penalty = match scheme {
                    Scheme::Fp => 1.0,
                    Scheme::W8a8 => {
                        linear_frac * g.int8_promotion_penalty + (1.0 - linear_frac)
                    }
                };
                flops * penalty / (g.peak_gflops * 1e9) + g.dispatch_overhead_s
            }
        }
    }

    /// Per-call runtime-API dispatch boundary for a PU assignment.
    pub fn dispatch_overhead(&self, pu: PuAssignment) -> f64 {
        match pu {
            PuAssignment::Cpu { .. } => self.platform.cpu.dispatch_overhead_s,
            PuAssignment::Gpu => self.platform.gpu.dispatch_overhead_s,
        }
    }

    /// One *batched* forward over `batch` padded lanes at `seq_len`:
    /// `batch ×` the single-lane FLOPs, one dispatch boundary for the
    /// whole call. `batch = 1` degenerates to [`Self::forward_latency`].
    pub fn batched_forward_latency(
        &self,
        spec: &ModelSpec,
        scheme: Scheme,
        pu: PuAssignment,
        seq_len: usize,
        batch: usize,
    ) -> f64 {
        let single = self.forward_latency(spec, scheme, pu, seq_len);
        let oh = self.dispatch_overhead(pu);
        (single - oh) * batch.max(1) as f64 + oh
    }

    /// Memory-traffic term of a KV-cache hit: seconds to stream `cached`
    /// tokens' worth of resident K/V tensors of `spec` back through the
    /// attention kernels, at the platform's effective DRAM bandwidth.
    /// Zero cached tokens cost exactly 0.0 seconds.
    pub fn kv_read_latency(&self, spec: &ModelSpec, scheme: Scheme, cached: usize) -> f64 {
        let mem = &self.platform.memory;
        let bytes = crate::kvcache::kv_bytes_per_token(spec, scheme, mem) * cached as f64;
        bytes / (mem.dram_gbps * 1e9)
    }

    /// Compute cost of one lane of an *incremental* forward — `cached` of
    /// the `seq_len` bucketed positions already have resident KV, so the
    /// lane pays compute only for the new fraction plus the memory-traffic
    /// term for re-reading the cached KV. No dispatch boundary included
    /// (the caller owns boundary accounting, fused or single).
    pub fn incremental_lane_cost(
        &self,
        spec: &ModelSpec,
        scheme: Scheme,
        pu: PuAssignment,
        seq_len: usize,
        cached: usize,
    ) -> f64 {
        let single = self.forward_latency(spec, scheme, pu, seq_len);
        let oh = self.dispatch_overhead(pu);
        let cached = cached.min(seq_len);
        let new_frac = (seq_len - cached) as f64 / seq_len.max(1) as f64;
        (single - oh) * new_frac + self.kv_read_latency(spec, scheme, cached)
    }

    /// One incremental forward including its dispatch boundary: the
    /// cache-hit counterpart of [`Self::forward_latency`]. At `cached = 0`
    /// this is *numerically* the plain forward; the engine still routes
    /// cache-off (and cache-cold) dispatches through
    /// [`Self::forward_latency`] directly so the `kv_cache: off` clock is
    /// bit-identical by construction, not by arithmetic coincidence.
    pub fn incremental_forward_latency(
        &self,
        spec: &ModelSpec,
        scheme: Scheme,
        pu: PuAssignment,
        seq_len: usize,
        cached: usize,
    ) -> f64 {
        self.incremental_lane_cost(spec, scheme, pu, seq_len, cached)
            + self.dispatch_overhead(pu)
    }

    /// Cost coefficient c = t_draft / t_target for a mapping at seq_len
    /// (the paper's Fig. 6 quantity).
    pub fn cost_coefficient(
        &self,
        drafter: (&ModelSpec, Scheme),
        target: (&ModelSpec, Scheme),
        mapping: super::pu::Mapping,
        seq_len: usize,
    ) -> f64 {
        let td = self.forward_latency(drafter.0, drafter.1, mapping.drafter, seq_len);
        let tt = self.forward_latency(target.0, target.1, mapping.target, seq_len);
        td / tt
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("platform", Json::Str(self.platform.name.clone()));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::pu::Mapping;

    fn specs() -> (ModelSpec, ModelSpec) {
        let target = ModelSpec {
            name: "target".into(),
            n_layers: 4,
            d_model: 128,
            n_heads: 4,
            ffn_dim: 352,
            vocab: 48,
            param_count: 816_256,
        };
        let drafter = ModelSpec {
            name: "drafter".into(),
            n_layers: 2,
            d_model: 96,
            n_heads: 4,
            ffn_dim: 256,
            vocab: 48,
            param_count: 230_880,
        };
        (target, drafter)
    }

    fn model() -> LatencyModel {
        LatencyModel::new(Platform::imx95())
    }

    /// The central calibration test: the derived cost coefficients at the
    /// paper's S_L = 63 operating point must sit at the DESIGN.md §5
    /// anchors (which in turn reproduce Table II via Eq. 1).
    #[test]
    fn calibration_anchors_at_s63() {
        let (t, d) = specs();
        let m = model();
        // Semi-quantized deployment: drafter fp, target w8a8.
        let c_het1 = m.cost_coefficient(
            (&d, Scheme::Fp), (&t, Scheme::W8a8), Mapping::heterogeneous(1), 63);
        assert!((c_het1 - 0.358).abs() < 0.04, "c_het(1) = {c_het1}");
        let c_homo1 = m.cost_coefficient(
            (&d, Scheme::Fp), (&t, Scheme::W8a8), Mapping::homogeneous(1), 63);
        assert!((c_homo1 - 0.80).abs() < 0.08, "c_homo(1) = {c_homo1}");
        // Hetero becomes infeasible (c > 1) from 3 cores on — Fig. 6b red.
        for cores in 3..=6 {
            let c = m.cost_coefficient(
                (&d, Scheme::Fp), (&t, Scheme::W8a8),
                Mapping::heterogeneous(cores), 63);
            assert!(c > 1.0, "c_het({cores}) = {c} should be infeasible");
        }
    }

    #[test]
    fn gpu_speeds_up_fp_drafter_vs_single_core() {
        let (_, d) = specs();
        let m = model();
        let cpu1 = m.forward_latency(&d, Scheme::Fp, PuAssignment::Cpu { cores: 1 }, 63);
        let gpu = m.forward_latency(&d, Scheme::Fp, PuAssignment::Gpu, 63);
        let ratio = cpu1 / gpu;
        // Paper: "roughly three times faster"; its c values imply ~2.
        assert!(ratio > 1.8 && ratio < 3.5, "{ratio}");
    }

    #[test]
    fn int8_promotion_hurts_on_gpu() {
        let (t, _) = specs();
        let m = model();
        let fp = m.forward_latency(&t, Scheme::Fp, PuAssignment::Gpu, 63);
        let q = m.forward_latency(&t, Scheme::W8a8, PuAssignment::Gpu, 63);
        assert!(q > fp, "int8 must be slower on Mali ({q} <= {fp})");
    }

    #[test]
    fn int8_helps_on_cpu() {
        let (t, _) = specs();
        let m = model();
        let fp = m.forward_latency(&t, Scheme::Fp, PuAssignment::Cpu { cores: 1 }, 63);
        let q = m.forward_latency(&t, Scheme::W8a8, PuAssignment::Cpu { cores: 1 }, 63);
        assert!(q < fp);
    }

    #[test]
    fn latency_monotone_in_seq_len() {
        let (t, _) = specs();
        let m = model();
        let mut prev = 0.0;
        for s in [16, 32, 48, 64, 96, 128] {
            let l = m.forward_latency(&t, Scheme::Fp, PuAssignment::Cpu { cores: 2 }, s);
            assert!(l > prev);
            prev = l;
        }
    }

    #[test]
    fn batched_latency_amortizes_one_dispatch_boundary() {
        let (t, _) = specs();
        let m = model();
        for pu in [PuAssignment::Cpu { cores: 2 }, PuAssignment::Gpu] {
            let single = m.forward_latency(&t, Scheme::Fp, pu, 63);
            let oh = m.dispatch_overhead(pu);
            // batch = 1 degenerates exactly to the single-call model.
            let b1 = m.batched_forward_latency(&t, Scheme::Fp, pu, 63, 1);
            assert!((b1 - single).abs() < 1e-15, "{b1} vs {single}");
            for b in [2usize, 4, 8] {
                let tb = m.batched_forward_latency(&t, Scheme::Fp, pu, 63, b);
                let expect = (single - oh) * b as f64 + oh;
                assert!((tb - expect).abs() < 1e-15);
                // The whole point of fusing: b lanes in one dispatch are
                // cheaper than b separate dispatches ...
                assert!(tb < single * b as f64);
                // ... by exactly the b-1 saved boundaries.
                assert!((single * b as f64 - tb - (b - 1) as f64 * oh).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn incremental_latency_prices_cache_hits_below_full_forwards() {
        let (t, _) = specs();
        let m = model();
        for pu in [PuAssignment::Cpu { cores: 2 }, PuAssignment::Gpu] {
            let full = m.forward_latency(&t, Scheme::W8a8, pu, 64);
            // No resident KV: numerically the plain forward.
            let cold = m.incremental_forward_latency(&t, Scheme::W8a8, pu, 64, 0);
            assert!((cold - full).abs() < 1e-15, "{cold} vs {full}");
            assert_eq!(m.kv_read_latency(&t, Scheme::W8a8, 0), 0.0);
            // More resident KV -> strictly cheaper forwards (the DRAM
            // read must undercut the compute it replaces at these sizes).
            let mut prev = full;
            for cached in [16usize, 32, 48, 63] {
                let inc = m.incremental_forward_latency(&t, Scheme::W8a8, pu, 64, cached);
                assert!(inc < prev, "cached={cached}: {inc} !< {prev}");
                assert!(inc > m.dispatch_overhead(pu));
                prev = inc;
            }
            // cached is clamped to the bucket.
            let a = m.incremental_forward_latency(&t, Scheme::W8a8, pu, 64, 64);
            let b = m.incremental_forward_latency(&t, Scheme::W8a8, pu, 64, 999);
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn more_cores_faster_target() {
        let (t, _) = specs();
        let m = model();
        let l1 = m.forward_latency(&t, Scheme::Fp, PuAssignment::Cpu { cores: 1 }, 63);
        let l6 = m.forward_latency(&t, Scheme::Fp, PuAssignment::Cpu { cores: 6 }, 63);
        assert!(l6 < l1 / 3.0, "6 cores should be much faster: {l1} -> {l6}");
    }
}
