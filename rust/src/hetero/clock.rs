//! Virtual clock: accrues simulated device-seconds while real execution
//! happens on the PJRT CPU client. Thread-safe; one clock per request (and
//! an aggregate per engine) so per-request simulated latency is exact.

use std::sync::atomic::{AtomicU64, Ordering};

/// Nanosecond-resolution virtual clock.
#[derive(Debug, Default)]
pub struct VirtualClock {
    nanos: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Advance by `seconds` of simulated time.
    pub fn advance(&self, seconds: f64) {
        debug_assert!(seconds >= 0.0 && seconds.is_finite());
        let ns = (seconds * 1e9).round() as u64;
        self.nanos.fetch_add(ns, Ordering::Relaxed);
    }

    /// Current simulated time in seconds.
    pub fn seconds(&self) -> f64 {
        self.nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn reset(&self) {
        self.nanos.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let c = VirtualClock::new();
        c.advance(0.5);
        c.advance(0.25);
        assert!((c.seconds() - 0.75).abs() < 1e-9);
        c.reset();
        assert_eq!(c.seconds(), 0.0);
    }

    #[test]
    fn thread_safe() {
        let c = std::sync::Arc::new(VirtualClock::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.advance(0.001);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!((c.seconds() - 8.0).abs() < 1e-6);
    }
}
