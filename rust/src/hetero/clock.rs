//! Simulated time.
//!
//! Two models live here:
//!
//! * [`VirtualClock`] — the original single accumulating clock: accrues
//!   simulated device-seconds while real execution happens on the PJRT
//!   CPU client. Thread-safe; one clock per request (and an aggregate per
//!   engine) so per-request simulated latency is exact. A single clock
//!   *serializes* everything charged to it — fine for one request's own
//!   compute cost, blind to cross-PU parallelism.
//!
//! * [`PuTimelines`] — the per-PU timeline model behind heterogeneous
//!   overlap: one ready-time per physical PU ([`PuId`]), each dispatch
//!   charged to the timeline its [`PuRoute`](super::pu::PuRoute) names and
//!   started at `max(pu_ready, inputs_ready)`. Dispatches routed to
//!   *different* PUs with satisfied inputs proceed concurrently, so one
//!   session's draft forwards on the GPU overlap co-scheduled sessions'
//!   verify forwards on the CPU cluster — the joint benefit the paper's
//!   cost model predicts for heterogeneous mappings. The timelines also
//!   account per-PU busy time, exact cross-PU overlap seconds, and the
//!   merged makespan, which is what the overlap experiments report
//!   against the cost model's prediction.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};

use super::pu::{PuId, NUM_PUS};

/// Nanosecond-resolution virtual clock.
#[derive(Debug, Default)]
pub struct VirtualClock {
    nanos: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Advance by `seconds` of simulated time and return the new timestamp
    /// (seconds), so callers don't have to re-read via [`Self::seconds`] —
    /// under concurrent advancers a separate read could observe other
    /// threads' increments interleaved between the add and the load.
    pub fn advance(&self, seconds: f64) -> f64 {
        debug_assert!(seconds >= 0.0 && seconds.is_finite());
        let ns = (seconds * 1e9).round() as u64;
        let now = self.nanos.fetch_add(ns, Ordering::Relaxed) + ns;
        now as f64 * 1e-9
    }

    /// Current simulated time in seconds.
    pub fn seconds(&self) -> f64 {
        self.nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn reset(&self) {
        self.nanos.store(0, Ordering::Relaxed);
    }
}

/// One scheduled dispatch's simulated interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub start: f64,
    pub end: f64,
}

/// Point-in-time accounting snapshot of a [`PuTimelines`] (used by the
/// worker to push per-tick deltas into the shared metrics sink).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TimelineSnapshot {
    /// Σ dispatch durations charged to each PU.
    pub busy: [f64; NUM_PUS],
    /// Dispatches charged to each PU.
    pub dispatches: [u64; NUM_PUS],
    /// Seconds during which more than one PU was busy (exact).
    pub overlap_s: f64,
    /// Latest ready time across all PUs — the simulated makespan.
    pub makespan: f64,
}

/// Per-PU simulated timelines with exact cross-PU overlap accounting.
///
/// The **readiness rule**: a dispatch routed to PU *p* with inputs
/// available at `inputs_ready` starts at `max(ready[p], inputs_ready)`
/// and occupies *p* until `start + duration`. Dispatches on one PU
/// serialize; dispatches on different PUs overlap whenever their input
/// dependencies allow.
///
/// In **serialized** mode ([`PuTimelines::serialized`]) every dispatch
/// blocks *all* PUs — the single-`VirtualClock` behavior, where the
/// makespan is exactly the sum of all dispatch durations. This is the
/// `hetero_overlap: false` A/B baseline: identical dispatches, identical
/// per-session charges, no cross-PU concurrency.
#[derive(Debug, Clone)]
pub struct PuTimelines {
    /// Earliest time each PU can start its next dispatch.
    ready: [f64; NUM_PUS],
    busy: [f64; NUM_PUS],
    dispatches: [u64; NUM_PUS],
    overlap_s: f64,
    /// Recent busy intervals per PU, ascending, pruned once no future
    /// dispatch on another PU can reach back into them (every future start
    /// on PU q is ≥ ready[q], so intervals ending at or before
    /// `min_{q≠p} ready[q]` can never intersect a new dispatch again).
    intervals: [VecDeque<(f64, f64)>; NUM_PUS],
    /// Serialized (single-clock) mode: dispatches block every PU.
    serialize: bool,
}

impl Default for PuTimelines {
    fn default() -> PuTimelines {
        PuTimelines::new()
    }
}

impl PuTimelines {
    /// Overlapped per-PU timelines (the heterogeneous-overlap model).
    pub fn new() -> PuTimelines {
        PuTimelines {
            ready: [0.0; NUM_PUS],
            busy: [0.0; NUM_PUS],
            dispatches: [0; NUM_PUS],
            overlap_s: 0.0,
            intervals: std::array::from_fn(|_| VecDeque::new()),
            serialize: false,
        }
    }

    /// Single-clock A/B baseline: every dispatch blocks every PU, so the
    /// makespan degenerates to the serialized sum of dispatch durations.
    pub fn serialized() -> PuTimelines {
        PuTimelines { serialize: true, ..PuTimelines::new() }
    }

    pub fn is_serialized(&self) -> bool {
        self.serialize
    }

    /// Schedule one dispatch on `pu` whose inputs are available at
    /// `inputs_ready`; returns the interval it occupies.
    pub fn dispatch(&mut self, pu: PuId, inputs_ready: f64, duration: f64) -> Span {
        self.dispatch_blocking(pu, &[], inputs_ready, duration)
    }

    /// Schedule one dispatch on `pu` that additionally *occupies* the
    /// `blocked` PUs for its duration without charging them busy time —
    /// the monolithic fused round, whose single graph spans both mapped
    /// partitions (see [`super::pu::PuRoute::mono`]). Blocked PUs accrue
    /// no busy seconds and no overlap (the fused graph's draft and verify
    /// phases are internally sequential).
    pub fn dispatch_blocking(
        &mut self,
        pu: PuId,
        blocked: &[PuId],
        inputs_ready: f64,
        duration: f64,
    ) -> Span {
        debug_assert!(duration >= 0.0 && duration.is_finite());
        debug_assert!(inputs_ready >= 0.0 && inputs_ready.is_finite());
        let p = pu.index();
        let mut start = self.ready[p].max(inputs_ready);
        if self.serialize {
            // Single-clock behavior: queue behind everything.
            for r in self.ready {
                start = start.max(r);
            }
        } else {
            for b in blocked {
                start = start.max(self.ready[b.index()]);
            }
        }
        let end = start + duration;
        self.busy[p] += duration;
        self.dispatches[p] += 1;
        if self.serialize {
            for r in self.ready.iter_mut() {
                *r = end;
            }
            return Span { start, end };
        }
        // Exact cross-PU overlap: intersect with the other PUs' recorded
        // busy intervals (blocked occupancy is deliberately not recorded).
        if duration > 0.0 {
            for q in 0..NUM_PUS {
                if q == p {
                    continue;
                }
                for &(s, e) in &self.intervals[q] {
                    let lo = s.max(start);
                    let hi = e.min(end);
                    if hi > lo {
                        self.overlap_s += hi - lo;
                    }
                }
            }
            // Record, merging with the previous interval when contiguous
            // (back-to-back dispatches are the common case).
            match self.intervals[p].back_mut() {
                Some(last) if start <= last.1 => last.1 = last.1.max(end),
                _ => self.intervals[p].push_back((start, end)),
            }
        }
        self.ready[p] = end;
        for b in blocked {
            let q = b.index();
            self.ready[q] = self.ready[q].max(end);
        }
        self.prune();
        Span { start, end }
    }

    /// Drop busy intervals no future dispatch can intersect.
    fn prune(&mut self) {
        for p in 0..NUM_PUS {
            let mut horizon = f64::INFINITY;
            for (q, &r) in self.ready.iter().enumerate() {
                if q != p {
                    horizon = horizon.min(r);
                }
            }
            while let Some(&(_, e)) = self.intervals[p].front() {
                if e <= horizon {
                    self.intervals[p].pop_front();
                } else {
                    break;
                }
            }
        }
    }

    /// Earliest ready time across PUs — the soonest any dispatch could
    /// start (0 before any dispatch).
    pub fn min_ready(&self) -> f64 {
        let m = self.ready.iter().copied().fold(f64::INFINITY, f64::min);
        if m.is_finite() {
            m
        } else {
            0.0
        }
    }

    /// Simulated "now" for newly admitted work: the earliest ready time
    /// among PUs that have actually dispatched. A PU the workload never
    /// touches (the GPU under a homogeneous mapping or baseline decode)
    /// stays at 0 forever and must not pin admission time to 0 — that
    /// would turn per-request timeline latencies into absolute finish
    /// times. 0 before any dispatch at all.
    pub fn now(&self) -> f64 {
        let m = self
            .ready
            .iter()
            .zip(&self.dispatches)
            .filter(|(_, &d)| d > 0)
            .map(|(&r, _)| r)
            .fold(f64::INFINITY, f64::min);
        if m.is_finite() {
            m
        } else {
            0.0
        }
    }

    /// Latest ready time across PUs — the simulated makespan so far.
    pub fn makespan(&self) -> f64 {
        self.ready.iter().copied().fold(0.0, f64::max)
    }

    /// Σ dispatch durations charged to `pu`.
    pub fn busy(&self, pu: PuId) -> f64 {
        self.busy[pu.index()]
    }

    /// Idle seconds on `pu` up to the current makespan.
    pub fn idle(&self, pu: PuId) -> f64 {
        (self.makespan() - self.busy(pu)).max(0.0)
    }

    /// Exact seconds during which ≥ 2 PUs were simultaneously busy.
    pub fn overlap_s(&self) -> f64 {
        self.overlap_s
    }

    pub fn snapshot(&self) -> TimelineSnapshot {
        TimelineSnapshot {
            busy: self.busy,
            dispatches: self.dispatches,
            overlap_s: self.overlap_s,
            makespan: self.makespan(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_returns_new_timestamp() {
        let c = VirtualClock::new();
        assert!((c.advance(0.5) - 0.5).abs() < 1e-9);
        assert!((c.advance(0.25) - 0.75).abs() < 1e-9);
        assert!((c.seconds() - 0.75).abs() < 1e-9);
        c.reset();
        assert_eq!(c.seconds(), 0.0);
    }

    #[test]
    fn thread_safe() {
        let c = std::sync::Arc::new(VirtualClock::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.advance(0.001);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!((c.seconds() - 8.0).abs() < 1e-6);
    }

    #[test]
    fn independent_pus_overlap() {
        let mut tl = PuTimelines::new();
        let a = tl.dispatch(PuId::Cpu, 0.0, 1.0);
        let b = tl.dispatch(PuId::Gpu, 0.0, 0.6);
        assert_eq!(a, Span { start: 0.0, end: 1.0 });
        assert_eq!(b, Span { start: 0.0, end: 0.6 });
        assert!((tl.makespan() - 1.0).abs() < 1e-12);
        assert!((tl.overlap_s() - 0.6).abs() < 1e-12);
        assert!((tl.busy(PuId::Cpu) - 1.0).abs() < 1e-12);
        assert!((tl.idle(PuId::Gpu) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn same_pu_serializes() {
        let mut tl = PuTimelines::new();
        tl.dispatch(PuId::Cpu, 0.0, 1.0);
        let b = tl.dispatch(PuId::Cpu, 0.0, 0.5);
        assert_eq!(b, Span { start: 1.0, end: 1.5 });
        assert_eq!(tl.overlap_s(), 0.0);
    }

    #[test]
    fn inputs_ready_delays_start() {
        let mut tl = PuTimelines::new();
        // GPU free at 0, but the inputs only materialize at 2.0.
        let s = tl.dispatch(PuId::Gpu, 2.0, 0.5);
        assert_eq!(s, Span { start: 2.0, end: 2.5 });
        // CPU work during the gap overlaps only the busy part.
        let c = tl.dispatch(PuId::Cpu, 0.0, 3.0);
        assert_eq!(c, Span { start: 0.0, end: 3.0 });
        assert!((tl.overlap_s() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn serialized_mode_sums_durations() {
        let mut tl = PuTimelines::serialized();
        tl.dispatch(PuId::Cpu, 0.0, 1.0);
        tl.dispatch(PuId::Gpu, 0.0, 0.5);
        let s = tl.dispatch(PuId::Cpu, 0.0, 0.25);
        assert_eq!(s, Span { start: 1.5, end: 1.75 });
        assert!((tl.makespan() - 1.75).abs() < 1e-12);
        assert!((tl.makespan() - (tl.busy(PuId::Cpu) + tl.busy(PuId::Gpu))).abs() < 1e-12);
        assert_eq!(tl.overlap_s(), 0.0);
    }

    #[test]
    fn blocking_dispatch_occupies_without_busy_or_overlap() {
        let mut tl = PuTimelines::new();
        // A mono round on CPU that also blocks the GPU.
        tl.dispatch_blocking(PuId::Cpu, &[PuId::Gpu], 0.0, 1.0);
        // GPU work must queue behind the blocked window.
        let g = tl.dispatch(PuId::Gpu, 0.0, 0.5);
        assert_eq!(g, Span { start: 1.0, end: 1.5 });
        assert_eq!(tl.busy(PuId::Gpu), 0.5);
        assert_eq!(tl.overlap_s(), 0.0);
    }

    #[test]
    fn overlap_is_exact_across_many_staggered_dispatches() {
        let mut tl = PuTimelines::new();
        // CPU: [0,1], [1,2], [2,3]; GPU: [0.5, 1.5], [1.5, 2.5].
        for _ in 0..3 {
            tl.dispatch(PuId::Cpu, 0.0, 1.0);
        }
        tl.dispatch(PuId::Gpu, 0.5, 1.0);
        tl.dispatch(PuId::Gpu, 0.0, 1.0);
        // GPU busy [0.5, 2.5] entirely inside CPU busy [0, 3].
        assert!((tl.overlap_s() - 2.0).abs() < 1e-12, "{}", tl.overlap_s());
        assert!((tl.makespan() - 3.0).abs() < 1e-12);
        let snap = tl.snapshot();
        assert_eq!(snap.dispatches, [3, 2]);
        assert!((snap.busy[0] - 3.0).abs() < 1e-12);
        assert!((snap.busy[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn now_ignores_pus_the_workload_never_touches() {
        let mut tl = PuTimelines::new();
        assert_eq!(tl.now(), 0.0);
        // CPU-only workload: "now" must track the CPU frontier, not the
        // forever-idle GPU (which would pin admissions to t = 0).
        tl.dispatch(PuId::Cpu, 0.0, 1.0);
        tl.dispatch(PuId::Cpu, 0.0, 1.0);
        assert!((tl.now() - 2.0).abs() < 1e-12);
        assert_eq!(tl.min_ready(), 0.0);
        // Once both PUs have dispatched, now is the earlier frontier.
        tl.dispatch(PuId::Gpu, 0.0, 0.5);
        assert!((tl.now() - 0.5).abs() < 1e-12);
        // A blocked-only PU (mono occupancy, no dispatches charged) still
        // doesn't count as touched.
        let mut mono = PuTimelines::new();
        mono.dispatch_blocking(PuId::Cpu, &[PuId::Gpu], 0.0, 1.0);
        assert!((mono.now() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pruning_keeps_interval_lists_bounded() {
        let mut tl = PuTimelines::new();
        for _ in 0..1000 {
            tl.dispatch(PuId::Cpu, 0.0, 0.001);
            tl.dispatch(PuId::Gpu, 0.0, 0.001);
        }
        // Contiguous merging + pruning: O(1) retained state.
        assert!(tl.intervals[0].len() <= 2, "{}", tl.intervals[0].len());
        assert!(tl.intervals[1].len() <= 2, "{}", tl.intervals[1].len());
        // Fully overlapped alternation: overlap ≈ each PU's busy time.
        assert!((tl.overlap_s() - 1.0).abs() < 1e-9, "{}", tl.overlap_s());
    }
}
