//! Processing-unit descriptors and spatial assignments (paper §III-B).
//!
//! The design space is spanned by *design variants* (how many CPU cores are
//! available, v = Π nᵢ) × *assignments* of each graph partition (drafter |
//! target, m = 2) to one of the N = 2 PUs.

/// Where one graph partition (drafter or target) executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PuAssignment {
    /// CPU cluster with `cores` Cortex-A55 cores (1..=6).
    Cpu { cores: usize },
    /// The Mali-G310 GPU (single shader core).
    Gpu,
}

impl PuAssignment {
    pub fn is_gpu(&self) -> bool {
        matches!(self, PuAssignment::Gpu)
    }

    pub fn label(&self) -> String {
        match self {
            PuAssignment::Cpu { cores } => format!("C-A55 {cores}C"),
            PuAssignment::Gpu => "Mali-G310".to_string(),
        }
    }
}

/// A coarse-grained spatial mapping of the speculative pipeline: one PU per
/// partition (the paper's m = 2 partitioning — drafter | target).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Mapping {
    pub drafter: PuAssignment,
    pub target: PuAssignment,
}

impl Mapping {
    /// Homogeneous CPU mapping: both models on the same `cores`-core cluster.
    pub fn homogeneous(cores: usize) -> Mapping {
        Mapping {
            drafter: PuAssignment::Cpu { cores },
            target: PuAssignment::Cpu { cores },
        }
    }

    /// The paper's heterogeneous mapping: drafter on GPU, target on CPU.
    pub fn heterogeneous(cores: usize) -> Mapping {
        Mapping {
            drafter: PuAssignment::Gpu,
            target: PuAssignment::Cpu { cores },
        }
    }

    pub fn is_heterogeneous(&self) -> bool {
        self.drafter != self.target
    }

    pub fn label(&self) -> String {
        if self.is_heterogeneous() {
            format!("drafter@{} / target@{}", self.drafter.label(), self.target.label())
        } else {
            format!("both@{}", self.target.label())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let h = Mapping::homogeneous(3);
        assert!(!h.is_heterogeneous());
        assert_eq!(h.target, PuAssignment::Cpu { cores: 3 });
        let x = Mapping::heterogeneous(1);
        assert!(x.is_heterogeneous());
        assert!(x.drafter.is_gpu());
    }

    #[test]
    fn labels() {
        assert_eq!(PuAssignment::Cpu { cores: 2 }.label(), "C-A55 2C");
        assert!(Mapping::heterogeneous(1).label().contains("Mali"));
    }
}
