//! Processing-unit descriptors and spatial assignments (paper §III-B).
//!
//! The design space is spanned by *design variants* (how many CPU cores are
//! available, v = Π nᵢ) × *assignments* of each graph partition (drafter |
//! target, m = 2) to one of the N = 2 PUs.

/// Physical processing-unit identity on the SoC — the granularity at which
/// the per-PU timelines serialize dispatches. Two [`PuAssignment::Cpu`]
/// values with different core counts still name the *same* physical CPU
/// cluster, so they share one timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PuId {
    /// The hexacore Cortex-A55 cluster.
    Cpu,
    /// The Mali-G310 GPU.
    Gpu,
}

/// Number of physical PUs on the modeled SoC (CPU cluster + GPU).
pub const NUM_PUS: usize = 2;

impl PuId {
    /// Dense index into per-PU arrays (`0..NUM_PUS`).
    pub fn index(self) -> usize {
        match self {
            PuId::Cpu => 0,
            PuId::Gpu => 1,
        }
    }

    /// All physical PUs, in index order.
    pub fn all() -> [PuId; NUM_PUS] {
        [PuId::Cpu, PuId::Gpu]
    }

    pub fn label(self) -> &'static str {
        match self {
            PuId::Cpu => "cpu",
            PuId::Gpu => "gpu",
        }
    }
}

/// Where one graph partition (drafter or target) executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PuAssignment {
    /// CPU cluster with `cores` Cortex-A55 cores (1..=6).
    Cpu { cores: usize },
    /// The Mali-G310 GPU (single shader core).
    Gpu,
}

impl PuAssignment {
    pub fn is_gpu(&self) -> bool {
        matches!(self, PuAssignment::Gpu)
    }

    /// The physical PU this assignment occupies (core-count variants of the
    /// CPU cluster all serialize on the one cluster timeline).
    pub fn id(&self) -> PuId {
        match self {
            PuAssignment::Cpu { .. } => PuId::Cpu,
            PuAssignment::Gpu => PuId::Gpu,
        }
    }

    pub fn label(&self) -> String {
        match self {
            PuAssignment::Cpu { cores } => format!("C-A55 {cores}C"),
            PuAssignment::Gpu => "Mali-G310".to_string(),
        }
    }
}

/// Timeline routing for one engine call, resolved from the policy-chosen
/// [`Mapping`] when a session plans the call: which PU's timeline the
/// dispatch is charged to, and which additional PU (if any) it occupies.
///
/// Plain forwards run on exactly one PU. A monolithic fused spec-step
/// (paper Fig. 3) spans both mapped partitions inside one graph, so it
/// *blocks* the secondary PU for its duration while its compute time is
/// charged to the primary (target) timeline — co-scheduled sessions cannot
/// overlap with either side of a mono round.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PuRoute {
    /// PU whose timeline is charged (busy time accrues here).
    pub primary: PuAssignment,
    /// Additional PU the dispatch occupies without accruing busy time
    /// (monolithic rounds only; `None` for plain forwards and for mono
    /// rounds whose mapping is homogeneous).
    pub blocks: Option<PuAssignment>,
}

impl PuRoute {
    /// Route for a plain forward on one PU.
    pub fn single(pu: PuAssignment) -> PuRoute {
        PuRoute { primary: pu, blocks: None }
    }

    /// Route for a monolithic fused round under `mapping`: charged to the
    /// target PU, blocking the drafter PU when it is a different device.
    pub fn mono(mapping: Mapping) -> PuRoute {
        PuRoute {
            primary: mapping.target,
            blocks: (mapping.drafter.id() != mapping.target.id()).then_some(mapping.drafter),
        }
    }
}

/// A coarse-grained spatial mapping of the speculative pipeline: one PU per
/// partition (the paper's m = 2 partitioning — drafter | target).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Mapping {
    pub drafter: PuAssignment,
    pub target: PuAssignment,
}

impl Mapping {
    /// Homogeneous CPU mapping: both models on the same `cores`-core cluster.
    pub fn homogeneous(cores: usize) -> Mapping {
        Mapping {
            drafter: PuAssignment::Cpu { cores },
            target: PuAssignment::Cpu { cores },
        }
    }

    /// The paper's heterogeneous mapping: drafter on GPU, target on CPU.
    pub fn heterogeneous(cores: usize) -> Mapping {
        Mapping {
            drafter: PuAssignment::Gpu,
            target: PuAssignment::Cpu { cores },
        }
    }

    pub fn is_heterogeneous(&self) -> bool {
        self.drafter != self.target
    }

    pub fn label(&self) -> String {
        if self.is_heterogeneous() {
            format!("drafter@{} / target@{}", self.drafter.label(), self.target.label())
        } else {
            format!("both@{}", self.target.label())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let h = Mapping::homogeneous(3);
        assert!(!h.is_heterogeneous());
        assert_eq!(h.target, PuAssignment::Cpu { cores: 3 });
        let x = Mapping::heterogeneous(1);
        assert!(x.is_heterogeneous());
        assert!(x.drafter.is_gpu());
    }

    #[test]
    fn labels() {
        assert_eq!(PuAssignment::Cpu { cores: 2 }.label(), "C-A55 2C");
        assert!(Mapping::heterogeneous(1).label().contains("Mali"));
    }

    #[test]
    fn physical_identity_ignores_core_count() {
        assert_eq!(PuAssignment::Cpu { cores: 1 }.id(), PuId::Cpu);
        assert_eq!(PuAssignment::Cpu { cores: 6 }.id(), PuId::Cpu);
        assert_eq!(PuAssignment::Gpu.id(), PuId::Gpu);
        assert_eq!(PuId::all().map(PuId::index), [0, 1]);
    }

    #[test]
    fn mono_route_blocks_the_other_pu_only_when_heterogeneous() {
        let het = PuRoute::mono(Mapping::heterogeneous(2));
        assert_eq!(het.primary, PuAssignment::Cpu { cores: 2 });
        assert_eq!(het.blocks, Some(PuAssignment::Gpu));
        let hom = PuRoute::mono(Mapping::homogeneous(3));
        assert_eq!(hom.primary, PuAssignment::Cpu { cores: 3 });
        assert_eq!(hom.blocks, None);
    }
}
