//! The simulated heterogeneous edge platform (NXP i.MX95 stand-in).
//!
//! We do not have the paper's silicon (hexacore Cortex-A55 + Mali-G310), so
//! per the substitution rule this module provides an *analytic latency
//! model* calibrated to the paper's measured cost coefficients (DESIGN.md
//! §5), while actual token computation executes on the PJRT CPU client.
//! A virtual clock accrues simulated device time; all paper-facing numbers
//! (Fig. 6, Tables II/III, Fig. 7) are read off that clock.

pub mod clock;
pub mod latency;
pub mod platform;
pub mod pu;

pub use clock::{PuTimelines, Span, TimelineSnapshot, VirtualClock};
pub use latency::LatencyModel;
pub use platform::Platform;
pub use pu::{Mapping, PuAssignment, PuId, PuRoute, NUM_PUS};
